//! Regenerate every table and figure of the paper's evaluation section and
//! (optionally) check the headline claims hold in shape.
//!
//!     cargo run --release --example paper_figures            # print all
//!     cargo run --release --example paper_figures -- --check # assert bands
//!     cargo run --release --example paper_figures -- --csv DIR  # CSV dump

use std::io::Write;

use anyhow::Result;
use quick_infer::figures;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let check = args.iter().any(|a| a == "--check");
    let csv_dir = args
        .iter()
        .position(|a| a == "--csv")
        .and_then(|i| args.get(i + 1).cloned());

    let out = &mut std::io::stdout();
    let f3 = figures::fig3(out)?;
    let f7 = figures::fig7(out)?;
    let f8 = figures::fig8(out)?;
    let t1 = figures::table1(out)?;

    if let Some(dir) = csv_dir {
        std::fs::create_dir_all(&dir)?;
        let mut w = std::fs::File::create(format!("{dir}/fig7.csv"))?;
        writeln!(w, "gpu,batch,fp16_tops,awq_tops,quick_tops")?;
        for r in &f7 {
            writeln!(w, "{:?},{},{:.3},{:.3},{:.3}", r.gpu, r.batch, r.fp16, r.awq, r.quick)?;
        }
        let mut w = std::fs::File::create(format!("{dir}/fig8.csv"))?;
        writeln!(w, "model,gpu,batch,fp16_tps,awq_tps,quick_tps")?;
        for r in &f8 {
            writeln!(w, "{:?},{:?},{},{:.1},{:.1},{:.1}", r.model, r.gpu, r.batch, r.fp16, r.awq, r.quick)?;
        }
        let mut w = std::fs::File::create(format!("{dir}/table1.csv"))?;
        writeln!(w, "model,fp16_tps,awq_tps,quick_tps")?;
        for r in &t1 {
            writeln!(
                w,
                "{:?},{:.1},{:.1},{:.1}",
                r.model, r.fp16.total_tok_per_s, r.awq.total_tok_per_s, r.quick.total_tok_per_s
            )?;
        }
        println!("\nCSV written to {dir}/");
    }

    if check {
        println!("\n== headline checks ==");
        // Fig 3: QUICK removes write-back conflicts entirely.
        assert_eq!(f3.quick_conflicts, 0, "Fig3: QUICK conflicts");
        assert!(f3.awq_conflicts > 0, "Fig3: baseline must conflict");
        println!("fig3: baseline {} conflicts, QUICK 0   OK", f3.awq_conflicts);

        // Fig 7 headline: QUICK/AWQ in 1.33–1.91x at batch 256 (band widened
        // ±0.1 for the simulated substrate).
        for r in f7.iter().filter(|r| r.batch == 256) {
            let s = r.quick / r.awq;
            assert!((1.23..=2.01).contains(&s), "fig7 {:?}: {s:.2}x", r.gpu);
            println!("fig7 {:?}: QUICK/AWQ @256 = {s:.2}x   OK", r.gpu);
        }

        // Fig 8: fp16 OOM where the paper says; QUICK >= AWQ.
        let mistral256 = f8
            .iter()
            .find(|r| matches!(r.model, quick_infer::model::Model::Mistral7B) && r.batch == 256)
            .unwrap();
        assert_eq!(mistral256.fp16, 0.0, "fig8: Mistral fp16 @256 must OOM");
        assert!(mistral256.quick > 0.0);
        println!("fig8: Mistral-7B/4090 fp16 OOM @256, QUICK {:.0} tok/s   OK", mistral256.quick);

        // Table 1: speedup bands (paper: +27% vs AWQ Vicuna, +29% 70B).
        for r in &t1 {
            let vs_awq = r.quick.total_tok_per_s / r.awq.total_tok_per_s - 1.0;
            assert!(
                (0.10..0.60).contains(&vs_awq),
                "table1 {:?}: QUICK vs AWQ {vs_awq:+.2}",
                r.model
            );
            println!("table1 {:?}: QUICK vs AWQ {:+.0}%   OK", r.model, vs_awq * 100.0);
        }
        assert!(t1[1].fp16.oom, "table1: 70B fp16 must OOM");
        println!("all headline checks passed");
    }
    Ok(())
}
