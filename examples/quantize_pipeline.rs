//! Offline quantization pipeline demo: the deploy-time tool a user runs to
//! convert fp32 weights into the QUICK on-disk layout, verifying (a) the
//! Rust packer agrees byte-for-byte with the Python packer (golden files)
//! and (b) dequantization round-trips within half an LSB.
//!
//!     make artifacts && cargo run --release --example quantize_pipeline

use anyhow::Result;
use quick_infer::quant;
use quick_infer::runtime::manifest::Manifest;
use quick_infer::runtime::HostTensor;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let (manifest, root) = Manifest::load(std::path::Path::new(&artifacts))?;
    let g = &manifest.pack_golden;
    let dir = root.join("golden");
    let (k, n, gs) = (g.k, g.n, g.group_size);
    println!("pack golden case: {k}x{n}, group {gs}");

    // Load the Python-side fp32 weights and re-run the whole pipeline in Rust.
    let w = HostTensor::from_bin(&dir, g.w.as_ref().unwrap())?;
    let t = quant::quantize_groupwise(w.as_f32()?, k, n, gs);

    // 1. Codes must match numpy's quantizer exactly.
    let codes_py = HostTensor::from_bin(&dir, g.codes.as_ref().unwrap())?;
    assert_eq!(t.codes, codes_py.as_i32()?, "codes mismatch");
    println!("codes: MATCH ({} values)", t.codes.len());

    // 2. Packed layouts must be byte-identical.
    let check_u32 = |name: &str, got: &[u32], spec: &quick_infer::runtime::manifest::BinSpec| -> Result<()> {
        let want = HostTensor::from_bin(&dir, spec)?;
        let want_u32: Vec<u32> = match want {
            HostTensor::U32(v, _) => v,
            _ => anyhow::bail!("{name}: expected u32 golden"),
        };
        assert_eq!(got, &want_u32[..], "{name} mismatch");
        println!("{name}: MATCH ({} words)", got.len());
        Ok(())
    };
    check_u32("awq layout", &quant::pack_awq(&t.codes, k, n), g.awq_words.as_ref().unwrap())?;
    check_u32(
        "quick dequant-order layout",
        &quant::pack_quick_dequant_order(&t.codes, k, n),
        g.quick_words.as_ref().unwrap(),
    )?;
    check_u32("quick interleaved stream", &quant::pack_quick(&t.codes, k, n), g.quick_stream.as_ref().unwrap())?;
    check_u32(
        "qzeros",
        &quant::pack_qzeros(&t.zeros, k / gs, n),
        g.qzeros.as_ref().unwrap(),
    )?;

    // 3. Dequantization round-trip.
    let dq = quant::dequantize(&t);
    let dq_py = HostTensor::from_bin(&dir, g.dequant.as_ref().unwrap())?;
    let max_err = dq
        .iter()
        .zip(dq_py.as_f32()?)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("dequant vs python: max err {max_err:.2e}");
    assert!(max_err < 1e-5);

    println!("quantize_pipeline OK — Rust and Python packers are bit-identical");
    Ok(())
}
