//! Quickstart: load an AOT GEMM artifact, run it through PJRT, and verify
//! against the golden outputs — the smallest end-to-end slice of the stack.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use quick_infer::runtime::Runtime;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut rt = Runtime::open(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());

    // 1. Run the QUICK W4A16 GEMM artifact on its golden inputs.
    let name = "gemm_quick_m16";
    let args = rt.golden_args(name)?;
    println!("executing {name} ({} args)...", args.len());
    let outs = rt.execute(name, &args)?;
    let want = rt.golden_outputs(name)?;
    let err = outs[0].max_abs_diff(&want[0])?;
    println!("max |out - golden| = {err:.3e}");
    assert!(err < 1e-3, "golden mismatch");

    // 2. Compare with the AWQ baseline artifact — different offline layout,
    //    identical math: outputs must agree bitwise-ish.
    let awq_outs = rt.execute("gemm_awq_m16", &rt.golden_args("gemm_awq_m16")?)?;
    let cross = outs[0].max_abs_diff(&awq_outs[0])?;
    println!("max |QUICK - AWQ| = {cross:.3e}");
    assert!(cross < 1e-4);

    // 3. Offline packing in Rust (the deploy-side tool): quantize a matrix
    //    and show the QUICK interleave.
    let (k, n) = (256, 128);
    let w: Vec<f32> = (0..k * n).map(|i| ((i * 2654435761usize) as f32 / u32::MAX as f32) - 0.5).collect();
    let t = quick_infer::quant::quantize_groupwise(&w, k, n, 128);
    let stream = quick_infer::quant::pack_quick(&t.codes, k, n);
    println!("packed {}x{} -> {} interleaved u32 words", k, n, stream.len());

    println!("quickstart OK");
    Ok(())
}
