//! Scale-out example: route a ShareGPT-like workload across N simulated
//! engine replicas and compare routing policies — the vLLM-router-shaped
//! front end over the Table-1 serving simulator.
//!
//!     cargo run --release --example router_scaleout [n_replicas]

use anyhow::Result;
use quick_infer::coordinator::router::{Policy, Router};
use quick_infer::coordinator::simserve::{simulate_serving, SimPolicy};
use quick_infer::gpusim::kernel_model::{Calib, KernelKind};
use quick_infer::gpusim::Gpu;
use quick_infer::model::Model;
use quick_infer::workload::{Request, ShareGptLike};

fn run_policy(policy: Policy, replicas: usize, reqs: &[Request]) -> Result<(f64, f64)> {
    let mut router = Router::new(policy, &vec![0u64; replicas])?;
    let mut shards: Vec<Vec<Request>> = vec![Vec::new(); replicas];
    for r in reqs {
        // Session key: requests from the same synthetic "user" (id / 8)
        // share a prefix in a real deployment.
        let d = router
            .route(r.prompt_tokens + r.gen_tokens, Some(r.id / 8))
            .expect("uncapped replicas always admit");
        shards[d.replica].push(*r);
    }

    // Each replica serves its shard (offline continuous batching); the
    // fleet finishes when the slowest replica does.
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let mut slowest = 0.0f64;
    let mut total_tokens = 0u64;
    for shard in &shards {
        if shard.is_empty() {
            continue;
        }
        let r = simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            shard,
            &SimPolicy::default(),
            &Calib::default(),
        );
        slowest = slowest.max(r.wall_s);
        total_tokens += r.prompt_tokens + r.gen_tokens;
    }
    let imbalance = {
        let sizes: Vec<f64> = shards
            .iter()
            .map(|s| s.iter().map(|r| (r.prompt_tokens + r.gen_tokens) as f64).sum())
            .collect();
        let max = sizes.iter().cloned().fold(0.0, f64::max);
        let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
        max / mean.max(1.0)
    };
    Ok((total_tokens as f64 / slowest.max(1e-9), imbalance))
}

fn main() -> Result<()> {
    let replicas: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let reqs = ShareGptLike::new().offline(1200, 99);
    println!("== router scale-out: {replicas} x A6000 / Vicuna-13B (QUICK), 1200 requests ==");
    println!("{:18} {:>16} {:>12}", "policy", "fleet tok/s", "imbalance");
    let mut results = Vec::new();
    for (name, policy) in [
        ("round-robin", Policy::RoundRobin),
        ("least-loaded", Policy::LeastLoaded),
        ("session-affinity", Policy::SessionAffinity),
    ] {
        let (tput, imb) = run_policy(policy, replicas, &reqs)?;
        println!("{name:18} {tput:>16.1} {imb:>12.3}");
        results.push((name, tput));
    }
    // Least-loaded must not lose to round-robin on a skewed offline queue.
    let rr = results.iter().find(|r| r.0 == "round-robin").unwrap().1;
    let ll = results.iter().find(|r| r.0 == "least-loaded").unwrap().1;
    assert!(ll >= rr * 0.95, "least-loaded regressed: {ll:.0} vs {rr:.0}");
    println!("router_scaleout OK");
    Ok(())
}
