//! End-to-end validation driver (DESIGN.md §6, last row): serve a batched
//! synthetic workload on the real AOT-compiled tiny model through the full
//! stack — admission → continuous batcher → PJRT decode/prefill artifacts —
//! and report latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example serve_e2e [kernel]

use anyhow::Result;
use quick_infer::coordinator::{Engine, EngineConfig, FinishReason, GenerationRequest};
use quick_infer::runtime::Runtime;
use quick_infer::workload;

fn run_kernel(artifacts: &str, kernel: &str, n_requests: usize) -> Result<(f64, u64)> {
    let rt = Runtime::open(artifacts)?;
    let mut engine = Engine::new(
        rt,
        EngineConfig { kernel: kernel.into(), max_queue: 4096, ..Default::default() },
    )?;
    let max_prompt = engine.prefill_window() as u64;
    let reqs = workload::tiny_workload(n_requests, max_prompt, 24, 42);

    let t0 = std::time::Instant::now();
    for r in &reqs {
        let prompt: Vec<i32> = (0..r.prompt_tokens)
            .map(|i| ((r.id * 131 + i * 17) % 512) as i32)
            .collect();
        engine.submit(GenerationRequest {
            id: r.id,
            prompt,
            max_new_tokens: r.gen_tokens as usize,
            temperature: None,
            eos_token: None,
        })?;
    }
    engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("--- kernel = {kernel} ---");
    println!("{}", engine.metrics.report(wall));
    let comps = engine.drain_completions();
    let finished = comps.iter().filter(|c| c.reason != FinishReason::Rejected).count();
    assert_eq!(finished, n_requests, "all requests must finish");
    // Determinism spot check: same engine config must reproduce tokens.
    println!(
        "sample completion (req 0): {:?}",
        comps.iter().find(|c| c.id == 0).map(|c| &c.tokens)
    );
    let gen = engine.metrics.generated_tokens;
    Ok((wall, gen))
}

fn main() -> Result<()> {
    let artifacts = std::env::var("ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let only: Option<String> = std::env::args().nth(1);
    let n_requests = 24;

    println!("== serve_e2e: {n_requests} requests on the AOT tiny model ==\n");
    let kernels: Vec<&str> = match &only {
        Some(k) => vec![k.as_str()],
        None => vec!["quick", "awq", "fp16"],
    };
    let mut results = Vec::new();
    for kernel in kernels {
        let (wall, gen) = run_kernel(&artifacts, kernel, n_requests)?;
        results.push((kernel.to_string(), wall, gen));
        println!();
    }

    println!("== summary (CPU-interpret numerics; kernel-level perf is modeled in gpusim) ==");
    for (kernel, wall, gen) in &results {
        println!("  {kernel:6} {gen} gen tokens in {wall:.2}s -> {:.1} tok/s", *gen as f64 / wall);
    }
    Ok(())
}
