"""AOT pipeline: lower every model/kernel variant to HLO text artifacts.

Python runs ONCE (``make artifacts``); the Rust binary then loads
``artifacts/hlo/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
touches Python again.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True`` — the Rust side unwraps with
``to_tuple()``. See /opt/xla-example/README.md.

Outputs
-------
artifacts/
  manifest.json          — artifact index + shapes + golden vector index
  hlo/<name>.hlo.txt     — one module per (entry, kernel, batch) variant
  golden/<name>.*.bin    — raw little-endian buffers for Rust integration
                           tests (inputs and expected outputs)
  golden/pack_*.bin      — packed-weight buffers for the Rust quant
                           cross-check (byte-identical packing required)
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import pack, quantize
from .kernels.awq_gemm import awq_gemm
from .kernels.fp16_gemm import fp16_gemm
from .kernels.quick_gemm import quick_gemm

# Artifact grid (DESIGN.md §6). Decode batches cover the continuous-batching
# lane counts the Rust engine uses; GEMM M values mirror Fig. 7's batch axis
# at CPU-tractable K=N.
DECODE_BATCHES = (1, 2, 4, 8)
PREFILL_SEQ = 16
GEMM_MS = (1, 16, 64, 128)
GEMM_K = 1024
GEMM_N = 1024
SEED = 2024

CFG = M.ModelConfig(
    vocab=512, d_model=256, n_layers=4, n_heads=4, d_ff=512,
    max_seq=64, group_size=128,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights MUST survive the text
    # round-trip — the default printer elides them as `constant({...})`.
    return comp.as_hlo_text(print_large_constants=True)


def _spec_of(x) -> dict:
    return {"dtype": str(x.dtype), "shape": list(x.shape)}


def _save_bin(path: Path, arr: np.ndarray) -> dict:
    arr = np.ascontiguousarray(arr)
    path.write_bytes(arr.tobytes())
    return {
        "path": str(path.name),
        "dtype": str(arr.dtype),
        "shape": list(arr.shape),
        "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
    }


class Emitter:
    def __init__(self, out_dir: Path, golden: bool = True):
        self.out = out_dir
        self.hlo_dir = out_dir / "hlo"
        self.gold_dir = out_dir / "golden"
        self.hlo_dir.mkdir(parents=True, exist_ok=True)
        self.gold_dir.mkdir(parents=True, exist_ok=True)
        self.manifest: dict = {
            "version": 1,
            "seed": SEED,
            "model_config": dataclasses.asdict(CFG),
            "artifacts": [],
            "pack_golden": {},
        }
        self.golden = golden

    def emit(self, name: str, fn, example_args: tuple, meta: dict) -> None:
        """Lower ``fn(*example_args)``, write HLO + golden vectors."""
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        (self.hlo_dir / f"{name}.hlo.txt").write_text(text)

        entry: dict = dict(meta)
        entry["name"] = name
        entry["path"] = f"hlo/{name}.hlo.txt"
        entry["args"] = [_spec_of(a) for a in example_args]

        outs = fn(*example_args)
        if not isinstance(outs, tuple):
            outs = (outs,)
        entry["outputs"] = [_spec_of(np.asarray(o)) for o in outs]

        if self.golden:
            gold_in, gold_out = [], []
            for i, a in enumerate(example_args):
                gold_in.append(
                    _save_bin(self.gold_dir / f"{name}.arg{i}.bin", np.asarray(a))
                )
            for j, o in enumerate(outs):
                gold_out.append(
                    _save_bin(self.gold_dir / f"{name}.out{j}.bin", np.asarray(o))
                )
            entry["golden"] = {"args": gold_in, "outputs": gold_out}
        self.manifest["artifacts"].append(entry)
        print(f"  {name}: {len(text) / 1e6:.2f} MB hlo, "
              f"{len(example_args)} args -> {len(outs)} outs")

    def finish(self) -> None:
        (self.out / "manifest.json").write_text(
            json.dumps(self.manifest, indent=1)
        )


# ---------------------------------------------------------------------------
# GEMM microbench artifacts (Fig. 7's kernel-level comparison, CPU-scaled)
# ---------------------------------------------------------------------------

def emit_gemms(em: Emitter) -> None:
    rng = np.random.default_rng(SEED)
    w = (rng.standard_normal((GEMM_K, GEMM_N)) * 0.05).astype(np.float32)
    q, s, z = quantize.quantize_groupwise(w, CFG.group_size)
    wq_quick = pack.pack_quick_dequant_order(q)
    wq_awq = pack.pack_awq(q)
    wdq = quantize.dequantize(q, s, z, CFG.group_size)  # fp path uses the
    # dequantized weights so all three kernels compute the same product.

    for m in GEMM_MS:
        x = (rng.standard_normal((m, GEMM_K)) * 0.5).astype(np.float32)
        for kern in M.KERNELS:
            name = f"gemm_{kern}_m{m}"
            if kern == "fp16":
                fn = functools.partial(
                    lambda x_, w_=jnp.asarray(wdq): (fp16_gemm(x_, w_),)
                )
            else:
                kfn = quick_gemm if kern == "quick" else awq_gemm
                wq = wq_quick if kern == "quick" else wq_awq
                fn = functools.partial(
                    lambda x_, k=kfn, ww=jnp.asarray(wq), ss=jnp.asarray(s),
                    zz=jnp.asarray(z): (
                        k(x_, ww, ss, zz, group_size=CFG.group_size),
                    )
                )
            em.emit(
                name, fn, (jnp.asarray(x),),
                {"kind": "gemm", "kernel": kern, "m": m, "k": GEMM_K,
                 "n": GEMM_N, "group_size": CFG.group_size},
            )


# ---------------------------------------------------------------------------
# Model artifacts (decode + prefill), weights baked as constants
# ---------------------------------------------------------------------------

def emit_model(em: Emitter) -> None:
    fp = M.init_params(CFG, seed=SEED)
    params = {
        "quick": M.quantize_params(fp, CFG, "quick"),
        "awq": M.quantize_params(fp, CFG, "awq"),
        "fp16": fp,
    }
    rng = np.random.default_rng(SEED + 1)

    for kern in M.KERNELS:
        p = jax.tree.map(jnp.asarray, params[kern])
        for b in DECODE_BATCHES:
            tokens = rng.integers(0, CFG.vocab, size=(b,)).astype(np.int32)
            pos = rng.integers(0, CFG.max_seq // 2, size=(b,)).astype(np.int32)
            kc, vc = M.empty_cache(CFG, b)

            def decode_fn(t, po, k, v, p=p, kern=kern):
                return M.decode_step(p, CFG, kern, t, po, k, v)

            em.emit(
                f"decode_{kern}_b{b}", decode_fn,
                (jnp.asarray(tokens), jnp.asarray(pos), kc, vc),
                {"kind": "decode", "kernel": kern, "batch": b,
                 "max_seq": CFG.max_seq},
            )

        # Prefill: batch 1, fixed padded prompt length.
        tokens = rng.integers(0, CFG.vocab, size=(1, PREFILL_SEQ)).astype(np.int32)
        length = np.asarray([PREFILL_SEQ - 3], np.int32)
        kc, vc = M.empty_cache(CFG, 1)

        def prefill_fn(t, ln, k, v, p=p, kern=kern):
            return M.prefill(p, CFG, kern, t, ln, k, v)

        em.emit(
            f"prefill_{kern}_b1_s{PREFILL_SEQ}", prefill_fn,
            (jnp.asarray(tokens), jnp.asarray(length), kc, vc),
            {"kind": "prefill", "kernel": kern, "batch": 1,
             "seq": PREFILL_SEQ, "max_seq": CFG.max_seq},
        )


# ---------------------------------------------------------------------------
# Pack golden files: Rust quant/ must reproduce these bytes exactly
# ---------------------------------------------------------------------------

def emit_pack_golden(em: Emitter) -> None:
    rng = np.random.default_rng(SEED + 2)
    K, N, G = 64, 32, 32
    w = rng.standard_normal((K, N)).astype(np.float32)
    q, s, z = quantize.quantize_groupwise(w, G)
    stream, perm = pack.pack_quick(q)
    gold = {
        "k": K, "n": N, "group_size": G,
        "w": _save_bin(em.gold_dir / "pack_w.bin", w),
        "codes": _save_bin(em.gold_dir / "pack_codes.bin", q.astype(np.int32)),
        "scales": _save_bin(em.gold_dir / "pack_scales.bin", s),
        "zeros": _save_bin(em.gold_dir / "pack_zeros.bin", z),
        "awq_words": _save_bin(em.gold_dir / "pack_awq.bin", pack.pack_awq(q)),
        "quick_words": _save_bin(
            em.gold_dir / "pack_quick_words.bin", pack.pack_quick_dequant_order(q)
        ),
        "quick_stream": _save_bin(em.gold_dir / "pack_quick_stream.bin", stream),
        "perm": _save_bin(em.gold_dir / "pack_perm.bin", perm.astype(np.int64)),
        "qzeros": _save_bin(em.gold_dir / "pack_qzeros.bin", pack.pack_qzeros(z)),
        "dequant": _save_bin(
            em.gold_dir / "pack_dequant.bin", quantize.dequantize(q, s, z, G)
        ),
    }
    em.manifest["pack_golden"] = gold


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir")
    ap.add_argument("--no-golden", action="store_true")
    args = ap.parse_args()
    jax.config.update("jax_platform_name", "cpu")

    out = Path(args.out)
    em = Emitter(out, golden=not args.no_golden)
    print("emitting GEMM microbench artifacts...")
    emit_gemms(em)
    print("emitting model artifacts...")
    emit_model(em)
    print("emitting pack golden files...")
    emit_pack_golden(em)
    em.finish()
    print(f"wrote {len(em.manifest['artifacts'])} artifacts to {out}")


if __name__ == "__main__":
    main()
