# L1: Pallas kernels for the paper compute hot-spot (W4A16 GEMM) plus the
# offline packing/interleaving and the pure-jnp oracle.
from . import pack, quantize, ref  # noqa: F401
from .awq_gemm import awq_gemm  # noqa: F401
from .fp16_gemm import fp16_gemm  # noqa: F401
from .quick_gemm import quick_gemm  # noqa: F401
