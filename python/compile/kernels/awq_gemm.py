"""Baseline mixed-precision GEMM kernel modeling the original AutoAWQ path.

Same math as ``quick_gemm.py`` but the weights arrive in the stock
AWQ/FasterTransformer nibble order (``pack.pack_awq``): sequentially unpacked
nibbles come out in permuted column order, so the kernel must **deinterleave
with a gather** before the dot. That gather is the Pallas analogue of the
original CUDA kernel's dequantize → shared-memory write-back → ``ldmatrix``
round-trip whose bank conflicts QUICK removes (paper Figs. 2–3); in the
`gpusim` substrate the very same layout difference is what produces the
conflict counts of Figure 3.

Kept as a first-class kernel (not a test fixture) because every figure in the
paper benchmarks against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .pack import FT_INV
from .quantize import PACK_FACTOR


def _dequant_block_awq(words, scales_blk, zeros_blk, block_k: int, group_size: int):
    """Unpack one word block, then *gather* nibbles back to logical order."""
    shifts = 4 * jnp.arange(PACK_FACTOR, dtype=jnp.uint32)
    nibbles = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF)
    # The deinterleave the naive layout forces (slot p holds FT_ORDER[p]).
    # Static per-slot slicing (not a gather with a captured index array):
    # pallas kernels may not close over array constants.
    nibbles = jnp.stack([nibbles[:, :, int(s)] for s in FT_INV], axis=-1)
    bk, w8, _ = nibbles.shape
    codes = nibbles.reshape(bk, w8 * PACK_FACTOR).astype(jnp.float32)
    g = block_k // group_size
    codes = codes.reshape(g, group_size, w8 * PACK_FACTOR)
    w = (codes - zeros_blk[:, None, :]) * scales_blk[:, None, :]
    return w.reshape(bk, w8 * PACK_FACTOR)


def _awq_kernel(x_ref, qw_ref, s_ref, z_ref, o_ref, *, block_k, group_size):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_block_awq(qw_ref[...], s_ref[...], z_ref[...], block_k, group_size)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def awq_gemm(
    x,
    qwords,
    scales,
    zeros,
    *,
    group_size: int = 128,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """``y = x @ dequant(qwords)`` with stock-AWQ-packed 4-bit weights.

    Interface mirrors :func:`quick_gemm.quick_gemm`; only the offline layout
    (and hence the in-kernel deinterleave) differs.
    """
    M, K = x.shape
    Kw, W = qwords.shape
    N = W * PACK_FACTOR
    assert Kw == K, (Kw, K)
    block_m = min(block_m, max(M, 1))
    if K % block_k != 0 or N % block_n != 0:
        raise ValueError(f"K={K}, N={N} must tile by ({block_k}, {block_n})")
    if block_k % group_size != 0:
        raise ValueError("block_k must be a multiple of group_size")

    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    Mp = M + pad_m
    gpb = block_k // group_size

    out = pl.pallas_call(
        functools.partial(_awq_kernel, block_k=block_k, group_size=group_size),
        grid=(Mp // block_m, N // block_n, K // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n // PACK_FACTOR), lambda m, n, k: (k, n)),
            pl.BlockSpec((gpb, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((gpb, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(x, qwords, scales, zeros)
    return out[:M] if pad_m else out
