"""Activation-aware weight scaling (AWQ, Lin et al. 2023) — the calibration
step that produces the quantized checkpoints QUICK serves.

AWQ's observation: ~1% of weight channels are *salient* because their input
activations are large; scaling those channels up before 4-bit quantization
(and folding the inverse scale into the activations / preceding layer)
preserves them. We implement the standard per-input-channel grid search:

    s_j = mean(|x_j|)^alpha,   alpha in [0, 1) grid
    w'[j, :] = w[j, :] * s_j;  quantize w'; at inference x_j is divided
    by s_j (folded upstream), so the product is unchanged up to
    quantization error.

The search minimizes ||x @ w  -  (x / s) @ dq(q(w * s))||_F on calibration
activations. Used offline only (deploy path); the Rust twin in
`rust/src/quant/search.rs` must agree on the selected alpha (golden test).
"""

from __future__ import annotations

import numpy as np

from . import quantize


def apply_channel_scale(w: np.ndarray, s: np.ndarray) -> np.ndarray:
    """Scale input channel j of ``w`` (K, N) by ``s[j]``."""
    return w * s[:, None]


def quant_dequant(w: np.ndarray, group_size: int) -> np.ndarray:
    q, sc, z = quantize.quantize_groupwise(w, group_size)
    return quantize.dequantize(q, sc, z, group_size)


def reconstruction_error(
    x: np.ndarray, w: np.ndarray, s: np.ndarray, group_size: int
) -> float:
    """||x @ w - (x/s) @ dq(q(w*s))||_F, the AWQ objective."""
    ref = x @ w
    wq = quant_dequant(apply_channel_scale(w, s), group_size)
    got = (x / s[None, :]) @ wq
    return float(np.linalg.norm(ref - got))


def search_awq_scales(
    w: np.ndarray,
    x_calib: np.ndarray,
    group_size: int = 128,
    n_grid: int = 20,
) -> tuple[np.ndarray, float, float]:
    """Grid-search the AWQ exponent alpha.

    w: (K, N) weights; x_calib: (B, K) calibration activations.
    Returns ``(scales (K,), best_alpha, best_err)``; alpha=0 (s=1) is in
    the grid so the search never does worse than plain quantization.
    """
    K = w.shape[0]
    assert x_calib.shape[1] == K
    act_mag = np.abs(x_calib).mean(axis=0)  # (K,)
    act_mag = np.maximum(act_mag, 1e-8)

    best = (np.ones(K, np.float32), 0.0, np.inf)
    for gi in range(n_grid):
        alpha = gi / n_grid
        s = act_mag**alpha
        # Normalize so scales straddle 1 (keeps dynamic range centered).
        s = (s / np.sqrt(s.max() * s.min())).astype(np.float32)
        err = reconstruction_error(x_calib, w, s, group_size)
        if err < best[2]:
            best = (s, alpha, err)
    return best


def quantize_awq(
    w: np.ndarray, x_calib: np.ndarray, group_size: int = 128, n_grid: int = 20
):
    """Full AWQ pipeline: search scales, quantize the scaled weights.

    Returns ``(q, qscales, zeros, channel_scales)``; at inference the
    activation is divided by ``channel_scales`` (folded into the previous
    RMSNorm in a real deployment).
    """
    s, _, _ = search_awq_scales(w, x_calib, group_size, n_grid)
    q, qs, z = quantize.quantize_groupwise(apply_channel_scale(w, s), group_size)
    return q, qs, z, s
