"""Plain full-precision tiled GEMM Pallas kernel — the paper's fp16 baseline.

(The CPU interpret path computes in f32; "fp16" names the *role* — the
unquantized baseline of Figures 7/8 — not the storage dtype. Real-TPU builds
would use bf16 inputs with f32 accumulation on the MXU.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fp16_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def fp16_gemm(
    x,
    w,
    *,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """``y = x @ w`` tiled for the MXU. x: (M, K), w: (K, N)."""
    M, K = x.shape
    Kw, N = w.shape
    assert Kw == K
    block_m = min(block_m, max(M, 1))
    if K % block_k != 0 or N % block_n != 0:
        raise ValueError(f"K={K}, N={N} must tile by ({block_k}, {block_n})")
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    Mp = M + pad_m

    out = pl.pallas_call(
        _fp16_kernel,
        grid=(Mp // block_m, N // block_n, K // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out[:M] if pad_m else out
