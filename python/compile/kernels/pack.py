"""Offline int4 packing and the QUICK interleaving permutations.

This file is the Python twin of ``rust/src/quant`` — both sides must produce
byte-identical buffers (checked by golden-file tests). Three layouts exist:

1. **Linear** (``pack_linear``): word ``j`` of row ``k`` packs the eight
   logical columns ``8j .. 8j+7`` with column ``8j+i`` in nibble slot ``i``.
   The "obvious" layout; used only as a reference point.

2. **AWQ / FasterTransformer order** (``pack_awq``): nibble slot ``p`` of a
   word holds logical column ``8j + FT_ORDER[p]`` with
   ``FT_ORDER = [0, 2, 4, 6, 1, 3, 5, 7]``. This is the layout AutoAWQ ships:
   it lets the parallel i4→f16 dequantizer extract even nibbles with a single
   mask and odd nibbles with one shift+mask (two f16x2 lanes per u32 step).
   The *cost* is that sequentially-unpacked nibbles come out in permuted
   column order, so the original kernel must shuffle them back — on GPU this
   is bound up with the shared-memory write-back that QUICK eliminates.

3. **QUICK order** (``pack_quick``): the dequant-aware reorder of the paper's
   Figure 5 composed with the ldmatrix-aware fragment interleave of Figure 4
   (Figure 6 = composition). Columns are pre-permuted by ``FT_ORDER`` *before*
   AWQ packing, so in-kernel sequential unpack yields logical column order
   directly — zero in-kernel shuffles. The fragment interleave is applied on
   top as a row/word permutation (``quick_fragment_perm``) so that, on the
   paper's hardware, each CUDA thread's ``mma`` fragments are DRAM-contiguous.
   On TPU (our Pallas kernel) the same property makes one VMEM block
   dequantize elementwise into exactly the (K_blk, N_blk) tile the MXU
   consumes — see DESIGN.md §Hardware-Adaptation.

All functions operate on ``(K, N)`` logical codes (values 0..15, int32) and
return ``(K, N // 8)`` uint32 word arrays (plus permutation metadata).
"""

from __future__ import annotations

import numpy as np

from .quantize import PACK_FACTOR, QMAX

# FasterTransformer parallel-dequant nibble order (paper Fig. 5).
FT_ORDER = np.array([0, 2, 4, 6, 1, 3, 5, 7], dtype=np.int64)
# Inverse: logical column i lives in nibble slot FT_INV[i].
FT_INV = np.argsort(FT_ORDER)

# mma.m16n8k16 fragment geometry (paper §3.2): 32 lanes, each lane owns
# (row, col) fragments of the 16x8 B-tile; ldmatrix loads 8x8 sub-matrices
# with lane l holding row l%8's 2-element fragment (Fig. 1).
MMA_M, MMA_N, MMA_K = 16, 8, 16
WARP_LANES = 32


def _check_qn(q: np.ndarray) -> None:
    if q.ndim != 2 or q.shape[1] % PACK_FACTOR != 0:
        raise ValueError(f"bad code shape {q.shape}")
    if q.min() < 0 or q.max() > QMAX:
        raise ValueError("codes out of [0, 15]")


def pack_words(q: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Pack (K, N) int4 codes into (K, N//8) u32 words.

    ``order[p]`` = logical offset (within the group of 8) stored in nibble
    slot ``p`` (slot p occupies bits ``4p .. 4p+3``).
    """
    _check_qn(q)
    K, N = q.shape
    g = q.reshape(K, N // PACK_FACTOR, PACK_FACTOR).astype(np.uint32)
    g = g[:, :, order]  # slot p <- logical order[p]
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32))[None, None, :]
    return (g << shifts).sum(axis=2, dtype=np.uint32)


def unpack_words(words: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_words` — returns (K, N) int32 codes."""
    K, W = words.shape
    shifts = (4 * np.arange(PACK_FACTOR, dtype=np.uint32))[None, None, :]
    g = (words[:, :, None] >> shifts) & np.uint32(0xF)
    out = np.empty((K, W, PACK_FACTOR), dtype=np.int32)
    out[:, :, order] = g.astype(np.int32)  # logical order[p] <- slot p
    return out.reshape(K, W * PACK_FACTOR)


def pack_linear(q: np.ndarray) -> np.ndarray:
    """Layout 1: slot i holds logical column 8j+i."""
    return pack_words(q, np.arange(PACK_FACTOR))


def pack_awq(q: np.ndarray) -> np.ndarray:
    """Layout 2: AutoAWQ/FasterTransformer nibble order (FT_ORDER)."""
    return pack_words(q, FT_ORDER)


def unpack_awq(words: np.ndarray) -> np.ndarray:
    return unpack_words(words, FT_ORDER)


def pack_quick_dequant_order(q: np.ndarray) -> np.ndarray:
    """Layout 3a (Fig. 5): dequant-aware reorder only.

    Equal to AWQ packing of the column-pre-permuted matrix; sequential
    in-kernel unpack (slot p -> column 8j+p) then yields logical order —
    i.e. this is ``pack_linear`` viewed through the FT dequantizer. The
    packed *bits* differ from ``pack_awq``; the *dequantizer* is identical.
    """
    return pack_words(q, np.arange(PACK_FACTOR))


def ldmatrix_fragment_perm(rows: int, n_words: int) -> np.ndarray:
    """Layout 3b (Fig. 4): ldmatrix/mma-aware word interleave.

    Returns ``perm`` of length ``rows * n_words`` such that
    ``flat_out[i] = flat_in[perm[i]]`` reorders the (K, N//8) word grid into
    the order in which the 32 lanes of a warp consume fragments of
    consecutive ``MMA_K x MMA_N`` B-tiles of ``mma.m16n8k16``:

      for each (k_tile, n_tile) in row-major tile order, emit the word of
      (k_tile*16 + lane%16? ...) — concretely lane ``l`` of the warp owns
      rows ``{l//4, l//4+8}`` and the nibble-pair columns ``2*(l%4)`` of each
      8x8 sub-matrix (Fig. 1); grouping the two K-halves of the m16n8k16
      B-operand per lane gives the contiguous-per-lane DRAM order.

    At word granularity (8 columns = one N-tile of the B fragment), tile
    ``(kt, nt)`` covers rows ``16*kt .. 16*kt+15`` and word column ``nt``.
    Lane l reads rows ``16*kt + (l % 4) * 4 + ...``: the exact sub-word
    assignment is below; the function asserts bijectivity.
    """
    K = rows
    W = n_words
    if K % MMA_K != 0:
        raise ValueError(f"rows={K} not a multiple of {MMA_K}")
    perm = np.empty(K * W, dtype=np.int64)
    idx = 0
    # ldmatrix.m8n8.x4 for a 16x16 B-operand region = two 8x8 matrices along
    # K for each of two N-halves; at our word granularity one word = 8
    # columns = the full n8 extent, so the lane->row map is: lane l loads
    # row (l % 8) of sub-matrix (l // 8). Sub-matrices are stacked along K:
    # rows 0-7 (sub 0), 8-15 (sub 1) of the tile.
    for kt in range(K // MMA_K):
        for nt in range(W):
            for lane in range(MMA_K):  # 16 row-fragments per (kt, nt) tile
                sub, r = divmod(lane, 8)
                row = kt * MMA_K + sub * 8 + r
                perm[idx] = row * W + nt
                idx += 1
    assert idx == K * W
    return perm


def apply_word_perm(words: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Flatten, permute, and return a 1-D interleaved word stream."""
    flat = words.reshape(-1)
    return flat[perm]


def invert_perm(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size, dtype=perm.dtype)
    return inv


def pack_quick(q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Full QUICK layout (Fig. 6): dequant-aware column order composed with
    the ldmatrix-aware fragment interleave.

    Returns ``(stream, perm)`` where ``stream`` is the 1-D u32 word stream in
    DRAM order and ``perm`` the applied word permutation (for tests /
    inversion). The two reorders commute because one permutes nibbles inside
    words and the other permutes whole words (paper §3.2, "the patterns are
    independent").
    """
    words = pack_quick_dequant_order(q)
    perm = ldmatrix_fragment_perm(*words.shape)
    return apply_word_perm(words, perm), perm


def unpack_quick(stream: np.ndarray, rows: int, cols: int) -> np.ndarray:
    """Inverse of :func:`pack_quick` — back to logical (K, N) codes."""
    W = cols // PACK_FACTOR
    perm = ldmatrix_fragment_perm(rows, W)
    words = np.empty(rows * W, dtype=np.uint32)
    words[perm] = stream
    return unpack_words(words.reshape(rows, W), np.arange(PACK_FACTOR))


def pack_qzeros(zeros: np.ndarray) -> np.ndarray:
    """Bit-faithful AWQ qzeros packing: (K//G, N) int zero-points ->
    (K//G, N//8) u32 in FT order (AutoAWQ convention)."""
    z = zeros.astype(np.int32)
    if z.min() < 0 or z.max() > QMAX:
        raise ValueError("zeros out of range")
    return pack_words(z, FT_ORDER)


def unpack_qzeros(words: np.ndarray) -> np.ndarray:
    return unpack_words(words, FT_ORDER)
