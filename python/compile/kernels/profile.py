"""L1 structural profiling: VMEM footprint and MXU utilization *estimates*
for the Pallas kernels, derived from their BlockSpecs (DESIGN.md §8).

Interpret-mode wallclock on CPU says nothing about TPU performance, so the
optimization signal for the kernel layer is structural:

* VMEM per grid cell = sum of the blocks resident while one kernel body
  runs (inputs + outputs + the dequantized tile the body materializes).
  Budget: 16 MiB/core (v4/v5 class).
* MXU utilization estimate = fraction of the (8, 128)-aligned systolic
  array the `dot` shapes fill, times an issue-efficiency factor for the
  number of MXU passes per grid cell.
* Op overhead = the element-wise dequant work per MXU pass (shift/and/
  scale are VPU-side and pipeline with the MXU; a gather does not).
"""

from __future__ import annotations

import dataclasses

F32 = 4  # bytes


@dataclasses.dataclass(frozen=True)
class KernelProfile:
    name: str
    block_m: int
    block_n: int
    block_k: int
    group_size: int
    vmem_bytes: int
    mxu_util: float
    has_relayout: bool

    def render(self) -> str:
        return (
            f"{self.name}: blocks ({self.block_m},{self.block_n},{self.block_k}) "
            f"VMEM {self.vmem_bytes / 1024:.1f} KiB  MXU~{self.mxu_util:.0%}  "
            f"relayout={'YES' if self.has_relayout else 'no'}"
        )


def profile_gemm_kernel(
    kind: str,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 128,
    group_size: int = 128,
) -> KernelProfile:
    """Structural profile of one of the three kernels at given blocks."""
    assert kind in ("quick", "awq", "fp16")
    gpb = block_k // group_size
    x_blk = block_m * block_k * F32
    out_blk = block_m * block_n * F32
    if kind == "fp16":
        w_blk = block_k * block_n * F32
        scratch = 0
        meta = 0
    else:
        w_blk = block_k * (block_n // 8) * F32  # packed u32 words
        meta = 2 * gpb * block_n * F32  # scales + zeros blocks
        # both quantized kernels materialize the dequantized (bk, bn) tile
        scratch = block_k * block_n * F32
    vmem = x_blk + w_blk + meta + scratch + out_blk

    # MXU: (8, 128) lanes; a dot of (bm, bk) @ (bk, bn) fills min(bm,8)x...
    # estimate = how full the contraction tiles keep the array.
    sublane_fill = min(block_m, 8) / 8 if block_m < 8 else 1.0
    lane_fill = min(block_n, 128) / 128
    k_fill = min(block_k, 128) / 128
    mxu = sublane_fill * lane_fill * k_fill
    # The AWQ kernel's deinterleave gather sits between the VMEM load and
    # the dot: it is a relayout the MXU pipeline stalls behind.
    has_relayout = kind == "awq"
    if has_relayout:
        mxu *= 0.75  # issue bubbles from the gather (structural estimate)
    return KernelProfile(
        name=f"{kind}_gemm",
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        group_size=group_size,
        vmem_bytes=vmem,
        mxu_util=mxu,
        has_relayout=has_relayout,
    )


VMEM_BUDGET = 16 * 1024 * 1024  # bytes/core


def check_budget(p: KernelProfile) -> bool:
    return p.vmem_bytes <= VMEM_BUDGET
