"""Group-wise 4-bit weight quantization (AWQ storage convention).

Logical layout convention used throughout the repo:

  * ``w``       — fp weight matrix of shape ``(K, N)`` (in_features x
                  out_features), multiplied as ``y = x @ w`` with
                  ``x: (M, K)``.
  * ``q``       — unsigned 4-bit codes, ``(K, N)``, values in ``[0, 15]``.
  * ``scales``  — per-group scales, ``(K // G, N)``.
  * ``zeros``   — per-group zero-points, ``(K // G, N)``; stored as float so
                  dequantization is ``w ≈ (q - z) * s``. (AutoAWQ packs the
                  integer zero-points into ``qzeros``; see ``pack.py`` for the
                  bit-faithful packed form used by the Rust substrate.)

Groups run along K (the reduction axis), matching AWQ/GPTQ.
"""

from __future__ import annotations

import numpy as np

QBITS = 4
QMAX = (1 << QBITS) - 1  # 15
PACK_FACTOR = 32 // QBITS  # 8 nibbles per u32 word


def quantize_groupwise(
    w: np.ndarray, group_size: int = 128
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Asymmetric per-group 4-bit quantization of ``w`` (K, N).

    Returns ``(q, scales, zeros)`` with shapes ``(K, N)``, ``(K//G, N)``,
    ``(K//G, N)``. Zero-points are integral (stored as float32) so that the
    packed ``qzeros`` form in ``pack.py`` is exact.
    """
    K, N = w.shape
    if K % group_size != 0:
        raise ValueError(f"K={K} not divisible by group_size={group_size}")
    G = group_size
    wg = w.reshape(K // G, G, N)
    wmin = wg.min(axis=1)  # (K//G, N)
    wmax = wg.max(axis=1)
    scales = (wmax - wmin) / QMAX
    # Guard degenerate all-equal groups.
    scales = np.where(scales <= 0, 1.0, scales).astype(np.float32)
    zeros = np.clip(np.round(-wmin / scales), 0, QMAX).astype(np.float32)
    q = np.round(wg / scales[:, None, :]) + zeros[:, None, :]
    q = np.clip(q, 0, QMAX).astype(np.int32).reshape(K, N)
    return q, scales, zeros


def dequantize(
    q: np.ndarray, scales: np.ndarray, zeros: np.ndarray, group_size: int = 128
) -> np.ndarray:
    """Inverse of :func:`quantize_groupwise` — ``(q - z) * s`` per group."""
    K, N = q.shape
    G = group_size
    qg = q.reshape(K // G, G, N).astype(np.float32)
    w = (qg - zeros[:, None, :]) * scales[:, None, :]
    return w.reshape(K, N).astype(np.float32)
