"""QUICK mixed-precision (W4A16-style) GEMM as a Pallas kernel.

TPU adaptation of the paper's conflict-free CUDA kernel (DESIGN.md
§Hardware-Adaptation): the quantized weights are packed **offline** in the
QUICK dequant-aware order (``pack.pack_quick_dequant_order``), so the kernel
dequantizes each VMEM block with *purely element-wise* ops — shift, mask,
scale — straight into the (block_k, block_n) tile the MXU ``dot`` consumes.
No in-kernel gather, transpose, or scratch round-trip: this is the TPU
analogue of skipping the shared-memory write-back + ``ldmatrix``.

Contrast with ``awq_gemm.py``, which models the original kernel: same math,
but the AWQ/FasterTransformer nibble order forces an in-kernel deinterleave
gather after unpacking (the analogue of the conflicted write-back).

Pallas runs ``interpret=True`` — CPU PJRT cannot execute Mosaic custom calls;
real-TPU performance is estimated structurally (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import PACK_FACTOR


def _dequant_block(words, scales_blk, zeros_blk, block_k: int, group_size: int):
    """Element-wise unpack + dequant of one (block_k, block_n//8) word block.

    Because of the offline QUICK reorder, nibble slot ``p`` *is* logical
    column ``8j + p``: a reshape finishes the unpack. Returns (block_k,
    block_n) f32.
    """
    shifts = 4 * jnp.arange(PACK_FACTOR, dtype=jnp.uint32)
    nibbles = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF)
    bk, w8, _ = nibbles.shape
    codes = nibbles.reshape(bk, w8 * PACK_FACTOR).astype(jnp.float32)
    # Per-group affine: groups run along K inside the block.
    g = block_k // group_size
    codes = codes.reshape(g, group_size, w8 * PACK_FACTOR)
    w = (codes - zeros_blk[:, None, :]) * scales_blk[:, None, :]
    return w.reshape(bk, w8 * PACK_FACTOR)


def _quick_kernel(x_ref, qw_ref, s_ref, z_ref, o_ref, *, block_k, group_size, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_block(qw_ref[...], s_ref[...], z_ref[...], block_k, group_size)
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def quick_gemm(
    x,
    qwords,
    scales,
    zeros,
    *,
    group_size: int = 128,
    block_m: int = 16,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """``y = x @ dequant(qwords)`` with QUICK-interleaved 4-bit weights.

    x: (M, K) f32; qwords: (K, N//8) u32 packed by
    ``pack.pack_quick_dequant_order``; scales/zeros: (K//G, N) f32.
    M is padded up to ``block_m`` internally (decode batches can be 1).
    """
    M, K = x.shape
    Kw, W = qwords.shape
    N = W * PACK_FACTOR
    assert Kw == K, (Kw, K)
    block_m = min(block_m, max(M, 1))
    if K % block_k != 0 or N % block_n != 0:
        raise ValueError(f"K={K}, N={N} must tile by ({block_k}, {block_n})")
    if block_k % group_size != 0:
        raise ValueError("block_k must be a multiple of group_size")

    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    Mp = M + pad_m
    k_steps = K // block_k
    gpb = block_k // group_size  # scale/zero groups per K-block

    out = pl.pallas_call(
        functools.partial(
            _quick_kernel, block_k=block_k, group_size=group_size, k_steps=k_steps
        ),
        grid=(Mp // block_m, N // block_n, k_steps),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_k, block_n // PACK_FACTOR), lambda m, n, k: (k, n)),
            pl.BlockSpec((gpb, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((gpb, block_n), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(x, qwords, scales, zeros)
    return out[:M] if pad_m else out
