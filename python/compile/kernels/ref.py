"""Pure-jnp correctness oracle for the mixed-precision GEMM kernels.

Everything here is straight-line jax.numpy with no Pallas, no packing
cleverness, and no tiling — the ground truth the kernels are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .quantize import PACK_FACTOR


def dequant_ref(q, scales, zeros, group_size: int):
    """``(q - z) * s`` with groups along K. q: (K, N) int; -> (K, N) f32."""
    K, N = q.shape
    G = group_size
    qg = q.reshape(K // G, G, N).astype(jnp.float32)
    w = (qg - zeros[:, None, :]) * scales[:, None, :]
    return w.reshape(K, N)


def gemm_ref(x, q, scales, zeros, group_size: int):
    """Oracle W4A16 GEMM: dequantize fully, then one jnp.dot.

    x: (M, K) f32, q: (K, N) int codes. Returns (M, N) f32.
    """
    w = dequant_ref(q, scales, zeros, group_size)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def unpack_words_ref(words, order):
    """jnp twin of pack.unpack_words for in-graph use. words: (K, W) uint32."""
    shifts = 4 * jnp.arange(PACK_FACTOR, dtype=jnp.uint32)
    g = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF)
    inv = np.argsort(np.asarray(order))
    g = g[:, :, inv]  # logical order
    K, W, _ = g.shape
    return g.reshape(K, W * PACK_FACTOR).astype(jnp.int32)


def gemm_fp16_ref(x, w):
    """Plain full-precision GEMM oracle."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
