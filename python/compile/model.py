"""L2: Llama-style decoder in JAX with W4-quantized linears (QUICK kernels).

The model is pure-functional: ``prefill`` and ``decode_step`` take and return
the KV cache explicitly so the Rust coordinator can thread cache buffers
between PJRT executions. Every linear layer dispatches to one of the L1
kernels (``quick`` / ``awq`` baseline / ``fp16``), so the whole network
lowers into a single HLO module per (kernel, batch) variant.

Weights are *baked into the HLO as constants* at AOT time (aot.py): artifacts
are self-contained and the Rust request path passes only
``(tokens, pos, k_cache, v_cache)``. See DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import pack, quantize
from .kernels.awq_gemm import awq_gemm
from .kernels.fp16_gemm import fp16_gemm
from .kernels.quick_gemm import quick_gemm

KERNELS = ("quick", "awq", "fp16")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-Llama architecture; all GEMM dims are multiples of 128 so the
    Pallas tiles fit without remainder handling."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 128
    group_size: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0
        for dim in (self.d_model, self.d_ff, self.vocab):
            assert dim % 128 == 0, f"dim {dim} must tile by 128"
        assert self.d_model % self.group_size == 0


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, Any]:
    """Random full-precision parameters (numpy, host-side)."""
    cfg.validate()
    rng = np.random.default_rng(seed)

    def dense(k, n, scale=None):
        scale = scale if scale is not None else (2.0 / (k + n)) ** 0.5
        return (rng.standard_normal((k, n)) * scale).astype(np.float32)

    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "attn_norm": np.ones(d, np.float32),
                "wq": dense(d, d),
                "wk": dense(d, d),
                "wv": dense(d, d),
                "wo": dense(d, d),
                "mlp_norm": np.ones(d, np.float32),
                "w_gate": dense(d, f),
                "w_up": dense(d, f),
                "w_down": dense(f, d),
            }
        )
    return {
        "embed": dense(v, d, scale=0.02),
        "layers": layers,
        "final_norm": np.ones(d, np.float32),
        "lm_head": dense(d, v),
    }


LINEAR_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def quantize_params(params: dict, cfg: ModelConfig, kernel: str) -> dict:
    """Quantize every linear to the packed layout ``kernel`` expects.

    ``fp16`` returns weights unchanged. ``quick``/``awq`` replace each (K, N)
    matrix with ``{"qwords", "scales", "zeros"}`` packed per pack.py.
    """
    if kernel == "fp16":
        return params

    packer = (
        pack.pack_quick_dequant_order if kernel == "quick" else pack.pack_awq
    )

    def quant(w):
        q, s, z = quantize.quantize_groupwise(w, cfg.group_size)
        return {"qwords": packer(q), "scales": s, "zeros": z}

    out = {
        "embed": params["embed"],
        "final_norm": params["final_norm"],
        "lm_head": quant(params["lm_head"]),
        "layers": [],
    }
    for lyr in params["layers"]:
        qlyr = dict(lyr)
        for name in LINEAR_NAMES:
            qlyr[name] = quant(lyr[name])
        out["layers"].append(qlyr)
    return out


def _linear(x, w, cfg: ModelConfig, kernel: str):
    """Dispatch one (M, K) x (K, N) projection to the selected L1 kernel."""
    if kernel == "fp16":
        return fp16_gemm(x, w)
    fn = quick_gemm if kernel == "quick" else awq_gemm
    return fn(
        x, w["qwords"], w["scales"], w["zeros"], group_size=cfg.group_size
    )


# ---------------------------------------------------------------------------
# Model math
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def rope(x, positions, theta, head_dim):
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S) or (1, S)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_decode(q, k_cache, v_cache, pos, cfg: ModelConfig):
    """Single-token attention against the cache.

    q: (B, H, hd); caches: (B, S, H, hd); pos: (B,) current index.
    Causal mask: attend to cache slots 0..pos inclusive.
    """
    scores = jnp.einsum("bhd,bshd->bhs", q, k_cache) / np.sqrt(cfg.head_dim)
    slot = jnp.arange(cfg.max_seq)[None, None, :]
    mask = slot <= pos[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, v_cache)


def _attention_prefill(q, k, v, cfg: ModelConfig):
    """Full causal attention. q,k,v: (B, S, H, hd)."""
    S = q.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def decode_step(params, cfg: ModelConfig, kernel: str, tokens, pos, k_cache, v_cache):
    """One token of autoregressive decode for a batch.

    tokens: (B,) i32; pos: (B,) i32 per-sequence positions (continuous
    batching: each lane has its own length); caches: (L, B, S, H, hd) f32.
    Returns (logits (B, V), k_cache', v_cache').
    """
    B = tokens.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]  # (B, d)

    new_k, new_v = [], []
    for li, lyr in enumerate(params["layers"]):
        h = rms_norm(x, lyr["attn_norm"], cfg.norm_eps)
        q = _linear(h, lyr["wq"], cfg, kernel).reshape(B, 1, H, hd)
        k = _linear(h, lyr["wk"], cfg, kernel).reshape(B, 1, H, hd)
        v = _linear(h, lyr["wv"], cfg, kernel).reshape(B, H, hd)
        q = rope(q, pos[:, None], cfg.rope_theta, hd).reshape(B, H, hd)
        k = rope(k, pos[:, None], cfg.rope_theta, hd).reshape(B, H, hd)

        # Scatter this step's K/V into each lane's slot `pos[b]`.
        kc = jax.vmap(
            lambda cache, val, p: jax.lax.dynamic_update_slice(
                cache, val[None], (p, 0, 0)
            )
        )(k_cache[li], k, pos)
        vc = jax.vmap(
            lambda cache, val, p: jax.lax.dynamic_update_slice(
                cache, val[None], (p, 0, 0)
            )
        )(v_cache[li], v, pos)
        new_k.append(kc)
        new_v.append(vc)

        attn = _attention_decode(q, kc, vc, pos, cfg).reshape(B, cfg.d_model)
        x = x + _linear(attn, lyr["wo"], cfg, kernel)

        h = rms_norm(x, lyr["mlp_norm"], cfg.norm_eps)
        gate = _linear(h, lyr["w_gate"], cfg, kernel)
        up = _linear(h, lyr["w_up"], cfg, kernel)
        x = x + _linear(jax.nn.silu(gate) * up, lyr["w_down"], cfg, kernel)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _linear(x, params["lm_head"], cfg, kernel)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill(params, cfg: ModelConfig, kernel: str, tokens, length, k_cache, v_cache):
    """Process a padded prompt. tokens: (B, S) i32, length: (B,) true lengths.

    Returns (last_logits (B, V), k_cache', v_cache') where last_logits is the
    logits at each lane's final real token (ready for the first sampled
    token). Padding tokens beyond ``length`` write garbage K/V into slots
    >= length; the decode-step causal mask (slot <= pos) never reads them.
    """
    B, S = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = params["embed"][tokens]  # (B, S, d)

    new_k, new_v = [], []
    for li, lyr in enumerate(params["layers"]):
        h = rms_norm(x, lyr["attn_norm"], cfg.norm_eps)
        flat = h.reshape(B * S, cfg.d_model)
        q = _linear(flat, lyr["wq"], cfg, kernel).reshape(B, S, H, hd)
        k = _linear(flat, lyr["wk"], cfg, kernel).reshape(B, S, H, hd)
        v = _linear(flat, lyr["wv"], cfg, kernel).reshape(B, S, H, hd)
        q = rope(q, positions, cfg.rope_theta, hd)
        k = rope(k, positions, cfg.rope_theta, hd)

        attn = _attention_prefill(q, k, v, cfg).reshape(B * S, cfg.d_model)
        x = x + _linear(attn, lyr["wo"], cfg, kernel).reshape(B, S, cfg.d_model)

        h = rms_norm(x, lyr["mlp_norm"], cfg.norm_eps).reshape(B * S, cfg.d_model)
        gate = _linear(h, lyr["w_gate"], cfg, kernel)
        up = _linear(h, lyr["w_up"], cfg, kernel)
        mlp = _linear(jax.nn.silu(gate) * up, lyr["w_down"], cfg, kernel)
        x = x + mlp.reshape(B, S, cfg.d_model)

        # Write prompt K/V into cache slots 0..S-1 (cache max_seq >= S).
        kc = jnp.zeros_like(k_cache[li]).at[:, :S].set(k)
        vc = jnp.zeros_like(v_cache[li]).at[:, :S].set(v)
        new_k.append(kc)
        new_v.append(vc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, (length - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]  # (B, d)
    logits = _linear(last, params["lm_head"], cfg, kernel)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def empty_cache(cfg: ModelConfig, batch: int):
    shape = (cfg.n_layers, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)
