#!/usr/bin/env python3
"""Generate golden packing/interleaving vectors for the Rust differential
tests (``rust/tests/differential_quant.rs``).

The Python side (``python/compile/kernels/pack.py``) is the reference
implementation; the Rust side (``rust/src/quant``) must reproduce every
buffer bit-exactly. This script freezes the reference's outputs into plain
text fixtures under ``rust/tests/fixtures/`` — inputs included, so the two
sides never need to agree on an RNG — and CI fails if either side drifts.

Usage::

    python3 python/tests/gen_golden_fixtures.py [out_dir]

Regenerate (and commit the diff) only when the *reference* layout
intentionally changes.
"""

from __future__ import annotations

import pathlib
import sys

import numpy as np

HERE = pathlib.Path(__file__).resolve()
sys.path.insert(0, str(HERE.parent.parent))  # python/

from compile.kernels import pack  # noqa: E402

# Shapes exercise: minimal tile (one 16-row k-tile), non-square, wide-N,
# and a deep-K case with the default group size.
CASES = [
    dict(k=16, n=64, seed=1, group_size=8),
    dict(k=48, n=32, seed=2, group_size=16),
    dict(k=64, n=128, seed=3, group_size=64),
    dict(k=128, n=64, seed=4, group_size=128),
]

# Quantized-KV cases (``rust/src/quant/kv.rs`` + the fused attention
# microkernel in ``rust/src/kernel/attention.rs``): per-token head-dim-group
# asymmetric quantization, packed little-endian into u32 words. K and V bit
# widths may differ; the first case also pins the degenerate constant-group
# (``s = 1.0``) path. Inputs are stored as f32 bit patterns, so the Rust
# side reproduces packing/metadata *bit-exactly* with no RNG coupling, and
# the f64-reference attention output is tolerance-checked.
KV_CASES = [
    dict(seq=40, d=64, group=32, kbits=4, vbits=4, m=4, seed=101),
    dict(seq=24, d=32, group=16, kbits=8, vbits=8, m=2, seed=102),
    dict(seq=9, d=64, group=64, kbits=8, vbits=4, m=3, seed=103),
]

# LUT-decode cases (``rust/src/quant/codebook.rs`` + the LUT decoders in
# ``rust/src/quant/decode.rs``): groupwise quantization onto a 16-entry
# codebook and the shared decode affine ``(table[q] - z) * s``. The
# decimal strings below are the shortest reprs of the exact f32 constants
# the Rust tables carry — both languages parse them to identical bits.
LUT_TABLES = {
    "int4": np.arange(16, dtype=np.float32),
    "nf4": np.array(
        [
            -1.0, -0.6961928, -0.52507305, -0.3949175, -0.28444138, -0.18477343,
            -0.091050036, 0.0, 0.0795803, 0.1609302, 0.2461123, 0.33791524,
            0.44070983, 0.562617, 0.72295684, 1.0,
        ],
        dtype=np.float32,
    ),
    "mxfp4": np.array(
        [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
         -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0],
        dtype=np.float32,
    ),
}

LUT_CASES = [
    dict(codebook="int4", k=32, n=32, group_size=16, seed=201),
    dict(codebook="nf4", k=64, n=32, group_size=32, seed=202),
    dict(codebook="mxfp4", k=32, n=64, group_size=16, seed=203),
]


def words_hex(a: np.ndarray) -> str:
    return " ".join(f"{w:08x}" for w in np.asarray(a, dtype=np.uint32).reshape(-1))


def f32_words_hex(a: np.ndarray) -> str:
    """f32 buffer rendered as 8-hex-digit IEEE-754 bit patterns."""
    flat = np.ascontiguousarray(a, dtype=np.float32).reshape(-1)
    return words_hex(flat.view(np.uint32))


def nibbles_hex(a: np.ndarray) -> str:
    return "".join(f"{int(v):x}" for v in np.asarray(a).reshape(-1))


def quantize_kv_np(x: np.ndarray, group: int, bits: int):
    """Bit-exact numpy mirror of Rust ``quant::kv::quantize_kv``.

    All arithmetic stays in float32 and ``np.rint`` rounds half-to-even,
    matching Rust's ``round_ties_even`` — the packed words, scales and
    zeros must agree with the Rust implementation bit for bit.
    """
    seq, d = x.shape
    assert bits in (4, 8) and group % 8 == 0 and d % group == 0
    qmax = np.float32((1 << bits) - 1)
    cpw = 32 // bits
    g = x.reshape(seq, d // group, group)
    lo = g.min(axis=2)
    hi = g.max(axis=2)
    s = (hi - lo) / qmax
    s = np.where(s <= np.float32(0.0), np.float32(1.0), s).astype(np.float32)
    z = np.clip(np.rint(-lo / s), np.float32(0.0), qmax).astype(np.float32)
    q = np.clip(np.rint(g / s[:, :, None]) + z[:, :, None], np.float32(0.0), qmax)
    q = q.reshape(seq, d).astype(np.uint32)
    words = np.zeros((seq, d // cpw), np.uint32)
    for j in range(d):
        words[:, j // cpw] |= q[:, j] << np.uint32(bits * (j % cpw))
    return words, s, z


def dequantize_kv_np(words, scales, zeros, seq, d, group, bits):
    """Numpy mirror of the Rust scalar KV row decoder: ``(q - z) * s``."""
    cpw = 32 // bits
    mask = np.uint32((1 << bits) - 1)
    q = np.zeros((seq, d), np.float32)
    for j in range(d):
        q[:, j] = ((words[:, j // cpw] >> np.uint32(bits * (j % cpw))) & mask).astype(
            np.float32
        )
    gi = np.arange(d) // group
    return (q - zeros[:, gi]) * scales[:, gi]


def quantize_groupwise_np(w: np.ndarray, gs: int):
    """Bit-exact numpy mirror of Rust ``quant::quantize_groupwise``
    (asymmetric min/max affine on the uniform INT4 grid). All arithmetic
    stays in float32; ``np.rint`` rounds half-to-even like Rust's
    ``round_ties_even``."""
    k, n = w.shape
    qmax = np.float32(15.0)
    g = w.reshape(k // gs, gs, n)
    lo = g.min(axis=1)
    hi = g.max(axis=1)
    s = ((hi - lo) / qmax).astype(np.float32)
    s = np.where(s <= np.float32(0.0), np.float32(1.0), s).astype(np.float32)
    z = np.clip(np.rint(-lo / s), np.float32(0.0), qmax).astype(np.float32)
    q = np.clip(np.rint(g / s[:, None, :]) + z[:, None, :], np.float32(0.0), qmax)
    return q.reshape(k, n).astype(np.int32), s, z


def quantize_codebook_np(w: np.ndarray, gs: int, table: np.ndarray):
    """Bit-exact numpy mirror of Rust
    ``quant::quantize_groupwise_codebook`` on a non-uniform grid:
    absmax-scaled nearest-entry rounding with zero zero-points; the first
    minimizing entry wins ties (``np.argmin`` == Rust's strict ``<``)."""
    k, n = w.shape
    absmax = np.abs(w).reshape(k // gs, gs, n).max(axis=1)
    s = (absmax / np.float32(np.abs(table).max())).astype(np.float32)
    s = np.where(s <= np.float32(0.0), np.float32(1.0), s).astype(np.float32)
    t = (w / np.repeat(s, gs, axis=0)).astype(np.float32)
    codes = np.argmin(np.abs(t[:, :, None] - table[None, None, :]), axis=2)
    return codes.astype(np.int32), s, np.zeros_like(s)


def lut_dequantize_np(codes, s, z, gs, table):
    """Numpy mirror of the Rust LUT decode affine ``(table[q] - z) * s``
    (``quant::dequantize_into`` / the LUT decoders)."""
    se = np.repeat(s, gs, axis=0)
    ze = np.repeat(z, gs, axis=0)
    return ((table[codes] - ze) * se).astype(np.float32)


def naive_attention_np(q, k, v, scale):
    """f64 reference: ``softmax(q k^T * scale) v``, cast to f32 at the end
    (mirrors Rust ``kernel::naive_attention`` up to f64 summation order)."""
    s = (q.astype(np.float64) @ k.astype(np.float64).T) * float(scale)
    s -= s.max(axis=1, keepdims=True)
    p = np.exp(s)
    out = (p @ v.astype(np.float64)) / p.sum(axis=1, keepdims=True)
    return out.astype(np.float32)


def main(out_dir: str) -> None:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for c in CASES:
        k, n, seed, gs = c["k"], c["n"], c["seed"], c["group_size"]
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, 16, size=(k, n)).astype(np.int32)
        zeros = rng.integers(0, 16, size=(k // gs, n)).astype(np.int32)

        linear = pack.pack_linear(codes)
        awq = pack.pack_awq(codes)
        quick, perm = pack.pack_quick(codes)
        qzeros = pack.pack_qzeros(zeros)

        # The reference must at least round-trip with itself.
        np.testing.assert_array_equal(pack.unpack_awq(awq), codes)
        np.testing.assert_array_equal(pack.unpack_quick(quick, k, n), codes)
        np.testing.assert_array_equal(pack.ldmatrix_fragment_perm(k, n // 8), perm)
        np.testing.assert_array_equal(pack.unpack_qzeros(qzeros), zeros)

        path = out / f"pack_k{k}_n{n}.txt"
        with open(path, "w") as f:
            f.write("# golden vectors — generated by python/tests/gen_golden_fixtures.py\n")
            f.write("# reference: python/compile/kernels/pack.py; do not edit by hand\n")
            f.write(f"k {k}\n")
            f.write(f"n {n}\n")
            f.write(f"seed {seed}\n")
            f.write(f"group_size {gs}\n")
            f.write(f"codes {nibbles_hex(codes)}\n")
            f.write(f"zeros {nibbles_hex(zeros)}\n")
            f.write(f"linear {words_hex(linear)}\n")
            f.write(f"awq {words_hex(awq)}\n")
            f.write(f"quick {words_hex(quick)}\n")
            f.write(f"qzeros {words_hex(qzeros)}\n")
            f.write(f"perm {' '.join(str(int(p)) for p in perm)}\n")
        print(f"wrote {path}")

    for c in LUT_CASES:
        cb, k, n, gs, seed = c["codebook"], c["k"], c["n"], c["group_size"], c["seed"]
        table = LUT_TABLES[cb]
        rng = np.random.default_rng(seed)
        w = rng.uniform(-1.0, 1.0, size=(k, n)).astype(np.float32)
        # Pin the degenerate path: an all-equal (uniform) / all-zero
        # (non-uniform) first group quantizes with s = 1.
        w[:gs, 0] = np.float32(0.5) if cb == "int4" else np.float32(0.0)

        if cb == "int4":
            codes, s, z = quantize_groupwise_np(w, gs)
        else:
            codes, s, z = quantize_codebook_np(w, gs, table)
        dq = lut_dequantize_np(codes, s, z, gs, table)
        quick, _ = pack.pack_quick(codes)

        assert codes.min() >= 0 and codes.max() <= 15
        np.testing.assert_array_equal(pack.unpack_quick(quick, k, n), codes)

        path = out / f"lut_{cb}_k{k}_n{n}.txt"
        with open(path, "w") as f:
            f.write("# golden LUT-decode vectors — generated by "
                    "python/tests/gen_golden_fixtures.py\n")
            f.write("# f32 buffers are IEEE-754 bit patterns; do not edit by hand\n")
            f.write(f"codebook {cb}\n")
            f.write(f"k {k}\n")
            f.write(f"n {n}\n")
            f.write(f"group_size {gs}\n")
            f.write(f"seed {seed}\n")
            f.write(f"w {f32_words_hex(w)}\n")
            f.write(f"codes {nibbles_hex(codes)}\n")
            f.write(f"quick {words_hex(quick)}\n")
            f.write(f"scales {f32_words_hex(s)}\n")
            f.write(f"zeros {f32_words_hex(z)}\n")
            f.write(f"dequant {f32_words_hex(dq)}\n")
        print(f"wrote {path}")

    for c in KV_CASES:
        seq, d, gs = c["seq"], c["d"], c["group"]
        kb, vb, m, seed = c["kbits"], c["vbits"], c["m"], c["seed"]
        rng = np.random.default_rng(seed)
        k = rng.uniform(-1.0, 1.0, size=(seq, d)).astype(np.float32)
        v = rng.uniform(-1.0, 1.0, size=(seq, d)).astype(np.float32)
        q = rng.uniform(-1.0, 1.0, size=(m, d)).astype(np.float32)
        # Pin the degenerate path: an all-equal group quantizes with s = 1.
        k[0, :gs] = np.float32(0.5)

        kw, ks, kz = quantize_kv_np(k, gs, kb)
        vw, vs, vz = quantize_kv_np(v, gs, vb)
        kd = dequantize_kv_np(kw, ks, kz, seq, d, gs, kb)
        vd = dequantize_kv_np(vw, vs, vz, seq, d, gs, vb)

        # The reference must round-trip within half a quantization step.
        gi = np.arange(d) // gs
        assert np.all(np.abs(k - kd) <= ks[:, gi] * 0.5 + 1e-5)
        assert np.all(np.abs(v - vd) <= vs[:, gi] * 0.5 + 1e-5)

        scale = np.float32(1.0) / np.sqrt(np.float32(d))
        attn = naive_attention_np(q, kd, vd, scale)

        path = out / f"kv_s{seq}_d{d}_b{kb}{vb}.txt"
        with open(path, "w") as f:
            f.write("# golden KV-quant vectors — generated by "
                    "python/tests/gen_golden_fixtures.py\n")
            f.write("# f32 buffers are IEEE-754 bit patterns; do not edit by hand\n")
            f.write(f"seq {seq}\n")
            f.write(f"d {d}\n")
            f.write(f"group {gs}\n")
            f.write(f"kbits {kb}\n")
            f.write(f"vbits {vb}\n")
            f.write(f"m {m}\n")
            f.write(f"seed {seed}\n")
            f.write(f"q {f32_words_hex(q)}\n")
            f.write(f"k {f32_words_hex(k)}\n")
            f.write(f"v {f32_words_hex(v)}\n")
            f.write(f"k_words {words_hex(kw)}\n")
            f.write(f"k_scales {f32_words_hex(ks)}\n")
            f.write(f"k_zeros {f32_words_hex(kz)}\n")
            f.write(f"v_words {words_hex(vw)}\n")
            f.write(f"v_scales {f32_words_hex(vs)}\n")
            f.write(f"v_zeros {f32_words_hex(vz)}\n")
            f.write(f"attn {f32_words_hex(attn)}\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    default_out = HERE.parent.parent.parent / "rust" / "tests" / "fixtures"
    main(sys.argv[1] if len(sys.argv) > 1 else str(default_out))
