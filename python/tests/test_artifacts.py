"""Artifact integrity tests: run after `make artifacts` (skipped when the
artifacts directory is absent, e.g. on a fresh checkout)."""

import hashlib
import json
from pathlib import Path

import pytest

ART = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_every_artifact_file_exists(manifest):
    for a in manifest["artifacts"]:
        p = ART / a["path"]
        assert p.exists(), a["name"]
        assert p.stat().st_size > 1000, f"{a['name']} suspiciously small"


def test_hlo_constants_not_elided(manifest):
    """The baked weights must survive the text round-trip: an elided
    constant prints as `constant({...})` and would silently zero the
    weights after parsing (regression guard for print_large_constants)."""
    for a in manifest["artifacts"]:
        if a["kind"] != "decode" or a["kernel"] != "quick":
            continue
        text = (ART / a["path"]).read_text()
        assert "constant({...})" not in text, a["name"]
        break
    else:
        pytest.fail("no quick decode artifact found")


def test_golden_checksums_match(manifest):
    checked = 0
    for a in manifest["artifacts"][:6]:  # spot-check a prefix, cheap
        g = a.get("golden")
        if not g:
            continue
        for spec in g["args"] + g["outputs"]:
            data = (ART / "golden" / spec["path"]).read_bytes()
            assert hashlib.sha256(data).hexdigest()[:16] == spec["sha256"], spec
            checked += 1
    assert checked > 0


def test_decode_grid_is_complete(manifest):
    """The engine needs a contiguous power-of-two decode ladder per kernel
    plus one prefill module."""
    for kern in ("quick", "awq", "fp16"):
        batches = sorted(
            a["batch"]
            for a in manifest["artifacts"]
            if a["kind"] == "decode" and a["kernel"] == kern
        )
        assert batches == [1, 2, 4, 8], (kern, batches)
        prefills = [
            a for a in manifest["artifacts"]
            if a["kind"] == "prefill" and a["kernel"] == kern
        ]
        assert len(prefills) == 1


def test_arg_specs_match_model_config(manifest):
    mc = manifest["model_config"]
    for a in manifest["artifacts"]:
        if a["kind"] != "decode":
            continue
        b = a["batch"]
        tokens, pos, kc, vc = a["args"]
        assert tokens["shape"] == [b] and tokens["dtype"] == "int32"
        assert pos["shape"] == [b]
        head_dim = mc["d_model"] // mc["n_heads"]
        want = [mc["n_layers"], b, mc["max_seq"], mc["n_heads"], head_dim]
        assert kc["shape"] == want and vc["shape"] == want, a["name"]
        logits = a["outputs"][0]
        assert logits["shape"] == [b, mc["vocab"]]
