"""AWQ activation-aware scale search tests."""

import numpy as np
import pytest

from compile.kernels import awq_search, quantize


def make_outlier_case(k=128, n=64, b=32, seed=0):
    """Weights + activations where a few channels carry big activations —
    the regime AWQ exists for."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n)).astype(np.float32) * 0.1
    x = rng.standard_normal((b, k)).astype(np.float32)
    # 4 salient channels with 30x activations
    hot = rng.choice(k, size=4, replace=False)
    x[:, hot] *= 30.0
    return w, x


def test_awq_beats_plain_quantization_with_outliers():
    w, x = make_outlier_case()
    s, alpha, err_awq = awq_search.search_awq_scales(w, x, group_size=32)
    err_plain = awq_search.reconstruction_error(x, w, np.ones(w.shape[0], np.float32), 32)
    assert err_awq < err_plain * 0.95, (err_awq, err_plain)
    assert alpha > 0.0  # a nontrivial exponent won


def test_alpha_zero_in_grid_never_worse():
    """Without outliers, the search may pick alpha=0 — but must never do
    worse than plain quantization."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    x = rng.standard_normal((16, 64)).astype(np.float32)
    _, _, err_awq = awq_search.search_awq_scales(w, x, group_size=32)
    err_plain = awq_search.reconstruction_error(x, w, np.ones(64, np.float32), 32)
    assert err_awq <= err_plain + 1e-6


def test_scaling_is_mathematically_transparent():
    """Without quantization, (x/s) @ (w*s) == x @ w exactly-ish."""
    w, x = make_outlier_case(seed=2)
    s = np.abs(x).mean(axis=0).astype(np.float32) ** 0.5
    s /= np.sqrt(s.max() * s.min())
    ref = x @ w
    got = (x / s[None, :]) @ awq_search.apply_channel_scale(w, s)
    np.testing.assert_allclose(ref, got, rtol=1e-4, atol=1e-4)


def test_quantize_awq_end_to_end():
    w, x = make_outlier_case(seed=3)
    q, qs, z, s = awq_search.quantize_awq(w, x, group_size=32, n_grid=10)
    assert q.shape == w.shape and s.shape == (w.shape[0],)
    # Reconstruction through the packed form stays below plain error.
    wq = quantize.dequantize(q, qs, z, 32)
    got = (x / s[None, :]) @ wq
    err = np.linalg.norm(x @ w - got)
    err_plain = awq_search.reconstruction_error(x, w, np.ones(w.shape[0], np.float32), 32)
    assert err <= err_plain


def test_rejects_shape_mismatch():
    w = np.zeros((64, 32), np.float32)
    x = np.zeros((8, 63), np.float32)
    with pytest.raises(AssertionError):
        awq_search.search_awq_scales(w, x, group_size=32)
