"""Hypothesis sweeps: kernel shapes/layouts vs the pure-jnp oracle.

Shapes are drawn small (interpret-mode Pallas is slow) but cover the
divisibility lattice: group size | block_k | K, N multiples of 128, M
arbitrary (exercises the padding path).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, quantize, ref
from compile.kernels.awq_gemm import awq_gemm
from compile.kernels.quick_gemm import quick_gemm

shape_strategy = st.tuples(
    st.integers(1, 48),                       # M — any
    st.sampled_from([128, 256, 384]),         # K — multiple of block_k
    st.sampled_from([128, 256]),              # N — multiple of block_n
    st.sampled_from([32, 64, 128]),           # group size
    st.integers(0, 2**31 - 1),                # seed
)


def _case(m, k, n, g, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k), dtype=np.float32)
    w = rng.standard_normal((k, n), dtype=np.float32) * 0.1
    q, s, z = quantize.quantize_groupwise(w, g)
    return x, q, s, z


@settings(max_examples=15, deadline=None)
@given(shape_strategy)
def test_quick_gemm_hypothesis(params):
    m, k, n, g, seed = params
    x, q, s, z = _case(m, k, n, g, seed)
    got = quick_gemm(
        jnp.asarray(x), jnp.asarray(pack.pack_quick_dequant_order(q)),
        jnp.asarray(s), jnp.asarray(z), group_size=g, block_k=128,
    )
    want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(q), jnp.asarray(s),
                        jnp.asarray(z), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(shape_strategy)
def test_awq_gemm_hypothesis(params):
    m, k, n, g, seed = params
    x, q, s, z = _case(m, k, n, g, seed)
    got = awq_gemm(
        jnp.asarray(x), jnp.asarray(pack.pack_awq(q)),
        jnp.asarray(s), jnp.asarray(z), group_size=g, block_k=128,
    )
    want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(q), jnp.asarray(s),
                        jnp.asarray(z), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@settings(max_examples=50, deadline=None)
@given(
    st.integers(1, 8).map(lambda v: v * 16),   # K multiple of 16
    st.integers(1, 16).map(lambda v: v * 8),   # N multiple of 8
    st.integers(0, 2**31 - 1),
)
def test_pack_roundtrip_hypothesis(k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 16, size=(k, n)).astype(np.int32)
    stream, _ = pack.pack_quick(q)
    np.testing.assert_array_equal(pack.unpack_quick(stream, k, n), q)
    np.testing.assert_array_equal(pack.unpack_awq(pack.pack_awq(q)), q)


@settings(max_examples=50, deadline=None)
@given(
    st.sampled_from([16, 32, 64, 128]),
    st.integers(1, 32),
)
def test_fragment_perm_bijective_hypothesis(rows, words):
    perm = pack.ldmatrix_fragment_perm(rows, words)
    assert np.array_equal(np.sort(perm), np.arange(rows * words))


@settings(max_examples=30, deadline=None)
@given(
    st.sampled_from([64, 128, 192]),
    st.sampled_from([8, 16, 32]),
    st.sampled_from([16, 32, 64]),
    st.integers(0, 2**31 - 1),
)
def test_quantize_roundtrip_error_hypothesis(k, n, g, seed):
    if k % g != 0:
        g = 16  # 16 divides every k above
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, n), dtype=np.float32)
    q, s, z = quantize.quantize_groupwise(w, g)
    w2 = quantize.dequantize(q, s, z, g)
    err = np.abs(w - w2).reshape(k // g, g, n).max(axis=1)
    assert np.all(err <= s * 0.5 + 1e-5)
