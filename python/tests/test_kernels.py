"""Kernel-vs-oracle correctness: the CORE signal of the Python layer.

Every Pallas kernel (QUICK, AWQ baseline, fp16) must agree with the pure-jnp
``ref.py`` oracle to float tolerance for all supported shapes/layouts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pack, quantize, ref
from compile.kernels.awq_gemm import awq_gemm
from compile.kernels.fp16_gemm import fp16_gemm
from compile.kernels.quick_gemm import quick_gemm

jax.config.update("jax_platform_name", "cpu")


def make_case(m, k, n, group_size, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k), dtype=np.float32)
    w = rng.standard_normal((k, n), dtype=np.float32) * 0.05
    q, scales, zeros = quantize.quantize_groupwise(w, group_size)
    return x, w, q, scales, zeros


CASES = [
    (1, 128, 128, 128),
    (4, 256, 128, 64),
    (16, 128, 256, 32),
    (33, 256, 256, 128),  # M not divisible by block_m -> padding path
    (128, 384, 128, 128),
]


@pytest.mark.parametrize("m,k,n,g", CASES)
def test_quick_gemm_matches_ref(m, k, n, g):
    x, w, q, scales, zeros = make_case(m, k, n, g)
    qwords = pack.pack_quick_dequant_order(q)
    got = quick_gemm(
        jnp.asarray(x), jnp.asarray(qwords), jnp.asarray(scales),
        jnp.asarray(zeros), group_size=g, block_k=max(g, 128),
    )
    want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(q), jnp.asarray(scales),
                        jnp.asarray(zeros), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("m,k,n,g", CASES)
def test_awq_gemm_matches_ref(m, k, n, g):
    x, w, q, scales, zeros = make_case(m, k, n, g)
    qwords = pack.pack_awq(q)
    got = awq_gemm(
        jnp.asarray(x), jnp.asarray(qwords), jnp.asarray(scales),
        jnp.asarray(zeros), group_size=g, block_k=max(g, 128),
    )
    want = ref.gemm_ref(jnp.asarray(x), jnp.asarray(q), jnp.asarray(scales),
                        jnp.asarray(zeros), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_quick_and_awq_agree_exactly():
    """Both kernels compute the identical dequantized product — the layouts
    must be numerically transparent, not approximately so."""
    x, w, q, scales, zeros = make_case(8, 256, 128, 128, seed=3)
    a = quick_gemm(jnp.asarray(x), jnp.asarray(pack.pack_quick_dequant_order(q)),
                   jnp.asarray(scales), jnp.asarray(zeros), group_size=128)
    b = awq_gemm(jnp.asarray(x), jnp.asarray(pack.pack_awq(q)),
                 jnp.asarray(scales), jnp.asarray(zeros), group_size=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("m,k,n", [(1, 128, 128), (7, 256, 128), (64, 128, 256)])
def test_fp16_gemm_matches_ref(m, k, n):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((m, k), dtype=np.float32)
    w = rng.standard_normal((k, n), dtype=np.float32)
    got = fp16_gemm(jnp.asarray(x), jnp.asarray(w))
    want = ref.gemm_fp16_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_quantization_error_bounded():
    """Dequantized weights are within half an LSB of the original per group."""
    rng = np.random.default_rng(7)
    w = rng.standard_normal((256, 64), dtype=np.float32)
    q, s, z = quantize.quantize_groupwise(w, 64)
    w2 = quantize.dequantize(q, s, z, 64)
    # max error <= scale/2 per group (plus clipping at the extremes)
    err = np.abs(w - w2).reshape(4, 64, 64).max(axis=1)
    assert np.all(err <= s * 0.5 + 1e-6)


def test_block_shape_validation():
    x, w, q, scales, zeros = make_case(4, 128, 128, 128)
    qwords = pack.pack_quick_dequant_order(q)
    with pytest.raises(ValueError):
        quick_gemm(jnp.asarray(x), jnp.asarray(qwords), jnp.asarray(scales),
                   jnp.asarray(zeros), group_size=128, block_k=96)
