"""L2 model tests: decode/prefill consistency, quantized-vs-fp parity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(n_layers=2, max_seq=32)  # small for test speed


@pytest.fixture(scope="module")
def fp_params():
    return M.init_params(CFG, seed=0)


def _greedy_decode(params, kernel, prompt, n_steps):
    """Prefill the prompt then greedily decode n_steps tokens."""
    B, S = 1, len(prompt)
    pad = CFG.max_seq - S if False else 0
    tokens = jnp.asarray([prompt], jnp.int32)
    length = jnp.asarray([S], jnp.int32)
    kc, vc = M.empty_cache(CFG, B)
    # prefill uses S = prompt length (padding exercised separately)
    logits, kc, vc = M.prefill(params, CFG, kernel, tokens, length, kc, vc)
    out = []
    pos = S
    tok = int(jnp.argmax(logits[0]))
    out.append(tok)
    for _ in range(n_steps - 1):
        logits, kc, vc = M.decode_step(
            params, CFG, kernel,
            jnp.asarray([tok], jnp.int32), jnp.asarray([pos], jnp.int32),
            kc, vc,
        )
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
        pos += 1
    return out


def test_decode_matches_prefill(fp_params):
    """Teacher-forcing equivalence: feeding tokens one-by-one through
    decode_step produces the same last-token logits as prefill."""
    prompt = [5, 17, 301, 42, 7, 99, 128, 200]
    B = 1
    kc, vc = M.empty_cache(CFG, B)
    logits_pf, _, _ = M.prefill(
        fp_params, CFG, "fp16",
        jnp.asarray([prompt], jnp.int32), jnp.asarray([len(prompt)], jnp.int32),
        kc, vc,
    )
    kc, vc = M.empty_cache(CFG, B)
    logits_ds = None
    for i, t in enumerate(prompt):
        logits_ds, kc, vc = M.decode_step(
            fp_params, CFG, "fp16",
            jnp.asarray([t], jnp.int32), jnp.asarray([i], jnp.int32), kc, vc,
        )
    np.testing.assert_allclose(
        np.asarray(logits_pf), np.asarray(logits_ds), rtol=1e-4, atol=1e-4
    )


def test_quick_awq_models_identical(fp_params):
    """The two quantized layouts decode bit-identically (same math)."""
    qp = M.quantize_params(fp_params, CFG, "quick")
    ap = M.quantize_params(fp_params, CFG, "awq")
    prompt = [1, 2, 3, 4]
    a = _greedy_decode(qp, "quick", prompt, 6)
    b = _greedy_decode(ap, "awq", prompt, 6)
    assert a == b


def test_quantized_close_to_fp(fp_params):
    """W4 logits stay close to fp logits (quantization noise only)."""
    qp = M.quantize_params(fp_params, CFG, "quick")
    tokens = jnp.asarray([[3, 14, 15, 92]], jnp.int32)
    length = jnp.asarray([4], jnp.int32)
    kc, vc = M.empty_cache(CFG, 1)
    lg_fp, _, _ = M.prefill(fp_params, CFG, "fp16", tokens, length, kc, vc)
    kc, vc = M.empty_cache(CFG, 1)
    lg_q, _, _ = M.prefill(qp, CFG, "quick", tokens, length, kc, vc)
    # correlation of logits should be very high
    a, b = np.asarray(lg_fp)[0], np.asarray(lg_q)[0]
    corr = np.corrcoef(a, b)[0, 1]
    # Random (untrained) weights amplify quantization noise through layers;
    # >0.95 logit correlation is the expected band for W4 on this config.
    assert corr > 0.95, corr


def test_batched_decode_independent_lanes(fp_params):
    """Lanes in a decode batch must not interact: batch-of-2 equals two
    batch-of-1 runs (continuous batching correctness)."""
    kc2, vc2 = M.empty_cache(CFG, 2)
    toks = jnp.asarray([7, 9], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    lg2, kc2, vc2 = M.decode_step(fp_params, CFG, "fp16", toks, pos, kc2, vc2)
    for lane, t in enumerate([7, 9]):
        kc1, vc1 = M.empty_cache(CFG, 1)
        lg1, _, _ = M.decode_step(
            fp_params, CFG, "fp16",
            jnp.asarray([t], jnp.int32), jnp.asarray([0], jnp.int32), kc1, vc1,
        )
        np.testing.assert_allclose(
            np.asarray(lg2[lane]), np.asarray(lg1[0]), rtol=1e-5, atol=1e-5
        )


def test_per_lane_positions(fp_params):
    """Different pos per lane: lane with longer history attends to it."""
    kc, vc = M.empty_cache(CFG, 2)
    # seed both lanes' slot 0
    lg, kc, vc = M.decode_step(
        fp_params, CFG, "fp16",
        jnp.asarray([5, 5], jnp.int32), jnp.asarray([0, 0], jnp.int32), kc, vc,
    )
    # lane 0 continues at pos 1; lane 1 restarts at pos 0 (fresh seq)
    lg, kc, vc = M.decode_step(
        fp_params, CFG, "fp16",
        jnp.asarray([6, 6], jnp.int32), jnp.asarray([1, 0], jnp.int32), kc, vc,
    )
    a, b = np.asarray(lg[0]), np.asarray(lg[1])
    assert not np.allclose(a, b)  # histories differ -> logits differ


def test_config_validation():
    with pytest.raises(AssertionError):
        M.ModelConfig(d_model=100).validate()
