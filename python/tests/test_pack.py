"""Packing / interleaving unit + property tests (paper Figs. 1, 4, 5, 6)."""

import numpy as np
import pytest

from compile.kernels import pack, quantize


def rand_codes(k, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 16, size=(k, n), dtype=np.int64).astype(np.int32)


@pytest.mark.parametrize("order", [np.arange(8), pack.FT_ORDER])
def test_pack_unpack_roundtrip(order):
    q = rand_codes(32, 64)
    words = pack.pack_words(q, order)
    assert words.dtype == np.uint32 and words.shape == (32, 8)
    np.testing.assert_array_equal(pack.unpack_words(words, order), q)


def test_ft_order_is_even_odd_split():
    """Fig. 5: slots 0..3 hold even logical columns, 4..7 the odds."""
    assert list(pack.FT_ORDER[:4]) == [0, 2, 4, 6]
    assert list(pack.FT_ORDER[4:]) == [1, 3, 5, 7]
    np.testing.assert_array_equal(pack.FT_ORDER[pack.FT_INV], np.arange(8))


def test_awq_vs_quick_bits_differ_but_decode_same():
    q = rand_codes(16, 32, seed=2)
    awq = pack.pack_awq(q)
    quick = pack.pack_quick_dequant_order(q)
    assert (awq != quick).any()  # genuinely different bit layouts
    np.testing.assert_array_equal(pack.unpack_awq(awq), q)
    np.testing.assert_array_equal(pack.unpack_words(quick, np.arange(8)), q)


def test_fragment_perm_is_bijection():
    perm = pack.ldmatrix_fragment_perm(64, 16)
    assert perm.shape == (64 * 16,)
    assert np.array_equal(np.sort(perm), np.arange(64 * 16))


def test_fragment_perm_tile_locality():
    """Each consecutive run of 16 stream words covers exactly one
    (16-row x 1-word-col) mma B-tile — the paper's direct-DRAM-load unit."""
    K, W = 32, 4
    perm = pack.ldmatrix_fragment_perm(K, W)
    for t in range(0, K * W, 16):
        rows = perm[t : t + 16] // W
        cols = perm[t : t + 16] % W
        assert len(set(cols.tolist())) == 1  # single word-column
        assert sorted(rows.tolist()) == list(range(rows.min(), rows.min() + 16))


def test_quick_full_roundtrip():
    q = rand_codes(48, 64, seed=5)
    stream, perm = pack.pack_quick(q)
    assert stream.ndim == 1
    np.testing.assert_array_equal(pack.unpack_quick(stream, 48, 64), q)


def test_invert_perm():
    perm = pack.ldmatrix_fragment_perm(16, 2)
    inv = pack.invert_perm(perm)
    np.testing.assert_array_equal(perm[inv], np.arange(perm.size))
    np.testing.assert_array_equal(inv[perm], np.arange(perm.size))


def test_qzeros_roundtrip():
    rng = np.random.default_rng(3)
    z = rng.integers(0, 16, size=(4, 32)).astype(np.float32)
    words = pack.pack_qzeros(z)
    np.testing.assert_array_equal(pack.unpack_qzeros(words), z.astype(np.int32))


def test_pack_rejects_bad_codes():
    with pytest.raises(ValueError):
        pack.pack_linear(np.full((8, 8), 16, dtype=np.int32))
    with pytest.raises(ValueError):
        pack.ldmatrix_fragment_perm(17, 2)  # rows not multiple of 16


def test_reorders_commute():
    """Paper §3.2: nibble reorder (within words) and fragment interleave
    (between words) are independent — applying them in either order yields
    the same stream."""
    q = rand_codes(32, 32, seed=9)
    words = pack.pack_quick_dequant_order(q)
    perm = pack.ldmatrix_fragment_perm(*words.shape)
    a = pack.apply_word_perm(words, perm)
    # Other order: interleave the *linear*-packed words, then fix nibbles by
    # repacking each word — equivalent because perm moves whole words.
    stream2, _ = pack.pack_quick(q)
    np.testing.assert_array_equal(a, stream2)
