"""Locks the engine's padded-prefill correctness argument (see
rust/src/coordinator/engine.rs docstring): prefill pads prompts to a fixed
window; pad slots hold garbage K/V, but decode overwrites slot `pos` before
attending (mask slot <= pos), so garbage is never visible."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(n_layers=2, max_seq=32)
PREFILL_S = 8


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=3)


def _decode_chain(params, kc, vc, first_tok, start_pos, steps):
    toks = []
    tok = first_tok
    pos = start_pos
    for _ in range(steps):
        logits, kc, vc = M.decode_step(
            params, CFG, "fp16",
            jnp.asarray([tok], jnp.int32), jnp.asarray([pos], jnp.int32),
            kc, vc,
        )
        tok = int(jnp.argmax(logits[0]))
        toks.append(tok)
        pos += 1
    return toks


def test_padded_prefill_equals_exact_prefill(params):
    """Prompt of length 5 padded to window 8 must generate the same
    continuation as feeding the 5 tokens through unpadded prefill."""
    prompt = [3, 141, 59, 26, 5]
    length = len(prompt)

    # Exact: prefill window == prompt length.
    kc, vc = M.empty_cache(CFG, 1)
    lg_exact, kc_e, vc_e = M.prefill(
        params, CFG, "fp16",
        jnp.asarray([prompt], jnp.int32), jnp.asarray([length], jnp.int32),
        kc, vc,
    )
    tok0_exact = int(jnp.argmax(lg_exact[0]))
    cont_exact = _decode_chain(params, kc_e, vc_e, tok0_exact, length, 6)

    # Padded: window 8, pad tokens are zeros, true length passed.
    padded = prompt + [0] * (PREFILL_S - length)
    kc, vc = M.empty_cache(CFG, 1)
    lg_pad, kc_p, vc_p = M.prefill(
        params, CFG, "fp16",
        jnp.asarray([padded], jnp.int32), jnp.asarray([length], jnp.int32),
        kc, vc,
    )
    tok0_pad = int(jnp.argmax(lg_pad[0]))

    # Last-real-token logits agree exactly (causal mask hides pads).
    np.testing.assert_allclose(
        np.asarray(lg_exact), np.asarray(lg_pad), rtol=1e-5, atol=1e-5
    )
    assert tok0_exact == tok0_pad

    # Continuation: decode overwrites pad slots before reading them.
    cont_pad = _decode_chain(params, kc_p, vc_p, tok0_pad, length, 6)
    assert cont_exact == cont_pad


def test_padded_prefill_quick_kernel(params):
    """Same property through the QUICK quantized kernels."""
    qp = M.quantize_params(params, CFG, "quick")
    prompt = [7, 8, 9]
    length = len(prompt)
    padded = prompt + [0] * (PREFILL_S - length)

    kc, vc = M.empty_cache(CFG, 1)
    lg_a, kc_a, vc_a = M.prefill(
        qp, CFG, "quick",
        jnp.asarray([prompt], jnp.int32), jnp.asarray([length], jnp.int32),
        kc, vc,
    )
    kc, vc = M.empty_cache(CFG, 1)
    lg_b, kc_b, vc_b = M.prefill(
        qp, CFG, "quick",
        jnp.asarray([padded], jnp.int32), jnp.asarray([length], jnp.int32),
        kc, vc,
    )
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), rtol=1e-5, atol=1e-5)

    # Continuation through the quantized decode path must also agree.
    def chain(kc, vc, tok, steps=4):
        toks, pos = [], length
        for _ in range(steps):
            logits, kc, vc = M.decode_step(
                qp, CFG, "quick",
                jnp.asarray([tok], jnp.int32), jnp.asarray([pos], jnp.int32),
                kc, vc,
            )
            tok = int(jnp.argmax(logits[0]))
            toks.append(tok)
            pos += 1
        return toks

    t = int(jnp.argmax(lg_a[0]))
    assert chain(kc_a, vc_a, t) == chain(kc_b, vc_b, t)


def test_length_one_prompt(params):
    """Degenerate single-token prompt through the padded window."""
    padded = [42] + [0] * (PREFILL_S - 1)
    kc, vc = M.empty_cache(CFG, 1)
    lg, kc, vc = M.prefill(
        params, CFG, "fp16",
        jnp.asarray([padded], jnp.int32), jnp.asarray([1], jnp.int32),
        kc, vc,
    )
    # Must equal a pure decode_step of the same token at pos 0.
    kc2, vc2 = M.empty_cache(CFG, 1)
    lg2, _, _ = M.decode_step(
        params, CFG, "fp16",
        jnp.asarray([42], jnp.int32), jnp.asarray([0], jnp.int32), kc2, vc2,
    )
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2), rtol=1e-4, atol=1e-4)
