"""Structural (L1/L2) performance assertions — DESIGN.md §8.

These lock the *mechanism* of the paper at the IR level: the QUICK kernel's
lowered HLO must contain no gather/relayout between the weight load and the
dot, while the AWQ baseline must contain the deinterleave the naive layout
forces; and the Pallas BlockSpecs must fit the VMEM budget with MXU-aligned
tiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pack, quantize
from compile.kernels.awq_gemm import awq_gemm
from compile.kernels.profile import (
    check_budget,
    profile_gemm_kernel,
    VMEM_BUDGET,
)
from compile.kernels.quick_gemm import quick_gemm


def lowered_text(fn, *args):
    return jax.jit(fn).lower(*args).as_text()


@pytest.fixture(scope="module")
def gemm_case():
    rng = np.random.default_rng(0)
    k, n, g = 256, 128, 128
    w = rng.standard_normal((k, n)).astype(np.float32) * 0.1
    q, s, z = quantize.quantize_groupwise(w, g)
    x = rng.standard_normal((8, k)).astype(np.float32)
    return x, q, s, z, g


def test_quick_hlo_has_no_weight_gather(gemm_case):
    x, q, s, z, g = gemm_case
    quick_ir = lowered_text(
        lambda x_: quick_gemm(
            x_, jnp.asarray(pack.pack_quick_dequant_order(q)),
            jnp.asarray(s), jnp.asarray(z), group_size=g,
        ),
        jnp.asarray(x),
    )
    awq_ir = lowered_text(
        lambda x_: awq_gemm(
            x_, jnp.asarray(pack.pack_awq(q)),
            jnp.asarray(s), jnp.asarray(z), group_size=g,
        ),
        jnp.asarray(x),
    )
    # The AWQ kernel's deinterleave lowers to a concatenate/gather over the
    # nibble axis; QUICK's unpack is pure elementwise + reshape.
    def relayout_ops(ir: str) -> int:
        return ir.count("stablehlo.concatenate") + ir.count("stablehlo.gather")

    assert relayout_ops(awq_ir) > relayout_ops(quick_ir), (
        relayout_ops(awq_ir),
        relayout_ops(quick_ir),
    )


def test_quick_hlo_not_larger_than_awq(gemm_case):
    """Same math, less data movement: the QUICK module must not carry more
    ops than the baseline."""
    x, q, s, z, g = gemm_case
    quick_ir = lowered_text(
        lambda x_: quick_gemm(
            x_, jnp.asarray(pack.pack_quick_dequant_order(q)),
            jnp.asarray(s), jnp.asarray(z), group_size=g,
        ),
        jnp.asarray(x),
    )
    awq_ir = lowered_text(
        lambda x_: awq_gemm(
            x_, jnp.asarray(pack.pack_awq(q)),
            jnp.asarray(s), jnp.asarray(z), group_size=g,
        ),
        jnp.asarray(x),
    )
    assert quick_ir.count("stablehlo.") <= awq_ir.count("stablehlo.")


def test_vmem_budgets():
    for kind in ("quick", "awq", "fp16"):
        p = profile_gemm_kernel(kind)
        assert check_budget(p), p.render()
        # Default tiles stay far under budget (headroom for double buffer).
        assert p.vmem_bytes < VMEM_BUDGET // 8, p.render()


def test_quick_vmem_smaller_than_fp16():
    """4-bit packed weight blocks shrink the VMEM working set."""
    q = profile_gemm_kernel("quick")
    f = profile_gemm_kernel("fp16")
    # quick adds a dequant scratch tile but its packed weights are 8x
    # smaller; net should not exceed fp16 + scratch.
    assert q.vmem_bytes <= f.vmem_bytes + q.block_k * q.block_n * 4


def test_mxu_alignment_and_relayout_flags():
    q = profile_gemm_kernel("quick")
    a = profile_gemm_kernel("awq")
    assert q.block_n % 128 == 0 and q.block_k % 128 == 0
    assert not q.has_relayout and a.has_relayout
    assert q.mxu_util > a.mxu_util


def test_decode_artifact_single_fusion_per_kernel_call():
    """The AOT decode module must not re-trace pallas bodies per layer in a
    way that blows up module size: rough proxy — module op count stays
    bounded (regression guard for the lowering path)."""
    from compile import model as M

    cfg = M.ModelConfig(n_layers=2, max_seq=16)
    params = M.quantize_params(M.init_params(cfg, 0), cfg, "quick")
    params = jax.tree.map(jnp.asarray, params)
    kc, vc = M.empty_cache(cfg, 1)
    ir = lowered_text(
        lambda t, p, k, v: M.decode_step(params, cfg, "quick", t, p, k, v),
        jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32), kc, vc,
    )
    n_ops = ir.count("stablehlo.")
    assert n_ops < 12_000, f"decode module exploded: {n_ops} ops"
