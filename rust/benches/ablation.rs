//! Ablation bench: decompose QUICK's gain into its three mechanisms
//! (write-back skip, dequant-aware reorder, tile-size opt — paper §3.1–3.3)
//! plus the §5 future-work split-K, across the Fig. 7 batch axis.

use quick_infer::gpusim::ablation::{model_quick_variant, QuickVariant};
use quick_infer::gpusim::kernel_model::Calib;
use quick_infer::gpusim::Gpu;
use quick_infer::util::Bench;

fn main() {
    let dev = Gpu::Rtx4090.spec();
    let calib = Calib::default();
    let variants = [
        ("baseline (AWQ)", QuickVariant::BASELINE),
        ("-wb-skip", QuickVariant { skip_writeback: false, ..QuickVariant::FULL }),
        ("-dq-reorder", QuickVariant { dequant_reorder: false, ..QuickVariant::FULL }),
        ("-tile-opt", QuickVariant { tile_size_opt: false, ..QuickVariant::FULL }),
        ("+split-k4", QuickVariant { split_k: Some(4), ..QuickVariant::FULL }),
        ("QUICK (full)", QuickVariant::FULL),
    ];

    println!("== Ablation: QUICK mechanisms on {} (TOPS, batch x 8192 x 8192) ==", dev.name);
    print!("{:16}", "variant");
    let batches = [1u64, 16, 64, 256];
    for b in batches {
        print!(" {:>9}", format!("b{b}"));
    }
    println!();
    for (name, v) in variants {
        print!("{name:16}");
        for b in batches {
            let p = model_quick_variant(&dev, &v, b, 8192, 8192, &calib);
            print!(" {:>9.2}", p.tops);
        }
        println!();
    }
    println!("\n(read: each '-X' row = full QUICK with mechanism X disabled; the");
    println!(" drop vs the full row is that mechanism's contribution)");

    println!("\n-- timing --");
    Bench::fast().run("model_quick_variant sweep (6 variants x 4 batches)", || {
        let mut acc = 0.0;
        for (_, v) in &variants {
            for b in batches {
                acc += model_quick_variant(&dev, v, b, 8192, 8192, &calib).tops;
            }
        }
        acc
    });
}
