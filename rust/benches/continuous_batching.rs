//! Continuous-batching bench: the token-budget scheduler with chunked
//! prefill vs the static prefill-then-decode wave baseline on the bursty
//! bimodal workload (A6000, Vicuna-13B), QUICK vs AWQ — plus
//! micro-benchmarks of the scheduler's step planning and the mixed-step
//! cost query.

use quick_infer::coordinator::batcher::{ChunkPolicy, ContinuousScheduler};
use quick_infer::coordinator::simserve::{simulate_continuous, ContinuousPolicy};
use quick_infer::figures;
use quick_infer::gpusim::kernel_model::{Calib, KernelKind};
use quick_infer::gpusim::{mixed_step_latency, Gpu};
use quick_infer::model::Model;
use quick_infer::util::Bench;
use quick_infer::workload::BurstyWorkload;

fn main() {
    let report = figures::continuous_batching(&mut std::io::stdout()).expect("report");
    assert!(
        report.quick_speedup() >= 1.3,
        "continuous/wave speedup {:.2}x below the 1.3x bar",
        report.quick_speedup()
    );

    println!("\n-- continuous-batching micro-benchmarks --");
    // Step planning over a saturated scheduler (256 resident sequences).
    let mut sched = ContinuousScheduler::new(ChunkPolicy::default());
    for i in 0..256 {
        sched.submit(i, 512, 128);
        sched.admit_next(0, |_| true).expect("admit");
    }
    Bench::fast().run_throughput("plan_step_256_seqs", 256, || sched.plan_step().step_tokens());

    // The batched cost query at a saturated mixed step.
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let calib = Calib::default();
    Bench::fast().run("mixed_step_latency_quick_b64_c448", || {
        mixed_step_latency(&dev, &spec, KernelKind::Quick, 64, 900, 448, 896, &calib).total_s()
    });

    // End-to-end simulated serving loop.
    let reqs = BurstyWorkload::default().offline(100, 7);
    Bench::fast().run("simulate_continuous_100req_quick", || {
        simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &calib,
        )
        .expect("simulate_continuous")
        .total_tok_per_s
    });
}
