//! Figure 3 bench: shared-memory bank conflicts of the baseline
//! dequant-write-back vs QUICK, at the paper's 64x8192x8192 workload —
//! plus timings of the conflict simulator itself.

use quick_infer::figures;
use quick_infer::gpusim::{trace, BankCounter};
use quick_infer::util::Bench;

fn main() {
    figures::fig3(&mut std::io::stdout()).expect("fig3");

    println!("\n-- fig3 micro-benchmarks --");
    let b = Bench::new();
    b.run("awq_writeback_tile_trace (BK64xBN128)", || {
        let mut counter = BankCounter::new();
        trace::awq_writeback(&mut counter, 128, 32);
        counter.conflicts
    });
    b.run("ldmatrix_tile_trace (16 tiles)", || {
        let mut counter = BankCounter::new();
        for base in (0..16u64).map(|i| i * 2048) {
            counter.access(&trace::ldmatrix_load(72, base), 16);
        }
        counter.conflicts
    });
}
