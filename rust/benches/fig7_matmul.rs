//! Figure 7 bench: mixed-precision GEMM TOPS vs batch on four devices
//! (cost model), plus — when artifacts exist — *measured* PJRT wall times
//! of the real Pallas-lowered GEMM artifacts on this CPU testbed.

use quick_infer::figures;
use quick_infer::gpusim::kernel_model::{model_gemm, Calib, KernelKind};
use quick_infer::gpusim::Gpu;
use quick_infer::runtime::Runtime;
use quick_infer::util::Bench;

/// Measured CPU execution of the AOT GEMM artifacts (numerics substrate —
/// NOT a GPU perf proxy; trends across kernels still reflect the extra
/// dequant/shuffle op counts).
fn measured_pjrt() {
    let Ok(mut rt) = Runtime::open("artifacts") else {
        eprintln!("(artifacts missing; skipping measured PJRT GEMM bench)");
        return;
    };
    println!("\n-- measured PJRT CPU GEMM (1024x1024 weights) --");
    let b = Bench::fast();
    for kern in ["quick", "awq", "fp16"] {
        for m in [1u64, 16, 128] {
            let name = format!("gemm_{kern}_m{m}");
            if rt.manifest.find(&name).is_none() {
                continue;
            }
            let args = rt.golden_args(&name).expect("golden args");
            let lits: Vec<xla::Literal> =
                args.iter().map(|t| t.to_literal().unwrap()).collect();
            rt.ensure_compiled(&name).expect("compile");
            b.run(&name, || rt.execute_literals(&name, &lits).expect("exec"));
        }
    }
}

fn main() {
    figures::fig7(&mut std::io::stdout()).expect("fig7");

    println!("\n-- fig7 model sweep timing --");
    let calib = Calib::default();
    Bench::new().run("model_gemm_full_sweep (4 gpus x 3 kernels x 9 batches)", || {
        let mut acc = 0.0;
        for gpu in Gpu::ALL {
            for kind in KernelKind::ALL {
                for m in figures::FIG7_BATCHES {
                    acc += model_gemm(&gpu.spec(), kind, m, 8192, 8192, &calib).tops;
                }
            }
        }
        acc
    });

    measured_pjrt();
}
