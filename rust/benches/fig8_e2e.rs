//! Figure 8 bench: end-to-end decode tokens/s vs batch for the four
//! (model, GPU) pairs, with OOM cutoffs, from the cost model.

use quick_infer::figures;
use quick_infer::gpusim::kernel_model::{Calib, KernelKind};
use quick_infer::gpusim::{decode_step_latency, Gpu};
use quick_infer::model::Model;
use quick_infer::util::Bench;

fn main() {
    figures::fig8(&mut std::io::stdout()).expect("fig8");

    println!("\n-- fig8 micro-benchmarks --");
    let calib = Calib::default();
    Bench::new().run("decode_step_model (70B @ b64)", || {
        decode_step_latency(
            &Gpu::RtxA6000.spec(),
            &Model::Llama2_70B.spec(),
            KernelKind::Quick,
            64,
            512,
            &calib,
        )
        .total_s()
    });
}
