//! L3 hot-path microbenchmarks (perf pass, DESIGN.md §8): offline packing
//! throughput (incl. the `dequantize_into` reused-buffer and memoized
//! fragment-perm variants), the native fused/write-back kernel pair —
//! now with a counting-allocator gate proving the plan-cached runtime
//! allocates *zero* bytes per call in steady state (with the span
//! tracer off *and* on), the obs tracer's per-span dispatch cost, KV
//! block manager ops, batcher step planning, bank-counter inner loop,
//! and — with artifacts present — the PJRT decode round-trip the
//! engine pays per token.

use quick_infer::coordinator::kv_cache::KvBlockManager;
use quick_infer::coordinator::{Batcher, GenerationRequest, StepPlan};
use quick_infer::gpusim::{trace, BankCounter};
use quick_infer::quant;
use quick_infer::runtime::Runtime;
use quick_infer::util::{Bench, CountingAlloc};

/// Every allocation in this bench binary is counted, so the kernel
/// steady-state checks below can assert an exact zero delta.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn bench_quant(b: &Bench) {
    println!("-- quant (4096x4096, group 128) --");
    let (k, n) = (4096usize, 4096usize);
    let w: Vec<f32> = (0..k * n)
        .map(|i| ((i as u32).wrapping_mul(2654435761) as f32 / u32::MAX as f32) - 0.5)
        .collect();
    let t = quant::quantize_groupwise(&w, k, n, 128);
    let elems = (k * n) as u64;
    b.run_throughput("quantize_groupwise", elems, || {
        quant::quantize_groupwise(&w, k, n, 128)
    });
    b.run_throughput("pack_quick (interleaved stream)", elems, || {
        quant::pack_quick(&t.codes, k, n)
    });
    b.run_throughput("pack_awq", elems, || quant::pack_awq(&t.codes, k, n));
    b.run_throughput("dequantize (alloc per call)", elems, || quant::dequantize(&t));
    let mut deq = vec![0f32; k * n];
    b.run_throughput("dequantize_into (reused buffer)", elems, || {
        quant::dequantize_into(&t, &mut deq);
        deq[0]
    });
    // unpack_quick goes through the memoized fragment perm; the first
    // call built the (k, n/8) permutation, every sample here reuses it.
    let stream = quant::pack_quick(&t.codes, k, n);
    b.run_throughput("unpack_quick (memoized perm)", elems, || {
        quant::unpack_quick(&stream, k, n)
    });
    b.run("ldmatrix_fragment_perm (fresh)", || quant::ldmatrix_fragment_perm(k, n / 8));
    b.run("ldmatrix_fragment_perm_memo (cached)", || {
        quant::ldmatrix_fragment_perm_memo(k, n / 8)
    });
}

fn bench_kernel(b: &Bench) {
    use quick_infer::kernel::{AwqWritebackBackend, Blocking, KernelBackend, QuickFusedBackend};
    println!("-- native kernel backends (1024x1024 g128, m=32) --");
    let (k, n, m) = (1024usize, 1024usize, 32usize);
    let w: Vec<f32> = (0..k * n)
        .map(|i| ((i as u32).wrapping_mul(2654435761) as f32 / u32::MAX as f32) - 0.5)
        .collect();
    let t = quant::quantize_groupwise(&w, k, n, 128);
    let fused = QuickFusedBackend::new(&t, Blocking::default());
    let writeback = AwqWritebackBackend::new(&t, Blocking::default());
    let x: Vec<f32> = (0..m * k)
        .map(|i| ((i as u32).wrapping_mul(2246822519) as f32 / u32::MAX as f32) - 0.5)
        .collect();
    let mut y = vec![0f32; m * n];
    let flops = (2 * m * n * k) as u64;
    b.run_throughput("gemm_quick_fused", flops, || {
        fused.gemm(&x, m, &mut y);
        y[0]
    });
    b.run_throughput("gemm_awq_writeback", flops, || {
        writeback.gemm(&x, m, &mut y);
        y[0]
    });

    // Steady-state allocation gate: after the warm calls above built the
    // plans, repeated same-shape GEMMs (and dequantize_into with a
    // reused buffer) must allocate *nothing* — the PlanCache contract.
    fn steady(name: &str, mut f: impl FnMut()) {
        f(); // warm: plan/scratch resident beyond any doubt
        let before = ALLOC.allocations();
        for _ in 0..10 {
            f();
        }
        let delta = ALLOC.allocations() - before;
        println!("{name:44} {delta:>4} allocs / 10 calls (steady state)");
        assert_eq!(delta, 0, "{name}: hot path allocated in steady state");
    }
    steady("gemm_quick_fused (plan-cached)", || {
        fused.gemm(&x, m, &mut y);
    });
    steady("gemm_awq_writeback (plan-cached)", || {
        writeback.gemm(&x, m, &mut y);
    });
    let mut deq = vec![0f32; k * n];
    steady("dequantize_into (reused buffer)", || {
        quant::dequantize_into(&t, &mut deq);
    });

    // The same gates with the span tracer live: instrumentation must
    // stay allocation-free in steady state too. Each thread's event
    // ring allocates once on its first span, so warm every pool worker
    // through a barrier job (tasks == slots forces one claim per
    // participant) before the counting window opens.
    {
        use quick_infer::kernel::WorkerPool;
        use quick_infer::obs::trace;
        use std::sync::atomic::{AtomicUsize, Ordering};
        trace::enable();
        let pool = WorkerPool::global();
        let slots = pool.workers() + 1;
        let started = AtomicUsize::new(0);
        pool.run(slots, slots, &|_t, _s| {
            started.fetch_add(1, Ordering::Relaxed);
            while started.load(Ordering::Relaxed) < slots {
                std::hint::spin_loop();
            }
        });
        steady("gemm_quick_fused (traced)", || {
            fused.gemm(&x, m, &mut y);
        });
        steady("gemm_awq_writeback (traced)", || {
            writeback.gemm(&x, m, &mut y);
        });
        trace::disable();
    }
}

fn bench_obs(b: &Bench) {
    use quick_infer::obs::trace;
    println!("-- obs tracer dispatch --");
    // The permanent cost every instrumentation site pays when tracing
    // is off: one relaxed load.
    trace::disable();
    b.run("span dispatch (tracing disabled)", || trace::span("bench.span", "bench"));
    // The recording cost (ring overflow folds to the cheaper
    // drop-newest path; both bound the per-event overhead).
    trace::enable();
    b.run("span dispatch (tracing enabled)", || trace::span("bench.span", "bench"));
    trace::disable();
}

fn bench_decoder_dispatch(b: &Bench) {
    use quick_infer::obs::trace;
    use quick_infer::quant::{
        select_awq_decoder, select_awq_lut_decoder, select_quick_decoder, select_quick_lut_decoder,
    };
    println!("-- decoder selection (memoized CPU-feature probe) --");
    // Warm every OnceLock first, so each timed call below is the
    // steady-state dispatch (one atomic load), never the first-call
    // CPUID probe.
    let _ = (select_quick_decoder(true), select_awq_decoder(true));
    let _ = (select_quick_lut_decoder(true), select_awq_lut_decoder(true));
    b.run("select_quick_decoder (memoized)", || select_quick_decoder(true) as usize);
    b.run("select_awq_decoder (memoized)", || select_awq_decoder(true) as usize);
    b.run("select_quick_lut_decoder (memoized)", || select_quick_lut_decoder(true) as usize);
    b.run("select_awq_lut_decoder (memoized)", || select_awq_lut_decoder(true) as usize);
    // The same dispatch with the span tracer live: selection + one span
    // is the whole per-GEMM decode-dispatch tax the obs layer can see.
    trace::enable();
    b.run("select_quick_decoder (memoized, traced)", || {
        let _s = trace::span("decode.select", "bench");
        select_quick_decoder(true) as usize
    });
    trace::disable();
}

fn bench_kv(b: &Bench) {
    println!("-- kv block manager --");
    b.run("alloc_append_free_churn (256 seqs)", || {
        let mut m = KvBlockManager::new(8192, 16, 0.01);
        for s in 0..256u64 {
            m.allocate(s, 200).unwrap();
        }
        for s in 0..256u64 {
            for _ in 0..32 {
                m.append_token(s).unwrap();
            }
        }
        for s in 0..256u64 {
            m.free_seq(s).unwrap();
        }
        m.free_blocks()
    });
}

fn bench_batcher(b: &Bench) {
    println!("-- batcher --");
    let mut batcher = Batcher::new(8, 1024, 64);
    for i in 0..512u64 {
        let _ = batcher.submit(GenerationRequest {
            id: i,
            prompt: vec![1, 2, 3, 4],
            max_new_tokens: 8,
            temperature: None,
            eos_token: None,
        });
    }
    for lane in 0..8 {
        if let StepPlan::Prefill { seq_index, .. } = batcher.plan() {
            batcher.start_prefill(seq_index, lane);
        }
    }
    b.run("plan_under_load (8 lanes, 500 queued)", || batcher.plan());
}

fn bench_bank(b: &Bench) {
    println!("-- bank counter --");
    b.run("writeback_trace_64rows", || {
        let mut counter = BankCounter::new();
        trace::awq_writeback(&mut counter, 128, 64);
        counter.conflicts
    });
}

fn bench_pjrt(b: &Bench) {
    let Ok(mut rt) = Runtime::open("artifacts") else {
        eprintln!("(artifacts missing; skipping PJRT round-trip bench)");
        return;
    };
    println!("-- PJRT round-trips (engine hot path) --");
    for name in ["decode_quick_b1", "decode_quick_b8", "gemm_quick_m1"] {
        if rt.manifest.find(name).is_none() {
            continue;
        }
        let args = rt.golden_args(name).expect("golden");
        let lits: Vec<xla::Literal> = args.iter().map(|t| t.to_literal().unwrap()).collect();
        rt.ensure_compiled(name).expect("compile");
        b.run(name, || rt.execute_literals(name, &lits).expect("exec"));
    }
}

fn main() {
    let b = Bench::fast();
    bench_quant(&b);
    bench_kernel(&b);
    bench_obs(&b);
    bench_decoder_dispatch(&b);
    bench_kv(&b);
    bench_batcher(&b);
    bench_bank(&b);
    bench_pjrt(&b);
}
