//! Measured native-kernel M-sweep bench: `gemm_quick_fused` vs
//! `gemm_awq_writeback` on this host (the executable analogue of the
//! Fig. 7 batch axis). Same harness the `quick-infer bench kernels` CLI
//! target and `simulate kernel-matmul` use; this entry point exists so
//! `cargo bench --bench kernel_matmul` slots into the existing bench
//! workflow next to `fig7_matmul`.

use quick_infer::figures;

fn main() {
    let report = figures::kernel_matmul(&mut std::io::stdout()).expect("kernel_matmul");
    assert!(
        report.within_tolerance(),
        "kernel divergence vs naive reference: fused {:.2e}, write-back {:.2e}",
        report.fused_rel_err,
        report.writeback_rel_err
    );
}
