//! Measured native-kernel benches: the `gemm_quick_fused` vs
//! `gemm_awq_writeback` M-sweep (the executable analogue of the Fig. 7
//! batch axis) plus the decode-shape runtime sweep (persistent pool vs
//! spawn-per-call, SIMD vs scalar, dispatch overhead). Same harnesses
//! the `quick-infer bench kernels` CLI target and `simulate
//! kernel-matmul` / `simulate step` use; this entry point exists so
//! `cargo bench --bench kernel_matmul` slots into the existing bench
//! workflow next to `fig7_matmul`.

use quick_infer::figures;

fn main() {
    let report = figures::kernel_matmul(&mut std::io::stdout()).expect("kernel_matmul");
    assert!(
        report.within_tolerance(),
        "kernel divergence vs naive reference: fused {:.2e}, write-back {:.2e}",
        report.fused_rel_err,
        report.writeback_rel_err
    );
    // Decode-shape runtime sweep on the same default layer size the CLI
    // uses (4096x4096 would dwarf the bench wall time here; 1024 shows
    // the same dispatch-vs-arithmetic structure).
    let decode = figures::decode_sweep_with(
        &mut std::io::stdout(),
        1024,
        1024,
        128,
        &figures::DECODE_SWEEP_BATCHES,
        &quick_infer::util::Bench::fast(),
    )
    .expect("decode_sweep");
    assert!(
        decode.within_tolerance(),
        "decode-sweep divergence vs naive reference: fused {:.2e}, write-back {:.2e}",
        decode.fused_rel_err,
        decode.writeback_rel_err
    );
    // LUT decoder sweep on the same layer: shift-mask vs byte-shuffle
    // LUT on identical INT4 bits, plus the NF4/MXFP4 codebooks only the
    // LUT tier can expand.
    let lut = figures::lut_sweep_with(
        &mut std::io::stdout(),
        1024,
        1024,
        128,
        &figures::DECODE_SWEEP_BATCHES,
        &quick_infer::util::Bench::fast(),
    )
    .expect("lut_sweep");
    assert!(
        lut.within_tolerance(),
        "lut-sweep divergence vs naive reference: {:.2e}",
        lut.lut_rel_err
    );
}
