//! Prefix-cache bench: automatic prefix caching on the Table-1 serving
//! simulator (A6000, Vicuna-13B, QUICK) — cache on vs off at equal KV
//! budget over a shared-prefix chat workload and a disjoint ShareGPT-like
//! control — plus micro-benchmarks of the radix-trie index and the cached
//! serving loop itself.

use quick_infer::coordinator::prefix::PrefixIndex;
use quick_infer::coordinator::simserve::{simulate_serving, SimPolicy};
use quick_infer::figures;
use quick_infer::gpusim::kernel_model::{Calib, KernelKind};
use quick_infer::gpusim::Gpu;
use quick_infer::model::Model;
use quick_infer::util::Bench;
use quick_infer::workload::SharedPrefixWorkload;

fn main() {
    let report = figures::prefix_cache(&mut std::io::stdout()).expect("prefix report");
    assert!(
        report.throughput_speedup() >= 1.2,
        "prefix cache speedup {:.2}x below the 1.2x bar",
        report.throughput_speedup()
    );

    println!("\n-- prefix-cache micro-benchmarks --");
    // Radix-trie chain walk over a deep cached prefix.
    let mut idx = PrefixIndex::new(16);
    let tokens: Vec<i32> = (0..4097).map(|i| (i % 509) as i32).collect();
    let blocks: Vec<u32> = (0..256).collect();
    assert_eq!(idx.insert(&tokens, &blocks).len(), 256);
    Bench::fast().run_throughput("match_prefix_256_blocks", 4096, || {
        idx.match_prefix(&tokens).len()
    });

    // Cached serving loop end to end.
    let reqs = SharedPrefixWorkload::default().offline(100, 7);
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    Bench::fast().run("simulate_shared_prefix_100req_cache_on", || {
        simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
        .expect("simulate_serving")
        .total_tok_per_s
    });
}
