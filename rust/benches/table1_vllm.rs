//! Table 1 bench: vLLM-style continuous-batching serving throughput on
//! A6000 for Vicuna-13B and Llama-2-70B (1000 ShareGPT-like requests),
//! plus timing of the serving simulator itself.

use quick_infer::coordinator::simserve::{simulate_serving, SimPolicy};
use quick_infer::figures;
use quick_infer::gpusim::kernel_model::{Calib, KernelKind};
use quick_infer::gpusim::Gpu;
use quick_infer::model::Model;
use quick_infer::util::Bench;
use quick_infer::workload::ShareGptLike;

fn main() {
    figures::table1(&mut std::io::stdout()).expect("table1");

    println!("\n-- table1 micro-benchmarks --");
    let reqs = ShareGptLike::new().offline(200, 7);
    Bench::fast().run("simulate_vicuna13b_quick_200req", || {
        simulate_serving(
            &Gpu::RtxA6000.spec(),
            &Model::Vicuna13B.spec(),
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
        .expect("simulate_serving")
        .gen_tok_per_s
    });
}
