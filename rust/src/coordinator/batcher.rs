//! Continuous batching with chunked prefill: token-budget step planning.
//!
//! Two schedulers live here:
//!
//! * [`ContinuousScheduler`] — the token-budget continuous-batching core
//!   (vLLM/Orca-style with Sarathi chunked prefill). Every step fills a
//!   fixed token budget with **decode tokens first** (one per running
//!   sequence whose prompt is fully computed), then slices admitted
//!   prompts into **prefill chunks** that ride the same step. The step's
//!   cost comes from one batched query into `gpusim::mixed_step_latency`
//!   at the *actual* mixed batch size, which is how kernel choice (QUICK
//!   vs AWQ) changes end-to-end throughput: decode lanes never stall for
//!   whole-prompt prefills, the sustained batch stays in the regime where
//!   the paper's larger-BM tiles win (§3.3 tile-size/batch trade-off:
//!   QUICK's register-resident weights allow BM up to 192, so throughput
//!   keeps scaling past the baseline's BM ≤ 64 saturation point), and
//!   prefill tokens amortize the per-step weight streaming that
//!   decode-only steps pay in full. Preemption under KV pressure follows
//!   vLLM's recompute policy: the victim re-queues and re-prefills (its
//!   cached prefix, if any, shrinks the recompute chunks).
//!
//! * [`Batcher`] — the lane scheduler of the real PJRT engine. The engine
//!   runs fixed-shape AOT artifacts (batch ∈ the manifest's compiled
//!   sizes), so its chunked prefill is lane-granular: a new sequence's
//!   head window goes through the prefill artifact, and the rest of its
//!   prompt is teacher-forced one token per *mixed* decode step alongside
//!   decoding lanes — the same decode-first/chunk-riding policy at the
//!   granularity the fixed shapes allow.
//!
//! The [`ContinuousScheduler`]'s step plans are consumed two ways: the
//! modeled serving twins price each step through `gpusim`, and the
//! `--measured` twins (`coordinator::measured`) *execute* each step's
//! mixed token count as a real GEMM stream on the native kernel runtime
//! — same plans, same admission, different clock.

use std::collections::VecDeque;
use std::sync::OnceLock;

use crate::obs::{trace, Counter, Registry};

use super::request::{FinishReason, GenerationRequest, SeqState, Sequence};

/// Registry handles for the continuous scheduler, resolved once.
struct SchedMetrics {
    steps: Counter,
    decode_lanes: Counter,
    prefill_tokens: Counter,
    chunked_prefill_tokens: Counter,
    preemptions: Counter,
    submitted: Counter,
}

fn sched_metrics() -> &'static SchedMetrics {
    static METRICS: OnceLock<SchedMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        SchedMetrics {
            steps: r.counter("sched.steps"),
            decode_lanes: r.counter("sched.decode_lanes"),
            prefill_tokens: r.counter("sched.prefill_tokens"),
            chunked_prefill_tokens: r.counter("sched.chunked_prefill_tokens"),
            preemptions: r.counter("sched.preemptions"),
            submitted: r.counter("sched.submitted"),
        }
    })
}

// ---------------------------------------------------------------------------
// Token-budget continuous scheduler (simulator + any token-granular engine).
// ---------------------------------------------------------------------------

/// Policy knobs for the token-budget scheduler.
#[derive(Debug, Clone, Copy)]
pub struct ChunkPolicy {
    /// Max tokens (decode + prefill chunks) per step — vLLM's
    /// `max_num_batched_tokens` with chunked prefill enabled.
    pub token_budget: u64,
    /// Max sequences resident (admitted, running or mid-prefill).
    pub max_num_seqs: usize,
}

impl Default for ChunkPolicy {
    fn default() -> Self {
        ChunkPolicy { token_budget: 512, max_num_seqs: 256 }
    }
}

/// Scheduler-side state of one sequence (lengths only — token content and
/// KV ownership live with the driver).
#[derive(Debug, Clone, Copy)]
pub struct SchedSeq {
    /// Driver-side request id (KV-cache sequence id).
    pub request_id: u64,
    pub prompt_tokens: u64,
    /// Generation budget (max new tokens).
    pub gen_budget: u64,
    /// Prompt tokens whose KV came from the prefix cache (they skip
    /// prefill compute; `prefilled` starts here).
    pub cached_prefix: u64,
    /// Prompt tokens computed so far (including the cached prefix).
    pub prefilled: u64,
    pub generated: u64,
    pub state: SchedState,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedState {
    Waiting,
    Running,
    Finished,
}

impl SchedSeq {
    /// Prompt fully computed — the sequence decodes from here on.
    pub fn in_decode(&self) -> bool {
        self.prefilled >= self.prompt_tokens
    }

    /// Prompt tokens still needing prefill compute.
    pub fn prefill_remaining(&self) -> u64 {
        self.prompt_tokens - self.prefilled.min(self.prompt_tokens)
    }
}

/// One prefill chunk scheduled into a step: `len` prompt tokens starting
/// at position `start` of sequence `seq`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    pub seq: SchedSeqId,
    pub start: u64,
    pub len: u64,
}

/// Index into the scheduler's sequence slab.
pub type SchedSeqId = usize;

/// The work of one engine step: decode lanes + prefill chunks sharing one
/// mixed batch.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepBatch {
    /// Sequences decoding one token this step.
    pub decode: Vec<SchedSeqId>,
    /// Prefill chunks riding the same step, FCFS order.
    pub chunks: Vec<PrefillChunk>,
}

impl StepBatch {
    pub fn is_empty(&self) -> bool {
        self.decode.is_empty() && self.chunks.is_empty()
    }

    pub fn prefill_tokens(&self) -> u64 {
        self.chunks.iter().map(|c| c.len).sum()
    }

    /// Total tokens of the mixed batch (the GEMM M dimension).
    pub fn step_tokens(&self) -> u64 {
        self.decode.len() as u64 + self.prefill_tokens()
    }

    /// Σ over chunk tokens of the context they attend to, approximated per
    /// chunk by its end context — the cost-model term for chunked-prefill
    /// attention (each chunk attends over everything computed before it
    /// plus itself).
    pub fn prefill_attn_ctx_tokens(&self) -> u64 {
        self.chunks.iter().map(|c| c.start + c.len).sum()
    }
}

/// Token-budget continuous-batching scheduler with chunked prefill.
///
/// Pure scheduling state machine: the driver owns admission gating (KV
/// capacity), per-step cost, and token content. Lifecycle per sequence:
/// `submit` → (driver admits) `admit_next` → steps of
/// `plan_step`/`commit_step` → `finish` (or `preempt` back to waiting).
#[derive(Debug)]
pub struct ContinuousScheduler {
    pub policy: ChunkPolicy,
    seqs: Vec<SchedSeq>,
    waiting: VecDeque<SchedSeqId>,
    /// Admission order (FCFS for chunk scheduling).
    running: Vec<SchedSeqId>,
}

impl ContinuousScheduler {
    pub fn new(policy: ChunkPolicy) -> Self {
        assert!(policy.token_budget > 0 && policy.max_num_seqs > 0);
        ContinuousScheduler {
            policy,
            seqs: Vec::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
        }
    }

    /// Queue a request. Returns its scheduler slot.
    pub fn submit(&mut self, request_id: u64, prompt_tokens: u64, gen_budget: u64) -> SchedSeqId {
        assert!(prompt_tokens > 0 && gen_budget > 0);
        sched_metrics().submitted.inc();
        let id = self.seqs.len();
        self.seqs.push(SchedSeq {
            request_id,
            prompt_tokens,
            gen_budget,
            cached_prefix: 0,
            prefilled: 0,
            generated: 0,
            state: SchedState::Waiting,
        });
        self.waiting.push_back(id);
        id
    }

    pub fn seq(&self, id: SchedSeqId) -> &SchedSeq {
        &self.seqs[id]
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Next sequence admission would take (FCFS), if any.
    pub fn peek_waiting(&self) -> Option<SchedSeqId> {
        self.waiting.front().copied()
    }

    /// Admit the head of the queue if the resident-sequence cap allows and
    /// `can_admit` (the driver's KV-capacity check) accepts it. A cached
    /// prefix of `cached_prefix` tokens skips that much prefill compute —
    /// "a prefix hit shrinks the remaining chunks".
    pub fn admit_next(
        &mut self,
        cached_prefix: u64,
        can_admit: impl FnOnce(&SchedSeq) -> bool,
    ) -> Option<SchedSeqId> {
        if self.running.len() >= self.policy.max_num_seqs {
            return None;
        }
        let &id = self.waiting.front()?;
        if !can_admit(&self.seqs[id]) {
            return None;
        }
        self.waiting.pop_front();
        let s = &mut self.seqs[id];
        // The cache always leaves at least the prompt's last token to
        // compute (its logits seed generation).
        s.cached_prefix = cached_prefix.min(s.prompt_tokens - 1);
        s.prefilled = s.cached_prefix;
        s.state = SchedState::Running;
        self.running.push(id);
        Some(id)
    }

    /// Drop the head of the queue (request larger than the whole pool).
    pub fn reject_waiting_head(&mut self) -> Option<SchedSeqId> {
        let id = self.waiting.pop_front()?;
        self.seqs[id].state = SchedState::Finished;
        Some(id)
    }

    /// Plan one step: fill the token budget with decode tokens first, then
    /// chunk the admitted prompts (FCFS) into the remainder.
    pub fn plan_step(&self) -> StepBatch {
        let mut span = trace::span("sched.plan_step", "scheduler");
        let mut budget = self.policy.token_budget;
        let mut batch = StepBatch::default();
        for &id in &self.running {
            if budget == 0 {
                break;
            }
            if self.seqs[id].in_decode() {
                batch.decode.push(id);
                budget -= 1;
            }
        }
        for &id in &self.running {
            if budget == 0 {
                break;
            }
            let s = &self.seqs[id];
            let rem = s.prefill_remaining();
            if rem == 0 {
                continue;
            }
            let len = rem.min(budget);
            batch.chunks.push(PrefillChunk { seq: id, start: s.prefilled, len });
            budget -= len;
        }
        let m = sched_metrics();
        m.steps.inc();
        m.decode_lanes.add(batch.decode.len() as u64);
        m.prefill_tokens.add(batch.prefill_tokens());
        span.arg("decode_lanes", batch.decode.len() as f64);
        span.arg("prefill_tokens", batch.prefill_tokens() as f64);
        span.arg("chunks", batch.chunks.len() as f64);
        batch
    }

    /// Apply one planned chunk; returns true when this chunk completed the
    /// prompt (the step's logits for its last token yield the sequence's
    /// first generated token — the driver records TTFT and counts the
    /// token via [`Self::commit_first_token`]).
    pub fn commit_chunk(&mut self, chunk: &PrefillChunk) -> bool {
        let s = &mut self.seqs[chunk.seq];
        debug_assert_eq!(s.state, SchedState::Running);
        debug_assert_eq!(s.prefilled, chunk.start);
        debug_assert!(chunk.len > 0 && chunk.start + chunk.len <= s.prompt_tokens);
        s.prefilled += chunk.len;
        sched_metrics().chunked_prefill_tokens.add(chunk.len);
        s.in_decode()
    }

    /// The prompt-completing chunk's last logits produced the first token.
    pub fn commit_first_token(&mut self, id: SchedSeqId) {
        let s = &mut self.seqs[id];
        debug_assert!(s.in_decode() && s.generated == 0);
        s.generated = 1;
    }

    /// One decode token landed for `id`. Returns true when the generation
    /// budget is now exhausted (driver should `finish`).
    pub fn commit_decode(&mut self, id: SchedSeqId) -> bool {
        let s = &mut self.seqs[id];
        debug_assert!(s.in_decode() && s.state == SchedState::Running);
        s.generated += 1;
        s.generated >= s.gen_budget
    }

    /// Retire a running sequence.
    pub fn finish(&mut self, id: SchedSeqId) {
        debug_assert_eq!(self.seqs[id].state, SchedState::Running);
        self.seqs[id].state = SchedState::Finished;
        self.running.retain(|&r| r != id);
    }

    /// Preempt under KV pressure (vLLM recompute policy): back to the
    /// waiting queue with the remaining generation budget; prefill state
    /// resets so the prompt recomputes on re-admission (a prefix cache can
    /// discount the recompute via `admit_next`'s `cached_prefix`).
    pub fn preempt(&mut self, id: SchedSeqId) {
        sched_metrics().preemptions.inc();
        let s = &mut self.seqs[id];
        debug_assert_eq!(s.state, SchedState::Running);
        s.gen_budget -= s.generated.min(s.gen_budget.saturating_sub(1));
        s.generated = 0;
        s.cached_prefix = 0;
        s.prefilled = 0;
        s.state = SchedState::Waiting;
        self.running.retain(|&r| r != id);
        self.waiting.push_back(id);
    }

    /// Scheduling invariants for tests.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for &id in self.waiting.iter().chain(self.running.iter()) {
            anyhow::ensure!(seen.insert(id), "seq {id} queued twice");
        }
        for &id in &self.waiting {
            anyhow::ensure!(
                self.seqs[id].state == SchedState::Waiting,
                "waiting seq {id} not Waiting"
            );
        }
        for &id in &self.running {
            let s = &self.seqs[id];
            anyhow::ensure!(s.state == SchedState::Running, "running seq {id} not Running");
            anyhow::ensure!(s.prefilled <= s.prompt_tokens, "seq {id} over-prefilled");
            anyhow::ensure!(
                s.in_decode() || s.generated == 0,
                "seq {id} generated before its prompt finished"
            );
        }
        let planned = self.plan_step();
        anyhow::ensure!(
            planned.step_tokens() <= self.policy.token_budget,
            "plan exceeds token budget"
        );
        Ok(())
    }
}

/// What the engine should run next.
#[derive(Debug, PartialEq, Eq)]
pub enum StepPlan {
    /// Run prefill for this queued sequence into the given free lane.
    Prefill { seq_index: usize, lane: usize },
    /// Run one decode step over these lanes (sorted ascending).
    Decode { lanes: Vec<usize> },
    /// Nothing to do.
    Idle,
}

/// Queue + lane bookkeeping. Generic over lane count (the widest artifact).
#[derive(Debug)]
pub struct Batcher {
    pub max_lanes: usize,
    /// Max waiting requests before admission rejects (backpressure).
    pub max_queue: usize,
    /// Context capacity per lane (artifact max_seq).
    pub max_seq: usize,
    /// lane -> sequence slot (index into `seqs`) or None.
    lanes: Vec<Option<usize>>,
    /// All sequences ever admitted this session (stable indices).
    pub seqs: Vec<Sequence>,
    /// Indices of waiting sequences, FCFS.
    waiting: VecDeque<usize>,
}

impl Batcher {
    pub fn new(max_lanes: usize, max_queue: usize, max_seq: usize) -> Self {
        assert!(max_lanes > 0);
        Batcher {
            max_lanes,
            max_queue,
            max_seq,
            lanes: vec![None; max_lanes],
            seqs: Vec::new(),
            waiting: VecDeque::new(),
        }
    }

    /// Admit a request. Returns the sequence slot, or Err(reason).
    pub fn submit(&mut self, req: GenerationRequest) -> Result<usize, FinishReason> {
        if self.waiting.len() >= self.max_queue {
            return Err(FinishReason::Rejected);
        }
        if req.prompt.is_empty()
            || req.prompt.len() + req.max_new_tokens > self.max_seq
        {
            return Err(FinishReason::Rejected);
        }
        let idx = self.seqs.len();
        self.seqs.push(Sequence::new(req));
        self.waiting.push_back(idx);
        Ok(idx)
    }

    pub fn free_lane(&self) -> Option<usize> {
        self.lanes.iter().position(Option::is_none)
    }

    pub fn active_lanes(&self) -> Vec<usize> {
        (0..self.lanes.len()).filter(|&l| self.lanes[l].is_some()).collect()
    }

    pub fn seq_in_lane(&self, lane: usize) -> Option<usize> {
        self.lanes[lane]
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.lanes.iter().any(Option::is_some)
    }

    /// Decide the next step (prefill-priority policy).
    pub fn plan(&self) -> StepPlan {
        if let (Some(&seq_index), Some(lane)) = (self.waiting.front(), self.free_lane()) {
            return StepPlan::Prefill { seq_index, lane };
        }
        let lanes = self.active_lanes();
        if lanes.is_empty() {
            StepPlan::Idle
        } else {
            StepPlan::Decode { lanes }
        }
    }

    /// Record that the engine served `tokens` of this sequence's prompt
    /// from the automatic prefix cache (admission-time hint: those tokens
    /// skip prefill compute; metrics and schedulers read it back).
    pub fn note_cached_prefix(&mut self, seq_index: usize, tokens: usize) {
        debug_assert!(tokens < self.seqs[seq_index].req.prompt.len().max(1));
        self.seqs[seq_index].cached_prefix_tokens = tokens;
    }

    /// Commit a planned prefill: bind the sequence to the lane.
    pub fn start_prefill(&mut self, seq_index: usize, lane: usize) {
        debug_assert_eq!(self.waiting.front(), Some(&seq_index));
        self.waiting.pop_front();
        debug_assert!(self.lanes[lane].is_none());
        self.lanes[lane] = Some(seq_index);
        self.seqs[seq_index].state = SeqState::Running { lane };
    }

    /// Finish the sequence in `lane` and free the lane.
    pub fn finish_lane(&mut self, lane: usize, reason: FinishReason) -> usize {
        let seq_index = self.lanes[lane].take().expect("finish_lane on empty lane");
        self.seqs[seq_index].finish(reason);
        seq_index
    }

    /// Lane-occupancy invariants for tests.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (l, slot) in self.lanes.iter().enumerate() {
            if let Some(s) = slot {
                anyhow::ensure!(seen.insert(*s), "seq {s} in two lanes");
                match self.seqs[*s].state {
                    SeqState::Running { lane } => {
                        anyhow::ensure!(lane == l, "lane mismatch for seq {s}")
                    }
                    other => anyhow::bail!("seq {s} in lane {l} but state {other:?}"),
                }
            }
        }
        for &w in &self.waiting {
            anyhow::ensure!(
                matches!(self.seqs[w].state, SeqState::Waiting),
                "waiting seq {w} not in Waiting state"
            );
        }
        for (i, s) in self.seqs.iter().enumerate() {
            anyhow::ensure!(
                s.cached_prefix_tokens <= s.req.prompt.len(),
                "seq {i} cached prefix exceeds its prompt"
            );
            anyhow::ensure!(
                s.prefilled <= s.req.prompt.len(),
                "seq {i} prefilled past its prompt"
            );
            anyhow::ensure!(
                !s.in_prefill() || s.generated == 0,
                "seq {i} generated mid-prefill"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn req(id: u64, prompt: usize, max_new: usize) -> GenerationRequest {
        GenerationRequest {
            id,
            prompt: (0..prompt as i32).collect(),
            max_new_tokens: max_new,
            temperature: None,
            eos_token: None,
        }
    }

    #[test]
    fn prefill_has_priority_over_decode() {
        let mut b = Batcher::new(2, 16, 64);
        let s0 = b.submit(req(0, 4, 4)).unwrap();
        b.start_prefill(s0, 0);
        b.submit(req(1, 4, 4)).unwrap();
        // lane 1 free + waiting request -> prefill first
        match b.plan() {
            StepPlan::Prefill { lane, .. } => assert_eq!(lane, 1),
            other => panic!("expected prefill, got {other:?}"),
        }
    }

    #[test]
    fn decode_when_lanes_full() {
        let mut b = Batcher::new(2, 16, 64);
        for i in 0..3 {
            b.submit(req(i, 4, 4)).unwrap();
        }
        b.start_prefill(0, 0);
        b.start_prefill(1, 1);
        assert_eq!(b.plan(), StepPlan::Decode { lanes: vec![0, 1] });
        b.check_invariants().unwrap();
    }

    #[test]
    fn finished_lane_reused() {
        let mut b = Batcher::new(1, 16, 64);
        b.submit(req(0, 2, 2)).unwrap();
        b.submit(req(1, 2, 2)).unwrap();
        b.start_prefill(0, 0);
        b.finish_lane(0, FinishReason::Length);
        match b.plan() {
            StepPlan::Prefill { seq_index, lane } => {
                assert_eq!((seq_index, lane), (1, 0));
            }
            other => panic!("{other:?}"),
        }
        b.check_invariants().unwrap();
    }

    #[test]
    fn backpressure_rejects_over_queue() {
        let mut b = Batcher::new(1, 2, 64);
        assert!(b.submit(req(0, 2, 2)).is_ok());
        assert!(b.submit(req(1, 2, 2)).is_ok());
        assert_eq!(b.submit(req(2, 2, 2)), Err(FinishReason::Rejected));
    }

    #[test]
    fn oversized_prompt_rejected() {
        let mut b = Batcher::new(1, 4, 16);
        assert_eq!(b.submit(req(0, 12, 8)), Err(FinishReason::Rejected));
        assert_eq!(b.submit(req(1, 0, 4)), Err(FinishReason::Rejected));
        assert!(b.submit(req(2, 8, 8)).is_ok());
    }

    #[test]
    fn idle_when_empty() {
        let b = Batcher::new(2, 4, 64);
        assert_eq!(b.plan(), StepPlan::Idle);
        assert!(!b.has_work());
    }

    #[test]
    fn cached_prefix_note_reduces_uncached_work() {
        let mut b = Batcher::new(1, 4, 64);
        let s = b.submit(req(0, 12, 4)).unwrap();
        assert_eq!(b.seqs[s].uncached_prompt_tokens(), 12);
        b.note_cached_prefix(s, 8);
        assert_eq!(b.seqs[s].cached_prefix_tokens, 8);
        assert_eq!(b.seqs[s].uncached_prompt_tokens(), 4);
        b.check_invariants().unwrap();
    }
}

#[cfg(test)]
mod continuous_tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn sched(budget: u64, max_seqs: usize) -> ContinuousScheduler {
        ContinuousScheduler::new(ChunkPolicy { token_budget: budget, max_num_seqs: max_seqs })
    }

    /// Drive every planned chunk/decode of one step to completion.
    fn run_step(s: &mut ContinuousScheduler) -> StepBatch {
        let batch = s.plan_step();
        for c in &batch.chunks {
            if s.commit_chunk(c) {
                s.commit_first_token(c.seq);
            }
        }
        for &id in &batch.decode {
            if s.commit_decode(id) {
                s.finish(id);
            }
        }
        batch
    }

    #[test]
    fn decode_fills_budget_first() {
        let mut s = sched(8, 16);
        // Two decoding sequences + one long prompt waiting to chunk.
        for i in 0..2 {
            s.submit(i, 4, 10);
            let id = s.admit_next(0, |_| true).unwrap();
            // complete the prompt in one chunk
            let b = s.plan_step();
            let c = b.chunks.iter().find(|c| c.seq == id).unwrap();
            assert!(s.commit_chunk(c));
            s.commit_first_token(id);
        }
        s.submit(2, 100, 4);
        s.admit_next(0, |_| true).unwrap();
        let batch = s.plan_step();
        assert_eq!(batch.decode.len(), 2);
        // Remaining 6 budget tokens go to the prompt's first chunk.
        assert_eq!(batch.chunks.len(), 1);
        assert_eq!(batch.chunks[0], PrefillChunk { seq: 2, start: 0, len: 6 });
        assert_eq!(batch.step_tokens(), 8);
        s.check_invariants().unwrap();
    }

    #[test]
    fn long_prompt_chunks_across_steps() {
        let mut s = sched(16, 4);
        s.submit(0, 40, 2);
        s.admit_next(0, |_| true).unwrap();
        let mut chunk_lens = Vec::new();
        while s.has_work() {
            let b = run_step(&mut s);
            assert!(!b.is_empty());
            chunk_lens.extend(b.chunks.iter().map(|c| c.len));
        }
        // 40 prompt tokens at budget 16: chunks 16, 16, 8.
        assert_eq!(chunk_lens, vec![16, 16, 8]);
    }

    #[test]
    fn cached_prefix_shrinks_chunks() {
        let mut s = sched(16, 4);
        s.submit(0, 40, 2);
        s.admit_next(32, |_| true).unwrap();
        let b = s.plan_step();
        // 32 tokens leased from the prefix cache: only 8 left to compute.
        assert_eq!(b.chunks, vec![PrefillChunk { seq: 0, start: 32, len: 8 }]);
        assert_eq!(s.seq(0).cached_prefix, 32);
    }

    #[test]
    fn cached_prefix_capped_below_full_prompt() {
        let mut s = sched(16, 4);
        s.submit(0, 10, 2);
        // Even a full-prompt "hit" leaves the last token to compute.
        s.admit_next(10, |_| true).unwrap();
        assert_eq!(s.seq(0).cached_prefix, 9);
        assert_eq!(s.seq(0).prefill_remaining(), 1);
    }

    #[test]
    fn chunk_completion_yields_first_token() {
        let mut s = sched(32, 4);
        s.submit(0, 8, 3);
        s.admit_next(0, |_| true).unwrap();
        let b = s.plan_step();
        assert_eq!(b.chunks[0].len, 8);
        assert!(s.commit_chunk(&b.chunks[0]));
        s.commit_first_token(0);
        assert_eq!(s.seq(0).generated, 1);
        // Two more decode steps exhaust the budget of 3.
        run_step(&mut s);
        assert!(s.has_work());
        run_step(&mut s);
        assert!(!s.has_work());
        assert_eq!(s.seq(0).state, SchedState::Finished);
    }

    #[test]
    fn preempt_requeues_with_recompute() {
        let mut s = sched(32, 4);
        s.submit(0, 8, 10);
        s.admit_next(0, |_| true).unwrap();
        run_step(&mut s); // prefill + first token
        run_step(&mut s); // one decode
        assert_eq!(s.seq(0).generated, 2);
        s.preempt(0);
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.waiting_len(), 1);
        let seq = s.seq(0);
        assert_eq!(seq.state, SchedState::Waiting);
        assert_eq!(seq.prefilled, 0, "recompute policy resets prefill");
        assert_eq!(seq.gen_budget, 8, "generated tokens deducted from budget");
        // Re-admission restarts chunking from scratch.
        s.admit_next(0, |_| true).unwrap();
        let b = s.plan_step();
        assert_eq!(b.chunks, vec![PrefillChunk { seq: 0, start: 0, len: 8 }]);
        s.check_invariants().unwrap();
    }

    #[test]
    fn admission_respects_cap_and_driver_veto() {
        let mut s = sched(32, 1);
        s.submit(0, 4, 2);
        s.submit(1, 4, 2);
        assert!(s.admit_next(0, |_| true).is_some());
        // Resident cap of 1.
        assert!(s.admit_next(0, |_| true).is_none());
        // Finish the resident sequence, then the driver vetoes (no KV).
        run_step(&mut s);
        run_step(&mut s);
        assert_eq!(s.running_len(), 0);
        assert!(s.admit_next(0, |_| false).is_none());
        assert_eq!(s.waiting_len(), 1);
        assert!(s.admit_next(0, |_| true).is_some());
    }

    #[test]
    fn budget_saturation_across_many_seqs() {
        let mut s = sched(64, 256);
        for i in 0..100 {
            s.submit(i, 32, 8);
            s.admit_next(0, |_| true).unwrap();
        }
        let b = s.plan_step();
        assert_eq!(b.step_tokens(), 64, "budget must be exactly filled");
        // FCFS: the first two prompts chunk (32 + 32), later ones wait.
        assert_eq!(b.chunks.len(), 2);
        s.check_invariants().unwrap();
    }

    #[test]
    fn attn_ctx_accounts_chunk_end_context() {
        let mut s = sched(16, 4);
        s.submit(0, 40, 2);
        s.admit_next(0, |_| true).unwrap();
        let b1 = s.plan_step();
        assert_eq!(b1.prefill_attn_ctx_tokens(), 16); // 0 + 16
        for c in &b1.chunks {
            s.commit_chunk(c);
        }
        let b2 = s.plan_step();
        assert_eq!(b2.prefill_attn_ctx_tokens(), 32); // 16 + 16
    }
}
