//! Continuous batcher: admission queue + lane assignment + step planning.
//!
//! The engine runs fixed-shape AOT decode artifacts (batch ∈ the manifest's
//! compiled sizes), so "continuous batching" here means: sequences join and
//! leave *lanes* of the widest useful artifact between steps, vLLM-style,
//! with the step batch chosen as the smallest compiled size ≥ active lanes.
//! Prefill runs as its own (batch-1) artifact call, scheduled ahead of
//! decode when lanes are free — the same prioritize-prefill policy vLLM's
//! default scheduler uses.

use std::collections::VecDeque;

use super::request::{FinishReason, GenerationRequest, SeqState, Sequence};

/// What the engine should run next.
#[derive(Debug, PartialEq, Eq)]
pub enum StepPlan {
    /// Run prefill for this queued sequence into the given free lane.
    Prefill { seq_index: usize, lane: usize },
    /// Run one decode step over these lanes (sorted ascending).
    Decode { lanes: Vec<usize> },
    /// Nothing to do.
    Idle,
}

/// Queue + lane bookkeeping. Generic over lane count (the widest artifact).
#[derive(Debug)]
pub struct Batcher {
    pub max_lanes: usize,
    /// Max waiting requests before admission rejects (backpressure).
    pub max_queue: usize,
    /// Context capacity per lane (artifact max_seq).
    pub max_seq: usize,
    /// lane -> sequence slot (index into `seqs`) or None.
    lanes: Vec<Option<usize>>,
    /// All sequences ever admitted this session (stable indices).
    pub seqs: Vec<Sequence>,
    /// Indices of waiting sequences, FCFS.
    waiting: VecDeque<usize>,
}

impl Batcher {
    pub fn new(max_lanes: usize, max_queue: usize, max_seq: usize) -> Self {
        assert!(max_lanes > 0);
        Batcher {
            max_lanes,
            max_queue,
            max_seq,
            lanes: vec![None; max_lanes],
            seqs: Vec::new(),
            waiting: VecDeque::new(),
        }
    }

    /// Admit a request. Returns the sequence slot, or Err(reason).
    pub fn submit(&mut self, req: GenerationRequest) -> Result<usize, FinishReason> {
        if self.waiting.len() >= self.max_queue {
            return Err(FinishReason::Rejected);
        }
        if req.prompt.is_empty()
            || req.prompt.len() + req.max_new_tokens > self.max_seq
        {
            return Err(FinishReason::Rejected);
        }
        let idx = self.seqs.len();
        self.seqs.push(Sequence::new(req));
        self.waiting.push_back(idx);
        Ok(idx)
    }

    pub fn free_lane(&self) -> Option<usize> {
        self.lanes.iter().position(Option::is_none)
    }

    pub fn active_lanes(&self) -> Vec<usize> {
        (0..self.lanes.len()).filter(|&l| self.lanes[l].is_some()).collect()
    }

    pub fn seq_in_lane(&self, lane: usize) -> Option<usize> {
        self.lanes[lane]
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.lanes.iter().any(Option::is_some)
    }

    /// Decide the next step (prefill-priority policy).
    pub fn plan(&self) -> StepPlan {
        if let (Some(&seq_index), Some(lane)) = (self.waiting.front(), self.free_lane()) {
            return StepPlan::Prefill { seq_index, lane };
        }
        let lanes = self.active_lanes();
        if lanes.is_empty() {
            StepPlan::Idle
        } else {
            StepPlan::Decode { lanes }
        }
    }

    /// Record that the engine served `tokens` of this sequence's prompt
    /// from the automatic prefix cache (admission-time hint: those tokens
    /// skip prefill compute; metrics and schedulers read it back).
    pub fn note_cached_prefix(&mut self, seq_index: usize, tokens: usize) {
        debug_assert!(tokens < self.seqs[seq_index].req.prompt.len().max(1));
        self.seqs[seq_index].cached_prefix_tokens = tokens;
    }

    /// Commit a planned prefill: bind the sequence to the lane.
    pub fn start_prefill(&mut self, seq_index: usize, lane: usize) {
        debug_assert_eq!(self.waiting.front(), Some(&seq_index));
        self.waiting.pop_front();
        debug_assert!(self.lanes[lane].is_none());
        self.lanes[lane] = Some(seq_index);
        self.seqs[seq_index].state = SeqState::Running { lane };
    }

    /// Finish the sequence in `lane` and free the lane.
    pub fn finish_lane(&mut self, lane: usize, reason: FinishReason) -> usize {
        let seq_index = self.lanes[lane].take().expect("finish_lane on empty lane");
        self.seqs[seq_index].finish(reason);
        seq_index
    }

    /// Lane-occupancy invariants for tests.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (l, slot) in self.lanes.iter().enumerate() {
            if let Some(s) = slot {
                anyhow::ensure!(seen.insert(*s), "seq {s} in two lanes");
                match self.seqs[*s].state {
                    SeqState::Running { lane } => {
                        anyhow::ensure!(lane == l, "lane mismatch for seq {s}")
                    }
                    other => anyhow::bail!("seq {s} in lane {l} but state {other:?}"),
                }
            }
        }
        for &w in &self.waiting {
            anyhow::ensure!(
                matches!(self.seqs[w].state, SeqState::Waiting),
                "waiting seq {w} not in Waiting state"
            );
        }
        for (i, s) in self.seqs.iter().enumerate() {
            anyhow::ensure!(
                s.cached_prefix_tokens <= s.req.prompt.len(),
                "seq {i} cached prefix exceeds its prompt"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, max_new: usize) -> GenerationRequest {
        GenerationRequest {
            id,
            prompt: (0..prompt as i32).collect(),
            max_new_tokens: max_new,
            temperature: None,
            eos_token: None,
        }
    }

    #[test]
    fn prefill_has_priority_over_decode() {
        let mut b = Batcher::new(2, 16, 64);
        let s0 = b.submit(req(0, 4, 4)).unwrap();
        b.start_prefill(s0, 0);
        b.submit(req(1, 4, 4)).unwrap();
        // lane 1 free + waiting request -> prefill first
        match b.plan() {
            StepPlan::Prefill { lane, .. } => assert_eq!(lane, 1),
            other => panic!("expected prefill, got {other:?}"),
        }
    }

    #[test]
    fn decode_when_lanes_full() {
        let mut b = Batcher::new(2, 16, 64);
        for i in 0..3 {
            b.submit(req(i, 4, 4)).unwrap();
        }
        b.start_prefill(0, 0);
        b.start_prefill(1, 1);
        assert_eq!(b.plan(), StepPlan::Decode { lanes: vec![0, 1] });
        b.check_invariants().unwrap();
    }

    #[test]
    fn finished_lane_reused() {
        let mut b = Batcher::new(1, 16, 64);
        b.submit(req(0, 2, 2)).unwrap();
        b.submit(req(1, 2, 2)).unwrap();
        b.start_prefill(0, 0);
        b.finish_lane(0, FinishReason::Length);
        match b.plan() {
            StepPlan::Prefill { seq_index, lane } => {
                assert_eq!((seq_index, lane), (1, 0));
            }
            other => panic!("{other:?}"),
        }
        b.check_invariants().unwrap();
    }

    #[test]
    fn backpressure_rejects_over_queue() {
        let mut b = Batcher::new(1, 2, 64);
        assert!(b.submit(req(0, 2, 2)).is_ok());
        assert!(b.submit(req(1, 2, 2)).is_ok());
        assert_eq!(b.submit(req(2, 2, 2)), Err(FinishReason::Rejected));
    }

    #[test]
    fn oversized_prompt_rejected() {
        let mut b = Batcher::new(1, 4, 16);
        assert_eq!(b.submit(req(0, 12, 8)), Err(FinishReason::Rejected));
        assert_eq!(b.submit(req(1, 0, 4)), Err(FinishReason::Rejected));
        assert!(b.submit(req(2, 8, 8)).is_ok());
    }

    #[test]
    fn idle_when_empty() {
        let b = Batcher::new(2, 4, 64);
        assert_eq!(b.plan(), StepPlan::Idle);
        assert!(!b.has_work());
    }

    #[test]
    fn cached_prefix_note_reduces_uncached_work() {
        let mut b = Batcher::new(1, 4, 64);
        let s = b.submit(req(0, 12, 4)).unwrap();
        assert_eq!(b.seqs[s].uncached_prompt_tokens(), 12);
        b.note_cached_prefix(s, 8);
        assert_eq!(b.seqs[s].cached_prefix_tokens, 8);
        assert_eq!(b.seqs[s].uncached_prompt_tokens(), 4);
        b.check_invariants().unwrap();
    }
}
