//! The serving engine: continuous batching with chunked prefill over the
//! AOT-compiled tiny model, executed through PJRT. Python is never on this
//! path.
//!
//! State layout: the engine keeps each lane's KV cache as host buffers of
//! shape `(L, 1, S, H, hd)` and assembles the batched `(L, B, S, H, hd)`
//! cache for whichever decode artifact width it selects for the step
//! (smallest compiled batch ≥ active lanes). Idle lanes carry zeros and
//! their outputs are discarded; because assembly happens per step from the
//! per-lane source of truth, dummy-lane KV writes never leak.
//!
//! Chunked prefill: a new sequence's head window goes through the prefill
//! artifact; any remaining prompt tokens are teacher-forced **one per
//! mixed decode step** alongside the decoding lanes (the lane-granular
//! version of the token-budget scheduler in `coordinator::batcher` — the
//! fixed-shape decode artifact is the step, mid-prefill lanes are the
//! chunks). Long prompts therefore no longer stall the decode batch with
//! serial batch-1 teacher-forcing; their tail tokens ride steps the
//! decoding lanes were paying for anyway.
//!
//! This engine serves real tokens through PJRT; its scheduling twin on
//! the native kernel runtime is `coordinator::measured` +
//! `simserve::simulate_continuous_measured`, which drives the same
//! decode-first/chunked-prefill step shape through per-rank
//! `StepExecutor` GEMM streams and reports measured tokens/sec against
//! the `gpusim` model (the drift ledger quantifies the seam).
//!
//! Correctness note on padded prefill: the prefill artifact processes a
//! fixed-length prompt window; pad slots beyond the true length hold
//! garbage K/V, but decode writes token `t` at slot `pos = len + t` *before*
//! attending (mask `slot <= pos`), so every garbage slot is overwritten
//! before it first becomes visible. Locked by `test_padded_prefill` on the
//! Python side and the engine integration test. Teacher-forced prompt
//! tokens follow the same rule: slot `prefilled` is written before any
//! later slot becomes visible.

use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batcher, StepPlan};
use super::sampler;
use super::metrics::EngineMetrics;
use super::prefix::PrefixIndex;
use super::request::{FinishReason, GenerationRequest, SeqState};
use crate::obs::{HistogramHandle, Registry};
use crate::runtime::{HostTensor, Runtime};

/// Registry mirrors of the engine's latency histograms, resolved once.
/// `EngineMetrics` stays the per-engine aggregate; these feed the
/// process-wide snapshot (`report obs`).
struct EngineObs {
    ttft_s: HistogramHandle,
    itl_s: HistogramHandle,
    e2e_s: HistogramHandle,
}

fn engine_obs() -> &'static EngineObs {
    static OBS: OnceLock<EngineObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = Registry::global();
        EngineObs {
            ttft_s: r.histogram("engine.ttft_s"),
            itl_s: r.histogram("engine.itl_s"),
            e2e_s: r.histogram("engine.e2e_s"),
        }
    })
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Which kernel variant's artifacts to serve ("quick" | "awq" | "fp16").
    pub kernel: String,
    pub max_queue: usize,
    /// Seed for temperature sampling (greedy requests ignore it).
    pub sample_seed: u64,
    /// Automatic prefix caching: reuse host KV blocks across requests that
    /// share a prompt prefix, skipping their prefill compute.
    pub enable_prefix_cache: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            kernel: "quick".into(),
            max_queue: 256,
            sample_seed: 0,
            enable_prefix_cache: true,
        }
    }
}

struct LaneCache {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Token granularity of the engine's prefix-cache blocks (small because
/// the tiny AOT model's context is small).
const PREFIX_BLOCK_TOKENS: usize = 8;
/// LRU budget: max cached blocks resident in host memory.
const PREFIX_CACHE_MAX_BLOCKS: usize = 512;

/// One cached full block of host KV: per layer, `PREFIX_BLOCK_TOKENS`
/// slots of `(heads, head_dim)` — the exact values the model computed for
/// these token ids at these positions, so reuse is bit-identical.
struct HostKvBlock {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Engine-side automatic prefix cache: the shared radix-trie index maps
/// token prefixes to handles into a host block store. Unlike the paged
/// simulator path there is no refcounting — leasing copies block data
/// into the lane cache, so eviction can never invalidate a running lane.
struct EnginePrefixCache {
    index: PrefixIndex,
    store: std::collections::HashMap<u32, HostKvBlock>,
    next_handle: u32,
}

/// Result of one finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
}

pub struct Engine {
    rt: Runtime,
    pub batcher: Batcher,
    pub metrics: EngineMetrics,
    cfg: EngineConfig,
    /// Compiled decode widths, ascending (from the manifest).
    widths: Vec<u64>,
    prefill_seq: usize,
    max_seq: usize,
    n_layers: usize,
    heads: usize,
    head_dim: usize,
    vocab: usize,
    lanes: Vec<Option<LaneCache>>,
    prefix: EnginePrefixCache,
    completions: Vec<Completion>,
    last_token_at: Vec<Option<Instant>>,
    rng: crate::util::rng::Rng,
    /// Steady-state decode fast path (perf pass §Perf iteration 3): while
    /// the active lane set is unchanged between decode steps, the batched
    /// KV cache stays as PJRT literals and is fed straight back into the
    /// next execution — skipping the per-step host gather/scatter
    /// (~2 MB x 4 memcpys + literal rebuilds per step at b8).
    steady: Option<SteadyState>,
}

struct SteadyState {
    lanes: Vec<usize>,
    nb: usize,
    k: xla::Literal,
    v: xla::Literal,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Self> {
        let m = &rt.manifest;
        let widths = m.decode_batches(&cfg.kernel);
        if widths.is_empty() {
            bail!("no decode artifacts for kernel '{}'", cfg.kernel);
        }
        let prefill = m
            .prefill_artifact(&cfg.kernel)
            .ok_or_else(|| anyhow!("no prefill artifact for '{}'", cfg.kernel))?;
        let prefill_seq = prefill.seq.unwrap_or(16) as usize;
        let mc = &m.model_config;
        let max_lanes = match widths.last() {
            Some(&w) => w as usize,
            None => bail!("no decode widths for kernel '{}'", cfg.kernel),
        };
        let max_seq = mc.max_seq as usize;
        let batcher = Batcher::new(max_lanes, cfg.max_queue, max_seq);
        Ok(Engine {
            widths,
            prefill_seq,
            max_seq,
            n_layers: mc.n_layers as usize,
            heads: mc.n_heads as usize,
            head_dim: (mc.d_model / mc.n_heads) as usize,
            vocab: mc.vocab as usize,
            lanes: (0..max_lanes).map(|_| None).collect(),
            prefix: EnginePrefixCache {
                index: PrefixIndex::new(PREFIX_BLOCK_TOKENS),
                store: std::collections::HashMap::new(),
                next_handle: 0,
            },
            last_token_at: vec![None; max_lanes],
            completions: Vec::new(),
            steady: None,
            batcher,
            metrics: EngineMetrics::new(),
            rng: crate::util::rng::Rng::seed_from_u64(cfg.sample_seed),
            cfg,
            rt,
        })
    }

    pub fn kernel(&self) -> &str {
        &self.cfg.kernel
    }

    /// Max prompt length this engine accepts. Prompts longer than the
    /// prefill artifact's window are *chunk-prefilled*: the first
    /// `prefill_seq` tokens go through the prefill artifact, the remainder
    /// are teacher-forced one at a time through batch-1 decode steps.
    pub fn max_prompt(&self) -> usize {
        self.max_seq - 1
    }

    /// The prefill artifact's native window.
    pub fn prefill_window(&self) -> usize {
        self.prefill_seq
    }

    pub fn max_context(&self) -> usize {
        self.max_seq
    }

    /// Submit a request; rejected requests complete immediately.
    pub fn submit(&mut self, req: GenerationRequest) -> Result<()> {
        if req.prompt.iter().any(|&t| t < 0 || t as usize >= self.vocab) {
            bail!("token id out of vocab range");
        }
        let id = req.id;
        match self.batcher.submit(req) {
            Ok(_) => {
                self.metrics.requests_admitted += 1;
            }
            Err(reason) => {
                self.metrics.requests_rejected += 1;
                self.completions.push(Completion { id, tokens: vec![], reason });
            }
        }
        Ok(())
    }

    /// Drive the engine until all submitted work is finished.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.batcher.has_work() {
            self.step()?;
        }
        Ok(())
    }

    /// Take finished requests.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// One engine step: either a prefill or a batched decode.
    pub fn step(&mut self) -> Result<bool> {
        self.metrics.engine_steps += 1;
        match self.batcher.plan() {
            StepPlan::Idle => Ok(false),
            StepPlan::Prefill { seq_index, lane } => {
                self.batcher.start_prefill(seq_index, lane);
                self.run_prefill(seq_index, lane)?;
                Ok(true)
            }
            StepPlan::Decode { lanes } => {
                self.run_decode(&lanes)?;
                Ok(true)
            }
        }
    }

    fn lane_elems(&self) -> usize {
        self.max_seq * self.heads * self.head_dim
    }

    /// Flush the steady-state literal cache back into per-lane host
    /// buffers (one-time cost paid only when lane membership changes).
    fn sync_steady_to_host(&mut self) -> Result<()> {
        let Some(st) = self.steady.take() else { return Ok(()) };
        let le = self.lane_elems();
        let k_host = HostTensor::from_literal(&st.k)?;
        let v_host = HostTensor::from_literal(&st.v)?;
        let (k_host, v_host) = (k_host.as_f32()?, v_host.as_f32()?);
        for (slot, &lane) in st.lanes.iter().enumerate() {
            // A lane may have finished since the last decode step.
            let Some(cache) = self.lanes[lane].as_mut() else { continue };
            for l in 0..self.n_layers {
                let src = (l * st.nb + slot) * le;
                let dst = l * le;
                cache.k[dst..dst + le].copy_from_slice(&k_host[src..src + le]);
                cache.v[dst..dst + le].copy_from_slice(&v_host[src..src + le]);
            }
        }
        Ok(())
    }

    fn run_prefill(&mut self, seq_index: usize, lane: usize) -> Result<()> {
        self.metrics.prefill_steps += 1;
        // The new lane joins the next decode batch: the literal-resident
        // steady state is about to be invalidated anyway, and this lane's
        // host buffer becomes authoritative.
        self.sync_steady_to_host()?;
        let s = self.prefill_seq;
        let (prompt_len, prompt) = {
            let seq = &self.batcher.seqs[seq_index];
            (seq.req.prompt.len(), seq.req.prompt.clone())
        };
        let cache_shape = vec![self.n_layers, 1, self.max_seq, self.heads, self.head_dim];

        // Longest cached prefix (full blocks only; the index always leaves
        // at least one prompt token to compute logits from).
        let matched = if self.cfg.enable_prefix_cache {
            self.prefix.index.match_prefix(&prompt)
        } else {
            Vec::new()
        };
        let mut cached_tokens = matched.len() * PREFIX_BLOCK_TOKENS;
        // A hit pays off only when it covers at least the prefill
        // artifact's window: the cached path skips that one artifact call
        // and lets the suffix ride mixed decode steps, so a shallower
        // match would trade one prefill call for >= window chunk-riding
        // steps instead of removing work.
        if cached_tokens < prompt_len.min(s) {
            cached_tokens = 0;
        }

        self.metrics.prompt_tokens += prompt_len as u64;
        if cached_tokens > 0 {
            // Prefix hit: seed the lane's KV from the cached blocks — the
            // exact values a from-scratch prefill would recompute. The
            // uncached suffix rides subsequent mixed decode steps (the
            // cache always leaves at least the prompt's last token, whose
            // step logits seed generation).
            let le = self.lane_elems();
            let span = PREFIX_BLOCK_TOKENS * self.heads * self.head_dim;
            let mut k = vec![0f32; self.n_layers * le];
            let mut v = vec![0f32; self.n_layers * le];
            for (bi, m) in matched[..cached_tokens / PREFIX_BLOCK_TOKENS].iter().enumerate() {
                let blk =
                    self.prefix.store.get(&m.block).expect("indexed block has host data");
                for l in 0..self.n_layers {
                    let dst = l * le + bi * span;
                    let src = l * span;
                    k[dst..dst + span].copy_from_slice(&blk.k[src..src + span]);
                    v[dst..dst + span].copy_from_slice(&blk.v[src..src + span]);
                }
            }
            self.lanes[lane] = Some(LaneCache { k, v });
            self.metrics.prefix_hits += 1;
            self.metrics.prefix_tokens_skipped += cached_tokens as u64;
            self.batcher.note_cached_prefix(seq_index, cached_tokens);
            self.batcher.seqs[seq_index].prefilled = cached_tokens;
            debug_assert!(self.batcher.seqs[seq_index].in_prefill());
            return Ok(());
        }

        if self.cfg.enable_prefix_cache {
            self.metrics.prefix_misses += 1;
        }
        // Head chunk through the prefill artifact; any remaining prompt
        // tokens are chunk-prefilled by the mixed decode steps.
        let head = prompt_len.min(s);
        let mut tokens_padded = prompt[..head].to_vec();
        tokens_padded.resize(s, 0);
        let name = format!("prefill_{}_b1_s{}", self.cfg.kernel, s);
        let zeros = vec![0f32; self.n_layers * self.lane_elems()];
        let args = [
            HostTensor::I32(tokens_padded, vec![1, s]),
            HostTensor::I32(vec![head as i32], vec![1]),
            HostTensor::F32(zeros.clone(), cache_shape.clone()),
            HostTensor::F32(zeros, cache_shape.clone()),
        ];
        let outs = self.rt.execute(&name, &args)?;
        let k = outs[1].as_f32()?.to_vec();
        let v = outs[2].as_f32()?.to_vec();
        self.lanes[lane] = Some(LaneCache { k, v });
        self.batcher.seqs[seq_index].prefilled = head;

        if head == prompt_len {
            // Whole prompt fit the window: its last-token logits yield the
            // first generated token now.
            let logits = outs[0].as_f32()?;
            if self.cfg.enable_prefix_cache {
                self.register_prompt_blocks(lane, &prompt);
            }
            let temp = self.batcher.seqs[seq_index].req.temperature;
            let tok = sampler::sample(&logits[..self.vocab], temp, &mut self.rng);
            let seq = &mut self.batcher.seqs[seq_index];
            seq.push_generated(tok);
            self.metrics.generated_tokens += 1;
            let first_at = seq
                .first_token_at
                .ok_or_else(|| anyhow!("sequence {} generated without a TTFT stamp", seq.req.id))?;
            let ttft = first_at.duration_since(seq.enqueued_at);
            self.metrics.ttft.record(ttft);
            engine_obs().ttft_s.record(ttft);
            self.last_token_at[lane] = Some(Instant::now());
            self.maybe_finish_lane(lane)?;
        }
        Ok(())
    }

    /// Insert the prompt's full blocks into the prefix index, copying
    /// their KV out of the lane cache; chain links already cached keep the
    /// first writer's data (content-identical by construction). Evicts LRU
    /// leaves past the store budget.
    fn register_prompt_blocks(&mut self, lane: usize, prompt: &[i32]) {
        let bs = PREFIX_BLOCK_TOKENS;
        let n_full = prompt.len() / bs;
        if n_full == 0 {
            return;
        }
        // Candidate handles: skip any still backing a live cached block so
        // a wrapped counter can never overwrite data an index node maps to.
        let mut handles = Vec::with_capacity(n_full);
        let mut h = self.prefix.next_handle;
        for _ in 0..n_full {
            while self.prefix.store.contains_key(&h) {
                h = h.wrapping_add(1);
            }
            handles.push(h);
            h = h.wrapping_add(1);
        }
        self.prefix.next_handle = h;
        let newly = self.prefix.index.insert(&prompt[..n_full * bs], &handles);
        if !newly.is_empty() {
            let cache = self.lanes[lane].as_ref().expect("lane cache present");
            let le = self.lane_elems();
            let span = bs * self.heads * self.head_dim;
            for (ci, handle) in newly {
                let mut k = Vec::with_capacity(self.n_layers * span);
                let mut v = Vec::with_capacity(self.n_layers * span);
                for l in 0..self.n_layers {
                    let src = l * le + ci * span;
                    k.extend_from_slice(&cache.k[src..src + span]);
                    v.extend_from_slice(&cache.v[src..src + span]);
                }
                self.prefix.store.insert(handle, HostKvBlock { k, v });
            }
        }
        while self.prefix.store.len() > PREFIX_CACHE_MAX_BLOCKS {
            match self.prefix.index.evict_lru(|_| true) {
                Some(b) => {
                    self.prefix.store.remove(&b);
                    self.metrics.prefix_evictions += 1;
                }
                None => break,
            }
        }
    }

    fn run_decode(&mut self, lanes: &[usize]) -> Result<()> {
        self.metrics.decode_steps += 1;
        self.metrics.decode_lane_steps += lanes.len() as u64;
        let widest = self.widths.iter().find(|&&w| w as usize >= lanes.len());
        let nb = match widest.or(self.widths.last()) {
            Some(&w) => w as usize,
            None => bail!("engine has no decode artifact widths"),
        };
        anyhow::ensure!(lanes.len() <= nb, "more active lanes than widest artifact");

        let le = self.lane_elems();
        let mut tokens = vec![0i32; nb];
        let mut pos = vec![0i32; nb];
        for (slot, &lane) in lanes.iter().enumerate() {
            let seq_index = self.batcher.seq_in_lane(lane).expect("active lane empty");
            let seq = &self.batcher.seqs[seq_index];
            if seq.in_prefill() {
                // Chunked prefill riding the decode batch: teacher-force
                // the next prompt token at its context position.
                tokens[slot] = seq.next_prefill_token();
                pos[slot] = seq.prefilled as i32;
            } else {
                tokens[slot] = seq.last_token();
                pos[slot] = (seq.pos() - 1) as i32;
            }
        }
        let tokens_lit = HostTensor::I32(tokens, vec![nb]).to_literal()?;
        let pos_lit = HostTensor::I32(pos, vec![nb]).to_literal()?;

        // Fast path: lane membership unchanged -> reuse the KV literals
        // from the previous step without touching the host.
        let steady_hit = matches!(&self.steady,
            Some(st) if st.nb == nb && st.lanes == lanes);
        if !steady_hit {
            self.sync_steady_to_host()?;
        }
        let (k_lit, v_lit) = match self.steady.take() {
            Some(st) if steady_hit => (st.k, st.v),
            _ => {
                // Assemble the batched cache from the per-lane host copies.
                let mut k = vec![0f32; self.n_layers * nb * le];
                let mut v = vec![0f32; self.n_layers * nb * le];
                for (slot, &lane) in lanes.iter().enumerate() {
                    let cache = self.lanes[lane].as_ref().expect("lane cache missing");
                    for l in 0..self.n_layers {
                        let dst = (l * nb + slot) * le;
                        let src = l * le;
                        k[dst..dst + le].copy_from_slice(&cache.k[src..src + le]);
                        v[dst..dst + le].copy_from_slice(&cache.v[src..src + le]);
                    }
                }
                let shape = vec![self.n_layers, nb, self.max_seq, self.heads, self.head_dim];
                (
                    HostTensor::F32(k, shape.clone()).to_literal()?,
                    HostTensor::F32(v, shape).to_literal()?,
                )
            }
        };

        let name = format!("decode_{}_b{}", self.cfg.kernel, nb);
        let args = [&tokens_lit, &pos_lit, &k_lit, &v_lit];
        let mut outs = self.rt.execute_literals(&name, &args)?;
        let logits_t = HostTensor::from_literal(&outs[0])?;
        let logits = logits_t.as_f32()?;
        // Keep the updated caches literal-resident for the next step.
        let new_v = outs.pop().expect("v out");
        let new_k = outs.pop().expect("k out");
        self.steady = Some(SteadyState { lanes: lanes.to_vec(), nb, k: new_k, v: new_v });

        let now = Instant::now();
        let mut membership_changed = false;
        // Lanes whose prompt completed this step: their slot logits yield
        // the first generated token, and their full prompt KV becomes
        // publishable once flushed to the host.
        let mut completed_prompts: Vec<(usize, usize)> = Vec::new();
        for (slot, &lane) in lanes.iter().enumerate() {
            let seq_index = self
                .batcher
                .seq_in_lane(lane)
                .ok_or_else(|| anyhow!("decode batch references empty lane {lane}"))?;
            if self.batcher.seqs[seq_index].in_prefill() {
                let seq = &mut self.batcher.seqs[seq_index];
                seq.prefilled += 1;
                self.metrics.chunked_prefill_tokens += 1;
                if seq.in_prefill() {
                    continue; // mid-prompt: this slot's logits are discarded
                }
                completed_prompts.push((slot, lane));
                continue;
            }
            let temp = self.batcher.seqs[seq_index].req.temperature;
            let tok = sampler::sample(
                &logits[slot * self.vocab..(slot + 1) * self.vocab],
                temp,
                &mut self.rng,
            );
            self.batcher.seqs[seq_index].push_generated(tok);
            self.metrics.generated_tokens += 1;
            if let Some(prev) = self.last_token_at[lane] {
                let itl = now.duration_since(prev);
                self.metrics.itl.record(itl);
                engine_obs().itl_s.record(itl);
            }
            self.last_token_at[lane] = Some(now);
            let was = self.batcher.seq_in_lane(lane).is_some();
            self.maybe_finish_lane(lane)?;
            if was && self.batcher.seq_in_lane(lane).is_none() {
                membership_changed = true;
            }
        }
        if !completed_prompts.is_empty() {
            // The completing tokens' KV lives only in the step's literals:
            // flush before publishing prompt blocks (costs one steady-state
            // rebuild, paid once per longer-than-window prompt).
            if self.cfg.enable_prefix_cache {
                self.sync_steady_to_host()?;
            }
            for &(slot, lane) in &completed_prompts {
                let seq_index = self
                    .batcher
                    .seq_in_lane(lane)
                    .ok_or_else(|| anyhow!("prompt-completing lane {lane} is empty"))?;
                if self.cfg.enable_prefix_cache {
                    let prompt = self.batcher.seqs[seq_index].req.prompt.clone();
                    self.register_prompt_blocks(lane, &prompt);
                }
                let temp = self.batcher.seqs[seq_index].req.temperature;
                let tok = sampler::sample(
                    &logits[slot * self.vocab..(slot + 1) * self.vocab],
                    temp,
                    &mut self.rng,
                );
                let seq = &mut self.batcher.seqs[seq_index];
                seq.push_generated(tok);
                self.metrics.generated_tokens += 1;
                let first_at = seq.first_token_at.ok_or_else(|| {
                    anyhow!("sequence {} generated without a TTFT stamp", seq.req.id)
                })?;
                let ttft = first_at.duration_since(seq.enqueued_at);
                self.metrics.ttft.record(ttft);
                engine_obs().ttft_s.record(ttft);
                self.last_token_at[lane] = Some(now);
                let was = self.batcher.seq_in_lane(lane).is_some();
                self.maybe_finish_lane(lane)?;
                if was && self.batcher.seq_in_lane(lane).is_none() {
                    membership_changed = true;
                }
            }
        }
        if membership_changed {
            // Finished lanes leave the batch: flush so surviving lanes'
            // host copies are current before the next (smaller) assembly.
            self.sync_steady_to_host()?;
        }
        Ok(())
    }

    fn maybe_finish_lane(&mut self, lane: usize) -> Result<()> {
        let seq_index = self.batcher.seq_in_lane(lane).expect("lane empty");
        let seq = &self.batcher.seqs[seq_index];
        // Also force-stop when the context window is exhausted.
        let stop = seq
            .should_stop()
            .or((seq.pos() >= self.max_seq).then_some(FinishReason::Length));
        if let Some(reason) = stop {
            let seq_index = self.batcher.finish_lane(lane, reason);
            self.lanes[lane] = None;
            self.last_token_at[lane] = None;
            let seq = &self.batcher.seqs[seq_index];
            self.metrics.requests_finished += 1;
            let finished_at = seq
                .finished_at
                .ok_or_else(|| anyhow!("sequence {} finished without a timestamp", seq.req.id))?;
            let e2e = finished_at.duration_since(seq.enqueued_at);
            self.metrics.e2e.record(e2e);
            engine_obs().e2e_s.record(e2e);
            self.completions.push(Completion {
                id: seq.req.id,
                tokens: seq.output_tokens().to_vec(),
                reason,
            });
        }
        Ok(())
    }

    /// Match the running state: used by tests/examples for assertions.
    pub fn active_sequences(&self) -> usize {
        self.batcher
            .seqs
            .iter()
            .filter(|s| matches!(s.state, SeqState::Running { .. }))
            .count()
    }

    pub fn runtime_stats(&self) -> &std::collections::HashMap<String, crate::runtime::ExecStats> {
        self.rt.stats()
    }
}
