//! Deterministic fault injection, replica failover with KV recompute, and
//! SLO-aware graceful degradation — the chaos-hardening layer over the
//! multi-replica serving simulation.
//!
//! The subsystem has three parts:
//!
//! * **Fault plans** ([`FaultPlan`]): a seeded, sorted schedule of replica
//!   crashes, recoveries, slowdown ("stall") windows, and transient KV-pool
//!   pressure windows, generated from a `(seed, scenario)` pair so every
//!   chaos run replays bit-identically ([`FaultPlan::generate`]).
//! * **Failover with KV-state correctness** ([`run_chaos`]): a crashed
//!   replica loses its KV pool and prefix cache wholesale — in-flight
//!   sequences requeue onto healthy replicas through the recompute path
//!   with exponential backoff, and the crashed replica's cache is replaced
//!   by a fresh instance so no phantom prefix hits survive the crash
//!   (asserted by [`ChaosResult::phantom_guard_violations`]). Recovery
//!   walks the router's unhealthy → probing → healthy ramp.
//! * **SLO-aware graceful degradation**: when a replica cannot admit a
//!   request at the pool precision, [`ShedPolicy::DegradeThenReject`]
//!   retries admission at [`KvPrecision::Int8`] then [`KvPrecision::Int4`]
//!   — quantized KV packs more tokens per block, so degraded admissions
//!   ride out pressure windows that would otherwise shed load — before
//!   falling back to rejection with a [`RejectReason`]. TTFT-expired heads
//!   are shed instead of served hopelessly late.
//!
//! Every admitted request terminates in exactly one [`Outcome`]: finished,
//! or rejected with a reason code. The chaos property suite
//! (`tests/chaos_property.rs`) checks that conservation law over hundreds
//! of random fault plans.

use std::collections::{HashSet, VecDeque};
use std::sync::OnceLock;

use anyhow::{ensure, Result};

use crate::gpusim::kernel_model::{Calib, KernelKind};
use crate::gpusim::{tp_step_latency, DeviceSpec};
use crate::model::LlmSpec;
use crate::obs::{trace, Counter, Registry};
use crate::quant::KvPrecision;
use crate::util::Rng;
use crate::workload::Request;

use super::batcher::{ChunkPolicy, ContinuousScheduler, SchedState};
use super::kv_cache::KvBlockManager;
use super::prefix::PrefixCache;
use super::router::{Health, Policy, RouteDecision, Router};
use super::simserve::{
    append_with_reclaim, context_ids, register_and_free, tp_kv_pool_blocks, ContinuousPolicy,
};

/// Named fault schedules [`FaultPlan::generate`] knows how to draw.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// No faults — the control arm.
    Calm,
    /// One replica crashes mid-run and later recovers.
    SingleCrash,
    /// Staggered crash/recover windows rolling across every replica.
    RollingCrashes,
    /// Slowdown windows (step latency multiplied) on most replicas.
    StallStorm,
    /// Transient KV-pool pressure windows on every replica.
    PressureWave,
    /// One crash, one stall window, and one pressure window.
    Mixed,
}

impl Scenario {
    /// Every scenario, in a stable order (seed-cycling in tests).
    pub const ALL: [Scenario; 6] = [
        Scenario::Calm,
        Scenario::SingleCrash,
        Scenario::RollingCrashes,
        Scenario::StallStorm,
        Scenario::PressureWave,
        Scenario::Mixed,
    ];

    /// Stable display name.
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Calm => "calm",
            Scenario::SingleCrash => "single-crash",
            Scenario::RollingCrashes => "rolling-crashes",
            Scenario::StallStorm => "stall-storm",
            Scenario::PressureWave => "pressure-wave",
            Scenario::Mixed => "mixed",
        }
    }
}

/// One injectable fault (or its clearing edge).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Replica dies: KV pool and prefix cache lost, in-flight work
    /// requeues elsewhere, router marks it down.
    Crash {
        /// Target replica index.
        replica: usize,
    },
    /// Crashed replica comes back empty and enters the probe ramp.
    Recover {
        /// Target replica index.
        replica: usize,
    },
    /// Replica slows down: step latency multiplied by `factor`.
    StallStart {
        /// Target replica index.
        replica: usize,
        /// Step-latency multiplier (clamped to `>= 1`).
        factor: f64,
    },
    /// Slowdown window ends.
    StallEnd {
        /// Target replica index.
        replica: usize,
    },
    /// A ghost allocation grabs `frac` of the replica's free KV blocks
    /// (co-tenant memory pressure).
    PressureStart {
        /// Target replica index.
        replica: usize,
        /// Fraction of currently-free blocks to hold (clamped to [0, 1]).
        frac: f64,
    },
    /// Pressure window ends: the ghost allocation is released.
    PressureEnd {
        /// Target replica index.
        replica: usize,
    },
}

/// A fault scheduled at a simulated instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Simulated time the fault fires, seconds.
    pub at_s: f64,
    /// What happens.
    pub kind: FaultKind,
}

/// A reproducible fault schedule: `(seed, scenario)` fully determines the
/// event list.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed the schedule was drawn from.
    pub seed: u64,
    /// Scenario shape the schedule was drawn for.
    pub scenario: Scenario,
    /// Events sorted by [`FaultEvent::at_s`] (ties keep generation order).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Draw a fault schedule for `n_replicas` replicas over `horizon_s`
    /// simulated seconds. Same `(seed, scenario, n_replicas, horizon_s)`
    /// → same plan, always.
    pub fn generate(seed: u64, scenario: Scenario, n_replicas: usize, horizon_s: f64) -> FaultPlan {
        let n = n_replicas.max(1);
        let horizon = if horizon_s.is_finite() && horizon_s > 0.0 { horizon_s } else { 1.0 };
        let mut rng =
            Rng::seed_from_u64(seed ^ 0x51C4_05EB_FA17_7001u64.wrapping_mul(scenario as u64 + 1));
        let mut events: Vec<FaultEvent> = Vec::new();
        let mut window = |rng: &mut Rng, lo: f64, hi: f64| {
            let start = horizon * rng.range_f64(lo, hi);
            let dur = horizon * rng.range_f64(0.15, 0.35);
            (start, start + dur)
        };
        match scenario {
            Scenario::Calm => {}
            Scenario::SingleCrash => {
                let r = rng.range_usize(0, n - 1);
                let (t0, t1) = window(&mut rng, 0.2, 0.5);
                events.push(FaultEvent { at_s: t0, kind: FaultKind::Crash { replica: r } });
                events.push(FaultEvent { at_s: t1, kind: FaultKind::Recover { replica: r } });
            }
            Scenario::RollingCrashes => {
                for r in 0..n {
                    let base = 0.1 + 0.7 * r as f64 / n as f64;
                    let t0 = horizon * (base + 0.05 * rng.f64());
                    let t1 = t0 + horizon * rng.range_f64(0.1, 0.2);
                    events.push(FaultEvent { at_s: t0, kind: FaultKind::Crash { replica: r } });
                    events.push(FaultEvent { at_s: t1, kind: FaultKind::Recover { replica: r } });
                }
            }
            Scenario::StallStorm => {
                for r in 0..n {
                    if n > 1 && rng.f64() < 0.3 {
                        continue; // leave some replicas clean
                    }
                    let (t0, t1) = window(&mut rng, 0.1, 0.5);
                    let factor = rng.range_f64(2.0, 8.0);
                    events.push(FaultEvent {
                        at_s: t0,
                        kind: FaultKind::StallStart { replica: r, factor },
                    });
                    events.push(FaultEvent { at_s: t1, kind: FaultKind::StallEnd { replica: r } });
                }
            }
            Scenario::PressureWave => {
                for r in 0..n {
                    let (t0, t1) = window(&mut rng, 0.1, 0.5);
                    let frac = rng.range_f64(0.5, 0.95);
                    events.push(FaultEvent {
                        at_s: t0,
                        kind: FaultKind::PressureStart { replica: r, frac },
                    });
                    events.push(FaultEvent {
                        at_s: t1,
                        kind: FaultKind::PressureEnd { replica: r },
                    });
                }
            }
            Scenario::Mixed => {
                let rc = rng.range_usize(0, n - 1);
                let (c0, c1) = window(&mut rng, 0.25, 0.45);
                events.push(FaultEvent { at_s: c0, kind: FaultKind::Crash { replica: rc } });
                events.push(FaultEvent { at_s: c1, kind: FaultKind::Recover { replica: rc } });
                let rs = rng.range_usize(0, n - 1);
                let (s0, s1) = window(&mut rng, 0.1, 0.4);
                let factor = rng.range_f64(2.0, 6.0);
                events.push(FaultEvent {
                    at_s: s0,
                    kind: FaultKind::StallStart { replica: rs, factor },
                });
                events.push(FaultEvent { at_s: s1, kind: FaultKind::StallEnd { replica: rs } });
                let rp = rng.range_usize(0, n - 1);
                let (p0, p1) = window(&mut rng, 0.1, 0.5);
                let frac = rng.range_f64(0.5, 0.9);
                events.push(FaultEvent {
                    at_s: p0,
                    kind: FaultKind::PressureStart { replica: rp, frac },
                });
                events.push(FaultEvent { at_s: p1, kind: FaultKind::PressureEnd { replica: rp } });
            }
        }
        events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        FaultPlan { seed, scenario, events }
    }
}

/// Per-request latency deadlines the shed ladder enforces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token deadline: a request still waiting for its first
    /// dispatch/admission past this is shed with
    /// [`RejectReason::SloExpired`].
    pub ttft_s: f64,
    /// Time-per-output-token budget: finished requests whose mean decode
    /// interval exceeded this count as [`ChaosResult::tpot_violations`].
    pub tpot_s: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { ttft_s: 30.0, tpot_s: 0.5 }
    }
}

/// What a replica does when a request cannot be admitted at the pool's
/// configured KV precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Retry admission at kv8 then kv4 (quantized KV packs more tokens
    /// per block) before giving up — graceful degradation.
    DegradeThenReject,
    /// Never degrade: wait, then shed on SLO expiry.
    RejectOnly,
}

impl ShedPolicy {
    /// Stable display name.
    pub fn label(&self) -> &'static str {
        match self {
            ShedPolicy::DegradeThenReject => "degrade",
            ShedPolicy::RejectOnly => "reject-only",
        }
    }
}

/// Why a request was turned away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Larger than the whole KV pool even at the lowest allowed precision.
    Oversized,
    /// Still undispatched/unadmitted past the TTFT deadline.
    SloExpired,
    /// Crashed out of its last allowed failover attempt.
    RetriesExhausted,
    /// Work left stranded when nothing could ever serve it again.
    NoCapacity,
}

impl RejectReason {
    /// Stable display name.
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::Oversized => "oversized",
            RejectReason::SloExpired => "slo-expired",
            RejectReason::RetriesExhausted => "retries-exhausted",
            RejectReason::NoCapacity => "no-capacity",
        }
    }
}

/// Terminal state of one admitted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Generated its full token budget.
    Finished,
    /// Shed with a reason code.
    Rejected(RejectReason),
}

/// Configuration for a chaos serving run.
#[derive(Debug, Clone)]
pub struct ChaosPolicy {
    /// Per-replica continuous-batching policy (token budget, block size,
    /// watermark, base KV precision, ...).
    pub serve: ContinuousPolicy,
    /// Replica count.
    pub n_replicas: usize,
    /// Routing policy across replicas.
    pub route: Policy,
    /// Latency deadlines.
    pub slo: SloSpec,
    /// Degrade-or-reject behavior under pool pressure.
    pub shed: ShedPolicy,
    /// Failover attempts per request before [`RejectReason::RetriesExhausted`].
    pub max_retries: u32,
    /// Base failover backoff, doubled per retry.
    pub retry_backoff_s: f64,
    /// Probe completions a recovered replica must serve before it is
    /// fully routable again.
    pub probe_successes: u32,
    /// KV pool size override in blocks per replica; `None` sizes the pool
    /// from the device/model as the serving simulation does.
    pub pool_blocks: Option<u64>,
    /// Livelock backstop: the run errors out after this many scheduler
    /// iterations.
    pub max_steps: u64,
}

impl Default for ChaosPolicy {
    fn default() -> Self {
        ChaosPolicy {
            serve: ContinuousPolicy::default(),
            n_replicas: 2,
            route: Policy::LeastLoaded,
            slo: SloSpec::default(),
            shed: ShedPolicy::DegradeThenReject,
            max_retries: 3,
            retry_backoff_s: 0.05,
            probe_successes: 2,
            pool_blocks: None,
            max_steps: 2_000_000,
        }
    }
}

/// What a chaos run produced.
#[derive(Debug, Clone, Default)]
pub struct ChaosResult {
    /// Requests that generated their full budget.
    pub finished: usize,
    /// Requests shed with a reason code.
    pub rejected: usize,
    /// Simulated wall time, seconds.
    pub wall_s: f64,
    /// Generation tokens delivered by finished requests.
    pub gen_tokens: u64,
    /// `gen_tokens / wall_s` — tokens of *completed* work per second.
    pub goodput_tok_per_s: f64,
    /// Mixed scheduler steps executed across all replicas.
    pub steps: u64,
    /// Crash events applied.
    pub crashes: u64,
    /// Recovery events applied.
    pub recoveries: u64,
    /// Stall windows opened.
    pub stall_windows: u64,
    /// Pressure windows opened.
    pub pressure_windows: u64,
    /// KV-pressure preemptions (recompute policy).
    pub preemptions: u64,
    /// In-flight sequences requeued off crashed replicas.
    pub failover_requeues: u64,
    /// Admissions degraded to kv8.
    pub degraded_int8: u64,
    /// Admissions degraded to kv4.
    pub degraded_int4: u64,
    /// Rejections: larger than the whole pool.
    pub rejected_oversized: u64,
    /// Rejections: TTFT deadline expired.
    pub rejected_slo: u64,
    /// Rejections: failover retries exhausted.
    pub rejected_retries: u64,
    /// Rejections: stranded with no capacity left, ever.
    pub rejected_capacity: u64,
    /// Finished requests whose mean decode interval blew the TPOT budget.
    pub tpot_violations: u64,
    /// Prefix-cache hits accumulated across every cache generation
    /// (crashes replace caches; pre-crash stats fold in here).
    pub prefix_hits: u64,
    /// Structural check: nonzero iff a freshly installed post-crash cache
    /// was not empty. Always 0 unless the failover path regresses.
    pub phantom_guard_violations: u64,
    /// `(request id, terminal state)` — exactly one entry per request.
    pub outcomes: Vec<(u64, Outcome)>,
}

/// Handles on the `chaos.*` counters in the global metrics registry.
struct ChaosMetrics {
    crashes: Counter,
    recoveries: Counter,
    stalls: Counter,
    pressure_events: Counter,
    degraded_admissions: Counter,
    rejected: Counter,
    requeued_on_failover: Counter,
}

fn chaos_metrics() -> &'static ChaosMetrics {
    static METRICS: OnceLock<ChaosMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        ChaosMetrics {
            crashes: r.counter("chaos.crashes"),
            recoveries: r.counter("chaos.recoveries"),
            stalls: r.counter("chaos.stalls"),
            pressure_events: r.counter("chaos.pressure_events"),
            degraded_admissions: r.counter("chaos.degraded_admissions"),
            rejected: r.counter("chaos.rejected"),
            requeued_on_failover: r.counter("chaos.requeued_on_failover"),
        }
    })
}

/// Ghost sequence id for replica-local pressure allocations; request ids
/// must stay below this space.
fn ghost_id(replica: usize) -> u64 {
    (1u64 << 60) + replica as u64
}

/// A request waiting to be routed (fresh arrival or failover requeue).
struct PendingDispatch {
    req: Request,
    retries: u32,
    not_before: f64,
    orig_gen: u64,
}

/// One replica's serving state.
struct ReplicaState {
    kv: KvBlockManager,
    cache: PrefixCache,
    sched: ContinuousScheduler,
    slot_req: Vec<Request>,
    slot_ids: Vec<Vec<i32>>,
    slot_decision: Vec<RouteDecision>,
    slot_retries: Vec<u32>,
    slot_first_tok: Vec<Option<f64>>,
    slot_orig_gen: Vec<u64>,
    /// Head + pool fingerprint of the last failed admission (retry is
    /// pointless until either changes).
    admit_blocked: Option<(usize, u64, u64)>,
    stall_factor: f64,
    crashed: bool,
    ghost: bool,
    /// Replica-local clock, seconds.
    now: f64,
}

impl ReplicaState {
    fn new(policy: &ChaosPolicy, blocks: u64) -> Self {
        let kv = KvBlockManager::new(blocks, policy.serve.block_size, policy.serve.watermark_frac)
            .with_precision(policy.serve.kv_precision);
        let cache =
            PrefixCache::new(kv.tokens_per_block() as usize, policy.serve.enable_prefix_cache);
        let sched = ContinuousScheduler::new(ChunkPolicy {
            token_budget: policy.serve.token_budget,
            max_num_seqs: policy.serve.max_num_seqs,
        });
        ReplicaState {
            kv,
            cache,
            sched,
            slot_req: Vec::new(),
            slot_ids: Vec::new(),
            slot_decision: Vec::new(),
            slot_retries: Vec::new(),
            slot_first_tok: Vec::new(),
            slot_orig_gen: Vec::new(),
            admit_blocked: None,
            stall_factor: 1.0,
            crashed: false,
            ghost: false,
            now: 0.0,
        }
    }

    /// Replace every piece of serving state with a fresh instance — the
    /// crash loses the KV pool, the prefix cache, and the scheduler.
    fn reset_after_crash(&mut self, policy: &ChaosPolicy, blocks: u64) {
        let now = self.now;
        *self = ReplicaState::new(policy, blocks);
        self.now = now;
    }
}

/// Read-only context threaded through the step helpers.
struct Env<'a> {
    dev: &'a DeviceSpec,
    spec: &'a LlmSpec,
    kind: KernelKind,
    calib: &'a Calib,
    policy: &'a ChaosPolicy,
}

fn record_reject(res: &mut ChaosResult, id: u64, reason: RejectReason) {
    res.rejected += 1;
    match reason {
        RejectReason::Oversized => res.rejected_oversized += 1,
        RejectReason::SloExpired => res.rejected_slo += 1,
        RejectReason::RetriesExhausted => res.rejected_retries += 1,
        RejectReason::NoCapacity => res.rejected_capacity += 1,
    }
    res.outcomes.push((id, Outcome::Rejected(reason)));
    chaos_metrics().rejected.inc();
}

/// Shed the replica's waiting head: release its router accounting and
/// record the outcome.
fn reject_head(
    rep: &mut ReplicaState,
    router: &mut Router,
    res: &mut ChaosResult,
    reason: RejectReason,
) {
    let Some(sid) = rep.sched.reject_waiting_head() else { return };
    let req = rep.slot_req[sid];
    router.on_finish(rep.slot_decision[sid], req.prompt_tokens + req.gen_tokens);
    record_reject(res, req.id, reason);
}

/// Would this request exceed the whole pool even at the lowest precision
/// the shed policy may admit it at?
fn oversized(rep: &ReplicaState, req: &Request, env: &Env<'_>) -> bool {
    let mut floor = rep.kv.precision();
    if env.policy.shed == ShedPolicy::DegradeThenReject && KvPrecision::Int4.bits() < floor.bits() {
        floor = KvPrecision::Int4;
    }
    rep.kv.blocks_needed_at(req.prompt_tokens.max(1), floor) + rep.kv.watermark_blocks()
        > rep.kv.total_blocks()
}

/// The degradation ladder: try admitting the waiting head at kv8, then
/// kv4. Degraded sequences skip the prefix cache entirely (no lease, no
/// registration — `register_and_free`'s precision guard keeps mixed
/// precisions out of the shared index).
fn admit_degraded(
    rep: &mut ReplicaState,
    sid: usize,
    env: &Env<'_>,
    res: &mut ChaosResult,
) -> Result<bool> {
    if env.policy.shed != ShedPolicy::DegradeThenReject {
        return Ok(false);
    }
    let req = rep.slot_req[sid];
    let base_bits = rep.kv.precision().bits();
    for precision in [KvPrecision::Int8, KvPrecision::Int4] {
        if precision.bits() >= base_bits {
            continue;
        }
        if !rep.kv.can_admit_at(req.prompt_tokens, precision) {
            continue;
        }
        let need = rep.kv.blocks_needed_at(req.prompt_tokens.max(1), precision);
        if !rep.cache.reclaim(&mut rep.kv, need) {
            continue;
        }
        rep.kv.allocate_with_precision(req.id, req.prompt_tokens, precision)?;
        let got = rep.sched.admit_next(0, |_| true);
        debug_assert_eq!(got, Some(sid));
        match precision {
            KvPrecision::Int8 => res.degraded_int8 += 1,
            _ => res.degraded_int4 += 1,
        }
        chaos_metrics().degraded_admissions.inc();
        return Ok(true);
    }
    Ok(false)
}

/// Complete a running sequence: publish+free its KV, release router
/// accounting, feed the probe ramp, and record the outcome.
fn finish_slot(
    rep: &mut ReplicaState,
    router: &mut Router,
    r_idx: usize,
    sid: usize,
    env: &Env<'_>,
    res: &mut ChaosResult,
) -> Result<()> {
    let req = rep.slot_req[sid];
    let generated = rep.sched.seq(sid).generated;
    register_and_free(&mut rep.kv, &mut rep.cache, &req)?;
    rep.sched.finish(sid);
    router.on_finish(rep.slot_decision[sid], req.prompt_tokens + req.gen_tokens);
    if matches!(router.health(r_idx), Health::Probing) {
        router.probe_result(r_idx, true);
    }
    if let Some(first) = rep.slot_first_tok[sid] {
        if generated > 1 {
            let tpot = (rep.now - first) / (generated - 1) as f64;
            if tpot > env.policy.slo.tpot_s {
                res.tpot_violations += 1;
            }
        }
    }
    res.finished += 1;
    res.gen_tokens += rep.slot_orig_gen[sid];
    res.outcomes.push((req.id, Outcome::Finished));
    Ok(())
}

/// Run admission (with the shed ladder) and one mixed scheduler step on
/// a replica. Returns whether the replica advanced its clock; `false`
/// means it is blocked: nothing running and the head unadmittable.
fn step_replica(
    rep: &mut ReplicaState,
    router: &mut Router,
    r_idx: usize,
    env: &Env<'_>,
    res: &mut ChaosResult,
) -> Result<bool> {
    // --- admission: FCFS with the SLO shed ladder ---
    while rep.sched.running_len() < env.policy.serve.max_num_seqs {
        let Some(sid) = rep.sched.peek_waiting() else { break };
        let req = rep.slot_req[sid];
        if rep.sched.running_len() == 0 {
            // With nothing running the pool will never improve on its
            // own: shed hopeless or already-expired heads now.
            if oversized(rep, &req, env) {
                reject_head(rep, router, res, RejectReason::Oversized);
                continue;
            }
            if rep.slot_retries[sid] == 0 && rep.now - req.arrival_s() >= env.policy.slo.ttft_s {
                reject_head(rep, router, res, RejectReason::SloExpired);
                continue;
            }
        }
        let pool = (rep.kv.free_blocks(), rep.kv.cached_idle_blocks());
        if rep.admit_blocked == Some((sid, pool.0, pool.1)) {
            break; // same head, same pool: admission would fail again
        }
        let admitted = match rep.cache.admit(&mut rep.kv, req.id, &rep.slot_ids[sid]) {
            Ok(matched) => {
                let got = rep.sched.admit_next(matched, |_| true);
                debug_assert_eq!(got, Some(sid));
                // Publish the prompt's full blocks eagerly so concurrent
                // same-prefix requests share them.
                let _ = rep.cache.register(&mut rep.kv, req.id, &rep.slot_ids[sid]);
                true
            }
            Err(_) => admit_degraded(rep, sid, env, res)?,
        };
        if admitted {
            rep.admit_blocked = None;
        } else {
            rep.admit_blocked = Some((sid, pool.0, pool.1));
            break;
        }
    }

    // --- one mixed step: decode lanes + FCFS prefill chunks ---
    let batch = rep.sched.plan_step();
    if batch.is_empty() {
        debug_assert_eq!(rep.sched.running_len(), 0);
        return Ok(false);
    }
    let decode_batch = batch.decode.len() as u64;
    let mean_ctx = if decode_batch > 0 {
        batch
            .decode
            .iter()
            .map(|&sid| {
                let s = rep.sched.seq(sid);
                s.prompt_tokens + s.generated
            })
            .sum::<u64>()
            / decode_batch
    } else {
        0
    };
    let perf = tp_step_latency(
        env.dev,
        env.spec,
        env.kind,
        1,
        decode_batch,
        mean_ctx,
        batch.prefill_tokens(),
        batch.prefill_attn_ctx_tokens(),
        env.calib,
    );
    rep.now += perf.total_s() * rep.stall_factor;
    res.steps += 1;

    // Commit prefill chunks; a prompt-completing chunk's last logits
    // yield the sequence's first generated token.
    for c in &batch.chunks {
        if rep.sched.commit_chunk(c) {
            rep.sched.commit_first_token(c.seq);
            rep.slot_first_tok[c.seq] = Some(rep.now);
            let (generated, budget) = {
                let s = rep.sched.seq(c.seq);
                (s.generated, s.gen_budget)
            };
            if generated >= budget {
                finish_slot(rep, router, r_idx, c.seq, env, res)?;
                continue;
            }
            let req = rep.slot_req[c.seq];
            if !append_with_reclaim(&mut rep.kv, &mut rep.cache, req.id) {
                register_and_free(&mut rep.kv, &mut rep.cache, &req)?;
                rep.sched.preempt(c.seq);
                res.preemptions += 1;
            }
        }
    }
    // Commit decode lanes; finished sequences leave their blocks warm in
    // the cache, KV exhaustion preempts (recompute policy).
    for &sid in &batch.decode {
        let done = rep.sched.commit_decode(sid);
        let req = rep.slot_req[sid];
        if done {
            finish_slot(rep, router, r_idx, sid, env, res)?;
            continue;
        }
        if !append_with_reclaim(&mut rep.kv, &mut rep.cache, req.id) {
            register_and_free(&mut rep.kv, &mut rep.cache, &req)?;
            rep.sched.preempt(sid);
            res.preemptions += 1;
        }
    }
    Ok(true)
}

/// Route every eligible queued request to a healthy replica; shed
/// first-dispatch requests whose TTFT deadline already expired. Entries
/// that cannot be placed (backoff pending, or no routable replica) stay
/// queued.
fn dispatch_pass(
    dispatch: &mut VecDeque<PendingDispatch>,
    router: &mut Router,
    replicas: &mut [ReplicaState],
    clock: f64,
    policy: &ChaosPolicy,
    res: &mut ChaosResult,
) {
    let mut keep: VecDeque<PendingDispatch> = VecDeque::with_capacity(dispatch.len());
    while let Some(p) = dispatch.pop_front() {
        if p.not_before > clock {
            keep.push_back(p);
            continue;
        }
        // Failover retries already produced a first token on their
        // original replica: TTFT shedding applies to first dispatch only.
        if p.retries == 0 && clock - p.req.arrival_s() >= policy.slo.ttft_s {
            record_reject(res, p.req.id, RejectReason::SloExpired);
            continue;
        }
        match router.route(p.req.prompt_tokens + p.req.gen_tokens, None) {
            Some(d) => {
                let rep = &mut replicas[d.replica];
                debug_assert!(!rep.crashed);
                rep.now = rep.now.max(clock);
                let sid = rep.sched.submit(p.req.id, p.req.prompt_tokens, p.req.gen_tokens.max(1));
                debug_assert_eq!(sid, rep.slot_req.len());
                rep.slot_ids.push(context_ids(&p.req, p.req.prompt_tokens));
                rep.slot_req.push(p.req);
                rep.slot_decision.push(d);
                rep.slot_retries.push(p.retries);
                rep.slot_first_tok.push(None);
                rep.slot_orig_gen.push(p.orig_gen);
            }
            None => keep.push_back(p),
        }
    }
    *dispatch = keep;
}

/// Apply one fault event. Crashes requeue live work into `dispatch`.
#[allow(clippy::too_many_arguments)]
fn apply_event(
    e: &FaultEvent,
    replicas: &mut [ReplicaState],
    router: &mut Router,
    dispatch: &mut VecDeque<PendingDispatch>,
    res: &mut ChaosResult,
    policy: &ChaosPolicy,
    blocks: u64,
) {
    let _span = trace::span1("chaos.fault", "chaos", "at_ms", e.at_s * 1e3);
    match e.kind {
        FaultKind::Crash { replica } => {
            let Some(rep) = replicas.get_mut(replica) else { return };
            if rep.crashed {
                return;
            }
            res.crashes += 1;
            chaos_metrics().crashes.inc();
            // Zero the router's in-flight accounting for this replica so
            // it is not "loaded" forever (and not routable while down).
            let _ = router.mark_down(replica);
            // The cache dies with the replica: fold its stats into the
            // run totals before discarding it.
            res.prefix_hits += rep.cache.stats.hits;
            // Requeue everything in flight: the KV is gone, so failover
            // recomputes the remaining generation on a healthy replica.
            for sid in 0..rep.slot_req.len() {
                let s = rep.sched.seq(sid);
                if s.state == SchedState::Finished {
                    continue;
                }
                let req = rep.slot_req[sid];
                let remaining = s.gen_budget.saturating_sub(s.generated).max(1);
                let retries = rep.slot_retries[sid] + 1;
                if retries > policy.max_retries {
                    record_reject(res, req.id, RejectReason::RetriesExhausted);
                    continue;
                }
                let backoff = policy.retry_backoff_s * (1u64 << (retries - 1).min(20)) as f64;
                dispatch.push_back(PendingDispatch {
                    req: Request { gen_tokens: remaining, ..req },
                    retries,
                    not_before: e.at_s + backoff,
                    orig_gen: rep.slot_orig_gen[sid],
                });
                res.failover_requeues += 1;
                chaos_metrics().requeued_on_failover.inc();
            }
            rep.reset_after_crash(policy, blocks);
            // Structural phantom-hit guard: the freshly installed cache
            // must be empty — a crashed replica's prefix blocks are gone.
            if rep.cache.stats.hits != 0 || !rep.cache.index().is_empty() {
                res.phantom_guard_violations += 1;
            }
            rep.crashed = true;
            rep.now = rep.now.max(e.at_s);
        }
        FaultKind::Recover { replica } => {
            let Some(rep) = replicas.get_mut(replica) else { return };
            if !rep.crashed {
                return;
            }
            rep.crashed = false;
            rep.now = rep.now.max(e.at_s);
            router.begin_probe(replica);
            res.recoveries += 1;
            chaos_metrics().recoveries.inc();
        }
        FaultKind::StallStart { replica, factor } => {
            let Some(rep) = replicas.get_mut(replica) else { return };
            if rep.crashed {
                return;
            }
            rep.stall_factor = factor.max(1.0);
            res.stall_windows += 1;
            chaos_metrics().stalls.inc();
        }
        FaultKind::StallEnd { replica } => {
            if let Some(rep) = replicas.get_mut(replica) {
                rep.stall_factor = 1.0;
            }
        }
        FaultKind::PressureStart { replica, frac } => {
            let Some(rep) = replicas.get_mut(replica) else { return };
            if rep.crashed {
                return;
            }
            if rep.ghost {
                let _ = rep.kv.free_seq(ghost_id(replica));
                rep.ghost = false;
            }
            let grab = (rep.kv.free_blocks() as f64 * frac.clamp(0.0, 1.0)) as u64;
            if grab >= 1 {
                let tokens = grab * rep.kv.tokens_per_block();
                if rep.kv.allocate(ghost_id(replica), tokens).is_ok() {
                    rep.ghost = true;
                }
            }
            res.pressure_windows += 1;
            chaos_metrics().pressure_events.inc();
        }
        FaultKind::PressureEnd { replica } => {
            let Some(rep) = replicas.get_mut(replica) else { return };
            if rep.ghost {
                let _ = rep.kv.free_seq(ghost_id(replica));
                rep.ghost = false;
            }
        }
    }
}

/// Serve `requests` on `policy.n_replicas` replicas while `plan`'s faults
/// fire — a discrete-event simulation over the same continuous-batching
/// core as `simulate_continuous`, plus the router's health machine,
/// failover-with-recompute, and the SLO shed ladder.
///
/// Deterministic: the same `(requests, plan, policy)` always produces the
/// same [`ChaosResult`]. Every request terminates in exactly one
/// [`Outcome`].
pub fn run_chaos(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    plan: &FaultPlan,
    policy: &ChaosPolicy,
    calib: &Calib,
) -> Result<ChaosResult> {
    ensure!(policy.n_replicas >= 1, "chaos policy needs at least one replica");
    let mut seen_ids = HashSet::new();
    for r in requests {
        ensure!(r.prompt_tokens > 0, "request {} has an empty prompt", r.id);
        ensure!(r.gen_tokens > 0, "request {} has an empty generation budget", r.id);
        ensure!(seen_ids.insert(r.id), "duplicate request id {} in chaos workload", r.id);
        ensure!(r.id < 1 << 60, "request id {} collides with the ghost-sequence id space", r.id);
    }

    let blocks = match policy.pool_blocks {
        Some(b) => b,
        None => {
            let p = &policy.serve;
            tp_kv_pool_blocks(dev, spec, kind, p.block_size, p.headroom_frac, 1)
        }
    };
    ensure!(blocks > 0, "KV pool has zero blocks: the device cannot hold the model weights");

    let _span = trace::span2(
        "chaos.run",
        "chaos",
        "replicas",
        policy.n_replicas as f64,
        "requests",
        requests.len() as f64,
    );
    let mut router = Router::new(policy.route, &vec![0; policy.n_replicas])?
        .with_probe_successes(policy.probe_successes);
    let mut replicas: Vec<ReplicaState> =
        (0..policy.n_replicas).map(|_| ReplicaState::new(policy, blocks)).collect();

    let mut sorted: Vec<Request> = requests.to_vec();
    sorted.sort_by_key(|r| (r.arrival_s_micros, r.id));
    let mut pending: VecDeque<Request> = sorted.into();
    let mut dispatch: VecDeque<PendingDispatch> = VecDeque::new();
    let mut events: VecDeque<FaultEvent> = plan.events.iter().copied().collect();

    let mut res = ChaosResult::default();
    let env = Env { dev, spec, kind, calib, policy };
    let mut clock = 0.0f64;
    let mut iters = 0u64;

    loop {
        iters += 1;
        ensure!(
            iters <= policy.max_steps,
            "chaos run exceeded {} scheduler iterations (livelock backstop)",
            policy.max_steps
        );

        // Fault events due at or before the global clock.
        loop {
            match events.front() {
                Some(e) if e.at_s <= clock => {
                    let e = *e;
                    events.pop_front();
                    apply_event(
                        &e,
                        &mut replicas,
                        &mut router,
                        &mut dispatch,
                        &mut res,
                        policy,
                        blocks,
                    );
                }
                _ => break,
            }
        }
        // Arrivals due.
        loop {
            match pending.front() {
                Some(r) if r.arrival_s() <= clock => {
                    let r = *r;
                    pending.pop_front();
                    dispatch.push_back(PendingDispatch {
                        req: r,
                        retries: 0,
                        not_before: r.arrival_s(),
                        orig_gen: r.gen_tokens,
                    });
                }
                _ => break,
            }
        }
        dispatch_pass(&mut dispatch, &mut router, &mut replicas, clock, policy, &mut res);

        // Earliest external state change the run still has ahead of it.
        let mut wake = f64::INFINITY;
        if let Some(e) = events.front() {
            wake = wake.min(e.at_s);
        }
        if let Some(r) = pending.front() {
            wake = wake.min(r.arrival_s());
        }
        for p in &dispatch {
            if p.not_before > clock {
                wake = wake.min(p.not_before);
            }
        }

        // Step the earliest-clock replica that can make progress, unless
        // an external change lands before its step would.
        let mut order: Vec<usize> = (0..replicas.len())
            .filter(|&i| !replicas[i].crashed && replicas[i].sched.has_work())
            .collect();
        order.sort_by(|&a, &b| replicas[a].now.total_cmp(&replicas[b].now).then(a.cmp(&b)));
        let mut progressed = false;
        for &r in &order {
            let tr = replicas[r].now.max(clock);
            if wake <= tr {
                break; // apply the external change first, then re-plan
            }
            replicas[r].now = tr;
            if step_replica(&mut replicas[r], &mut router, r, &env, &mut res)? {
                clock = tr;
                progressed = true;
                break;
            }
            // Blocked: nothing running and the head unadmittable. Its
            // only self-driven transition is head TTFT expiry.
            let rep = &replicas[r];
            if let Some(sid) = rep.sched.peek_waiting() {
                if rep.slot_retries[sid] == 0 {
                    let deadline = rep.slot_req[sid].arrival_s() + policy.slo.ttft_s;
                    if deadline > clock {
                        wake = wake.min(deadline);
                    }
                }
            }
        }
        if progressed {
            continue;
        }
        if wake.is_finite() {
            clock = wake;
            continue;
        }
        break; // nothing can ever happen again
    }

    // Terminal sweep: whatever is still queued can never be served.
    while let Some(p) = dispatch.pop_front() {
        record_reject(&mut res, p.req.id, RejectReason::NoCapacity);
    }
    for rep in replicas.iter_mut() {
        while rep.sched.peek_waiting().is_some() {
            reject_head(rep, &mut router, &mut res, RejectReason::NoCapacity);
        }
    }

    for rep in &replicas {
        res.prefix_hits += rep.cache.stats.hits;
        res.wall_s = res.wall_s.max(rep.now);
    }
    res.wall_s = res.wall_s.max(clock);
    res.goodput_tok_per_s = res.gen_tokens as f64 / res.wall_s.max(1e-9);
    Ok(res)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::ShareGptLike;

    fn specs() -> (DeviceSpec, LlmSpec) {
        (Gpu::RtxA6000.spec(), Model::Mistral7B.spec())
    }

    fn small_policy(n_replicas: usize, shed: ShedPolicy) -> ChaosPolicy {
        ChaosPolicy {
            serve: ContinuousPolicy { max_num_seqs: 16, token_budget: 256, ..Default::default() },
            n_replicas,
            shed,
            slo: SloSpec { ttft_s: 1e9, tpot_s: 1e9 },
            pool_blocks: Some(512),
            ..Default::default()
        }
    }

    fn run(reqs: &[Request], plan: &FaultPlan, policy: &ChaosPolicy) -> ChaosResult {
        let (dev, spec) = specs();
        run_chaos(&dev, &spec, KernelKind::Quick, reqs, plan, policy, &Calib::default()).unwrap()
    }

    fn one_request(id: u64, prompt: u64, gen: u64) -> Request {
        Request {
            id,
            prompt_tokens: prompt,
            gen_tokens: gen,
            arrival_s_micros: 0,
            sys_id: 0,
            sys_tokens: 0,
            stream_id: id,
        }
    }

    #[test]
    fn fault_plan_is_deterministic() {
        let a = FaultPlan::generate(42, Scenario::Mixed, 3, 20.0);
        let b = FaultPlan::generate(42, Scenario::Mixed, 3, 20.0);
        assert_eq!(a.events, b.events);
        assert!(!a.events.is_empty());
        for w in a.events.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "events must be time-sorted");
        }
    }

    #[test]
    fn calm_scenario_completes_every_request() {
        let reqs = ShareGptLike::new().online(40, 8.0, 7);
        let plan = FaultPlan::generate(1, Scenario::Calm, 2, 10.0);
        let res = run(&reqs, &plan, &small_policy(2, ShedPolicy::DegradeThenReject));
        assert_eq!(res.finished, reqs.len());
        assert_eq!(res.rejected, 0);
        assert_eq!(res.outcomes.len(), reqs.len());
        assert_eq!(res.crashes, 0);
        assert_eq!(res.phantom_guard_violations, 0);
        assert!(res.goodput_tok_per_s > 0.0);
    }

    #[test]
    fn run_chaos_is_deterministic() {
        let reqs = ShareGptLike::new().online(25, 15.0, 11);
        let plan = FaultPlan::generate(9, Scenario::Mixed, 2, 8.0);
        let policy = small_policy(2, ShedPolicy::DegradeThenReject);
        let a = run(&reqs, &plan, &policy);
        let b = run(&reqs, &plan, &policy);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.goodput_tok_per_s.to_bits(), b.goodput_tok_per_s.to_bits());
    }

    #[test]
    fn single_crash_fails_over_and_conserves_requests() {
        let reqs = ShareGptLike::new().offline(30, 3);
        let plan = FaultPlan {
            seed: 0,
            scenario: Scenario::SingleCrash,
            events: vec![
                FaultEvent { at_s: 0.05, kind: FaultKind::Crash { replica: 0 } },
                FaultEvent { at_s: 5.0, kind: FaultKind::Recover { replica: 0 } },
            ],
        };
        let res = run(&reqs, &plan, &small_policy(2, ShedPolicy::DegradeThenReject));
        assert_eq!(res.crashes, 1);
        assert_eq!(res.recoveries, 1);
        assert!(res.failover_requeues > 0, "crash at 0.05s must catch in-flight work");
        assert_eq!(res.finished + res.rejected, reqs.len());
        assert_eq!(res.outcomes.len(), reqs.len());
        let mut ids: Vec<u64> = res.outcomes.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        let mut want: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        want.sort_unstable();
        assert_eq!(ids, want, "exactly one outcome per request");
        assert_eq!(res.phantom_guard_violations, 0);
    }

    #[test]
    fn degrade_ladder_admits_what_reject_only_sheds() {
        // 64-block pool, 90% held by pressure: a 100-token prompt needs
        // 7 blocks + 1 watermark at f16 (> 7 free) but only 4 + 1 at kv8.
        let reqs = vec![one_request(1, 100, 4)];
        let plan = FaultPlan {
            seed: 0,
            scenario: Scenario::PressureWave,
            events: vec![FaultEvent {
                at_s: 0.0,
                kind: FaultKind::PressureStart { replica: 0, frac: 0.9 },
            }],
        };
        let mut degrade = small_policy(1, ShedPolicy::DegradeThenReject);
        degrade.pool_blocks = Some(64);
        let res = run(&reqs, &plan, &degrade);
        assert_eq!(res.finished, 1);
        assert_eq!(res.degraded_int8 + res.degraded_int4, 1);
        assert_eq!(res.pressure_windows, 1);

        let mut reject = small_policy(1, ShedPolicy::RejectOnly);
        reject.pool_blocks = Some(64);
        reject.slo = SloSpec { ttft_s: 0.5, tpot_s: 1e9 };
        let res = run(&reqs, &plan, &reject);
        assert_eq!(res.finished, 0);
        assert_eq!(res.rejected_slo, 1, "reject-only sheds on TTFT expiry");
    }

    #[test]
    fn oversized_request_rejected_with_reason() {
        let reqs = vec![one_request(1, 10_000, 4)];
        let plan = FaultPlan::generate(0, Scenario::Calm, 1, 1.0);
        let mut policy = small_policy(1, ShedPolicy::DegradeThenReject);
        policy.pool_blocks = Some(8);
        let res = run(&reqs, &plan, &policy);
        assert_eq!(res.rejected_oversized, 1);
        assert_eq!(res.finished, 0);
    }

    #[test]
    fn crash_without_retries_rejects_in_flight_work() {
        let reqs = vec![one_request(1, 64, 64), one_request(2, 64, 64), one_request(3, 64, 64)];
        let plan = FaultPlan {
            seed: 0,
            scenario: Scenario::SingleCrash,
            events: vec![FaultEvent { at_s: 0.01, kind: FaultKind::Crash { replica: 0 } }],
        };
        let mut policy = small_policy(1, ShedPolicy::DegradeThenReject);
        policy.max_retries = 0;
        let res = run(&reqs, &plan, &policy);
        assert_eq!(res.rejected_retries, 3);
        assert_eq!(res.finished, 0);
        assert_eq!(res.outcomes.len(), 3);
    }

    #[test]
    fn unrecoverable_crash_strands_requeues_as_no_capacity() {
        let reqs = vec![one_request(1, 64, 64), one_request(2, 64, 64), one_request(3, 64, 64)];
        let plan = FaultPlan {
            seed: 0,
            scenario: Scenario::SingleCrash,
            events: vec![FaultEvent { at_s: 0.01, kind: FaultKind::Crash { replica: 0 } }],
        };
        let policy = small_policy(1, ShedPolicy::DegradeThenReject);
        let res = run(&reqs, &plan, &policy);
        assert_eq!(res.failover_requeues, 3);
        assert_eq!(res.rejected_capacity, 3, "no replica ever serves again");
        assert_eq!(res.finished + res.rejected, 3);
    }

    #[test]
    fn ghost_ids_stay_out_of_request_space() {
        let reqs = vec![Request { id: 1 << 60, ..one_request(0, 8, 2) }];
        let plan = FaultPlan::generate(0, Scenario::Calm, 1, 1.0);
        let (dev, spec) = specs();
        let err = run_chaos(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &plan,
            &small_policy(1, ShedPolicy::DegradeThenReject),
            &Calib::default(),
        );
        assert!(err.is_err());
    }
}
