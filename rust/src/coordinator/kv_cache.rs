//! Paged KV-cache block manager (vLLM-style).
//!
//! The serving engine accounts KV memory in fixed-size blocks of
//! `block_size` token slots per sequence. Weight-only quantization frees
//! ~3x of weight memory, which becomes KV budget — this is the mechanism
//! behind the paper's "larger batch inference becomes possible" (§4.2) and
//! the OOM column of Table 1; the block manager makes it concrete.
//!
//! Invariants (enforced by unit + property tests):
//! * a physical block is owned by at most one sequence at a time;
//! * `free_blocks + allocated == total` at all times;
//! * freeing a sequence returns exactly the blocks it held.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Sequence identifier.
pub type SeqId = u64;

/// Fixed-capacity block pool + per-sequence block tables.
#[derive(Debug)]
pub struct KvBlockManager {
    block_size: u64,
    total_blocks: u64,
    free: Vec<u32>,
    tables: HashMap<SeqId, BlockTable>,
    /// Blocks kept free as headroom for in-flight decodes (vLLM's
    /// watermark prevents admission from starving running sequences).
    watermark_blocks: u64,
}

#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<u32>,
    /// Tokens currently stored.
    pub tokens: u64,
}

impl KvBlockManager {
    pub fn new(total_blocks: u64, block_size: u64, watermark_frac: f64) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        assert!((0.0..0.5).contains(&watermark_frac));
        KvBlockManager {
            block_size,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            tables: HashMap::new(),
            watermark_blocks: (total_blocks as f64 * watermark_frac).ceil() as u64,
        }
    }

    /// Pool capacity helpers -------------------------------------------------
    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> u64 {
        self.free.len() as u64
    }

    pub fn allocated_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks()
    }

    pub fn blocks_needed(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_size)
    }

    /// Admission check: can a new sequence of `prompt_tokens` be allocated
    /// without dipping into the decode watermark?
    pub fn can_admit(&self, prompt_tokens: u64) -> bool {
        self.blocks_needed(prompt_tokens.max(1)) + self.watermark_blocks
            <= self.free_blocks()
    }

    /// Allocate the block table for a new sequence's prompt.
    pub fn allocate(&mut self, seq: SeqId, prompt_tokens: u64) -> Result<()> {
        if self.tables.contains_key(&seq) {
            bail!("sequence {seq} already has a block table");
        }
        let need = self.blocks_needed(prompt_tokens.max(1));
        if need > self.free_blocks() {
            bail!("out of KV blocks: need {need}, free {}", self.free_blocks());
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.tables.insert(seq, BlockTable { blocks, tokens: prompt_tokens });
        Ok(())
    }

    /// Append one decoded token; may claim one more block. Returns true if
    /// a block was claimed.
    pub fn append_token(&mut self, seq: SeqId) -> Result<bool> {
        let bs = self.block_size;
        let table = match self.tables.get_mut(&seq) {
            Some(t) => t,
            None => bail!("append_token: unknown sequence {seq}"),
        };
        table.tokens += 1;
        let need = table.tokens.div_ceil(bs);
        if need > table.blocks.len() as u64 {
            match self.free.pop() {
                Some(b) => {
                    self.tables.get_mut(&seq).unwrap().blocks.push(b);
                    Ok(true)
                }
                None => {
                    // Roll back the token count so callers can preempt.
                    self.tables.get_mut(&seq).unwrap().tokens -= 1;
                    bail!("out of KV blocks while decoding sequence {seq}")
                }
            }
        } else {
            Ok(false)
        }
    }

    /// Release a finished (or preempted) sequence's blocks.
    pub fn free_seq(&mut self, seq: SeqId) -> Result<u64> {
        let table = match self.tables.remove(&seq) {
            Some(t) => t,
            None => bail!("free_seq: unknown sequence {seq}"),
        };
        let n = table.blocks.len() as u64;
        self.free.extend(table.blocks);
        Ok(n)
    }

    pub fn table(&self, seq: SeqId) -> Option<&BlockTable> {
        self.tables.get(&seq)
    }

    pub fn num_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Sanity: no block owned twice, ledger balances.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.total_blocks as usize];
        for &b in &self.free {
            anyhow::ensure!(!seen[b as usize], "block {b} double-listed in free");
            seen[b as usize] = true;
        }
        for (seq, t) in &self.tables {
            for &b in &t.blocks {
                anyhow::ensure!(!seen[b as usize], "block {b} double-owned (seq {seq})");
                seen[b as usize] = true;
            }
        }
        anyhow::ensure!(seen.iter().all(|&s| s), "leaked blocks");
        Ok(())
    }
}

/// Size a block pool for a device: KV budget = device memory − weights −
/// activation headroom.
pub fn blocks_for_device(
    mem_bytes: f64,
    weight_bytes: f64,
    kv_bytes_per_token: f64,
    block_size: u64,
    headroom_frac: f64,
) -> u64 {
    let budget = (mem_bytes * (1.0 - headroom_frac) - weight_bytes).max(0.0);
    let tokens = budget / kv_bytes_per_token;
    (tokens / block_size as f64).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvBlockManager {
        KvBlockManager::new(64, 16, 0.05)
    }

    #[test]
    fn allocate_and_free_balances() {
        let mut m = mgr();
        m.allocate(1, 40).unwrap(); // 3 blocks
        m.allocate(2, 1).unwrap(); // 1 block
        assert_eq!(m.allocated_blocks(), 4);
        m.check_invariants().unwrap();
        assert_eq!(m.free_seq(1).unwrap(), 3);
        assert_eq!(m.allocated_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn append_claims_block_at_boundary() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap(); // exactly one full block
        assert!(m.append_token(1).unwrap()); // 17th token -> new block
        assert!(!m.append_token(1).unwrap()); // 18th fits
        m.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let mut m = KvBlockManager::new(2, 4, 0.0);
        m.allocate(1, 8).unwrap(); // both blocks
        assert!(m.allocate(2, 1).is_err());
        let before = m.table(1).unwrap().tokens;
        assert!(m.append_token(1).is_err());
        assert_eq!(m.table(1).unwrap().tokens, before, "rollback on failure");
        m.check_invariants().unwrap();
    }

    #[test]
    fn watermark_blocks_admission_but_not_decode() {
        let mut m = KvBlockManager::new(20, 16, 0.25); // watermark = 5
        assert!(m.can_admit(16 * 14));
        assert!(!m.can_admit(16 * 16)); // would leave < watermark
        m.allocate(1, 16 * 14).unwrap();
        // decode can still take blocks below the watermark
        for _ in 0..16 {
            m.append_token(1).unwrap();
        }
        m.check_invariants().unwrap();
    }

    #[test]
    fn double_allocate_rejected() {
        let mut m = mgr();
        m.allocate(1, 4).unwrap();
        assert!(m.allocate(1, 4).is_err());
    }

    #[test]
    fn device_sizing_quantization_frees_kv() {
        // A6000 48 GiB, Llama-2-70B: fp16 weights don't fit; W4 leaves room.
        let mem = 48.0 * (1u64 << 30) as f64;
        let kv_tok = 2.0 * 80.0 * 8.0 * 128.0 * 2.0; // GQA 70B per-token bytes
        let fp16 = blocks_for_device(mem, 140e9, kv_tok, 16, 0.05);
        let w4 = blocks_for_device(mem, 36e9, kv_tok, 16, 0.05);
        assert_eq!(fp16, 0);
        assert!(w4 > 1000);
    }
}
