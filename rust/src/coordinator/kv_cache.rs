//! Paged KV-cache block manager (vLLM-style) with refcounted
//! copy-on-write sharing.
//!
//! The serving engine accounts KV memory in fixed-size blocks of
//! `block_size` token slots per sequence. Weight-only quantization frees
//! ~3x of weight memory, which becomes KV budget — this is the mechanism
//! behind the paper's "larger batch inference becomes possible" (§4.2) and
//! the OOM column of Table 1; the block manager makes it concrete.
//!
//! Blocks are refcounted so sequences can share them: the automatic
//! prefix cache (`coordinator::prefix`) leases full blocks of a matched
//! prompt prefix to new sequences, and [`KvBlockManager::fork`] clones a
//! whole sequence. Writes into a shared partial tail block trigger
//! copy-on-write ([`KvBlockManager::append_token`]). A block released by
//! its last sequence either returns to the free list or — when the prefix
//! index holds it (`cached`) — stays resident as *evictable idle*
//! capacity until [`KvBlockManager::evict`] reclaims it.
//!
//! Invariants (enforced by unit + property tests):
//! * per-block refcount equals the number of block tables referencing it;
//! * a block appears at most once in any one sequence's table;
//! * every block is on the free list, referenced, or cached — no leaks,
//!   and free-listed blocks are never referenced or cached;
//! * freeing a sequence conserves the ledger exactly.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, ensure, Result};

use crate::quant::KvPrecision;

/// Sequence identifier.
pub type SeqId = u64;

/// Fixed-capacity block pool + per-sequence block tables.
///
/// Blocks are fixed-size *byte slabs* sized to hold `block_size` f16
/// tokens. A sequence stored at a quantized [`KvPrecision`] packs more
/// tokens into the same slab ([`KvPrecision::tokens_per_block`]), so the
/// same pool admits ~2x (8-bit) to ~3.4x (4-bit) the resident tokens —
/// while the refcount/COW/prefix machinery, which only moves whole
/// slabs, is untouched. Each sequence records the precision it was
/// allocated at; admission ([`KvBlockManager::can_admit`]) prices the
/// pool-default precision set by [`KvBlockManager::with_precision`].
#[derive(Debug)]
pub struct KvBlockManager {
    block_size: u64,
    /// Pool-default storage precision for new sequences.
    precision: KvPrecision,
    total_blocks: u64,
    free: Vec<u32>,
    /// Per-block count of sequences referencing it.
    refs: Vec<u32>,
    /// Per-block: held by the prefix index (content-addressed, reusable).
    cached: Vec<bool>,
    /// Blocks with `refs == 0 && cached` (evictable idle capacity).
    cached_idle: u64,
    tables: HashMap<SeqId, BlockTable>,
    /// Blocks kept free as headroom for in-flight decodes (vLLM's
    /// watermark prevents admission from starving running sequences).
    watermark_blocks: u64,
    /// Copy-on-write forks taken on shared tail blocks.
    cow_forks: u64,
}

#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<u32>,
    /// Tokens currently stored.
    pub tokens: u64,
    /// Storage precision this sequence's blocks were packed at (fixed at
    /// allocation; forks inherit it).
    pub precision: KvPrecision,
}

impl KvBlockManager {
    pub fn new(total_blocks: u64, block_size: u64, watermark_frac: f64) -> Self {
        assert!(block_size > 0 && total_blocks > 0);
        assert!((0.0..0.5).contains(&watermark_frac));
        KvBlockManager {
            block_size,
            precision: KvPrecision::F16,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            refs: vec![0; total_blocks as usize],
            cached: vec![false; total_blocks as usize],
            cached_idle: 0,
            tables: HashMap::new(),
            watermark_blocks: (total_blocks as f64 * watermark_frac).ceil() as u64,
            cow_forks: 0,
        }
    }

    /// Pool capacity helpers -------------------------------------------------
    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> u64 {
        self.free.len() as u64
    }

    /// Blocks actively referenced by at least one sequence.
    pub fn allocated_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks() - self.cached_idle
    }

    /// Idle blocks held only by the prefix cache (reclaimable via
    /// [`Self::evict`]).
    pub fn cached_idle_blocks(&self) -> u64 {
        self.cached_idle
    }

    pub fn watermark_blocks(&self) -> u64 {
        self.watermark_blocks
    }

    pub fn cow_forks(&self) -> u64 {
        self.cow_forks
    }

    /// Set the pool-default [`KvPrecision`] for sequences allocated after
    /// this call (builder-style; `F16` if never called, which reproduces
    /// the pre-quantization block math bit-for-bit).
    pub fn with_precision(mut self, precision: KvPrecision) -> Self {
        self.precision = precision;
        self
    }

    /// The pool-default storage precision.
    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    /// Tokens one slab holds at the pool-default precision.
    pub fn tokens_per_block(&self) -> u64 {
        self.precision.tokens_per_block(self.block_size)
    }

    /// Blocks a sequence of `tokens` needs at the pool-default precision.
    pub fn blocks_needed(&self, tokens: u64) -> u64 {
        self.blocks_needed_at(tokens, self.precision)
    }

    /// Blocks a sequence of `tokens` needs at an explicit precision —
    /// the per-precision byte cost, in slab units.
    pub fn blocks_needed_at(&self, tokens: u64, precision: KvPrecision) -> u64 {
        tokens.div_ceil(precision.tokens_per_block(self.block_size))
    }

    pub fn ref_count(&self, block: u32) -> u32 {
        self.refs[block as usize]
    }

    pub fn is_cached(&self, block: u32) -> bool {
        self.cached[block as usize]
    }

    /// A cached block no sequence references: reclaimable.
    pub fn is_evictable(&self, block: u32) -> bool {
        self.cached[block as usize] && self.refs[block as usize] == 0
    }

    /// Admission check: can a new sequence of `prompt_tokens` be allocated
    /// without dipping into the decode watermark? Idle cached blocks count
    /// as capacity — eviction reclaims them on demand. Prices the
    /// pool-default precision.
    pub fn can_admit(&self, prompt_tokens: u64) -> bool {
        self.can_admit_at(prompt_tokens, self.precision)
    }

    /// [`Self::can_admit`] at an explicit per-sequence precision.
    pub fn can_admit_at(&self, prompt_tokens: u64, precision: KvPrecision) -> bool {
        self.blocks_needed_at(prompt_tokens.max(1), precision) + self.watermark_blocks
            <= self.free_blocks() + self.cached_idle
    }

    /// Allocate the block table for a new sequence's prompt at the
    /// pool-default precision.
    pub fn allocate(&mut self, seq: SeqId, prompt_tokens: u64) -> Result<()> {
        self.allocate_shared(seq, prompt_tokens, &[])
    }

    /// [`Self::allocate`] at an explicit per-sequence precision (mixed
    /// pools: e.g. latency-critical sequences kept at f16 next to
    /// quantized bulk traffic).
    pub fn allocate_with_precision(
        &mut self,
        seq: SeqId,
        prompt_tokens: u64,
        precision: KvPrecision,
    ) -> Result<()> {
        self.allocate_shared_at(seq, prompt_tokens, &[], precision)
    }

    /// Allocate a new sequence whose first `shared.len()` blocks are
    /// leased from live blocks (cached prefix or another sequence); only
    /// the remainder comes from the free list. Shared blocks gain a
    /// reference; writes into a shared tail later copy-on-write.
    pub fn allocate_shared(
        &mut self,
        seq: SeqId,
        prompt_tokens: u64,
        shared: &[u32],
    ) -> Result<()> {
        self.allocate_shared_at(seq, prompt_tokens, shared, self.precision)
    }

    /// [`Self::allocate_shared`] at an explicit per-sequence precision.
    /// Shared (leased) blocks must have been packed at the same precision
    /// the new sequence reads them at — the prefix cache guarantees this
    /// by keying pools, not blocks; here it is the caller's contract.
    pub fn allocate_shared_at(
        &mut self,
        seq: SeqId,
        prompt_tokens: u64,
        shared: &[u32],
        precision: KvPrecision,
    ) -> Result<()> {
        if self.tables.contains_key(&seq) {
            bail!("sequence {seq} already has a block table");
        }
        let need = self.blocks_needed_at(prompt_tokens.max(1), precision);
        ensure!(
            shared.len() as u64 <= need,
            "shared prefix ({} blocks) longer than the sequence needs ({need})",
            shared.len()
        );
        let mut uniq = HashSet::new();
        for &b in shared {
            ensure!((b as u64) < self.total_blocks, "shared block {b} out of range");
            ensure!(uniq.insert(b), "shared block {b} listed twice");
            ensure!(
                self.refs[b as usize] > 0 || self.cached[b as usize],
                "shared block {b} is not live (free-listed?)"
            );
        }
        let fresh = need - shared.len() as u64;
        if fresh > self.free_blocks() {
            bail!("out of KV blocks: need {fresh} fresh, free {}", self.free_blocks());
        }
        for &b in shared {
            let i = b as usize;
            if self.refs[i] == 0 && self.cached[i] {
                self.cached_idle -= 1;
            }
            self.refs[i] += 1;
        }
        let mut blocks: Vec<u32> = shared.to_vec();
        for _ in 0..fresh {
            let b = self
                .free
                .pop()
                .ok_or_else(|| anyhow!("KV free list drained mid-allocation for sequence {seq}"))?;
            self.refs[b as usize] += 1;
            blocks.push(b);
        }
        self.tables.insert(seq, BlockTable { blocks, tokens: prompt_tokens, precision });
        Ok(())
    }

    /// Clone `parent`'s block table for `child` with every block shared
    /// (refcount++), including a partial tail — the tail copy-on-writes
    /// on the next append. Costs zero free blocks.
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> Result<()> {
        if self.tables.contains_key(&child) {
            bail!("sequence {child} already has a block table");
        }
        let table = match self.tables.get(&parent) {
            Some(t) => t.clone(),
            None => bail!("fork: unknown parent sequence {parent}"),
        };
        for &b in &table.blocks {
            self.refs[b as usize] += 1;
        }
        self.tables.insert(child, table);
        Ok(())
    }

    /// The sequence's *sealed* full blocks: immutable (appends only ever
    /// touch the tail slot past them) and therefore safe to publish into
    /// the prefix index. The partial tail stays private.
    pub fn seal(&self, seq: SeqId) -> Result<Vec<u32>> {
        let table = match self.tables.get(&seq) {
            Some(t) => t,
            None => bail!("seal: unknown sequence {seq}"),
        };
        let tpb = table.precision.tokens_per_block(self.block_size);
        let full = (table.tokens / tpb) as usize;
        Ok(table.blocks[..full.min(table.blocks.len())].to_vec())
    }

    /// Mutable table lookup with a descriptive error for callers that
    /// already established the sequence is live.
    fn table_mut(&mut self, seq: SeqId) -> Result<&mut BlockTable> {
        self.tables.get_mut(&seq).ok_or_else(|| anyhow!("unknown sequence {seq}"))
    }

    /// Append one decoded token; may claim one more block, either at a
    /// block boundary or to copy-on-write a shared partial tail. Returns
    /// true if a block was claimed from the free list.
    pub fn append_token(&mut self, seq: SeqId) -> Result<bool> {
        let bs = self.block_size;
        let table = match self.tables.get_mut(&seq) {
            Some(t) => t,
            None => bail!("append_token: unknown sequence {seq}"),
        };
        table.tokens += 1;
        let need = table.tokens.div_ceil(table.precision.tokens_per_block(bs));
        if need > table.blocks.len() as u64 {
            // Crossed a block boundary: claim a fresh block.
            match self.free.pop() {
                Some(b) => {
                    self.refs[b as usize] += 1;
                    self.table_mut(seq)?.blocks.push(b);
                    Ok(true)
                }
                None => {
                    // Roll back the token count so callers can preempt.
                    self.table_mut(seq)?.tokens -= 1;
                    bail!("out of KV blocks while decoding sequence {seq}")
                }
            }
        } else {
            // Writing into the existing partial tail slot.
            let tail = *table.blocks.last().expect("non-empty table");
            if self.refs[tail as usize] > 1 {
                // Shared tail: copy-on-write into a private block.
                match self.free.pop() {
                    Some(b) => {
                        self.refs[b as usize] += 1;
                        self.refs[tail as usize] -= 1;
                        let t = self.table_mut(seq)?;
                        match t.blocks.last_mut() {
                            Some(slot) => *slot = b,
                            None => bail!("copy-on-write on empty table for sequence {seq}"),
                        }
                        self.cow_forks += 1;
                        Ok(true)
                    }
                    None => {
                        self.table_mut(seq)?.tokens -= 1;
                        bail!("out of KV blocks for copy-on-write on sequence {seq}")
                    }
                }
            } else {
                // Exclusively owned; cached blocks are always full, so an
                // in-place tail write can never corrupt the prefix cache.
                debug_assert!(
                    !self.cached[tail as usize],
                    "in-place write into cached block {tail}"
                );
                Ok(false)
            }
        }
    }

    /// Release a finished (or preempted) sequence's blocks. Blocks whose
    /// last reference drops here return to the free list unless the
    /// prefix index holds them (those stay resident as evictable idle).
    /// Returns the number of blocks returned to the free list.
    pub fn free_seq(&mut self, seq: SeqId) -> Result<u64> {
        let table = match self.tables.remove(&seq) {
            Some(t) => t,
            None => bail!("free_seq: unknown sequence {seq}"),
        };
        let mut freed = 0;
        for b in table.blocks {
            let i = b as usize;
            debug_assert!(self.refs[i] > 0, "freeing unreferenced block {b}");
            self.refs[i] -= 1;
            if self.refs[i] == 0 {
                if self.cached[i] {
                    self.cached_idle += 1;
                } else {
                    self.free.push(b);
                    freed += 1;
                }
            }
        }
        Ok(freed)
    }

    /// Mark a (live, referenced) block as held by the prefix index.
    /// Idempotent; the block survives its last sequence reference as
    /// evictable idle capacity.
    pub fn mark_cached(&mut self, block: u32) -> Result<()> {
        let i = block as usize;
        ensure!((block as u64) < self.total_blocks, "block {block} out of range");
        if self.cached[i] {
            return Ok(());
        }
        ensure!(self.refs[i] > 0, "only referenced blocks can enter the cache");
        self.cached[i] = true;
        Ok(())
    }

    /// Reclaim an evictable idle block to the free list (the prefix index
    /// must have dropped its entry first — see `prefix::PrefixCache`).
    pub fn evict(&mut self, block: u32) -> Result<()> {
        ensure!(self.is_evictable(block), "block {block} is not evictable");
        self.cached[block as usize] = false;
        self.cached_idle -= 1;
        self.free.push(block);
        Ok(())
    }

    pub fn table(&self, seq: SeqId) -> Option<&BlockTable> {
        self.tables.get(&seq)
    }

    pub fn num_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Sanity: refcounts equal table references, no per-sequence
    /// duplicates, free blocks unreferenced and uncached, nothing leaks,
    /// idle counter matches.
    pub fn check_invariants(&self) -> Result<()> {
        let n = self.total_blocks as usize;
        let mut counted = vec![0u32; n];
        for (seq, t) in &self.tables {
            let mut seen = HashSet::new();
            for &b in &t.blocks {
                ensure!((b as u64) < self.total_blocks, "block {b} out of range");
                ensure!(seen.insert(b), "block {b} twice in seq {seq}");
                counted[b as usize] += 1;
            }
            ensure!(
                t.blocks.len() as u64
                    >= t.tokens.div_ceil(t.precision.tokens_per_block(self.block_size)),
                "seq {seq} has fewer blocks than tokens need"
            );
        }
        for b in 0..n {
            ensure!(
                counted[b] == self.refs[b],
                "refcount drift on block {b}: counted {}, stored {}",
                counted[b],
                self.refs[b]
            );
        }
        let mut on_free = vec![false; n];
        for &b in &self.free {
            let i = b as usize;
            ensure!(!on_free[i], "block {b} double-listed in free");
            on_free[i] = true;
            ensure!(self.refs[i] == 0, "free block {b} still referenced");
            ensure!(!self.cached[i], "free block {b} still cached");
        }
        let mut idle = 0u64;
        for b in 0..n {
            ensure!(
                on_free[b] || self.refs[b] > 0 || self.cached[b],
                "leaked block {b}"
            );
            if self.refs[b] == 0 && self.cached[b] {
                idle += 1;
            }
        }
        ensure!(
            idle == self.cached_idle,
            "cached_idle drift: counted {idle}, stored {}",
            self.cached_idle
        );
        Ok(())
    }
}

/// Size a block pool for a device: KV budget = device memory − weights −
/// activation headroom.
pub fn blocks_for_device(
    mem_bytes: f64,
    weight_bytes: f64,
    kv_bytes_per_token: f64,
    block_size: u64,
    headroom_frac: f64,
) -> u64 {
    let budget = (mem_bytes * (1.0 - headroom_frac) - weight_bytes).max(0.0);
    let tokens = budget / kv_bytes_per_token;
    (tokens / block_size as f64).floor() as u64
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn mgr() -> KvBlockManager {
        KvBlockManager::new(64, 16, 0.05)
    }

    #[test]
    fn allocate_and_free_balances() {
        let mut m = mgr();
        m.allocate(1, 40).unwrap(); // 3 blocks
        m.allocate(2, 1).unwrap(); // 1 block
        assert_eq!(m.allocated_blocks(), 4);
        m.check_invariants().unwrap();
        assert_eq!(m.free_seq(1).unwrap(), 3);
        assert_eq!(m.allocated_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn append_claims_block_at_boundary() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap(); // exactly one full block
        assert!(m.append_token(1).unwrap()); // 17th token -> new block
        assert!(!m.append_token(1).unwrap()); // 18th fits
        m.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let mut m = KvBlockManager::new(2, 4, 0.0);
        m.allocate(1, 8).unwrap(); // both blocks
        assert!(m.allocate(2, 1).is_err());
        let before = m.table(1).unwrap().tokens;
        assert!(m.append_token(1).is_err());
        assert_eq!(m.table(1).unwrap().tokens, before, "rollback on failure");
        m.check_invariants().unwrap();
    }

    #[test]
    fn watermark_blocks_admission_but_not_decode() {
        let mut m = KvBlockManager::new(20, 16, 0.25); // watermark = 5
        assert!(m.can_admit(16 * 14));
        assert!(!m.can_admit(16 * 16)); // would leave < watermark
        m.allocate(1, 16 * 14).unwrap();
        // decode can still take blocks below the watermark
        for _ in 0..16 {
            m.append_token(1).unwrap();
        }
        m.check_invariants().unwrap();
    }

    #[test]
    fn double_allocate_rejected() {
        let mut m = mgr();
        m.allocate(1, 4).unwrap();
        assert!(m.allocate(1, 4).is_err());
    }

    #[test]
    fn fork_shares_all_blocks_then_cow_on_append() {
        let mut m = KvBlockManager::new(8, 4, 0.0);
        m.allocate(1, 6).unwrap(); // 2 blocks, partial tail (2/4 used)
        m.fork(1, 2).unwrap();
        assert_eq!(m.free_blocks(), 6, "fork costs no blocks");
        let tail = *m.table(1).unwrap().blocks.last().unwrap();
        assert_eq!(m.ref_count(tail), 2);
        m.check_invariants().unwrap();

        // Child append lands in the shared partial tail -> copy-on-write.
        assert!(m.append_token(2).unwrap());
        assert_eq!(m.cow_forks(), 1);
        assert_eq!(m.ref_count(tail), 1);
        assert_ne!(
            m.table(1).unwrap().blocks.last(),
            m.table(2).unwrap().blocks.last()
        );
        m.check_invariants().unwrap();

        // Parent's tail is private again: in-place append, no claim.
        assert!(!m.append_token(1).unwrap());
        assert_eq!(m.cow_forks(), 1);

        m.free_seq(1).unwrap();
        m.free_seq(2).unwrap();
        assert_eq!(m.free_blocks(), 8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn seal_returns_only_full_blocks() {
        let mut m = KvBlockManager::new(8, 4, 0.0);
        m.allocate(1, 10).unwrap(); // 3 blocks, 2 full
        let sealed = m.seal(1).unwrap();
        assert_eq!(sealed.len(), 2);
        assert_eq!(&m.table(1).unwrap().blocks[..2], &sealed[..]);
    }

    #[test]
    fn cached_block_lifecycle_survives_free_then_evicts() {
        let mut m = KvBlockManager::new(8, 4, 0.0);
        m.allocate(1, 9).unwrap(); // 3 blocks, 2 full
        for b in m.seal(1).unwrap() {
            m.mark_cached(b).unwrap();
        }
        m.check_invariants().unwrap();
        // Only the uncached partial tail returns to the free list.
        assert_eq!(m.free_seq(1).unwrap(), 1);
        assert_eq!(m.cached_idle_blocks(), 2);
        assert_eq!(m.allocated_blocks(), 0);
        assert!(m.can_admit(32), "idle blocks still count as capacity");
        m.check_invariants().unwrap();

        // Lease one idle block into a new sequence, evict the other.
        let shared = {
            let mut idle: Vec<u32> =
                (0..8).filter(|&b| m.is_evictable(b)).collect();
            idle.sort_unstable();
            idle
        };
        m.allocate_shared(2, 5, &shared[..1]).unwrap();
        assert_eq!(m.cached_idle_blocks(), 1);
        m.evict(shared[1]).unwrap();
        assert_eq!(m.cached_idle_blocks(), 0);
        assert!(!m.is_cached(shared[1]));
        m.check_invariants().unwrap();

        m.free_seq(2).unwrap();
        // shared[0] is still cached -> idle again, not freed.
        assert_eq!(m.cached_idle_blocks(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn allocate_shared_rejects_dead_or_duplicate_blocks() {
        let mut m = KvBlockManager::new(8, 4, 0.0);
        m.allocate(1, 4).unwrap();
        let b = m.table(1).unwrap().blocks[0];
        // Free-listed block cannot be shared.
        let dead = (0..8).find(|&x| m.ref_count(x) == 0).unwrap();
        assert!(m.allocate_shared(2, 8, &[dead]).is_err());
        // Duplicate shared list rejected.
        assert!(m.allocate_shared(2, 12, &[b, b]).is_err());
        // Live block shared fine.
        m.allocate_shared(2, 8, &[b]).unwrap();
        assert_eq!(m.ref_count(b), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn evict_rejects_live_or_uncached_blocks() {
        let mut m = KvBlockManager::new(4, 4, 0.0);
        m.allocate(1, 8).unwrap();
        let b = m.table(1).unwrap().blocks[0];
        assert!(m.evict(b).is_err(), "referenced block not evictable");
        m.mark_cached(b).unwrap();
        assert!(m.evict(b).is_err(), "cached but referenced: not evictable");
        m.free_seq(1).unwrap();
        m.evict(b).unwrap();
        assert!(m.evict(b).is_err(), "already evicted");
        m.check_invariants().unwrap();
    }

    #[test]
    fn watermark_math_prices_per_precision_byte_cost() {
        // Same byte pool (20 slabs sized for 16 f16 tokens, watermark 5
        // slabs) at each storage precision: admission must count blocks
        // in *slab* units derived from the precision's byte cost, so the
        // quantized pools admit proportionally more tokens before the
        // watermark bites.
        for (prec, tpb) in [
            (KvPrecision::F16, 16u64),
            (KvPrecision::Int8, 29),
            (KvPrecision::Int4, 53),
        ] {
            let m = KvBlockManager::new(20, 16, 0.25).with_precision(prec);
            assert_eq!(m.tokens_per_block(), tpb, "{prec:?}");
            assert_eq!(m.blocks_needed(tpb * 3 + 1), 4, "{prec:?}");
            // 14 blocks + 5 watermark fits in 20; 16 + 5 does not.
            assert!(m.can_admit(tpb * 14), "{prec:?}");
            assert!(!m.can_admit(tpb * 16), "{prec:?}");
        }
    }

    #[test]
    fn mixed_precision_sequences_share_one_pool() {
        let mut m = KvBlockManager::new(8, 4, 0.0); // slabs of 4 f16 tokens
        let tpb4 = KvPrecision::Int4.tokens_per_block(4); // 13 tokens/slab
        assert_eq!(tpb4, 13);
        m.allocate(1, 8).unwrap(); // f16 default: 2 slabs
        m.allocate_with_precision(2, 20, KvPrecision::Int4).unwrap(); // 2 slabs
        assert_eq!(m.allocated_blocks(), 4);
        assert_eq!(m.table(1).unwrap().precision, KvPrecision::F16);
        assert_eq!(m.table(2).unwrap().precision, KvPrecision::Int4);
        m.check_invariants().unwrap();
        // Per-sequence boundary math: the f16 seq claims a slab on its
        // 9th token; the int4 seq has 13-token slabs, so token 21 of 26
        // capacity stays in place.
        assert!(m.append_token(1).unwrap());
        assert!(!m.append_token(2).unwrap());
        // Admission at an explicit precision prices that precision.
        assert!(m.can_admit_at(13 * 3, KvPrecision::Int4));
        assert!(!m.can_admit_at(13 * 3, KvPrecision::F16));
        m.check_invariants().unwrap();
    }

    #[test]
    fn quantized_pool_boundary_and_cow_respect_tokens_per_block() {
        let mut m = KvBlockManager::new(8, 4, 0.0).with_precision(KvPrecision::Int8);
        let tpb = KvPrecision::Int8.tokens_per_block(4); // 7 tokens/slab
        assert_eq!(tpb, 7);
        m.allocate(1, tpb).unwrap(); // exactly one full slab
        assert_eq!(m.allocated_blocks(), 1);
        assert_eq!(m.seal(1).unwrap().len(), 1);
        assert!(m.append_token(1).unwrap(), "boundary claims a slab");
        // Fork shares the partial tail; the child's append copy-on-writes.
        m.fork(1, 2).unwrap();
        assert!(m.append_token(2).unwrap());
        assert_eq!(m.cow_forks(), 1);
        assert_eq!(m.table(2).unwrap().precision, KvPrecision::Int8);
        m.check_invariants().unwrap();
    }

    #[test]
    fn device_sizing_quantization_frees_kv() {
        // A6000 48 GiB, Llama-2-70B: fp16 weights don't fit; W4 leaves room.
        let mem = 48.0 * (1u64 << 30) as f64;
        let kv_tok = 2.0 * 80.0 * 8.0 * 128.0 * 2.0; // GQA 70B per-token bytes
        let fp16 = blocks_for_device(mem, 140e9, kv_tok, 16, 0.05);
        let w4 = blocks_for_device(mem, 36e9, kv_tok, 16, 0.05);
        assert_eq!(fp16, 0);
        assert!(w4 > 1000);
    }
}
