//! Measured serving runtime: the bridge that closes the
//! modeled-vs-measured seam.
//!
//! The serving simulators in [`super::simserve`] advance their clock by
//! `gpusim`-modeled step latencies. This module supplies the *measured*
//! twin: a [`MeasuredEngine`] holds one prepared
//! [`StepExecutor`](crate::kernel::StepExecutor) per tensor-parallel
//! rank and, for every scheduler step, runs the full weight-GEMM stream
//! at the step's actual mixed prefill/decode batch `M` on this CPU —
//! through the same `WorkerPool`-backed fused/write-back kernels
//! `simulate step` benchmarks. The step's charged latency is
//!
//! ```text
//! measured GEMM-stream wall time (tp ranks run concurrently)
//!   + gpusim-priced ring collectives (tp_step_comm_s, 0 at tp = 1)
//! ```
//!
//! Since PR 8 the decode-attention term is executed too: each rank's
//! executor runs the fused quantized-KV attention kernel
//! (`kernel::attn_quant_fused`, or the dense-tiled baseline at
//! [`KvPrecision::F16`]) once per per-rank (layer × KV head) at a fixed
//! representative context of [`MEASURED_ATTN_CTX`] tokens, inside the
//! same step wall clock — so the measured clock now covers GEMMs *and*
//! attention, and the drift ledger gains `(m, ctx, head_dim)` rows
//! priced against `gpusim::kv_attn_term`. Non-GEMM elementwise glue
//! remains modeled only. The modeled step latency is still evaluated
//! side by side and accumulated in [`MeasuredStats::modeled_s`], and
//! per-GEMM drift feeds the global
//! [`DriftAccountant`](crate::obs::DriftAccountant) ledger via
//! `StepExecutor::enable_drift`. Prefix-cache hits shrink the
//! scheduler's planned chunks, so cached tokens never reach
//! [`MeasuredEngine::execute`] — a hit skips real compute, observable
//! as fewer [`MeasuredStats::executed_tokens`].
//!
//! TP ranks are spawned as scoped threads but share this host's one
//! `WorkerPool`, whose submit lock serializes GEMM jobs — the measured
//! wall time is the ranks-share-one-CPU stand-in, with the inter-rank
//! communication priced by the same collective model `simulate tp`
//! uses.

use anyhow::Result;
use std::time::Instant;

use crate::gpusim::{tp_step_comm_s, Calib, DeviceSpec};
use crate::kernel::{Blocking, StepBackend, StepExecutor};
use crate::model::LlmSpec;
use crate::quant::{CodebookKind, KvPrecision};
use crate::workload::{BurstyWorkload, Request, SharedPrefixWorkload};

/// Representative decode context length (KV rows per lane) the measured
/// attention term runs at. Deliberately *not* a weight dimension of any
/// tabulated model, so the `(m, ctx, head_dim)` drift keys never
/// collide with the GEMM `(m, k, n)` keys, and small enough to fit the
/// tiny model's 64-token context.
pub const MEASURED_ATTN_CTX: usize = 48;

/// Running totals of a measured serving run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeasuredStats {
    /// Scheduler steps executed on the native runtime.
    pub steps: u64,
    /// Tokens that actually ran through the GEMM stream (sum of step
    /// batches). Prefix-cache hits reduce this — cached tokens are
    /// never planned into a step.
    pub executed_tokens: u64,
    /// Measured wall seconds of the GEMM streams (concurrent ranks).
    pub gemm_wall_s: f64,
    /// Modeled ring-collective seconds charged on top (0 at tp = 1).
    pub comm_s: f64,
    /// What the `gpusim` cost model priced the same steps at (the
    /// modeled twin, evaluated side by side every step).
    pub modeled_s: f64,
}

impl MeasuredStats {
    /// Seconds the measured clock advanced: GEMM wall + priced comm.
    pub fn measured_total_s(&self) -> f64 {
        self.gemm_wall_s + self.comm_s
    }

    /// Modeled-over-measured time across the run, `None` before any
    /// step. The modeled side includes attention/glue terms the
    /// runtime does not execute, so this is the *serving-level* seam
    /// width, not a per-kernel ratio (the drift ledger has those).
    pub fn modeled_over_measured(&self) -> Option<f64> {
        if self.measured_total_s() <= 0.0 {
            None
        } else {
            Some(self.modeled_s / self.measured_total_s())
        }
    }
}

/// One prepared native runtime per TP rank, stepped by the serving
/// simulators in place of the cost model (see the module docs).
pub struct MeasuredEngine {
    dev: DeviceSpec,
    spec: LlmSpec,
    tp: u64,
    ranks: Vec<StepExecutor>,
    /// Totals over every executed step.
    pub stats: MeasuredStats,
}

impl MeasuredEngine {
    /// Prepare `tp` ranks of `spec`'s weight-GEMM stream for `backend`,
    /// each with its own seeded random quantized weights (seed + rank)
    /// and drift instrumentation against `dev`/`calib`, plus the
    /// measured decode-attention term over `kv_precision` KV at
    /// [`MEASURED_ATTN_CTX`] tokens. `tp = 1` builds the full un-sharded
    /// stream; `tp > 1` builds each rank's `tp_gemms` share (errors on
    /// non-divisible head counts before touching `tp_gemms`, which
    /// would panic).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        backend: StepBackend,
        tp: u64,
        group_size: usize,
        m_max: usize,
        seed: u64,
        kv_precision: KvPrecision,
        calib: &Calib,
    ) -> Result<MeasuredEngine> {
        Self::new_codebook(
            dev,
            spec,
            backend,
            tp,
            group_size,
            m_max,
            seed,
            kv_precision,
            calib,
            CodebookKind::Int4Uniform,
        )
    }

    /// [`MeasuredEngine::new`] with the weight codebook chosen per run:
    /// non-uniform grids (NF4/MXFP4) force every rank's executor onto
    /// the LUT decode tier, so a measured serving run prices exactly the
    /// decoder a non-uniform checkpoint would pay.
    #[allow(clippy::too_many_arguments)]
    pub fn new_codebook(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        backend: StepBackend,
        tp: u64,
        group_size: usize,
        m_max: usize,
        seed: u64,
        kv_precision: KvPrecision,
        calib: &Calib,
        codebook: CodebookKind,
    ) -> Result<MeasuredEngine> {
        anyhow::ensure!(tp >= 1, "tp must be >= 1, got {tp}");
        anyhow::ensure!(
            spec.n_heads % tp == 0 && spec.kv_heads % tp == 0,
            "{}: {} heads / {} kv heads not divisible by tp={tp}",
            spec.name,
            spec.n_heads,
            spec.kv_heads
        );
        let mut ranks = Vec::with_capacity(tp as usize);
        for rank in 0..tp {
            let mut e = if tp == 1 {
                StepExecutor::new_codebook(
                    spec,
                    backend,
                    Blocking::default(),
                    group_size,
                    m_max,
                    seed,
                    codebook,
                )?
            } else {
                StepExecutor::new_tp_codebook(
                    spec,
                    tp,
                    backend,
                    Blocking::default(),
                    group_size,
                    m_max,
                    seed + rank,
                    codebook,
                )?
            };
            e.enable_drift(dev, calib);
            e.enable_attention(
                spec,
                tp,
                kv_precision,
                MEASURED_ATTN_CTX,
                seed.wrapping_add(0xA77).wrapping_add(rank),
            )?;
            ranks.push(e);
        }
        Ok(MeasuredEngine {
            dev: *dev,
            spec: *spec,
            tp,
            ranks,
            stats: MeasuredStats::default(),
        })
    }

    /// TP group size the engine was built for.
    pub fn tp_degree(&self) -> u64 {
        self.tp
    }

    /// Largest step batch [`MeasuredEngine::execute`] accepts.
    pub fn m_max(&self) -> usize {
        self.ranks[0].m_max()
    }

    /// Backend every rank's GEMMs run through.
    pub fn backend(&self) -> StepBackend {
        self.ranks[0].backend_kind()
    }

    /// Execute one scheduler step of `m` tokens for real and return the
    /// seconds to advance the serving clock by: the measured wall time
    /// of the concurrent per-rank GEMM streams plus the modeled ring
    /// collectives. `modeled_s` is the cost model's price for the same
    /// step, accumulated as the side-by-side twin.
    ///
    /// # Panics
    /// If `m` is outside `1..=m_max` — the serving policy must size the
    /// engine to its token budget up front.
    pub fn execute(&mut self, m: usize, modeled_s: f64) -> f64 {
        assert!(
            m >= 1 && m <= self.m_max(),
            "measured step batch {m} outside 1..={}",
            self.m_max()
        );
        let t0 = Instant::now();
        let (rank0, rest) = self.ranks.split_at_mut(1);
        if rest.is_empty() {
            rank0[0].step(m).expect("batch within m_max");
        } else {
            // The group steps in lockstep: peers on scoped threads, rank
            // 0 on the caller. All GEMM jobs funnel through the shared
            // WorkerPool (ranks share this one CPU), so the wall time
            // measured here is the group-wide step time.
            std::thread::scope(|s| {
                let peers: Vec<_> = rest
                    .iter_mut()
                    .map(|r| s.spawn(move || r.step(m).map(|_| ())))
                    .collect();
                rank0[0].step(m).expect("batch within m_max");
                for p in peers {
                    p.join().expect("rank thread panicked").expect("batch within m_max");
                }
            });
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-12);
        let comm = tp_step_comm_s(&self.dev, &self.spec, m as u64, self.tp);
        self.stats.steps += 1;
        self.stats.executed_tokens += m as u64;
        self.stats.gemm_wall_s += wall;
        self.stats.comm_s += comm;
        self.stats.modeled_s += modeled_s;
        wall + comm
    }
}

/// The bursty workload scaled to the tiny model the measured runtime
/// can serve: the same shape as [`BurstyWorkload::default`] (bursts,
/// long prompts, heavy-tail generations), with every request fitting
/// the tiny model's 64-token context, so a measured run stays in the
/// single-digit-GFLOP range.
pub fn measured_bursty(n: usize, seed: u64) -> Vec<Request> {
    BurstyWorkload {
        burst_size: (3, 8),
        long_frac: 0.25,
        tail_frac: 0.25,
        short_prompt: (4, 10),
        short_gen: (4, 12),
        tail_gen: (24, 48),
        long_prompt: (24, 40),
        long_gen: (2, 8),
    }
    .offline(n, seed)
}

/// The shared-prefix workload scaled to the tiny model: same popularity
/// skew and multi-turn structure as [`SharedPrefixWorkload::default`],
/// with system prompts spanning several full cache blocks (the measured
/// policy's 8-token blocks) while every conversation turn still fits
/// the 64-token context.
pub fn measured_shared_prefix(n: usize, seed: u64) -> Vec<Request> {
    SharedPrefixWorkload {
        n_system_prompts: 4,
        zipf_s: 1.1,
        sys_tokens: (32, 40),
        user_tokens: (2, 4),
        gen_tokens: (2, 4),
        turns: (2, 2),
    }
    .offline(n, seed)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;

    #[test]
    fn executes_and_accumulates() {
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Tiny.spec();
        let mut eng = MeasuredEngine::new(
            &dev,
            &spec,
            StepBackend::Fused,
            1,
            128,
            8,
            7,
            KvPrecision::Int4,
            &Calib::default(),
        )
        .unwrap();
        let dt = eng.execute(4, 1e-3);
        assert!(dt > 0.0);
        assert!(eng.ranks[0].attention_enabled(), "measured steps execute attention");
        assert!(eng.ranks[0].last_attn_s() > 0.0, "attention term timed");
        assert_eq!(eng.stats.steps, 1);
        assert_eq!(eng.stats.executed_tokens, 4);
        assert_eq!(eng.stats.comm_s, 0.0, "tp=1 has no collectives");
        assert!((eng.stats.modeled_s - 1e-3).abs() < 1e-15);
        assert!(eng.stats.modeled_over_measured().is_some());
    }

    #[test]
    fn nonuniform_codebook_forces_lut_on_every_rank() {
        use crate::quant::DecoderKind;
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Tiny.spec();
        let mut eng = MeasuredEngine::new_codebook(
            &dev,
            &spec,
            StepBackend::Fused,
            2,
            128,
            8,
            7,
            KvPrecision::F16,
            &Calib::default(),
            CodebookKind::Nf4,
        )
        .unwrap();
        for r in &eng.ranks {
            assert_eq!(r.codebook(), CodebookKind::Nf4);
            assert_eq!(r.decoder_kind(), DecoderKind::Lut, "non-uniform grid must decode via LUT");
        }
        assert!(eng.execute(4, 0.0) > 0.0, "LUT-decoded step executes");
    }

    #[test]
    fn tp_group_prices_collectives_and_shards_flops() {
        let dev = Gpu::A100.spec();
        let spec = Model::Tiny.spec();
        let calib = Calib::default();
        let mut tp2 = MeasuredEngine::new(
            &dev,
            &spec,
            StepBackend::Fused,
            2,
            128,
            8,
            7,
            KvPrecision::F16,
            &calib,
        )
        .unwrap();
        let dt = tp2.execute(8, 0.0);
        let comm = tp_step_comm_s(&dev, &spec, 8, 2);
        assert!(comm > 0.0);
        assert!(dt >= comm, "charged time must include the priced collectives");
        assert_eq!(tp2.stats.comm_s, comm);
    }

    #[test]
    fn rejects_indivisible_tp() {
        let dev = Gpu::A100.spec();
        let spec = Model::Tiny.spec(); // 4 heads
        assert!(MeasuredEngine::new(
            &dev,
            &spec,
            StepBackend::Fused,
            3,
            128,
            8,
            7,
            KvPrecision::F16,
            &Calib::default()
        )
        .is_err());
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn execute_rejects_oversized_batches() {
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Tiny.spec();
        let mut eng = MeasuredEngine::new(
            &dev,
            &spec,
            StepBackend::Fused,
            1,
            128,
            4,
            7,
            KvPrecision::F16,
            &Calib::default(),
        )
        .unwrap();
        eng.execute(5, 0.0);
    }

    #[test]
    fn scaled_workloads_fit_the_tiny_context() {
        let spec = Model::Tiny.spec();
        for r in measured_bursty(64, 1).iter().chain(&measured_shared_prefix(64, 2)) {
            assert!(
                r.prompt_tokens + r.gen_tokens <= spec.max_seq,
                "request {} needs {} tokens, context is {}",
                r.id,
                r.prompt_tokens + r.gen_tokens,
                spec.max_seq
            );
        }
    }
}
