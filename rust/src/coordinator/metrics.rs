//! Serving metrics: throughput counters and latency histograms.

use std::time::Duration;

/// Fixed-bucket latency histogram (log-spaced, 1 us .. ~1000 s).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    bounds: Vec<f64>,
    count: u64,
    sum_s: f64,
    max_s: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 1us * 2^i, 30 buckets -> covers up to ~1073 s.
        let bounds: Vec<f64> = (0..30).map(|i| 1e-6 * (1u64 << i) as f64).collect();
        Histogram { buckets: vec![0; 31], bounds, count: 0, sum_s: 0.0, max_s: 0.0 }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_s(d.as_secs_f64());
    }

    pub fn record_s(&mut self, s: f64) {
        let idx = self.bounds.partition_point(|&b| b < s);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_s += s;
        self.max_s = self.max_s.max(s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum_s / self.count as f64 }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max_s };
            }
        }
        self.max_s
    }
}

/// Aggregated engine metrics.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_admitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    /// Sum over decode steps of active lanes (for mean batch occupancy).
    pub decode_lane_steps: u64,
    /// Prompt tokens teacher-forced through *mixed* decode steps (chunked
    /// prefill riding the decode batch instead of stalling it).
    pub chunked_prefill_tokens: u64,
    /// Prefix-cache counters: requests admitted with/without a cached
    /// prompt prefix, prompt tokens whose prefill was skipped, and cached
    /// blocks evicted under the cache's budget.
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_tokens_skipped: u64,
    pub prefix_evictions: u64,
    pub ttft: Histogram,
    pub itl: Histogram,
    pub e2e: Histogram,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self { ttft: Histogram::new(), itl: Histogram::new(), e2e: Histogram::new(), ..Default::default() }
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_lane_steps as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of admissions that found a cached prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let n = self.prefix_hits + self.prefix_misses;
        if n == 0 { 0.0 } else { self.prefix_hits as f64 / n as f64 }
    }

    pub fn report(&self, wall_s: f64) -> String {
        format!(
            "requests: {} admitted, {} finished, {} rejected\n\
             tokens:   {} prompt, {} generated\n\
             steps:    {} total ({} prefill, {} decode; mean decode batch {:.2}; {} chunk-riding prompt tokens)\n\
             prefix:   {} hits / {} misses ({:.0}% hit rate), {} tokens skipped, {} evictions\n\
             wall:     {:.2}s -> {:.1} gen tok/s\n\
             TTFT:     mean {:.1} ms, p50 {:.1} ms, p99 {:.1} ms\n\
             ITL:      mean {:.1} ms, p50 {:.1} ms, p99 {:.1} ms",
            self.requests_admitted,
            self.requests_finished,
            self.requests_rejected,
            self.prompt_tokens,
            self.generated_tokens,
            self.engine_steps,
            self.prefill_steps,
            self.decode_steps,
            self.mean_decode_batch(),
            self.chunked_prefill_tokens,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_hit_rate() * 100.0,
            self.prefix_tokens_skipped,
            self.prefix_evictions,
            wall_s,
            self.generated_tokens as f64 / wall_s.max(1e-9),
            self.ttft.mean_s() * 1e3,
            self.ttft.quantile_s(0.5) * 1e3,
            self.ttft.quantile_s(0.99) * 1e3,
            self.itl.mean_s() * 1e3,
            self.itl.quantile_s(0.5) * 1e3,
            self.itl.quantile_s(0.99) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_s(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-3 && p99 <= h.max_s() * 2.0);
        assert!((h.mean_s() - 0.05).abs() < 0.01);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_s(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn mean_decode_batch() {
        let mut m = EngineMetrics::new();
        m.decode_steps = 4;
        m.decode_lane_steps = 10;
        assert_eq!(m.mean_decode_batch(), 2.5);
    }

    #[test]
    fn prefix_hit_rate_and_report_line() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.prefix_tokens_skipped = 48;
        assert_eq!(m.prefix_hit_rate(), 0.75);
        let report = m.report(1.0);
        assert!(report.contains("75% hit rate"), "{report}");
        assert!(report.contains("48 tokens skipped"), "{report}");
    }
}
