//! Serving metrics: throughput counters and latency histograms.
//!
//! The latency [`Histogram`] itself lives in [`crate::obs`] since PR 6
//! (the registry, the simulations, and the engine all share one
//! implementation); this module keeps the engine-side aggregate and its
//! report, rendered through the shared [`Report`] writer so serving
//! output and `report obs` cannot drift apart.

pub use crate::obs::Histogram;
use crate::obs::Report;

/// Aggregated engine metrics.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub requests_admitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    /// Sum over decode steps of active lanes (for mean batch occupancy).
    pub decode_lane_steps: u64,
    /// Prompt tokens teacher-forced through *mixed* decode steps (chunked
    /// prefill riding the decode batch instead of stalling it).
    pub chunked_prefill_tokens: u64,
    /// Prefix-cache counters: requests admitted with/without a cached
    /// prompt prefix, prompt tokens whose prefill was skipped, and cached
    /// blocks evicted under the cache's budget.
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_tokens_skipped: u64,
    pub prefix_evictions: u64,
    pub ttft: Histogram,
    pub itl: Histogram,
    pub e2e: Histogram,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self { ttft: Histogram::new(), itl: Histogram::new(), e2e: Histogram::new(), ..Default::default() }
    }

    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_lane_steps as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of admissions that found a cached prompt prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let n = self.prefix_hits + self.prefix_misses;
        if n == 0 { 0.0 } else { self.prefix_hits as f64 / n as f64 }
    }

    pub fn report(&self, wall_s: f64) -> String {
        let mut r = Report::new();
        r.line(
            "requests",
            format!(
                "{} admitted, {} finished, {} rejected",
                self.requests_admitted, self.requests_finished, self.requests_rejected
            ),
        );
        r.line(
            "tokens",
            format!("{} prompt, {} generated", self.prompt_tokens, self.generated_tokens),
        );
        r.line(
            "steps",
            format!(
                "{} total ({} prefill, {} decode; mean decode batch {:.2}; {} chunk-riding prompt tokens)",
                self.engine_steps,
                self.prefill_steps,
                self.decode_steps,
                self.mean_decode_batch(),
                self.chunked_prefill_tokens,
            ),
        );
        r.line(
            "prefix",
            format!(
                "{} hits / {} misses ({:.0}% hit rate), {} tokens skipped, {} evictions",
                self.prefix_hits,
                self.prefix_misses,
                self.prefix_hit_rate() * 100.0,
                self.prefix_tokens_skipped,
                self.prefix_evictions,
            ),
        );
        r.line(
            "wall",
            format!(
                "{:.2}s -> {:.1} gen tok/s",
                wall_s,
                self.generated_tokens as f64 / wall_s.max(1e-9)
            ),
        );
        r.line("TTFT", Report::hist_ms(&self.ttft));
        r.line("ITL", Report::hist_ms(&self.itl));
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn mean_decode_batch() {
        let mut m = EngineMetrics::new();
        m.decode_steps = 4;
        m.decode_lane_steps = 10;
        assert_eq!(m.mean_decode_batch(), 2.5);
    }

    #[test]
    fn prefix_hit_rate_and_report_line() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.prefix_tokens_skipped = 48;
        assert_eq!(m.prefix_hit_rate(), 0.75);
        let report = m.report(1.0);
        assert!(report.contains("75% hit rate"), "{report}");
        assert!(report.contains("48 tokens skipped"), "{report}");
    }

    #[test]
    fn report_routes_through_shared_writer() {
        let mut m = EngineMetrics::new();
        m.ttft.record_s(2e-3);
        let report = m.report(1.0);
        // The TTFT/ITL lines are Report::hist_ms renderings with the
        // 10-column label gutter the Report writer enforces.
        assert!(report.contains(&format!("TTFT:     {}", Report::hist_ms(&m.ttft))), "{report}");
        assert!(report.contains("ITL:      mean"), "{report}");
    }
}
