//! L3 coordinator: the serving engine (vLLM-shaped) and its parts.
//!
//! * [`request`] — request/sequence lifecycle types.
//! * [`batcher`] — FCFS admission queue, lane assignment, prefill-priority
//!   step planning (continuous batching over fixed-shape AOT artifacts).
//! * [`kv_cache`] — paged KV block manager (vLLM-style) with refcounted
//!   copy-on-write block sharing, the memory accountant that converts
//!   quantization's freed bytes into batch slots.
//! * [`prefix`] — automatic prefix cache: content-addressed full KV
//!   blocks (hash chained over token ids), a radix-trie index mapping
//!   token prefixes to cached block chains, and LRU eviction of
//!   unreferenced blocks. Shared prompt prefixes (system prompts,
//!   multi-turn chat) skip their prefill compute.
//! * [`engine`] — the real engine: drives the PJRT runtime over the
//!   AOT-compiled tiny model; Python never runs here.
//! * [`router`] — multi-replica request router (round-robin, least-loaded,
//!   session-affinity, prefix-aware) for scale-out serving.
//! * [`simserve`] — the same policy run against the `gpusim` cost model at
//!   paper scale (Table 1, Fig. 8).
//! * [`metrics`] — throughput counters and TTFT/ITL histograms.

pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod prefix;
pub mod request;
pub mod router;
pub mod sampler;
pub mod simserve;

pub use batcher::{Batcher, StepPlan};
pub use engine::{Completion, Engine, EngineConfig};
pub use kv_cache::{blocks_for_device, KvBlockManager};
pub use metrics::{EngineMetrics, Histogram};
pub use prefix::{chain_hash, BlockHash, PrefixCache, PrefixIndex, PrefixStats, ROOT_HASH};
pub use request::{FinishReason, GenerationRequest, SeqState, Sequence};
pub use router::{prefix_key, Policy, RouteDecision, Router};
pub use simserve::{simulate_serving, SimPolicy, SimResult};
