//! L3 coordinator: the serving engine (vLLM-shaped) and its parts.
//!
//! * [`request`] — request/sequence lifecycle types.
//! * [`batcher`] — the scheduling core: the token-budget
//!   [`ContinuousScheduler`] (continuous batching with chunked prefill —
//!   decode tokens fill each step's budget first, admitted prompts chunk
//!   into the remainder), plus the lane-granular [`Batcher`] the
//!   fixed-shape PJRT engine drives with the same decode-first policy.
//! * [`kv_cache`] — paged KV block manager (vLLM-style) with refcounted
//!   copy-on-write block sharing, the memory accountant that converts
//!   quantization's freed bytes into batch slots.
//! * [`prefix`] — automatic prefix cache: content-addressed full KV
//!   blocks (hash chained over token ids), a radix-trie index mapping
//!   token prefixes to cached block chains, and LRU eviction of
//!   unreferenced blocks. Shared prompt prefixes (system prompts,
//!   multi-turn chat) skip their prefill compute.
//! * [`engine`] — the real engine: drives the PJRT runtime over the
//!   AOT-compiled tiny model; Python never runs here.
//! * [`router`] — multi-replica request router (round-robin, least-loaded,
//!   session-affinity, prefix-aware, tensor-parallel group placement) for
//!   scale-out serving.
//! * [`simserve`] — the serving policies run against the `gpusim` cost
//!   model at paper scale: continuous batching with chunked prefill
//!   (per-step cost from `gpusim::mixed_step_latency` at the actual mixed
//!   batch size), its tensor-parallel variant ([`simserve::simulate_tp`]:
//!   per-rank GEMMs at `1/tp` weight volume + per-layer all-reduces, KV
//!   pool grown by the weight bytes TP frees), the static
//!   prefill-then-decode wave baseline, and the legacy step-admission
//!   reference behind Table 1 / Fig. 8.
//! * [`measured`] — the modeled-vs-measured bridge: a
//!   [`measured::MeasuredEngine`] holds one native `StepExecutor` per TP
//!   rank and executes each scheduler step's GEMM stream for real, so
//!   `simserve`'s `*_measured` twins report throughput from this CPU's
//!   kernels (ring collectives priced by `gpusim::collective`) while
//!   feeding the drift ledger against the modeled twin.
//! * [`metrics`] — throughput counters and TTFT/ITL histograms.
//! * [`faults`] — chaos hardening: deterministic fault plans (crashes,
//!   stalls, KV-pool pressure), replica failover with KV recompute and
//!   phantom-prefix-hit prevention, and SLO-aware graceful degradation
//!   (f16 → kv8 → kv4 admission ladder before rejection).

// Robustness ramp (ISSUE 9): serving hot paths surface descriptive
// `Result` errors instead of panicking. New coordinator code must not
// introduce bare `unwrap()`; tests opt out locally.
#![warn(clippy::unwrap_used)]

pub mod batcher;
pub mod engine;
pub mod faults;
pub mod kv_cache;
pub mod measured;
pub mod metrics;
pub mod prefix;
pub mod request;
pub mod router;
pub mod sampler;
pub mod simserve;

pub use batcher::{
    Batcher, ChunkPolicy, ContinuousScheduler, PrefillChunk, SchedSeq, SchedSeqId, SchedState,
    StepBatch, StepPlan,
};
pub use engine::{Completion, Engine, EngineConfig};
pub use faults::{
    run_chaos, ChaosPolicy, ChaosResult, FaultEvent, FaultKind, FaultPlan, Outcome, RejectReason,
    Scenario, ShedPolicy, SloSpec,
};
pub use kv_cache::{blocks_for_device, KvBlockManager};
pub use measured::{
    measured_bursty, measured_shared_prefix, MeasuredEngine, MeasuredStats, MEASURED_ATTN_CTX,
};
pub use metrics::{EngineMetrics, Histogram};
pub use prefix::{chain_hash, BlockHash, PrefixCache, PrefixIndex, PrefixStats, ROOT_HASH};
pub use request::{FinishReason, GenerationRequest, SeqState, Sequence};
pub use router::{prefix_key, DrainedLoad, Health, Policy, RouteDecision, Router};
pub use simserve::{
    simulate_continuous, simulate_continuous_measured, simulate_serving, simulate_static_wave,
    simulate_static_wave_measured, simulate_tp, simulate_tp_measured, ContinuousPolicy,
    ContinuousResult, MeasuredRun, SimPolicy, SimResult,
};
