//! Automatic prefix caching: content-addressed KV blocks + radix-trie index.
//!
//! vLLM-style automatic prefix cache over the paged [`KvBlockManager`]:
//!
//! * every **full** KV block is content-addressed by a hash chained over
//!   its token ids and all preceding block hashes ([`chain_hash`]) — two
//!   sequences that share a token prefix share the same block-hash chain;
//! * a block-granular radix trie ([`PrefixIndex`]) maps token prefixes to
//!   cached block chains (one trie node per full block, children keyed by
//!   the chained hash, longest-prefix matching at block granularity);
//! * unreferenced cached blocks stay resident as *evictable idle* capacity
//!   and are reclaimed leaf-first in LRU order when admission or decode
//!   needs free blocks.
//!
//! [`PrefixCache`] couples the index to the block manager's refcounted
//! copy-on-write ownership: admission leases matched blocks (refcount++),
//! skipping prefill compute for those tokens; registration publishes a
//! sequence's sealed full blocks; release keeps them warm for the next
//! request with the same prefix (system prompts, multi-turn chat,
//! few-shot templates — the dominant pattern in the "millions of users"
//! serving regime the ROADMAP targets).

use std::collections::{HashMap, HashSet};
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::obs::{Counter, Registry};

use super::kv_cache::{KvBlockManager, SeqId};

/// Registry mirrors of [`PrefixStats`], resolved once. The per-cache
/// struct stays the source of truth for reports; the registry view is
/// what `report obs` and trace consumers see process-wide.
struct PrefixMetrics {
    hits: Counter,
    misses: Counter,
    tokens_skipped: Counter,
    evictions: Counter,
    registered_blocks: Counter,
}

fn prefix_metrics() -> &'static PrefixMetrics {
    static METRICS: OnceLock<PrefixMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        PrefixMetrics {
            hits: r.counter("prefix.hits"),
            misses: r.counter("prefix.misses"),
            tokens_skipped: r.counter("prefix.tokens_skipped"),
            evictions: r.counter("prefix.evictions"),
            registered_blocks: r.counter("prefix.registered_blocks"),
        }
    })
}

/// Chained content hash of a full KV block.
pub type BlockHash = u64;

/// Hash-chain seed for the empty prefix.
pub const ROOT_HASH: BlockHash = 0x9E37_79B9_7F4A_7C15;

/// Extend the hash chain `parent` with one block's token ids.
///
/// FNV-style fold plus a SplitMix64 finalizer so chained states stay
/// decorrelated; collisions are additionally guarded by comparing the
/// stored token ids on every trie hit.
pub fn chain_hash(parent: BlockHash, tokens: &[i32]) -> BlockHash {
    let mut h = parent ^ 0xA076_1D64_78BD_642F;
    for &t in tokens {
        h = (h ^ (t as u32 as u64)).wrapping_mul(0x1_0000_01B3);
        h ^= h >> 29;
    }
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// One matched block of a cached prefix chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixMatch {
    pub hash: BlockHash,
    /// Physical block id (or an engine-side handle) holding the KV data.
    pub block: u32,
}

/// One trie node = one full cached block.
#[derive(Debug)]
struct Node {
    hash: BlockHash,
    parent: Option<u32>,
    /// The block's token ids (exactly `block_size`) — collision guard and
    /// the trie edge label.
    tokens: Vec<i32>,
    block: u32,
    /// Number of child nodes; only leaves (0) are evictable.
    children: u32,
    /// Logical LRU tick of the last match/insert touching this node.
    last_used: u64,
}

/// Block-granular radix trie over token prefixes.
///
/// Nodes live in a slab (`slots`) with a free list; `by_hash` gives O(1)
/// chain walking, the parent/children links give leaf-first eviction.
#[derive(Debug)]
pub struct PrefixIndex {
    block_size: usize,
    slots: Vec<Option<Node>>,
    free_slots: Vec<u32>,
    by_hash: HashMap<BlockHash, u32>,
    tick: u64,
}

impl PrefixIndex {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0);
        PrefixIndex {
            block_size,
            slots: Vec::new(),
            free_slots: Vec::new(),
            by_hash: HashMap::new(),
            tick: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Number of cached blocks in the index.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free_slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Walk the cached chain for `tokens` with no LRU side effects.
    fn walk_prefix(&self, tokens: &[i32]) -> Vec<(u32, PrefixMatch)> {
        let bs = self.block_size;
        let max_blocks = tokens.len().saturating_sub(1) / bs;
        let mut out = Vec::new();
        let mut h = ROOT_HASH;
        for i in 0..max_blocks {
            let chunk = &tokens[i * bs..(i + 1) * bs];
            let next = chain_hash(h, chunk);
            let Some(&slot) = self.by_hash.get(&next) else { break };
            let node = self.slots[slot as usize].as_ref().expect("hash maps to live node");
            if node.tokens != chunk {
                break; // 64-bit collision: treat as a miss
            }
            out.push((slot, PrefixMatch { hash: next, block: node.block }));
            h = next;
        }
        out
    }

    fn touch(&mut self, slot: u32) {
        self.tick += 1;
        self.slots[slot as usize].as_mut().expect("touched slot holds a live node").last_used =
            self.tick;
    }

    /// Longest cached prefix of `tokens`, as a chain of full blocks.
    ///
    /// Always leaves at least one token uncovered so the caller still has
    /// a token to run and produce logits from (vLLM's `- 1` rule).
    /// Touches every matched node's LRU tick.
    pub fn match_prefix(&mut self, tokens: &[i32]) -> Vec<PrefixMatch> {
        let walked = self.walk_prefix(tokens);
        let mut out = Vec::with_capacity(walked.len());
        for (slot, m) in walked {
            self.touch(slot);
            out.push(m);
        }
        out
    }

    /// Longest cached prefix length in tokens, LRU-neutral (estimation
    /// only — a request that is merely *considered* must not keep its
    /// chain artificially warm).
    pub fn match_len_tokens(&self, tokens: &[i32]) -> u64 {
        self.walk_prefix(tokens).len() as u64 * self.block_size as u64
    }

    /// Insert the full-block prefix of `tokens`, adopting the caller's
    /// physical `blocks` for chain links not already cached. Existing
    /// links are kept (first writer wins — the caller's duplicate block
    /// stays private) and LRU-touched. Returns `(chunk_index, block)` for
    /// every newly adopted block so the caller can publish its data.
    pub fn insert(&mut self, tokens: &[i32], blocks: &[u32]) -> Vec<(usize, u32)> {
        let bs = self.block_size;
        let n = (tokens.len() / bs).min(blocks.len());
        let mut out = Vec::new();
        let mut h = ROOT_HASH;
        let mut parent: Option<u32> = None;
        for i in 0..n {
            let chunk = &tokens[i * bs..(i + 1) * bs];
            let next = chain_hash(h, chunk);
            if let Some(&slot) = self.by_hash.get(&next) {
                if self.slots[slot as usize].as_ref().expect("live").tokens != chunk {
                    break; // collision: refuse to extend a divergent chain
                }
                self.tick += 1;
                self.slots[slot as usize]
                    .as_mut()
                    .expect("indexed slot holds a live node")
                    .last_used = self.tick;
                parent = Some(slot);
                h = next;
                continue;
            }
            self.tick += 1;
            let node = Node {
                hash: next,
                parent,
                tokens: chunk.to_vec(),
                block: blocks[i],
                children: 0,
                last_used: self.tick,
            };
            let slot = match self.free_slots.pop() {
                Some(s) => {
                    self.slots[s as usize] = Some(node);
                    s
                }
                None => {
                    self.slots.push(Some(node));
                    (self.slots.len() - 1) as u32
                }
            };
            if let Some(p) = parent {
                self.slots[p as usize]
                    .as_mut()
                    .expect("parent slot holds a live node")
                    .children += 1;
            }
            self.by_hash.insert(next, slot);
            out.push((i, blocks[i]));
            parent = Some(slot);
            h = next;
        }
        out
    }

    /// Evict the least-recently-used *leaf* whose block passes `can_evict`;
    /// returns the freed block. Interior nodes become evictable once their
    /// children are gone (leaf-first, vLLM-style).
    pub fn evict_lru(&mut self, can_evict: impl Fn(u32) -> bool) -> Option<u32> {
        self.evict_lru_many(1, can_evict).pop()
    }

    /// Evict up to `k` current leaves passing `can_evict`, oldest first,
    /// in one slab scan. Amortizes the scan when the caller needs many
    /// blocks (or expects to need more soon); interior nodes exposed by
    /// these removals are picked up by the next call.
    pub fn evict_lru_many(&mut self, k: usize, can_evict: impl Fn(u32) -> bool) -> Vec<u32> {
        if k == 0 {
            return Vec::new();
        }
        let mut cands: Vec<(u64, u32)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                s.as_ref()
                    .filter(|n| n.children == 0 && can_evict(n.block))
                    .map(|n| (n.last_used, i as u32))
            })
            .collect();
        cands.sort_unstable();
        cands.truncate(k);
        cands.into_iter().map(|(_, slot)| self.remove_slot(slot)).collect()
    }

    fn remove_slot(&mut self, slot: u32) -> u32 {
        let node = self.slots[slot as usize].take().expect("live");
        self.by_hash.remove(&node.hash);
        if let Some(p) = node.parent {
            if let Some(pn) = self.slots[p as usize].as_mut() {
                pn.children -= 1;
            }
        }
        self.free_slots.push(slot);
        node.block
    }

    /// Exact count of blocks reclaimable by leaf-first eviction: nodes
    /// passing `pred` with no failing descendant (a leased or protected
    /// descendant pins every ancestor until it is released).
    pub fn reclaimable_count(&self, mut pred: impl FnMut(u32) -> bool) -> u64 {
        let n = self.slots.len();
        let mut pass = vec![false; n];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(node) = s {
                pass[i] = pred(node.block);
            }
        }
        let mut pinned = vec![false; n];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(node) = s {
                if !pass[i] {
                    let mut p = node.parent;
                    while let Some(pi) = p {
                        if pinned[pi as usize] {
                            break;
                        }
                        pinned[pi as usize] = true;
                        p = self.slots[pi as usize].as_ref().and_then(|x| x.parent);
                    }
                }
            }
        }
        (0..n)
            .filter(|&i| self.slots[i].is_some() && pass[i] && !pinned[i])
            .count() as u64
    }
}

/// Cache hit/eviction counters (mirrored into `EngineMetrics` /
/// `SimResult` by the serving layers).
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    /// Requests admitted with a non-empty cached prefix.
    pub hits: u64,
    /// Requests admitted with no cached prefix.
    pub misses: u64,
    /// Prompt tokens whose prefill compute was skipped.
    pub tokens_skipped: u64,
    /// Cached blocks reclaimed to the free list.
    pub evictions: u64,
    /// Full blocks published into the index.
    pub registered_blocks: u64,
}

impl PrefixStats {
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 { 0.0 } else { self.hits as f64 / n as f64 }
    }
}

/// The prefix cache: radix-trie index + eviction policy, coupled to the
/// refcounted [`KvBlockManager`]. All block-state transitions go through
/// the manager so its ledger invariants keep holding.
#[derive(Debug)]
pub struct PrefixCache {
    index: PrefixIndex,
    enabled: bool,
    pub stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(block_size: usize, enabled: bool) -> Self {
        PrefixCache { index: PrefixIndex::new(block_size), enabled, stats: PrefixStats::default() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn index(&self) -> &PrefixIndex {
        &self.index
    }

    /// Prompt tokens the cache currently covers for this token stream,
    /// without leasing anything or touching LRU state (admission-budget
    /// estimation).
    pub fn peek_match_tokens(&self, tokens: &[i32]) -> u64 {
        if !self.enabled {
            return 0;
        }
        self.index.match_len_tokens(tokens)
    }

    /// Admit a sequence: lease the longest cached prefix (skipping its
    /// prefill), evict idle cached blocks as needed for the rest, and
    /// allocate. Returns the number of prompt tokens served from cache.
    /// Errors when the pool (free + evictable) cannot cover the request
    /// without dipping below the decode watermark.
    pub fn admit(&mut self, kv: &mut KvBlockManager, seq: SeqId, tokens: &[i32]) -> Result<u64> {
        let need_total = kv.blocks_needed(tokens.len().max(1) as u64);
        if self.enabled {
            // Walk LRU-neutrally: a request that is merely *considered*
            // (and may fail admission every round under pressure) must not
            // keep its chain warm; ticks are touched only on lease commit.
            let walked = self.index.walk_prefix(tokens);
            let protect: HashSet<u32> = walked.iter().map(|(_, m)| m.block).collect();
            let need_fresh = need_total - walked.len() as u64;
            let headroom = kv.free_blocks()
                + self.index.reclaimable_count(|b| kv.is_evictable(b) && !protect.contains(&b));
            if headroom >= need_fresh + kv.watermark_blocks()
                && self.reclaim_protected(kv, need_fresh, &protect)
            {
                let blocks: Vec<u32> = walked.iter().map(|(_, m)| m.block).collect();
                kv.allocate_shared(seq, tokens.len().max(1) as u64, &blocks)?;
                for (slot, _) in walked {
                    self.index.touch(slot);
                }
                let skipped = blocks.len() as u64 * self.index.block_size() as u64;
                if skipped > 0 {
                    self.stats.hits += 1;
                    self.stats.tokens_skipped += skipped;
                    prefix_metrics().hits.inc();
                    prefix_metrics().tokens_skipped.add(skipped);
                } else {
                    self.stats.misses += 1;
                    prefix_metrics().misses.inc();
                }
                return Ok(skipped);
            }
            // Fall through: the matched chain could not be honored (e.g.
            // every evictable block is part of it) — admit exclusively so
            // caching never admits less than the cache-off policy would.
        }
        let reclaimable = self.index.reclaimable_count(|b| kv.is_evictable(b));
        if kv.free_blocks() + reclaimable < need_total + kv.watermark_blocks() {
            bail!(
                "admission would dip below the decode watermark: need {need_total}, \
                 free {} (+{reclaimable} reclaimable), watermark {}",
                kv.free_blocks(),
                kv.watermark_blocks()
            );
        }
        self.reclaim_protected(kv, need_total, &HashSet::new());
        kv.allocate(seq, tokens.len().max(1) as u64)?;
        if self.enabled {
            self.stats.misses += 1;
            prefix_metrics().misses.inc();
        }
        Ok(0)
    }

    /// Publish a sequence's sealed full blocks into the index (content
    /// already deduplicated: chain links cached by an earlier sequence are
    /// kept and this sequence's copies stay private).
    pub fn register(&mut self, kv: &mut KvBlockManager, seq: SeqId, tokens: &[i32]) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        let bs = self.index.block_size();
        let full = kv.seal(seq)?;
        let n = full.len().min(tokens.len() / bs);
        if n == 0 {
            return Ok(());
        }
        for (_, b) in self.index.insert(&tokens[..n * bs], &full[..n]) {
            kv.mark_cached(b)?;
            self.stats.registered_blocks += 1;
            prefix_metrics().registered_blocks.inc();
        }
        Ok(())
    }

    /// Reclaim idle cached blocks until `need_free` blocks are free.
    /// Returns false if eviction ran dry first (decode then preempts, as
    /// without a cache).
    pub fn reclaim(&mut self, kv: &mut KvBlockManager, need_free: u64) -> bool {
        self.reclaim_protected(kv, need_free, &HashSet::new())
    }

    fn reclaim_protected(
        &mut self,
        kv: &mut KvBlockManager,
        need_free: u64,
        protect: &HashSet<u32>,
    ) -> bool {
        while kv.free_blocks() < need_free {
            // Evict a batch per scan: over-shooting the immediate need by
            // a few LRU blocks keeps the steady-state decode path (which
            // reclaims one block per token) off the O(index) scan.
            let want = ((need_free - kv.free_blocks()) as usize).max(32);
            let freed = self
                .index
                .evict_lru_many(want, |b| kv.is_evictable(b) && !protect.contains(&b));
            if freed.is_empty() {
                return false;
            }
            for b in freed {
                kv.evict(b).expect("evict_lru returned a non-evictable block");
                self.stats.evictions += 1;
                prefix_metrics().evictions.inc();
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn toks(lo: i32, n: usize) -> Vec<i32> {
        (lo..lo + n as i32).collect()
    }

    #[test]
    fn chain_hash_diverges_on_token_and_parent() {
        let a = chain_hash(ROOT_HASH, &[1, 2, 3, 4]);
        assert_eq!(a, chain_hash(ROOT_HASH, &[1, 2, 3, 4]));
        assert_ne!(a, chain_hash(ROOT_HASH, &[1, 2, 3, 5]));
        assert_ne!(a, chain_hash(a, &[1, 2, 3, 4]));
    }

    #[test]
    fn index_matches_inserted_prefix_and_caps_last_token() {
        let mut idx = PrefixIndex::new(4);
        let t = toks(0, 12);
        assert_eq!(idx.insert(&t, &[10, 11, 12]).len(), 3);
        // 12 tokens = 3 full blocks, but the cap leaves the last token.
        let m = idx.match_prefix(&t);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].block, 10);
        assert_eq!(m[1].block, 11);
        // 13 tokens -> all 3 blocks match.
        let mut t13 = t.clone();
        t13.push(99);
        assert_eq!(idx.match_prefix(&t13).len(), 3);
        // Divergent tail matches only the shared head.
        let mut div = toks(0, 8);
        div.extend(toks(100, 5));
        assert_eq!(idx.match_prefix(&div).len(), 2);
    }

    #[test]
    fn insert_dedups_against_existing_chain() {
        let mut idx = PrefixIndex::new(4);
        let t = toks(0, 8);
        assert_eq!(idx.insert(&t, &[1, 2]).len(), 2);
        // Same content, different physical blocks: nothing new inserted.
        assert!(idx.insert(&t, &[7, 8]).is_empty());
        // A longer chain extends past the shared head only.
        let mut t12 = t.clone();
        t12.extend(toks(50, 4));
        let newly = idx.insert(&t12, &[7, 8, 9]);
        assert_eq!(newly, vec![(2, 9)]);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn lru_evicts_leaves_first_least_recent_first() {
        let mut idx = PrefixIndex::new(4);
        let a = toks(0, 8); // chain a0 -> a1
        let b = toks(100, 4); // chain b0
        idx.insert(&a, &[1, 2]);
        idx.insert(&b, &[3]);
        // Touch chain b so chain a's leaf is the LRU leaf.
        let mut b5 = b.clone();
        b5.push(0);
        idx.match_prefix(&b5);
        // a0 has a child, so the first eviction must take leaf a1.
        assert_eq!(idx.evict_lru(|_| true), Some(2));
        assert_eq!(idx.evict_lru(|_| true), Some(1)); // now a0 is a leaf
        assert_eq!(idx.evict_lru(|_| true), Some(3));
        assert_eq!(idx.evict_lru(|_| true), None);
        assert!(idx.is_empty());
    }

    #[test]
    fn cache_admit_leases_then_register_publishes() {
        let mut kv = KvBlockManager::new(16, 4, 0.0);
        let mut c = PrefixCache::new(4, true);
        let prompt = toks(0, 9); // 3 blocks, 2 full
        assert_eq!(c.admit(&mut kv, 1, &prompt).unwrap(), 0);
        c.register(&mut kv, 1, &prompt).unwrap();
        assert_eq!(c.index().len(), 2);
        kv.free_seq(1).unwrap();
        assert_eq!(kv.cached_idle_blocks(), 2);
        // Second identical prompt leases both full blocks.
        assert_eq!(c.admit(&mut kv, 2, &prompt).unwrap(), 8);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.tokens_skipped, 8);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_admits_more_concurrent_sequences() {
        // Acceptance: a fully-shared prefix admits more concurrent
        // sequences than exclusive ownership allows at equal KV budget.
        let (total, bs) = (24u64, 16u64);
        let prefix = toks(0, 128); // 8 full blocks
        let mk = |salt: i32| {
            let mut p = prefix.clone();
            p.push(1000 + salt);
            p // 129 tokens -> 9 blocks
        };

        let mut kv = KvBlockManager::new(total, bs, 0.0);
        let mut off = PrefixCache::new(bs as usize, false);
        let mut exclusive = 0u64;
        while off.admit(&mut kv, exclusive, &mk(exclusive as i32)).is_ok() {
            exclusive += 1;
        }
        assert_eq!(exclusive, 2); // 9 blocks each, 24 total

        let mut kv = KvBlockManager::new(total, bs, 0.0);
        let mut on = PrefixCache::new(bs as usize, true);
        let mut shared = 0u64;
        loop {
            let p = mk(shared as i32);
            match on.admit(&mut kv, shared, &p) {
                Ok(_) => {
                    on.register(&mut kv, shared, &p).unwrap();
                    shared += 1;
                }
                Err(_) => break,
            }
        }
        kv.check_invariants().unwrap();
        assert!(shared > exclusive, "shared {shared} <= exclusive {exclusive}");
        assert_eq!(shared, 16); // 8 shared + 1 private tail each
    }

    #[test]
    fn eviction_reclaims_idle_blocks_for_new_admissions() {
        let mut kv = KvBlockManager::new(8, 4, 0.0);
        let mut c = PrefixCache::new(4, true);
        let a = toks(0, 17); // 5 blocks, 4 full
        c.admit(&mut kv, 1, &a).unwrap();
        c.register(&mut kv, 1, &a).unwrap();
        kv.free_seq(1).unwrap();
        assert_eq!(kv.free_blocks(), 4);
        assert_eq!(kv.cached_idle_blocks(), 4);
        // A disjoint prompt needing 6 blocks forces eviction of idle ones.
        let b = toks(500, 23);
        assert_eq!(c.admit(&mut kv, 2, &b).unwrap(), 0);
        assert!(c.stats.evictions >= 2, "evictions {}", c.stats.evictions);
        kv.check_invariants().unwrap();
    }
}
