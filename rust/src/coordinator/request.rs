//! Request and sequence lifecycle types.

use std::time::Instant;

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its max_new_tokens budget.
    Length,
    /// Produced the EOS token.
    Eos,
    /// Evicted under memory pressure (resubmitted by the scheduler).
    Preempted,
    /// Rejected at admission (queue full / prompt too long).
    Rejected,
}

/// Client-visible request parameters.
#[derive(Debug, Clone)]
pub struct GenerationRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy if None, else sample with this temperature (tiny engine uses
    /// greedy; the field keeps the API honest).
    pub temperature: Option<f32>,
    pub eos_token: Option<i32>,
}

/// Server-side state of one sequence.
#[derive(Debug)]
pub struct Sequence {
    pub req: GenerationRequest,
    /// All tokens: prompt followed by generated.
    pub tokens: Vec<i32>,
    pub generated: usize,
    /// Prompt tokens whose KV has been computed (or leased from the
    /// prefix cache). A sequence with `prefilled < prompt.len()` is
    /// mid-chunked-prefill: its remaining prompt tokens ride mixed decode
    /// steps one per step until the prompt completes.
    pub prefilled: usize,
    /// Prompt tokens served from the automatic prefix cache at prefill
    /// (their KV was reused, so their prefill compute was skipped).
    pub cached_prefix_tokens: usize,
    pub state: SeqState,
    pub enqueued_at: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    pub finish: Option<FinishReason>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Waiting,
    /// Prefill done, decoding in lane `lane`.
    Running { lane: usize },
    Finished,
}

impl Sequence {
    pub fn new(req: GenerationRequest) -> Self {
        let tokens = req.prompt.clone();
        Sequence {
            req,
            tokens,
            generated: 0,
            prefilled: 0,
            cached_prefix_tokens: 0,
            state: SeqState::Waiting,
            enqueued_at: Instant::now(),
            first_token_at: None,
            finished_at: None,
            finish: None,
        }
    }

    /// Current position of the *next* token to be written (also the
    /// attention context length so far).
    pub fn pos(&self) -> usize {
        self.tokens.len()
    }

    /// Prompt tokens that still need prefill compute (total minus the
    /// cached prefix).
    pub fn uncached_prompt_tokens(&self) -> usize {
        self.req.prompt.len() - self.cached_prefix_tokens.min(self.req.prompt.len())
    }

    /// Still computing its prompt: the next mixed decode step should feed
    /// `prompt[prefilled]` instead of the last generated token.
    pub fn in_prefill(&self) -> bool {
        self.prefilled < self.req.prompt.len()
    }

    /// The prompt token a mixed step should teacher-force next.
    pub fn next_prefill_token(&self) -> i32 {
        debug_assert!(self.in_prefill());
        self.req.prompt[self.prefilled]
    }

    pub fn last_token(&self) -> i32 {
        *self.tokens.last().expect("sequence has no tokens")
    }

    pub fn push_generated(&mut self, tok: i32) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.tokens.push(tok);
        self.generated += 1;
    }

    pub fn should_stop(&self) -> Option<FinishReason> {
        if self.generated >= self.req.max_new_tokens {
            return Some(FinishReason::Length);
        }
        if let Some(eos) = self.req.eos_token {
            if self.generated > 0 && self.last_token() == eos {
                return Some(FinishReason::Eos);
            }
        }
        None
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = SeqState::Finished;
        self.finish = Some(reason);
        self.finished_at = Some(Instant::now());
    }

    pub fn output_tokens(&self) -> &[i32] {
        &self.tokens[self.req.prompt.len()..]
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    fn req(prompt: usize, max_new: usize, eos: Option<i32>) -> GenerationRequest {
        GenerationRequest {
            id: 1,
            prompt: (0..prompt as i32).collect(),
            max_new_tokens: max_new,
            temperature: None,
            eos_token: eos,
        }
    }

    #[test]
    fn lifecycle_and_outputs() {
        let mut s = Sequence::new(req(3, 2, None));
        assert_eq!(s.pos(), 3);
        assert!(s.should_stop().is_none());
        s.push_generated(7);
        assert!(s.first_token_at.is_some());
        assert!(s.should_stop().is_none());
        s.push_generated(9);
        assert_eq!(s.should_stop(), Some(FinishReason::Length));
        assert_eq!(s.output_tokens(), &[7, 9]);
    }

    #[test]
    fn chunked_prefill_progress() {
        let mut s = Sequence::new(req(5, 2, None));
        assert!(s.in_prefill());
        for i in 0..5 {
            assert_eq!(s.next_prefill_token(), i as i32);
            s.prefilled += 1;
        }
        assert!(!s.in_prefill());
        s.push_generated(9);
        assert_eq!(s.generated, 1);
    }

    #[test]
    fn eos_stops_early() {
        let mut s = Sequence::new(req(2, 10, Some(0)));
        s.push_generated(5);
        assert!(s.should_stop().is_none());
        s.push_generated(0);
        assert_eq!(s.should_stop(), Some(FinishReason::Eos));
    }

    #[test]
    fn eos_in_prompt_does_not_stop() {
        let s = Sequence::new(GenerationRequest {
            id: 1,
            prompt: vec![0, 0],
            max_new_tokens: 4,
            temperature: None,
            eos_token: Some(0),
        });
        assert!(s.should_stop().is_none());
    }
}
