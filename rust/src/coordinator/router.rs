//! Request router: spreads incoming requests across engine replicas
//! (vLLM-router-shaped front end for multi-GPU or multi-process serving).
//!
//! The router is deliberately engine-agnostic: replicas are registered
//! with a capacity hint and report load through [`RouterHandle::on_admit`]
//! / [`RouterHandle::on_finish`]; policies act on the tracked load.
//! The real [`super::engine::Engine`] and the Table-1 simulator both fit
//! behind this interface (see `examples/serve_e2e.rs` for single-replica
//! use; `router` tests exercise multi-replica balancing).

use anyhow::{bail, Result};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Cycle through replicas regardless of load.
    RoundRobin,
    /// Pick the replica with the fewest in-flight tokens (prompt +
    /// expected generation), tie-broken by index.
    LeastLoaded,
    /// Prefix-affinity hashing: requests with the same session key land on
    /// the same replica (KV reuse), falling back to least-loaded when the
    /// preferred replica is saturated.
    SessionAffinity,
    /// Content-aware affinity: route on the chained hash of the first
    /// prompt block (see [`prefix_key`]) so requests sharing a prompt
    /// prefix land on the replica whose automatic prefix cache
    /// (`coordinator::prefix`) already holds its KV blocks. Same spill
    /// behavior as [`Policy::SessionAffinity`].
    PrefixAware,
    /// Tensor-parallel placement: replicas are the *ranks* of contiguous
    /// TP groups of [`Router::tp_degree`] members (group `g` = replicas
    /// `g*tp .. (g+1)*tp`). A request is routed to the least-loaded group
    /// with room on **every** rank and occupies all of them — a TP step
    /// runs on all ranks in lockstep, so load, capacity, and health are
    /// tracked group-wide. Construct the router with [`Router::new_tp`].
    TpGroup,
}

/// Routing key for [`Policy::PrefixAware`]: the content hash of the first
/// prompt block, chained from the root exactly like the prefix index does,
/// so router placement and cache lookup agree on what "same prefix" means.
pub fn prefix_key(prompt: &[i32], block_size: usize) -> u64 {
    let take = prompt.len().min(block_size.max(1));
    super::prefix::chain_hash(super::prefix::ROOT_HASH, &prompt[..take])
}

/// Replica health in the unhealthy → probing → healthy state machine.
///
/// A replica marked down ([`Router::mark_down`]) takes no traffic until
/// the operator (or the fault injector's recovery event) moves it to
/// [`Health::Probing`] via [`Router::begin_probe`]. A probing replica
/// accepts **one** request at a time; each completion reported through
/// [`Router::probe_result`] counts toward the configured success bar
/// ([`Router::with_probe_successes`]), after which the replica is fully
/// [`Health::Healthy`] again. A failed probe sends it back to
/// [`Health::Unhealthy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Routable at full capacity.
    Healthy,
    /// Not routable; in-flight accounting was drained on entry.
    Unhealthy,
    /// Routable with a single canary request in flight.
    Probing,
}

/// In-flight load drained off a replica by [`Router::mark_down`]: the
/// caller is responsible for requeueing these requests elsewhere (the
/// KV they accumulated on the dead replica is gone — the recompute
/// preemption path re-prefills them on the new placement).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrainedLoad {
    /// Requests that were in flight on the drained replica (or group).
    pub reqs: u64,
    /// Token load those requests carried.
    pub tokens: u64,
}

/// Tracked state of one replica.
#[derive(Debug, Clone)]
struct Replica {
    /// In-flight token load (prompt + max_new of admitted requests).
    inflight_tokens: u64,
    /// In-flight request count.
    inflight_reqs: u64,
    /// Admission cap: max in-flight requests (0 = unlimited).
    max_reqs: u64,
    health: Health,
    /// Successful probe completions since entering [`Health::Probing`].
    probe_ok: u32,
}

/// The router.
#[derive(Debug)]
pub struct Router {
    policy: Policy,
    replicas: Vec<Replica>,
    /// Ranks per TP group ([`Policy::TpGroup`] only; 1 otherwise).
    tp_degree: usize,
    rr_next: usize,
    /// Probe completions required to graduate Probing → Healthy.
    probe_successes: u32,
    /// Requests successfully placed.
    pub routed: u64,
    /// Requests turned away (no replica/group with room).
    pub rejected: u64,
    /// Requests drained off replicas marked down mid-flight.
    pub drained: u64,
}

/// Admission ticket: which replica got the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub replica: usize,
}

impl Router {
    /// Build a router over `replica_caps.len()` independent replicas
    /// (`cap = 0` means unlimited in-flight requests).
    pub fn new(policy: Policy, replica_caps: &[u64]) -> Result<Self> {
        Self::new_tp(policy, replica_caps, 1)
    }

    /// Build a router whose replicas are the ranks of `tp_degree`-way
    /// tensor-parallel groups (required for [`Policy::TpGroup`]; other
    /// policies ignore the grouping). The replica count must be a
    /// positive multiple of `tp_degree`.
    pub fn new_tp(policy: Policy, replica_caps: &[u64], tp_degree: usize) -> Result<Self> {
        if replica_caps.is_empty() {
            bail!("router needs at least one replica");
        }
        if tp_degree == 0 {
            bail!("tp_degree must be >= 1");
        }
        if replica_caps.len() % tp_degree != 0 {
            bail!(
                "{} replicas do not form whole {}-way TP groups",
                replica_caps.len(),
                tp_degree
            );
        }
        Ok(Router {
            policy,
            replicas: replica_caps
                .iter()
                .map(|&cap| Replica {
                    inflight_tokens: 0,
                    inflight_reqs: 0,
                    max_reqs: cap,
                    health: Health::Healthy,
                    probe_ok: 0,
                })
                .collect(),
            tp_degree,
            rr_next: 0,
            probe_successes: 1,
            routed: 0,
            rejected: 0,
            drained: 0,
        })
    }

    /// Probe completions a recovering replica must serve before it is
    /// fully routable again (default 1).
    pub fn with_probe_successes(mut self, n: u32) -> Self {
        self.probe_successes = n.max(1);
        self
    }

    /// Replica (rank) count.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Ranks per TP group (1 unless built with [`Router::new_tp`]).
    pub fn tp_degree(&self) -> usize {
        self.tp_degree
    }

    /// Mark a replica (and therefore its whole TP group under
    /// [`Policy::TpGroup`]) routable or not. Taking a replica down
    /// **drains** its in-flight accounting — see [`Router::mark_down`],
    /// which this delegates to — so a replica that dies mid-flight does
    /// not stay "loaded" forever. Bringing it up skips the probe ramp
    /// and restores full health immediately.
    pub fn set_healthy(&mut self, replica: usize, healthy: bool) {
        if healthy {
            for i in self.affected_ranks(replica) {
                self.replicas[i].health = Health::Healthy;
                self.replicas[i].probe_ok = 0;
            }
        } else {
            let _ = self.mark_down(replica);
        }
    }

    /// A replica's current health state.
    pub fn health(&self, replica: usize) -> Health {
        self.replicas[replica].health
    }

    /// Take a replica out of rotation (its whole TP group under
    /// [`Policy::TpGroup`]) and drain its in-flight accounting. Returns
    /// the load that was in flight so the caller can requeue those
    /// requests on healthy replicas; their route decisions are dead —
    /// a later [`Router::on_finish`] against one is a harmless no-op
    /// (counters saturate at zero).
    pub fn mark_down(&mut self, replica: usize) -> DrainedLoad {
        let mut drained = DrainedLoad::default();
        for i in self.affected_ranks(replica) {
            let r = &mut self.replicas[i];
            drained.reqs = drained.reqs.max(r.inflight_reqs);
            drained.tokens = drained.tokens.max(r.inflight_tokens);
            r.inflight_reqs = 0;
            r.inflight_tokens = 0;
            r.health = Health::Unhealthy;
            r.probe_ok = 0;
        }
        self.drained += drained.reqs;
        drained
    }

    /// Move an unhealthy replica (group) into the probing state: it may
    /// take one canary request at a time until [`Router::probe_result`]
    /// reports enough successes. No-op unless currently unhealthy.
    pub fn begin_probe(&mut self, replica: usize) {
        for i in self.affected_ranks(replica) {
            if self.replicas[i].health == Health::Unhealthy {
                self.replicas[i].health = Health::Probing;
                self.replicas[i].probe_ok = 0;
            }
        }
    }

    /// Report the outcome of a request served by a probing replica. A
    /// success counts toward the configured bar
    /// ([`Router::with_probe_successes`]); reaching it graduates the
    /// replica (group) to [`Health::Healthy`]. A failure sends it back
    /// to [`Health::Unhealthy`] (and re-drains anything in flight).
    pub fn probe_result(&mut self, replica: usize, ok: bool) {
        if self.replicas[replica].health != Health::Probing {
            return;
        }
        if !ok {
            let _ = self.mark_down(replica);
            return;
        }
        let bar = self.probe_successes;
        let mut graduated = false;
        for i in self.affected_ranks(replica) {
            let r = &mut self.replicas[i];
            r.probe_ok += 1;
            if r.probe_ok >= bar {
                graduated = true;
            }
        }
        if graduated {
            for i in self.affected_ranks(replica) {
                self.replicas[i].health = Health::Healthy;
                self.replicas[i].probe_ok = 0;
            }
        }
    }

    /// The ranks of the TP group containing `replica`.
    fn group_of(&self, replica: usize) -> std::ops::Range<usize> {
        let g = replica / self.tp_degree;
        g * self.tp_degree..(g + 1) * self.tp_degree
    }

    /// Ranks a health transition touches: the whole TP group under
    /// [`Policy::TpGroup`] (a group steps in lockstep, so one sick rank
    /// takes all of them out), the single replica otherwise.
    fn affected_ranks(&self, replica: usize) -> std::ops::Range<usize> {
        if self.policy == Policy::TpGroup {
            self.group_of(replica)
        } else {
            replica..replica + 1
        }
    }

    fn has_room(&self, i: usize) -> bool {
        let r = &self.replicas[i];
        match r.health {
            Health::Healthy => r.max_reqs == 0 || r.inflight_reqs < r.max_reqs,
            // One canary in flight at a time while probing.
            Health::Probing => r.inflight_reqs == 0,
            Health::Unhealthy => false,
        }
    }

    /// Route one request of `tokens` total work (prompt + max_new).
    /// `session` keys affinity routing (ignored by other policies).
    pub fn route(&mut self, tokens: u64, session: Option<u64>) -> Option<RouteDecision> {
        let n = self.replicas.len();
        let pick = match self.policy {
            Policy::RoundRobin => {
                let mut chosen = None;
                for off in 0..n {
                    let i = (self.rr_next + off) % n;
                    if self.has_room(i) {
                        chosen = Some(i);
                        self.rr_next = (i + 1) % n;
                        break;
                    }
                }
                chosen
            }
            Policy::LeastLoaded => self.least_loaded(),
            // PrefixAware is SessionAffinity with a content-derived key:
            // callers pass `prefix_key(prompt, block_size)` as `session`.
            Policy::SessionAffinity | Policy::PrefixAware => {
                let preferred = session.map(|s| (s as usize) % n);
                match preferred {
                    Some(p) if self.has_room(p) => Some(p),
                    _ => self.least_loaded(),
                }
            }
            // Least-loaded over whole groups; the decision names the
            // group's lead rank.
            Policy::TpGroup => self.least_loaded_group(),
        };
        match pick {
            Some(i) => {
                // Under TpGroup the request runs on every rank of the
                // group (activations are replicated, weights sharded), so
                // each rank carries the full token load.
                let targets = if self.policy == Policy::TpGroup {
                    self.group_of(i)
                } else {
                    i..i + 1
                };
                for r in targets {
                    self.replicas[r].inflight_tokens += tokens;
                    self.replicas[r].inflight_reqs += 1;
                }
                self.routed += 1;
                Some(RouteDecision { replica: i })
            }
            None => {
                self.rejected += 1;
                None
            }
        }
    }

    /// Lead rank of the least-loaded TP group with room on every rank.
    fn least_loaded_group(&self) -> Option<usize> {
        let g = self.tp_degree;
        (0..self.replicas.len() / g)
            .filter(|&gi| (gi * g..(gi + 1) * g).all(|i| self.has_room(i)))
            .min_by_key(|&gi| {
                let load: u64 =
                    (gi * g..(gi + 1) * g).map(|i| self.replicas[i].inflight_tokens).sum();
                (load, gi)
            })
            .map(|gi| gi * g)
    }

    fn least_loaded(&self) -> Option<usize> {
        (0..self.replicas.len())
            .filter(|&i| self.has_room(i))
            .min_by_key(|&i| (self.replicas[i].inflight_tokens, i))
    }

    /// Report request completion so load tracking stays truthful (under
    /// [`Policy::TpGroup`] the whole group is released).
    pub fn on_finish(&mut self, d: RouteDecision, tokens: u64) {
        let targets = if self.policy == Policy::TpGroup {
            self.group_of(d.replica)
        } else {
            d.replica..d.replica + 1
        };
        for i in targets {
            let r = &mut self.replicas[i];
            r.inflight_tokens = r.inflight_tokens.saturating_sub(tokens);
            r.inflight_reqs = r.inflight_reqs.saturating_sub(1);
        }
    }

    pub fn inflight(&self, replica: usize) -> (u64, u64) {
        let r = &self.replicas[replica];
        (r.inflight_reqs, r.inflight_tokens)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(Policy::RoundRobin, &[0, 0, 0]).unwrap();
        let seq: Vec<usize> =
            (0..6).map(|_| r.route(10, None).unwrap().replica).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_uneven_work() {
        let mut r = Router::new(Policy::LeastLoaded, &[0, 0]).unwrap();
        let d0 = r.route(1000, None).unwrap(); // heavy -> replica 0
        assert_eq!(d0.replica, 0);
        // next several light requests should all avoid the loaded replica
        for _ in 0..3 {
            assert_eq!(r.route(10, None).unwrap().replica, 1);
        }
        // until replica 1 accumulates more load
        assert_eq!(r.inflight(1).0, 3);
        r.on_finish(d0, 1000);
        assert_eq!(r.route(10, None).unwrap().replica, 0);
    }

    #[test]
    fn capacity_caps_admission() {
        let mut r = Router::new(Policy::RoundRobin, &[1, 1]).unwrap();
        assert!(r.route(5, None).is_some());
        assert!(r.route(5, None).is_some());
        assert!(r.route(5, None).is_none(), "both replicas full");
        assert_eq!(r.rejected, 1);
    }

    #[test]
    fn unhealthy_replica_skipped() {
        let mut r = Router::new(Policy::RoundRobin, &[0, 0]).unwrap();
        r.set_healthy(0, false);
        for _ in 0..4 {
            assert_eq!(r.route(1, None).unwrap().replica, 1);
        }
        r.set_healthy(0, true);
        assert_eq!(r.route(1, None).unwrap().replica, 0);
    }

    #[test]
    fn session_affinity_sticks_then_spills() {
        let mut r = Router::new(Policy::SessionAffinity, &[2, 2]).unwrap();
        let s = Some(7u64); // 7 % 2 = replica 1
        assert_eq!(r.route(1, s).unwrap().replica, 1);
        assert_eq!(r.route(1, s).unwrap().replica, 1);
        // replica 1 now at cap -> spill to least-loaded (0)
        assert_eq!(r.route(1, s).unwrap().replica, 0);
    }

    #[test]
    fn finish_releases_load() {
        let mut r = Router::new(Policy::LeastLoaded, &[0]).unwrap();
        let d = r.route(500, None).unwrap();
        assert_eq!(r.inflight(0), (1, 500));
        r.on_finish(d, 500);
        assert_eq!(r.inflight(0), (0, 0));
    }

    #[test]
    fn zero_replicas_rejected() {
        assert!(Router::new(Policy::RoundRobin, &[]).is_err());
    }

    #[test]
    fn prefix_aware_groups_shared_first_block() {
        let mut r = Router::new(Policy::PrefixAware, &[0, 0, 0, 0]).unwrap();
        let bs = 16usize;
        // Same system prompt, divergent tails: identical first block.
        let shared: Vec<i32> = (0..24).collect();
        let mut a = shared.clone();
        a.extend([900, 901]);
        let mut b = shared.clone();
        b.extend([700, 701, 702]);
        let key = prefix_key(&shared, bs);
        assert_eq!(prefix_key(&a, bs), key, "tail must not change the key");
        assert_eq!(prefix_key(&b, bs), key);
        let want = (key as usize) % 4;
        assert_eq!(r.route(10, Some(prefix_key(&a, bs))).unwrap().replica, want);
        assert_eq!(r.route(10, Some(prefix_key(&b, bs))).unwrap().replica, want);
        // A different opening block routes by its own hash.
        let other: Vec<i32> = (500..540).collect();
        let other_want = (prefix_key(&other, bs) as usize) % 4;
        assert_eq!(
            r.route(10, Some(prefix_key(&other, bs))).unwrap().replica,
            other_want
        );
        assert_ne!(prefix_key(&other, bs), key);
    }

    #[test]
    fn tp_group_occupies_every_rank() {
        // 4 ranks = two 2-way TP groups; a request lands on a whole group.
        let mut r = Router::new_tp(Policy::TpGroup, &[0, 0, 0, 0], 2).unwrap();
        let d0 = r.route(100, None).unwrap();
        assert_eq!(d0.replica, 0, "empty router picks group 0's lead rank");
        assert_eq!(r.inflight(0), (1, 100));
        assert_eq!(r.inflight(1), (1, 100), "both ranks of the group are loaded");
        assert_eq!(r.inflight(2), (0, 0));
        // Next request goes to the now-lighter group 1.
        let d1 = r.route(10, None).unwrap();
        assert_eq!(d1.replica, 2);
        assert_eq!(r.inflight(3), (1, 10));
        // Finish releases the whole group.
        r.on_finish(d0, 100);
        assert_eq!(r.inflight(0), (0, 0));
        assert_eq!(r.inflight(1), (0, 0));
    }

    #[test]
    fn tp_group_balances_by_group_load() {
        let mut r = Router::new_tp(Policy::TpGroup, &[0; 8], 4).unwrap();
        let heavy = r.route(1000, None).unwrap();
        assert_eq!(heavy.replica, 0);
        for _ in 0..3 {
            assert_eq!(r.route(10, None).unwrap().replica, 4, "light work avoids group 0");
        }
        r.on_finish(heavy, 1000);
        assert_eq!(r.route(10, None).unwrap().replica, 0);
    }

    #[test]
    fn tp_group_skips_groups_with_a_sick_rank() {
        let mut r = Router::new_tp(Policy::TpGroup, &[0, 0, 0, 0], 2).unwrap();
        r.set_healthy(1, false); // one rank down takes the whole group out
        for _ in 0..3 {
            assert_eq!(r.route(1, None).unwrap().replica, 2);
        }
        r.set_healthy(1, true);
        assert_eq!(r.route(1, None).unwrap().replica, 0);
    }

    #[test]
    fn tp_group_capacity_is_per_rank() {
        let mut r = Router::new_tp(Policy::TpGroup, &[1, 1], 2).unwrap();
        assert!(r.route(5, None).is_some());
        assert!(r.route(5, None).is_none(), "every rank at cap: group full");
        assert_eq!(r.rejected, 1);
    }

    #[test]
    fn ragged_tp_grouping_rejected() {
        assert!(Router::new_tp(Policy::TpGroup, &[0, 0, 0], 2).is_err());
        assert!(Router::new_tp(Policy::TpGroup, &[0, 0], 0).is_err());
    }

    #[test]
    fn mark_down_drains_inflight_accounting() {
        // Regression: a replica marked unhealthy mid-flight used to keep
        // its inflight_reqs counted forever, so it looked loaded (or at
        // cap) even after recovery.
        let mut r = Router::new(Policy::LeastLoaded, &[2, 2]).unwrap();
        let d0 = r.route(100, None).unwrap();
        let d1 = r.route(100, None).unwrap();
        assert_eq!((d0.replica, d1.replica), (0, 1));
        let drained = r.mark_down(0);
        assert_eq!(drained, DrainedLoad { reqs: 1, tokens: 100 });
        assert_eq!(r.inflight(0), (0, 0), "accounting drained, not leaked");
        assert_eq!(r.health(0), Health::Unhealthy);
        assert_eq!(r.drained, 1);
        // A stale on_finish against the drained replica is a no-op.
        r.on_finish(d0, 100);
        assert_eq!(r.inflight(0), (0, 0));
    }

    #[test]
    fn recovered_replica_is_dispatchable_again() {
        let mut r = Router::new(Policy::RoundRobin, &[1, 0]).unwrap();
        let _ = r.route(10, None).unwrap(); // replica 0 at cap 1
        r.mark_down(0);
        // While down, everything lands on replica 1.
        for _ in 0..3 {
            assert_eq!(r.route(10, None).unwrap().replica, 1);
        }
        r.set_healthy(0, true);
        assert_eq!(r.health(0), Health::Healthy);
        // The drained slot freed the cap: replica 0 takes traffic again.
        assert_eq!(r.route(10, None).unwrap().replica, 0);
    }

    #[test]
    fn probe_ramp_graduates_after_configured_successes() {
        let mut r = Router::new(Policy::LeastLoaded, &[0, 0])
            .unwrap()
            .with_probe_successes(2);
        let _ = r.route(50, None).unwrap();
        r.mark_down(0);
        assert!(r.route(1, None).unwrap().replica == 1, "down replica skipped");
        r.begin_probe(0);
        assert_eq!(r.health(0), Health::Probing);
        // Probing admits one canary at a time even though cap is
        // unlimited; least-loaded prefers the empty probing replica.
        let probe1 = r.route(1, None).unwrap();
        assert_eq!(probe1.replica, 0);
        assert!(
            r.route(1, None).unwrap().replica == 1,
            "second request must not pile onto the probing replica"
        );
        r.on_finish(probe1, 1);
        r.probe_result(0, true);
        assert_eq!(r.health(0), Health::Probing, "one success of two");
        let probe2 = r.route(1, None).unwrap();
        assert_eq!(probe2.replica, 0);
        r.on_finish(probe2, 1);
        r.probe_result(0, true);
        assert_eq!(r.health(0), Health::Healthy, "graduated after 2 successes");
    }

    #[test]
    fn failed_probe_returns_to_unhealthy() {
        let mut r = Router::new(Policy::RoundRobin, &[0, 0]).unwrap();
        r.mark_down(0);
        r.begin_probe(0);
        let d = r.route(5, None).unwrap();
        assert_eq!(d.replica, 0);
        r.probe_result(0, false);
        assert_eq!(r.health(0), Health::Unhealthy);
        assert_eq!(r.inflight(0), (0, 0), "failed probe re-drains");
        // begin_probe is a no-op on healthy replicas.
        r.begin_probe(1);
        assert_eq!(r.health(1), Health::Healthy);
    }

    #[test]
    fn tp_group_mark_down_drains_every_rank() {
        let mut r = Router::new_tp(Policy::TpGroup, &[0, 0, 0, 0], 2).unwrap();
        let d = r.route(100, None).unwrap();
        assert_eq!(d.replica, 0);
        let drained = r.mark_down(1); // any rank takes the group down
        assert_eq!(drained, DrainedLoad { reqs: 1, tokens: 100 });
        assert_eq!(r.inflight(0), (0, 0));
        assert_eq!(r.inflight(1), (0, 0));
        assert_eq!(r.health(0), Health::Unhealthy);
        assert_eq!(r.route(10, None).unwrap().replica, 2);
    }

    #[test]
    fn prefix_aware_spills_when_preferred_replica_full() {
        let mut r = Router::new(Policy::PrefixAware, &[1, 1]).unwrap();
        let key = prefix_key(&[1, 2, 3, 4], 4);
        let first = r.route(5, Some(key)).unwrap().replica;
        assert_eq!(first, (key as usize) % 2);
        // Preferred replica is at cap: spill to the other one.
        let second = r.route(5, Some(key)).unwrap().replica;
        assert_eq!(second, 1 - first);
    }
}
