//! Token sampling: greedy argmax and seeded temperature sampling over the
//! logits rows the engine gets back from PJRT.

use crate::util::rng::Rng;

/// Greedy argmax (temperature None / 0).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Sample from softmax(logits / temperature) using the provided RNG.
/// Numerically stable (max-subtracted); temperature must be > 0.
pub fn sample_temperature(logits: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    assert!(temperature > 0.0, "temperature must be positive");
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - max) / temperature) as f64).exp())
        .collect();
    let sum: f64 = probs.iter().sum();
    for p in &mut probs {
        *p /= sum;
    }
    let mut u = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        if u < p {
            return i as i32;
        }
        u -= p;
    }
    (probs.len() - 1) as i32
}

/// Dispatch on the request's temperature setting.
pub fn sample(logits: &[f32], temperature: Option<f32>, rng: &mut Rng) -> i32 {
    match temperature {
        Some(t) if t > 0.0 => sample_temperature(logits, t, rng),
        _ => argmax(logits),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -5.0]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = [0.0f32, 5.0, 1.0, -2.0];
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..50 {
            assert_eq!(sample_temperature(&logits, 0.01, &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = [0.0f32, 5.0, 1.0, -2.0];
        let mut rng = Rng::seed_from_u64(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            seen.insert(sample_temperature(&logits, 50.0, &mut rng));
        }
        assert!(seen.len() >= 3, "high T should visit most tokens: {seen:?}");
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        let a: Vec<i32> = {
            let mut rng = Rng::seed_from_u64(9);
            (0..20).map(|_| sample_temperature(&logits, 1.0, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Rng::seed_from_u64(9);
            (0..20).map(|_| sample_temperature(&logits, 1.0, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn frequencies_follow_softmax() {
        // Two logits 0 and ln(3): probabilities 1/4 and 3/4.
        let logits = [0.0f32, (3.0f32).ln()];
        let mut rng = Rng::seed_from_u64(3);
        let n = 20_000;
        let ones = (0..n).filter(|_| sample_temperature(&logits, 1.0, &mut rng) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn dispatch_none_is_greedy() {
        let mut rng = Rng::seed_from_u64(4);
        assert_eq!(sample(&[0.0, 9.0], None, &mut rng), 1);
        assert_eq!(sample(&[0.0, 9.0], Some(0.0), &mut rng), 1);
    }
}
