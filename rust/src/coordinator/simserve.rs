//! Serving *simulator*: the continuous-batching engine run against the
//! `gpusim` cost model instead of PJRT, over the paper's full-size models
//! and devices. Regenerates Table 1 and the Fig. 8 batch sweeps.
//!
//! The same scheduling policy as the real [`super::engine`] (prefill
//! priority, FCFS admission) but with (a) simulated time advanced by the
//! kernel cost model, and (b) KV accounting through the paged
//! [`super::kv_cache::KvBlockManager`] sized from the device's free memory
//! — which is how weight-only quantization turns freed weight bytes into
//! batch capacity (paper §4.2).
//!
//! With `SimPolicy::enable_prefix_cache` (default on, matching vLLM), the
//! automatic prefix cache (`super::prefix`) runs against the *real* token
//! streams synthesized by `workload::Request::token_at`: admission leases
//! the longest cached block chain, the prefill cost model is charged only
//! for the uncached suffix, and finished sequences leave their full
//! blocks resident as evictable idle capacity. Shared-prefix traffic
//! (system prompts, multi-turn chat) therefore shows the throughput/TTFT
//! gain as a function of hit rate; disjoint traffic is unaffected.

use std::collections::VecDeque;
use std::sync::OnceLock;

use anyhow::{anyhow, Context, Result};

use crate::gpusim::kernel_model::{model_gemm, Calib, KernelKind};
use crate::gpusim::DeviceSpec;
use crate::model::LlmSpec;
use crate::obs::{Histogram, HistogramHandle, Registry, Report};
use crate::workload::Request;

use super::kv_cache::{blocks_for_device, KvBlockManager};
use super::prefix::PrefixCache;

/// Registry mirror of the continuous simulator's TTFT distribution (the
/// local [`Histogram`] stays the source of each run's `mean_ttft_s`; the
/// mirror is what `report obs` sees across runs).
fn sim_ttft_hist() -> &'static HistogramHandle {
    static H: OnceLock<HistogramHandle> = OnceLock::new();
    H.get_or_init(|| Registry::global().histogram("sim.ttft_s"))
}

/// Simulation policy knobs (vLLM defaults where applicable).
#[derive(Debug, Clone, Copy)]
pub struct SimPolicy {
    /// Max concurrently running sequences.
    pub max_num_seqs: usize,
    /// KV block size in tokens.
    pub block_size: u64,
    /// Fraction of the pool kept free as an admission watermark.
    pub watermark_frac: f64,
    /// Memory fraction reserved for activations/runtime.
    pub headroom_frac: f64,
    /// Max prompt tokens batched into one prefill step.
    pub max_prefill_tokens: u64,
    /// Automatic prefix caching (copy-on-write block sharing).
    pub enable_prefix_cache: bool,
}

impl Default for SimPolicy {
    fn default() -> Self {
        SimPolicy {
            max_num_seqs: 256,
            block_size: 16,
            watermark_frac: 0.01,
            headroom_frac: 0.10,
            max_prefill_tokens: 4096,
            enable_prefix_cache: true,
        }
    }
}

/// Outcome of one simulated serving run.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimResult {
    /// Requests completed.
    pub finished: usize,
    /// Simulated wall-clock time.
    pub wall_s: f64,
    /// Prompt tokens admitted.
    pub prompt_tokens: u64,
    /// Tokens generated.
    pub gen_tokens: u64,
    /// Generated tokens per second — Table 1's metric.
    pub gen_tok_per_s: f64,
    /// Prompt+generated per second (vLLM's "total token throughput").
    pub total_tok_per_s: f64,
    /// Mean decode batch over decode steps.
    pub mean_batch: f64,
    /// True when weights + minimal KV do not fit the device.
    pub oom: bool,
    /// Sequences preempted (vLLM recompute policy).
    pub preemptions: u64,
    /// Mean time-to-first-token across (re)admissions.
    pub mean_ttft_s: f64,
    /// Prefix-cache counters (zero when the cache is off or never hits).
    pub prefix_hits: u64,
    /// Prefix-cache admission misses.
    pub prefix_misses: u64,
    /// Prompt tokens whose prefill the cache skipped.
    pub prefix_tokens_skipped: u64,
    /// Cached blocks evicted under pool pressure.
    pub prefix_evictions: u64,
}

impl SimResult {
    pub fn prefix_hit_rate(&self) -> f64 {
        let n = self.prefix_hits + self.prefix_misses;
        if n == 0 { 0.0 } else { self.prefix_hits as f64 / n as f64 }
    }

    /// Render through the shared [`Report`] writer — the same layout
    /// `EngineMetrics::report` and `report obs` use.
    pub fn report(&self) -> String {
        let mut r = Report::new();
        r.line("requests", format!("{} finished in {:.2}s (sim)", self.finished, self.wall_s));
        r.line(
            "tokens",
            format!(
                "{} prompt + {} generated ({:.1} gen tok/s, {:.1} total tok/s)",
                self.prompt_tokens, self.gen_tokens, self.gen_tok_per_s, self.total_tok_per_s
            ),
        );
        r.line(
            "batching",
            format!("mean decode batch {:.1}, {} preemptions", self.mean_batch, self.preemptions),
        );
        r.line(
            "prefix",
            format!(
                "{:.0}% hit rate, {} tokens skipped, {} evictions",
                self.prefix_hit_rate() * 100.0,
                self.prefix_tokens_skipped,
                self.prefix_evictions
            ),
        );
        r.line("TTFT", format!("mean {:.1} ms", self.mean_ttft_s * 1e3));
        r.finish()
    }
}

struct RunningSeq {
    req: Request,
    generated: u64,
}

/// Materialize the first `n` synthetic token ids of a request's stream.
pub(crate) fn context_ids(req: &Request, n: u64) -> Vec<i32> {
    (0..n).map(|p| req.token_at(p)).collect()
}

/// Append one token's KV slot, reclaiming an idle cached block on demand
/// (eviction stands in for the free list the cache withholds).
pub(crate) fn append_with_reclaim(
    kv: &mut KvBlockManager,
    cache: &mut PrefixCache,
    id: u64,
) -> bool {
    if kv.append_token(id).is_ok() {
        return true;
    }
    cache.reclaim(kv, 1) && kv.append_token(id).is_ok()
}

/// Publish a sequence's full blocks into the prefix cache, then release it.
///
/// A sequence stored at a precision other than the pool's (graceful
/// degradation, `coordinator::faults`) is freed without registering: the
/// cache pairs whole slabs with token runs of the *pool* precision's
/// per-block length, so a mixed-precision table cannot be shared.
pub(crate) fn register_and_free(
    kv: &mut KvBlockManager,
    cache: &mut PrefixCache,
    req: &Request,
) -> Result<()> {
    let (stored, same_precision) = match kv.table(req.id) {
        Some(t) => (t.tokens, t.precision == kv.precision()),
        None => (0, true),
    };
    if same_precision {
        let _ = cache.register(kv, req.id, &context_ids(req, stored));
    }
    match kv.free_seq(req.id) {
        Ok(_) => Ok(()),
        Err(e) => Err(anyhow!("releasing KV of live sequence {}: {e}", req.id)),
    }
}

/// Latency of a (possibly batched) prefill totalling `tokens` prompt tokens.
pub(crate) fn prefill_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    tokens: u64,
    calib: &Calib,
) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let mut t = 0.0;
    for g in spec.gemms() {
        t += model_gemm(dev, kind, tokens, g.n, g.k, calib).latency_s * g.count as f64;
    }
    // Prefill attention: O(T^2 d) flops on tensor cores, usually minor vs
    // the 7 weight GEMMs at these prompt lengths.
    let attn_flops = 2.0 * 2.0 * (tokens * tokens) as f64 * spec.d_model as f64
        * spec.n_layers as f64;
    t + attn_flops / (dev.tc_tflops * 1e12 * calib.mma_eff)
}

pub(crate) fn decode_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    mean_ctx: u64,
    calib: &Calib,
) -> f64 {
    crate::gpusim::decode_step_latency(dev, spec, kind, batch, mean_ctx.max(1), calib)
        .total_s()
}

fn kv_pool_blocks(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    block_size: u64,
    headroom_frac: f64,
) -> u64 {
    tp_kv_pool_blocks(dev, spec, kind, block_size, headroom_frac, 1)
}

/// Per-rank KV pool of a `tp`-way tensor-parallel group, in *logical*
/// blocks: each rank stores `1/tp` of the weights (freeing memory for KV)
/// and `1/tp` of every token's KV (its shard of the heads), so the pool a
/// TP group offers the scheduler is the per-rank block count — every rank
/// admits and evicts the same logical blocks in lockstep. `tp = 1`
/// reproduces the single-GPU pool bit-exactly.
pub(crate) fn tp_kv_pool_blocks(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    block_size: u64,
    headroom_frac: f64,
    tp_degree: u64,
) -> u64 {
    let w4 = !matches!(kind, KernelKind::Fp16);
    let tp = tp_degree as f64;
    let kv_per_token =
        (2 * spec.n_layers * spec.kv_heads * spec.head_dim()) as f64 * 2.0 / tp;
    blocks_for_device(
        dev.mem_bytes(),
        spec.weight_bytes(w4) / tp,
        kv_per_token,
        block_size,
        headroom_frac,
    )
}

/// Run the continuous-batching simulation over an offline workload (all
/// requests queued at t=0, like vLLM's throughput benchmark).
///
/// Errors only on internal accounting violations (a live sequence whose
/// KV blocks cannot be released); an undersized device is reported via
/// [`SimResult::oom`], not an error.
pub fn simulate_serving(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &SimPolicy,
    calib: &Calib,
) -> Result<SimResult> {
    let blocks = kv_pool_blocks(dev, spec, kind, policy.block_size, policy.headroom_frac);
    if blocks == 0 {
        return Ok(SimResult { oom: true, ..Default::default() });
    }

    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac);
    let mut cache = PrefixCache::new(policy.block_size as usize, policy.enable_prefix_cache);
    let mut waiting: VecDeque<Request> = requests.iter().copied().collect();
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut clock = 0.0f64;
    let mut prompt_tokens = 0u64;
    let mut gen_tokens = 0u64;
    let mut finished = 0usize;
    let mut decode_steps = 0u64;
    let mut decode_lane_steps = 0u64;
    let mut preemptions = 0u64;
    let mut ttft = Histogram::new();

    while !waiting.is_empty() || !running.is_empty() {
        // --- admission: batch prefills while budget allows; a matched
        // prefix is leased from the cache and skips prefill compute ---
        let mut prefill_batch_tokens = 0u64;
        while let Some(&req) = waiting.front() {
            if running.len() >= policy.max_num_seqs {
                break;
            }
            let ids = context_ids(&req, req.prompt_tokens);
            // Budget the batch by the tokens that actually need compute
            // (prompt minus the currently cached prefix).
            let est_new = req.prompt_tokens - cache.peek_match_tokens(&ids);
            if prefill_batch_tokens + est_new > policy.max_prefill_tokens {
                break;
            }
            let Ok(matched) = cache.admit(&mut kv, req.id, &ids) else { break };
            waiting.pop_front();
            prompt_tokens += req.prompt_tokens;
            prefill_batch_tokens += req.prompt_tokens - matched;
            // Publish the prompt's full blocks right away so concurrent
            // same-prefix requests can share them (vLLM registers
            // computed blocks eagerly).
            let _ = cache.register(&mut kv, req.id, &ids);
            running.push(RunningSeq { req, generated: 0 });
            if prefill_batch_tokens > policy.max_prefill_tokens {
                // admit()'s exclusive fall-back can deliver less cached
                // prefix than estimated; bound the budget overshoot to
                // this one request.
                break;
            }
        }
        if prefill_batch_tokens > 0 {
            clock += prefill_latency(dev, spec, kind, prefill_batch_tokens, calib);
            // The prefill's last-token logits yield each admitted
            // sequence's first generated token (vLLM counts it this way).
            for r in running.iter_mut().filter(|r| r.generated == 0) {
                r.generated = 1;
                gen_tokens += 1;
                ttft.record_s(clock - r.req.arrival_s());
                let _ = append_with_reclaim(&mut kv, &mut cache, r.req.id);
            }
        }

        if running.is_empty() {
            if waiting.is_empty() {
                break;
            }
            // Workload item larger than the whole pool: drop it (vLLM
            // would reject it too).
            waiting.pop_front();
            continue;
        }

        // --- one decode step over all running sequences ---
        let batch = running.len() as u64;
        let mean_ctx = running
            .iter()
            .map(|r| r.req.prompt_tokens + r.generated)
            .sum::<u64>()
            / batch;
        clock += decode_latency(dev, spec, kind, batch, mean_ctx, calib);
        decode_steps += 1;
        decode_lane_steps += batch;

        let mut i = 0;
        while i < running.len() {
            running[i].generated += 1;
            gen_tokens += 1;
            let req = running[i].req;
            let generated = running[i].generated;
            if generated >= req.gen_tokens {
                // Finished: leave the context's full blocks warm for the
                // conversation's next turn.
                register_and_free(&mut kv, &mut cache, &req)?;
                finished += 1;
                running.swap_remove(i);
                continue;
            }
            if !append_with_reclaim(&mut kv, &mut cache, req.id) {
                // Preempt (vLLM recompute policy): release the blocks —
                // computed full blocks stay cached, so the re-prefill is
                // discounted on re-admission — and requeue.
                let victim = running.swap_remove(i);
                register_and_free(&mut kv, &mut cache, &victim.req)?;
                preemptions += 1;
                let mut back = victim.req;
                back.gen_tokens -= victim.generated.min(back.gen_tokens - 1);
                waiting.push_back(back);
                continue;
            }
            i += 1;
        }
    }

    Ok(SimResult {
        finished,
        wall_s: clock,
        prompt_tokens,
        gen_tokens,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        total_tok_per_s: (prompt_tokens + gen_tokens) as f64 / clock.max(1e-9),
        mean_batch: if decode_steps == 0 {
            0.0
        } else {
            decode_lane_steps as f64 / decode_steps as f64
        },
        oom: false,
        preemptions,
        mean_ttft_s: ttft.mean_s(),
        prefix_hits: cache.stats.hits,
        prefix_misses: cache.stats.misses,
        prefix_tokens_skipped: cache.stats.tokens_skipped,
        prefix_evictions: cache.stats.evictions,
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::{ShareGptLike, SharedPrefixWorkload};

    /// Test-local shadow of [`super::simulate_serving`]: same signature,
    /// unwrapped result.
    fn simulate_serving(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        kind: KernelKind,
        requests: &[Request],
        policy: &SimPolicy,
        calib: &Calib,
    ) -> SimResult {
        super::simulate_serving(dev, spec, kind, requests, policy, calib).unwrap()
    }

    fn run(kind: KernelKind, model: Model) -> SimResult {
        let reqs = ShareGptLike::new().offline(300, 42);
        simulate_serving(
            &Gpu::RtxA6000.spec(),
            &model.spec(),
            kind,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
    }

    #[test]
    fn table1_vicuna_ordering() {
        // Table 1: QUICK > AWQ > FP16 on Vicuna-13B/A6000.
        let fp = run(KernelKind::Fp16, Model::Vicuna13B);
        let awq = run(KernelKind::Awq, Model::Vicuna13B);
        let quick = run(KernelKind::Quick, Model::Vicuna13B);
        assert!(!fp.oom && !awq.oom && !quick.oom);
        assert!(quick.gen_tok_per_s > awq.gen_tok_per_s, "{quick:?} vs {awq:?}");
        assert!(awq.gen_tok_per_s > fp.gen_tok_per_s * 0.9, "{awq:?} vs {fp:?}");
    }

    #[test]
    fn table1_llama70b_fp16_oom() {
        let fp = run(KernelKind::Fp16, Model::Llama2_70B);
        assert!(fp.oom);
        let quick = run(KernelKind::Quick, Model::Llama2_70B);
        assert!(!quick.oom && quick.gen_tok_per_s > 0.0);
    }

    #[test]
    fn all_requests_complete() {
        let reqs = ShareGptLike::new().offline(100, 7);
        let r = simulate_serving(
            &Gpu::A100.spec(),
            &Model::Mistral7B.spec(),
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        );
        assert_eq!(r.finished, 100);
        let want: u64 = reqs.iter().map(|r| r.gen_tokens).sum();
        assert!(r.gen_tokens >= want, "{} < {}", r.gen_tokens, want);
    }

    #[test]
    fn quantized_sustains_bigger_batches() {
        let fp = run(KernelKind::Fp16, Model::Vicuna13B);
        let quick = run(KernelKind::Quick, Model::Vicuna13B);
        assert!(
            quick.mean_batch > fp.mean_batch,
            "quick batch {} !> fp16 batch {}",
            quick.mean_batch,
            fp.mean_batch
        );
    }

    #[test]
    fn shared_prefix_cache_speeds_up_serving() {
        // Acceptance: >=1.2x throughput and lower mean TTFT on the
        // shared-prefix workload at equal KV budget.
        let reqs = SharedPrefixWorkload::default().offline(200, 9);
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let on = simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        );
        let off = simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy { enable_prefix_cache: false, ..SimPolicy::default() },
            &Calib::default(),
        );
        assert!(!on.oom && !off.oom);
        assert_eq!(on.finished, reqs.len());
        assert_eq!(off.finished, reqs.len());
        assert!(on.prefix_hits > 0 && on.prefix_tokens_skipped > 0);
        assert!(
            on.total_tok_per_s >= off.total_tok_per_s * 1.2,
            "cache-on {:.1} tok/s !>= 1.2x cache-off {:.1} tok/s",
            on.total_tok_per_s,
            off.total_tok_per_s
        );
        assert!(
            on.mean_ttft_s < off.mean_ttft_s,
            "cache-on TTFT {:.3}s !< cache-off {:.3}s",
            on.mean_ttft_s,
            off.mean_ttft_s
        );
    }

    #[test]
    fn disjoint_workload_unaffected_by_cache() {
        // On a disjoint-prompt workload with ample KV (no preemptions) the
        // cache must be a bit-exact no-op.
        let reqs = ShareGptLike::new().offline(100, 7);
        let dev = Gpu::A100.spec();
        let spec = Model::Mistral7B.spec();
        let on = simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        );
        let off = simulate_serving(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy { enable_prefix_cache: false, ..SimPolicy::default() },
            &Calib::default(),
        );
        assert_eq!(on.preemptions, 0);
        assert_eq!(off.preemptions, 0);
        assert_eq!(on.prefix_tokens_skipped, 0, "disjoint prompts must not hit");
        assert_eq!(on.wall_s, off.wall_s, "cache changed disjoint-workload timing");
        assert_eq!(on.gen_tokens, off.gen_tokens);
        assert_eq!(on.finished, off.finished);
    }
}

// ---------------------------------------------------------------------------
// Online serving (Poisson arrivals): latency percentiles vs offered load.
// ---------------------------------------------------------------------------

/// Per-request latency sample from an online simulation.
#[derive(Debug, Clone, Copy)]
pub struct OnlineLatency {
    /// Workload request id.
    pub request_id: u64,
    /// Arrival-to-completion latency, seconds.
    pub e2e_s: f64,
}

/// Result of an online (open-loop) serving simulation.
#[derive(Debug, Clone, Default)]
pub struct OnlineResult {
    /// Requests completed.
    pub finished: usize,
    /// Simulated wall-clock time.
    pub wall_s: f64,
    /// Generated tokens per second.
    pub gen_tok_per_s: f64,
    /// Per-request end-to-end latency samples.
    pub latencies: Vec<OnlineLatency>,
    /// True when weights + minimal KV do not fit the device.
    pub oom: bool,
    /// Mean time-to-first-token across (re)admissions.
    pub mean_ttft_s: f64,
    /// Prefix-cache admission hits (zero when the cache is off).
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill the cache skipped.
    pub prefix_tokens_skipped: u64,
    /// Cached blocks evicted under pool pressure.
    pub prefix_evictions: u64,
}

impl OnlineResult {
    pub fn e2e_quantile_s(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut xs: Vec<f64> = self.latencies.iter().map(|l| l.e2e_s).collect();
        xs.sort_by(f64::total_cmp);
        let idx = (q.clamp(0.0, 1.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx]
    }

    pub fn mean_e2e_s(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        self.latencies.iter().map(|l| l.e2e_s).sum::<f64>() / self.latencies.len() as f64
    }
}

/// Open-loop simulation: requests arrive at their `arrival_s`; the engine
/// runs prefill-priority continuous batching under the same KV accounting
/// as [`simulate_serving`] (including the automatic prefix cache). Used
/// for latency-vs-load curves (not a paper figure — an extension the
/// serving community expects; see `quick-infer loadtest`).
///
/// Errors only on internal KV-accounting violations; an undersized
/// device is reported via [`OnlineResult::oom`].
pub fn simulate_online(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &SimPolicy,
    calib: &Calib,
) -> Result<OnlineResult> {
    let blocks = kv_pool_blocks(dev, spec, kind, policy.block_size, policy.headroom_frac);
    if blocks == 0 {
        return Ok(OnlineResult { oom: true, ..Default::default() });
    }
    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac);
    let mut cache = PrefixCache::new(policy.block_size as usize, policy.enable_prefix_cache);
    let mut pending: VecDeque<Request> = requests.iter().copied().collect();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut clock = 0.0f64;
    let mut gen_tokens = 0u64;
    let mut latencies = Vec::with_capacity(requests.len());
    let mut ttft = Histogram::new();

    loop {
        // Move arrived requests into the queue.
        while let Some(&r) = pending.front() {
            if r.arrival_s() > clock {
                break;
            }
            pending.pop_front();
            waiting.push_back(r);
        }
        if waiting.is_empty() && running.is_empty() {
            match pending.front() {
                Some(r) => {
                    clock = r.arrival_s(); // idle until next arrival
                    continue;
                }
                None => break,
            }
        }

        // Admission + prefill batch (prefix-matched tokens are free).
        let mut prefill_tokens = 0u64;
        while let Some(&req) = waiting.front() {
            if running.len() >= policy.max_num_seqs {
                break;
            }
            let ids = context_ids(&req, req.prompt_tokens);
            let est_new = req.prompt_tokens - cache.peek_match_tokens(&ids);
            if prefill_tokens + est_new > policy.max_prefill_tokens {
                break;
            }
            let Ok(matched) = cache.admit(&mut kv, req.id, &ids) else { break };
            waiting.pop_front();
            prefill_tokens += req.prompt_tokens - matched;
            let _ = cache.register(&mut kv, req.id, &ids);
            running.push(RunningSeq { req, generated: 0 });
            if prefill_tokens > policy.max_prefill_tokens {
                break; // bound overshoot from admit()'s exclusive fall-back
            }
        }
        if prefill_tokens > 0 {
            clock += prefill_latency(dev, spec, kind, prefill_tokens, calib);
            for r in running.iter_mut().filter(|r| r.generated == 0) {
                r.generated = 1;
                gen_tokens += 1;
                ttft.record_s(clock - r.req.arrival_s());
                let _ = append_with_reclaim(&mut kv, &mut cache, r.req.id);
            }
        }
        if running.is_empty() {
            continue;
        }

        // One decode step.
        let batch = running.len() as u64;
        let mean_ctx = running
            .iter()
            .map(|r| r.req.prompt_tokens + r.generated)
            .sum::<u64>()
            / batch;
        clock += decode_latency(dev, spec, kind, batch, mean_ctx, calib);

        let mut i = 0;
        while i < running.len() {
            running[i].generated += 1;
            gen_tokens += 1;
            let req = running[i].req;
            let generated = running[i].generated;
            if generated >= req.gen_tokens {
                register_and_free(&mut kv, &mut cache, &req)?;
                latencies.push(OnlineLatency {
                    request_id: req.id,
                    e2e_s: clock - req.arrival_s(),
                });
                running.swap_remove(i);
                continue;
            }
            if !append_with_reclaim(&mut kv, &mut cache, req.id) {
                let victim = running.swap_remove(i);
                register_and_free(&mut kv, &mut cache, &victim.req)?;
                let mut back = victim.req;
                back.gen_tokens -= victim.generated.min(back.gen_tokens - 1);
                waiting.push_back(back);
                continue;
            }
            i += 1;
        }
    }

    Ok(OnlineResult {
        finished: latencies.len(),
        wall_s: clock,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        latencies,
        oom: false,
        mean_ttft_s: ttft.mean_s(),
        prefix_hits: cache.stats.hits,
        prefix_tokens_skipped: cache.stats.tokens_skipped,
        prefix_evictions: cache.stats.evictions,
    })
}

#[cfg(test)]
mod online_tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::{ShareGptLike, SharedPrefixWorkload};

    /// Test-local shadow of [`super::simulate_online`]: same signature,
    /// unwrapped result.
    fn simulate_online(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        kind: KernelKind,
        requests: &[Request],
        policy: &SimPolicy,
        calib: &Calib,
    ) -> OnlineResult {
        super::simulate_online(dev, spec, kind, requests, policy, calib).unwrap()
    }

    fn run_online(rate: f64, kind: KernelKind) -> OnlineResult {
        let reqs = ShareGptLike::new().online(150, rate, 11);
        simulate_online(
            &Gpu::RtxA6000.spec(),
            &Model::Vicuna13B.spec(),
            kind,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
    }

    #[test]
    fn all_online_requests_finish() {
        let r = run_online(2.0, KernelKind::Quick);
        assert_eq!(r.finished, 150);
        assert!(!r.oom);
    }

    #[test]
    fn latency_grows_with_offered_load() {
        let light = run_online(0.5, KernelKind::Quick);
        let heavy = run_online(20.0, KernelKind::Quick);
        assert!(
            heavy.mean_e2e_s() > light.mean_e2e_s(),
            "heavy {} !> light {}",
            heavy.mean_e2e_s(),
            light.mean_e2e_s()
        );
    }

    #[test]
    fn quick_sustains_lower_latency_than_awq_under_load() {
        let q = run_online(6.0, KernelKind::Quick);
        let a = run_online(6.0, KernelKind::Awq);
        assert!(
            q.e2e_quantile_s(0.9) < a.e2e_quantile_s(0.9),
            "p90 quick {} !< awq {}",
            q.e2e_quantile_s(0.9),
            a.e2e_quantile_s(0.9)
        );
    }

    #[test]
    fn quantiles_monotone() {
        let r = run_online(4.0, KernelKind::Quick);
        assert!(r.e2e_quantile_s(0.5) <= r.e2e_quantile_s(0.9));
        assert!(r.e2e_quantile_s(0.9) <= r.e2e_quantile_s(0.99));
    }

    #[test]
    fn online_shared_prefix_lowers_ttft() {
        let reqs = SharedPrefixWorkload::default().online(150, 4.0, 21);
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let on = simulate_online(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        );
        let off = simulate_online(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &SimPolicy { enable_prefix_cache: false, ..SimPolicy::default() },
            &Calib::default(),
        );
        assert!(!on.oom && !off.oom);
        assert!(on.prefix_hits > 0);
        assert!(
            on.mean_ttft_s < off.mean_ttft_s,
            "online cache-on TTFT {:.3}s !< cache-off {:.3}s",
            on.mean_ttft_s,
            off.mean_ttft_s
        );
    }
}

// ---------------------------------------------------------------------------
// Continuous batching with chunked prefill (the token-budget scheduler) and
// the static prefill-then-decode wave baseline it replaces.
// ---------------------------------------------------------------------------

use super::batcher::{ChunkPolicy, ContinuousScheduler};
use super::measured::{MeasuredEngine, MeasuredStats};
use crate::gpusim::tp_step_latency;
use crate::kernel::StepBackend;
use crate::quant::{CodebookKind, KvPrecision};

/// Policy for [`simulate_continuous`] / [`simulate_static_wave`].
#[derive(Debug, Clone, Copy)]
pub struct ContinuousPolicy {
    /// Max concurrently resident sequences.
    pub max_num_seqs: usize,
    /// KV block size in tokens.
    pub block_size: u64,
    /// Fraction of the pool kept free as an admission watermark.
    pub watermark_frac: f64,
    /// Memory fraction reserved for activations/runtime.
    pub headroom_frac: f64,
    /// Per-step token budget (decode tokens + prefill-chunk tokens) —
    /// vLLM's `max_num_batched_tokens` with chunked prefill on.
    pub token_budget: u64,
    /// Automatic prefix caching (continuous scheduler only; a hit shrinks
    /// a prompt's remaining chunks).
    pub enable_prefix_cache: bool,
    /// Prefill-call token cap for the wave baseline's whole-wave prefill.
    pub wave_prefill_tokens: u64,
    /// KV-cache storage precision: quantized precisions shrink per-token
    /// byte cost, so the same pool of fixed-size block slabs holds
    /// `KvPrecision::tokens_per_block(block_size)` tokens per block
    /// (~3.4x more at 4-bit). `F16` reproduces the historical block math
    /// bit-for-bit.
    pub kv_precision: KvPrecision,
    /// Weight codebook the *measured* twins quantize against. Non-uniform
    /// grids (NF4/MXFP4) force the LUT decode tier in every rank's
    /// executor; the modeled simulators ignore this field (their dequant
    /// pricing comes from [`Calib::dequant_scale`]).
    pub codebook: CodebookKind,
}

impl Default for ContinuousPolicy {
    fn default() -> Self {
        ContinuousPolicy {
            max_num_seqs: 256,
            block_size: 16,
            watermark_frac: 0.01,
            headroom_frac: 0.10,
            token_budget: 512,
            enable_prefix_cache: true,
            wave_prefill_tokens: 4096,
            kv_precision: KvPrecision::F16,
            codebook: CodebookKind::Int4Uniform,
        }
    }
}

impl ContinuousPolicy {
    /// Policy sized for the *measured* twins serving the tiny model on
    /// the native runtime: a 128-token step budget (the executor's
    /// buffers are allocated to it up front) and 8-token KV blocks so
    /// the scaled-down shared-prefix prompts still span whole cached
    /// blocks.
    pub fn measured_default() -> Self {
        ContinuousPolicy {
            max_num_seqs: 64,
            block_size: 8,
            token_budget: 128,
            wave_prefill_tokens: 128,
            ..ContinuousPolicy::default()
        }
    }
}

/// Outcome of a continuous-batching (or wave-baseline) simulation.
#[derive(Debug, Clone, Copy, Default)]
pub struct ContinuousResult {
    /// Requests completed.
    pub finished: usize,
    /// Simulated wall-clock time.
    pub wall_s: f64,
    /// Distinct prompt tokens admitted (first admissions only — preemption
    /// recomputes are scheduler overhead, not offered work).
    pub prompt_tokens: u64,
    /// Tokens generated.
    pub gen_tokens: u64,
    /// Generated tokens per second.
    pub gen_tok_per_s: f64,
    /// (prompt + generated) / wall — vLLM's total token throughput.
    pub total_tok_per_s: f64,
    /// Mixed steps executed.
    pub steps: u64,
    /// Mean tokens per step (decode + chunk): the sustained GEMM M.
    pub mean_step_tokens: f64,
    /// Mean decode lanes over steps that decoded at all.
    pub mean_decode_batch: f64,
    /// Prefill chunks scheduled (≥ one per admitted prompt).
    pub prefill_chunks: u64,
    /// True when weights + minimal KV do not fit the device.
    pub oom: bool,
    /// Sequences preempted (vLLM recompute policy).
    pub preemptions: u64,
    /// Mean time-to-first-token across (re)admissions.
    pub mean_ttft_s: f64,
    /// Prefix-cache admission hits.
    pub prefix_hits: u64,
    /// Prefix-cache admission misses.
    pub prefix_misses: u64,
    /// Prompt tokens whose prefill the cache skipped.
    pub prefix_tokens_skipped: u64,
    /// Cached blocks evicted under pool pressure.
    pub prefix_evictions: u64,
}

impl ContinuousResult {
    pub fn prefix_hit_rate(&self) -> f64 {
        let n = self.prefix_hits + self.prefix_misses;
        if n == 0 { 0.0 } else { self.prefix_hits as f64 / n as f64 }
    }

    /// Render through the shared [`Report`] writer — the same layout
    /// `EngineMetrics::report` and `report obs` use.
    pub fn report(&self) -> String {
        let mut r = Report::new();
        r.line("requests", format!("{} finished in {:.2}s (sim)", self.finished, self.wall_s));
        r.line(
            "tokens",
            format!(
                "{} prompt + {} generated ({:.1} gen tok/s, {:.1} total tok/s)",
                self.prompt_tokens, self.gen_tokens, self.gen_tok_per_s, self.total_tok_per_s
            ),
        );
        r.line(
            "steps",
            format!(
                "{} mixed steps, mean {:.1} tokens/step, mean decode batch {:.1}",
                self.steps, self.mean_step_tokens, self.mean_decode_batch
            ),
        );
        r.line(
            "batching",
            format!("{} prefill chunks, {} preemptions", self.prefill_chunks, self.preemptions),
        );
        r.line(
            "prefix",
            format!(
                "{:.0}% hit rate, {} tokens skipped, {} evictions",
                self.prefix_hit_rate() * 100.0,
                self.prefix_tokens_skipped,
                self.prefix_evictions
            ),
        );
        r.line("TTFT", format!("mean {:.1} ms", self.mean_ttft_s * 1e3));
        r.finish()
    }
}

/// Continuous batching with chunked prefill over arrivals (offline
/// workloads simply have every `arrival_s == 0`).
///
/// Each iteration: arrivals are queued; admission leases prefix-cache
/// matches and allocates full-prompt KV (the chunk schedule changes
/// *compute* timing, not memory footprint); the token-budget scheduler
/// plans one mixed step (decode first, then FCFS prefill chunks); its
/// latency comes from one [`crate::gpusim::mixed_step_latency`]-equivalent
/// query at the actual mixed batch size. Decode appends that run out of KV
/// blocks preempt the sequence (vLLM recompute policy) back to the queue.
pub fn simulate_continuous(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
) -> Result<ContinuousResult> {
    run_continuous(dev, spec, kind, requests, policy, calib, 1, None)
}

/// Token budget for a `tp`-way group: scale the configured per-step budget
/// by the group's step-latency speedup at the nominal operating point, so
/// a group that steps faster packs proportionally more tokens per step and
/// keeps the same wall-clock step-time target (vLLM deployments tune
/// `max_num_batched_tokens` per hardware config the same way). Never
/// scales below the configured budget.
fn tp_scaled_token_budget(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    policy: &ContinuousPolicy,
    tp_degree: u64,
    calib: &Calib,
) -> u64 {
    if tp_degree <= 1 {
        return policy.token_budget;
    }
    let probe = |tp: u64| {
        let decode = (policy.token_budget / 2).max(1);
        let chunk = policy.token_budget.saturating_sub(decode);
        tp_step_latency(dev, spec, kind, tp, decode, 512, chunk, chunk * 2, calib).total_s()
    };
    let speedup = (probe(1) / probe(tp_degree).max(1e-12)).max(1.0);
    ((policy.token_budget as f64 * speedup).round() as u64).max(policy.token_budget)
}

/// [`simulate_continuous`] on a `tp_degree`-way tensor-parallel group:
/// per-step cost from [`tp_step_latency`] (per-rank GEMMs at `1/tp`
/// weight volume + two ring all-reduces per layer), the per-rank KV pool
/// from the weight bytes TP frees on each rank, and the scheduler's token
/// budget scaled to the group's effective step latency
/// (`tp_scaled_token_budget`). `tp_degree = 1` is bit-identical to
/// [`simulate_continuous`] — the controlled baseline of the scaling sweep
/// (`figures::tensor_parallel`, `quick-infer simulate tp`).
pub fn simulate_tp(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &ContinuousPolicy,
    tp_degree: u64,
    calib: &Calib,
) -> Result<ContinuousResult> {
    let tp = tp_degree.max(1);
    let scaled = ContinuousPolicy {
        token_budget: tp_scaled_token_budget(dev, spec, kind, policy, tp, calib),
        ..*policy
    };
    run_continuous(dev, spec, kind, requests, &scaled, calib, tp, None)
}

/// The continuous-batching loop behind both twins. With `measured:
/// None` the clock advances by the modeled step latency (bit-identical
/// to the pre-measured-runtime behavior); with `Some(engine)` every
/// planned step executes its GEMM stream for real on the native runtime
/// and the clock advances by the measured wall time plus priced
/// collectives, while the modeled latency is still evaluated as the
/// side-by-side twin (drift ledger, [`MeasuredStats::modeled_s`]).
#[allow(clippy::too_many_arguments)]
fn run_continuous(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
    tp_degree: u64,
    mut measured: Option<&mut MeasuredEngine>,
) -> Result<ContinuousResult> {
    let blocks =
        tp_kv_pool_blocks(dev, spec, kind, policy.block_size, policy.headroom_frac, tp_degree);
    if blocks == 0 {
        return Ok(ContinuousResult { oom: true, ..Default::default() });
    }
    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac)
        .with_precision(policy.kv_precision);
    // The prefix cache's token granularity must match the pool's: a
    // quantized pool packs more tokens into each slab, and `seal` /
    // `register` pair whole slabs with token runs of that length. At
    // F16 this is exactly `policy.block_size`.
    let mut cache = PrefixCache::new(kv.tokens_per_block() as usize, policy.enable_prefix_cache);
    let mut sched = ContinuousScheduler::new(ChunkPolicy {
        token_budget: policy.token_budget,
        max_num_seqs: policy.max_num_seqs,
    });
    let mut pending: VecDeque<Request> = requests.iter().copied().collect();
    // Scheduler slot -> workload request (token streams, arrival).
    let mut slot_req: Vec<Request> = Vec::new();
    // Slot -> materialized prompt token ids (built once; admission under
    // pool pressure may retry for thousands of steps).
    let mut slot_ids: Vec<Vec<i32>> = Vec::new();
    // Count each request's prompt once across preemption re-admissions.
    let mut counted: Vec<bool> = Vec::new();
    // Head request + pool state of the last failed admission: retrying is
    // pointless (and re-walks the prefix trie) until either changes.
    let mut admit_blocked: Option<(usize, u64, u64)> = None;

    let mut clock = 0.0f64;
    let mut prompt_tokens = 0u64;
    let mut gen_tokens = 0u64;
    let mut finished = 0usize;
    let mut steps = 0u64;
    let mut step_tokens_sum = 0u64;
    let mut decode_steps = 0u64;
    let mut decode_lane_steps = 0u64;
    let mut prefill_chunks = 0u64;
    let mut preemptions = 0u64;
    let mut ttft = Histogram::new();

    loop {
        while let Some(&r) = pending.front() {
            if r.arrival_s() > clock {
                break;
            }
            pending.pop_front();
            let sid = sched.submit(r.id, r.prompt_tokens, r.gen_tokens);
            debug_assert_eq!(sid, slot_req.len());
            slot_ids.push(context_ids(&r, r.prompt_tokens));
            slot_req.push(r);
            counted.push(false);
        }
        if !sched.has_work() {
            match pending.front() {
                Some(r) => {
                    clock = r.arrival_s(); // idle until the next arrival
                    continue;
                }
                None => break,
            }
        }

        // --- admission: FCFS while the resident cap and KV pool allow ---
        while sched.running_len() < policy.max_num_seqs {
            let Some(sid) = sched.peek_waiting() else { break };
            let pool = (kv.free_blocks(), kv.cached_idle_blocks());
            if admit_blocked == Some((sid, pool.0, pool.1)) {
                break; // same head, same pool: admit() would fail again
            }
            let req = slot_req[sid];
            match cache.admit(&mut kv, req.id, &slot_ids[sid]) {
                Ok(matched) => {
                    admit_blocked = None;
                    let admitted = sched.admit_next(matched, |_| true);
                    debug_assert_eq!(admitted, Some(sid));
                    if !counted[sid] {
                        counted[sid] = true;
                        prompt_tokens += req.prompt_tokens;
                    }
                    // Publish the prompt's full blocks eagerly so
                    // concurrent same-prefix requests share them.
                    let _ = cache.register(&mut kv, req.id, &slot_ids[sid]);
                }
                Err(_) => {
                    if sched.running_len() == 0 {
                        // Request larger than the whole pool: reject it
                        // (nothing running will ever free enough blocks).
                        sched.reject_waiting_head();
                        continue;
                    }
                    admit_blocked = Some((sid, pool.0, pool.1));
                    break; // pool pressure: retry once the pool changes
                }
            }
        }

        // --- one mixed step: decode lanes + FCFS prefill chunks ---
        let batch = sched.plan_step();
        if batch.is_empty() {
            debug_assert_eq!(sched.running_len(), 0);
            match pending.front() {
                Some(r) => {
                    clock = clock.max(r.arrival_s());
                    continue;
                }
                None => {
                    if sched.peek_waiting().is_some() {
                        // Unadmittable leftovers with nothing running.
                        sched.reject_waiting_head();
                        continue;
                    }
                    break;
                }
            }
        }
        let decode_batch = batch.decode.len() as u64;
        let mean_ctx = if decode_batch > 0 {
            batch
                .decode
                .iter()
                .map(|&sid| {
                    let s = sched.seq(sid);
                    s.prompt_tokens + s.generated
                })
                .sum::<u64>()
                / decode_batch
        } else {
            0
        };
        // At tp_degree = 1 this is bit-identical to `mixed_step_latency`
        // (collective::tp1_reduces_exactly_to_mixed_step).
        let perf = tp_step_latency(
            dev,
            spec,
            kind,
            tp_degree,
            decode_batch,
            mean_ctx,
            batch.prefill_tokens(),
            batch.prefill_attn_ctx_tokens(),
            calib,
        );
        clock += match measured.as_deref_mut() {
            None => perf.total_s(),
            // Real compute: the step's mixed batch M through the
            // per-rank GEMM streams. Prefix-cache hits already shrank
            // the planned chunks, so cached tokens never reach the
            // runtime.
            Some(eng) => eng.execute(batch.step_tokens() as usize, perf.total_s()),
        };
        steps += 1;
        step_tokens_sum += batch.step_tokens();
        prefill_chunks += batch.chunks.len() as u64;
        if decode_batch > 0 {
            decode_steps += 1;
            decode_lane_steps += decode_batch;
        }

        // Commit prefill chunks; a prompt-completing chunk's last logits
        // yield the sequence's first generated token.
        for c in &batch.chunks {
            if sched.commit_chunk(c) {
                sched.commit_first_token(c.seq);
                gen_tokens += 1;
                let req = slot_req[c.seq];
                let dt = clock - req.arrival_s();
                ttft.record_s(dt);
                sim_ttft_hist().record_s(dt);
                let s = sched.seq(c.seq);
                if s.generated >= s.gen_budget {
                    register_and_free(&mut kv, &mut cache, &req)?;
                    sched.finish(c.seq);
                    finished += 1;
                    continue;
                }
                // The first token's KV slot is subject to the same pool
                // pressure as decode appends: preempt on exhaustion.
                if !append_with_reclaim(&mut kv, &mut cache, req.id) {
                    register_and_free(&mut kv, &mut cache, &req)?;
                    sched.preempt(c.seq);
                    preemptions += 1;
                }
            }
        }
        // Commit decode lanes; finished sequences leave their blocks warm
        // in the cache, KV exhaustion preempts (recompute policy).
        for &sid in &batch.decode {
            gen_tokens += 1;
            let done = sched.commit_decode(sid);
            let req = slot_req[sid];
            if done {
                register_and_free(&mut kv, &mut cache, &req)?;
                sched.finish(sid);
                finished += 1;
                continue;
            }
            if !append_with_reclaim(&mut kv, &mut cache, req.id) {
                register_and_free(&mut kv, &mut cache, &req)?;
                sched.preempt(sid);
                preemptions += 1;
            }
        }
    }

    Ok(ContinuousResult {
        finished,
        wall_s: clock,
        prompt_tokens,
        gen_tokens,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        total_tok_per_s: (prompt_tokens + gen_tokens) as f64 / clock.max(1e-9),
        steps,
        mean_step_tokens: step_tokens_sum as f64 / steps.max(1) as f64,
        mean_decode_batch: decode_lane_steps as f64 / decode_steps.max(1) as f64,
        prefill_chunks,
        oom: false,
        preemptions,
        mean_ttft_s: ttft.mean_s(),
        prefix_hits: cache.stats.hits,
        prefix_misses: cache.stats.misses,
        prefix_tokens_skipped: cache.stats.tokens_skipped,
        prefix_evictions: cache.stats.evictions,
    })
}

/// The scheduler the continuous batcher replaces: static
/// prefill-then-decode *waves* (Orca's/vLLM's motivating baseline, and the
/// paper-era FasterTransformer serving mode). A wave admits as many queued
/// requests as KV allows — reserving each sequence's full prompt+gen
/// context, since without preemption admission must be safe — prefills
/// every admitted prompt, then decodes until the *entire wave* finishes
/// before admitting again. The drain phase runs at ever-smaller decode
/// batches, precisely the regime where the paper's Fig. 7 shows all
/// kernels starved; heavy-tailed generation lengths make it expensive.
pub fn simulate_static_wave(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
) -> Result<ContinuousResult> {
    run_static_wave(dev, spec, kind, requests, policy, calib, None)
}

/// The wave loop behind both twins (same `measured` contract as
/// [`run_continuous`]): a measured run executes each whole-wave prefill
/// call and each drain decode step as a real GEMM stream at that call's
/// token count.
fn run_static_wave(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
    mut measured: Option<&mut MeasuredEngine>,
) -> Result<ContinuousResult> {
    let blocks = kv_pool_blocks(dev, spec, kind, policy.block_size, policy.headroom_frac);
    if blocks == 0 {
        return Ok(ContinuousResult { oom: true, ..Default::default() });
    }
    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac)
        .with_precision(policy.kv_precision);
    let mut pending: VecDeque<Request> = requests.iter().copied().collect();
    let mut waiting: VecDeque<Request> = VecDeque::new();

    let mut clock = 0.0f64;
    let mut prompt_tokens = 0u64;
    let mut gen_tokens = 0u64;
    let mut finished = 0usize;
    let mut steps = 0u64;
    let mut step_tokens_sum = 0u64;
    let mut decode_steps = 0u64;
    let mut decode_lane_steps = 0u64;
    let mut ttft = Histogram::new();

    loop {
        while let Some(&r) = pending.front() {
            if r.arrival_s() > clock {
                break;
            }
            pending.pop_front();
            waiting.push_back(r);
        }
        if waiting.is_empty() {
            match pending.front() {
                Some(r) => {
                    clock = r.arrival_s();
                    continue;
                }
                None => break,
            }
        }

        // --- form one wave (reserve prompt + full generation budget) ---
        let mut wave: Vec<RunningSeq> = Vec::new();
        while let Some(&req) = waiting.front() {
            if wave.len() >= policy.max_num_seqs {
                break;
            }
            if kv.allocate(req.id, req.prompt_tokens + req.gen_tokens).is_err() {
                break;
            }
            waiting.pop_front();
            prompt_tokens += req.prompt_tokens;
            wave.push(RunningSeq { req, generated: 0 });
        }
        if wave.is_empty() {
            // Head request larger than the whole pool: reject it.
            waiting.pop_front();
            continue;
        }

        // --- prefill the whole wave, max_prefill-token calls ---
        let mut rem: u64 = wave.iter().map(|s| s.req.prompt_tokens).sum();
        while rem > 0 {
            let call = rem.min(policy.wave_prefill_tokens.max(1));
            let modeled = prefill_latency(dev, spec, kind, call, calib);
            clock += match measured.as_deref_mut() {
                None => modeled,
                Some(eng) => eng.execute(call as usize, modeled),
            };
            steps += 1;
            step_tokens_sum += call;
            rem -= call;
        }
        for s in wave.iter_mut() {
            s.generated = 1;
            gen_tokens += 1;
            ttft.record_s(clock - s.req.arrival_s());
        }

        // --- decode until the whole wave drains ---
        loop {
            let active: Vec<usize> = (0..wave.len())
                .filter(|&i| wave[i].generated < wave[i].req.gen_tokens)
                .collect();
            if active.is_empty() {
                break;
            }
            let batch = active.len() as u64;
            let mean_ctx = active
                .iter()
                .map(|&i| wave[i].req.prompt_tokens + wave[i].generated)
                .sum::<u64>()
                / batch;
            let modeled = decode_latency(dev, spec, kind, batch, mean_ctx, calib);
            clock += match measured.as_deref_mut() {
                None => modeled,
                Some(eng) => eng.execute(batch as usize, modeled),
            };
            steps += 1;
            step_tokens_sum += batch;
            decode_steps += 1;
            decode_lane_steps += batch;
            for &i in &active {
                wave[i].generated += 1;
                gen_tokens += 1;
            }
        }
        for s in &wave {
            kv.free_seq(s.req.id)
                .map_err(|e| anyhow!("releasing KV of wave sequence {}: {e}", s.req.id))?;
            finished += 1;
        }
    }

    Ok(ContinuousResult {
        finished,
        wall_s: clock,
        prompt_tokens,
        gen_tokens,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        total_tok_per_s: (prompt_tokens + gen_tokens) as f64 / clock.max(1e-9),
        steps,
        mean_step_tokens: step_tokens_sum as f64 / steps.max(1) as f64,
        mean_decode_batch: decode_lane_steps as f64 / decode_steps.max(1) as f64,
        prefill_chunks: 0,
        oom: false,
        preemptions: 0,
        mean_ttft_s: ttft.mean_s(),
        prefix_hits: 0,
        prefix_misses: 0,
        prefix_tokens_skipped: 0,
        prefix_evictions: 0,
    })
}

// ---------------------------------------------------------------------------
// Measured twins: the same serving loops with the clock advanced by the
// native StepExecutor runtime instead of the cost model.
// ---------------------------------------------------------------------------

/// Outcome of a measured serving run: the usual serving result (its
/// `wall_s` and throughputs computed on the *measured* clock) plus the
/// runtime's accumulated [`MeasuredStats`].
#[derive(Debug, Clone, Copy)]
pub struct MeasuredRun {
    /// Serving result on the measured clock.
    pub result: ContinuousResult,
    /// Native-runtime totals (executed tokens, GEMM wall, priced comm,
    /// modeled twin seconds).
    pub stats: MeasuredStats,
}

impl MeasuredRun {
    /// Render the serving result plus the measured-runtime summary.
    pub fn report(&self) -> String {
        let s = &self.stats;
        let ratio = match s.modeled_over_measured() {
            Some(v) => format!("{v:.3}"),
            None => "n/a".to_string(),
        };
        let mut r = Report::new();
        r.line(
            "measured",
            format!(
                "{} steps, {} executed tokens, GEMM wall {:.4}s + comm {:.4}s",
                s.steps, s.executed_tokens, s.gemm_wall_s, s.comm_s
            ),
        );
        r.line(
            "modeled twin",
            format!("{:.4}s for the same steps (modeled/measured {ratio})", s.modeled_s),
        );
        format!("{}{}", self.result.report(), r.finish())
    }
}

/// Executor batch capacity a measured run must be provisioned for: the
/// scheduler's token budget, the wave baseline's prefill call cap, and
/// the largest possible decode batch.
fn measured_m_max(policy: &ContinuousPolicy) -> usize {
    policy
        .token_budget
        .max(policy.wave_prefill_tokens)
        .max(policy.max_num_seqs as u64) as usize
}

/// [`simulate_continuous`] with every step executed on the native
/// runtime (see [`MeasuredEngine`]): same scheduler, same prefix cache,
/// same admission — the clock advances by measured GEMM wall time, and
/// every step also feeds the drift ledger against the `calib`-modeled
/// twin. `group_size`/`seed` parameterize the random quantized weights.
#[allow(clippy::too_many_arguments)]
pub fn simulate_continuous_measured(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    backend: StepBackend,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
    group_size: usize,
    seed: u64,
) -> Result<MeasuredRun> {
    simulate_tp_measured(dev, spec, backend, requests, policy, 1, calib, group_size, seed)
}

/// [`simulate_tp`]'s measured twin: `tp_degree` per-rank GEMM streams
/// run concurrently (sharing this host's worker pool) with the ring
/// collectives priced by [`crate::gpusim::tp_step_comm_s`]. Errors if
/// `spec`'s head counts are not divisible by `tp_degree`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_tp_measured(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    backend: StepBackend,
    requests: &[Request],
    policy: &ContinuousPolicy,
    tp_degree: u64,
    calib: &Calib,
    group_size: usize,
    seed: u64,
) -> Result<MeasuredRun> {
    let tp = tp_degree.max(1);
    let kind = backend.kernel_kind();
    let scaled = ContinuousPolicy {
        token_budget: tp_scaled_token_budget(dev, spec, kind, policy, tp, calib),
        ..*policy
    };
    let mut eng = MeasuredEngine::new_codebook(
        dev,
        spec,
        backend,
        tp,
        group_size,
        measured_m_max(&scaled),
        seed,
        scaled.kv_precision,
        calib,
        scaled.codebook,
    )?;
    let result = run_continuous(dev, spec, kind, requests, &scaled, calib, tp, Some(&mut eng))
        .context("measured continuous run")?;
    Ok(MeasuredRun { result, stats: eng.stats })
}

/// [`simulate_static_wave`]'s measured twin — the baseline a measured
/// continuous run is compared against on equal (real) compute.
#[allow(clippy::too_many_arguments)]
pub fn simulate_static_wave_measured(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    backend: StepBackend,
    requests: &[Request],
    policy: &ContinuousPolicy,
    calib: &Calib,
    group_size: usize,
    seed: u64,
) -> Result<MeasuredRun> {
    let mut eng = MeasuredEngine::new_codebook(
        dev,
        spec,
        backend,
        1,
        group_size,
        measured_m_max(policy),
        seed,
        policy.kv_precision,
        calib,
        policy.codebook,
    )?;
    let kind = backend.kernel_kind();
    let result = run_static_wave(dev, spec, kind, requests, policy, calib, Some(&mut eng))
        .context("measured wave run")?;
    Ok(MeasuredRun { result, stats: eng.stats })
}

#[cfg(test)]
mod continuous_tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::{BurstyWorkload, ShareGptLike, SharedPrefixWorkload};

    fn a6000_vicuna() -> (DeviceSpec, LlmSpec) {
        (Gpu::RtxA6000.spec(), Model::Vicuna13B.spec())
    }

    /// Test-local shadows of the public simulators: same signatures,
    /// unwrapped results.
    fn simulate_continuous(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        kind: KernelKind,
        requests: &[Request],
        policy: &ContinuousPolicy,
        calib: &Calib,
    ) -> ContinuousResult {
        super::simulate_continuous(dev, spec, kind, requests, policy, calib).unwrap()
    }

    fn simulate_static_wave(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        kind: KernelKind,
        requests: &[Request],
        policy: &ContinuousPolicy,
        calib: &Calib,
    ) -> ContinuousResult {
        super::simulate_static_wave(dev, spec, kind, requests, policy, calib).unwrap()
    }

    #[allow(clippy::too_many_arguments)]
    fn simulate_tp(
        dev: &DeviceSpec,
        spec: &LlmSpec,
        kind: KernelKind,
        requests: &[Request],
        policy: &ContinuousPolicy,
        tp_degree: u64,
        calib: &Calib,
    ) -> ContinuousResult {
        super::simulate_tp(dev, spec, kind, requests, policy, tp_degree, calib).unwrap()
    }

    #[test]
    fn all_continuous_requests_complete() {
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().offline(100, 7);
        let r = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &Calib::default(),
        );
        assert_eq!(r.finished, 100);
        assert!(!r.oom);
        let want_gen: u64 = reqs.iter().map(|r| r.gen_tokens).sum();
        assert!(r.gen_tokens >= want_gen, "{} < {want_gen}", r.gen_tokens);
        let want_prompt: u64 = reqs.iter().map(|r| r.prompt_tokens).sum();
        assert_eq!(r.prompt_tokens, want_prompt);
        assert!(r.prefill_chunks >= 100);
    }

    #[test]
    fn quantized_kv_pool_serves_the_same_workload() {
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().offline(60, 7);
        let calib = Calib::default();
        let f16 = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &calib,
        );
        let q4 = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy { kv_precision: KvPrecision::Int4, ..Default::default() },
            &calib,
        );
        assert!(!f16.oom && !q4.oom);
        assert_eq!(q4.finished, f16.finished, "precision must not drop requests");
        assert_eq!(q4.gen_tokens, f16.gen_tokens);
        // A ~3.4x-denser pool can only relieve memory pressure.
        assert!(q4.preemptions <= f16.preemptions);
    }

    #[test]
    fn all_wave_requests_complete() {
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().offline(100, 7);
        let r = simulate_static_wave(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &Calib::default(),
        );
        assert_eq!(r.finished, 100);
        assert!(!r.oom);
    }

    #[test]
    fn steps_respect_token_budget() {
        let (dev, spec) = a6000_vicuna();
        let policy = ContinuousPolicy { token_budget: 256, ..Default::default() };
        let reqs = BurstyWorkload::default().offline(60, 3);
        let r = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &policy,
            &Calib::default(),
        );
        assert!(r.mean_step_tokens <= 256.0 + 1e-9);
        assert!(r.mean_step_tokens > 32.0, "budget badly underfilled: {}", r.mean_step_tokens);
    }

    #[test]
    fn continuous_beats_wave_on_bursty_traffic() {
        // Tentpole acceptance: >= 1.3x total token throughput for the
        // QUICK kernel on the bursty workload at equal KV budget.
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().online(250, 1.0, 42);
        let policy = ContinuousPolicy::default();
        let calib = Calib::default();
        let wave = simulate_static_wave(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib);
        let cont = simulate_continuous(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib);
        assert!(!wave.oom && !cont.oom);
        assert_eq!(wave.finished, 250);
        assert_eq!(cont.finished, 250);
        let speedup = cont.total_tok_per_s / wave.total_tok_per_s;
        assert!(
            speedup >= 1.3,
            "continuous {:.1} tok/s only {speedup:.2}x wave {:.1} tok/s",
            cont.total_tok_per_s,
            wave.total_tok_per_s
        );
    }

    #[test]
    fn chunked_prefill_sustains_bigger_mixed_batches() {
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().offline(150, 11);
        let policy = ContinuousPolicy::default();
        let calib = Calib::default();
        let wave = simulate_static_wave(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib);
        let cont = simulate_continuous(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib);
        // The mixed steps keep the GEMM M well above the wave's decode-only
        // steps (that's where the throughput comes from).
        assert!(
            cont.mean_step_tokens > wave.mean_decode_batch * 1.5,
            "mixed steps {:.1} tokens vs wave decode batch {:.1}",
            cont.mean_step_tokens,
            wave.mean_decode_batch
        );
    }

    #[test]
    fn prefix_cache_shrinks_chunks_on_shared_prefixes() {
        // Interop with the automatic prefix cache: shared-prefix traffic
        // skips prefill chunks and speeds up the continuous scheduler.
        let (dev, spec) = a6000_vicuna();
        let reqs = SharedPrefixWorkload::default().offline(200, 9);
        let calib = Calib::default();
        let on = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &calib,
        );
        let off = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy { enable_prefix_cache: false, ..Default::default() },
            &calib,
        );
        assert!(!on.oom && !off.oom);
        assert_eq!(on.finished, reqs.len());
        assert_eq!(off.finished, reqs.len());
        assert!(on.prefix_hits > 0 && on.prefix_tokens_skipped > 0);
        assert!(
            on.total_tok_per_s >= off.total_tok_per_s * 1.15,
            "cache-on {:.1} tok/s !>= 1.15x cache-off {:.1}",
            on.total_tok_per_s,
            off.total_tok_per_s
        );
        assert!(on.mean_ttft_s < off.mean_ttft_s);
    }

    #[test]
    fn disjoint_traffic_unaffected_by_cache() {
        let dev = Gpu::A100.spec();
        let spec = Model::Mistral7B.spec();
        let reqs = ShareGptLike::new().offline(100, 7);
        let calib = Calib::default();
        let on = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &calib,
        );
        let off = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy { enable_prefix_cache: false, ..Default::default() },
            &calib,
        );
        assert_eq!(on.preemptions, 0);
        assert_eq!(on.prefix_tokens_skipped, 0, "disjoint prompts must not hit");
        assert_eq!(on.wall_s, off.wall_s, "cache changed disjoint-workload timing");
        assert_eq!(on.gen_tokens, off.gen_tokens);
    }

    #[test]
    fn preemption_recovers_under_memory_pressure() {
        // A tiny KV pool (high headroom) forces preemptions; every request
        // must still finish exactly once.
        let (dev, spec) = a6000_vicuna();
        let policy = ContinuousPolicy { headroom_frac: 0.78, ..Default::default() };
        let reqs = BurstyWorkload::default().offline(80, 21);
        let r = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &policy,
            &Calib::default(),
        );
        assert!(!r.oom);
        assert_eq!(r.finished, 80);
        assert!(r.preemptions > 0, "pressure run should preempt");
    }

    #[test]
    fn tp_degree_one_is_bit_identical_to_continuous() {
        // simulate_tp at tp=1 must be a controlled baseline: same budget,
        // same pool, bit-identical step latencies -> identical result.
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().offline(80, 17);
        let policy = ContinuousPolicy::default();
        let calib = Calib::default();
        let base = simulate_continuous(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib);
        let tp1 = simulate_tp(&dev, &spec, KernelKind::Quick, &reqs, &policy, 1, &calib);
        assert_eq!(base.wall_s, tp1.wall_s);
        assert_eq!(base.gen_tokens, tp1.gen_tokens);
        assert_eq!(base.steps, tp1.steps);
        assert_eq!(base.finished, tp1.finished);
    }

    #[test]
    fn tp_group_completes_and_speeds_up_the_large_model() {
        // 4-way TP on A100/70B: all requests finish and the group clearly
        // outruns the single GPU on the same workload.
        let dev = Gpu::A100.spec();
        let spec = Model::Llama2_70B.spec();
        let reqs = BurstyWorkload::default().offline(40, 23);
        let policy = ContinuousPolicy::default();
        let calib = Calib::default();
        let tp1 = simulate_tp(&dev, &spec, KernelKind::Quick, &reqs, &policy, 1, &calib);
        let tp4 = simulate_tp(&dev, &spec, KernelKind::Quick, &reqs, &policy, 4, &calib);
        assert!(!tp1.oom && !tp4.oom);
        assert_eq!(tp1.finished, 40);
        assert_eq!(tp4.finished, 40);
        assert!(
            tp4.total_tok_per_s > tp1.total_tok_per_s * 1.5,
            "tp4 {:.1} tok/s not well above tp1 {:.1}",
            tp4.total_tok_per_s,
            tp1.total_tok_per_s
        );
    }

    #[test]
    fn online_continuous_tracks_arrivals() {
        let (dev, spec) = a6000_vicuna();
        let reqs = BurstyWorkload::default().online(120, 0.5, 13);
        let r = simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy::default(),
            &Calib::default(),
        );
        assert_eq!(r.finished, 120);
        // The run can't end before the last arrival.
        assert!(r.wall_s >= reqs.last().unwrap().arrival_s());
    }
}
