//! Serving *simulator*: the continuous-batching engine run against the
//! `gpusim` cost model instead of PJRT, over the paper's full-size models
//! and devices. Regenerates Table 1 and the Fig. 8 batch sweeps.
//!
//! The same scheduling policy as the real [`super::engine`] (prefill
//! priority, FCFS admission) but with (a) simulated time advanced by the
//! kernel cost model, and (b) KV accounting through the paged
//! [`super::kv_cache::KvBlockManager`] sized from the device's free memory
//! — which is how weight-only quantization turns freed weight bytes into
//! batch capacity (paper §4.2).

use std::collections::VecDeque;

use crate::gpusim::kernel_model::{model_gemm, Calib, KernelKind};
use crate::gpusim::DeviceSpec;
use crate::model::LlmSpec;
use crate::workload::Request;

use super::kv_cache::{blocks_for_device, KvBlockManager};

/// Simulation policy knobs (vLLM defaults where applicable).
#[derive(Debug, Clone, Copy)]
pub struct SimPolicy {
    pub max_num_seqs: usize,
    pub block_size: u64,
    pub watermark_frac: f64,
    /// Memory fraction reserved for activations/runtime.
    pub headroom_frac: f64,
    /// Max prompt tokens batched into one prefill step.
    pub max_prefill_tokens: u64,
}

impl Default for SimPolicy {
    fn default() -> Self {
        SimPolicy {
            max_num_seqs: 256,
            block_size: 16,
            watermark_frac: 0.01,
            headroom_frac: 0.10,
            max_prefill_tokens: 4096,
        }
    }
}

/// Outcome of one simulated serving run.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub finished: usize,
    pub wall_s: f64,
    pub prompt_tokens: u64,
    pub gen_tokens: u64,
    /// Generated tokens per second — Table 1's metric.
    pub gen_tok_per_s: f64,
    /// Prompt+generated per second (vLLM's "total token throughput").
    pub total_tok_per_s: f64,
    pub mean_batch: f64,
    pub oom: bool,
    pub preemptions: u64,
}

struct RunningSeq {
    req: Request,
    generated: u64,
}

/// Latency of a (possibly batched) prefill totalling `tokens` prompt tokens.
fn prefill_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    tokens: u64,
    calib: &Calib,
) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let mut t = 0.0;
    for g in spec.gemms() {
        t += model_gemm(dev, kind, tokens, g.n, g.k, calib).latency_s * g.count as f64;
    }
    // Prefill attention: O(T^2 d) flops on tensor cores, usually minor vs
    // the 7 weight GEMMs at these prompt lengths.
    let attn_flops = 2.0 * 2.0 * (tokens * tokens) as f64 * spec.d_model as f64
        * spec.n_layers as f64;
    t + attn_flops / (dev.tc_tflops * 1e12 * calib.mma_eff)
}

fn decode_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    mean_ctx: u64,
    calib: &Calib,
) -> f64 {
    crate::gpusim::decode_step_latency(dev, spec, kind, batch, mean_ctx.max(1), calib)
        .total_s()
}

/// Run the continuous-batching simulation over an offline workload (all
/// requests queued at t=0, like vLLM's throughput benchmark).
pub fn simulate_serving(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &SimPolicy,
    calib: &Calib,
) -> SimResult {
    let w4 = !matches!(kind, KernelKind::Fp16);
    let kv_per_token =
        (2 * spec.n_layers * spec.kv_heads * spec.head_dim()) as f64 * 2.0;
    let blocks = blocks_for_device(
        dev.mem_bytes(),
        spec.weight_bytes(w4),
        kv_per_token,
        policy.block_size,
        policy.headroom_frac,
    );
    if blocks == 0 {
        return SimResult {
            finished: 0,
            wall_s: 0.0,
            prompt_tokens: 0,
            gen_tokens: 0,
            gen_tok_per_s: 0.0,
            total_tok_per_s: 0.0,
            mean_batch: 0.0,
            oom: true,
            preemptions: 0,
        };
    }

    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac);
    let mut waiting: VecDeque<Request> = requests.iter().copied().collect();
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut clock = 0.0f64;
    let mut prompt_tokens = 0u64;
    let mut gen_tokens = 0u64;
    let mut finished = 0usize;
    let mut decode_steps = 0u64;
    let mut decode_lane_steps = 0u64;
    let mut preemptions = 0u64;

    while !waiting.is_empty() || !running.is_empty() {
        // --- admission: batch prefills while budget allows ---
        let mut prefill_batch_tokens = 0u64;
        while let Some(&req) = waiting.front() {
            if running.len() >= policy.max_num_seqs
                || prefill_batch_tokens + req.prompt_tokens > policy.max_prefill_tokens
                || !kv.can_admit(req.prompt_tokens)
            {
                break;
            }
            waiting.pop_front();
            kv.allocate(req.id, req.prompt_tokens).expect("admission checked");
            prompt_tokens += req.prompt_tokens;
            prefill_batch_tokens += req.prompt_tokens;
            running.push(RunningSeq { req, generated: 0 });
        }
        if prefill_batch_tokens > 0 {
            clock += prefill_latency(dev, spec, kind, prefill_batch_tokens, calib);
            // The prefill's last-token logits yield each admitted
            // sequence's first generated token (vLLM counts it this way).
            for r in running.iter_mut().filter(|r| r.generated == 0) {
                r.generated = 1;
                gen_tokens += 1;
                let _ = kv.append_token(r.req.id);
            }
        }

        if running.is_empty() {
            if waiting.is_empty() {
                break;
            }
            // Workload item larger than the whole pool: drop it (vLLM
            // would reject it too).
            let r = waiting.pop_front().unwrap();
            let _ = r;
            continue;
        }

        // --- one decode step over all running sequences ---
        let batch = running.len() as u64;
        let mean_ctx = running
            .iter()
            .map(|r| r.req.prompt_tokens + r.generated)
            .sum::<u64>()
            / batch;
        clock += decode_latency(dev, spec, kind, batch, mean_ctx, calib);
        decode_steps += 1;
        decode_lane_steps += batch;

        let mut i = 0;
        while i < running.len() {
            let r = &mut running[i];
            r.generated += 1;
            gen_tokens += 1;
            if r.generated >= r.req.gen_tokens {
                kv.free_seq(r.req.id).expect("finished seq has blocks");
                finished += 1;
                running.swap_remove(i);
                continue;
            }
            if kv.append_token(r.req.id).is_err() {
                // Preempt the newest sequence (vLLM recompute policy):
                // free its blocks and push it back on the queue.
                let victim = running.swap_remove(i);
                kv.free_seq(victim.req.id).expect("victim has blocks");
                preemptions += 1;
                let mut back = victim.req;
                back.gen_tokens -= victim.generated.min(back.gen_tokens - 1);
                waiting.push_back(back);
                continue;
            }
            i += 1;
        }
    }

    SimResult {
        finished,
        wall_s: clock,
        prompt_tokens,
        gen_tokens,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        total_tok_per_s: (prompt_tokens + gen_tokens) as f64 / clock.max(1e-9),
        mean_batch: if decode_steps == 0 {
            0.0
        } else {
            decode_lane_steps as f64 / decode_steps as f64
        },
        oom: false,
        preemptions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::ShareGptLike;

    fn run(kind: KernelKind, model: Model) -> SimResult {
        let reqs = ShareGptLike::new().offline(300, 42);
        simulate_serving(
            &Gpu::RtxA6000.spec(),
            &model.spec(),
            kind,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
    }

    #[test]
    fn table1_vicuna_ordering() {
        // Table 1: QUICK > AWQ > FP16 on Vicuna-13B/A6000.
        let fp = run(KernelKind::Fp16, Model::Vicuna13B);
        let awq = run(KernelKind::Awq, Model::Vicuna13B);
        let quick = run(KernelKind::Quick, Model::Vicuna13B);
        assert!(!fp.oom && !awq.oom && !quick.oom);
        assert!(quick.gen_tok_per_s > awq.gen_tok_per_s, "{quick:?} vs {awq:?}");
        assert!(awq.gen_tok_per_s > fp.gen_tok_per_s * 0.9, "{awq:?} vs {fp:?}");
    }

    #[test]
    fn table1_llama70b_fp16_oom() {
        let fp = run(KernelKind::Fp16, Model::Llama2_70B);
        assert!(fp.oom);
        let quick = run(KernelKind::Quick, Model::Llama2_70B);
        assert!(!quick.oom && quick.gen_tok_per_s > 0.0);
    }

    #[test]
    fn all_requests_complete() {
        let reqs = ShareGptLike::new().offline(100, 7);
        let r = simulate_serving(
            &Gpu::A100.spec(),
            &Model::Mistral7B.spec(),
            KernelKind::Quick,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        );
        assert_eq!(r.finished, 100);
        let want: u64 = reqs.iter().map(|r| r.gen_tokens).sum();
        assert!(r.gen_tokens >= want, "{} < {}", r.gen_tokens, want);
    }

    #[test]
    fn quantized_sustains_bigger_batches() {
        let fp = run(KernelKind::Fp16, Model::Vicuna13B);
        let quick = run(KernelKind::Quick, Model::Vicuna13B);
        assert!(
            quick.mean_batch > fp.mean_batch,
            "quick batch {} !> fp16 batch {}",
            quick.mean_batch,
            fp.mean_batch
        );
    }
}

// ---------------------------------------------------------------------------
// Online serving (Poisson arrivals): latency percentiles vs offered load.
// ---------------------------------------------------------------------------

/// Per-request latency sample from an online simulation.
#[derive(Debug, Clone, Copy)]
pub struct OnlineLatency {
    pub request_id: u64,
    pub e2e_s: f64,
}

/// Result of an online (open-loop) serving simulation.
#[derive(Debug, Clone)]
pub struct OnlineResult {
    pub finished: usize,
    pub wall_s: f64,
    pub gen_tok_per_s: f64,
    pub latencies: Vec<OnlineLatency>,
    pub oom: bool,
}

impl OnlineResult {
    pub fn e2e_quantile_s(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut xs: Vec<f64> = self.latencies.iter().map(|l| l.e2e_s).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = (q.clamp(0.0, 1.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx]
    }

    pub fn mean_e2e_s(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        self.latencies.iter().map(|l| l.e2e_s).sum::<f64>() / self.latencies.len() as f64
    }
}

/// Open-loop simulation: requests arrive at their `arrival_s`; the engine
/// runs prefill-priority continuous batching under the same KV accounting
/// as [`simulate_serving`]. Used for latency-vs-load curves (not a paper
/// figure — an extension the serving community expects; see
/// `quick-infer loadtest`).
pub fn simulate_online(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    requests: &[Request],
    policy: &SimPolicy,
    calib: &Calib,
) -> OnlineResult {
    let w4 = !matches!(kind, KernelKind::Fp16);
    let kv_per_token =
        (2 * spec.n_layers * spec.kv_heads * spec.head_dim()) as f64 * 2.0;
    let blocks = blocks_for_device(
        dev.mem_bytes(),
        spec.weight_bytes(w4),
        kv_per_token,
        policy.block_size,
        policy.headroom_frac,
    );
    if blocks == 0 {
        return OnlineResult {
            finished: 0,
            wall_s: 0.0,
            gen_tok_per_s: 0.0,
            latencies: vec![],
            oom: true,
        };
    }
    let mut kv = KvBlockManager::new(blocks, policy.block_size, policy.watermark_frac);
    let mut pending: VecDeque<Request> = requests.iter().copied().collect();
    let mut waiting: VecDeque<Request> = VecDeque::new();
    let mut running: Vec<RunningSeq> = Vec::new();
    let mut clock = 0.0f64;
    let mut gen_tokens = 0u64;
    let mut latencies = Vec::with_capacity(requests.len());

    loop {
        // Move arrived requests into the queue.
        while pending.front().map_or(false, |r| r.arrival_s() <= clock) {
            waiting.push_back(pending.pop_front().unwrap());
        }
        if waiting.is_empty() && running.is_empty() {
            match pending.front() {
                Some(r) => {
                    clock = r.arrival_s(); // idle until next arrival
                    continue;
                }
                None => break,
            }
        }

        // Admission + prefill batch.
        let mut prefill_tokens = 0u64;
        while let Some(&req) = waiting.front() {
            if running.len() >= policy.max_num_seqs
                || prefill_tokens + req.prompt_tokens > policy.max_prefill_tokens
                || !kv.can_admit(req.prompt_tokens)
            {
                break;
            }
            waiting.pop_front();
            kv.allocate(req.id, req.prompt_tokens).expect("checked");
            prefill_tokens += req.prompt_tokens;
            running.push(RunningSeq { req, generated: 0 });
        }
        if prefill_tokens > 0 {
            clock += prefill_latency(dev, spec, kind, prefill_tokens, calib);
            for r in running.iter_mut().filter(|r| r.generated == 0) {
                r.generated = 1;
                gen_tokens += 1;
                let _ = kv.append_token(r.req.id);
            }
        }
        if running.is_empty() {
            continue;
        }

        // One decode step.
        let batch = running.len() as u64;
        let mean_ctx = running
            .iter()
            .map(|r| r.req.prompt_tokens + r.generated)
            .sum::<u64>()
            / batch;
        clock += decode_latency(dev, spec, kind, batch, mean_ctx, calib);

        let mut i = 0;
        while i < running.len() {
            let r = &mut running[i];
            r.generated += 1;
            gen_tokens += 1;
            if r.generated >= r.req.gen_tokens {
                kv.free_seq(r.req.id).expect("blocks");
                latencies.push(OnlineLatency {
                    request_id: r.req.id,
                    e2e_s: clock - r.req.arrival_s(),
                });
                running.swap_remove(i);
                continue;
            }
            if kv.append_token(r.req.id).is_err() {
                let victim = running.swap_remove(i);
                kv.free_seq(victim.req.id).expect("blocks");
                let mut back = victim.req;
                back.gen_tokens -= victim.generated.min(back.gen_tokens - 1);
                waiting.push_back(back);
                continue;
            }
            i += 1;
        }
    }

    OnlineResult {
        finished: latencies.len(),
        wall_s: clock,
        gen_tok_per_s: gen_tokens as f64 / clock.max(1e-9),
        latencies,
        oom: false,
    }
}

#[cfg(test)]
mod online_tests {
    use super::*;
    use crate::gpusim::Gpu;
    use crate::model::Model;
    use crate::workload::ShareGptLike;

    fn run_online(rate: f64, kind: KernelKind) -> OnlineResult {
        let reqs = ShareGptLike::new().online(150, rate, 11);
        simulate_online(
            &Gpu::RtxA6000.spec(),
            &Model::Vicuna13B.spec(),
            kind,
            &reqs,
            &SimPolicy::default(),
            &Calib::default(),
        )
    }

    #[test]
    fn all_online_requests_finish() {
        let r = run_online(2.0, KernelKind::Quick);
        assert_eq!(r.finished, 150);
        assert!(!r.oom);
    }

    #[test]
    fn latency_grows_with_offered_load() {
        let light = run_online(0.5, KernelKind::Quick);
        let heavy = run_online(20.0, KernelKind::Quick);
        assert!(
            heavy.mean_e2e_s() > light.mean_e2e_s(),
            "heavy {} !> light {}",
            heavy.mean_e2e_s(),
            light.mean_e2e_s()
        );
    }

    #[test]
    fn quick_sustains_lower_latency_than_awq_under_load() {
        let q = run_online(6.0, KernelKind::Quick);
        let a = run_online(6.0, KernelKind::Awq);
        assert!(
            q.e2e_quantile_s(0.9) < a.e2e_quantile_s(0.9),
            "p90 quick {} !< awq {}",
            q.e2e_quantile_s(0.9),
            a.e2e_quantile_s(0.9)
        );
    }

    #[test]
    fn quantiles_monotone() {
        let r = run_online(4.0, KernelKind::Quick);
        assert!(r.e2e_quantile_s(0.5) <= r.e2e_quantile_s(0.9));
        assert!(r.e2e_quantile_s(0.9) <= r.e2e_quantile_s(0.99));
    }
}
