//! Paper-figure harnesses: each function regenerates one table/figure of
//! the evaluation section and prints the same rows/series the paper
//! reports (DESIGN.md §6). Shared by the CLI (`quick-infer simulate`), the
//! `paper_figures` example, and the criterion benches.

use std::io::Write;
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::coordinator::faults::{
    run_chaos, ChaosPolicy, ChaosResult, FaultEvent, FaultKind, FaultPlan, Scenario, ShedPolicy,
    SloSpec,
};
use crate::coordinator::measured::{measured_bursty, measured_shared_prefix};
use crate::coordinator::simserve::{
    simulate_continuous, simulate_continuous_measured, simulate_serving, simulate_static_wave,
    simulate_static_wave_measured, simulate_tp, simulate_tp_measured, ContinuousPolicy,
    ContinuousResult, MeasuredRun, SimPolicy, SimResult,
};
use crate::gpusim::kernel_model::{
    calibrate_step_writeback, calibrate_writeback, model_gemm, Calib, KernelKind,
};
use crate::gpusim::{
    calibrate_kv_attn, kv_attn_term, max_batch_before_oom, tokens_per_second, tp_step_latency, Gpu,
};
use crate::kernel::{
    attn_dense_tiled, attn_quant_fused, gemm_awq_writeback, gemm_quick_fused, max_rel_err,
    naive_attention, simd_level, AttnConfig, AwqWeights, AwqWritebackBackend, Blocking,
    KernelBackend, NaiveBackend, PlanCache, QuickFusedBackend, QuickWeights, StepBackend,
    StepExecutor, WorkerPool,
};
use crate::model::Model;
use crate::obs::DriftAccountant;
use crate::quant::{
    dequantize_kv, quantize_groupwise, quantize_groupwise_codebook, quantize_kv, CodebookKind,
    DecoderKind, KvPrecision, KV_GROUP,
};
use crate::util::{Bench, Rng};
use crate::workload::{BurstyWorkload, Request, ShareGptLike, SharedPrefixWorkload};

/// Figure 3 — shared-memory bank conflicts, 64x8192x8192 GEMM.
pub fn fig3(out: &mut impl Write) -> Result<Fig3Data> {
    let calib = Calib::default();
    let dev = Gpu::Rtx4090.spec();
    writeln!(out, "\n== Figure 3: smem bank conflicts (64x8192x8192, {}) ==", dev.name)?;
    writeln!(out, "{:8} {:>16} {:>14} {:>10}", "kernel", "wb conflicts", "wb multiplier", "TOPS")?;
    let mut data = Fig3Data::default();
    for kind in KernelKind::ALL {
        let p = model_gemm(&dev, kind, 64, 8192, 8192, &calib);
        writeln!(
            out,
            "{:8} {:>16} {:>14.2} {:>10.1}",
            kind.label(),
            p.conflicts,
            p.conflict_multiplier,
            p.tops
        )?;
        match kind {
            KernelKind::Awq => data.awq_conflicts = p.conflicts,
            KernelKind::Quick => data.quick_conflicts = p.conflicts,
            KernelKind::Fp16 => data.fp16_conflicts = p.conflicts,
        }
    }
    writeln!(
        out,
        "paper: original kernel shows heavy write-back conflicts; QUICK ~0"
    )?;
    Ok(data)
}

#[derive(Debug, Default, Clone, Copy)]
pub struct Fig3Data {
    /// Write-back conflicts, fp16 kernel (none: no dequant).
    pub fp16_conflicts: u64,
    /// Write-back conflicts, AWQ baseline (the Fig. 3 spike).
    pub awq_conflicts: u64,
    /// Write-back conflicts, QUICK (zero by construction).
    pub quick_conflicts: u64,
}

/// Batch sizes (GEMM M) swept by Figure 7.
pub const FIG7_BATCHES: [u64; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Figure 7 — kernel TOPS vs batch on all four devices.
pub fn fig7(out: &mut impl Write) -> Result<Vec<Fig7Row>> {
    let calib = Calib::default();
    let mut rows = Vec::new();
    for gpu in Gpu::ALL {
        let dev = gpu.spec();
        writeln!(out, "\n== Figure 7: batch x 8192 x 8192 GEMM TOPS on {} ==", dev.name)?;
        writeln!(out, "{:>6} {:>10} {:>10} {:>10} {:>12}", "batch", "fp16", "AWQ", "QUICK", "QUICK/AWQ")?;
        for m in FIG7_BATCHES {
            let f = model_gemm(&dev, KernelKind::Fp16, m, 8192, 8192, &calib);
            let a = model_gemm(&dev, KernelKind::Awq, m, 8192, 8192, &calib);
            let q = model_gemm(&dev, KernelKind::Quick, m, 8192, 8192, &calib);
            writeln!(
                out,
                "{:>6} {:>10.2} {:>10.2} {:>10.2} {:>11.2}x",
                m,
                f.tops,
                a.tops,
                q.tops,
                q.tops / a.tops
            )?;
            rows.push(Fig7Row { gpu, batch: m, fp16: f.tops, awq: a.tops, quick: q.tops });
        }
    }
    // Paper §4.1 headline: 1.33–1.91x over AWQ at batch 256.
    let speedups: Vec<f64> = rows
        .iter()
        .filter(|r| r.batch == 256)
        .map(|r| r.quick / r.awq)
        .collect();
    let lo = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = speedups.iter().cloned().fold(0.0, f64::max);
    writeln!(out, "\nQUICK/AWQ speedup @256 across devices: {lo:.2}x – {hi:.2}x (paper: 1.33–1.91x)")?;
    Ok(rows)
}

#[derive(Debug, Clone, Copy)]
pub struct Fig7Row {
    /// Device of this row.
    pub gpu: Gpu,
    /// GEMM M (batch size).
    pub batch: u64,
    /// fp16 kernel TOPS.
    pub fp16: f64,
    /// AWQ baseline TOPS.
    pub awq: f64,
    /// QUICK kernel TOPS.
    pub quick: f64,
}

/// The (model, device, decode-context) triples of Figure 8. Contexts are
/// chosen to match the paper's memory narrative: Mistral-7B/4090 at 512
/// reproduces "fp16 impossible at batch 256, 4-bit possible" (§4.2); the
/// MHA 13B/33B models use 256 (0.8-1.6 MB/token KV would otherwise OOM the
/// quantized runs before the paper's largest plotted batches).
pub const FIG8_PAIRS: [(Model, Gpu, u64); 4] = [
    (Model::Mistral7B, Gpu::Rtx4090, 512),
    (Model::Vicuna13B, Gpu::RtxA6000, 256),
    (Model::Llama2_13B, Gpu::L40, 256),
    (Model::Llama33B, Gpu::A100, 256),
];

/// Batch sizes swept by Figure 8.
pub const FIG8_BATCHES: [u64; 7] = [1, 8, 16, 32, 64, 128, 256];

/// Figure 8 — end-to-end decode throughput vs batch, with OOM cutoffs.
pub fn fig8(out: &mut impl Write) -> Result<Vec<Fig8Row>> {
    let calib = Calib::default();
    let mut rows = Vec::new();
    for (model, gpu, ctx) in FIG8_PAIRS {
        let dev = gpu.spec();
        let spec = model.spec();
        writeln!(out, "\n== Figure 8: {} on {} (tokens/s, ctx {}) ==", spec.name, dev.name, ctx)?;
        writeln!(out, "{:>6} {:>10} {:>10} {:>10}", "batch", "fp16", "AWQ", "QUICK")?;
        let fp16_max = max_batch_before_oom(&dev, &spec, false, ctx);
        let w4_max = max_batch_before_oom(&dev, &spec, true, ctx);
        for b in FIG8_BATCHES {
            let fmt = |kind: KernelKind, maxb: u64| -> (String, f64) {
                if b > maxb {
                    ("OOM".into(), 0.0)
                } else {
                    let t = tokens_per_second(&dev, &spec, kind, b, ctx, &calib);
                    (format!("{t:.0}"), t)
                }
            };
            let (fs, fv) = fmt(KernelKind::Fp16, fp16_max);
            let (as_, av) = fmt(KernelKind::Awq, w4_max);
            let (qs, qv) = fmt(KernelKind::Quick, w4_max);
            writeln!(out, "{:>6} {:>10} {:>10} {:>10}", b, fs, as_, qs)?;
            rows.push(Fig8Row { model, gpu, batch: b, fp16: fv, awq: av, quick: qv });
        }
        writeln!(out, "fp16 max batch: {fp16_max}; 4-bit max batch: {w4_max}")?;
    }
    Ok(rows)
}

#[derive(Debug, Clone, Copy)]
pub struct Fig8Row {
    /// Model of this row.
    pub model: Model,
    /// Device of this row.
    pub gpu: Gpu,
    /// Decode batch size.
    pub batch: u64,
    /// fp16 tokens/s (0.0 = OOM).
    pub fp16: f64,
    /// AWQ tokens/s (0.0 = OOM).
    pub awq: f64,
    /// QUICK tokens/s (0.0 = OOM).
    pub quick: f64,
}

/// Table 1 — vLLM-style serving throughput on A6000.
pub fn table1(out: &mut impl Write) -> Result<Vec<Table1Row>> {
    let calib = Calib::default();
    let dev = Gpu::RtxA6000.spec();
    // The paper benchmarked vLLM without automatic prefix caching; keep
    // the cache off so the reproduced absolutes stay a controlled
    // baseline (preempted requests would otherwise re-hit their own
    // prompts and drift the memory-tight rows). figures::prefix_cache
    // reports the cache's effect separately.
    let policy = SimPolicy { enable_prefix_cache: false, ..SimPolicy::default() };
    let reqs = ShareGptLike::new().offline(1000, 2024);
    let mut rows = Vec::new();
    writeln!(out, "\n== Table 1: serving throughput, {} (1000 ShareGPT-like reqs) ==", dev.name)?;
    writeln!(
        out,
        "{:14} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "model", "FP16", "AWQ", "QUICK", "vs FP16", "vs AWQ"
    )?;
    for model in [Model::Vicuna13B, Model::Llama2_70B] {
        let spec = model.spec();
        let run = |kind| simulate_serving(&dev, &spec, kind, &reqs, &policy, &calib);
        let fp = run(KernelKind::Fp16)?;
        let awq = run(KernelKind::Awq)?;
        let quick = run(KernelKind::Quick)?;
        // vLLM's benchmark_throughput reports *total* token throughput
        // (prompt + generated) — the convention Table 1's absolute numbers
        // follow; our simulated absolutes land close to the paper's under
        // the same convention (see EXPERIMENTS.md).
        let f = |r: &crate::coordinator::simserve::SimResult| {
            if r.oom { "OOM".to_string() } else { format!("{:.1}", r.total_tok_per_s) }
        };
        let vs_fp = if fp.oom {
            "-".into()
        } else {
            format!("{:+.0}%", (quick.total_tok_per_s / fp.total_tok_per_s - 1.0) * 100.0)
        };
        let vs_awq = format!("{:+.0}%", (quick.total_tok_per_s / awq.total_tok_per_s - 1.0) * 100.0);
        writeln!(
            out,
            "{:14} {:>10} {:>10} {:>10} {:>12} {:>12}",
            spec.name,
            f(&fp),
            f(&awq),
            f(&quick),
            vs_fp,
            vs_awq
        )?;
        rows.push(Table1Row { model, fp16: fp, awq, quick });
    }
    writeln!(out, "paper: Vicuna-13B 985.2 / 1030.4 / 1308.6 (+33% / +27%); Llama-2-70B OOM / 224.3 / 290.2 (+29%)")?;
    Ok(rows)
}

#[derive(Debug, Clone, Copy)]
pub struct Table1Row {
    /// Model of this row.
    pub model: Model,
    /// fp16 serving result.
    pub fp16: crate::coordinator::simserve::SimResult,
    /// AWQ serving result.
    pub awq: crate::coordinator::simserve::SimResult,
    /// QUICK serving result.
    pub quick: crate::coordinator::simserve::SimResult,
}

/// Automatic-prefix-cache evaluation (not a paper figure — the serving
/// extension Table 1 monetizes): QUICK on A6000/Vicuna-13B, cache on vs
/// off at equal KV budget, over a shared-prefix chat workload and a
/// disjoint ShareGPT-like control.
pub fn prefix_cache(out: &mut impl Write) -> Result<PrefixCacheReport> {
    let calib = Calib::default();
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let on_policy = SimPolicy::default();
    let off_policy = SimPolicy { enable_prefix_cache: false, ..SimPolicy::default() };
    let shared = SharedPrefixWorkload::default().offline(300, 2025);
    let disjoint = ShareGptLike::new().offline(300, 2025);

    let run = |reqs: &[crate::workload::Request], policy: &SimPolicy| {
        simulate_serving(&dev, &spec, KernelKind::Quick, reqs, policy, &calib)
    };
    let report = PrefixCacheReport {
        shared_on: run(&shared, &on_policy)?,
        shared_off: run(&shared, &off_policy)?,
        disjoint_on: run(&disjoint, &on_policy)?,
        disjoint_off: run(&disjoint, &off_policy)?,
    };

    writeln!(
        out,
        "\n== Prefix cache: {} on {}, QUICK, 300 reqs/workload ==",
        spec.name, dev.name
    )?;
    writeln!(
        out,
        "{:22} {:>6} {:>12} {:>12} {:>10} {:>10}",
        "workload", "cache", "tok/s", "mean TTFT", "hit rate", "evictions"
    )?;
    let mut row = |name: &str, cache: &str, r: &SimResult| {
        writeln!(
            out,
            "{:22} {:>6} {:>12.1} {:>11.3}s {:>9.0}% {:>10}",
            name,
            cache,
            r.total_tok_per_s,
            r.mean_ttft_s,
            r.prefix_hit_rate() * 100.0,
            r.prefix_evictions
        )
    };
    row("shared-prefix chat", "on", &report.shared_on)?;
    row("shared-prefix chat", "off", &report.shared_off)?;
    row("disjoint ShareGPT", "on", &report.disjoint_on)?;
    row("disjoint ShareGPT", "off", &report.disjoint_off)?;
    writeln!(
        out,
        "prefix cache hit rate: {:.0}% ({} hits / {} misses), {} prompt tokens skipped \
         -> {:.2}x throughput, {:.2}x TTFT on shared prefixes",
        report.shared_on.prefix_hit_rate() * 100.0,
        report.shared_on.prefix_hits,
        report.shared_on.prefix_misses,
        report.shared_on.prefix_tokens_skipped,
        report.throughput_speedup(),
        report.shared_on.mean_ttft_s / report.shared_off.mean_ttft_s.max(1e-9),
    )?;
    Ok(report)
}

/// Continuous-batching evaluation (the scheduler rewrite the paper's
/// batch-scaling results motivate): QUICK and AWQ on A6000/Vicuna-13B over
/// the bursty bimodal workload, token-budget continuous batching with
/// chunked prefill vs the static prefill-then-decode wave baseline — plus
/// the QUICK-vs-AWQ end-to-end gap as offered load grows, the serving-level
/// image of Figure 7's batch axis.
pub fn continuous_batching(out: &mut impl Write) -> Result<ContinuousBatchingReport> {
    let calib = Calib::default();
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let policy = ContinuousPolicy::default();
    let reqs = BurstyWorkload::default().online(250, 1.0, 2026);

    let run_wave = |kind| simulate_static_wave(&dev, &spec, kind, &reqs, &policy, &calib);
    let run_cont = |kind| simulate_continuous(&dev, &spec, kind, &reqs, &policy, &calib);
    let mut report = ContinuousBatchingReport {
        wave_awq: run_wave(KernelKind::Awq)?,
        cont_awq: run_cont(KernelKind::Awq)?,
        wave_quick: run_wave(KernelKind::Quick)?,
        cont_quick: run_cont(KernelKind::Quick)?,
        gap_rows: Vec::new(),
    };

    writeln!(
        out,
        "\n== Continuous batching: {} on {}, bursty bimodal workload (250 reqs) ==",
        spec.name, dev.name
    )?;
    writeln!(
        out,
        "{:8} {:12} {:>10} {:>10} {:>11} {:>12} {:>8}",
        "kernel", "scheduler", "tok/s", "gen tok/s", "mean TTFT", "step tokens", "preempt"
    )?;
    let mut row = |kernel: &str, sched: &str, r: &ContinuousResult| {
        writeln!(
            out,
            "{:8} {:12} {:>10.1} {:>10.1} {:>10.2}s {:>12.1} {:>8}",
            kernel,
            sched,
            r.total_tok_per_s,
            r.gen_tok_per_s,
            r.mean_ttft_s,
            r.mean_step_tokens,
            r.preemptions
        )
    };
    row("AWQ", "static wave", &report.wave_awq)?;
    row("AWQ", "continuous", &report.cont_awq)?;
    row("QUICK", "static wave", &report.wave_quick)?;
    row("QUICK", "continuous", &report.cont_quick)?;
    writeln!(
        out,
        "continuous/wave speedup: QUICK {:.2}x, AWQ {:.2}x (acceptance bar: 1.3x)",
        report.quick_speedup(),
        report.cont_awq.total_tok_per_s / report.wave_awq.total_tok_per_s.max(1e-9),
    )?;

    writeln!(out, "\n-- QUICK/AWQ end-to-end gap vs offered load (continuous) --")?;
    writeln!(
        out,
        "{:>12} {:>12} {:>12} {:>10} {:>12}",
        "bursts/s", "AWQ tok/s", "QUICK tok/s", "gap", "mean batch"
    )?;
    for rate in [0.125, 0.25, 0.5, 1.0, 2.0] {
        let reqs = BurstyWorkload::default().online(200, rate, 7);
        let a = simulate_continuous(&dev, &spec, KernelKind::Awq, &reqs, &policy, &calib)?;
        let q = simulate_continuous(&dev, &spec, KernelKind::Quick, &reqs, &policy, &calib)?;
        writeln!(
            out,
            "{:>12.3} {:>12.1} {:>12.1} {:>9.2}x {:>12.1}",
            rate,
            a.gen_tok_per_s,
            q.gen_tok_per_s,
            q.gen_tok_per_s / a.gen_tok_per_s.max(1e-9),
            q.mean_decode_batch
        )?;
        report.gap_rows.push(GapRow { rate, awq: a, quick: q });
    }
    writeln!(
        out,
        "paper Fig. 7 at serving level: the gap widens with load as sustained \
         batches reach the region where AWQ's write-back stalls dominate"
    )?;
    Ok(report)
}

/// Batch sizes (GEMM M) swept by the measured native-kernel figure — the
/// M axis of the paper's Fig. 7, batch 1 → 256.
pub const KERNEL_MATMUL_BATCHES: [usize; 5] = [1, 8, 32, 128, 256];

/// One batch point of the measured native-kernel M-sweep.
#[derive(Debug, Clone, Copy)]
pub struct KernelMatmulRow {
    /// GEMM M (batch size).
    pub m: usize,
    /// Measured GFLOP/s, fused-from-interleaved path.
    pub fused_gflops: f64,
    /// Measured GFLOP/s, dequant-to-scratch write-back path.
    pub writeback_gflops: f64,
    /// Measured median wall seconds per fused GEMM.
    pub fused_s: f64,
    /// Measured median wall seconds per write-back GEMM.
    pub writeback_s: f64,
}

impl KernelMatmulRow {
    /// Fused over write-back throughput at this batch.
    pub fn speedup(&self) -> f64 {
        self.fused_gflops / self.writeback_gflops.max(1e-12)
    }
}

/// Result set of [`kernel_matmul`]: the measured sweep plus the
/// differential gate and the measured-cost calibration of the GPU model.
#[derive(Debug, Clone)]
pub struct KernelMatmulReport {
    /// Weight in-features (reduction axis).
    pub k: usize,
    /// Weight out-features.
    pub n: usize,
    /// Quantization group length along K.
    pub group_size: usize,
    /// One row per swept batch, ascending.
    pub rows: Vec<KernelMatmulRow>,
    /// Max relative error of the fused path vs the naive reference.
    pub fused_rel_err: f64,
    /// Max relative error of the write-back path vs the naive reference.
    pub writeback_rel_err: f64,
    /// `gpusim` calibration whose write-back penalty is fit to the
    /// *measured* fused/write-back gap at the largest swept batch.
    pub calibrated: Calib,
}

impl KernelMatmulReport {
    /// The differential gate: both optimized paths within 1e-4 relative
    /// error of the naive reference.
    pub fn within_tolerance(&self) -> bool {
        self.fused_rel_err <= 1e-4 && self.writeback_rel_err <= 1e-4
    }

    /// The row for batch `m` (panics if the batch was not swept).
    pub fn row(&self, m: usize) -> &KernelMatmulRow {
        self.rows
            .iter()
            .find(|r| r.m == m)
            .unwrap_or_else(|| panic!("batch {m} not swept"))
    }
}

/// Measured native-kernel M-sweep (the executable analogue of Figure 7):
/// `gemm_quick_fused` vs `gemm_awq_writeback` on this host's CPU, default
/// 1024x1024 g128 layer, batch 1 → 256. Absolute GFLOP/s are
/// host-dependent; the fused-over-write-back *gap* is the paper's
/// mechanism. Run via `quick-infer simulate kernel-matmul`; the
/// 4096x4096 acceptance sweep lives in `quick-infer bench kernels`.
pub fn kernel_matmul(out: &mut impl Write) -> Result<KernelMatmulReport> {
    kernel_matmul_with(out, 1024, 1024, 128, &KERNEL_MATMUL_BATCHES, &Bench::fast())
}

/// [`kernel_matmul`] with explicit layer shape, batch list, and bench
/// configuration (the CLI and CI smoke pass smaller ones). The report
/// rows go to `out`; the bench harness additionally prints raw per-run
/// lines to stdout unless the caller passes a [`Bench::silent`] runner.
pub fn kernel_matmul_with(
    out: &mut impl Write,
    k: usize,
    n: usize,
    group_size: usize,
    batches: &[usize],
    bench: &Bench,
) -> Result<KernelMatmulReport> {
    anyhow::ensure!(!batches.is_empty(), "batch list must be non-empty");
    writeln!(
        out,
        "\n== Measured native W4A16 kernels: {k}x{n} g{group_size}, batch sweep (this CPU) =="
    )?;
    let mut rng = Rng::seed_from_u64(0x51C4);
    let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let t = quantize_groupwise(&w, k, n, group_size);
    drop(w);
    let naive = NaiveBackend::from_quantized(&t);
    let fused = QuickFusedBackend::new(&t, Blocking::default());
    let writeback = AwqWritebackBackend::new(&t, Blocking::default());

    // Differential gate at a fixed small batch before any timing.
    let gate_m = 8usize;
    let x_gate: Vec<f32> = (0..gate_m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let mut y_ref = vec![0f32; gate_m * n];
    let mut y_opt = vec![0f32; gate_m * n];
    naive.gemm(&x_gate, gate_m, &mut y_ref);
    fused.gemm(&x_gate, gate_m, &mut y_opt);
    let fused_rel_err = max_rel_err(&y_opt, &y_ref);
    writeback.gemm(&x_gate, gate_m, &mut y_opt);
    let writeback_rel_err = max_rel_err(&y_opt, &y_ref);
    writeln!(
        out,
        "differential gate vs naive reference (m={gate_m}): fused {fused_rel_err:.2e}, \
         write-back {writeback_rel_err:.2e} (bar 1e-4)"
    )?;

    writeln!(
        out,
        "{:>6} {:>14} {:>14} {:>14}",
        "batch", "fused GF/s", "wb GF/s", "fused/wb"
    )?;
    let mut rows = Vec::new();
    for &m in batches {
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut y = vec![0f32; m * n];
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let rf = bench.run(&format!("gemm_quick_fused {k}x{n} m{m}"), || {
            fused.gemm(&x, m, &mut y);
            y[0]
        });
        let rw = bench.run(&format!("gemm_awq_writeback {k}x{n} m{m}"), || {
            writeback.gemm(&x, m, &mut y);
            y[0]
        });
        let row = KernelMatmulRow {
            m,
            fused_gflops: flops / rf.median_ns,
            writeback_gflops: flops / rw.median_ns,
            fused_s: rf.median_ns / 1e9,
            writeback_s: rw.median_ns / 1e9,
        };
        writeln!(
            out,
            "{:>6} {:>14.2} {:>14.2} {:>13.2}x",
            m, row.fused_gflops, row.writeback_gflops, row.speedup()
        )?;
        rows.push(row);
    }

    // Engine hook: fit the GPU model's write-back penalty to the gap we
    // just *measured*, so simserve/kernel_model queries can run on
    // measured rather than modeled tile costs.
    let last = rows[rows.len() - 1];
    let calibrated = calibrate_writeback(
        &Gpu::Rtx4090.spec(),
        last.m as u64,
        n as u64,
        k as u64,
        last.fused_s,
        last.writeback_s,
        &Calib::default(),
    );
    writeln!(
        out,
        "measured wb/fused gap at m={}: {:.2}x -> calibrated gpusim writeback_scale {:.3} \
         (default 1.0)",
        last.m,
        last.writeback_s / last.fused_s.max(1e-12),
        calibrated.writeback_scale
    )?;
    writeln!(
        out,
        "paper Fig. 7 mechanism on CPU: the interleaved stream feeds the microkernel \
         fragments directly; the write-back path pays the scratch round-trip AWQ pays \
         through shared memory"
    )?;
    Ok(KernelMatmulReport {
        k,
        n,
        group_size,
        rows,
        fused_rel_err,
        writeback_rel_err,
        calibrated,
    })
}

/// Decode batch sizes (GEMM M) swept by [`decode_sweep`] and
/// [`step_throughput`] — the shapes where dispatch overhead and decode
/// cost, not arithmetic, decide the outcome.
pub const DECODE_SWEEP_BATCHES: [usize; 4] = [1, 2, 4, 8];

/// One decode-shape point of the runtime-tier sweep: the fused path
/// under each (dispatch, microkernel) tier, the write-back path under
/// the full runtime, and the measured per-call dispatch overhead.
#[derive(Debug, Clone, Copy)]
pub struct DecodeSweepRow {
    /// GEMM M (decode batch).
    pub m: usize,
    /// Fused path, persistent pool + SIMD (the full runtime).
    pub fused_pool_simd_gflops: f64,
    /// Fused path, persistent pool + scalar microkernel/decoders.
    pub fused_pool_scalar_gflops: f64,
    /// Fused path, spawn-per-call threads + SIMD.
    pub fused_spawn_simd_gflops: f64,
    /// Fused path, spawn-per-call + scalar — the PR 4 baseline.
    pub fused_spawn_scalar_gflops: f64,
    /// Write-back path under the full runtime (pool + SIMD).
    pub writeback_pool_simd_gflops: f64,
    /// Median ns to dispatch a no-op job through the pool at this
    /// shape's task/thread counts (pure dispatch overhead, no GEMM).
    pub pool_dispatch_ns: f64,
    /// Median ns for the same no-op job via spawn-per-call threads.
    pub spawn_dispatch_ns: f64,
    /// Median ns for the pooled no-op dispatch with the span tracer
    /// enabled — `pool_dispatch_traced_ns - pool_dispatch_ns` is the
    /// per-dispatch tracing tax the obs layer charges.
    pub pool_dispatch_traced_ns: f64,
}

impl DecodeSweepRow {
    /// Full runtime (pool + SIMD) over the PR 4 spawn-per-call scalar
    /// baseline — the tentpole's acceptance ratio.
    pub fn runtime_speedup(&self) -> f64 {
        self.fused_pool_simd_gflops / self.fused_spawn_scalar_gflops.max(1e-12)
    }

    /// Fused over write-back under the full runtime (must stay >= 1x:
    /// the paper's gap must survive the shared speedups).
    pub fn fused_over_writeback(&self) -> f64 {
        self.fused_pool_simd_gflops / self.writeback_pool_simd_gflops.max(1e-12)
    }
}

/// Result set of [`decode_sweep`].
#[derive(Debug, Clone)]
pub struct DecodeSweepReport {
    /// Weight in-features (reduction axis).
    pub k: usize,
    /// Weight out-features.
    pub n: usize,
    /// Quantization group length along K.
    pub group_size: usize,
    /// SIMD tier the `simd: true` rows ran at (`avx2`/`neon`/`scalar`).
    pub simd_level: &'static str,
    /// One row per swept batch, ascending.
    pub rows: Vec<DecodeSweepRow>,
    /// Max relative error of the full-runtime fused path vs naive.
    pub fused_rel_err: f64,
    /// Max relative error of the full-runtime write-back path vs naive.
    pub writeback_rel_err: f64,
}

impl DecodeSweepReport {
    /// The differential gate: both runtime paths within 1e-4 of naive.
    pub fn within_tolerance(&self) -> bool {
        self.fused_rel_err <= 1e-4 && self.writeback_rel_err <= 1e-4
    }

    /// The row for batch `m` (panics if the batch was not swept).
    pub fn row(&self, m: usize) -> &DecodeSweepRow {
        self.rows.iter().find(|r| r.m == m).unwrap_or_else(|| panic!("batch {m} not swept"))
    }
}

/// Decode-shape runtime sweep (the tentpole's measurement): the fused
/// path at M ∈ {1, 2, 4, 8} under every (dispatch, microkernel) tier —
/// persistent pool vs PR 4 spawn-per-call, SIMD vs scalar — plus the
/// write-back path under the full runtime and the no-op dispatch
/// overhead measured separately from GFLOP/s. Default 4096x4096 g128
/// layer via `bench kernels`.
pub fn decode_sweep(out: &mut impl Write) -> Result<DecodeSweepReport> {
    decode_sweep_with(out, 4096, 4096, 128, &DECODE_SWEEP_BATCHES, &Bench::fast())
}

/// [`decode_sweep`] with explicit layer shape, batch list, and bench
/// configuration (CLI and CI smoke pass smaller ones).
pub fn decode_sweep_with(
    out: &mut impl Write,
    k: usize,
    n: usize,
    group_size: usize,
    batches: &[usize],
    bench: &Bench,
) -> Result<DecodeSweepReport> {
    anyhow::ensure!(!batches.is_empty(), "batch list must be non-empty");
    writeln!(
        out,
        "\n== Decode-shape runtime sweep: {k}x{n} g{group_size}, simd tier '{}' (this CPU) ==",
        simd_level()
    )?;
    let mut rng = Rng::seed_from_u64(0xDEC0);
    let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let t = quantize_groupwise(&w, k, n, group_size);
    drop(w);
    let qw = QuickWeights::from_quantized(&t);
    let aw = AwqWeights::from_quantized(&t);

    let pool_simd = Blocking::default();
    let pool_scalar = Blocking { simd: false, ..Blocking::default() };
    let spawn_simd = Blocking { pool: false, ..Blocking::default() };
    let spawn_scalar = Blocking { simd: false, pool: false, ..Blocking::default() };

    // Differential gate: the full runtime vs the naive reference, once,
    // at the largest swept batch — M >= 4 exercises the SIMD
    // microkernel's 4-row main accumulator loop (small M only hits the
    // remainder loop) and the pooled dispatch path.
    let naive = NaiveBackend::from_quantized(&t);
    let gate_m = batches.iter().copied().max().unwrap_or(1);
    let x_gate: Vec<f32> = (0..gate_m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let mut y_ref = vec![0f32; gate_m * n];
    let mut y_opt = vec![0f32; gate_m * n];
    naive.gemm(&x_gate, gate_m, &mut y_ref);
    gemm_quick_fused(&x_gate, gate_m, &qw, &pool_simd, &mut y_opt)?;
    let fused_rel_err = max_rel_err(&y_opt, &y_ref);
    gemm_awq_writeback(&x_gate, gate_m, &aw, &pool_simd, &mut y_opt)?;
    let writeback_rel_err = max_rel_err(&y_opt, &y_ref);
    writeln!(
        out,
        "differential gate vs naive (m={gate_m}): fused {fused_rel_err:.2e}, \
         write-back {writeback_rel_err:.2e} (bar 1e-4)"
    )?;

    writeln!(
        out,
        "{:>4} {:>11} {:>11} {:>11} {:>11} {:>11} {:>9} {:>10} {:>10} {:>10}",
        "m",
        "pool+simd",
        "pool+scal",
        "spawn+simd",
        "spawn+scal",
        "wb pool",
        "runtime x",
        "disp pool",
        "disp spawn",
        "disp trace"
    )?;
    let mut rows = Vec::new();
    for &m in batches {
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut y = vec![0f32; m * n];
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let mut gf = |name: &str, b: &Blocking, fused: bool| -> Result<f64> {
            let r = if fused {
                bench.run(&format!("fused {name} {k}x{n} m{m}"), || {
                    gemm_quick_fused(&x, m, &qw, b, &mut y).expect("fused gemm");
                    y[0]
                })
            } else {
                bench.run(&format!("writeback {name} {k}x{n} m{m}"), || {
                    gemm_awq_writeback(&x, m, &aw, b, &mut y).expect("writeback gemm");
                    y[0]
                })
            };
            Ok(flops / r.median_ns)
        };
        let fused_pool_simd_gflops = gf("pool+simd", &pool_simd, true)?;
        let fused_pool_scalar_gflops = gf("pool+scalar", &pool_scalar, true)?;
        let fused_spawn_simd_gflops = gf("spawn+simd", &spawn_simd, true)?;
        let fused_spawn_scalar_gflops = gf("spawn+scalar", &spawn_scalar, true)?;
        let writeback_pool_simd_gflops = gf("pool+simd", &pool_simd, false)?;
        // Dispatch overhead: the same tile/thread geometry, zero work —
        // what each dispatch tier charges per call before any math runs.
        let plan = PlanCache::global().plan(m, k, n, &pool_simd)?;
        let (tasks, threads) = (plan.tasks.len(), plan.threads);
        let pool_dispatch_ns = bench
            .run(&format!("dispatch pool m{m} ({tasks}t/{threads}w)"), || {
                WorkerPool::global().run(tasks, threads, &|_t, _s| {});
            })
            .median_ns;
        let spawn_dispatch_ns = bench
            .run(&format!("dispatch spawn m{m} ({tasks}t/{threads}w)"), || {
                crate::kernel::partition::spawn_run(tasks, threads, &|_t, _s| {});
            })
            .median_ns;
        // Same pooled dispatch with the span tracer live: the delta is
        // the obs layer's per-dispatch tax, reported next to the raw
        // number so regressions show up in `bench check`.
        let was_tracing = crate::obs::trace::enabled();
        crate::obs::trace::enable();
        let pool_dispatch_traced_ns = bench
            .run(&format!("dispatch pool traced m{m} ({tasks}t/{threads}w)"), || {
                WorkerPool::global().run(tasks, threads, &|_t, _s| {});
            })
            .median_ns;
        if !was_tracing {
            crate::obs::trace::disable();
        }
        let row = DecodeSweepRow {
            m,
            fused_pool_simd_gflops,
            fused_pool_scalar_gflops,
            fused_spawn_simd_gflops,
            fused_spawn_scalar_gflops,
            writeback_pool_simd_gflops,
            pool_dispatch_ns,
            spawn_dispatch_ns,
            pool_dispatch_traced_ns,
        };
        writeln!(
            out,
            "{:>4} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>8.2}x {:>10} {:>10} {:>10}",
            m,
            row.fused_pool_simd_gflops,
            row.fused_pool_scalar_gflops,
            row.fused_spawn_simd_gflops,
            row.fused_spawn_scalar_gflops,
            row.writeback_pool_simd_gflops,
            row.runtime_speedup(),
            crate::util::bench::fmt_ns(row.pool_dispatch_ns),
            crate::util::bench::fmt_ns(row.spawn_dispatch_ns),
            crate::util::bench::fmt_ns(row.pool_dispatch_traced_ns),
        )?;
        rows.push(row);
    }
    let worst_gap = rows
        .iter()
        .map(DecodeSweepRow::fused_over_writeback)
        .fold(f64::INFINITY, f64::min);
    writeln!(
        out,
        "runtime speedup (pool+simd over PR4 spawn+scalar) at m={}: {:.2}x (bar 1.5x); \
         fused/write-back min over sweep: {:.2}x (bar 1.0x)",
        rows.last().map(|r| r.m).unwrap_or(0),
        rows.last().map(DecodeSweepRow::runtime_speedup).unwrap_or(0.0),
        worst_gap
    )?;
    Ok(DecodeSweepReport {
        k,
        n,
        group_size,
        simd_level: simd_level(),
        rows,
        fused_rel_err,
        writeback_rel_err,
    })
}

/// One decode-batch point of the LUT-vs-shift-mask decoder sweep: the
/// fused path on the uniform INT4 grid under both decode tiers, plus the
/// non-uniform codebooks (NF4 / MXFP4), which only the LUT tier can
/// decode.
#[derive(Debug, Clone, Copy)]
pub struct LutSweepRow {
    /// GEMM M (decode batch).
    pub m: usize,
    /// Uniform INT4, arithmetic shift-mask decoder (the incumbent).
    pub shift_mask_gflops: f64,
    /// Uniform INT4 through the byte-shuffle LUT decoder — same bits in,
    /// same floats out, different expansion engine.
    pub lut_int4_gflops: f64,
    /// NF4 codebook through the LUT decoder.
    pub lut_nf4_gflops: f64,
    /// MXFP4 codebook through the LUT decoder.
    pub lut_mxfp4_gflops: f64,
}

impl LutSweepRow {
    /// LUT-INT4 over shift-mask on identical weights — the tentpole's
    /// "LUT does not regress the uniform path" ratio (bar 1.0x).
    pub fn lut_over_shift(&self) -> f64 {
        self.lut_int4_gflops / self.shift_mask_gflops.max(1e-12)
    }

    /// Worst non-uniform codebook over LUT-INT4 at this batch: the table
    /// contents must not change the decode cost (bar 0.95x).
    pub fn nonuniform_over_int4(&self) -> f64 {
        self.lut_nf4_gflops.min(self.lut_mxfp4_gflops) / self.lut_int4_gflops.max(1e-12)
    }
}

/// Result set of [`lut_sweep`].
#[derive(Debug, Clone)]
pub struct LutSweepReport {
    /// Weight in-features (reduction axis).
    pub k: usize,
    /// Weight out-features.
    pub n: usize,
    /// Quantization group length along K.
    pub group_size: usize,
    /// SIMD tier the sweep ran at (`avx2`/`neon`/`scalar`).
    pub simd_level: &'static str,
    /// One row per swept batch, ascending.
    pub rows: Vec<LutSweepRow>,
    /// Max relative error of the fused LUT path vs naive-on-dequantized,
    /// taken over all three codebooks at the largest swept batch.
    pub lut_rel_err: f64,
}

impl LutSweepReport {
    /// The differential gate: every LUT decode path within 1e-4 of the
    /// naive reference on its own codebook.
    pub fn within_tolerance(&self) -> bool {
        self.lut_rel_err <= 1e-4
    }

    /// The row for batch `m` (panics if the batch was not swept).
    pub fn row(&self, m: usize) -> &LutSweepRow {
        self.rows.iter().find(|r| r.m == m).unwrap_or_else(|| panic!("batch {m} not swept"))
    }

    /// LUT-INT4 over shift-mask at the largest swept batch — the
    /// acceptance ratio `bench check` gates on.
    pub fn lut_speedup(&self) -> f64 {
        self.rows.last().map(LutSweepRow::lut_over_shift).unwrap_or(0.0)
    }

    /// Min over the sweep of the worst non-uniform/INT4-LUT ratio: NF4
    /// and MXFP4 must track uniform-INT4 LUT throughput.
    pub fn min_nonuniform_over_int4(&self) -> f64 {
        self.rows.iter().map(LutSweepRow::nonuniform_over_int4).fold(f64::INFINITY, f64::min)
    }
}

/// LUT-vs-shift-mask decoder sweep (`bench kernels --lut`): the fused
/// path at M ∈ {1, 2, 4, 8} on one uniform-INT4 layer under both decode
/// tiers, and on NF4/MXFP4 re-quantizations of the same weights under
/// the LUT tier, with a differential gate per codebook. Default
/// 4096x4096 g128 layer.
pub fn lut_sweep(out: &mut impl Write) -> Result<LutSweepReport> {
    lut_sweep_with(out, 4096, 4096, 128, &DECODE_SWEEP_BATCHES, &Bench::fast())
}

/// [`lut_sweep`] with explicit layer shape, batch list, and bench
/// configuration (CLI and CI smoke pass smaller ones).
pub fn lut_sweep_with(
    out: &mut impl Write,
    k: usize,
    n: usize,
    group_size: usize,
    batches: &[usize],
    bench: &Bench,
) -> Result<LutSweepReport> {
    anyhow::ensure!(!batches.is_empty(), "batch list must be non-empty");
    writeln!(
        out,
        "\n== LUT decoder sweep: {k}x{n} g{group_size}, simd tier '{}' (this CPU) ==",
        simd_level()
    )?;
    let mut rng = Rng::seed_from_u64(0x10D4);
    let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    // One tensor per codebook. INT4 shift-mask and INT4 LUT share bits —
    // only the Blocking's decoder differs — so any throughput delta is
    // the expansion engine, not the data.
    let tensors = [
        quantize_groupwise_codebook(&w, k, n, group_size, CodebookKind::Int4Uniform),
        quantize_groupwise_codebook(&w, k, n, group_size, CodebookKind::Nf4),
        quantize_groupwise_codebook(&w, k, n, group_size, CodebookKind::Mxfp4),
    ];
    drop(w);
    let weights: Vec<QuickWeights> = tensors.iter().map(QuickWeights::from_quantized).collect();

    let shift_b = Blocking::default();
    let lut_b = Blocking { decoder: DecoderKind::Lut, ..Blocking::default() };

    // Differential gate: each codebook's fused LUT path vs the naive
    // reference on that codebook's own dequantized weights, at the
    // largest swept batch.
    let gate_m = batches.iter().copied().max().unwrap_or(1);
    let x_gate: Vec<f32> = (0..gate_m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let mut lut_rel_err = 0.0f64;
    for (t, qw) in tensors.iter().zip(&weights) {
        let naive = NaiveBackend::from_quantized(t);
        let mut y_ref = vec![0f32; gate_m * n];
        let mut y_opt = vec![0f32; gate_m * n];
        naive.gemm(&x_gate, gate_m, &mut y_ref);
        gemm_quick_fused(&x_gate, gate_m, qw, &lut_b, &mut y_opt)?;
        lut_rel_err = lut_rel_err.max(max_rel_err(&y_opt, &y_ref));
    }
    writeln!(
        out,
        "differential gate vs naive (m={gate_m}, all codebooks): lut {lut_rel_err:.2e} (bar 1e-4)"
    )?;

    writeln!(
        out,
        "{:>4} {:>11} {:>11} {:>11} {:>11} {:>9} {:>9}",
        "m", "shift-mask", "lut int4", "lut nf4", "lut mxfp4", "lut/shft", "nonuni x"
    )?;
    let mut rows = Vec::new();
    for &m in batches {
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let mut y = vec![0f32; m * n];
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let mut gf = |name: &str, qw: &QuickWeights, b: &Blocking| -> Result<f64> {
            let r = bench.run(&format!("lut sweep {name} {k}x{n} m{m}"), || {
                gemm_quick_fused(&x, m, qw, b, &mut y).expect("fused gemm");
                y[0]
            });
            Ok(flops / r.median_ns)
        };
        let row = LutSweepRow {
            m,
            shift_mask_gflops: gf("shift int4", &weights[0], &shift_b)?,
            lut_int4_gflops: gf("lut int4", &weights[0], &lut_b)?,
            lut_nf4_gflops: gf("lut nf4", &weights[1], &lut_b)?,
            lut_mxfp4_gflops: gf("lut mxfp4", &weights[2], &lut_b)?,
        };
        writeln!(
            out,
            "{:>4} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>8.2}x {:>8.2}x",
            m,
            row.shift_mask_gflops,
            row.lut_int4_gflops,
            row.lut_nf4_gflops,
            row.lut_mxfp4_gflops,
            row.lut_over_shift(),
            row.nonuniform_over_int4(),
        )?;
        rows.push(row);
    }
    let report = LutSweepReport { k, n, group_size, simd_level: simd_level(), rows, lut_rel_err };
    writeln!(
        out,
        "lut/shift-mask at m={}: {:.2}x (bar 1.0x); worst nonuniform/int4-lut over \
         sweep: {:.2}x (bar 0.95x)",
        report.rows.last().map(|r| r.m).unwrap_or(0),
        report.lut_speedup(),
        report.min_nonuniform_over_int4()
    )?;
    Ok(report)
}

/// One batch point of the measured end-to-end step sweep.
#[derive(Debug, Clone, Copy)]
pub struct StepThroughputRow {
    /// Decode batch (tokens per step).
    pub m: usize,
    /// Median wall seconds per fused step.
    pub fused_s: f64,
    /// Median wall seconds per write-back step.
    pub writeback_s: f64,
    /// Fused tokens/sec (`m / fused_s`).
    pub fused_tok_s: f64,
    /// Write-back tokens/sec.
    pub writeback_tok_s: f64,
}

impl StepThroughputRow {
    /// Fused over write-back step throughput.
    pub fn speedup(&self) -> f64 {
        self.fused_tok_s / self.writeback_tok_s.max(1e-12)
    }
}

/// Result set of [`step_throughput`]: measured decode tokens/sec for one
/// full model step plus the step-fitted GPU-model calibration.
#[derive(Debug, Clone)]
pub struct StepThroughputReport {
    /// Model whose GEMM stream ran.
    pub model: Model,
    /// Quantization group size used.
    pub group_size: usize,
    /// One row per swept batch, ascending.
    pub rows: Vec<StepThroughputRow>,
    /// `gpusim` calibration whose write-back penalty is fit to the
    /// measured fused/write-back *step* gap at the largest swept batch
    /// ([`calibrate_step_writeback`]).
    pub calibrated: Calib,
}

impl StepThroughputReport {
    /// The row for batch `m` (panics if the batch was not swept).
    pub fn row(&self, m: usize) -> &StepThroughputRow {
        self.rows.iter().find(|r| r.m == m).unwrap_or_else(|| panic!("batch {m} not swept"))
    }
}

/// Measured end-to-end decode-step throughput (`simulate step`): run the
/// whole [`crate::model::LlmSpec::gemms`] stream of `model` through the
/// fused and write-back backends via [`StepExecutor`] at decode batches
/// M ∈ {1, 2, 4, 8}, report tokens/sec, and fit the GPU model's
/// write-back penalty to the measured *step* gap — the first measured
/// end-to-end number `gpusim`/`simserve` can calibrate against.
pub fn step_throughput(out: &mut impl Write, model: Model) -> Result<StepThroughputReport> {
    step_throughput_with(
        out,
        model,
        128,
        &DECODE_SWEEP_BATCHES,
        &Bench::fast(),
        CodebookKind::Int4Uniform,
    )
}

/// [`step_throughput`] with explicit group size, batch list, bench
/// configuration, and weight codebook (`simulate step --codebook nf4`
/// runs the whole GEMM stream through the LUT decode tier).
pub fn step_throughput_with(
    out: &mut impl Write,
    model: Model,
    group_size: usize,
    batches: &[usize],
    bench: &Bench,
    codebook: CodebookKind,
) -> Result<StepThroughputReport> {
    anyhow::ensure!(!batches.is_empty(), "batch list must be non-empty");
    let spec = model.spec();
    let m_max = batches.iter().copied().max().unwrap_or(1);
    writeln!(
        out,
        "\n== Measured decode step: {} ({} weight GEMMs/step, g{group_size}, {} weights, this CPU) ==",
        spec.name,
        spec.gemms().iter().map(|g| g.count).sum::<u64>(),
        codebook.label()
    )?;
    let b = Blocking::default();
    let mut fused = StepExecutor::new_codebook(
        &spec,
        StepBackend::Fused,
        b,
        group_size,
        m_max,
        0x57E9,
        codebook,
    )?;
    let mut wb = StepExecutor::new_codebook(
        &spec,
        StepBackend::Writeback,
        b,
        group_size,
        m_max,
        0x57E9,
        codebook,
    )?;
    // Drift accountant: every measured GEMM also records its
    // gpusim-modeled latency, so `report obs` can surface the running
    // modeled/measured ratio per shape.
    let drift_dev = Gpu::Rtx4090.spec();
    let drift_calib = Calib::default();
    fused.enable_drift(&drift_dev, &drift_calib);
    wb.enable_drift(&drift_dev, &drift_calib);
    writeln!(
        out,
        "{:>4} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "m", "fused tok/s", "wb tok/s", "fused step", "wb step", "fused/wb"
    )?;
    let mut rows = Vec::new();
    for &m in batches {
        let rf = bench.run(&format!("step fused {} m{m}", spec.name), || {
            fused.step(m).expect("fused step").wall_s
        });
        let rw = bench.run(&format!("step writeback {} m{m}", spec.name), || {
            wb.step(m).expect("writeback step").wall_s
        });
        let row = StepThroughputRow {
            m,
            fused_s: rf.median_ns / 1e9,
            writeback_s: rw.median_ns / 1e9,
            fused_tok_s: m as f64 / (rf.median_ns / 1e9),
            writeback_tok_s: m as f64 / (rw.median_ns / 1e9),
        };
        writeln!(
            out,
            "{:>4} {:>12.1} {:>12.1} {:>12} {:>12} {:>9.2}x",
            m,
            row.fused_tok_s,
            row.writeback_tok_s,
            crate::util::bench::fmt_ns(rf.median_ns),
            crate::util::bench::fmt_ns(rw.median_ns),
            row.speedup()
        )?;
        rows.push(row);
    }
    // Engine hook: fit the GPU model's write-back penalty to the
    // *measured step* gap, so simserve/kernel_model queries can run on
    // an end-to-end-calibrated cost model.
    let last = rows[rows.len() - 1];
    let calibrated = calibrate_step_writeback(
        &Gpu::Rtx4090.spec(),
        &spec,
        last.m as u64,
        last.fused_s,
        last.writeback_s,
        &Calib::default(),
    );
    writeln!(
        out,
        "measured step wb/fused gap at m={}: {:.2}x -> step-calibrated gpusim \
         writeback_scale {:.3} (default 1.0)",
        last.m,
        last.writeback_s / last.fused_s.max(1e-12),
        calibrated.writeback_scale
    )?;
    Ok(StepThroughputReport { model, group_size, rows, calibrated })
}

/// KV context lengths (rows) swept by [`attention_sweep`].
pub const ATTN_SWEEP_SEQS: [usize; 3] = [128, 512, 2048];

/// Decode batches (query rows) swept by [`attention_sweep`].
pub const ATTN_SWEEP_BATCHES: [usize; 3] = [1, 4, 16];

/// One `(seq, m)` point of the measured fused dequant-attention sweep.
#[derive(Debug, Clone, Copy)]
pub struct AttnSweepRow {
    /// KV rows (context length).
    pub seq: usize,
    /// Query rows (decode batch).
    pub m: usize,
    /// Measured GFLOP/s, fused attention over 4-bit KV.
    pub q4_gflops: f64,
    /// Measured GFLOP/s, fused attention over 8-bit KV.
    pub q8_gflops: f64,
    /// Measured GFLOP/s, dense-tiled f32 baseline ("f16 KV").
    pub dense_gflops: f64,
}

impl AttnSweepRow {
    /// Fused 4-bit over dense-baseline throughput at this point.
    pub fn q4_over_dense(&self) -> f64 {
        self.q4_gflops / self.dense_gflops.max(1e-12)
    }
}

/// Result set of [`attention_sweep`]: the measured `(seq, m)` sweep plus
/// the differential gate against the f64 naive reference.
#[derive(Debug, Clone)]
pub struct AttnSweepReport {
    /// Head dimension.
    pub d: usize,
    /// KV quantization group along the head dimension.
    pub group: usize,
    /// One row per swept `(seq, m)`, seq-major ascending.
    pub rows: Vec<AttnSweepRow>,
    /// Max relative error of the fused 4-bit path vs [`naive_attention`]
    /// run *on the same dequantized KV* — the gate measures kernel
    /// arithmetic, not quantization loss.
    pub q4_rel_err: f64,
    /// Max relative error of the fused 8-bit path vs the reference.
    pub q8_rel_err: f64,
    /// Max relative error of the dense-tiled path vs the reference.
    pub dense_rel_err: f64,
}

impl AttnSweepReport {
    /// The differential gate: every attention path within 1e-4 relative
    /// error of the f64 naive reference, debug and release.
    pub fn within_tolerance(&self) -> bool {
        self.q4_rel_err <= 1e-4 && self.q8_rel_err <= 1e-4 && self.dense_rel_err <= 1e-4
    }

    /// The row at `(seq, m)` (panics if the point was not swept).
    pub fn row(&self, seq: usize, m: usize) -> &AttnSweepRow {
        self.rows
            .iter()
            .find(|r| r.seq == seq && r.m == m)
            .unwrap_or_else(|| panic!("(seq {seq}, m {m}) not swept"))
    }
}

/// Measured fused dequant-attention sweep (the KV-cache analogue of
/// [`kernel_matmul`]): [`attn_quant_fused`] at 4 and 8 bits vs the
/// [`attn_dense_tiled`] f32 baseline on this host's CPU, across context
/// lengths and decode batches. Absolute GFLOP/s are host-dependent; the
/// point is the differential gate plus the quantized stream reading
/// ~2x/~3.4x fewer KV bytes per token on a bandwidth-bound shape. Run
/// via `quick-infer bench kernels --attention`.
pub fn attention_sweep(out: &mut impl Write) -> Result<AttnSweepReport> {
    attention_sweep_with(out, 128, KV_GROUP, &ATTN_SWEEP_SEQS, &ATTN_SWEEP_BATCHES, &Bench::fast())
}

/// [`attention_sweep`] with explicit head dim, group, sweep lists, and
/// bench configuration (the CLI `--quick` path and CI smoke pass smaller
/// ones).
pub fn attention_sweep_with(
    out: &mut impl Write,
    d: usize,
    group: usize,
    seqs: &[usize],
    batches: &[usize],
    bench: &Bench,
) -> Result<AttnSweepReport> {
    anyhow::ensure!(!seqs.is_empty() && !batches.is_empty(), "seq/batch lists must be non-empty");
    anyhow::ensure!(
        group % 8 == 0 && d % group == 0,
        "head dim {d} not divisible by 8-aligned group {group} (KV packing contract)"
    );
    writeln!(
        out,
        "\n== Measured fused dequant-attention: d={d} g{group}, (seq x batch) sweep (this CPU) =="
    )?;
    let seq_max = *seqs.iter().max().unwrap();
    let m_max = *batches.iter().max().unwrap();
    let scale = 1.0 / (d as f32).sqrt();
    let cfg = AttnConfig::default();
    let mut rng = Rng::seed_from_u64(0xA77E);
    let k: Vec<f32> = (0..seq_max * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let v: Vec<f32> = (0..seq_max * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let q: Vec<f32> = (0..m_max * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();

    // Differential gate at the largest swept shape (covers multi-tile
    // streaming and the threaded path), against the f64 reference on the
    // *dequantized* KV so kernel error is isolated from quantization
    // error — same bar as the GEMM gate in [`kernel_matmul_with`].
    let kq4 = quantize_kv(&k, seq_max, d, group, 4);
    let vq4 = quantize_kv(&v, seq_max, d, group, 4);
    let kq8 = quantize_kv(&k, seq_max, d, group, 8);
    let vq8 = quantize_kv(&v, seq_max, d, group, 8);
    let mut y_ref = vec![0f32; m_max * d];
    let mut y = vec![0f32; m_max * d];
    naive_attention(
        &q,
        &dequantize_kv(&kq4),
        &dequantize_kv(&vq4),
        m_max,
        seq_max,
        d,
        scale,
        &mut y_ref,
    );
    attn_quant_fused(&q, &kq4, &vq4, m_max, scale, &cfg, &mut y)?;
    let q4_rel_err = max_rel_err(&y, &y_ref);
    naive_attention(
        &q,
        &dequantize_kv(&kq8),
        &dequantize_kv(&vq8),
        m_max,
        seq_max,
        d,
        scale,
        &mut y_ref,
    );
    attn_quant_fused(&q, &kq8, &vq8, m_max, scale, &cfg, &mut y)?;
    let q8_rel_err = max_rel_err(&y, &y_ref);
    naive_attention(&q, &k, &v, m_max, seq_max, d, scale, &mut y_ref);
    attn_dense_tiled(&q, &k, &v, m_max, seq_max, d, scale, &cfg, &mut y)?;
    let dense_rel_err = max_rel_err(&y, &y_ref);
    writeln!(
        out,
        "differential gate vs naive reference (seq={seq_max}, m={m_max}): kv4 {q4_rel_err:.2e}, \
         kv8 {q8_rel_err:.2e}, dense {dense_rel_err:.2e} (bar 1e-4)"
    )?;

    writeln!(
        out,
        "{:>6} {:>5} {:>12} {:>12} {:>12} {:>10}",
        "seq", "m", "kv4 GF/s", "kv8 GF/s", "dense GF/s", "kv4/dense"
    )?;
    let mut rows = Vec::new();
    for &seq in seqs {
        let ks = &k[..seq * d];
        let vs = &v[..seq * d];
        let kq4 = quantize_kv(ks, seq, d, group, 4);
        let vq4 = quantize_kv(vs, seq, d, group, 4);
        let kq8 = quantize_kv(ks, seq, d, group, 8);
        let vq8 = quantize_kv(vs, seq, d, group, 8);
        for &m in batches {
            let qs = &q[..m * d];
            let flops = 4.0 * m as f64 * seq as f64 * d as f64;
            let ys = &mut y[..m * d];
            let r4 = bench.run(&format!("attn_quant_fused kv4 d{d} s{seq} m{m}"), || {
                attn_quant_fused(qs, &kq4, &vq4, m, scale, &cfg, ys).expect("kv4 attention");
                ys[0]
            });
            let r8 = bench.run(&format!("attn_quant_fused kv8 d{d} s{seq} m{m}"), || {
                attn_quant_fused(qs, &kq8, &vq8, m, scale, &cfg, ys).expect("kv8 attention");
                ys[0]
            });
            let rd = bench.run(&format!("attn_dense_tiled d{d} s{seq} m{m}"), || {
                attn_dense_tiled(qs, ks, vs, m, seq, d, scale, &cfg, ys).expect("dense attention");
                ys[0]
            });
            let row = AttnSweepRow {
                seq,
                m,
                q4_gflops: flops / r4.median_ns,
                q8_gflops: flops / r8.median_ns,
                dense_gflops: flops / rd.median_ns,
            };
            writeln!(
                out,
                "{:>6} {:>5} {:>12.2} {:>12.2} {:>12.2} {:>9.2}x",
                seq, m, row.q4_gflops, row.q8_gflops, row.dense_gflops, row.q4_over_dense()
            )?;
            rows.push(row);
        }
    }
    writeln!(
        out,
        "paper mechanism at the KV cache: the quantized stream reads ~2x (kv8) / ~3.4x (kv4) \
         fewer bytes per token and decodes in-register — no scratch round-trip, the same \
         deleted write-back that wins the weight GEMMs"
    )?;
    Ok(AttnSweepReport { d, group, rows, q4_rel_err, q8_rel_err, dense_rel_err })
}

/// One precision row of the [`kv_cache_quant`] density table.
#[derive(Debug, Clone, Copy)]
pub struct KvDensityRow {
    /// Storage precision.
    pub precision: KvPrecision,
    /// Effective bytes per stored element at [`KV_GROUP`] (metadata
    /// amortized in).
    pub bytes_per_elem: f64,
    /// Tokens one 16-f16-token block slab holds at this precision.
    pub tokens_per_block: u64,
    /// Resident-token density relative to f16.
    pub density_x: f64,
}

/// Result set of [`kv_cache_quant`]: the byte accounting, the modeled
/// serving comparison, and the measured-attention calibration.
#[derive(Debug, Clone)]
pub struct KvCacheQuantReport {
    /// Byte-accounting rows: f16, Int8, Int4 (in that order).
    pub density: Vec<KvDensityRow>,
    /// Serving run with the unquantized f16 pool.
    pub f16: ContinuousResult,
    /// Serving run with the 8-bit pool.
    pub q8: ContinuousResult,
    /// Serving run with the 4-bit pool.
    pub q4: ContinuousResult,
    /// Measured whole-model attention seconds behind the calibration.
    pub measured_attn_s: f64,
    /// `gpusim` calibration whose `kv_attn_scale` is fit to the measured
    /// fused-attention wall time ([`calibrate_kv_attn`]).
    pub calibrated: Calib,
}

impl KvCacheQuantReport {
    /// Resident-token density of 4-bit over f16 (tokens-per-block ratio).
    pub fn q4_density(&self) -> f64 {
        self.density
            .iter()
            .find(|r| r.precision == KvPrecision::Int4)
            .map_or(0.0, |r| r.density_x)
    }

    /// 4-bit over f16 serving throughput on the modeled clock.
    pub fn q4_speedup(&self) -> f64 {
        self.q4.total_tok_per_s / self.f16.total_tok_per_s.max(1e-9)
    }
}

/// KV-cache quantization figure — `quick-infer simulate kv`. Three views
/// of the same knob: the byte accounting that turns fixed-size block
/// slabs into ~2x/~3.4x resident tokens, a memory-pressured
/// shared-prefix serving comparison at each precision on the modeled
/// clock, and one measured [`attn_quant_fused`] call fit back into the
/// gpusim [`Calib::kv_attn_scale`] so the modeled attention term runs on
/// this host's measured number.
pub fn kv_cache_quant(out: &mut impl Write) -> Result<KvCacheQuantReport> {
    writeln!(out, "\n== Quantized KV cache: density, serving, calibration ==")?;
    const BS: u64 = 16;
    writeln!(out, "{:>5} {:>12} {:>14} {:>9}", "prec", "bytes/elem", "tokens/block", "density")?;
    let f16_tpb = KvPrecision::F16.tokens_per_block(BS) as f64;
    let mut density = Vec::new();
    for p in [KvPrecision::F16, KvPrecision::Int8, KvPrecision::Int4] {
        let row = KvDensityRow {
            precision: p,
            bytes_per_elem: p.bytes_per_elem(KV_GROUP),
            tokens_per_block: p.tokens_per_block(BS),
            density_x: p.tokens_per_block(BS) as f64 / f16_tpb,
        };
        writeln!(
            out,
            "{:>5} {:>12.3} {:>14} {:>8.2}x",
            p.label(),
            row.bytes_per_elem,
            row.tokens_per_block,
            row.density_x
        )?;
        density.push(row);
    }

    // Serving under memory pressure: the same shared-prefix burst at
    // each precision — more resident tokens means fewer preemptions and
    // steadier TTFT on the same device.
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let calib = Calib::default();
    let reqs = SharedPrefixWorkload::default().offline(160, 2077);
    let base = ContinuousPolicy::default();
    let run = |p: KvPrecision| {
        simulate_continuous(
            &dev,
            &spec,
            KernelKind::Quick,
            &reqs,
            &ContinuousPolicy { kv_precision: p, ..base },
            &calib,
        )
    };
    let f16 = run(KvPrecision::F16)?;
    let q8 = run(KvPrecision::Int8)?;
    let q4 = run(KvPrecision::Int4)?;
    writeln!(
        out,
        "\n-- {} on {}, {} shared-prefix requests (modeled clock) --",
        spec.name,
        dev.name,
        reqs.len()
    )?;
    writeln!(
        out,
        "{:>5} {:>10} {:>9} {:>10} {:>10}",
        "prec", "tok/s", "preempt", "ttft s", "hit rate"
    )?;
    for (p, r) in [(KvPrecision::F16, &f16), (KvPrecision::Int8, &q8), (KvPrecision::Int4, &q4)] {
        writeln!(
            out,
            "{:>5} {:>10.1} {:>9} {:>10.3} {:>9.1}%",
            p.label(),
            r.total_tok_per_s,
            r.preemptions,
            r.mean_ttft_s,
            r.prefix_hit_rate() * 100.0
        )?;
    }

    // Engine hook: measure the fused kernel once at a decode shape,
    // extrapolate to the whole model (`n_layers * kv_heads` single-head
    // calls — the exact extrapolation `StepExecutor::enable_attention`
    // uses), and fit the modeled KV-bandwidth term to it.
    let cal_spec = Model::Tiny.spec();
    let d = cal_spec.head_dim() as usize;
    let (m, ctx) = (8usize, 512usize);
    let mut rng = Rng::seed_from_u64(0xCA1B);
    let k: Vec<f32> = (0..ctx * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let v: Vec<f32> = (0..ctx * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let q: Vec<f32> = (0..m * d).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let kq = quantize_kv(&k, ctx, d, KV_GROUP, 4);
    let vq = quantize_kv(&v, ctx, d, KV_GROUP, 4);
    let cfg = AttnConfig::default();
    let scale = 1.0 / (d as f32).sqrt();
    let mut y = vec![0f32; m * d];
    let bench = Bench::smoke().silent();
    let r = bench.run(&format!("attn calib {} m{m} ctx{ctx}", cal_spec.name), || {
        attn_quant_fused(&q, &kq, &vq, m, scale, &cfg, &mut y).expect("calibration attention");
        y[0]
    });
    let calls = cal_spec.n_layers * cal_spec.kv_heads;
    let measured_attn_s = ((r.median_ns / 1e9) * calls as f64).max(1e-12);
    let calibrated =
        calibrate_kv_attn(&dev, &cal_spec, m as u64, ctx as u64, measured_attn_s, &calib);
    let modeled_default = kv_attn_term(&dev, &cal_spec, m as u64, ctx as u64, &calib);
    let modeled_fit = kv_attn_term(&dev, &cal_spec, m as u64, ctx as u64, &calibrated);
    writeln!(
        out,
        "\n-- measured fused-attention calibration ({}, m={m}, ctx={ctx}, kv4) --",
        cal_spec.name
    )?;
    writeln!(
        out,
        "measured whole-model attention {measured_attn_s:.3e} s ({calls} single-head calls); \
         modeled default {modeled_default:.3e} s -> fit {modeled_fit:.3e} s \
         (kv_attn_scale {:.3})",
        calibrated.kv_attn_scale
    )?;
    Ok(KvCacheQuantReport { density, f16, q8, q4, measured_attn_s, calibrated })
}

/// The tp degrees swept by [`tensor_parallel`].
pub const TP_DEGREES: [u64; 4] = [1, 2, 4, 8];

/// Tensor-parallel scaling evaluation (not a paper figure — the
/// multi-GPU extension the ROADMAP's production target requires):
/// Llama-2-70B served by a TP group of A100s over the bursty bimodal
/// workload, tp_degree ∈ {1, 2, 4, 8}. Each rank runs the continuous
/// scheduler at `1/tp` weight volume (QUICK shards are packed
/// independently per rank — `quant::shard`), pays two ring all-reduces
/// per layer (`gpusim::collective`), and scales its token budget to the
/// group's effective step latency. Reports per-degree throughput,
/// scaling efficiency, the step-time breakdown (GEMM vs collective), and
/// the QUICK-vs-AWQ gap as TP shrinks each rank's per-GPU N.
pub fn tensor_parallel(out: &mut impl Write) -> Result<TensorParallelReport> {
    let calib = Calib::default();
    let dev = Gpu::A100.spec();
    let spec = Model::Llama2_70B.spec();
    let policy = ContinuousPolicy::default();
    let reqs = BurstyWorkload::default().offline(160, 2027);

    writeln!(
        out,
        "\n== Tensor parallelism: {} on {} x tp, bursty workload ({} reqs) ==",
        spec.name,
        dev.name,
        reqs.len()
    )?;
    writeln!(
        out,
        "{:>4} {:>13} {:>13} {:>9} {:>11} {:>13} {:>10}",
        "tp", "QUICK tok/s", "speedup", "scaling", "step toks", "AWQ tok/s", "QUICK/AWQ"
    )?;
    let mut rows = Vec::new();
    let mut baseline = 0.0f64;
    for tp in TP_DEGREES {
        let quick = simulate_tp(&dev, &spec, KernelKind::Quick, &reqs, &policy, tp, &calib)?;
        let awq = simulate_tp(&dev, &spec, KernelKind::Awq, &reqs, &policy, tp, &calib)?;
        if tp == 1 {
            baseline = quick.total_tok_per_s;
        }
        let speedup = quick.total_tok_per_s / baseline.max(1e-9);
        writeln!(
            out,
            "{:>4} {:>13.1} {:>12.2}x {:>8.0}% {:>11.1} {:>13.1} {:>9.2}x",
            tp,
            quick.total_tok_per_s,
            speedup,
            speedup / tp as f64 * 100.0,
            quick.mean_step_tokens,
            awq.total_tok_per_s,
            quick.total_tok_per_s / awq.total_tok_per_s.max(1e-9),
        )?;
        rows.push(TpRow { tp_degree: tp, awq, quick });
    }
    let report = TensorParallelReport { rows };

    writeln!(out, "\n-- QUICK per-step breakdown at a 512-token mixed step --")?;
    writeln!(
        out,
        "{:>4} {:>10} {:>10} {:>10} {:>8}",
        "tp", "step ms", "gemm ms", "comm ms", "comm %"
    )?;
    for tp in TP_DEGREES {
        let b = tp_step_latency(&dev, &spec, KernelKind::Quick, tp, 128, 1024, 384, 768, &calib);
        writeln!(
            out,
            "{:>4} {:>10.2} {:>10.2} {:>10.2} {:>7.1}%",
            tp,
            b.total_s() * 1e3,
            b.gemm_s * 1e3,
            b.comm_s * 1e3,
            b.comm_s / b.total_s() * 100.0
        )?;
    }
    writeln!(
        out,
        "sharding is drawn in logical (k, n) space before the QUICK interleave \
         (quant::shard); per-rank N shrinks 1/tp, so the kernel-level QUICK/AWQ \
         gap narrows with degree while the all-reduce cost grows"
    )?;
    Ok(report)
}

/// One tp-degree point of the [`tensor_parallel`] sweep.
#[derive(Debug, Clone, Copy)]
pub struct TpRow {
    /// TP group size of this point.
    pub tp_degree: u64,
    /// AWQ-kernel serving result at this degree.
    pub awq: ContinuousResult,
    /// QUICK-kernel serving result at this degree.
    pub quick: ContinuousResult,
}

/// Result set of the [`tensor_parallel`] sweep.
#[derive(Debug, Clone)]
pub struct TensorParallelReport {
    /// One row per swept tp degree, ascending.
    pub rows: Vec<TpRow>,
}

impl TensorParallelReport {
    /// The row for `tp_degree` (panics if the degree was not swept).
    pub fn row(&self, tp_degree: u64) -> &TpRow {
        self.rows
            .iter()
            .find(|r| r.tp_degree == tp_degree)
            .unwrap_or_else(|| panic!("tp_degree {tp_degree} not swept"))
    }

    /// QUICK total-token throughput at `tp_degree` over the tp=1 baseline.
    pub fn quick_speedup(&self, tp_degree: u64) -> f64 {
        self.row(tp_degree).quick.total_tok_per_s
            / self.row(1).quick.total_tok_per_s.max(1e-9)
    }

    /// Fraction of ideal linear scaling realized at `tp_degree`
    /// (`speedup / tp` — the per-degree efficiency the sweep prints).
    pub fn scaling_efficiency(&self, tp_degree: u64) -> f64 {
        self.quick_speedup(tp_degree) / tp_degree as f64
    }
}

/// One offered-load point of the QUICK-vs-AWQ gap sweep.
#[derive(Debug, Clone, Copy)]
pub struct GapRow {
    /// Offered load, bursts per second.
    pub rate: f64,
    /// AWQ continuous-batching result.
    pub awq: ContinuousResult,
    /// QUICK continuous-batching result.
    pub quick: ContinuousResult,
}

impl GapRow {
    /// QUICK over AWQ generated-token throughput at this load.
    pub fn gap(&self) -> f64 {
        self.quick.gen_tok_per_s / self.awq.gen_tok_per_s.max(1e-9)
    }
}

#[derive(Debug, Clone)]
pub struct ContinuousBatchingReport {
    /// AWQ under the static-wave baseline.
    pub wave_awq: ContinuousResult,
    /// AWQ under continuous batching.
    pub cont_awq: ContinuousResult,
    /// QUICK under the static-wave baseline.
    pub wave_quick: ContinuousResult,
    /// QUICK under continuous batching.
    pub cont_quick: ContinuousResult,
    /// QUICK-vs-AWQ gap sweep over offered load.
    pub gap_rows: Vec<GapRow>,
}

impl ContinuousBatchingReport {
    /// Continuous over static-wave total token throughput, QUICK kernel.
    pub fn quick_speedup(&self) -> f64 {
        self.cont_quick.total_tok_per_s / self.wave_quick.total_tok_per_s.max(1e-9)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct PrefixCacheReport {
    /// Shared-prefix workload, cache on.
    pub shared_on: SimResult,
    /// Shared-prefix workload, cache off.
    pub shared_off: SimResult,
    /// Disjoint control workload, cache on.
    pub disjoint_on: SimResult,
    /// Disjoint control workload, cache off.
    pub disjoint_off: SimResult,
}

impl PrefixCacheReport {
    /// Cache-on over cache-off total token throughput on shared prefixes.
    pub fn throughput_speedup(&self) -> f64 {
        self.shared_on.total_tok_per_s / self.shared_off.total_tok_per_s.max(1e-9)
    }
}

/// Group size and weight seed shared by every measured serving figure, so
/// runs that should be comparable execute the same quantized weights.
const MEASURED_GROUP_SIZE: usize = 128;
const MEASURED_SEED: u64 = 0x5EED;

fn measured_row(out: &mut impl Write, label: &str, r: &MeasuredRun) -> std::io::Result<()> {
    writeln!(
        out,
        "{:<22} {:>12.1} {:>10} {:>12.4} {:>10.4} {:>11.4}",
        label,
        r.result.total_tok_per_s,
        r.stats.executed_tokens,
        r.stats.gemm_wall_s,
        r.stats.comm_s,
        r.stats.modeled_s
    )
}

/// Measured serving figure — `simulate continuous --measured`. The same
/// continuous-vs-wave and prefix-cache comparisons the modeled figures
/// make, but with every scheduler step executed as a real GEMM stream on
/// this CPU's native runtime ([`MeasuredEngine`](crate::coordinator::MeasuredEngine)):
/// throughput is wall-clock tokens/sec of the fused/write-back kernels,
/// the modeled twin runs side by side, and every step feeds the global
/// drift ledger (printed at the end).
pub fn measured_serving(
    out: &mut impl Write,
    n_requests: usize,
    codebook: CodebookKind,
) -> Result<MeasuredServingReport> {
    let calib = Calib::default();
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Tiny.spec();
    let policy = ContinuousPolicy { codebook, ..ContinuousPolicy::measured_default() };
    writeln!(
        out,
        "\n== Measured serving: {} on this CPU's native runtime ({} requests, {} weights; \
         {} prices KV/comm) ==",
        spec.name,
        n_requests,
        codebook.label(),
        dev.name
    )?;
    writeln!(
        out,
        "{:<22} {:>12} {:>10} {:>12} {:>10} {:>11}",
        "run", "tok/s", "exec tok", "gemm wall s", "comm s", "modeled s"
    )?;

    let cont = |backend: StepBackend, reqs: &[Request], pol: &ContinuousPolicy| {
        simulate_continuous_measured(
            &dev,
            &spec,
            backend,
            reqs,
            pol,
            &calib,
            MEASURED_GROUP_SIZE,
            MEASURED_SEED,
        )
    };

    let bursty = measured_bursty(n_requests, 3001);
    let wave_fused = simulate_static_wave_measured(
        &dev,
        &spec,
        StepBackend::Fused,
        &bursty,
        &policy,
        &calib,
        MEASURED_GROUP_SIZE,
        MEASURED_SEED,
    )?;
    let cont_fused = cont(StepBackend::Fused, &bursty, &policy)?;
    let cont_writeback = cont(StepBackend::Writeback, &bursty, &policy)?;
    measured_row(out, "fused / static wave", &wave_fused)?;
    measured_row(out, "fused / continuous", &cont_fused)?;
    measured_row(out, "writeback / continuous", &cont_writeback)?;
    let modeled_twin =
        simulate_continuous(&dev, &spec, KernelKind::Quick, &bursty, &policy, &calib)?;
    writeln!(
        out,
        "{:<22} {:>12.1}  (gpusim clock, same scheduler decisions)",
        "modeled twin (QUICK)", modeled_twin.total_tok_per_s
    )?;
    writeln!(
        out,
        "continuous/wave (measured): {:.2}x; fused/writeback (measured): {:.2}x",
        cont_fused.result.total_tok_per_s / wave_fused.result.total_tok_per_s.max(1e-9),
        cont_fused.result.total_tok_per_s / cont_writeback.result.total_tok_per_s.max(1e-9),
    )?;

    writeln!(out, "\n-- prefix cache on real compute (shared-prefix workload) --")?;
    let shared = measured_shared_prefix(n_requests, 3002);
    let prefix_on = cont(StepBackend::Fused, &shared, &policy)?;
    let off_policy = ContinuousPolicy { enable_prefix_cache: false, ..policy };
    let prefix_off = cont(StepBackend::Fused, &shared, &off_policy)?;
    measured_row(out, "fused / cache on", &prefix_on)?;
    measured_row(out, "fused / cache off", &prefix_off)?;
    let report = MeasuredServingReport {
        wave_fused,
        cont_fused,
        cont_writeback,
        modeled_twin,
        prefix_on,
        prefix_off,
    };
    writeln!(
        out,
        "cache hits skipped {} prompt tokens of real GEMM work ({} vs {} executed)",
        report.prefix_executed_saving(),
        prefix_on.stats.executed_tokens,
        prefix_off.stats.executed_tokens
    )?;

    writeln!(out, "\n-- modeled-vs-measured drift ledger (per GEMM shape) --")?;
    write!(out, "{}", DriftAccountant::global().report())?;
    Ok(report)
}

/// Everything [`measured_serving`] ran, for the acceptance tests.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredServingReport {
    /// Fused kernel under the static-wave baseline (measured clock).
    pub wave_fused: MeasuredRun,
    /// Fused kernel under continuous batching (measured clock).
    pub cont_fused: MeasuredRun,
    /// Write-back baseline under continuous batching (measured clock).
    pub cont_writeback: MeasuredRun,
    /// The gpusim twin of `cont_fused` — same scheduler, modeled clock.
    pub modeled_twin: ContinuousResult,
    /// Shared-prefix workload with the prefix cache on (measured).
    pub prefix_on: MeasuredRun,
    /// Shared-prefix workload with the prefix cache off (measured).
    pub prefix_off: MeasuredRun,
}

impl MeasuredServingReport {
    /// Continuous over static-wave throughput on the measured clock.
    pub fn continuous_speedup(&self) -> f64 {
        self.cont_fused.result.total_tok_per_s / self.wave_fused.result.total_tok_per_s.max(1e-9)
    }

    /// Fused over write-back throughput on the measured clock.
    pub fn fused_over_writeback(&self) -> f64 {
        self.cont_fused.result.total_tok_per_s
            / self.cont_writeback.result.total_tok_per_s.max(1e-9)
    }

    /// Prompt tokens the prefix cache kept away from the real GEMM
    /// stream (cache-off executed minus cache-on executed).
    pub fn prefix_executed_saving(&self) -> u64 {
        self.prefix_off.stats.executed_tokens.saturating_sub(self.prefix_on.stats.executed_tokens)
    }
}

/// Measured tensor-parallel figure — `simulate tp --measured`. Each
/// degree serves the same workload with `tp` concurrent per-rank GEMM
/// streams on this host (ranks share the worker pool) plus ring
/// collectives priced by [`crate::gpusim::tp_step_comm_s`] on the A100
/// link table.
pub fn tensor_parallel_measured(
    out: &mut impl Write,
    degrees: &[u64],
    n_requests: usize,
) -> Result<MeasuredTpReport> {
    anyhow::ensure!(!degrees.is_empty(), "need at least one tp degree");
    let calib = Calib::default();
    let dev = Gpu::A100.spec();
    let spec = Model::Tiny.spec();
    let policy = ContinuousPolicy::measured_default();
    let reqs = measured_bursty(n_requests, 3003);
    writeln!(
        out,
        "\n== Measured tensor parallel: {} x{:?} ranks on this CPU ({} links price comm) ==",
        spec.name, degrees, dev.name
    )?;
    writeln!(
        out,
        "{:>4} {:>12} {:>12} {:>10} {:>11} {:>11}",
        "tp", "tok/s", "gemm wall s", "comm s", "comm share", "modeled s"
    )?;
    let mut rows = Vec::new();
    for &tp in degrees {
        let run = simulate_tp_measured(
            &dev,
            &spec,
            StepBackend::Fused,
            &reqs,
            &policy,
            tp,
            &calib,
            MEASURED_GROUP_SIZE,
            MEASURED_SEED + tp,
        )?;
        let row = MeasuredTpRow { tp_degree: tp, run };
        writeln!(
            out,
            "{:>4} {:>12.1} {:>12.4} {:>10.4} {:>10.1}% {:>11.4}",
            tp,
            run.result.total_tok_per_s,
            run.stats.gemm_wall_s,
            run.stats.comm_s,
            row.comm_share() * 100.0,
            run.stats.modeled_s
        )?;
        rows.push(row);
    }
    writeln!(
        out,
        "ranks share one CPU, so measured tok/s shows sharding overhead, not speedup; \
         the comm share column is the priced collective cost the modeled sweep charges"
    )?;
    Ok(MeasuredTpReport { rows })
}

/// One degree of the measured TP sweep.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredTpRow {
    /// Ranks in the group.
    pub tp_degree: u64,
    /// The measured run at this degree.
    pub run: MeasuredRun,
}

impl MeasuredTpRow {
    /// Fraction of the measured clock spent in priced collectives.
    pub fn comm_share(&self) -> f64 {
        self.run.stats.comm_s / self.run.stats.measured_total_s().max(1e-12)
    }
}

/// Everything [`tensor_parallel_measured`] ran, for the tests.
#[derive(Debug, Clone)]
pub struct MeasuredTpReport {
    /// One row per requested degree, in input order.
    pub rows: Vec<MeasuredTpRow>,
}

impl MeasuredTpReport {
    /// Row for `tp_degree` (panics if the sweep did not run it).
    pub fn row(&self, tp_degree: u64) -> &MeasuredTpRow {
        self.rows.iter().find(|r| r.tp_degree == tp_degree).expect("degree not swept")
    }
}

/// One (kernel × shed policy) cell of [`chaos_serving`].
#[derive(Debug, Clone)]
pub struct ChaosCell {
    /// Kernel family the replicas price their steps with.
    pub kind: KernelKind,
    /// Admission behavior under KV-pool pressure.
    pub shed: ShedPolicy,
    /// The chaos run's full result.
    pub result: ChaosResult,
}

/// Everything [`chaos_serving`] ran, for the tests.
#[derive(Debug, Clone)]
pub struct ChaosServingReport {
    /// Pressure-wave cells: (QUICK, AWQ) × (degrade, reject-only).
    pub pressure: Vec<ChaosCell>,
    /// Mixed-fault cells (crash + stall + pressure): QUICK and AWQ under
    /// the degrade ladder. Empty when the sweep ran in quick mode.
    pub mixed: Vec<ChaosCell>,
}

impl ChaosServingReport {
    /// Pressure-wave result for `kind` under `shed` (panics if the sweep
    /// did not run that cell).
    pub fn pressure_cell(&self, kind: KernelKind, shed: ShedPolicy) -> &ChaosResult {
        self.pressure
            .iter()
            .find(|c| c.kind == kind && c.shed == shed)
            .map(|c| &c.result)
            .expect("cell not swept")
    }
}

/// Run one chaos cell per pool task and return the results in cell order.
fn chaos_cells(
    dev: &crate::gpusim::DeviceSpec,
    spec: &crate::model::LlmSpec,
    cells: &[(KernelKind, ShedPolicy)],
    reqs: &[Request],
    plan: &FaultPlan,
    policy: &(dyn Fn(ShedPolicy) -> ChaosPolicy + Sync),
    calib: &Calib,
) -> Result<Vec<ChaosCell>> {
    let slots: Mutex<Vec<Option<Result<ChaosResult>>>> =
        Mutex::new(cells.iter().map(|_| None).collect());
    WorkerPool::global().run(cells.len(), cells.len(), &|t, _slot| {
        let (kind, shed) = cells[t];
        let r = run_chaos(dev, spec, kind, reqs, plan, &policy(shed), calib);
        slots.lock().unwrap_or_else(|e| e.into_inner())[t] = Some(r);
    });
    let mut ran = Vec::with_capacity(cells.len());
    let drained = slots.into_inner().unwrap_or_else(|e| e.into_inner());
    for ((kind, shed), slot) in cells.iter().copied().zip(drained) {
        ran.push(ChaosCell { kind, shed, result: slot.expect("pool ran every cell")? });
    }
    Ok(ran)
}

/// Chaos serving sweep — goodput under deterministic fault schedules,
/// QUICK vs AWQ (`simulate chaos`).
///
/// Two sections:
///
/// * **Pressure wave** (the acceptance cell): one replica whose KV pool
///   loses 90% of its blocks for most of the horizon. The degrade
///   ladder ([`ShedPolicy::DegradeThenReject`]: f16 → kv8 → kv4
///   admission) runs against [`ShedPolicy::RejectOnly`] under the
///   *same* schedule and SLO. The ladder must win strictly: kv4 packs
///   ~3.3x more tokens per block, so it keeps admitting where
///   reject-only sheds every in-window arrival on the TTFT deadline.
/// * **Mixed faults** (skipped with `quick`): two replicas through a
///   seeded crash/stall/pressure schedule — failover requeues in-flight
///   work for KV recompute, the router ramps the recovered replica back
///   through probing, and every request still terminates exactly once.
pub fn chaos_serving(out: &mut impl Write, quick: bool) -> Result<ChaosServingReport> {
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Mistral7B.spec();
    let calib = Calib::default();

    // Pressure fixture: five requests arrive inside the pressure window,
    // four after it lifts. A 220-token prompt against the 7 blocks a
    // 90%-squeezed 64-block pool has left needs 15 blocks at f16
    // (14 + watermark), 9 at kv8, 6 at kv4 — only the ladder's bottom
    // rung fits, so reject-only can do nothing but shed.
    let mut reqs: Vec<Request> = Vec::new();
    for i in 0..5u64 {
        reqs.push(Request {
            id: 1 + i,
            prompt_tokens: 220,
            gen_tokens: 6,
            arrival_s_micros: 100_000 + 250_000 * i,
            sys_id: 0,
            sys_tokens: 0,
            stream_id: 1 + i,
        });
    }
    for i in 0..4u64 {
        reqs.push(Request {
            id: 11 + i,
            prompt_tokens: 220,
            gen_tokens: 6,
            arrival_s_micros: 1_700_000 + 50_000 * i,
            sys_id: 0,
            sys_tokens: 0,
            stream_id: 11 + i,
        });
    }
    let plan = FaultPlan {
        seed: 0,
        scenario: Scenario::PressureWave,
        events: vec![
            FaultEvent { at_s: 0.0, kind: FaultKind::PressureStart { replica: 0, frac: 0.9 } },
            FaultEvent { at_s: 1.5, kind: FaultKind::PressureEnd { replica: 0 } },
        ],
    };
    let policy = |shed: ShedPolicy| ChaosPolicy {
        serve: ContinuousPolicy { max_num_seqs: 8, ..ContinuousPolicy::default() },
        n_replicas: 1,
        slo: SloSpec { ttft_s: 0.3, tpot_s: 1.0 },
        shed,
        pool_blocks: Some(64),
        ..ChaosPolicy::default()
    };
    let cells = [
        (KernelKind::Quick, ShedPolicy::DegradeThenReject),
        (KernelKind::Quick, ShedPolicy::RejectOnly),
        (KernelKind::Awq, ShedPolicy::DegradeThenReject),
        (KernelKind::Awq, ShedPolicy::RejectOnly),
    ];
    let pressure = chaos_cells(&dev, &spec, &cells, &reqs, &plan, &policy, &calib)?;

    writeln!(
        out,
        "\n== Chaos serving: goodput under faults ({} on {}) ==",
        spec.name, dev.name
    )?;
    writeln!(out, "-- pressure wave: 90% of a 64-block pool held 0.0-1.5s, TTFT SLO 0.3s --")?;
    writeln!(
        out,
        "{:6} {:12} {:>8} {:>8} {:>8} {:>8} {:>12}",
        "kernel", "shed", "finished", "shed", "kv8", "kv4", "goodput t/s"
    )?;
    for c in &pressure {
        writeln!(
            out,
            "{:6} {:12} {:>8} {:>8} {:>8} {:>8} {:>12.1}",
            c.kind.label(),
            c.shed.label(),
            c.result.finished,
            c.result.rejected,
            c.result.degraded_int8,
            c.result.degraded_int4,
            c.result.goodput_tok_per_s
        )?;
    }
    let report = ChaosServingReport { pressure, mixed: Vec::new() };
    for kind in [KernelKind::Quick, KernelKind::Awq] {
        let d = report.pressure_cell(kind, ShedPolicy::DegradeThenReject);
        let r = report.pressure_cell(kind, ShedPolicy::RejectOnly);
        ensure!(
            d.degraded_int8 + d.degraded_int4 > 0,
            "{}: the degrade ladder never engaged under pressure",
            kind.label()
        );
        ensure!(
            r.rejected_slo > 0,
            "{}: reject-only shed nothing — the pressure window has no teeth",
            kind.label()
        );
        ensure!(
            d.goodput_tok_per_s > r.goodput_tok_per_s,
            "{}: degrade goodput {:.1} not strictly above reject-only {:.1}",
            kind.label(),
            d.goodput_tok_per_s,
            r.goodput_tok_per_s
        );
    }
    let dq = report.pressure_cell(KernelKind::Quick, ShedPolicy::DegradeThenReject);
    let rq = report.pressure_cell(KernelKind::Quick, ShedPolicy::RejectOnly);
    writeln!(
        out,
        "degrade ladder sustains {:.1} tok/s vs {:.1} reject-only under the same schedule (QUICK)",
        dq.goodput_tok_per_s, rq.goodput_tok_per_s
    )?;
    if quick {
        return Ok(report);
    }

    // Mixed faults: a seeded crash + stall + pressure schedule over two
    // replicas, arrivals spanning the whole horizon so the crash lands
    // on live work and failover has something to recompute.
    let mixed_plan = FaultPlan::generate(42, Scenario::Mixed, 2, 6.0);
    let mixed_reqs: Vec<Request> = (0..48u64)
        .map(|i| Request {
            id: 100 + i,
            prompt_tokens: 160 + (i * 37) % 220,
            gen_tokens: 12 + (i % 21),
            arrival_s_micros: i * 120_000,
            sys_id: 0,
            sys_tokens: 0,
            stream_id: 100 + i,
        })
        .collect();
    let mixed_policy = |shed: ShedPolicy| ChaosPolicy {
        serve: ContinuousPolicy { max_num_seqs: 32, ..ContinuousPolicy::default() },
        n_replicas: 2,
        slo: SloSpec { ttft_s: 5.0, tpot_s: 0.5 },
        shed,
        pool_blocks: Some(256),
        ..ChaosPolicy::default()
    };
    let mixed_cells = [
        (KernelKind::Quick, ShedPolicy::DegradeThenReject),
        (KernelKind::Awq, ShedPolicy::DegradeThenReject),
    ];
    let mixed =
        chaos_cells(&dev, &spec, &mixed_cells, &mixed_reqs, &mixed_plan, &mixed_policy, &calib)?;
    writeln!(out, "-- mixed faults: seeded crash + stall + pressure, 2 replicas, 48 requests --")?;
    writeln!(
        out,
        "{:6} {:>8} {:>8} {:>8} {:>9} {:>9} {:>12}",
        "kernel", "finished", "shed", "crashes", "requeues", "degraded", "goodput t/s"
    )?;
    for c in &mixed {
        writeln!(
            out,
            "{:6} {:>8} {:>8} {:>8} {:>9} {:>9} {:>12.1}",
            c.kind.label(),
            c.result.finished,
            c.result.rejected,
            c.result.crashes,
            c.result.failover_requeues,
            c.result.degraded_int8 + c.result.degraded_int4,
            c.result.goodput_tok_per_s
        )?;
        ensure!(
            c.result.finished + c.result.rejected == mixed_reqs.len(),
            "{}: {} finished + {} shed != {} submitted",
            c.kind.label(),
            c.result.finished,
            c.result.rejected,
            mixed_reqs.len()
        );
        ensure!(c.result.crashes == 1, "{}: mixed plan must crash once", c.kind.label());
        ensure!(
            c.result.phantom_guard_violations == 0,
            "{}: phantom prefix hits survived a crash",
            c.kind.label()
        );
    }
    Ok(ChaosServingReport { mixed, ..report })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_is_conflict_free() {
        let d = fig3(&mut std::io::sink()).unwrap();
        assert_eq!(d.quick_conflicts, 0);
        assert_eq!(d.fp16_conflicts, 0);
        assert!(d.awq_conflicts > 100_000, "got {}", d.awq_conflicts);
    }

    #[test]
    fn fig7_shape_holds_on_all_devices() {
        let rows = fig7(&mut std::io::sink()).unwrap();
        for gpu in Gpu::ALL {
            let dev_rows: Vec<_> = rows.iter().filter(|r| r.gpu == gpu).collect();
            // Small batch: quantized kernels beat fp16.
            let b1 = dev_rows.iter().find(|r| r.batch == 1).unwrap();
            assert!(b1.quick > b1.fp16 && b1.awq > b1.fp16, "{gpu:?} b1");
            // Large batch: AWQ degrades below fp16; QUICK stays ahead of AWQ.
            let b256 = dev_rows.iter().find(|r| r.batch == 256).unwrap();
            assert!(b256.awq < b256.fp16, "{gpu:?} AWQ should lose at 256");
            let speedup = b256.quick / b256.awq;
            assert!(
                (1.25..2.1).contains(&speedup),
                "{gpu:?} QUICK/AWQ @256 = {speedup:.2}"
            );
        }
    }

    #[test]
    fn prefix_cache_speedup_and_disjoint_parity() {
        // Acceptance: >=1.2x throughput and lower TTFT on shared prefixes
        // at equal KV budget; zero gain on disjoint prompts.
        let r = prefix_cache(&mut std::io::sink()).unwrap();
        assert!(!r.shared_on.oom && !r.shared_off.oom);
        assert!(
            r.throughput_speedup() >= 1.2,
            "speedup {:.2}x < 1.2x ({:?} vs {:?})",
            r.throughput_speedup(),
            r.shared_on.total_tok_per_s,
            r.shared_off.total_tok_per_s
        );
        assert!(
            r.shared_on.mean_ttft_s < r.shared_off.mean_ttft_s,
            "TTFT {:.3}s !< {:.3}s",
            r.shared_on.mean_ttft_s,
            r.shared_off.mean_ttft_s
        );
        assert!(r.shared_on.prefix_hit_rate() > 0.5);
        // Disjoint control: no cross-request hits, no regression. (Under
        // memory pressure a preempted request may re-hit its *own* cached
        // prompt on re-admission — a gain, never a loss; the bit-exact
        // no-preemption parity check lives in simserve's tests.)
        let ratio = r.disjoint_on.total_tok_per_s / r.disjoint_off.total_tok_per_s;
        assert!(ratio >= 0.99, "cache regressed the disjoint workload: {ratio:.4}x");
        if r.disjoint_off.preemptions == 0 {
            assert_eq!(r.disjoint_on.prefix_hits, 0, "disjoint prompts must not hit");
            assert!(ratio <= 1.01, "disjoint workload shifted by cache: {ratio:.4}x");
        }
    }

    #[test]
    fn continuous_batching_report_holds_acceptance() {
        let r = continuous_batching(&mut std::io::sink()).unwrap();
        assert!(!r.cont_quick.oom && !r.wave_quick.oom);
        assert!(
            r.quick_speedup() >= 1.3,
            "continuous/wave speedup {:.2}x below the 1.3x bar",
            r.quick_speedup()
        );
        // QUICK beats AWQ under both schedulers.
        assert!(r.cont_quick.total_tok_per_s > r.cont_awq.total_tok_per_s);
        // The gap sweep spans unsaturated -> saturated load.
        assert!(r.gap_rows.len() >= 3);
        let first = r.gap_rows.first().unwrap().gap();
        let last = r.gap_rows.last().unwrap().gap();
        assert!(last > first, "gap did not widen: {first:.3} -> {last:.3}");
    }

    #[test]
    fn tensor_parallel_scales_monotonically() {
        // Acceptance: monotone throughput gain from tp 1 -> 4 for QUICK
        // under BurstyWorkload, with per-degree scaling efficiency
        // printed (sanity-checked here as < 100% of linear).
        let r = tensor_parallel(&mut std::io::sink()).unwrap();
        assert_eq!(r.rows.len(), TP_DEGREES.len());
        for row in &r.rows {
            assert!(!row.quick.oom && !row.awq.oom, "tp={}", row.tp_degree);
            assert_eq!(row.quick.finished, 160, "tp={}", row.tp_degree);
            assert_eq!(row.awq.finished, 160, "tp={}", row.tp_degree);
        }
        let q = |tp: u64| r.row(tp).quick.total_tok_per_s;
        assert!(q(2) > q(1), "tp2 {:.1} !> tp1 {:.1}", q(2), q(1));
        assert!(q(4) > q(2), "tp4 {:.1} !> tp2 {:.1}", q(4), q(2));
        assert!(q(8) > q(4), "tp8 {:.1} !> tp4 {:.1}", q(8), q(4));
        // Collectives + unsharded overheads keep scaling sublinear…
        assert!(
            r.scaling_efficiency(8) < 1.0,
            "tp8 efficiency {:.2} >= linear",
            r.scaling_efficiency(8)
        );
        // …but TP must remain worthwhile, not pathological.
        assert!(
            r.scaling_efficiency(4) > 0.5,
            "tp4 efficiency {:.2} below 50%",
            r.scaling_efficiency(4)
        );
        // QUICK keeps beating AWQ at every degree.
        for row in &r.rows {
            assert!(
                row.quick.total_tok_per_s > row.awq.total_tok_per_s,
                "tp={}: QUICK {:.1} !> AWQ {:.1}",
                row.tp_degree,
                row.quick.total_tok_per_s,
                row.awq.total_tok_per_s
            );
        }
    }

    #[test]
    fn kernel_matmul_smoke_is_consistent() {
        // Tiny shape + smoke bench: exercises the full measured path
        // (gate, sweep, calibration) without meaningful wall time.
        let b = Bench::smoke().silent();
        let r = kernel_matmul_with(&mut std::io::sink(), 64, 48, 32, &[1, 4], &b).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert!(
            r.within_tolerance(),
            "fused {:.2e} / wb {:.2e} off the naive reference",
            r.fused_rel_err,
            r.writeback_rel_err
        );
        assert!(r.row(1).fused_gflops > 0.0 && r.row(4).writeback_gflops > 0.0);
        assert!(r.calibrated.writeback_scale >= 0.0);
        assert!(kernel_matmul_with(&mut std::io::sink(), 64, 48, 32, &[], &b).is_err());
    }

    #[test]
    fn decode_sweep_smoke_is_consistent() {
        // Tiny shape + smoke bench: exercises every runtime tier (pool /
        // spawn x simd / scalar), the dispatch-overhead rows, and the
        // differential gate without meaningful wall time. The traced
        // dispatch row toggles the process-global tracer, so take the
        // obs test guard.
        let _g = crate::obs::trace::test_guard();
        let b = Bench::smoke().silent();
        let r = decode_sweep_with(&mut std::io::sink(), 64, 48, 32, &[1, 2], &b).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert!(
            r.within_tolerance(),
            "fused {:.2e} / wb {:.2e} off the naive reference",
            r.fused_rel_err,
            r.writeback_rel_err
        );
        for row in &r.rows {
            assert!(row.fused_pool_simd_gflops > 0.0 && row.fused_spawn_scalar_gflops > 0.0);
            assert!(row.pool_dispatch_ns >= 0.0 && row.spawn_dispatch_ns >= 0.0);
            assert!(row.pool_dispatch_traced_ns >= 0.0);
            assert!(row.runtime_speedup() > 0.0 && row.fused_over_writeback() > 0.0);
        }
        assert!(["avx2", "neon", "scalar"].contains(&r.simd_level));
        assert!(decode_sweep_with(&mut std::io::sink(), 64, 48, 32, &[], &b).is_err());
    }

    #[test]
    fn lut_sweep_smoke_is_consistent() {
        // Tiny shape + smoke bench: both decode tiers on INT4 plus the
        // two non-uniform codebooks, with the per-codebook differential
        // gate. Ratios are positive but not gated here — throughput
        // claims belong to `bench kernels` on a quiet machine.
        let b = Bench::smoke().silent();
        let r = lut_sweep_with(&mut std::io::sink(), 64, 48, 32, &[1, 2], &b).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert!(r.within_tolerance(), "lut {:.2e} off the naive reference", r.lut_rel_err);
        for row in &r.rows {
            assert!(row.shift_mask_gflops > 0.0 && row.lut_int4_gflops > 0.0);
            assert!(row.lut_nf4_gflops > 0.0 && row.lut_mxfp4_gflops > 0.0);
            assert!(row.lut_over_shift() > 0.0 && row.nonuniform_over_int4() > 0.0);
        }
        assert!(r.lut_speedup() > 0.0 && r.min_nonuniform_over_int4() > 0.0);
        assert_eq!(r.row(2).m, 2);
        assert!(lut_sweep_with(&mut std::io::sink(), 64, 48, 32, &[], &b).is_err());
    }

    #[test]
    fn measured_serving_smoke_runs_real_steps() {
        // Tiny request count: the point is that every run actually drove
        // the native runtime (executed tokens, non-empty drift ledger)
        // and the prefix cache kept real compute off the GEMM stream —
        // the timing claims live in tests/measured_serving.rs.
        let r = measured_serving(&mut std::io::sink(), 3, CodebookKind::Int4Uniform).unwrap();
        for (label, run) in [
            ("wave fused", &r.wave_fused),
            ("cont fused", &r.cont_fused),
            ("cont writeback", &r.cont_writeback),
            ("prefix on", &r.prefix_on),
            ("prefix off", &r.prefix_off),
        ] {
            assert!(run.result.finished == 3, "{label}: {} finished", run.result.finished);
            assert!(run.stats.steps > 0 && run.stats.executed_tokens > 0, "{label}");
            assert!(run.stats.gemm_wall_s > 0.0 && run.stats.modeled_s > 0.0, "{label}");
            assert_eq!(run.stats.comm_s, 0.0, "{label}: tp=1 must not price collectives");
            assert!(run.stats.modeled_over_measured().is_some(), "{label}");
        }
        assert!(r.modeled_twin.total_tok_per_s > 0.0);
        // Cache-on never executes more than cache-off on the same work.
        assert!(r.prefix_on.stats.executed_tokens <= r.prefix_off.stats.executed_tokens);
        assert!(
            !DriftAccountant::global().is_empty(),
            "measured runs must feed the drift ledger"
        );
    }

    #[test]
    fn tensor_parallel_measured_smoke_prices_comm() {
        let r = tensor_parallel_measured(&mut std::io::sink(), &[1, 2], 2).unwrap();
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.row(1).run.stats.comm_s, 0.0, "tp=1 has no collectives");
        assert!(r.row(2).run.stats.comm_s > 0.0, "tp=2 must price ring collectives");
        assert!(r.row(2).comm_share() > 0.0 && r.row(2).comm_share() < 1.0);
        for row in &r.rows {
            assert!(row.run.result.finished == 2, "tp={}", row.tp_degree);
            assert!(row.run.stats.executed_tokens > 0, "tp={}", row.tp_degree);
        }
        assert!(tensor_parallel_measured(&mut std::io::sink(), &[], 2).is_err());
    }

    #[test]
    fn step_throughput_smoke_on_tiny() {
        let b = Bench::smoke().silent();
        let r = step_throughput_with(
            &mut std::io::sink(),
            Model::Tiny,
            128,
            &[1, 2],
            &b,
            CodebookKind::Int4Uniform,
        )
        .unwrap();
        assert_eq!(r.rows.len(), 2);
        for row in &r.rows {
            assert!(row.fused_tok_s > 0.0 && row.writeback_tok_s > 0.0, "m={}", row.m);
            assert!(row.fused_s > 0.0 && row.writeback_s > 0.0);
        }
        // The step-fitted calibration must be a consumable Calib.
        assert!(r.calibrated.writeback_scale >= 0.0 && r.calibrated.writeback_scale <= 1024.0);
        assert_eq!(r.row(2).m, 2);
    }

    #[test]
    fn attention_sweep_smoke_is_consistent() {
        // Tiny shapes + smoke bench: the full sweep path (gate at both
        // bit widths, dense baseline, timing rows) without meaningful
        // wall time.
        let b = Bench::smoke().silent();
        let r = attention_sweep_with(&mut std::io::sink(), 32, 16, &[16, 33], &[1, 2], &b).unwrap();
        assert_eq!(r.rows.len(), 4);
        assert!(
            r.within_tolerance(),
            "kv4 {:.2e} / kv8 {:.2e} / dense {:.2e} off the naive reference",
            r.q4_rel_err,
            r.q8_rel_err,
            r.dense_rel_err
        );
        for row in &r.rows {
            assert!(row.q4_gflops > 0.0 && row.q8_gflops > 0.0 && row.dense_gflops > 0.0);
            assert!(row.q4_over_dense() > 0.0);
        }
        assert_eq!(r.row(33, 2).m, 2);
        assert!(attention_sweep_with(&mut std::io::sink(), 32, 16, &[], &[1], &b).is_err());
        // The KV packing contract: a head dim the group does not divide
        // is an error, not a silent fallback.
        assert!(attention_sweep_with(&mut std::io::sink(), 20, 16, &[8], &[1], &b).is_err());
    }

    #[test]
    fn kv_cache_quant_report_holds_density_and_calibration() {
        let r = kv_cache_quant(&mut std::io::sink()).unwrap();
        // Byte accounting: the ISSUE's >= 3x resident-token bar for
        // 4-bit, a strict win for 8-bit.
        assert_eq!(r.density.len(), 3);
        assert!(r.q4_density() >= 3.0, "kv4 density {:.2}x below the 3x bar", r.q4_density());
        let q8_density = r
            .density
            .iter()
            .find(|row| row.precision == KvPrecision::Int8)
            .map_or(0.0, |row| row.density_x);
        assert!(q8_density > 1.0, "kv8 density {q8_density:.2}x not a win");
        // Serving: every precision finishes the burst, and the denser
        // pool never preempts more than the f16 baseline.
        for (label, run) in [("f16", &r.f16), ("kv8", &r.q8), ("kv4", &r.q4)] {
            assert!(!run.oom, "{label} oomed");
            assert_eq!(run.finished, 160, "{label}: {} finished", run.finished);
        }
        assert!(
            r.q4.preemptions <= r.f16.preemptions,
            "kv4 preempted more ({}) than f16 ({})",
            r.q4.preemptions,
            r.f16.preemptions
        );
        assert!(r.q4_speedup() > 0.0);
        // Calibration: a positive measured wall fit to a consumable Calib.
        assert!(r.measured_attn_s > 0.0);
        assert!(r.calibrated.kv_attn_scale >= 0.0 && r.calibrated.kv_attn_scale <= 1024.0);
    }

    #[test]
    fn chaos_serving_degrade_beats_reject_only() {
        let r = chaos_serving(&mut std::io::sink(), true).unwrap();
        assert_eq!(r.pressure.len(), 4);
        assert!(r.mixed.is_empty(), "quick mode skips the mixed sweep");
        for kind in [KernelKind::Quick, KernelKind::Awq] {
            let d = r.pressure_cell(kind, ShedPolicy::DegradeThenReject);
            let rj = r.pressure_cell(kind, ShedPolicy::RejectOnly);
            // The five in-window arrivals only fit at kv4; reject-only
            // sheds all of them on the 0.3s TTFT deadline.
            assert_eq!(d.finished, 9, "{:?}", kind);
            assert_eq!(d.degraded_int4, 5, "{:?}", kind);
            assert_eq!(rj.finished, 4, "{:?}", kind);
            assert_eq!(rj.rejected_slo, 5, "{:?}", kind);
            assert!(d.goodput_tok_per_s > rj.goodput_tok_per_s, "{:?}", kind);
        }
    }

    #[test]
    fn chaos_serving_mixed_sweep_conserves_requests() {
        let r = chaos_serving(&mut std::io::sink(), false).unwrap();
        assert_eq!(r.mixed.len(), 2);
        for c in &r.mixed {
            assert_eq!(c.result.crashes, 1, "{:?}", c.kind);
            assert_eq!(c.result.recoveries, 1, "{:?}", c.kind);
            assert_eq!(c.result.finished + c.result.rejected, 48, "{:?}", c.kind);
            assert_eq!(c.result.phantom_guard_violations, 0, "{:?}", c.kind);
        }
    }

    #[test]
    fn fig8_fp16_oom_cutoffs() {
        let rows = fig8(&mut std::io::sink()).unwrap();
        // Mistral-7B on 4090: fp16 dies by 256, W4 survives (paper §4.2).
        let m = |b: u64| rows.iter().find(|r| r.model == Model::Mistral7B && r.batch == b).unwrap();
        assert_eq!(m(256).fp16, 0.0);
        assert!(m(256).quick > 0.0);
        // QUICK >= AWQ everywhere it runs.
        for r in &rows {
            if r.quick > 0.0 && r.awq > 0.0 {
                assert!(r.quick >= r.awq * 0.99, "{:?} b{}", r.model, r.batch);
            }
        }
    }
}
