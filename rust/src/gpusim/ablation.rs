//! Ablations over QUICK's design choices (DESIGN.md §6, paper §3.2–3.3, §5).
//!
//! The paper composes three mechanisms; this module models each switch
//! independently so their contributions can be separated:
//!
//! 1. **Write-back skip** (§3.1, the ldmatrix-aware interleave): removes
//!    the conflicted shared-memory write-back. Without it, dequantized
//!    weights round-trip through shared memory.
//! 2. **Dequant-aware reorder** (§3.2, Fig. 5): without it, the kernel
//!    pays an in-register shuffle after unpacking (≈2 extra ALU ops per
//!    element — the byte-permute work the FT layout otherwise forces).
//! 3. **Tile-size optimization** (§3.3): without it, QUICK is restricted
//!    to the baseline's BM ≤ 64 tiles and re-reads weights more often at
//!    large batch.
//!
//! Plus the paper's stated future work (§5): **split-K** for the skinny-M
//! decode regime — splitting the reduction across blocks to fill idle SMs,
//! at the cost of a fp16 partial-sum reduction pass over DRAM.

use super::gpu::DeviceSpec;
use super::kernel_model::{model_gemm, Calib, KernelKind, KernelPerf};

/// One ablated variant of the QUICK kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuickVariant {
    /// §3.1 interleave: skip the smem write-back (the core trick).
    pub skip_writeback: bool,
    /// §3.2 dequant-aware nibble reorder (no in-register shuffle).
    pub dequant_reorder: bool,
    /// §3.3 enlarged activation tiles.
    pub tile_size_opt: bool,
    /// §5 future work: split-K for skinny M.
    pub split_k: Option<u32>,
}

impl QuickVariant {
    pub const FULL: QuickVariant = QuickVariant {
        skip_writeback: true,
        dequant_reorder: true,
        tile_size_opt: true,
        split_k: None,
    };

    pub const BASELINE: QuickVariant = QuickVariant {
        skip_writeback: false,
        dequant_reorder: true, // AutoAWQ ships the FT reorder already
        tile_size_opt: false,
        split_k: None,
    };

    pub fn label(&self) -> String {
        if *self == Self::FULL {
            return "QUICK (full)".into();
        }
        if *self == Self::BASELINE {
            return "baseline (AWQ)".into();
        }
        let mut parts = Vec::new();
        parts.push(if self.skip_writeback { "+wb-skip" } else { "-wb-skip" });
        parts.push(if self.dequant_reorder { "+dq-reorder" } else { "-dq-reorder" });
        parts.push(if self.tile_size_opt { "+tile-opt" } else { "-tile-opt" });
        let mut s = parts.join(" ");
        if let Some(k) = self.split_k {
            s.push_str(&format!(" +split-k{k}"));
        }
        s
    }
}

/// Model a QUICK variant by adjusting the calibrated terms:
/// * no `skip_writeback`  -> run the AWQ schedule (write-back + conflicts);
/// * no `dequant_reorder` -> +2 ALU ops per dequantized element (shuffle);
/// * no `tile_size_opt`   -> QUICK's tile menu capped at BM 64 — modeled by
///   taking the QUICK latency at the capped tile via the AWQ-sized grid
///   (weight re-read factor of the BM<=64 menu);
/// * `split_k = Some(s)`  -> reduction split `s` ways: mma/dequant shrink
///   by the extra SM fill, plus a partial-sum pass (M*N*4*s bytes) and an
///   epilogue reduction.
pub fn model_quick_variant(
    dev: &DeviceSpec,
    v: &QuickVariant,
    m: u64,
    n: u64,
    k: u64,
    calib: &Calib,
) -> KernelPerf {
    let mut c = *calib;
    if !v.dequant_reorder {
        // In-register deinterleave: PRMT/byte-perm per pair of elements.
        c.dequant_ops += 2.0;
    }
    let base_kind = if v.skip_writeback { KernelKind::Quick } else { KernelKind::Awq };
    let mut perf = model_gemm(dev, base_kind, m, n, k, &c);

    if v.skip_writeback && !v.tile_size_opt && perf.tile.bm > 64 {
        // Re-model with the tile menu capped at the baseline's BM:
        // approximate by the AWQ grid's weight-pass count at BM=64 applied
        // to the QUICK (no-wb) cost: extra weight DRAM passes dominate.
        let capped = model_gemm(dev, KernelKind::Awq, m, n, k, &c);
        // Remove the write-back/conflict cost from the capped baseline to
        // isolate "QUICK minus tile-opt": wb time = bytes*mult/smem_bw.
        let wb_time = capped.smem_writeback_bytes * capped.conflict_multiplier
            / dev.smem_bw();
        let lat = (capped.latency_s - wb_time).max(perf.latency_s);
        perf = KernelPerf {
            latency_s: lat,
            tops: 2.0 * (m * n * k) as f64 / lat / 1e12,
            conflicts: 0,
            smem_writeback_bytes: 0.0,
            conflict_multiplier: 1.0,
            tile: capped.tile,
            ..perf
        };
    }

    if let Some(s) = v.split_k.filter(|&s| s > 1) {
        let s = s as u64;
        // Partial sums: each split writes an fp32 M x N partial, then a
        // reduction kernel reads them back.
        let partial_bytes = (m * n * 4 * s) as f64 * 2.0; // write + read
        let reduce_time = partial_bytes / (dev.dram_bw() * c.dram_eff)
            + c.overhead_s; // epilogue kernel
        // More blocks fill idle SMs in the skinny-M regime: compute time
        // shrinks by the improved fill (bounded by s and by full fill).
        let blocks = (m.div_ceil(perf.tile.bm) * n.div_ceil(perf.tile.bn)) as f64;
        let fill_before = (blocks / dev.sms as f64).min(1.0).max(0.25);
        let fill_after = (blocks * s as f64 / dev.sms as f64).min(1.0).max(0.25);
        let speedup = fill_after / fill_before;
        let lat = perf.latency_s / speedup + reduce_time;
        perf = KernelPerf {
            latency_s: lat,
            tops: 2.0 * (m * n * k) as f64 / lat / 1e12,
            ..perf
        };
    }
    perf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;

    fn run(v: QuickVariant, m: u64) -> KernelPerf {
        model_quick_variant(&Gpu::Rtx4090.spec(), &v, m, 8192, 8192, &Calib::default())
    }

    #[test]
    fn full_quick_beats_every_single_ablation() {
        for m in [64u64, 256] {
            let full = run(QuickVariant::FULL, m);
            for v in [
                QuickVariant { skip_writeback: false, ..QuickVariant::FULL },
                QuickVariant { dequant_reorder: false, ..QuickVariant::FULL },
                QuickVariant { tile_size_opt: false, ..QuickVariant::FULL },
            ] {
                let abl = run(v, m);
                assert!(
                    full.tops >= abl.tops * 0.999,
                    "m={m}: FULL {:.1} < {} {:.1}",
                    full.tops,
                    v.label(),
                    abl.tops
                );
            }
        }
    }

    #[test]
    fn writeback_skip_is_the_dominant_mechanism_at_large_batch() {
        let m = 256;
        let full = run(QuickVariant::FULL, m);
        let no_wb = run(QuickVariant { skip_writeback: false, ..QuickVariant::FULL }, m);
        let no_dq = run(QuickVariant { dequant_reorder: false, ..QuickVariant::FULL }, m);
        let loss_wb = full.tops / no_wb.tops;
        let loss_dq = full.tops / no_dq.tops;
        assert!(loss_wb > loss_dq, "wb-skip {loss_wb:.2} should matter more than dq-reorder {loss_dq:.2}");
    }

    #[test]
    fn tile_opt_matters_most_above_batch_32() {
        // §3.3: "further increase in throughput for larger batch sizes,
        // particularly those exceeding 32".
        let no_tile = QuickVariant { tile_size_opt: false, ..QuickVariant::FULL };
        let gain_16 = run(QuickVariant::FULL, 16).tops / run(no_tile, 16).tops;
        let gain_256 = run(QuickVariant::FULL, 256).tops / run(no_tile, 256).tops;
        assert!(gain_256 >= gain_16, "{gain_256:.3} vs {gain_16:.3}");
        assert!(gain_256 > 1.02, "tile-opt should help at 256: {gain_256:.3}");
    }

    #[test]
    fn split_k_helps_skinny_m_only() {
        let split = QuickVariant { split_k: Some(4), ..QuickVariant::FULL };
        let skinny_gain = run(split, 1).tops / run(QuickVariant::FULL, 1).tops;
        let fat_gain = run(split, 256).tops / run(QuickVariant::FULL, 256).tops;
        assert!(skinny_gain > 1.0, "split-k must help at m=1: {skinny_gain:.3}");
        assert!(fat_gain <= 1.0 + 1e-9, "split-k must not help at m=256: {fat_gain:.3}");
    }

    #[test]
    fn baseline_variant_equals_awq_kind() {
        let m = 128;
        let a = run(QuickVariant::BASELINE, m);
        let b = model_gemm(&Gpu::Rtx4090.spec(), KernelKind::Awq, m, 8192, 8192, &Calib::default());
        assert!((a.tops - b.tops).abs() < 1e-9);
    }
}
