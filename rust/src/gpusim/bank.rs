//! Shared-memory bank-conflict counter (the quantity of paper Fig. 3).
//!
//! NVIDIA shared memory on Ampere/Ada: 32 banks, 4 bytes wide, bank index =
//! `(byte_addr / 4) % 32`. A warp memory instruction is split into *phases*
//! of up to 32 lanes x 4 bytes (wider per-lane accesses issue multiple
//! phases: 8 lanes/phase for 16-byte, 16 lanes/phase for 8-byte). Within a
//! phase, lanes hitting the **same bank but different 32-bit words**
//! serialize: the phase replays `degree` times where `degree` is the max
//! number of distinct words mapped to any single bank. Lanes reading the
//! *same* word broadcast for loads (no conflict); stores to the same word
//! also complete in one replay (one lane wins — CUDA's multicast store
//! rule), so the same distinct-words rule applies.

/// Number of banks (Volta..Ada).
pub const NUM_BANKS: usize = 32;
/// Bank width, bytes.
pub const BANK_BYTES: u64 = 4;

/// Accumulates conflict statistics over a stream of warp accesses.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BankCounter {
    /// Warp-instruction phases issued.
    pub phases: u64,
    /// Extra serialized replays beyond the first transaction of each phase
    /// (this is what Nsight reports as `shared_ld/st_bank_conflict`).
    pub conflicts: u64,
    /// Total transactions (phases + conflicts).
    pub transactions: u64,
}

impl BankCounter {
    /// Fresh counter (no transactions recorded).
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one warp instruction where each lane accesses
    /// `bytes_per_lane` bytes starting at its address in `lane_addrs`
    /// (byte addresses into shared memory). Returns the conflict degree
    /// summed over the instruction's phases.
    pub fn access(&mut self, lane_addrs: &[u64], bytes_per_lane: u64) -> u64 {
        assert!(matches!(bytes_per_lane, 1 | 2 | 4 | 8 | 16));
        // Lanes per phase so one phase moves <= 128 B.
        let lanes_per_phase = (128 / bytes_per_lane).min(32) as usize;
        let mut total_extra = 0;
        for phase_lanes in lane_addrs.chunks(lanes_per_phase) {
            // Each lane may touch ceil(bytes/4) words; for <=4 B it is one.
            let words_per_lane = bytes_per_lane.div_ceil(BANK_BYTES).max(1);
            let mut per_bank: [Vec<u64>; NUM_BANKS] = Default::default();
            for &addr in phase_lanes {
                for wi in 0..words_per_lane {
                    let word = addr / BANK_BYTES + wi;
                    let bank = (word % NUM_BANKS as u64) as usize;
                    if !per_bank[bank].contains(&word) {
                        per_bank[bank].push(word);
                    }
                }
            }
            let degree = per_bank.iter().map(Vec::len).max().unwrap_or(0).max(1) as u64;
            self.phases += 1;
            self.transactions += degree;
            total_extra += degree - 1;
        }
        self.conflicts += total_extra;
        total_extra
    }

    /// Average replay multiplier (1.0 = conflict-free).
    pub fn multiplier(&self) -> f64 {
        if self.phases == 0 {
            1.0
        } else {
            self.transactions as f64 / self.phases as f64
        }
    }

    /// Accumulate another counter's totals into this one.
    pub fn merge(&mut self, other: &BankCounter) {
        self.phases += other.phases;
        self.conflicts += other.conflicts;
        self.transactions += other.transactions;
    }

    /// Scale counts by `n` repetitions of the same pattern (tiles are
    /// identical, so one representative tile is simulated and multiplied).
    pub fn scaled(&self, n: u64) -> BankCounter {
        BankCounter {
            phases: self.phases * n,
            conflicts: self.conflicts * n,
            transactions: self.transactions * n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conflict_free_unit_stride() {
        // 32 lanes, 4 B each, consecutive: one word per bank.
        let addrs: Vec<u64> = (0..32).map(|l| l * 4).collect();
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 0);
        assert_eq!(c.multiplier(), 1.0);
    }

    #[test]
    fn broadcast_same_word_is_free() {
        let addrs = vec![128u64; 32];
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 0);
    }

    #[test]
    fn stride_two_words_two_way() {
        // 4-byte accesses at 8-byte stride: lanes 0&16 share bank 0 with
        // different words -> 2-way conflict.
        let addrs: Vec<u64> = (0..32).map(|l| l * 8).collect();
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 1);
        assert_eq!(c.transactions, 2);
    }

    #[test]
    fn stride_32_words_fully_serialized() {
        // All 32 lanes hit bank 0 with distinct words: 32-way.
        let addrs: Vec<u64> = (0..32).map(|l| l * 128).collect();
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 31);
    }

    #[test]
    fn sixteen_byte_access_phases() {
        // 16-byte per lane -> 8 lanes per phase, 4 phases per warp.
        let addrs: Vec<u64> = (0..32).map(|l| l * 16).collect();
        let mut c = BankCounter::new();
        let extra = c.access(&addrs, 16);
        assert_eq!(c.phases, 4);
        // 8 lanes x 4 words each = 32 distinct words covering all banks once.
        assert_eq!(extra, 0);
    }

    #[test]
    fn padded_row_kills_conflicts() {
        // Classic: 32x32 f32 tile column access. Row stride 32 words ->
        // all lanes in one bank (31 extra). Padding to 33 words -> none.
        let bad: Vec<u64> = (0..32).map(|l| l * 32 * 4).collect();
        let good: Vec<u64> = (0..32).map(|l| l * 33 * 4).collect();
        let mut c1 = BankCounter::new();
        let mut c2 = BankCounter::new();
        assert_eq!(c1.access(&bad, 4), 31);
        assert_eq!(c2.access(&good, 4), 0);
    }

    #[test]
    fn broadcast_32_way_same_word_single_transaction() {
        // All 32 lanes on one word: a broadcast, not a 32-way conflict —
        // exactly one transaction, multiplier 1.0.
        let addrs = vec![64u64; 32];
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 0);
        assert_eq!((c.phases, c.transactions, c.conflicts), (1, 1, 0));
        assert_eq!(c.multiplier(), 1.0);
    }

    #[test]
    fn mixed_broadcast_and_conflict_counts_distinct_words_only() {
        // Lanes 0..16 broadcast word 0; lanes 16..32 hit bank 0 with four
        // distinct words (stride 32 words). Degree = max distinct words in
        // one bank = 1 (word 0) + 4 = 5 -> 4 extra replays.
        let mut addrs = vec![0u64; 16];
        addrs.extend((0..16).map(|l| (l / 4 + 1) * 32 * 4));
        let mut c = BankCounter::new();
        assert_eq!(c.access(&addrs, 4), 4);
        assert_eq!(c.transactions, 5);
    }

    #[test]
    fn awq_writeback_multiplier_locked() {
        // The write-back multiplier the kernel model's baseline term
        // depends on (paper Figs. 2-3). One warp-row of the AWQ dequant
        // write-back: 8 nibble-slot store instructions; each lane scatters
        // a 2-byte value at 16-byte stride, so the words each phase
        // touches are `lane*4 + col/2` — every bank holds exactly 4
        // distinct words. Hand-computed: 8 phases, 4-way conflict each ->
        // 32 transactions, 24 extra replays, multiplier exactly 4.0.
        let mut c = BankCounter::new();
        let instrs = crate::gpusim::trace::awq_writeback(&mut c, 256, 1);
        assert_eq!(instrs, 8);
        assert_eq!(c.phases, 8);
        assert_eq!(c.transactions, 32);
        assert_eq!(c.conflicts, 24);
        assert_eq!(c.multiplier(), 4.0);
    }

    #[test]
    fn awq_writeback_tile_multiplier_locked() {
        // The model's representative tile (BK=64, BN=128): 32 warp-rows ->
        // 256 phases, 1024 transactions, 768 conflicts; the multiplier
        // stays exactly 4.0 independent of the row stride (the pattern is
        // row-local).
        for stride in [128u64, 256, 512] {
            let mut c = BankCounter::new();
            crate::gpusim::trace::awq_writeback(&mut c, stride, 32);
            assert_eq!(c.phases, 256, "stride {stride}");
            assert_eq!(c.transactions, 1024, "stride {stride}");
            assert_eq!(c.conflicts, 768, "stride {stride}");
            assert_eq!(c.multiplier(), 4.0, "stride {stride}");
        }
    }

    #[test]
    fn scaled_multiplies() {
        let mut c = BankCounter::new();
        c.access(&(0..32).map(|l| l * 8).collect::<Vec<_>>(), 4);
        let s = c.scaled(10);
        assert_eq!(s.conflicts, c.conflicts * 10);
        assert_eq!(s.phases, c.phases * 10);
    }
}
