//! Collective-communication cost model for tensor-parallel serving.
//!
//! Megatron-style TP runs every weight GEMM at `1/tp` volume per rank
//! (see [`crate::model::LlmSpec::tp_gemms`]) and stitches the layer back
//! together with **two all-reduces per transformer layer** — one after
//! the attention-output projection, one after the MLP-down projection,
//! each over the fp16 activations `(M, d_model)` of the step — plus one
//! **logits all-gather** per step for the column-sharded lm_head (each
//! rank holds `vocab / tp` of every sampled position's logits). This
//! module prices those collectives from the per-GPU link numbers in the
//! [`super::gpu`] table (NVLink3 for A100, PCIe 4.0 x16 for the Ada/
//! Ampere cards) using the standard ring-algorithm cost:
//!
//! * ring all-reduce of `B` bytes over `p` ranks: `2(p-1)` hops moving
//!   `B/p` each → `2 B (p-1)/p / link_bw + 2 (p-1) · link_latency`;
//! * ring all-gather (each rank contributes `B/p`, ends with `B`):
//!   `(p-1)` hops → `B (p-1)/p / link_bw + (p-1) · link_latency`.
//!
//! [`tp_step_latency`] composes the sharded GEMMs, head-sharded
//! attention, and the per-layer all-reduces into the TP image of
//! [`super::e2e::mixed_step_latency`]; at `tp = 1` it reduces to the
//! single-GPU query **exactly** (bit-identical float math — the
//! continuous-batching simulator relies on this to make `tp_degree = 1`
//! a controlled baseline).

use super::gpu::DeviceSpec;
use super::kernel_model::{model_gemm, Calib, KernelKind};
use crate::model::LlmSpec;

/// Latency of a ring all-reduce of `bytes` across `tp` ranks over `dev`'s
/// TP links. Zero at `tp <= 1` or `bytes <= 0` (no communication).
pub fn ring_all_reduce_s(dev: &DeviceSpec, bytes: f64, tp: u64) -> f64 {
    if tp <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let hops = 2.0 * (tp as f64 - 1.0);
    let volume = 2.0 * bytes * (tp as f64 - 1.0) / tp as f64;
    volume / dev.link_bw() + hops * dev.link_latency_s
}

/// Latency of a ring all-gather producing `bytes` total on every rank
/// (each rank contributes `bytes / tp`). Zero at `tp <= 1`.
pub fn ring_all_gather_s(dev: &DeviceSpec, bytes: f64, tp: u64) -> f64 {
    if tp <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let hops = (tp - 1) as f64;
    let volume = bytes * (tp as f64 - 1.0) / tp as f64;
    volume / dev.link_bw() + hops * dev.link_latency_s
}

/// Breakdown of one tensor-parallel mixed step (the TP image of
/// [`super::e2e::MixedStepBreakdown`]): per-rank compute terms plus the collective
/// time the group spends synchronizing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TpStepBreakdown {
    /// TP group size the step was evaluated at.
    pub tp_degree: u64,
    /// Decode lanes in the step.
    pub decode_batch: u64,
    /// Chunked-prefill prompt tokens riding the step.
    pub prefill_tokens: u64,
    /// Weight-GEMM time at `1/tp` volume per rank.
    pub gemm_s: f64,
    /// Decode attention over this rank's `kv_heads / tp` head shard.
    pub decode_attn_s: f64,
    /// Chunked-prefill attention over this rank's head shard.
    pub prefill_attn_s: f64,
    /// Two ring all-reduces per layer over the step's `(M, d_model)`
    /// fp16 activations, plus the `(M, vocab)` logits all-gather for the
    /// column-sharded lm_head (upper bound: real engines gather only the
    /// sampled positions, which is at most the step's M tokens).
    pub comm_s: f64,
    /// Non-GEMM glue (norms, rope, sampling, kernel launches).
    pub other_s: f64,
}

impl TpStepBreakdown {
    /// Total step latency (the TP group steps in lockstep, so this is the
    /// group-wide wall time, not a per-rank average).
    pub fn total_s(&self) -> f64 {
        self.gemm_s + self.decode_attn_s + self.prefill_attn_s + self.comm_s + self.other_s
    }

    /// Tokens processed by the step (decode + chunked prefill).
    pub fn step_tokens(&self) -> u64 {
        self.decode_batch + self.prefill_tokens
    }
}

/// Collective time one TP step of `m` tokens spends synchronizing:
/// `2 · n_layers` ring all-reduces of the `(m, d_model)` fp16
/// activations plus one `(m, vocab)` logits all-gather for the
/// column-sharded lm_head. Zero at `tp_degree = 1`.
///
/// This is exactly the `comm_s` term of [`tp_step_latency`] (same float
/// operations in the same order); it is exposed separately so the
/// measured serving runtime (`coordinator::measured`) can price its
/// ring-collective stand-in identically while the GEMM stream runs for
/// real.
pub fn tp_step_comm_s(dev: &DeviceSpec, spec: &LlmSpec, m: u64, tp_degree: u64) -> f64 {
    let activation_bytes = (m * spec.d_model) as f64 * 2.0;
    let logits_bytes = (m * spec.vocab) as f64 * 2.0;
    spec.n_layers as f64 * 2.0 * ring_all_reduce_s(dev, activation_bytes, tp_degree)
        + ring_all_gather_s(dev, logits_bytes, tp_degree)
}

/// Latency of one mixed decode + chunked-prefill step on a `tp`-way
/// tensor-parallel group of `dev` GPUs.
///
/// Identical contract to [`super::e2e::mixed_step_latency`] (same
/// `decode_*` / `prefill_*` arguments), evaluated at:
///
/// * weight GEMMs from [`LlmSpec::tp_gemms`] — `1/tp` volume per rank,
///   run at the full mixed batch `M` (activations are replicated);
/// * attention terms divided by `tp` (KV heads are sharded with the QKV
///   columns, so each rank reads/computes only its heads' KV);
/// * plus `2 · n_layers` ring all-reduces of the `(M, d_model)` fp16
///   activations ([`ring_all_reduce_s`]) and one `(M, vocab)` logits
///   all-gather for the column-sharded lm_head ([`ring_all_gather_s`]);
/// * per-kernel launch overheads unchanged (each rank launches the same
///   kernel sequence concurrently).
///
/// At `tp = 1` every term equals the single-GPU query bit-exactly and
/// `comm_s == 0`.
// One scalar per physical term, mirroring mixed_step_latency's signature.
#[allow(clippy::too_many_arguments)]
pub fn tp_step_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    tp_degree: u64,
    decode_batch: u64,
    decode_mean_ctx: u64,
    prefill_tokens: u64,
    prefill_attn_ctx_tokens: u64,
    calib: &Calib,
) -> TpStepBreakdown {
    assert!(tp_degree >= 1, "tp_degree must be >= 1");
    let m = decode_batch + prefill_tokens;
    assert!(m > 0, "tp step with no tokens");
    let tp = tp_degree as f64;
    let mut gemm_s = 0.0;
    for g in spec.tp_gemms(tp_degree) {
        gemm_s += model_gemm(dev, kind, m, g.n, g.k, calib).latency_s * g.count as f64;
    }
    let decode_attn_s = if decode_batch > 0 {
        spec.kv_bytes(decode_batch, decode_mean_ctx.max(1)) / tp
            / (dev.dram_bw() * calib.dram_eff)
            + spec.n_layers as f64 * 2.0 * calib.overhead_s
    } else {
        0.0
    };
    let prefill_attn_s = if prefill_tokens > 0 {
        let attn_flops = 2.0 * 2.0 * prefill_attn_ctx_tokens as f64
            * spec.d_model as f64
            * spec.n_layers as f64
            / tp;
        attn_flops / (dev.tc_tflops * 1e12 * calib.mma_eff)
    } else {
        0.0
    };
    let comm_s = tp_step_comm_s(dev, spec, m, tp_degree);
    let other_s = spec.n_layers as f64 * 4.0 * calib.overhead_s;
    TpStepBreakdown {
        tp_degree,
        decode_batch,
        prefill_tokens,
        gemm_s,
        decode_attn_s,
        prefill_attn_s,
        comm_s,
        other_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::e2e::mixed_step_latency;
    use crate::gpusim::gpu::Gpu;
    use crate::model::Model;

    #[test]
    fn ring_costs_zero_without_peers() {
        let dev = Gpu::A100.spec();
        assert_eq!(ring_all_reduce_s(&dev, 1e6, 1), 0.0);
        assert_eq!(ring_all_gather_s(&dev, 1e6, 1), 0.0);
        assert_eq!(ring_all_reduce_s(&dev, 0.0, 8), 0.0);
    }

    #[test]
    fn ring_all_reduce_monotone_in_bytes_and_degree() {
        let dev = Gpu::A100.spec();
        assert!(ring_all_reduce_s(&dev, 2e6, 4) > ring_all_reduce_s(&dev, 1e6, 4));
        // More ranks move a larger fraction of the buffer and pay more hops.
        assert!(ring_all_reduce_s(&dev, 1e6, 8) > ring_all_reduce_s(&dev, 1e6, 2));
        // All-gather moves half the all-reduce volume in half the hops.
        assert!(ring_all_gather_s(&dev, 1e6, 4) < ring_all_reduce_s(&dev, 1e6, 4));
    }

    #[test]
    fn nvlink_beats_pcie_on_the_same_collective() {
        let bytes = 8.0 * 1024.0 * 1024.0;
        let a100 = ring_all_reduce_s(&Gpu::A100.spec(), bytes, 4);
        let a6000 = ring_all_reduce_s(&Gpu::RtxA6000.spec(), bytes, 4);
        assert!(a100 < a6000 / 4.0, "NVLink {a100} not well under PCIe {a6000}");
    }

    #[test]
    fn tp1_reduces_exactly_to_mixed_step() {
        // The simulator treats tp_degree = 1 as a controlled baseline:
        // every term must be bit-identical to the non-TP query.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let calib = Calib::default();
        for (b, ctx, chunk) in [(1u64, 128u64, 0u64), (32, 512, 64), (0, 0, 256)] {
            let m =
                mixed_step_latency(&dev, &spec, KernelKind::Quick, b, ctx, chunk, chunk * 2, &calib);
            let t = tp_step_latency(
                &dev,
                &spec,
                KernelKind::Quick,
                1,
                b,
                ctx,
                chunk,
                chunk * 2,
                &calib,
            );
            assert_eq!(t.comm_s, 0.0);
            assert_eq!(t.gemm_s, m.gemm_s, "b={b} chunk={chunk}");
            assert_eq!(t.decode_attn_s, m.decode_attn_s);
            assert_eq!(t.prefill_attn_s, m.prefill_attn_s);
            assert_eq!(t.total_s(), m.total_s());
        }
    }

    #[test]
    fn tp_shrinks_steps_at_scale_despite_comm() {
        // 70B on NVLink A100s at a big mixed batch: the per-rank GEMM
        // saving dwarfs the two all-reduces per layer.
        let dev = Gpu::A100.spec();
        let spec = Model::Llama2_70B.spec();
        let calib = Calib::default();
        let step = |tp| {
            tp_step_latency(&dev, &spec, KernelKind::Quick, tp, 128, 1024, 384, 768, &calib)
        };
        let (t1, t2, t4, t8) = (step(1), step(2), step(4), step(8));
        assert!(t2.comm_s > 0.0);
        assert!(t2.total_s() < t1.total_s());
        assert!(t4.total_s() < t2.total_s());
        assert!(t8.total_s() < t4.total_s());
        // Scaling is sublinear: comm + per-kernel overheads don't shard.
        assert!(t4.total_s() > t1.total_s() / 4.0);
    }

    #[test]
    fn comm_grows_with_degree_and_tokens() {
        let dev = Gpu::A100.spec();
        let spec = Model::Llama2_70B.spec();
        let calib = Calib::default();
        let step = |tp, chunk| {
            tp_step_latency(&dev, &spec, KernelKind::Quick, tp, 64, 512, chunk, chunk, &calib)
        };
        assert!(step(8, 256).comm_s > step(2, 256).comm_s);
        assert!(step(4, 512).comm_s > step(4, 64).comm_s);
    }
}
