//! End-to-end decode modeling: per-step latency, tokens/s, and the OOM
//! predictor behind Figure 8's missing fp16 bars.

use super::gpu::DeviceSpec;
use super::kernel_model::{model_gemm, Calib, KernelKind};
use crate::model::LlmSpec;

/// Breakdown of one decode step at a given batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeBreakdown {
    pub batch: u64,
    /// Time in the weight GEMMs (what the kernel choice changes).
    pub gemm_s: f64,
    /// Attention (QK^T, softmax, PV) — fp16 in all variants, KV-bandwidth
    /// bound during decode.
    pub attn_s: f64,
    /// Non-GEMM glue (norms, rope, sampling, kernel launches).
    pub other_s: f64,
}

impl DecodeBreakdown {
    pub fn total_s(&self) -> f64 {
        self.gemm_s + self.attn_s + self.other_s
    }
}

/// Latency of one decode step: every weight GEMM at M = batch via the
/// kernel model, plus a KV-bandwidth attention term.
pub fn decode_step_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    ctx_len: u64,
    calib: &Calib,
) -> DecodeBreakdown {
    assert!(batch > 0);
    let mut gemm_s = 0.0;
    for g in spec.gemms() {
        let p = model_gemm(dev, kind, batch, g.n, g.k, calib);
        gemm_s += p.latency_s * g.count as f64;
    }
    // Decode attention reads each sequence's K and V once: bandwidth-bound.
    let kv_read = spec.kv_bytes(batch, ctx_len);
    let attn_s = kv_read / (dev.dram_bw() * calib.dram_eff)
        + spec.n_layers as f64 * 2.0 * calib.overhead_s; // 2 attn kernels/layer
    // Elementwise glue: norms/rope/residuals, ~20 small launches per layer
    // fused down to ~4 in practice.
    let other_s = spec.n_layers as f64 * 4.0 * calib.overhead_s;
    DecodeBreakdown { batch, gemm_s, attn_s, other_s }
}

/// Decode throughput (tokens/s) at a static batch, Fig. 8's y-axis.
pub fn tokens_per_second(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    ctx_len: u64,
    calib: &Calib,
) -> f64 {
    let step = decode_step_latency(dev, spec, kind, batch, ctx_len, calib);
    batch as f64 / step.total_s()
}

/// Does (weights + KV at `ctx_len` + activations + CUDA overhead) fit?
pub fn fits_in_memory(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    w4: bool,
    batch: u64,
    ctx_len: u64,
) -> bool {
    const RUNTIME_OVERHEAD: f64 = 1.5 * (1u64 << 30) as f64; // CUDA ctx etc.
    let need = spec.weight_bytes(w4)
        + spec.kv_bytes(batch, ctx_len)
        + spec.activation_bytes(batch)
        + RUNTIME_OVERHEAD;
    need <= dev.mem_bytes()
}

/// Largest power-of-two batch that fits (0 = not even batch 1 — the paper's
/// "OOM" cells).
pub fn max_batch_before_oom(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    w4: bool,
    ctx_len: u64,
) -> u64 {
    if !fits_in_memory(dev, spec, w4, 1, ctx_len) {
        return 0;
    }
    let mut b = 1;
    while b <= 1024 && fits_in_memory(dev, spec, w4, b * 2, ctx_len) {
        b *= 2;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;
    use crate::model::Model;

    const CTX: u64 = 1024;

    #[test]
    fn fig8_mistral_4090_fp16_ooms_at_256() {
        // Paper §4.2: fp16 Mistral-7B on RTX 4090 cannot run batch 256;
        // 4-bit can.
        let dev = Gpu::Rtx4090.spec();
        let spec = Model::Mistral7B.spec();
        assert!(!fits_in_memory(&dev, &spec, false, 256, 512));
        assert!(fits_in_memory(&dev, &spec, true, 256, 512));
    }

    #[test]
    fn table1_llama70b_a6000_fp16_oom() {
        // Table 1: fp16 Llama-2-70B OOMs on A6000 (140 GB weights alone);
        // W4 fits.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Llama2_70B.spec();
        assert_eq!(max_batch_before_oom(&dev, &spec, false, CTX), 0);
        assert!(max_batch_before_oom(&dev, &spec, true, CTX) >= 8);
    }

    #[test]
    fn quick_beats_awq_at_large_batch_e2e() {
        let dev = Gpu::Rtx4090.spec();
        let spec = Model::Mistral7B.spec();
        let calib = Calib::default();
        let q = tokens_per_second(&dev, &spec, KernelKind::Quick, 128, CTX, &calib);
        let a = tokens_per_second(&dev, &spec, KernelKind::Awq, 128, CTX, &calib);
        let gain = q / a;
        assert!(gain > 1.15, "e2e QUICK/AWQ gain {gain:.2} too small");
        assert!(gain < 2.2, "e2e gain {gain:.2} implausibly large");
    }

    #[test]
    fn throughput_increases_with_batch() {
        let dev = Gpu::L40.spec();
        let spec = Model::Llama2_13B.spec();
        let calib = Calib::default();
        let mut prev = 0.0;
        for b in [1u64, 4, 16, 64] {
            let t = tokens_per_second(&dev, &spec, KernelKind::Quick, b, CTX, &calib);
            assert!(t > prev, "tokens/s not increasing at batch {b}");
            prev = t;
        }
    }

    #[test]
    fn gemm_dominates_decode_at_small_ctx() {
        let dev = Gpu::A100.spec();
        let spec = Model::Llama33B.spec();
        let b = decode_step_latency(&dev, &spec, KernelKind::Quick, 32, 256, &Calib::default());
        assert!(b.gemm_s > b.attn_s);
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::gpusim::gpu::Gpu;
    use crate::gpusim::kernel_model::{model_gemm, KernelKind};
    use crate::model::Model;

    #[test]
    #[ignore] // calibration probe, run with --ignored -- --nocapture
    fn print_table1_operating_point() {
        let dev = Gpu::RtxA6000.spec();
        let calib = Calib::default();
        for model in [Model::Vicuna13B, Model::Llama2_70B] {
            let spec = model.spec();
            for kind in [KernelKind::Fp16, KernelKind::Awq, KernelKind::Quick] {
                for batch in [32u64, 64, 128] {
                    let d = decode_step_latency(&dev, &spec, kind, batch, 400, &calib);
                    println!(
                        "{} {:6} b{batch}: step {:.2} ms (gemm {:.2}, attn {:.2}, other {:.2}) -> {:.0} tok/s",
                        spec.name, kind.label(), d.total_s()*1e3, d.gemm_s*1e3,
                        d.attn_s*1e3, d.other_s*1e3, batch as f64 / d.total_s()
                    );
                }
            }
            for g in spec.gemms() {
                let p = model_gemm(&dev, KernelKind::Awq, 64, g.n, g.k, &calib);
                println!("  awq b64 {}: {:.0} us tile bm{} wb {:.1} MB", g.name,
                    p.latency_s*1e6, p.tile.bm, p.smem_writeback_bytes/1e6);
            }
        }
    }
}
