//! End-to-end decode modeling: per-step latency, tokens/s, and the OOM
//! predictor behind Figure 8's missing fp16 bars.

use super::gpu::DeviceSpec;
use super::kernel_model::{model_gemm, Calib, KernelKind};
use crate::model::LlmSpec;

/// Breakdown of one decode step at a given batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeBreakdown {
    /// Decode batch size the step was evaluated at.
    pub batch: u64,
    /// Time in the weight GEMMs (what the kernel choice changes).
    pub gemm_s: f64,
    /// Attention (QK^T, softmax, PV) — fp16 in all variants, KV-bandwidth
    /// bound during decode.
    pub attn_s: f64,
    /// Non-GEMM glue (norms, rope, sampling, kernel launches).
    pub other_s: f64,
}

impl DecodeBreakdown {
    /// Total decode-step latency.
    pub fn total_s(&self) -> f64 {
        self.gemm_s + self.attn_s + self.other_s
    }
}

/// Latency of one decode step: every weight GEMM at M = batch via the
/// kernel model, plus a KV-bandwidth attention term.
pub fn decode_step_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    ctx_len: u64,
    calib: &Calib,
) -> DecodeBreakdown {
    assert!(batch > 0);
    let mut gemm_s = 0.0;
    for g in spec.gemms() {
        let p = model_gemm(dev, kind, batch, g.n, g.k, calib);
        gemm_s += p.latency_s * g.count as f64;
    }
    // Decode attention reads each sequence's K and V once: bandwidth-bound.
    let attn_s = kv_attn_term(dev, spec, batch, ctx_len, calib);
    // Elementwise glue: norms/rope/residuals, ~20 small launches per layer
    // fused down to ~4 in practice.
    let other_s = spec.n_layers as f64 * 4.0 * calib.overhead_s;
    DecodeBreakdown { batch, gemm_s, attn_s, other_s }
}

/// The decode-attention KV-bandwidth term shared by
/// [`decode_step_latency`] and [`mixed_step_latency`]: each decode lane
/// reads its sequence's K and V once at derated DRAM bandwidth (scaled
/// by [`Calib::kv_attn_scale`]), plus two attention-kernel launches per
/// layer. At the default `kv_attn_scale = 1.0` this is bit-identical to
/// the pure first-principles term. Public so the measured path
/// (`kernel::StepExecutor::enable_attention`) can price the modeled side
/// of its per-shape attention drift rows with the exact same formula.
pub fn kv_attn_term(dev: &DeviceSpec, spec: &LlmSpec, batch: u64, ctx: u64, calib: &Calib) -> f64 {
    calib.kv_attn_scale * spec.kv_bytes(batch, ctx) / (dev.dram_bw() * calib.dram_eff)
        + spec.n_layers as f64 * 2.0 * calib.overhead_s // 2 attn kernels/layer
}

/// Fit [`Calib::kv_attn_scale`] so the modeled decode-attention term at
/// `(batch, ctx)` matches an *attention wall time measured* by the fused
/// dequant-attention kernel (`kernel::attn_quant_fused` running inside
/// `kernel::StepExecutor` — see `StepExecutor::enable_attention`). The
/// term is linear in the scale, so this solves directly rather than
/// bisecting, with the same `[0, 1024]` clamp-to-achievable semantics as
/// [`super::calibrate_writeback`].
///
/// # Panics
///
/// Panics unless `measured_attn_s` is positive.
pub fn calibrate_kv_attn(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    batch: u64,
    ctx: u64,
    measured_attn_s: f64,
    base: &Calib,
) -> Calib {
    assert!(measured_attn_s > 0.0, "measured attention latency must be positive");
    let bw_s = spec.kv_bytes(batch, ctx.max(1)) / (dev.dram_bw() * base.dram_eff);
    let overhead_s = spec.n_layers as f64 * 2.0 * base.overhead_s;
    let scale = ((measured_attn_s - overhead_s) / bw_s).clamp(0.0, 1024.0);
    Calib { kv_attn_scale: scale, ..*base }
}

/// Breakdown of one *mixed* engine step: `decode_batch` sequences each
/// contributing one decode token plus `prefill_tokens` chunked-prefill
/// prompt tokens riding the same weight GEMMs (Sarathi/vLLM-style chunked
/// prefill). This is the batched-cost query the continuous-batching
/// scheduler drives: the weight GEMMs run once at
/// `M = decode_batch + prefill_tokens`, so prefill tokens amortize the
/// per-step weight streaming that decode-only steps pay in full — exactly
/// the batch-scaling regime (paper §3.3, Figs. 7–8) where QUICK's deleted
/// write-back wins the most.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MixedStepBreakdown {
    /// Decode lanes in the step.
    pub decode_batch: u64,
    /// Chunked-prefill prompt tokens riding the step.
    pub prefill_tokens: u64,
    /// Time in the weight GEMMs at the mixed batch size.
    pub gemm_s: f64,
    /// Decode attention: KV-bandwidth bound reads for the decode lanes.
    pub decode_attn_s: f64,
    /// Prefill attention: tensor-core flops over each chunk's attended
    /// context.
    pub prefill_attn_s: f64,
    /// Non-GEMM glue (norms, rope, sampling, kernel launches).
    pub other_s: f64,
}

impl MixedStepBreakdown {
    /// Total mixed-step latency.
    pub fn total_s(&self) -> f64 {
        self.gemm_s + self.decode_attn_s + self.prefill_attn_s + self.other_s
    }

    /// Tokens processed by the step (decode + chunked prefill).
    pub fn step_tokens(&self) -> u64 {
        self.decode_batch + self.prefill_tokens
    }
}

/// Latency of one mixed decode + chunked-prefill step.
///
/// * `decode_batch` sequences decode one token each against a mean context
///   of `decode_mean_ctx` tokens;
/// * `prefill_tokens` prompt tokens (across any number of per-sequence
///   chunks) share the step's weight GEMMs;
/// * `prefill_attn_ctx_tokens` is the sum over scheduled chunk tokens of
///   the context length they attend to (callers sum `chunk_end_ctx` per
///   chunk) — the O(T·ctx) flops term of chunked-prefill attention.
///
/// With `prefill_tokens == 0` this reduces exactly to
/// [`decode_step_latency`]; the whole point of the mixed step is that
/// `mixed < decode-only + prefill-only` because the weight traffic and
/// per-kernel launch overheads are paid once.
// One scalar per physical term; a param struct would obscure call sites.
#[allow(clippy::too_many_arguments)]
pub fn mixed_step_latency(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    decode_batch: u64,
    decode_mean_ctx: u64,
    prefill_tokens: u64,
    prefill_attn_ctx_tokens: u64,
    calib: &Calib,
) -> MixedStepBreakdown {
    let m = decode_batch + prefill_tokens;
    assert!(m > 0, "mixed step with no tokens");
    let mut gemm_s = 0.0;
    for g in spec.gemms() {
        gemm_s += model_gemm(dev, kind, m, g.n, g.k, calib).latency_s * g.count as f64;
    }
    let decode_attn_s = if decode_batch > 0 {
        kv_attn_term(dev, spec, decode_batch, decode_mean_ctx.max(1), calib)
    } else {
        0.0
    };
    let prefill_attn_s = if prefill_tokens > 0 {
        let attn_flops = 2.0 * 2.0 * prefill_attn_ctx_tokens as f64
            * spec.d_model as f64
            * spec.n_layers as f64;
        attn_flops / (dev.tc_tflops * 1e12 * calib.mma_eff)
    } else {
        0.0
    };
    let other_s = spec.n_layers as f64 * 4.0 * calib.overhead_s;
    MixedStepBreakdown {
        decode_batch,
        prefill_tokens,
        gemm_s,
        decode_attn_s,
        prefill_attn_s,
        other_s,
    }
}

/// Decode throughput (tokens/s) at a static batch, Fig. 8's y-axis.
pub fn tokens_per_second(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    kind: KernelKind,
    batch: u64,
    ctx_len: u64,
    calib: &Calib,
) -> f64 {
    let step = decode_step_latency(dev, spec, kind, batch, ctx_len, calib);
    batch as f64 / step.total_s()
}

/// Does (weights + KV at `ctx_len` + activations + CUDA overhead) fit?
pub fn fits_in_memory(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    w4: bool,
    batch: u64,
    ctx_len: u64,
) -> bool {
    const RUNTIME_OVERHEAD: f64 = 1.5 * (1u64 << 30) as f64; // CUDA ctx etc.
    let need = spec.weight_bytes(w4)
        + spec.kv_bytes(batch, ctx_len)
        + spec.activation_bytes(batch)
        + RUNTIME_OVERHEAD;
    need <= dev.mem_bytes()
}

/// Largest power-of-two batch that fits (0 = not even batch 1 — the paper's
/// "OOM" cells).
pub fn max_batch_before_oom(
    dev: &DeviceSpec,
    spec: &LlmSpec,
    w4: bool,
    ctx_len: u64,
) -> u64 {
    if !fits_in_memory(dev, spec, w4, 1, ctx_len) {
        return 0;
    }
    let mut b = 1;
    while b <= 1024 && fits_in_memory(dev, spec, w4, b * 2, ctx_len) {
        b *= 2;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;
    use crate::model::Model;

    const CTX: u64 = 1024;

    #[test]
    fn fig8_mistral_4090_fp16_ooms_at_256() {
        // Paper §4.2: fp16 Mistral-7B on RTX 4090 cannot run batch 256;
        // 4-bit can.
        let dev = Gpu::Rtx4090.spec();
        let spec = Model::Mistral7B.spec();
        assert!(!fits_in_memory(&dev, &spec, false, 256, 512));
        assert!(fits_in_memory(&dev, &spec, true, 256, 512));
    }

    #[test]
    fn table1_llama70b_a6000_fp16_oom() {
        // Table 1: fp16 Llama-2-70B OOMs on A6000 (140 GB weights alone);
        // W4 fits.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Llama2_70B.spec();
        assert_eq!(max_batch_before_oom(&dev, &spec, false, CTX), 0);
        assert!(max_batch_before_oom(&dev, &spec, true, CTX) >= 8);
    }

    #[test]
    fn quick_beats_awq_at_large_batch_e2e() {
        let dev = Gpu::Rtx4090.spec();
        let spec = Model::Mistral7B.spec();
        let calib = Calib::default();
        let q = tokens_per_second(&dev, &spec, KernelKind::Quick, 128, CTX, &calib);
        let a = tokens_per_second(&dev, &spec, KernelKind::Awq, 128, CTX, &calib);
        let gain = q / a;
        assert!(gain > 1.15, "e2e QUICK/AWQ gain {gain:.2} too small");
        assert!(gain < 2.2, "e2e gain {gain:.2} implausibly large");
    }

    #[test]
    fn throughput_increases_with_batch() {
        let dev = Gpu::L40.spec();
        let spec = Model::Llama2_13B.spec();
        let calib = Calib::default();
        let mut prev = 0.0;
        for b in [1u64, 4, 16, 64] {
            let t = tokens_per_second(&dev, &spec, KernelKind::Quick, b, CTX, &calib);
            assert!(t > prev, "tokens/s not increasing at batch {b}");
            prev = t;
        }
    }

    #[test]
    fn mixed_step_reduces_to_decode_step() {
        // prefill_tokens == 0 must reproduce decode_step_latency exactly.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let calib = Calib::default();
        for (b, ctx) in [(1u64, 128u64), (32, 512), (128, 1024)] {
            let d = decode_step_latency(&dev, &spec, KernelKind::Quick, b, ctx, &calib);
            let m = mixed_step_latency(&dev, &spec, KernelKind::Quick, b, ctx, 0, 0, &calib);
            assert!(
                (d.total_s() - m.total_s()).abs() < 1e-12,
                "b={b} ctx={ctx}: {} vs {}",
                d.total_s(),
                m.total_s()
            );
            assert_eq!(m.prefill_attn_s, 0.0);
        }
    }

    #[test]
    fn chunked_prefill_piggybacks_on_decode() {
        // In the memory-bound decode regime (small batch, weight streaming
        // dominates) a fused mixed step is much cheaper than a decode step
        // plus a separate prefill call for the same tokens: the weight
        // traffic and launch overheads are paid once. This is the saving
        // the continuous scheduler's chunk-riding monetizes.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let calib = Calib::default();
        for kind in [KernelKind::Awq, KernelKind::Quick] {
            let (b, ctx, chunk) = (8u64, 400u64, 56u64);
            let fused =
                mixed_step_latency(&dev, &spec, kind, b, ctx, chunk, chunk * 2, &calib);
            let decode = decode_step_latency(&dev, &spec, kind, b, ctx, &calib);
            let prefill_only =
                mixed_step_latency(&dev, &spec, kind, 0, 0, chunk, chunk * 2, &calib);
            assert!(
                fused.total_s() < 0.85 * (decode.total_s() + prefill_only.total_s()),
                "{kind:?}: fused {} !< 0.85x separate {}",
                fused.total_s(),
                decode.total_s() + prefill_only.total_s()
            );
        }
    }

    #[test]
    fn mixed_step_monotone_in_prefill_tokens() {
        let dev = Gpu::A100.spec();
        let spec = Model::Mistral7B.spec();
        let calib = Calib::default();
        let mut prev = 0.0;
        for chunk in [0u64, 64, 256, 512, 1024] {
            let m = mixed_step_latency(
                &dev,
                &spec,
                KernelKind::Quick,
                16,
                512,
                chunk,
                chunk * 2,
                &calib,
            );
            assert!(m.total_s() >= prev * 0.999, "not monotone at chunk {chunk}");
            assert_eq!(m.step_tokens(), 16 + chunk);
            prev = m.total_s();
        }
    }

    #[test]
    fn pure_chunk_step_has_no_decode_attention() {
        let dev = Gpu::A100.spec();
        let spec = Model::Mistral7B.spec();
        let m = mixed_step_latency(
            &dev,
            &spec,
            KernelKind::Quick,
            0,
            0,
            512,
            1024,
            &Calib::default(),
        );
        assert_eq!(m.decode_attn_s, 0.0);
        assert!(m.prefill_attn_s > 0.0 && m.gemm_s > 0.0);
    }

    #[test]
    fn gemm_dominates_decode_at_small_ctx() {
        let dev = Gpu::A100.spec();
        let spec = Model::Llama33B.spec();
        let b = decode_step_latency(&dev, &spec, KernelKind::Quick, 32, 256, &Calib::default());
        assert!(b.gemm_s > b.attn_s);
    }

    #[test]
    fn calibrate_kv_attn_matches_measured_attention() {
        let dev = Gpu::A100.spec();
        let spec = Model::Llama33B.spec();
        let base = Calib::default();
        let (batch, ctx) = (16u64, 700u64);
        // Synthesize a "measured" attention time from a known scale and
        // check the fit recovers it (the term is linear in the scale).
        let truth = Calib { kv_attn_scale: 2.5, ..base };
        let measured = decode_step_latency(&dev, &spec, KernelKind::Quick, batch, ctx, &truth)
            .attn_s;
        let fit = calibrate_kv_attn(&dev, &spec, batch, ctx, measured, &base);
        assert!((fit.kv_attn_scale - 2.5).abs() < 1e-9, "{}", fit.kv_attn_scale);
        // Every other knob is carried over from the base.
        assert_eq!(fit.writeback_scale, base.writeback_scale);
        // The fitted calib reproduces the measured term.
        let re = decode_step_latency(&dev, &spec, KernelKind::Quick, batch, ctx, &fit).attn_s;
        assert!((re - measured).abs() / measured < 1e-12);
        // A measured time at or below the launch-overhead floor clamps to 0.
        let floor = calibrate_kv_attn(&dev, &spec, batch, ctx, 1e-12, &base);
        assert_eq!(floor.kv_attn_scale, 0.0);
    }

    #[test]
    fn default_kv_attn_scale_is_identity() {
        // kv_attn_scale = 1.0 must reproduce the historical term exactly
        // (1.0 * x == x in IEEE arithmetic): spot-check against the
        // hand-written formula.
        let dev = Gpu::RtxA6000.spec();
        let spec = Model::Vicuna13B.spec();
        let calib = Calib::default();
        let b = decode_step_latency(&dev, &spec, KernelKind::Quick, 8, 333, &calib);
        let want = spec.kv_bytes(8, 333) / (dev.dram_bw() * calib.dram_eff)
            + spec.n_layers as f64 * 2.0 * calib.overhead_s;
        assert_eq!(b.attn_s.to_bits(), want.to_bits());
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::gpusim::gpu::Gpu;
    use crate::gpusim::kernel_model::{model_gemm, KernelKind};
    use crate::model::Model;

    #[test]
    #[ignore] // calibration probe, run with --ignored -- --nocapture
    fn print_table1_operating_point() {
        let dev = Gpu::RtxA6000.spec();
        let calib = Calib::default();
        for model in [Model::Vicuna13B, Model::Llama2_70B] {
            let spec = model.spec();
            for kind in [KernelKind::Fp16, KernelKind::Awq, KernelKind::Quick] {
                for batch in [32u64, 64, 128] {
                    let d = decode_step_latency(&dev, &spec, kind, batch, 400, &calib);
                    println!(
                        "{} {:6} b{batch}: step {:.2} ms (gemm {:.2}, attn {:.2}, other {:.2}) -> {:.0} tok/s",
                        spec.name, kind.label(), d.total_s()*1e3, d.gemm_s*1e3,
                        d.attn_s*1e3, d.other_s*1e3, batch as f64 / d.total_s()
                    );
                }
            }
            for g in spec.gemms() {
                let p = model_gemm(&dev, KernelKind::Awq, 64, g.n, g.k, &calib);
                println!("  awq b64 {}: {:.0} us tile bm{} wb {:.1} MB", g.name,
                    p.latency_s*1e6, p.tile.bm, p.smem_writeback_bytes/1e6);
            }
        }
    }
}
