//! Device specification table — public datasheet numbers for the four GPUs
//! of the paper's evaluation (Figs. 7–8, Table 1).

/// One GPU model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    /// Marketing name, as the paper's figures label it.
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: u32,
    /// SM boost clock, GHz.
    pub clock_ghz: f64,
    /// Peak fp16 tensor-core throughput with fp32 accumulate, TFLOP/s.
    /// (The dense, non-sparsity number — what GEMM kernels actually see.)
    pub tc_tflops: f64,
    /// Peak fp32 CUDA-core throughput, TFLOP/s.
    pub fp32_tflops: f64,
    /// Peak fp16 CUDA-core (half2 intrinsic) throughput, TFLOP/s — the pipe
    /// the parallel dequantizer's FMAs actually run on.
    pub fp16_alu_tflops: f64,
    /// DRAM bandwidth, GB/s.
    pub dram_gbps: f64,
    /// Device memory, GiB.
    pub mem_gib: f64,
    /// L2 cache, MiB (governs weight-tile reuse across concurrent blocks).
    pub l2_mib: f64,
    /// Shared memory per SM, KiB (max carve-out).
    pub smem_per_sm_kib: u32,
    /// 32-bit registers per SM.
    pub regs_per_sm: u32,
    /// Max resident warps per SM.
    pub max_warps_per_sm: u32,
    /// Shared-memory bandwidth per SM, bytes/cycle (128 B/clk on all of
    /// Ampere/Ada: 32 banks x 4 B).
    pub smem_bytes_per_clk: u32,
    /// Per-GPU interconnect bandwidth to tensor-parallel peers, GB/s per
    /// direction (NVLink3 for A100-SXM; PCIe 4.0 x16 for the Ada/Ampere
    /// cards, which have no inter-GPU NVLink fabric at rack scale).
    pub link_gbps: f64,
    /// Per-hop link latency, seconds (send/recv launch + wire + switch).
    pub link_latency_s: f64,
}

impl DeviceSpec {
    /// Aggregate shared-memory bandwidth, bytes/s.
    pub fn smem_bw(&self) -> f64 {
        self.sms as f64 * self.smem_bytes_per_clk as f64 * self.clock_ghz * 1e9
    }

    /// DRAM bandwidth in bytes/s.
    pub fn dram_bw(&self) -> f64 {
        self.dram_gbps * 1e9
    }

    /// Device memory in bytes.
    pub fn mem_bytes(&self) -> f64 {
        self.mem_gib * (1u64 << 30) as f64
    }

    /// Tensor-parallel link bandwidth in bytes/s per direction.
    pub fn link_bw(&self) -> f64 {
        self.link_gbps * 1e9
    }
}

/// The paper's four evaluation devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gpu {
    /// Ada AD102 consumer flagship (paper Figs. 3, 7, 8).
    Rtx4090,
    /// Ampere GA102 workstation card (paper Table 1).
    RtxA6000,
    /// Ada AD102 datacenter card.
    L40,
    /// A100-SXM4-80GB (GA100), the NVLink-connected datacenter part.
    A100,
}

impl Gpu {
    /// Every evaluated device, in the paper's order.
    pub const ALL: [Gpu; 4] = [Gpu::Rtx4090, Gpu::RtxA6000, Gpu::L40, Gpu::A100];

    /// Datasheet numbers for this device.
    pub fn spec(self) -> DeviceSpec {
        match self {
            // Ada AD102. 128 SM, 330 fp16 TC TFLOPs (165 with fp32 acc is
            // the *marketing* split; AD10x does fp32-acc at full rate).
            Gpu::Rtx4090 => DeviceSpec {
                name: "RTX 4090",
                sms: 128,
                clock_ghz: 2.52,
                tc_tflops: 165.2,
                fp32_tflops: 82.6,
                fp16_alu_tflops: 82.6,
                dram_gbps: 1008.0,
                mem_gib: 24.0,
                l2_mib: 72.0,
                smem_per_sm_kib: 100,
                regs_per_sm: 65536,
                max_warps_per_sm: 48,
                smem_bytes_per_clk: 128,
                link_gbps: 32.0,
                link_latency_s: 5e-6,
            },
            // Ampere GA102, workstation.
            Gpu::RtxA6000 => DeviceSpec {
                name: "RTX A6000",
                sms: 84,
                clock_ghz: 1.80,
                tc_tflops: 77.4,
                fp32_tflops: 38.7,
                fp16_alu_tflops: 77.4,
                dram_gbps: 768.0,
                mem_gib: 48.0,
                l2_mib: 6.0,
                smem_per_sm_kib: 100,
                regs_per_sm: 65536,
                max_warps_per_sm: 48,
                smem_bytes_per_clk: 128,
                link_gbps: 32.0,
                link_latency_s: 5e-6,
            },
            // Ada AD102, datacenter.
            Gpu::L40 => DeviceSpec {
                name: "L40",
                sms: 142,
                clock_ghz: 2.49,
                tc_tflops: 90.5,
                fp32_tflops: 90.5,
                fp16_alu_tflops: 90.5,
                dram_gbps: 864.0,
                mem_gib: 48.0,
                l2_mib: 96.0,
                smem_per_sm_kib: 100,
                regs_per_sm: 65536,
                max_warps_per_sm: 48,
                smem_bytes_per_clk: 128,
                link_gbps: 32.0,
                link_latency_s: 5e-6,
            },
            // A100-SXM4-80GB (GA100).
            Gpu::A100 => DeviceSpec {
                name: "A100",
                sms: 108,
                clock_ghz: 1.41,
                tc_tflops: 312.0,
                fp32_tflops: 19.5,
                fp16_alu_tflops: 78.0,
                dram_gbps: 2039.0,
                mem_gib: 80.0,
                l2_mib: 40.0,
                smem_per_sm_kib: 164,
                regs_per_sm: 65536,
                max_warps_per_sm: 64,
                smem_bytes_per_clk: 128,
                link_gbps: 300.0,
                link_latency_s: 3e-6,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_sanity() {
        for g in Gpu::ALL {
            let s = g.spec();
            assert!(s.sms > 0 && s.tc_tflops > 10.0 && s.dram_gbps > 500.0);
            assert!(s.smem_bw() > 1e12, "{}: smem bw too low", s.name);
        }
    }

    #[test]
    fn link_specs_sane() {
        for g in Gpu::ALL {
            let s = g.spec();
            assert!(s.link_gbps > 0.0 && s.link_latency_s > 0.0, "{}", s.name);
            // Inter-GPU links are always slower than local DRAM.
            assert!(s.link_bw() < s.dram_bw(), "{}: link faster than DRAM", s.name);
        }
        // NVLink A100 vs the PCIe cards.
        assert!(Gpu::A100.spec().link_gbps > 4.0 * Gpu::L40.spec().link_gbps);
    }

    #[test]
    fn a100_has_most_dram_bw() {
        let a100 = Gpu::A100.spec().dram_gbps;
        for g in [Gpu::Rtx4090, Gpu::RtxA6000, Gpu::L40] {
            assert!(a100 > g.spec().dram_gbps);
        }
    }
}
