//! Tile-level latency model for the three GEMM kernels (fp16 / AWQ
//! baseline / QUICK) — regenerates Figure 7 and feeds Figure 8 / Table 1.
//!
//! The model composes first-principles terms:
//!
//! * **DRAM time** — weight + activation + output traffic over `dram_bw`,
//!   with threadblock-swizzle L2 reuse of weight tiles across concurrent
//!   M-blocks and L2-resident activations when they fit.
//! * **Tensor-core time** — padded-tile MMA flops over `tc_tflops`,
//!   derated by occupancy-driven latency hiding.
//! * **Dequant time** (quantized kernels) — ~4 CUDA-core ops per
//!   dequantized fp16 element on the half2 ALU pipe (the
//!   FasterTransformer dequantizer is fp16x2 arithmetic).
//! * **Write-back time** (baseline only) — dequantized weights pushed
//!   through shared memory, serialized by the *measured* bank-conflict
//!   multiplier from [`super::trace::awq_writeback`] +
//!   [`super::bank::BankCounter`]. This is the term QUICK deletes (paper
//!   §3.1) — on the critical path because `ldmatrix` requires the tile to
//!   be fully visible in shared memory before `mma` can issue.
//!
//! Per-kernel tile candidates mirror §3.3: the baseline stages weights in
//! shared memory (smem-limited occupancy, BM <= 64); QUICK's register-only
//! weight path allows BM up to 192 ("tile size optimization"), trading
//! register pressure for fewer weight re-reads at large batch.

use super::bank::BankCounter;
use super::gpu::DeviceSpec;
use super::occupancy::{latency_hiding, occupancy, BlockResources};
use super::trace;
use crate::quant::DecoderKind;

/// Which kernel is being modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Half-precision GEMM (cuBLAS-like), the unquantized baseline.
    Fp16,
    /// AutoAWQ-style mixed-precision kernel: dequant → smem write-back →
    /// ldmatrix → mma.
    Awq,
    /// The paper's kernel: offline interleave, direct DRAM→register weight
    /// loads, dequant in registers, no weight smem.
    Quick,
}

impl KernelKind {
    /// All modeled kernels, baseline first.
    pub const ALL: [KernelKind; 3] = [KernelKind::Fp16, KernelKind::Awq, KernelKind::Quick];

    /// Short display label (figure/CLI rows).
    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Fp16 => "fp16",
            KernelKind::Awq => "AWQ",
            KernelKind::Quick => "QUICK",
        }
    }
}

/// One thread-block tile shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Tile rows (M per thread block).
    pub bm: u64,
    /// Tile columns (N per thread block).
    pub bn: u64,
    /// Reduction depth per main-loop iteration.
    pub bk: u64,
    /// Warps per thread block.
    pub warps: u32,
    /// Registers per thread the tile needs resident.
    pub regs_per_thread: u32,
}

/// Calibration constants — every non-datasheet number in the model lives
/// here (documented in DESIGN.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calib {
    /// Fraction of peak tensor-core throughput a well-tuned GEMM reaches.
    pub mma_eff: f64,
    /// Fraction of peak DRAM bandwidth streaming loads reach.
    pub dram_eff: f64,
    /// CUDA-core ops per dequantized element (AND+SHR+sub+FMA).
    pub dequant_ops: f64,
    /// Fixed kernel launch + epilogue overhead, seconds.
    pub overhead_s: f64,
    /// Threadblock-swizzle span: adjacent M-blocks sharing weight tiles
    /// through L2.
    pub swizzle_span: u64,
    /// Multiplier on the baseline kernel's modeled write-back time.
    /// `1.0` = pure first-principles model; [`calibrate_writeback`] sets
    /// it so the modeled AWQ/QUICK gap matches the gap *measured* by the
    /// native kernel backend (`crate::kernel`, `bench kernels`).
    pub writeback_scale: f64,
    /// Multiplier on the decode-attention KV-bandwidth term of
    /// [`super::decode_step_latency`] / [`super::mixed_step_latency`].
    /// `1.0` = pure first-principles model (attention reads each
    /// sequence's K and V once at `dram_eff` bandwidth);
    /// [`calibrate_kv_attn`] sets it so the modeled term matches the
    /// attention wall time *measured* by the fused dequant-attention
    /// kernel (`kernel::attn_quant_fused` via `StepExecutor`).
    pub kv_attn_scale: f64,
    /// Multiplier on the dequant term when the kernel runs the
    /// shift-mask nibble decoder ([`DecoderKind::ShiftMask`]). `1.0` =
    /// the stock ~4-ops-per-element estimate ([`Calib::dequant_ops`]).
    pub dequant_scale_shift: f64,
    /// Multiplier on the dequant term when the kernel runs the 16-entry
    /// codebook LUT decoder ([`DecoderKind::Lut`]) — byte shuffle +
    /// affine rather than AND/SHR/sub/FMA. `1.0` = priced identically
    /// to shift-mask; [`calibrate_dequant`] fits it so the modeled
    /// LUT/shift-mask latency ratio matches the ratio *measured* by the
    /// native decoders (`bench kernels --lut`).
    pub dequant_scale_lut: f64,
}

impl Default for Calib {
    fn default() -> Self {
        Calib {
            mma_eff: 0.75,
            dram_eff: 0.80,
            dequant_ops: 4.0,
            overhead_s: 8e-6,
            swizzle_span: 8,
            writeback_scale: 1.0,
            kv_attn_scale: 1.0,
            dequant_scale_shift: 1.0,
            dequant_scale_lut: 1.0,
        }
    }
}

impl Calib {
    /// The dequant-term multiplier for `decoder` — the key the drift
    /// accountant and [`calibrate_dequant`] price decoders by.
    pub fn dequant_scale(&self, decoder: DecoderKind) -> f64 {
        match decoder {
            DecoderKind::ShiftMask => self.dequant_scale_shift,
            DecoderKind::Lut => self.dequant_scale_lut,
        }
    }
}

/// Model output for one (kernel, M, N, K, device) point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelPerf {
    pub kind: KernelKind,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub latency_s: f64,
    /// Effective tera-ops/s on the *true* (unpadded) flops — Fig. 7's y-axis.
    pub tops: f64,
    pub dram_bytes: f64,
    /// Dequantized bytes pushed through shared memory (baseline only).
    pub smem_writeback_bytes: f64,
    /// Shared-memory bank conflicts for the whole problem (Fig. 3).
    pub conflicts: u64,
    /// Conflict replay multiplier observed on the write-back pattern.
    pub conflict_multiplier: f64,
    pub occupancy_fraction: f64,
    pub tile: TileConfig,
}

/// Weight bytes per element for 4-bit + group metadata (scales fp16 +
/// packed qzeros), group size 128: 0.5 + (2 + 0.5)/128.
const Q4_BYTES_PER_ELEM: f64 = 0.5 + 2.5 / 128.0;
const F16_BYTES: f64 = 2.0;

fn tile_candidates(kind: KernelKind) -> Vec<TileConfig> {
    match kind {
        KernelKind::Fp16 => vec![
            TileConfig { bm: 64, bn: 128, bk: 32, warps: 4, regs_per_thread: 112 },
            TileConfig { bm: 128, bn: 128, bk: 32, warps: 4, regs_per_thread: 128 },
            TileConfig { bm: 256, bn: 128, bk: 32, warps: 8, regs_per_thread: 128 },
        ],
        // Baseline: weight staging caps the tile (smem pressure, §3.3).
        KernelKind::Awq => vec![
            TileConfig { bm: 16, bn: 128, bk: 64, warps: 4, regs_per_thread: 96 },
            TileConfig { bm: 32, bn: 128, bk: 64, warps: 4, regs_per_thread: 96 },
            TileConfig { bm: 64, bn: 128, bk: 64, warps: 4, regs_per_thread: 104 },
        ],
        // QUICK: no weight smem -> larger activation tiles become viable.
        KernelKind::Quick => vec![
            TileConfig { bm: 16, bn: 128, bk: 64, warps: 4, regs_per_thread: 128 },
            TileConfig { bm: 32, bn: 128, bk: 64, warps: 4, regs_per_thread: 136 },
            TileConfig { bm: 64, bn: 128, bk: 64, warps: 4, regs_per_thread: 144 },
            TileConfig { bm: 128, bn: 128, bk: 64, warps: 4, regs_per_thread: 160 },
            TileConfig { bm: 192, bn: 128, bk: 64, warps: 4, regs_per_thread: 184 },
        ],
    }
}

/// Shared memory one block of this kernel needs (double-buffered fp16
/// tiles; the baseline also stages the dequantized weight tile).
fn smem_bytes(kind: KernelKind, t: &TileConfig) -> u32 {
    let act = t.bm * t.bk * 2 * 2; // two stages
    let weight = match kind {
        KernelKind::Fp16 | KernelKind::Awq => t.bk * t.bn * 2 * 2,
        KernelKind::Quick => 0,
    };
    (act + weight) as u32
}

/// Measure the write-back conflict multiplier for one representative tile
/// of the baseline kernel, plus total conflicts scaled to the full problem.
fn writeback_conflicts(t: &TileConfig, blocks: u64, k_iters: u64) -> (u64, f64) {
    let mut c = BankCounter::new();
    // One (block, k-iter): a BK x BN dequantized weight tile; each warp-row
    // of the trace covers 256 fp16 (32 lanes x 8), so BK*BN/256 rows.
    let rows = (t.bk * t.bn) / 256;
    trace::awq_writeback(&mut c, t.bn, rows);
    let per_tile = c;
    let total = per_tile.scaled(blocks * k_iters);
    (total.conflicts, per_tile.multiplier())
}

/// Model one GEMM: `y(M,N) = x(M,K) @ w(K,N)` on `dev` with kernel `kind`
/// (shift-mask decoder — see [`model_gemm_decoder`] for the LUT tier).
pub fn model_gemm(
    dev: &DeviceSpec,
    kind: KernelKind,
    m: u64,
    n: u64,
    k: u64,
    calib: &Calib,
) -> KernelPerf {
    model_gemm_decoder(dev, kind, DecoderKind::ShiftMask, m, n, k, calib)
}

/// Like [`model_gemm`], but price the dequant term for a specific nibble
/// decoder: the per-element cost is `dequant_ops * dequant_scale(decoder)`
/// ops, so shift-mask and LUT kernels model separately once
/// [`calibrate_dequant`] has fit the LUT scale. With the default `Calib`
/// both decoders price identically.
pub fn model_gemm_decoder(
    dev: &DeviceSpec,
    kind: KernelKind,
    decoder: DecoderKind,
    m: u64,
    n: u64,
    k: u64,
    calib: &Calib,
) -> KernelPerf {
    assert!(m > 0 && n > 0 && k > 0);
    let mut best: Option<KernelPerf> = None;
    for t in tile_candidates(kind) {
        let perf = model_with_tile(dev, kind, decoder, m, n, k, &t, calib);
        if best.as_ref().map_or(true, |b| perf.latency_s < b.latency_s) {
            best = Some(perf);
        }
    }
    best.unwrap()
}

fn model_with_tile(
    dev: &DeviceSpec,
    kind: KernelKind,
    decoder: DecoderKind,
    m: u64,
    n: u64,
    k: u64,
    t: &TileConfig,
    calib: &Calib,
) -> KernelPerf {
    let tm = m.div_ceil(t.bm);
    let tn = n.div_ceil(t.bn);
    let k_iters = k.div_ceil(t.bk);
    let blocks = tm * tn;

    // --- occupancy ---
    let occ = occupancy(dev, &BlockResources {
        warps: t.warps,
        smem_bytes: smem_bytes(kind, t),
        regs_per_thread: t.regs_per_thread,
    });
    // Few blocks -> some SMs idle (wave quantization).
    let sm_fill = (blocks as f64 / dev.sms as f64).min(1.0);
    let hiding = latency_hiding(occ.fraction) * sm_fill.max(0.25);

    // --- DRAM traffic ---
    let bpe_w = match kind {
        KernelKind::Fp16 => F16_BYTES,
        _ => Q4_BYTES_PER_ELEM,
    };
    // Weight strips re-stream once per swizzle-span of M-blocks.
    let weight_passes = tm.div_ceil(calib.swizzle_span) as f64;
    let weight_bytes = (k * n) as f64 * bpe_w * weight_passes;
    // Activations: resident in L2 across N-blocks when they fit.
    let act_once = (m * k) as f64 * F16_BYTES;
    let act_bytes = if act_once <= dev.l2_mib * 1024.0 * 1024.0 * 0.5 {
        act_once
    } else {
        act_once * (tn as f64 / calib.swizzle_span as f64).max(1.0)
    };
    let out_bytes = (m * n) as f64 * F16_BYTES;
    let dram_bytes = weight_bytes + act_bytes + out_bytes;
    let dram_time = dram_bytes / (dev.dram_bw() * calib.dram_eff);

    // --- tensor-core time (padded tiles do full work) ---
    let mma_flops = 2.0 * (tm * t.bm) as f64 * (tn * t.bn) as f64 * k as f64;
    let mma_time = mma_flops / (dev.tc_tflops * 1e12 * calib.mma_eff * hiding);

    // --- dequantization (CUDA cores) ---
    let dequant_elems = match kind {
        KernelKind::Fp16 => 0.0,
        // Every M-block pass dequantizes the full K x N weight strip.
        _ => (k * n) as f64 * tm as f64,
    };
    let dequant_time = calib.dequant_ops * calib.dequant_scale(decoder) * dequant_elems
        / (dev.fp16_alu_tflops * 1e12 * hiding);

    // --- shared-memory write-back (baseline only), conflict-serialized ---
    let (conflicts, mult, wb_bytes, wb_time) = match kind {
        KernelKind::Awq => {
            let (confl, mult) = writeback_conflicts(t, blocks, k_iters);
            let bytes = (k * n) as f64 * F16_BYTES * tm as f64;
            // Conflicts serialize replays: effective bandwidth /= mult.
            // The ldmatrix re-read of the same data is swizzled
            // (conflict-free) and overlaps the next dequant batch; the
            // write-back itself cannot be hidden (ldmatrix needs the full
            // tile visible -> __syncthreads barrier).
            let time = bytes * mult * calib.writeback_scale / dev.smem_bw();
            (confl, mult, bytes, time)
        }
        _ => (0, 1.0, 0.0, 0.0),
    };

    // Compute-side critical path: mma + dequant (+ write-back barrier for
    // the baseline) — these serialize per §2.3/Fig. 2; DRAM streaming
    // overlaps via async copy.
    let busy = mma_time + dequant_time + wb_time;
    let latency = calib.overhead_s + busy.max(dram_time);
    let true_flops = 2.0 * m as f64 * n as f64 * k as f64;

    KernelPerf {
        kind,
        m,
        n,
        k,
        latency_s: latency,
        tops: true_flops / latency / 1e12,
        dram_bytes,
        smem_writeback_bytes: wb_bytes,
        conflicts,
        conflict_multiplier: mult,
        occupancy_fraction: occ.fraction,
        tile: *t,
    }
}

/// Calibrate the modeled write-back penalty from *measured* native-kernel
/// tile costs (the engine hook behind `bench kernels`): returns a `Calib`
/// whose [`Calib::writeback_scale`] makes the modeled AWQ/QUICK latency
/// ratio at `(m, n, k)` on `dev` match the measured
/// write-back/fused wall-time ratio from [`crate::kernel`]'s
/// `gemm_awq_writeback` / `gemm_quick_fused` pair.
///
/// The scale is found by bisection (the modeled ratio is monotone
/// non-decreasing in the scale) and clamped to `[0, 1024]`; if the model
/// cannot reach the measured ratio even at the clamp — e.g. the measured
/// gap exceeds what any write-back serialization could explain, or is
/// below the model's write-back-free floor — the nearest achievable scale
/// is returned. Every `simserve` / `figures` query that takes a `Calib`
/// can then run on measured rather than modeled tile costs.
///
/// # Panics
///
/// Panics unless both measured latencies are positive.
pub fn calibrate_writeback(
    dev: &DeviceSpec,
    m: u64,
    n: u64,
    k: u64,
    measured_fused_s: f64,
    measured_writeback_s: f64,
    base: &Calib,
) -> Calib {
    assert!(
        measured_fused_s > 0.0 && measured_writeback_s > 0.0,
        "measured latencies must be positive"
    );
    let target = (measured_writeback_s / measured_fused_s).max(1.0);
    fit_writeback_scale(target, base, |scale| {
        let c = Calib { writeback_scale: scale, ..*base };
        model_gemm(dev, KernelKind::Awq, m, n, k, &c).latency_s
            / model_gemm(dev, KernelKind::Quick, m, n, k, &c).latency_s
    })
}

/// Modeled latency of all weight GEMMs of one forward step of `spec` at
/// batch `m` — the model-side twin of `kernel::StepExecutor::step`
/// (which *measures* the same stream natively). Attention and
/// collectives are intentionally excluded on both sides so measured and
/// modeled step latencies are like-for-like.
pub fn model_step_gemms(
    dev: &DeviceSpec,
    spec: &crate::model::LlmSpec,
    kind: KernelKind,
    m: u64,
    calib: &Calib,
) -> f64 {
    spec.gemms()
        .iter()
        .map(|g| model_gemm(dev, kind, m, g.n, g.k, calib).latency_s * g.count as f64)
        .sum()
}

/// Like [`calibrate_writeback`], but fit against a *measured full decode
/// step* rather than a single GEMM: finds the [`Calib::writeback_scale`]
/// at which the modeled AWQ/QUICK **step** latency ratio
/// ([`model_step_gemms`]) matches the measured write-back/fused step
/// ratio from `kernel::StepExecutor` (`simulate step`). Same bisection,
/// same clamping semantics.
///
/// # Panics
///
/// Panics unless both measured step latencies are positive.
pub fn calibrate_step_writeback(
    dev: &DeviceSpec,
    spec: &crate::model::LlmSpec,
    m: u64,
    measured_fused_s: f64,
    measured_writeback_s: f64,
    base: &Calib,
) -> Calib {
    assert!(
        measured_fused_s > 0.0 && measured_writeback_s > 0.0,
        "measured step latencies must be positive"
    );
    let target = (measured_writeback_s / measured_fused_s).max(1.0);
    fit_writeback_scale(target, base, |scale| {
        let c = Calib { writeback_scale: scale, ..*base };
        model_step_gemms(dev, spec, KernelKind::Awq, m, &c)
            / model_step_gemms(dev, spec, KernelKind::Quick, m, &c)
    })
}

/// Shared bisection core of the two calibration hooks: find the
/// `writeback_scale` at which `ratio(scale)` (monotone non-decreasing)
/// reaches `target`, clamped to `[0, 1024]` with nearest-achievable
/// fallback at either end.
fn fit_writeback_scale(target: f64, base: &Calib, ratio: impl Fn(f64) -> f64) -> Calib {
    Calib { writeback_scale: fit_scale(target, &ratio), ..*base }
}

/// Generic monotone-bisection core shared by the calibration hooks: the
/// scale in `[0, 1024]` at which `ratio(scale)` (monotone non-decreasing)
/// reaches `target`, with nearest-achievable fallback at either end.
fn fit_scale(target: f64, ratio: &impl Fn(f64) -> f64) -> f64 {
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while ratio(hi) < target && hi < 1024.0 {
        hi *= 2.0;
    }
    if ratio(lo) >= target {
        // Measured gap at or below the scale-free floor.
        return lo;
    }
    if ratio(hi) < target {
        return hi;
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if ratio(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Calibrate the LUT decoder's modeled dequant cost from *measured*
/// native decode-tier costs (the engine hook behind `bench kernels
/// --lut`): returns a `Calib` whose [`Calib::dequant_scale_lut`] makes
/// the modeled LUT/shift-mask latency ratio of kernel `kind` at
/// `(m, n, k)` on `dev` match the measured ratio from running the same
/// GEMM through [`crate::kernel`] with each [`DecoderKind`]. The
/// shift-mask scale is left at `base`'s (the shift-mask tier is the
/// reference the stock `dequant_ops` estimate was built for), so after
/// calibration the cost model prices the two decoders separately.
///
/// Same bisection and clamping semantics as [`calibrate_writeback`]:
/// the fitted scale lives in `[0, 1024]`; targets outside the model's
/// reachable ratio band (e.g. a DRAM-bound shape where dequant time is
/// fully hidden) return the nearest achievable scale. A LUT tier
/// measured *faster* than shift-mask fits a scale below
/// `dequant_scale_shift`; slower fits one above.
///
/// # Panics
///
/// Panics unless both measured latencies are positive and `kind` is a
/// quantized kernel (fp16 has no dequant term to scale).
pub fn calibrate_dequant(
    dev: &DeviceSpec,
    kind: KernelKind,
    m: u64,
    n: u64,
    k: u64,
    measured_shift_s: f64,
    measured_lut_s: f64,
    base: &Calib,
) -> Calib {
    assert!(
        measured_shift_s > 0.0 && measured_lut_s > 0.0,
        "measured decoder latencies must be positive"
    );
    assert!(kind != KernelKind::Fp16, "fp16 has no dequant term to calibrate");
    let target = measured_lut_s / measured_shift_s;
    let shift_s = model_gemm_decoder(dev, kind, DecoderKind::ShiftMask, m, n, k, base).latency_s;
    let scale = fit_scale(target, &|s| {
        let c = Calib { dequant_scale_lut: s, ..*base };
        model_gemm_decoder(dev, kind, DecoderKind::Lut, m, n, k, &c).latency_s / shift_s
    });
    Calib { dequant_scale_lut: scale, ..*base }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;

    fn perf(kind: KernelKind, m: u64) -> KernelPerf {
        model_gemm(&Gpu::A100.spec(), kind, m, 8192, 8192, &Calib::default())
    }

    #[test]
    fn quantized_wins_small_batch() {
        // Memory-bound regime: 4-bit weights ~4x less traffic. AWQ keeps
        // only part of that advantage (its write-back + shuffle overheads
        // bite even at batch 1 — cf. Fig. 7's A100 panel where AWQ sits
        // well below 4x fp16); QUICK retains more.
        for m in [1, 8, 16] {
            let f = perf(KernelKind::Fp16, m);
            let q = perf(KernelKind::Quick, m);
            let a = perf(KernelKind::Awq, m);
            assert!(q.tops > 1.5 * f.tops, "m={m}: QUICK {} vs fp16 {}", q.tops, f.tops);
            assert!(a.tops > 1.3 * f.tops, "m={m}: AWQ {} vs fp16 {}", a.tops, f.tops);
            assert!(q.tops > a.tops, "m={m}: QUICK must beat AWQ");
        }
    }

    #[test]
    fn awq_degrades_at_large_batch() {
        // Paper §4.1: AWQ falls below fp16 as batch approaches 128.
        let f = perf(KernelKind::Fp16, 256);
        let a = perf(KernelKind::Awq, 256);
        assert!(a.tops < f.tops, "AWQ {} !< fp16 {}", a.tops, f.tops);
    }

    #[test]
    fn quick_speedup_over_awq_in_paper_band() {
        // Paper: 1.33–1.91x at batch 256 (any device). Allow a wide check
        // here; the per-device assertions live in the fig7 bench harness.
        let a = perf(KernelKind::Awq, 256);
        let q = perf(KernelKind::Quick, 256);
        let speedup = q.tops / a.tops;
        assert!(
            (1.2..2.2).contains(&speedup),
            "QUICK/AWQ speedup {speedup:.2} out of band"
        );
    }

    #[test]
    fn quick_has_zero_conflicts_awq_many() {
        let a = perf(KernelKind::Awq, 64);
        let q = perf(KernelKind::Quick, 64);
        let f = perf(KernelKind::Fp16, 64);
        assert!(a.conflicts > 0);
        assert_eq!(q.conflicts, 0);
        assert_eq!(f.conflicts, 0);
        assert!(a.conflict_multiplier > 1.5);
    }

    #[test]
    fn latency_monotone_in_m() {
        for kind in KernelKind::ALL {
            let mut prev = 0.0;
            for m in [1u64, 4, 16, 64, 256, 1024] {
                let p = perf(kind, m);
                assert!(
                    p.latency_s >= prev * 0.99,
                    "{:?} latency not monotone at m={m}",
                    kind
                );
                prev = p.latency_s;
            }
        }
    }

    #[test]
    fn quick_prefers_bigger_tiles_at_large_m() {
        let small = perf(KernelKind::Quick, 16);
        let large = perf(KernelKind::Quick, 256);
        assert!(large.tile.bm >= small.tile.bm);
        assert!(large.tile.bm >= 128, "tile-size optimization not engaged");
    }

    #[test]
    fn calibrate_writeback_matches_measured_ratio() {
        let dev = Gpu::A100.spec();
        let base = Calib::default();
        // A reachable target inside the model's dynamic range.
        let calib = calibrate_writeback(&dev, 256, 8192, 8192, 1.0e-3, 1.5e-3, &base);
        let a = model_gemm(&dev, KernelKind::Awq, 256, 8192, 8192, &calib);
        let q = model_gemm(&dev, KernelKind::Quick, 256, 8192, 8192, &calib);
        let ratio = a.latency_s / q.latency_s;
        assert!((ratio - 1.5).abs() < 0.03, "calibrated ratio {ratio:.3} != 1.5");
        // A larger measured gap calibrates to a larger scale.
        let bigger = calibrate_writeback(&dev, 256, 8192, 8192, 1.0e-3, 1.8e-3, &base);
        assert!(bigger.writeback_scale > calib.writeback_scale);
        // A measured gap of 1.0x sits at (or below) the write-back-free
        // floor: the calibrated scale collapses to (near) zero.
        let floor = calibrate_writeback(&dev, 256, 8192, 8192, 1.0e-3, 1.0e-3, &base);
        assert!(floor.writeback_scale < 0.05, "floor scale {}", floor.writeback_scale);
        // Non-writeback fields pass through untouched.
        assert_eq!(calib.mma_eff, base.mma_eff);
        assert_eq!(calib.swizzle_span, base.swizzle_span);
    }

    #[test]
    fn writeback_scale_moves_only_the_awq_kernel() {
        let dev = Gpu::A100.spec();
        let scaled = Calib { writeback_scale: 2.0, ..Calib::default() };
        for kind in [KernelKind::Fp16, KernelKind::Quick] {
            let a = model_gemm(&dev, kind, 64, 8192, 8192, &Calib::default());
            let b = model_gemm(&dev, kind, 64, 8192, 8192, &scaled);
            assert_eq!(a.latency_s, b.latency_s, "{kind:?} must be unaffected");
        }
        let base = model_gemm(&dev, KernelKind::Awq, 64, 8192, 8192, &Calib::default());
        let doubled = model_gemm(&dev, KernelKind::Awq, 64, 8192, 8192, &scaled);
        assert!(doubled.latency_s > base.latency_s, "write-back term must scale");
    }

    #[test]
    fn step_model_sums_the_gemm_stream() {
        use crate::model::Model;
        let dev = Gpu::A100.spec();
        let spec = Model::Mistral7B.spec();
        let calib = Calib::default();
        let step = model_step_gemms(&dev, &spec, KernelKind::Quick, 8, &calib);
        // Hand-sum must match, and the step must cost more than its
        // single largest GEMM.
        let by_hand: f64 = spec
            .gemms()
            .iter()
            .map(|g| {
                model_gemm(&dev, KernelKind::Quick, 8, g.n, g.k, &calib).latency_s
                    * g.count as f64
            })
            .sum();
        assert!((step - by_hand).abs() < 1e-12);
        let one = model_gemm(&dev, KernelKind::Quick, 8, spec.d_ff, spec.d_model, &calib);
        assert!(step > one.latency_s);
    }

    #[test]
    fn calibrate_step_matches_measured_step_ratio() {
        use crate::model::Model;
        let dev = Gpu::A100.spec();
        let spec = Model::Vicuna13B.spec();
        let base = Calib::default();
        let calib = calibrate_step_writeback(&dev, &spec, 8, 1.0e-2, 1.4e-2, &base);
        let a = model_step_gemms(&dev, &spec, KernelKind::Awq, 8, &calib);
        let q = model_step_gemms(&dev, &spec, KernelKind::Quick, 8, &calib);
        let ratio = a / q;
        assert!((ratio - 1.4).abs() < 0.03, "calibrated step ratio {ratio:.3} != 1.4");
        // Floor semantics match the single-GEMM hook.
        let floor = calibrate_step_writeback(&dev, &spec, 8, 1.0e-2, 1.0e-2, &base);
        assert!(floor.writeback_scale < 0.05);
        // Non-writeback fields pass through untouched.
        assert_eq!(calib.dram_eff, base.dram_eff);
    }

    #[test]
    fn default_calib_prices_decoders_identically() {
        let dev = Gpu::A100.spec();
        let calib = Calib::default();
        for kind in [KernelKind::Awq, KernelKind::Quick] {
            let shift = model_gemm(&dev, kind, 64, 8192, 8192, &calib);
            let lut =
                model_gemm_decoder(&dev, kind, DecoderKind::Lut, 64, 8192, 8192, &calib);
            assert_eq!(shift.latency_s, lut.latency_s, "{kind:?}: default scales are 1.0");
        }
    }

    #[test]
    fn lut_scale_moves_only_the_lut_tier() {
        let dev = Gpu::A100.spec();
        let scaled = Calib { dequant_scale_lut: 32.0, ..Calib::default() };
        let shift = model_gemm(&dev, KernelKind::Quick, 256, 8192, 8192, &scaled);
        let base = model_gemm(&dev, KernelKind::Quick, 256, 8192, 8192, &Calib::default());
        assert_eq!(shift.latency_s, base.latency_s, "shift-mask tier must be unaffected");
        let lut =
            model_gemm_decoder(&dev, KernelKind::Quick, DecoderKind::Lut, 256, 8192, 8192, &scaled);
        assert!(lut.latency_s > shift.latency_s, "scaled LUT dequant must cost more");
    }

    #[test]
    fn calibrate_dequant_matches_measured_ratio() {
        let dev = Gpu::A100.spec();
        let base = Calib::default();
        // LUT tier measured 30% slower than shift-mask on this shape.
        let calib =
            calibrate_dequant(&dev, KernelKind::Quick, 256, 8192, 8192, 1.0e-3, 1.3e-3, &base);
        let shift = model_gemm(&dev, KernelKind::Quick, 256, 8192, 8192, &calib);
        let lut =
            model_gemm_decoder(&dev, KernelKind::Quick, DecoderKind::Lut, 256, 8192, 8192, &calib);
        let ratio = lut.latency_s / shift.latency_s;
        assert!((ratio - 1.3).abs() < 0.03, "calibrated ratio {ratio:.3} != 1.3");
        assert!(calib.dequant_scale_lut > calib.dequant_scale_shift);
        // A LUT tier measured well below the dequant-free floor clamps
        // the fitted scale to (near) zero.
        let floor =
            calibrate_dequant(&dev, KernelKind::Quick, 256, 8192, 8192, 1.0e-3, 0.5e-3, &base);
        assert!(floor.dequant_scale_lut < 0.05, "floor scale {}", floor.dequant_scale_lut);
        // Non-dequant fields pass through untouched.
        assert_eq!(calib.writeback_scale, base.writeback_scale);
        assert_eq!(calib.mma_eff, base.mma_eff);
    }

    #[test]
    fn all_devices_produce_sane_numbers() {
        for g in Gpu::ALL {
            for kind in KernelKind::ALL {
                let p = model_gemm(&g.spec(), kind, 128, 8192, 8192, &Calib::default());
                assert!(p.latency_s > 0.0 && p.latency_s < 1.0);
                assert!(p.tops > 0.1 && p.tops < g.spec().tc_tflops);
            }
        }
    }
}
