//! GPU kernel execution model — the simulation substrate that stands in for
//! the paper's NVIDIA testbed (DESIGN.md §2, substitution table).
//!
//! The paper's evaluation is entirely microarchitectural: shared-memory bank
//! conflicts (Fig. 3), mixed-precision GEMM TOPS across batch sizes and
//! devices (Fig. 7), end-to-end decode throughput (Fig. 8), and
//! vLLM-integrated serving throughput (Table 1). None of those quantities
//! require silicon to reproduce *in shape*: they are deterministic functions
//! of (a) the warp-level access patterns the kernel issues, (b) the tile
//! schedule, and (c) device parameters (SMs, bandwidths, peak tensor-core
//! throughput). This module implements exactly those three ingredients:
//!
//! * [`bank`] — the 32-bank shared-memory conflict counter (NVIDIA's
//!   documented rule: one transaction per distinct 32-bit word per bank per
//!   phase; conflict degree = serialized replays).
//! * [`trace`] — warp access-pattern generators for `ldmatrix` loads, the
//!   baseline kernel's dequant write-back stores, and QUICK's direct
//!   DRAM→register loads.
//! * [`gpu`] — device spec table (RTX 4090, RTX A6000, L40, A100-80G) from
//!   public datasheets.
//! * [`occupancy`] — active-warps-per-SM calculator (shared-memory and
//!   register limits), reproducing §3.3's smem→register pressure shift.
//! * [`kernel_model`] — tile-level latency model for the three kernels
//!   (fp16 / AWQ baseline / QUICK) combining compute, DRAM, and
//!   conflict-serialized shared-memory phases into TOPS.
//! * [`e2e`] — per-decode-step latency and tokens/s for a full LLM
//!   (Fig. 8), including the KV-cache/weights OOM predictor.
//! * [`collective`] — ring all-reduce / all-gather cost model over the
//!   per-GPU link table, and [`collective::tp_step_latency`]: the
//!   tensor-parallel image of the mixed batched step (per-rank GEMMs at
//!   `1/tp` volume + two all-reduces per layer).
//!
//! Calibration constants (pipeline efficiencies) are centralized in
//! [`kernel_model::Calib`] and documented in DESIGN.md §Perf — everything
//! else is first-principles.

pub mod ablation;
pub mod bank;
pub mod collective;
pub mod e2e;
pub mod gpu;
pub mod kernel_model;
pub mod occupancy;
pub mod report;
pub mod trace;

pub use bank::BankCounter;
pub use collective::{
    ring_all_gather_s, ring_all_reduce_s, tp_step_comm_s, tp_step_latency, TpStepBreakdown,
};
pub use e2e::{
    calibrate_kv_attn, decode_step_latency, kv_attn_term, max_batch_before_oom,
    mixed_step_latency, tokens_per_second, DecodeBreakdown, MixedStepBreakdown,
};
pub use gpu::{DeviceSpec, Gpu};
pub use kernel_model::{
    calibrate_dequant, calibrate_step_writeback, calibrate_writeback, model_gemm_decoder,
    model_step_gemms, Calib, KernelKind, KernelPerf, TileConfig,
};
