//! Active-warps-per-SM occupancy calculator (paper §3.3).
//!
//! The baseline mixed-precision kernel stages both activations *and*
//! dequantized weights in shared memory, so smem size caps the number of
//! resident blocks. QUICK keeps weights in registers: smem pressure drops,
//! register pressure rises, and the larger activation tile trades DRAM
//! re-reads for occupancy — the effect this module quantifies.

use super::gpu::DeviceSpec;

/// Resource usage of one thread block of a kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockResources {
    /// Warps per block.
    pub warps: u32,
    /// Shared memory per block, bytes.
    pub smem_bytes: u32,
    /// Registers per thread.
    pub regs_per_thread: u32,
}

/// Occupancy result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    pub blocks_per_sm: u32,
    pub active_warps: u32,
    /// active_warps / max_warps, in [0, 1].
    pub fraction: f64,
    /// Which resource bound first: "smem", "regs", or "warps".
    pub limiter: &'static str,
}

/// Compute theoretical occupancy for `block` on `dev`.
pub fn occupancy(dev: &DeviceSpec, block: &BlockResources) -> Occupancy {
    assert!(block.warps > 0);
    let by_warps = dev.max_warps_per_sm / block.warps;
    let by_smem = if block.smem_bytes == 0 {
        u32::MAX
    } else {
        (dev.smem_per_sm_kib * 1024) / block.smem_bytes
    };
    let regs_per_block = block.regs_per_thread * block.warps * 32;
    let by_regs = if regs_per_block == 0 {
        u32::MAX
    } else {
        dev.regs_per_sm / regs_per_block
    };

    let blocks = by_warps.min(by_smem).min(by_regs);
    // Tie-break order: warps (the benign limit) > regs > smem.
    let limiter = if blocks == by_warps {
        "warps"
    } else if blocks == by_regs {
        "regs"
    } else {
        "smem"
    };
    let active = (blocks * block.warps).min(dev.max_warps_per_sm);
    Occupancy {
        blocks_per_sm: blocks,
        active_warps: active,
        fraction: active as f64 / dev.max_warps_per_sm as f64,
        limiter,
    }
}

/// Latency-hiding efficiency as a function of occupancy: GEMM kernels
/// saturate the pipes well below full occupancy (4+ active warps per SM
/// sub-partition); model as a smooth ramp that reaches ~0.95 at 50%.
pub fn latency_hiding(frac: f64) -> f64 {
    let x = frac.clamp(0.0, 1.0);
    (1.0 - (-x * 6.0).exp()).min(0.95) / 0.95
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;

    fn a100() -> DeviceSpec {
        Gpu::A100.spec()
    }

    #[test]
    fn smem_limited_baseline_block() {
        // Baseline kernel: 4 warps, big smem (activations + weights).
        let o = occupancy(&a100(), &BlockResources {
            warps: 4,
            smem_bytes: 48 * 1024,
            regs_per_thread: 96,
        });
        assert_eq!(o.limiter, "smem");
        assert_eq!(o.blocks_per_sm, 3);
    }

    #[test]
    fn quick_block_shifts_pressure_to_regs() {
        // QUICK: half the smem (no weight staging), more registers.
        let base = occupancy(&a100(), &BlockResources {
            warps: 4,
            smem_bytes: 48 * 1024,
            regs_per_thread: 96,
        });
        let quick = occupancy(&a100(), &BlockResources {
            warps: 4,
            smem_bytes: 20 * 1024,
            regs_per_thread: 160,
        });
        assert_eq!(quick.limiter, "regs");
        // §3.3: "similar theoretical multiprocessor occupancy"
        assert!((quick.active_warps as i64 - base.active_warps as i64).abs() <= 8);
    }

    #[test]
    fn warp_limited_tiny_block() {
        let o = occupancy(&a100(), &BlockResources {
            warps: 8,
            smem_bytes: 1024,
            regs_per_thread: 32,
        });
        assert_eq!(o.limiter, "warps");
        assert_eq!(o.active_warps, a100().max_warps_per_sm);
    }

    #[test]
    fn latency_hiding_monotone() {
        let mut prev = 0.0;
        for i in 0..=10 {
            let v = latency_hiding(i as f64 / 10.0);
            assert!(v >= prev);
            prev = v;
        }
        assert!(latency_hiding(0.5) > 0.9);
        assert!(latency_hiding(1.0) <= 1.0);
    }
}
