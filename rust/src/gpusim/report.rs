//! Nsight-Compute-style kernel profile report (the tooling behind Fig. 3's
//! measurement methodology): for one modeled kernel launch, the achieved
//! occupancy, per-resource limiter, memory throughputs, conflict counters,
//! and the time breakdown the latency model composed.

use std::fmt::Write as _;

use super::gpu::DeviceSpec;
use super::kernel_model::{model_gemm, Calib, KernelKind, KernelPerf};

/// A profiling report for one kernel launch.
#[derive(Debug, Clone)]
pub struct KernelReport {
    pub perf: KernelPerf,
    pub device: &'static str,
    /// DRAM throughput as a fraction of peak.
    pub dram_util: f64,
    /// Effective TC utilization (true flops / peak over the latency).
    pub mma_util: f64,
    /// Shared-memory write-back throughput demand, bytes/s (0 for QUICK).
    pub smem_wb_bw: f64,
}

/// Profile one GEMM launch.
pub fn profile(
    dev: &DeviceSpec,
    kind: KernelKind,
    m: u64,
    n: u64,
    k: u64,
    calib: &Calib,
) -> KernelReport {
    let perf = model_gemm(dev, kind, m, n, k, calib);
    let true_flops = 2.0 * (m * n * k) as f64;
    KernelReport {
        device: dev.name,
        dram_util: perf.dram_bytes / perf.latency_s / dev.dram_bw(),
        mma_util: true_flops / perf.latency_s / (dev.tc_tflops * 1e12),
        smem_wb_bw: perf.smem_writeback_bytes * perf.conflict_multiplier / perf.latency_s,
        perf,
    }
}

impl KernelReport {
    /// Render the ncu-like text block.
    pub fn render(&self) -> String {
        let p = &self.perf;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "Kernel: {} GEMM  {}x{}x{} (MxNxK) on {}",
            p.kind.label(),
            p.m,
            p.n,
            p.k,
            self.device
        );
        let _ = writeln!(
            s,
            "  Duration                {:>12.2} us",
            p.latency_s * 1e6
        );
        let _ = writeln!(s, "  Effective throughput    {:>12.2} TOPS", p.tops);
        let _ = writeln!(
            s,
            "  Tile (BMxBNxBK)         {:>12}",
            format!("{}x{}x{}", p.tile.bm, p.tile.bn, p.tile.bk)
        );
        let _ = writeln!(
            s,
            "  Achieved occupancy      {:>11.1}%",
            p.occupancy_fraction * 100.0
        );
        let _ = writeln!(
            s,
            "  DRAM throughput         {:>11.1}%  ({:.1} GB moved)",
            self.dram_util * 100.0,
            p.dram_bytes / 1e9
        );
        let _ = writeln!(
            s,
            "  Tensor-core utilization {:>11.1}%",
            self.mma_util * 100.0
        );
        let _ = writeln!(
            s,
            "  Shared st.bank_conflict {:>12}",
            p.conflicts
        );
        let _ = writeln!(
            s,
            "  Write-back replay mult. {:>12.2}x  ({:.1} MB through smem)",
            p.conflict_multiplier,
            p.smem_writeback_bytes / 1e6
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::gpu::Gpu;

    #[test]
    fn utilizations_are_fractions() {
        for kind in KernelKind::ALL {
            let r = profile(&Gpu::A100.spec(), kind, 128, 8192, 8192, &Calib::default());
            assert!((0.0..=1.0).contains(&r.dram_util), "{:?} dram {}", kind, r.dram_util);
            assert!((0.0..=1.0).contains(&r.mma_util), "{:?} mma {}", kind, r.mma_util);
        }
    }

    #[test]
    fn report_flags_the_paper_bottlenecks() {
        // Large batch: AWQ has write-back pressure, QUICK none; fp16's
        // tensor-core utilization beats AWQ's.
        let awq = profile(&Gpu::Rtx4090.spec(), KernelKind::Awq, 256, 8192, 8192, &Calib::default());
        let quick = profile(&Gpu::Rtx4090.spec(), KernelKind::Quick, 256, 8192, 8192, &Calib::default());
        let fp16 = profile(&Gpu::Rtx4090.spec(), KernelKind::Fp16, 256, 8192, 8192, &Calib::default());
        assert!(awq.smem_wb_bw > 0.0);
        assert_eq!(quick.smem_wb_bw, 0.0);
        assert!(fp16.mma_util > awq.mma_util);
        assert!(quick.mma_util > awq.mma_util);
    }

    #[test]
    fn render_contains_key_rows() {
        let r = profile(&Gpu::L40.spec(), KernelKind::Awq, 64, 8192, 8192, &Calib::default());
        let text = r.render();
        for needle in ["Duration", "occupancy", "bank_conflict", "replay"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }
}
