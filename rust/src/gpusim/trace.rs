//! Warp-level shared-memory access-pattern generators.
//!
//! These produce the byte-address traces that [`super::bank::BankCounter`]
//! scores. Each generator models one phase of one warp's work on one
//! GEMM tile, derived from the actual data layouts in `crate::quant`:
//!
//! * [`ldmatrix_load`] — `ldmatrix.sync.aligned.m8n8.x4` reads of a fp16
//!   tile resident in shared memory (both kernels use this for
//!   *activations*; only the baseline uses it for weights).
//! * [`awq_writeback`] — the baseline kernel's dequant write-back: each lane
//!   holds 8 dequantized fp16 values from one packed u32 and stores them to
//!   the tile's logical positions. Because the AWQ nibble order interleaves
//!   columns (FT_ORDER) *and* dequantization expands data 4x, lanes scatter
//!   2-byte values at stride 2 across the row — the bank-conflicted pattern
//!   of paper Figs. 2–3.
//! * [`quick_direct_load`] — QUICK's replacement: weights go DRAM→register,
//!   so the shared-memory trace is *empty by construction*.

use super::bank::BankCounter;
use crate::quant::FT_ORDER;

/// Bytes per fp16 element.
const F16: u64 = 2;

/// One `ldmatrix.m8n8.x4` issued by a full warp: four 8x8 fp16 matrices.
/// Lane `l` supplies the base address of row `l % 8` of matrix `l / 8`
/// and receives 16 bytes (one matrix row). `row_stride_elems` is the
/// shared-memory row pitch of the tile in elements.
///
/// Returns the per-lane byte addresses (32 lanes, 16 B each).
pub fn ldmatrix_load(row_stride_elems: u64, base: u64) -> Vec<u64> {
    (0..32)
        .map(|l| {
            let (mat, row) = (l / 8, l % 8);
            // Matrices tile an 16x16 region: mats 0,1 stack along rows,
            // 2,3 the adjacent 8-column block (x4 layout).
            let r = (mat % 2) * 8 + row;
            let c = (mat / 2) * 8;
            base + r * row_stride_elems * F16 + c * F16
        })
        .collect()
}

/// The baseline kernel's dequant write-back for one warp iteration.
///
/// Each lane dequantizes one packed u32 (8 int4 codes → 8 fp16) and stores
/// the halves to their *logical* columns inside the smem tile. With the
/// stock AWQ layout, nibble slot `p` holds logical column `FT_ORDER[p]`, so
/// the eight 2-byte stores of a lane land at byte offsets
/// `FT_ORDER[p] * 2` within the lane's 16-byte span: even/odd column pairs
/// interleave and consecutive lanes' spans abut. The result is eight
/// strided 2-byte store instructions per warp (one per nibble slot) instead
/// of one coalesced 16-byte store — multiplied 4x versus the packed data
/// volume by the dequant expansion (paper §2.3).
///
/// `lane_cols` = number of u32 words each lane processes per row chunk;
/// `row_stride_elems` = smem row pitch. Appends every store phase to `c`
/// and returns the number of warp store instructions issued.
pub fn awq_writeback(
    c: &mut BankCounter,
    row_stride_elems: u64,
    rows_per_warp: u64,
) -> u64 {
    let mut instrs = 0;
    // One warp handles `rows_per_warp` tile rows; per row, 32 lanes cover
    // 32 words = 256 fp16 columns. For each nibble slot p, all 32 lanes
    // store lane-strided 2-byte values simultaneously.
    for row in 0..rows_per_warp {
        for p in 0..8u64 {
            let col_in_word = FT_ORDER[p as usize] as u64;
            let addrs: Vec<u64> = (0..32)
                .map(|lane| {
                    let word_base = lane * 8; // 8 fp16 per word span
                    (row * row_stride_elems + word_base + col_in_word) * F16
                })
                .collect();
            c.access(&addrs, 2);
            instrs += 1;
        }
    }
    instrs
}

/// QUICK's weight path: direct DRAM→register loads, no shared memory at
/// all. Kept as an explicit (empty) generator so Fig. 3's "QUICK
/// write-back = 0" row comes from the same machinery.
pub fn quick_direct_load(_c: &mut BankCounter) -> u64 {
    0 // zero shared-memory instructions by construction
}

/// Activation staging (both kernels): fp16 tile rows copied gmem→smem with
/// 16-byte vectorized stores, unit stride — conflict-free when the pitch is
/// a multiple of 32 banks. One instruction per 32 lanes x 16 B = 512 B row
/// chunk.
pub fn activation_store(c: &mut BankCounter, row_stride_elems: u64, rows: u64) -> u64 {
    let mut instrs = 0;
    let row_bytes = row_stride_elems * F16;
    for row in 0..rows {
        let mut off = 0;
        while off < row_bytes {
            let addrs: Vec<u64> =
                (0..32).map(|l| row * row_bytes + off + l * 16).collect();
            c.access(&addrs, 16);
            off += 32 * 16;
            instrs += 1;
        }
    }
    instrs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ldmatrix_pitch_multiple_of_banks_conflicts() {
        // Naive pitch 64 fp16 = 128 B: rows map to the same banks ->
        // conflicts; XOR-swizzled/padded pitch 72 avoids them.
        let mut bad = BankCounter::new();
        bad.access(&ldmatrix_load(64, 0), 16);
        let mut good = BankCounter::new();
        good.access(&ldmatrix_load(72, 0), 16);
        assert!(bad.conflicts > 0, "expected conflicts at pitch 64");
        assert_eq!(good.conflicts, 0, "padded pitch must be conflict-free");
    }

    #[test]
    fn awq_writeback_has_conflicts() {
        let mut c = BankCounter::new();
        let n = awq_writeback(&mut c, 256, 4);
        assert_eq!(n, 32); // 4 rows x 8 nibble-slot stores
        assert!(c.conflicts > 0, "dequant write-back must conflict");
        assert!(c.multiplier() > 1.5, "got {}", c.multiplier());
    }

    #[test]
    fn quick_has_zero_smem_traffic() {
        let mut c = BankCounter::new();
        assert_eq!(quick_direct_load(&mut c), 0);
        assert_eq!(c.phases, 0);
        assert_eq!(c.conflicts, 0);
    }

    #[test]
    fn activation_store_conflict_free() {
        let mut c = BankCounter::new();
        activation_store(&mut c, 256, 8);
        assert_eq!(c.conflicts, 0);
    }
}
