//! Fused dequant-attention over block-quantized KV — the attention twin
//! of `gemm_quick_fused`.
//!
//! [`attn_quant_fused`] streams one head's packed K/V
//! ([`crate::quant::QuantizedKv`]) in KV-tile order and, per tile,
//! decodes the rows in-register (scalar or AVX2 via
//! [`crate::quant::select_kv_decoder`]), computes the tile's `QK^T`
//! scores, folds them into a FlashAttention-style online softmax
//! (running max `m`, exp-sum `l`, rescale factor `alpha = exp(m_prev -
//! m_next)`), and accumulates the tile's `A·V` contribution — one
//! I/O-aware pass, no materialized `seq`-length score row beyond the
//! tile, no dequantized KV ever written to memory. Query rows are
//! striped across the shared [`super::WorkerPool`], the same threading
//! substrate the GEMM paths use.
//!
//! [`naive_attention`] is the f64-accumulating scalar reference (full
//! softmax, dense f32 K/V) every fused variant is differential-tested
//! against at the documented `1e-4` [`super::max_rel_err`] gate — pass
//! it the [`crate::quant::dequantize_kv`] of the same packed KV and the
//! quantization error cancels, leaving only kernel arithmetic under
//! test. [`attn_dense_tiled`] runs the identical tiled online-softmax
//! loop over dense f32 rows: the "f16 KV" baseline of the bench sweep
//! (`bench kernels --attention`), isolating the in-register decode cost
//! from the online-softmax restructuring.

use anyhow::{ensure, Result};

use crate::quant::{select_kv_decoder, KvDecodeFn, QuantizedKv};

use super::pool::WorkerPool;

/// Tuning knobs for the tiled attention kernels (the attention analogue
/// of [`super::Blocking`]).
#[derive(Debug, Clone, Copy)]
pub struct AttnConfig {
    /// KV rows per online-softmax tile (the panel one rescale covers).
    pub seq_tile: usize,
    /// Worker threads; `0` = auto (1 for small problems, else cores,
    /// capped at the query-row count).
    pub threads: usize,
    /// Use the SIMD KV decoders when the CPU supports them.
    pub simd: bool,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig { seq_tile: 64, threads: 0, simd: true }
    }
}

impl AttnConfig {
    /// Resolve the worker count for an `(m, seq, d)` problem: explicit
    /// counts are capped at `m` (one query row is the unit of work);
    /// auto stays single-threaded until the flop count outgrows
    /// dispatch overhead (same break-even structure as
    /// [`super::Blocking::resolve_threads`]).
    pub fn resolve_threads(&self, m: usize, seq: usize, d: usize) -> usize {
        let cap = m.max(1);
        if self.threads > 0 {
            return self.threads.min(cap);
        }
        let flops = 4.0 * m as f64 * seq as f64 * d as f64;
        if flops < (1u64 << 22) as f64 {
            return 1;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(cap).max(1)
    }
}

/// Reference attention: `out = softmax(q K^T * scale) V` with f64
/// scores, f64 full softmax, and f64 `A·V` accumulation — essentially
/// exact at these sizes, keeping the reference's own rounding out of
/// the differential gate (same rationale as [`super::NaiveBackend`]).
///
/// `q` is `(m, d)` row-major, `k`/`v` are `(seq, d)` row-major, `out`
/// is `(m, d)`.
///
/// # Panics
///
/// Panics on buffer-length mismatches or `seq == 0`.
pub fn naive_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    m: usize,
    seq: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
) {
    assert!(seq > 0, "empty KV");
    assert_eq!(q.len(), m * d, "q buffer size");
    assert_eq!(k.len(), seq * d, "k buffer size");
    assert_eq!(v.len(), seq * d, "v buffer size");
    assert_eq!(out.len(), m * d, "out buffer size");
    let mut scores = vec![0f64; seq];
    let mut acc = vec![0f64; d];
    for i in 0..m {
        let qrow = &q[i * d..(i + 1) * d];
        let mut smax = f64::NEG_INFINITY;
        for (j, sc) in scores.iter_mut().enumerate() {
            let krow = &k[j * d..(j + 1) * d];
            let mut dot = 0f64;
            for (&qv, &kv) in qrow.iter().zip(krow) {
                dot += qv as f64 * kv as f64;
            }
            *sc = dot * scale as f64;
            smax = smax.max(*sc);
        }
        let mut l = 0f64;
        for sc in scores.iter_mut() {
            *sc = (*sc - smax).exp();
            l += *sc;
        }
        acc.fill(0.0);
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &v[j * d..(j + 1) * d];
            for (a, &vv) in acc.iter_mut().zip(vrow) {
                *a += p * vv as f64;
            }
        }
        let orow = &mut out[i * d..(i + 1) * d];
        for (o, &a) in orow.iter_mut().zip(&acc) {
            *o = (a / l) as f32;
        }
    }
}

/// A KV operand the tiled kernel can stream row-by-row: packed quantized
/// rows decoded through a selected [`KvDecodeFn`], or dense f32 rows
/// (the f16-baseline path, a plain copy into the tile scratch).
enum KvRef<'a> {
    Quant(&'a QuantizedKv, KvDecodeFn),
    Dense(&'a [f32]),
}

impl KvRef<'_> {
    /// Materialize row `j` into `row` (`d` floats).
    #[inline]
    fn decode_row(&self, j: usize, row: &mut [f32]) {
        match *self {
            KvRef::Quant(kv, decode) => {
                let (s, z) = kv.token_meta(j);
                decode(kv.token_words(j), s, z, kv.group, row);
            }
            KvRef::Dense(data) => {
                let d = row.len();
                row.copy_from_slice(&data[j * d..(j + 1) * d]);
            }
        }
    }
}

/// Fused attention over quantized KV: per KV tile, decode K rows
/// in-register, compute `QK^T` scores, update the online softmax
/// (`m`/`l`/accumulator rescaled by `alpha = exp(m_prev - m_next)`),
/// decode V rows, and accumulate `A·V` — then normalize once at the
/// end. K and V may use different bit widths; they must agree on
/// `seq`/`d`. Differentially gated against [`naive_attention`] at
/// `1e-4` max relative error ([`super::max_rel_err`]) in both debug and
/// release.
///
/// `q` is `(m, d)` row-major, `out` is `(m, d)`.
///
/// # Errors
///
/// Errors on shape mismatches between `q`, `kq`, `vq`, and `out`, on
/// `seq == 0`, and on a zero `seq_tile`.
pub fn attn_quant_fused(
    q: &[f32],
    kq: &QuantizedKv,
    vq: &QuantizedKv,
    m: usize,
    scale: f32,
    cfg: &AttnConfig,
    out: &mut [f32],
) -> Result<()> {
    ensure!(kq.seq == vq.seq && kq.d == vq.d, "K/V shape mismatch");
    let kref = KvRef::Quant(kq, select_kv_decoder(kq.bits, cfg.simd));
    let vref = KvRef::Quant(vq, select_kv_decoder(vq.bits, cfg.simd));
    attn_tiled(q, &kref, &vref, m, kq.seq, kq.d, scale, cfg, out)
}

/// The tiled online-softmax loop over *dense* f32 KV — identical
/// arithmetic to [`attn_quant_fused`] minus the in-register decode; the
/// unquantized ("f16 KV") baseline of the attention bench sweep.
///
/// # Errors
///
/// Errors on shape mismatches, `seq == 0`, or a zero `seq_tile`.
#[allow(clippy::too_many_arguments)]
pub fn attn_dense_tiled(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    m: usize,
    seq: usize,
    d: usize,
    scale: f32,
    cfg: &AttnConfig,
    out: &mut [f32],
) -> Result<()> {
    ensure!(k.len() == seq * d && v.len() == seq * d, "K/V buffer size");
    attn_tiled(q, &KvRef::Dense(k), &KvRef::Dense(v), m, seq, d, scale, cfg, out)
}

/// Shared tiled kernel: query rows striped over the worker pool, one
/// online-softmax state per row, KV streamed tile-by-tile through the
/// operands' row decoders.
#[allow(clippy::too_many_arguments)]
fn attn_tiled(
    q: &[f32],
    k: &KvRef<'_>,
    v: &KvRef<'_>,
    m: usize,
    seq: usize,
    d: usize,
    scale: f32,
    cfg: &AttnConfig,
    out: &mut [f32],
) -> Result<()> {
    ensure!(seq > 0, "empty KV");
    ensure!(cfg.seq_tile > 0, "seq_tile must be positive");
    ensure!(q.len() == m * d, "q buffer size: {} != {m} x {d}", q.len());
    ensure!(out.len() == m * d, "out buffer size: {} != {m} x {d}", out.len());
    if m == 0 {
        return Ok(());
    }
    let threads = cfg.resolve_threads(m, seq, d);
    let tile = cfg.seq_tile;

    // Disjoint-row output writes from pool workers (each query row is
    // owned by exactly one task below).
    struct OutPtr(*mut f32);
    unsafe impl Sync for OutPtr {}
    let out_ptr = OutPtr(out.as_mut_ptr());

    let body = move |task: usize, _slot: usize| {
        // One scratch set per task (tasks == threads, rows striped), so
        // a call allocates O(threads) tile buffers, not O(m).
        let mut krow = vec![0f32; d];
        let mut vrow = vec![0f32; d];
        let mut scores = vec![0f32; tile];
        let mut acc = vec![0f32; d];
        for i in (task..m).step_by(threads) {
            let qrow = &q[i * d..(i + 1) * d];
            let mut m_run = f32::NEG_INFINITY;
            let mut l = 0f32;
            acc.fill(0.0);
            let mut t0 = 0;
            while t0 < seq {
                let t1 = (t0 + tile).min(seq);
                // QK^T for the tile, K decoded in-register row by row.
                let mut m_tile = f32::NEG_INFINITY;
                for j in t0..t1 {
                    k.decode_row(j, &mut krow);
                    let mut dot = 0f32;
                    for (&qv, &kv) in qrow.iter().zip(&krow) {
                        dot += qv * kv;
                    }
                    let s = dot * scale;
                    scores[j - t0] = s;
                    m_tile = m_tile.max(s);
                }
                // Online-softmax fold: rescale state to the new max.
                let m_next = m_run.max(m_tile);
                let alpha = (m_run - m_next).exp(); // 0 on the first tile
                l *= alpha;
                if alpha != 1.0 {
                    for a in acc.iter_mut() {
                        *a *= alpha;
                    }
                }
                // A·V for the tile, V decoded in-register row by row.
                for j in t0..t1 {
                    let p = (scores[j - t0] - m_next).exp();
                    l += p;
                    v.decode_row(j, &mut vrow);
                    for (a, &vv) in acc.iter_mut().zip(&vrow) {
                        *a += p * vv;
                    }
                }
                m_run = m_next;
                t0 = t1;
            }
            // SAFETY: rows are striped `task, task+threads, ...` — no two
            // tasks touch the same output row; the slice outlives run().
            let orow = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(i * d), d)
            };
            for (o, &a) in orow.iter_mut().zip(&acc) {
                *o = a / l;
            }
        }
    };
    WorkerPool::global().run(threads, threads, &body);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::max_rel_err;
    use crate::quant::{dequantize_kv, quantize_kv};
    use crate::util::Rng;

    fn rand_buf(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(lo, hi) as f32).collect()
    }

    #[test]
    fn naive_softmax_rows_are_convex_combinations() {
        // With all-equal V rows, attention output equals that row exactly
        // regardless of the scores.
        let (m, seq, d) = (3, 17, 16);
        let mut rng = Rng::seed_from_u64(3);
        let q = rand_buf(&mut rng, m * d, -1.0, 1.0);
        let k = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        let vrow = rand_buf(&mut rng, d, -1.0, 1.0);
        let v: Vec<f32> = (0..seq).flat_map(|_| vrow.iter().copied()).collect();
        let mut out = vec![0f32; m * d];
        naive_attention(&q, &k, &v, m, seq, d, 0.125, &mut out);
        for i in 0..m {
            assert!(max_rel_err(&out[i * d..(i + 1) * d], &vrow) <= 1e-6);
        }
    }

    #[test]
    fn fused_matches_naive_on_dequantized_kv() {
        let mut rng = Rng::seed_from_u64(7);
        for &bits in &[4u32, 8] {
            let (m, seq, d, group) = (5, 83, 64, 32);
            let q = rand_buf(&mut rng, m * d, -1.0, 1.0);
            let k = rand_buf(&mut rng, seq * d, -1.0, 1.0);
            let v = rand_buf(&mut rng, seq * d, -1.0, 1.0);
            let kq = quantize_kv(&k, seq, d, group, bits);
            let vq = quantize_kv(&v, seq, d, group, bits);
            let scale = 1.0 / (d as f32).sqrt();
            let mut want = vec![0f32; m * d];
            naive_attention(
                &q,
                &dequantize_kv(&kq),
                &dequantize_kv(&vq),
                m,
                seq,
                d,
                scale,
                &mut want,
            );
            for cfg in [
                AttnConfig::default(),
                AttnConfig { seq_tile: 16, threads: 1, simd: false },
                AttnConfig { seq_tile: 7, threads: 3, simd: true },
            ] {
                let mut got = vec![0f32; m * d];
                attn_quant_fused(&q, &kq, &vq, m, scale, &cfg, &mut got).unwrap();
                let err = max_rel_err(&got, &want);
                assert!(err <= 1e-4, "bits={bits} cfg={cfg:?}: {err}");
            }
        }
    }

    #[test]
    fn dense_tiled_matches_naive() {
        let (m, seq, d) = (4, 130, 32);
        let mut rng = Rng::seed_from_u64(9);
        let q = rand_buf(&mut rng, m * d, -1.0, 1.0);
        let k = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        let v = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut want = vec![0f32; m * d];
        naive_attention(&q, &k, &v, m, seq, d, scale, &mut want);
        let mut got = vec![0f32; m * d];
        let cfg = AttnConfig { seq_tile: 33, ..Default::default() };
        attn_dense_tiled(&q, &k, &v, m, seq, d, scale, &cfg, &mut got).unwrap();
        assert!(max_rel_err(&got, &want) <= 1e-4);
    }

    #[test]
    fn mixed_kv_bits_and_shape_errors() {
        let mut rng = Rng::seed_from_u64(13);
        let (m, seq, d, group) = (2, 21, 32, 32);
        let q = rand_buf(&mut rng, m * d, -1.0, 1.0);
        let k = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        let v = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        // 8-bit K with 4-bit V is a legal (and useful) combination.
        let kq = quantize_kv(&k, seq, d, group, 8);
        let vq = quantize_kv(&v, seq, d, group, 4);
        let mut out = vec![0f32; m * d];
        let scale = 1.0 / (d as f32).sqrt();
        attn_quant_fused(&q, &kq, &vq, m, scale, &AttnConfig::default(), &mut out).unwrap();
        let mut want = vec![0f32; m * d];
        naive_attention(&q, &dequantize_kv(&kq), &dequantize_kv(&vq), m, seq, d, scale, &mut want);
        assert!(max_rel_err(&out, &want) <= 1e-4);
        // Mismatched seq rejected.
        let short = quantize_kv(&v[..(seq - 1) * d], seq - 1, d, group, 4);
        assert!(attn_quant_fused(&q, &kq, &short, m, scale, &AttnConfig::default(), &mut out)
            .is_err());
        // Wrong out length rejected.
        let mut bad = vec![0f32; m * d - 1];
        assert!(attn_quant_fused(&q, &kq, &vq, m, scale, &AttnConfig::default(), &mut bad)
            .is_err());
    }

    #[test]
    fn long_sequences_stay_stable_under_large_scores() {
        // Large scale pushes scores far apart: the online rescale must
        // not overflow/underflow where a naive unshifted softmax would.
        let (m, seq, d, group) = (2, 257, 32, 32);
        let mut rng = Rng::seed_from_u64(17);
        let q = rand_buf(&mut rng, m * d, -3.0, 3.0);
        let k = rand_buf(&mut rng, seq * d, -3.0, 3.0);
        let v = rand_buf(&mut rng, seq * d, -1.0, 1.0);
        let kq = quantize_kv(&k, seq, d, group, 8);
        let vq = quantize_kv(&v, seq, d, group, 8);
        let mut want = vec![0f32; m * d];
        naive_attention(&q, &dequantize_kv(&kq), &dequantize_kv(&vq), m, seq, d, 4.0, &mut want);
        let mut got = vec![0f32; m * d];
        attn_quant_fused(&q, &kq, &vq, m, 4.0, &AttnConfig::default(), &mut got).unwrap();
        assert!(got.iter().all(|x| x.is_finite()));
        assert!(max_rel_err(&got, &want) <= 1e-4);
    }
}
