//! Cache-blocking parameters shared by the fused and write-back GEMM
//! paths.
//!
//! Both backends run the *same* loop nest — M-blocks of `mc` rows,
//! K-blocks of `kc` rows (16-aligned so every block is whole
//! `mma.m16n8k16` K-tiles), word-column panels — and the same `4 x 8`
//! register microkernel, so the measured fused-vs-write-back gap isolates
//! the scratch round-trip rather than a tuning difference.

use anyhow::Result;

use crate::quant::{MMA_K, PACK_FACTOR};

/// Cache-blocking configuration for the native kernel backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    /// Activation rows per M-block. Weight decode is amortized across the
    /// whole block (the paper's per-threadblock dequant multiplicity:
    /// every M-block pass re-decodes its K x N strip).
    pub mc: usize,
    /// Reduction rows per K-block; must be a positive multiple of 16
    /// (whole interleave K-tiles).
    pub kc: usize,
    /// Word-columns (8 logical columns each) per N-panel. Sizes the
    /// write-back path's scratch tile: `kc * nc_words * 8` f32 — the CPU
    /// stand-in for the baseline kernel's shared-memory staging buffer.
    pub nc_words: usize,
    /// Worker threads; `0` = auto (one per core for large problems,
    /// single-threaded when the GEMM is too small to amortize spawning).
    pub threads: usize,
}

impl Default for Blocking {
    fn default() -> Self {
        // mc 64 x kc 256 keeps the x strip (~64 KiB) L2-resident; nc 16
        // words = 128 columns gives the write-back path a 128 KiB scratch
        // tile, the same order as the smem staging the AWQ kernel pays.
        Blocking { mc: 64, kc: 256, nc_words: 16, threads: 0 }
    }
}

impl Blocking {
    /// Validate this blocking against a weight shape `(k, n)`.
    pub fn validate(&self, k: usize, n: usize) -> Result<()> {
        anyhow::ensure!(self.mc > 0, "mc must be > 0");
        anyhow::ensure!(
            self.kc > 0 && self.kc % MMA_K == 0,
            "kc={} must be a positive multiple of {MMA_K} (interleave K-tile)",
            self.kc
        );
        anyhow::ensure!(self.nc_words > 0, "nc_words must be > 0");
        anyhow::ensure!(
            k > 0 && k % MMA_K == 0,
            "K={k} must be a positive multiple of {MMA_K} (QUICK stream K-tile)"
        );
        anyhow::ensure!(
            n > 0 && n % PACK_FACTOR == 0,
            "N={n} must be a positive multiple of {PACK_FACTOR} (nibbles per word)"
        );
        Ok(())
    }

    /// Resolve the worker count for an `m x k x n` GEMM: the configured
    /// count, or (auto) one thread per core once the problem is large
    /// enough to amortize spawn + scatter, never more than one per
    /// word-column.
    pub fn effective_threads(&self, m: usize, k: usize, n: usize) -> usize {
        let w_total = n / PACK_FACTOR;
        let cap = w_total.max(1);
        if self.threads != 0 {
            return self.threads.min(cap);
        }
        let flops = 2 * m * k * n;
        if flops < (1 << 22) {
            return 1;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(cap)
    }

    /// f32 capacity of the write-back scratch tile this blocking implies.
    pub fn scratch_len(&self) -> usize {
        self.kc * self.nc_words * PACK_FACTOR
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_blocking_is_valid() {
        Blocking::default().validate(4096, 4096).unwrap();
    }

    #[test]
    fn validate_rejects_bad_shapes_and_params() {
        let b = Blocking::default();
        assert!(b.validate(24, 64).is_err(), "K not 16-aligned");
        assert!(b.validate(64, 12).is_err(), "N not 8-aligned");
        assert!(b.validate(0, 64).is_err());
        let bad_kc = Blocking { kc: 24, ..Blocking::default() };
        assert!(bad_kc.validate(64, 64).is_err());
        let bad_mc = Blocking { mc: 0, ..Blocking::default() };
        assert!(bad_mc.validate(64, 64).is_err());
    }

    #[test]
    fn thread_resolution() {
        let auto = Blocking::default();
        // Tiny problem: stay single-threaded regardless of cores.
        assert_eq!(auto.effective_threads(1, 64, 64), 1);
        // Explicit count is honored but capped at one per word-column.
        let two = Blocking { threads: 2, ..Blocking::default() };
        assert_eq!(two.effective_threads(1, 64, 64), 2);
        let many = Blocking { threads: 64, ..Blocking::default() };
        assert_eq!(many.effective_threads(1, 64, 16), 2);
        // Large problem in auto mode: at least one thread, never more
        // than one per word-column.
        let t = auto.effective_threads(256, 4096, 4096);
        assert!(t >= 1 && t <= 4096 / 8);
    }

    #[test]
    fn scratch_sizing() {
        let b = Blocking { kc: 32, nc_words: 2, ..Blocking::default() };
        assert_eq!(b.scratch_len(), 32 * 16);
    }
}
