//! Cache-blocking parameters shared by the fused and write-back GEMM
//! paths.
//!
//! Both backends run the *same* loop nest — M-blocks of `mc` rows,
//! K-blocks of `kc` rows (16-aligned so every block is whole
//! `mma.m16n8k16` K-tiles), word-column panels — and the same `4 x 8`
//! register microkernel, so the measured fused-vs-write-back gap isolates
//! the scratch round-trip rather than a tuning difference.
//!
//! The [`Blocking::simd`] and [`Blocking::pool`] knobs select the runtime
//! tier (vectorized microkernel/decoders; persistent worker pool) — both
//! default on; the benches pin them off to measure each tier's
//! contribution against PR 4's scalar spawn-per-call baseline.

use anyhow::Result;

use crate::quant::{DecoderKind, MMA_K, PACK_FACTOR};

/// Cache-blocking configuration for the native kernel backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Blocking {
    /// Activation rows per M-block. Weight decode is amortized across the
    /// whole block (the paper's per-threadblock dequant multiplicity:
    /// every M-block pass re-decodes its K x N strip).
    pub mc: usize,
    /// Reduction rows per K-block; must be a positive multiple of 16
    /// (whole interleave K-tiles).
    pub kc: usize,
    /// Word-columns (8 logical columns each) per N-panel — also the
    /// work-stealing tile the thread partitioner hands out. Sizes the
    /// write-back path's scratch tile: `kc * nc_words * 8` f32 — the CPU
    /// stand-in for the baseline kernel's shared-memory staging buffer.
    pub nc_words: usize,
    /// Worker threads; `0` = auto (one per core for large problems,
    /// single-threaded when the GEMM is too small to amortize dispatch).
    /// Explicit and auto counts alike are clamped by
    /// [`Blocking::resolve_threads`].
    pub threads: usize,
    /// Use the SIMD microkernel and nibble decoders when the host
    /// supports them (`false` pins the portable scalar paths — the bench
    /// comparison rows).
    pub simd: bool,
    /// Dispatch column-panel tiles through the persistent
    /// [`super::WorkerPool`] (`false` reverts to PR 4's spawn-per-call
    /// scoped threads — the bench comparison rows).
    pub pool: bool,
    /// Which nibble-decode tier the GEMM runs: the shift-mask expansion
    /// or the 16-entry codebook table lookup. Part of the plan-cache
    /// key (via `Blocking`'s `Hash`), so the decoder choice is priced
    /// and planned per shape like every other knob. Weights carrying a
    /// non-uniform codebook force the LUT tier regardless of this
    /// setting (the shift-mask tier cannot decode them).
    pub decoder: DecoderKind,
}

impl Default for Blocking {
    fn default() -> Self {
        // mc 64 x kc 256 keeps the x strip (~64 KiB) L2-resident; nc 16
        // words = 128 columns gives the write-back path a 128 KiB scratch
        // tile, the same order as the smem staging the AWQ kernel pays.
        Blocking {
            mc: 64,
            kc: 256,
            nc_words: 16,
            threads: 0,
            simd: true,
            pool: true,
            decoder: DecoderKind::ShiftMask,
        }
    }
}

impl Blocking {
    /// Validate this blocking against a weight shape `(k, n)`.
    pub fn validate(&self, k: usize, n: usize) -> Result<()> {
        anyhow::ensure!(self.mc > 0, "mc must be > 0");
        anyhow::ensure!(
            self.kc > 0 && self.kc % MMA_K == 0,
            "kc={} must be a positive multiple of {MMA_K} (interleave K-tile)",
            self.kc
        );
        anyhow::ensure!(self.nc_words > 0, "nc_words must be > 0");
        anyhow::ensure!(
            k > 0 && k % MMA_K == 0,
            "K={k} must be a positive multiple of {MMA_K} (QUICK stream K-tile)"
        );
        anyhow::ensure!(
            n > 0 && n % PACK_FACTOR == 0,
            "N={n} must be a positive multiple of {PACK_FACTOR} (nibbles per word)"
        );
        Ok(())
    }

    /// Number of column-panel work-stealing tiles an `n`-column output
    /// splits into (the parallelism ceiling of the partitioner).
    pub fn n_tiles(&self, n: usize) -> usize {
        (n / PACK_FACTOR).div_ceil(self.nc_words).max(1)
    }

    /// Resolve the worker count for an `m x k x n` GEMM.
    ///
    /// * Explicit requests (`threads > 0`) are clamped to the number of
    ///   column-panel tiles — asking for 64 threads on a 4-tile problem
    ///   used to oversubscribe; now it resolves to 4. (M-blocks do not
    ///   multiply parallelism: the partitioner splits the N axis only, so
    ///   the N-tile count is the true ceiling.)
    /// * Auto (`threads == 0`) resolves to one thread per core — capped
    ///   at [`std::thread::available_parallelism`] *and* the tile count —
    ///   and stays single-threaded when the GEMM is too small to
    ///   amortize even pooled dispatch.
    pub fn resolve_threads(&self, m: usize, k: usize, n: usize) -> usize {
        let cap = self.n_tiles(n);
        if self.threads != 0 {
            return self.threads.min(cap).max(1);
        }
        let flops = 2 * m * k * n;
        if flops < (1 << 22) {
            return 1;
        }
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(cap).max(1)
    }

    /// f32 capacity of the write-back scratch tile this blocking implies
    /// (also the per-slot scratch the plan cache keeps resident; the
    /// fused path's `kc x 8` fragment panel is a prefix of it).
    pub fn scratch_len(&self) -> usize {
        self.kc * self.nc_words * PACK_FACTOR
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_blocking_is_valid() {
        Blocking::default().validate(4096, 4096).unwrap();
    }

    #[test]
    fn validate_rejects_bad_shapes_and_params() {
        let b = Blocking::default();
        assert!(b.validate(24, 64).is_err(), "K not 16-aligned");
        assert!(b.validate(64, 12).is_err(), "N not 8-aligned");
        assert!(b.validate(0, 64).is_err());
        let bad_kc = Blocking { kc: 24, ..Blocking::default() };
        assert!(bad_kc.validate(64, 64).is_err());
        let bad_mc = Blocking { mc: 0, ..Blocking::default() };
        assert!(bad_mc.validate(64, 64).is_err());
    }

    #[test]
    fn resolve_threads_clamps_and_caps() {
        let auto = Blocking::default();
        // Tiny problem: stay single-threaded regardless of cores.
        assert_eq!(auto.resolve_threads(1, 64, 64), 1);
        // Auto on a large problem: at least one thread, never more than
        // the host's cores or the column-panel tile count.
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let t = auto.resolve_threads(256, 4096, 4096);
        assert!(t >= 1 && t <= cores && t <= auto.n_tiles(4096));
        // Explicit requests above the tile count are clamped, not
        // oversubscribed: 4096 columns = 512 word-columns = 32 default
        // tiles, so 64 requested threads resolve to 32.
        let many = Blocking { threads: 64, ..Blocking::default() };
        assert_eq!(many.n_tiles(4096), 32);
        assert_eq!(many.resolve_threads(1, 64, 4096), 32);
        // A 64-column output is a single tile: everything resolves to 1.
        assert_eq!(many.resolve_threads(1, 64, 64), 1);
        // Explicit requests at or below the tile count are honored.
        let two = Blocking { threads: 2, ..Blocking::default() };
        assert_eq!(two.resolve_threads(1, 64, 4096), 2);
        // Finer tiles raise the ceiling.
        let fine = Blocking { nc_words: 1, threads: 64, ..Blocking::default() };
        assert_eq!(fine.resolve_threads(1, 64, 128), 16);
    }

    #[test]
    fn scratch_sizing() {
        let b = Blocking { kc: 32, nc_words: 2, ..Blocking::default() };
        assert_eq!(b.scratch_len(), 32 * 16);
    }
}
