//! `StepExecutor` — run every weight GEMM of one LLM decode step through
//! a chosen native backend, end to end.
//!
//! PR 4 proved the fused-vs-write-back gap on isolated GEMMs; serving
//! cares about the *step*: all of [`LlmSpec::gemms`] (`wq`/`wk`/`wv`/
//! `wo`, the SwiGLU triple, `lm_head`), each run `count` times, at the
//! decode batch M. The executor prepares one packed weight matrix per
//! GEMM shape (synthetic, seeded — layers share weights, which changes
//! nothing about the memory/compute path being measured), pre-generates
//! activations, and times a full pass — the first *measured* end-to-end
//! tokens/sec this repo produces, which
//! [`crate::gpusim::calibrate_step_writeback`] fits the GPU model
//! against (`simulate step`).
//!
//! [`StepExecutor::new_tp`] builds the per-rank view instead
//! ([`LlmSpec::tp_gemms`], Megatron partitioning), so one process can
//! measure what a tensor-parallel rank's GEMM stream costs natively.
//!
//! Correctness is property-tested: a fused (or write-back) executor's
//! outputs must match a naive executor's per-GEMM reference outputs on
//! identical seeds (`tests/property_tests.rs`).
//!
//! Since PR 8 the step can also *execute the decode-attention term*
//! ([`StepExecutor::enable_attention`]): per step, the fused
//! quantized-KV kernel ([`super::attn_quant_fused`]) runs once per
//! (layer × KV head) over a seeded KV cache at a representative context
//! length, timed next to the GEMM stream, with its drift recorded per
//! `(m, ctx, head_dim)` against the `gpusim` KV-bandwidth term
//! ([`crate::gpusim::kv_attn_term`]) — the measured side
//! [`crate::gpusim::calibrate_kv_attn`] fits against.

use std::collections::{BTreeMap, HashMap};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::Result;

use crate::gpusim::kernel_model::model_gemm_decoder;
use crate::gpusim::{kv_attn_term, Calib, DeviceSpec, KernelKind};
use crate::model::{GemmShape, LlmSpec};
use crate::obs::{trace, Counter, DriftAccountant, Registry};
use crate::quant::{
    quantize_groupwise_codebook, quantize_kv, CodebookKind, DecoderKind, KvPrecision, QuantizedKv,
    KV_GROUP,
};
use crate::util::Rng;

use super::attention::{attn_dense_tiled, attn_quant_fused, AttnConfig};
use super::blocking::Blocking;
use super::fused::effective_decoder;
use super::{AwqWritebackBackend, KernelBackend, NaiveBackend, QuickFusedBackend};

/// Registry handles for the executor's step counters, resolved once.
struct ExecMetrics {
    steps: Counter,
    gemm_calls: Counter,
}

fn exec_metrics() -> &'static ExecMetrics {
    static METRICS: OnceLock<ExecMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        ExecMetrics {
            steps: r.counter("executor.steps"),
            gemm_calls: r.counter("executor.gemm_calls"),
        }
    })
}

/// Drift-accounting configuration: which `gpusim` kernel model to hold
/// the measured GEMMs against (see [`StepExecutor::enable_drift`]).
struct DriftConfig {
    dev: DeviceSpec,
    kind: KernelKind,
    /// Nibble-decode tier the executor's weights actually run, so the
    /// modeled twin prices the same decoder
    /// ([`crate::gpusim::Calib::dequant_scale`]).
    decoder: DecoderKind,
    calib: Calib,
    /// Memoized modeled latency per `(m, gemm_index)` — `model_gemm`
    /// allocates while searching tile candidates, so the model is
    /// evaluated once per shape and the steady-state step stays
    /// allocation-free.
    modeled_s: HashMap<(usize, usize), f64>,
}

/// The executable decode-attention term of a step (see
/// [`StepExecutor::enable_attention`]): a seeded quantized (or dense)
/// KV cache at a fixed representative context length, plus the query /
/// output buffers the fused kernel streams through every step.
struct AttnState {
    /// Spec the modeled twin prices the whole-model term from.
    spec: LlmSpec,
    /// Representative decode context length (KV rows per lane).
    ctx: usize,
    /// Head dimension (`spec.head_dim()`).
    head_dim: usize,
    /// Fused-kernel invocations per step: per-rank layers × KV heads.
    calls: usize,
    /// Tensor-parallel ways — the modeled whole-model term is divided by
    /// this to price one rank's share.
    tp: u64,
    /// Quantized K/V (`None` at [`KvPrecision::F16`], which runs the
    /// dense-tiled baseline over `k_dense`/`v_dense` instead).
    kq: Option<QuantizedKv>,
    vq: Option<QuantizedKv>,
    /// Dense f32 K/V for the F16 path (empty when quantized).
    k_dense: Vec<f32>,
    v_dense: Vec<f32>,
    /// Query rows, `m_max * head_dim` (sliced to the step's M).
    q: Vec<f32>,
    /// Attention output, `m_max * head_dim` (overwritten per call).
    out: Vec<f32>,
    cfg: AttnConfig,
    /// `1 / sqrt(head_dim)`.
    scale: f32,
    /// Measured seconds of the attention term in the most recent step.
    attn_s: f64,
    /// Memoized modeled attention seconds per batch M (same rationale as
    /// [`DriftConfig::modeled_s`]).
    modeled_s: HashMap<usize, f64>,
}

/// Which executable backend a [`StepExecutor`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepBackend {
    /// f64-accumulating dense reference ([`NaiveBackend`]).
    Naive,
    /// Fused-from-interleaved QUICK path ([`QuickFusedBackend`]).
    Fused,
    /// Dequant-to-scratch AWQ baseline ([`AwqWritebackBackend`]).
    Writeback,
}

impl StepBackend {
    /// Short display label (report rows, JSON).
    pub fn label(self) -> &'static str {
        match self {
            StepBackend::Naive => "naive",
            StepBackend::Fused => "fused",
            StepBackend::Writeback => "writeback",
        }
    }

    /// The `gpusim` kernel this backend stands in for (fused → QUICK,
    /// write-back → AWQ, naive → fp16 reference) — the modeled twin
    /// drift accounting and the measured serving twins price against.
    pub fn kernel_kind(self) -> KernelKind {
        match self {
            StepBackend::Naive => KernelKind::Fp16,
            StepBackend::Fused => KernelKind::Quick,
            StepBackend::Writeback => KernelKind::Awq,
        }
    }
}

/// One weight GEMM of the step, prepared for repeated execution.
pub struct StepGemm {
    /// Projection name ("wq", "w_down", "lm_head", ...).
    pub name: &'static str,
    /// Reduction dimension.
    pub k: usize,
    /// Output features.
    pub n: usize,
    /// Executions per forward pass (= n_layers for per-layer GEMMs).
    pub count: usize,
    backend: Box<dyn KernelBackend>,
}

impl StepGemm {
    /// The prepared backend for this GEMM.
    pub fn backend(&self) -> &dyn KernelBackend {
        self.backend.as_ref()
    }
}

/// Timing result of one executed step.
#[derive(Debug, Clone, Copy)]
pub struct StepResult {
    /// Decode batch (tokens in flight; one token per sequence).
    pub m: usize,
    /// Wall-clock seconds for the whole step.
    pub wall_s: f64,
    /// GEMM invocations performed (sum of counts).
    pub gemm_calls: usize,
    /// True multiply-add flops of the step (2·m·Σ k·n·count).
    pub flops: f64,
    /// End-to-end decode throughput: `m / wall_s`.
    pub tokens_per_s: f64,
}

impl StepResult {
    /// Aggregate GEMM throughput of the step in GFLOP/s.
    pub fn gflops(&self) -> f64 {
        self.flops / self.wall_s.max(1e-12) / 1e9
    }
}

/// Runs one model's full decode-step GEMM stream through a chosen
/// [`KernelBackend`] (see the module docs).
pub struct StepExecutor {
    name: &'static str,
    backend: StepBackend,
    /// 16-entry grid the step's weights were quantized on.
    codebook: CodebookKind,
    /// Nibble-decode tier the quantized backends resolve to (the
    /// requested [`Blocking::decoder`], forced to LUT by a non-uniform
    /// codebook) — what drift accounting prices the modeled twin with.
    decoder: DecoderKind,
    m_max: usize,
    gemms: Vec<StepGemm>,
    /// One activation buffer per distinct reduction dimension
    /// (`m_max * k` values, sliced to the step's M).
    xs: BTreeMap<usize, Vec<f32>>,
    /// One output buffer per GEMM (`m_max * n`, sliced to the step's M);
    /// retained so reference checks can inspect the last step's outputs.
    ys: Vec<Vec<f32>>,
    /// Measured seconds of each GEMM group in the most recent step.
    gemm_s: Vec<f64>,
    /// Batch of the most recent completed step (0 before the first):
    /// rows beyond it in `ys` are stale leftovers from earlier steps, so
    /// [`StepExecutor::output`] refuses to serve past it.
    last_m: usize,
    /// When set, every step feeds the modeled-vs-measured ledger.
    drift: Option<DriftConfig>,
    /// When set, every step also executes the decode-attention term.
    attn: Option<AttnState>,
}

impl StepExecutor {
    /// Prepare the full (un-sharded) decode step of `spec`: one seeded
    /// random quantized weight matrix per [`LlmSpec::gemms`] entry,
    /// packed for `backend`, plus activation/output buffers for batches
    /// up to `m_max`.
    pub fn new(
        spec: &LlmSpec,
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
    ) -> Result<StepExecutor> {
        Self::new_codebook(
            spec,
            backend,
            blocking,
            group_size,
            m_max,
            seed,
            CodebookKind::Int4Uniform,
        )
    }

    /// [`StepExecutor::new`] with the weights quantized on an arbitrary
    /// 16-entry grid — the entry point non-uniform 4-bit models (NF4,
    /// MXFP4) take into measured serving. Non-uniform grids force the
    /// LUT decode tier regardless of [`Blocking::decoder`].
    #[allow(clippy::too_many_arguments)]
    pub fn new_codebook(
        spec: &LlmSpec,
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
        codebook: CodebookKind,
    ) -> Result<StepExecutor> {
        Self::from_gemms_codebook(
            spec.name,
            &spec.gemms(),
            backend,
            blocking,
            group_size,
            m_max,
            seed,
            codebook,
        )
    }

    /// Prepare one rank's share of a `tp`-way tensor-parallel step
    /// ([`LlmSpec::tp_gemms`]; panics on non-divisible head counts, like
    /// `tp_gemms` itself).
    pub fn new_tp(
        spec: &LlmSpec,
        tp: u64,
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
    ) -> Result<StepExecutor> {
        Self::new_tp_codebook(
            spec,
            tp,
            backend,
            blocking,
            group_size,
            m_max,
            seed,
            CodebookKind::Int4Uniform,
        )
    }

    /// [`StepExecutor::new_tp`] on an arbitrary 16-entry grid (see
    /// [`StepExecutor::new_codebook`]).
    #[allow(clippy::too_many_arguments)]
    pub fn new_tp_codebook(
        spec: &LlmSpec,
        tp: u64,
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
        codebook: CodebookKind,
    ) -> Result<StepExecutor> {
        Self::from_gemms_codebook(
            spec.name,
            &spec.tp_gemms(tp),
            backend,
            blocking,
            group_size,
            m_max,
            seed,
            codebook,
        )
    }

    /// Prepare an arbitrary GEMM list (the entry point the spec wrappers
    /// funnel into; property tests drive it with random shape sets).
    pub fn from_gemms(
        name: &'static str,
        shapes: &[GemmShape],
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
    ) -> Result<StepExecutor> {
        Self::from_gemms_codebook(
            name,
            shapes,
            backend,
            blocking,
            group_size,
            m_max,
            seed,
            CodebookKind::Int4Uniform,
        )
    }

    /// [`StepExecutor::from_gemms`] with the weights quantized on an
    /// arbitrary 16-entry grid (see [`StepExecutor::new_codebook`]).
    #[allow(clippy::too_many_arguments)]
    pub fn from_gemms_codebook(
        name: &'static str,
        shapes: &[GemmShape],
        backend: StepBackend,
        blocking: Blocking,
        group_size: usize,
        m_max: usize,
        seed: u64,
        codebook: CodebookKind,
    ) -> Result<StepExecutor> {
        anyhow::ensure!(!shapes.is_empty(), "step needs at least one GEMM");
        anyhow::ensure!(m_max > 0, "m_max must be > 0");
        let mut rng = Rng::seed_from_u64(seed);
        let mut gemms = Vec::with_capacity(shapes.len());
        for g in shapes {
            let (k, n) = (g.k as usize, g.n as usize);
            blocking.validate(k, n)?;
            anyhow::ensure!(
                group_size > 0 && k % group_size == 0,
                "{}: K={k} not divisible by group_size={group_size}",
                g.name
            );
            let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect();
            let t = quantize_groupwise_codebook(&w, k, n, group_size, codebook);
            let be: Box<dyn KernelBackend> = match backend {
                StepBackend::Naive => Box::new(NaiveBackend::from_quantized(&t)),
                StepBackend::Fused => Box::new(QuickFusedBackend::new(&t, blocking)),
                StepBackend::Writeback => Box::new(AwqWritebackBackend::new(&t, blocking)),
            };
            gemms.push(StepGemm { name: g.name, k, n, count: g.count as usize, backend: be });
        }
        let mut xs: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
        for g in &gemms {
            xs.entry(g.k).or_insert_with(|| {
                (0..m_max * g.k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
            });
        }
        let ys = gemms.iter().map(|g| vec![0f32; m_max * g.n]).collect();
        let gemm_s = vec![0.0; gemms.len()];
        Ok(StepExecutor {
            name,
            backend,
            codebook,
            decoder: effective_decoder(blocking.decoder, codebook),
            m_max,
            gemms,
            xs,
            ys,
            gemm_s,
            last_m: 0,
            drift: None,
            attn: None,
        })
    }

    /// Start feeding the process-wide [`DriftAccountant`]: every later
    /// [`StepExecutor::step`] records each GEMM's `gpusim`-modeled
    /// latency on `dev` under `calib` next to the measured one, keyed by
    /// shape. The kernel kind is implied by the backend (fused → QUICK,
    /// write-back → AWQ, naive → fp16 reference).
    pub fn enable_drift(&mut self, dev: &DeviceSpec, calib: &Calib) {
        self.drift = Some(DriftConfig {
            dev: *dev,
            kind: self.backend.kernel_kind(),
            decoder: self.decoder,
            calib: *calib,
            modeled_s: HashMap::new(),
        });
    }

    /// Start *executing* the decode-attention term: every later
    /// [`StepExecutor::step`] runs the fused quantized-KV attention
    /// kernel once per (per-rank layer × KV head) — `spec.n_layers *
    /// spec.kv_heads / tp` calls — over a seeded KV cache of `ctx`
    /// tokens at `precision` ([`KvPrecision::F16`] runs the dense-tiled
    /// baseline instead), timed inside the step wall clock. When
    /// [`StepExecutor::enable_drift`] is also on, each step records the
    /// measured attention seconds against the `gpusim` KV-bandwidth
    /// term under the shape key `(m, ctx, head_dim)` — disjoint from
    /// the GEMM `(m, k, n)` keys as long as `ctx` is not a weight
    /// reduction dimension (pick something well under `d_model`).
    ///
    /// # Errors
    ///
    /// Errors when `ctx == 0`, `tp` is zero or does not divide
    /// `spec.kv_heads`, or a quantized precision is requested for a
    /// head dimension not divisible by 8 (the KV packing contract).
    pub fn enable_attention(
        &mut self,
        spec: &LlmSpec,
        tp: u64,
        precision: KvPrecision,
        ctx: usize,
        seed: u64,
    ) -> Result<()> {
        anyhow::ensure!(ctx > 0, "attention context must be positive");
        anyhow::ensure!(
            tp >= 1 && spec.kv_heads % tp == 0,
            "{}: {} KV heads not divisible by tp={tp}",
            spec.name,
            spec.kv_heads
        );
        let head_dim = spec.head_dim() as usize;
        let calls = ((spec.n_layers * (spec.kv_heads / tp)) as usize).max(1);
        let mut rng = Rng::seed_from_u64(seed);
        let k: Vec<f32> = (0..ctx * head_dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let v: Vec<f32> = (0..ctx * head_dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let q: Vec<f32> =
            (0..self.m_max * head_dim).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let (kq, vq, k_dense, v_dense) = match precision {
            KvPrecision::F16 => (None, None, k, v),
            KvPrecision::Int8 | KvPrecision::Int4 => {
                anyhow::ensure!(
                    head_dim % 8 == 0,
                    "{}: head_dim {head_dim} not divisible by 8 (KV packing)",
                    spec.name
                );
                // Largest 8-aligned group (≤ KV_GROUP) dividing head_dim.
                let group = if head_dim % KV_GROUP == 0 {
                    KV_GROUP
                } else if head_dim % 16 == 0 {
                    16
                } else {
                    8
                };
                let bits = precision.bits();
                (
                    Some(quantize_kv(&k, ctx, head_dim, group, bits)),
                    Some(quantize_kv(&v, ctx, head_dim, group, bits)),
                    Vec::new(),
                    Vec::new(),
                )
            }
        };
        self.attn = Some(AttnState {
            spec: *spec,
            ctx,
            head_dim,
            calls,
            tp,
            kq,
            vq,
            k_dense,
            v_dense,
            q,
            out: vec![0f32; self.m_max * head_dim],
            cfg: AttnConfig::default(),
            scale: 1.0 / (head_dim as f32).sqrt(),
            attn_s: 0.0,
            modeled_s: HashMap::new(),
        });
        Ok(())
    }

    /// Model/config name this executor was built from.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The backend every GEMM runs through.
    pub fn backend_kind(&self) -> StepBackend {
        self.backend
    }

    /// The 16-entry grid the step's weights were quantized on.
    pub fn codebook(&self) -> CodebookKind {
        self.codebook
    }

    /// The nibble-decode tier the quantized backends resolve to (the
    /// requested [`Blocking::decoder`], forced to LUT when
    /// [`StepExecutor::codebook`] is non-uniform). Drift accounting
    /// prices the modeled twin with this decoder.
    pub fn decoder_kind(&self) -> DecoderKind {
        self.decoder
    }

    /// Largest batch [`StepExecutor::step`] accepts.
    pub fn m_max(&self) -> usize {
        self.m_max
    }

    /// The prepared GEMM list, in execution order.
    pub fn gemms(&self) -> &[StepGemm] {
        &self.gemms
    }

    /// True multiply-add flops of one step at batch `m`.
    pub fn step_flops(&self, m: usize) -> f64 {
        2.0 * m as f64 * self.gemms.iter().map(|g| (g.k * g.n * g.count) as f64).sum::<f64>()
    }

    /// Run one full decode step at batch `m` (`1 ..= m_max`), timing the
    /// whole GEMM stream. After the first call per M, every plan is
    /// cached and the stream allocates nothing.
    pub fn step(&mut self, m: usize) -> Result<StepResult> {
        anyhow::ensure!(
            m >= 1 && m <= self.m_max,
            "step batch {m} outside 1..={} (m_max)",
            self.m_max
        );
        let t0 = Instant::now();
        let mut gemm_calls = 0;
        let tracing = trace::enabled();
        for (gi, g) in self.gemms.iter().enumerate() {
            let x = &self.xs[&g.k][..m * g.k];
            let y = &mut self.ys[gi][..m * g.n];
            let span_t0 = if tracing { trace::now_ns() } else { 0 };
            let g0 = Instant::now();
            for _ in 0..g.count {
                g.backend.gemm(x, m, y);
                gemm_calls += 1;
            }
            let dt = g0.elapsed().as_secs_f64().max(1e-12);
            self.gemm_s[gi] = dt;
            if tracing {
                let gflops = 2.0 * (m * g.k * g.n * g.count) as f64 / dt / 1e9;
                trace::complete(
                    g.name,
                    "executor",
                    span_t0,
                    (dt * 1e9) as u64,
                    &[("m", m as f64), ("k", g.k as f64), ("n", g.n as f64), ("gflops", gflops)],
                );
            }
            if let Some(drift) = &mut self.drift {
                let modeled_call = *drift.modeled_s.entry((m, gi)).or_insert_with(|| {
                    model_gemm_decoder(
                        &drift.dev,
                        drift.kind,
                        drift.decoder,
                        m as u64,
                        g.n as u64,
                        g.k as u64,
                        &drift.calib,
                    )
                    .latency_s
                });
                DriftAccountant::global().record(
                    (m as u64, g.k as u64, g.n as u64),
                    modeled_call * g.count as f64,
                    dt,
                    g.count as u64,
                );
            }
        }
        if let Some(attn) = &mut self.attn {
            let d = attn.head_dim;
            let q = &attn.q[..m * d];
            let out = &mut attn.out[..m * d];
            let span_t0 = if tracing { trace::now_ns() } else { 0 };
            let a0 = Instant::now();
            for _ in 0..attn.calls {
                match (&attn.kq, &attn.vq) {
                    (Some(kq), Some(vq)) => {
                        attn_quant_fused(q, kq, vq, m, attn.scale, &attn.cfg, out)?
                    }
                    _ => attn_dense_tiled(
                        q,
                        &attn.k_dense,
                        &attn.v_dense,
                        m,
                        attn.ctx,
                        d,
                        attn.scale,
                        &attn.cfg,
                        out,
                    )?,
                }
            }
            let dt = a0.elapsed().as_secs_f64().max(1e-12);
            attn.attn_s = dt;
            if tracing {
                trace::complete(
                    "attn",
                    "executor",
                    span_t0,
                    (dt * 1e9) as u64,
                    &[
                        ("m", m as f64),
                        ("ctx", attn.ctx as f64),
                        ("head_dim", d as f64),
                        ("calls", attn.calls as f64),
                    ],
                );
            }
            if let Some(drift) = &self.drift {
                // Whole-model modeled attention seconds, one rank's share.
                let modeled = *attn.modeled_s.entry(m).or_insert_with(|| {
                    kv_attn_term(&drift.dev, &attn.spec, m as u64, attn.ctx as u64, &drift.calib)
                        / attn.tp as f64
                });
                DriftAccountant::global().record(
                    (m as u64, attn.ctx as u64, d as u64),
                    modeled,
                    dt,
                    attn.calls as u64,
                );
            }
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-12);
        self.last_m = m;
        let em = exec_metrics();
        em.steps.inc();
        em.gemm_calls.add(gemm_calls as u64);
        Ok(StepResult {
            m,
            wall_s,
            gemm_calls,
            flops: self.step_flops(m),
            tokens_per_s: m as f64 / wall_s,
        })
    }

    /// Measured seconds of each GEMM group (all `count` calls) in the
    /// most recent [`StepExecutor::step`], indexed like
    /// [`StepExecutor::gemms`]. Zeros before the first step.
    pub fn last_gemm_s(&self) -> &[f64] {
        &self.gemm_s
    }

    /// Whether [`StepExecutor::enable_attention`] is on.
    pub fn attention_enabled(&self) -> bool {
        self.attn.is_some()
    }

    /// Measured seconds of the decode-attention term (all `layers × KV
    /// heads` kernel calls) in the most recent step — `0.0` before the
    /// first step or when attention execution is not enabled.
    pub fn last_attn_s(&self) -> f64 {
        self.attn.as_ref().map_or(0.0, |a| a.attn_s)
    }

    /// The activation buffer for reduction dimension `k`, sliced to
    /// batch `m` (reference checks).
    pub fn activation(&self, k: usize, m: usize) -> &[f32] {
        &self.xs[&k][..m * k]
    }

    /// GEMM `gi`'s output from the most recent step, sliced to `m`
    /// rows (reference checks).
    ///
    /// # Panics
    /// If `m` exceeds the batch of the last executed step: rows past it
    /// still hold values from an *earlier* step and must not be served
    /// as current output.
    pub fn output(&self, gi: usize, m: usize) -> &[f32] {
        assert!(
            m <= self.last_m,
            "output(gi={gi}, m={m}): last step ran at batch {}; rows {}..{m} are stale",
            self.last_m,
            self.last_m,
        );
        &self.ys[gi][..m * self.gemms[gi].n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::max_rel_err;
    use crate::model::Model;

    #[test]
    fn fused_step_matches_naive_step_on_tiny() {
        let spec = Model::Tiny.spec();
        let b = Blocking::default();
        let mut naive = StepExecutor::new(&spec, StepBackend::Naive, b, 128, 4, 7).unwrap();
        let mut fused = StepExecutor::new(&spec, StepBackend::Fused, b, 128, 4, 7).unwrap();
        let rn = naive.step(3).unwrap();
        let rf = fused.step(3).unwrap();
        assert_eq!(rn.gemm_calls, rf.gemm_calls);
        assert_eq!(rn.gemm_calls, 7 * 4 + 1, "7 per-layer GEMMs x 4 layers + lm_head");
        assert!(rf.tokens_per_s > 0.0 && rf.gflops() > 0.0);
        for gi in 0..naive.gemms().len() {
            let err = max_rel_err(fused.output(gi, 3), naive.output(gi, 3));
            assert!(err <= 1e-4, "gemm {gi} ({}): {err}", naive.gemms()[gi].name);
        }
    }

    #[test]
    fn nonuniform_step_matches_naive_step_and_forces_lut() {
        let spec = Model::Tiny.spec();
        let b = Blocking::default();
        for cb in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let mut naive =
                StepExecutor::new_codebook(&spec, StepBackend::Naive, b, 128, 2, 11, cb).unwrap();
            let mut fused =
                StepExecutor::new_codebook(&spec, StepBackend::Fused, b, 128, 2, 11, cb).unwrap();
            assert_eq!(fused.codebook(), cb);
            // ShiftMask was requested (default Blocking) but a
            // non-uniform grid cannot run it.
            assert_eq!(fused.decoder_kind(), DecoderKind::Lut, "{cb:?}");
            naive.step(2).unwrap();
            fused.step(2).unwrap();
            for gi in 0..naive.gemms().len() {
                let err = max_rel_err(fused.output(gi, 2), naive.output(gi, 2));
                assert!(err <= 1e-4, "{cb:?} gemm {gi}: {err}");
            }
        }
    }

    #[test]
    fn uniform_step_honors_the_requested_decoder() {
        let spec = Model::Tiny.spec();
        let shift = StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 128, 2, 3)
            .unwrap();
        assert_eq!(shift.codebook(), CodebookKind::Int4Uniform);
        assert_eq!(shift.decoder_kind(), DecoderKind::ShiftMask);
        let b = Blocking { decoder: DecoderKind::Lut, ..Blocking::default() };
        let lut = StepExecutor::new(&spec, StepBackend::Fused, b, 128, 2, 3).unwrap();
        assert_eq!(lut.decoder_kind(), DecoderKind::Lut);
    }

    #[test]
    fn tp_rank_shrinks_the_stream() {
        let spec = Model::Tiny.spec();
        let b = Blocking::default();
        let full = StepExecutor::new(&spec, StepBackend::Fused, b, 64, 2, 1).unwrap();
        let rank = StepExecutor::new_tp(&spec, 2, StepBackend::Fused, b, 64, 2, 1).unwrap();
        assert!(rank.step_flops(1) < full.step_flops(1));
        // Megatron partitioning shards every GEMM's volume by tp.
        assert!((rank.step_flops(1) - full.step_flops(1) / 2.0).abs() < 1e-3);
    }

    #[test]
    fn step_rejects_out_of_range_batches() {
        let spec = Model::Tiny.spec();
        let mut e =
            StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 128, 2, 3).unwrap();
        assert!(e.step(0).is_err());
        assert!(e.step(3).is_err());
        assert!(e.step(2).is_ok());
    }

    #[test]
    #[should_panic(expected = "stale")]
    fn output_refuses_rows_beyond_last_step() {
        let spec = Model::Tiny.spec();
        let mut e =
            StepExecutor::new(&spec, StepBackend::Naive, Blocking::default(), 128, 4, 7).unwrap();
        e.step(3).unwrap();
        e.step(2).unwrap();
        // Rows 2..3 still hold the step(3) values; serving them as the
        // current step's output is the bug this guards against.
        let _ = e.output(0, 3);
    }

    #[test]
    fn output_serves_rows_up_to_last_step() {
        let spec = Model::Tiny.spec();
        let mut e =
            StepExecutor::new(&spec, StepBackend::Naive, Blocking::default(), 128, 4, 7).unwrap();
        e.step(3).unwrap();
        assert_eq!(e.output(0, 3).len(), 3 * e.gemms()[0].n);
        assert_eq!(e.output(0, 2).len(), 2 * e.gemms()[0].n);
    }

    #[test]
    fn rejects_misaligned_group_size() {
        let spec = Model::Tiny.spec();
        let e = StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 96, 2, 3);
        assert!(e.is_err(), "96 does not divide d_model=256");
    }

    #[test]
    fn attention_term_is_measured_alongside_the_gemms() {
        let spec = Model::Tiny.spec();
        let mut e =
            StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 128, 3, 5).unwrap();
        assert!(!e.attention_enabled());
        assert_eq!(e.last_attn_s(), 0.0);
        for precision in [KvPrecision::Int4, KvPrecision::Int8, KvPrecision::F16] {
            e.enable_attention(&spec, 1, precision, 48, 0xA77).unwrap();
            assert!(e.attention_enabled());
            let r = e.step(3).unwrap();
            let attn_s = e.last_attn_s();
            assert!(attn_s > 0.0, "{precision:?}: attention term untimed");
            assert!(attn_s <= r.wall_s, "{precision:?}: attention outside the step wall clock");
        }
    }

    #[test]
    fn attention_drift_is_recorded_under_its_own_shape_key() {
        let spec = Model::Tiny.spec();
        // ctx = 37 is not a GEMM dimension of any model, so the key is
        // uniquely this test's even on the shared global accountant.
        let (ctx, m) = (37usize, 2usize);
        let mut e =
            StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 128, 2, 9).unwrap();
        e.enable_drift(&crate::gpusim::Gpu::A100.spec(), &Calib::default());
        e.enable_attention(&spec, 1, KvPrecision::Int4, ctx, 0xA77).unwrap();
        e.step(m).unwrap();
        let key = (m as u64, ctx as u64, spec.head_dim());
        let snap = DriftAccountant::global().snapshot();
        let stat = snap.iter().find(|(k, _)| *k == key);
        let (_, stat) = stat.expect("attention drift row missing");
        assert!(stat.modeled_s > 0.0 && stat.measured_s > 0.0);
        assert_eq!(stat.samples % (spec.n_layers * spec.kv_heads), 0);
    }

    #[test]
    fn enable_attention_rejects_bad_shapes() {
        let spec = Model::Tiny.spec();
        let mut e =
            StepExecutor::new(&spec, StepBackend::Fused, Blocking::default(), 128, 2, 9).unwrap();
        assert!(e.enable_attention(&spec, 1, KvPrecision::Int4, 0, 1).is_err(), "ctx 0");
        assert!(e.enable_attention(&spec, 3, KvPrecision::Int4, 16, 1).is_err(), "tp 3 vs 4 heads");
        assert!(!e.attention_enabled(), "failed enables must not arm the term");
    }
}
