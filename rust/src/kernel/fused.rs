//! `gemm_quick_fused` — the fused dequant-GEMM path that consumes the
//! QUICK interleaved stream directly.
//!
//! Per (M-block, K-block, word-column): load the contiguous 16-word runs
//! the offline interleave laid down for that column's fragments, decode
//! them in-register into a `kc x 8` fragment panel **already in
//! microkernel tile order** (no runtime permutation — `FT_ORDER` was
//! applied offline by the dequant-aware reorder, the tile transpose by
//! the fragment interleave), then run the shared `4 x 8` microkernel
//! across the M-block with the panel as the weight operand. The panel is
//! the CPU stand-in for the paper's register-file fragments: 8 KiB,
//! L1-resident, written linearly, consumed immediately — against the
//! write-back path's 16x-larger scratch tile with its runtime FT-order
//! scatter (the shared-memory staging QUICK deletes, §3.1). Decode
//! multiplicity (once per M-block pass), blocking, threading, and the
//! microkernel are identical across the two paths, so the measured gap
//! isolates the staging round-trip.
//!
//! Execution runs through the kernel runtime: the per-shape
//! [`GemmPlan`] supplies precomputed run offsets, work-stealing tiles,
//! and resident scratch (nothing allocates on a repeated-shape call);
//! [`Blocking::simd`] selects the vectorized microkernel + decoder pair.

use anyhow::Result;

use crate::quant::decode::{
    select_quick_decoder, select_quick_lut_decoder, DecodeQuickFn, DecodeQuickLutFn, TILE_COLS,
    TILE_ROWS,
};
use crate::quant::{pack_quick, Codebook, CodebookKind, DecoderKind, QuantizedTensor, PACK_FACTOR};

use super::blocking::Blocking;
use super::microkernel;
use super::plan::{GemmPlan, PlanCache};

/// A weight matrix packed into the full QUICK layout (interleaved stream
/// + group metadata), ready for [`gemm_quick_fused`].
#[derive(Debug, Clone)]
pub struct QuickWeights {
    /// The `pack_quick` interleaved word stream (1-D DRAM order).
    pub stream: Vec<u32>,
    /// Per-group scales, row-major `(k / group_size, n)`.
    pub scales: Vec<f32>,
    /// Per-group zero-points, same shape as scales.
    pub zeros: Vec<f32>,
    /// In-features (reduction axis).
    pub k: usize,
    /// Out-features.
    pub n: usize,
    /// Quantization group length along K.
    pub group_size: usize,
    /// The 16-entry grid the stream's nibbles index. Non-uniform grids
    /// (NF4/MXFP4) force the LUT decode tier in [`gemm_quick_fused`].
    pub codebook: CodebookKind,
}

impl QuickWeights {
    /// Pack a logical quantized tensor into the QUICK layout
    /// (the tensor's codebook rides along).
    ///
    /// # Panics
    ///
    /// Panics on the `pack_quick` shape contract (`k % 16`, `n % 8`).
    pub fn from_quantized(t: &QuantizedTensor) -> Self {
        QuickWeights {
            stream: pack_quick(&t.codes, t.k, t.n),
            scales: t.scales.clone(),
            zeros: t.zeros.clone(),
            k: t.k,
            n: t.n,
            group_size: t.group_size,
            codebook: t.codebook,
        }
    }
}

/// The decode tier a GEMM call actually runs: the blocking's request,
/// upgraded to [`DecoderKind::Lut`] whenever the weights carry a
/// non-uniform codebook (shift-mask arithmetic cannot decode those).
pub(crate) fn effective_decoder(requested: DecoderKind, codebook: CodebookKind) -> DecoderKind {
    if codebook.is_uniform() {
        requested
    } else {
        DecoderKind::Lut
    }
}

/// A resolved quick-run decode tier: one enum dispatch per 16-word run,
/// function pointers and the codebook bound once per GEMM call.
pub(crate) enum QuickDecode {
    /// Shift-mask expansion (uniform INT4 only).
    Shift(DecodeQuickFn),
    /// Codebook table lookup.
    Lut(DecodeQuickLutFn, &'static Codebook),
}

impl QuickDecode {
    /// Resolve the decode tier for `(blocking, weights-codebook)`.
    pub(crate) fn resolve(simd: bool, requested: DecoderKind, codebook: CodebookKind) -> Self {
        match effective_decoder(requested, codebook) {
            DecoderKind::ShiftMask => QuickDecode::Shift(select_quick_decoder(simd)),
            DecoderKind::Lut => QuickDecode::Lut(select_quick_lut_decoder(simd), codebook.table()),
        }
    }

    /// Decode one 16-word run (the [`select_quick_decoder`] contract).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run(
        &self,
        run: &[u32],
        row0: usize,
        col0: usize,
        scales: &[f32],
        zeros: &[f32],
        n: usize,
        group_size: usize,
        frag: &mut [f32],
    ) {
        match self {
            QuickDecode::Shift(f) => f(run, row0, col0, scales, zeros, n, group_size, frag),
            QuickDecode::Lut(f, cb) => f(run, row0, col0, scales, zeros, n, group_size, cb, frag),
        }
    }
}

/// `y(m, n) = x(m, k) @ w(k, n)` with `w` consumed directly from the
/// interleaved QUICK stream; `y` is overwritten.
///
/// Resolves the execution plan through the process-wide [`PlanCache`]
/// (a map hit on every repeated shape — every decode step); errors on
/// shape violations (`x`/`y` length, blocking contract).
pub fn gemm_quick_fused(
    x: &[f32],
    m: usize,
    w: &QuickWeights,
    b: &Blocking,
    y: &mut [f32],
) -> Result<()> {
    let plan = PlanCache::global().plan(m, w.k, w.n, b)?;
    gemm_quick_fused_planned(x, w, &plan, y)
}

/// [`gemm_quick_fused`] with a caller-held [`GemmPlan`] — the
/// `StepExecutor` hot path, which resolves each layer's plan once and
/// skips even the cache lookup per call.
pub fn gemm_quick_fused_planned(
    x: &[f32],
    w: &QuickWeights,
    plan: &GemmPlan,
    y: &mut [f32],
) -> Result<()> {
    anyhow::ensure!(
        plan.k == w.k && plan.n == w.n,
        "plan shape ({}, {}) does not match weights ({}, {})",
        plan.k,
        plan.n,
        w.k,
        w.n
    );
    let m = plan.m;
    anyhow::ensure!(x.len() == m * w.k, "x holds {} values, needs {}", x.len(), m * w.k);
    anyhow::ensure!(y.len() == m * w.n, "y holds {} values, needs {}", y.len(), m * w.n);
    let b = plan.blocking;
    let kern = microkernel::select(b.simd);
    let decode = QuickDecode::resolve(b.simd, b.decoder, w.codebook);
    plan.execute(y, &|panel, out, ldy, out_c0, scratch| {
        // The K-strip fragment panel: kc x 8 f32 (8 KiB at the default
        // blocking), resident in the plan's per-slot scratch and refilled
        // for every (M-block, K-block, word-column). This is the
        // register-file analogue — written linearly by the sequential
        // decode, still L1-hot when the microkernel reads it.
        let frag = &mut scratch[..b.kc * TILE_COLS];
        let mut m0 = 0;
        while m0 < m {
            let m1 = (m0 + b.mc).min(m);
            let mut kb0 = 0;
            while kb0 < w.k {
                let kc_len = b.kc.min(w.k - kb0);
                for wj in panel.wj0..panel.wj1 {
                    for kt_rel in 0..kc_len / TILE_ROWS {
                        let row0 = kb0 + kt_rel * TILE_ROWS;
                        let off = plan.run_offset(row0 / TILE_ROWS, wj);
                        decode.run(
                            &w.stream[off..off + TILE_ROWS],
                            row0,
                            wj * PACK_FACTOR,
                            &w.scales,
                            &w.zeros,
                            w.n,
                            w.group_size,
                            &mut frag[kt_rel * TILE_ROWS * TILE_COLS..],
                        );
                    }
                    kern(
                        x,
                        w.k,
                        m0,
                        m1,
                        kb0,
                        kc_len,
                        frag,
                        TILE_COLS,
                        out,
                        ldy,
                        wj * PACK_FACTOR - out_c0,
                    );
                }
                kb0 += kc_len;
            }
            m0 = m1;
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{max_rel_err, KernelBackend, NaiveBackend};
    use crate::quant::quantize_groupwise;
    use crate::util::Rng;

    fn rand_case(k: usize, n: usize, g: usize, m: usize, seed: u64) -> (Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::seed_from_u64(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let t = quantize_groupwise(&w, k, n, g);
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        (x, t)
    }

    #[test]
    fn matches_naive_on_nonsquare_shapes() {
        for (k, n, g, m) in [(64, 24, 32, 1), (128, 40, 64, 9), (96, 64, 32, 5)] {
            let (x, t) = rand_case(k, n, g, m, 42 + m as u64);
            let naive = NaiveBackend::from_quantized(&t);
            let mut want = vec![0f32; m * n];
            naive.gemm(&x, m, &mut want);
            let w = QuickWeights::from_quantized(&t);
            let mut got = vec![f32::NAN; m * n];
            gemm_quick_fused(&x, m, &w, &Blocking::default(), &mut got).unwrap();
            let err = max_rel_err(&got, &want);
            assert!(err <= 1e-4, "k={k} n={n} g={g} m={m}: rel err {err}");
        }
    }

    #[test]
    fn partial_blocks_and_tiny_blocking_agree() {
        // kc/mc/nc smaller than the shape forces every partial-block edge.
        let (k, n, g, m) = (80, 48, 16, 11);
        let (x, t) = rand_case(k, n, g, m, 7);
        let naive = NaiveBackend::from_quantized(&t);
        let mut want = vec![0f32; m * n];
        naive.gemm(&x, m, &mut want);
        let w = QuickWeights::from_quantized(&t);
        let tiny = Blocking { mc: 3, kc: 32, nc_words: 1, threads: 1, ..Blocking::default() };
        let mut got = vec![0f32; m * n];
        gemm_quick_fused(&x, m, &w, &tiny, &mut got).unwrap();
        assert!(max_rel_err(&got, &want) <= 1e-4);
    }

    #[test]
    fn multithreaded_pool_and_spawn_equal_single() {
        let (k, n, g, m) = (64, 80, 32, 6);
        let (x, t) = rand_case(k, n, g, m, 99);
        let w = QuickWeights::from_quantized(&t);
        let mut single = vec![0f32; m * n];
        gemm_quick_fused(&x, m, &w, &Blocking { threads: 1, ..Blocking::default() }, &mut single)
            .unwrap();
        for pool in [true, false] {
            let b = Blocking { threads: 3, nc_words: 2, pool, ..Blocking::default() };
            let mut multi = vec![0f32; m * n];
            gemm_quick_fused(&x, m, &w, &b, &mut multi).unwrap();
            assert_eq!(single, multi, "pool={pool}: partition must not change results");
        }
    }

    #[test]
    fn simd_and_scalar_agree_closely() {
        // FMA rounds once per multiply-add where the scalar path rounds
        // twice; the difference grows with K, so the full-GEMM bar is
        // 1e-5 (the strict 1e-6 microkernel property lives in
        // microkernel.rs over short reductions).
        let (k, n, g, m) = (256, 64, 64, 9);
        let (x, t) = rand_case(k, n, g, m, 31);
        let w = QuickWeights::from_quantized(&t);
        let mut simd = vec![0f32; m * n];
        let mut scalar = vec![0f32; m * n];
        gemm_quick_fused(&x, m, &w, &Blocking { threads: 1, ..Blocking::default() }, &mut simd)
            .unwrap();
        let sb = Blocking { threads: 1, simd: false, ..Blocking::default() };
        gemm_quick_fused(&x, m, &w, &sb, &mut scalar).unwrap();
        assert!(max_rel_err(&simd, &scalar) <= 1e-5);
    }

    #[test]
    fn lut_decoder_on_uniform_weights_is_bit_identical() {
        // Same identity table, same affine, no FMA in the decoders:
        // switching `Blocking::decoder` must not change a single bit.
        use crate::quant::DecoderKind;
        let (k, n, g, m) = (96, 40, 32, 7);
        let (x, t) = rand_case(k, n, g, m, 63);
        let w = QuickWeights::from_quantized(&t);
        let shift = Blocking { threads: 1, ..Blocking::default() };
        let lut = Blocking { threads: 1, decoder: DecoderKind::Lut, ..Blocking::default() };
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        gemm_quick_fused(&x, m, &w, &shift, &mut a).unwrap();
        gemm_quick_fused(&x, m, &w, &lut, &mut b).unwrap();
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn nonuniform_codebooks_match_naive_reference() {
        // NF4/MXFP4 weights force the LUT tier; the fused output must
        // agree with naive-on-dequantized at the kernel bar.
        use crate::quant::{quantize_groupwise_codebook, CodebookKind};
        let (k, n, g, m) = (64, 48, 32, 5);
        let mut rng = Rng::seed_from_u64(77);
        let wf: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        for kind in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let t = quantize_groupwise_codebook(&wf, k, n, g, kind);
            let naive = NaiveBackend::from_quantized(&t);
            let mut want = vec![0f32; m * n];
            naive.gemm(&x, m, &mut want);
            let w = QuickWeights::from_quantized(&t);
            assert_eq!(w.codebook, kind);
            let mut got = vec![f32::NAN; m * n];
            gemm_quick_fused(&x, m, &w, &Blocking::default(), &mut got).unwrap();
            let err = max_rel_err(&got, &want);
            assert!(err <= 1e-4, "{kind:?}: rel err {err}");
        }
    }

    #[test]
    fn planned_entry_rejects_mismatched_plan() {
        let (x, t) = rand_case(32, 16, 32, 2, 1);
        let w = QuickWeights::from_quantized(&t);
        let plan = PlanCache::global().plan(2, 64, 16, &Blocking::default()).unwrap();
        let mut y = vec![0f32; 2 * 16];
        let e = gemm_quick_fused_planned(&x, &w, &plan, &mut y).unwrap_err();
        assert!(e.to_string().contains("plan shape"), "{e}");
    }

    #[test]
    fn rejects_bad_buffers() {
        let (_, t) = rand_case(32, 16, 32, 1, 1);
        let w = QuickWeights::from_quantized(&t);
        let b = Blocking::default();
        assert!(gemm_quick_fused(&[0.0; 31], 1, &w, &b, &mut [0.0; 16]).is_err());
        assert!(gemm_quick_fused(&[0.0; 32], 1, &w, &b, &mut [0.0; 15]).is_err());
        assert!(gemm_quick_fused(&[], 0, &w, &b, &mut []).is_err());
    }
}
