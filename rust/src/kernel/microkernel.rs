//! The register-tiled `4 x 8` FMA microkernel both native GEMM paths
//! share.
//!
//! One invocation accumulates `y[r, col0..col0+8] += x[r, kk0..kk0+len] @
//! tile` for `r` in an M-strip, reading dequantized weights from `tile`
//! (a `len x 8` panel with arbitrary row stride). The fused path hands it
//! a 16x8 fragment decoded moments earlier and still L1-hot — the CPU
//! analogue of MMA fragments fed straight from registers; the write-back
//! path hands it a slice of its large scratch tile, paying the
//! memory round-trip the paper's baseline kernel pays through shared
//! memory. Identical inner loop either way, so the measured gap is the
//! operand's journey, not the arithmetic.

/// Rows per register strip (`MR`): 4 rows x 8 columns of f32 accumulators
/// stay in registers across the whole reduction.
pub const MR: usize = 4;

/// Columns per microkernel tile (`NR`): the 8 logical columns of one
/// packed word.
pub const NR: usize = 8;

/// Accumulate `y[m0..m1, col0..col0+8] += x[m0..m1, kk0..kk0+len] @ tile`.
///
/// * `x` — activations, row-major `(m, k)` with row stride `k`.
/// * `tile` — dequantized weight panel: `len` rows x 8 columns, row
///   stride `tile_stride` (8 for the fused fragment, panel width for the
///   write-back scratch).
/// * `y` — output, row stride `ldy`, columns starting at `col0`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fma_tile8(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
) {
    debug_assert!(tile_stride >= NR && tile.len() >= (len - 1) * tile_stride + NR);
    let mut r = m0;
    while r + MR <= m1 {
        let mut acc = [[0f32; NR]; MR];
        for kk in 0..len {
            let trow = &tile[kk * tile_stride..kk * tile_stride + NR];
            for (i, a) in acc.iter_mut().enumerate() {
                let xv = x[(r + i) * k + kk0 + kk];
                for (ap, &tv) in a.iter_mut().zip(trow) {
                    *ap += xv * tv;
                }
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let yrow = &mut y[(r + i) * ldy + col0..(r + i) * ldy + col0 + NR];
            for (yp, &av) in yrow.iter_mut().zip(a) {
                *yp += av;
            }
        }
        r += MR;
    }
    // Remainder strip (m1 - r < MR rows).
    while r < m1 {
        let mut acc = [0f32; NR];
        for kk in 0..len {
            let xv = x[r * k + kk0 + kk];
            let trow = &tile[kk * tile_stride..kk * tile_stride + NR];
            for (ap, &tv) in acc.iter_mut().zip(trow) {
                *ap += xv * tv;
            }
        }
        let yrow = &mut y[r * ldy + col0..r * ldy + col0 + NR];
        for (yp, &av) in yrow.iter_mut().zip(&acc) {
            *yp += av;
        }
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference(
        x: &[f32],
        k: usize,
        m: usize,
        tile: &[f32],
        stride: usize,
        len: usize,
    ) -> Vec<f32> {
        let mut y = vec![0f32; m * NR];
        for r in 0..m {
            for kk in 0..len {
                for p in 0..NR {
                    y[r * NR + p] += x[r * k + kk] * tile[kk * stride + p];
                }
            }
        }
        y
    }

    #[test]
    fn matches_reference_including_remainder_rows() {
        // m = 7 exercises one full MR strip plus a 3-row remainder.
        let (m, k, len) = (7usize, 24usize, 24usize);
        let x: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
        let tile: Vec<f32> = (0..len * NR).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
        let mut y = vec![0f32; m * NR];
        fma_tile8(&x, k, 0, m, 0, len, &tile, NR, &mut y, NR, 0);
        assert_eq!(y, reference(&x, k, m, &tile, NR, len));
    }

    #[test]
    fn strided_tile_and_offset_output() {
        // Tile embedded in a wider panel (stride 24), output written into
        // a wider y at col0 = 8, rows 2..5 only, reduction offset kk0 = 8.
        let (k, len, stride, ldy) = (32usize, 16usize, 24usize, 32usize);
        let x: Vec<f32> = (0..6 * k).map(|i| ((i * 5) % 11) as f32 - 5.0).collect();
        let panel: Vec<f32> = (0..len * stride).map(|i| ((i * 3) % 17) as f32 * 0.125).collect();
        let mut y = vec![1.0f32; 6 * ldy]; // pre-filled: microkernel accumulates
        fma_tile8(&x, k, 2, 5, 8, len, &panel, stride, &mut y, ldy, 8);
        for r in 0..6 {
            for c in 0..ldy {
                let mut want = 1.0f32;
                if (2..5).contains(&r) && (8..16).contains(&c) {
                    for kk in 0..len {
                        want += x[r * k + 8 + kk] * panel[kk * stride + (c - 8)];
                    }
                }
                let got = y[r * ldy + c];
                let tol = 1e-4 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "r={r} c={c}: {got} vs {want}");
            }
        }
    }
}
