//! The register-tiled `4 x 8` FMA microkernel both native GEMM paths
//! share — now with explicitly vectorized variants.
//!
//! One invocation accumulates `y[r, col0..col0+8] += x[r, kk0..kk0+len] @
//! tile` for `r` in an M-strip, reading dequantized weights from `tile`
//! (a `len x 8` panel with arbitrary row stride). The fused path hands it
//! a 16x8 fragment decoded moments earlier and still L1-hot — the CPU
//! analogue of MMA fragments fed straight from registers; the write-back
//! path hands it a slice of its large scratch tile, paying the
//! memory round-trip the paper's baseline kernel pays through shared
//! memory. Identical inner loop either way, so the measured gap is the
//! operand's journey, not the arithmetic.
//!
//! Three implementations sit behind one function-pointer dispatch
//! ([`select`]):
//!
//! * **AVX2 + FMA** (x86_64) — the 8 columns of one packed word are
//!   exactly one 256-bit lane; the 4x8 accumulator block lives in four
//!   `ymm` registers across the whole reduction, with one broadcast + one
//!   `vfmadd` per (row, k) step. Gated on a one-time runtime CPUID check.
//! * **NEON** (aarch64) — the same block as eight `float32x4_t`
//!   accumulators (two per row), `vfmaq_n_f32` per half-row.
//! * **scalar** — the portable fallback (PR 4's original loop), also the
//!   reference the SIMD paths are property-tested against (within 1e-6:
//!   fused-multiply-add rounds once where mul+add rounds twice).
//!
//! Selection is per-GEMM-call via [`Blocking::simd`]
//! (`crate::kernel::Blocking`), so benches can pin either path.

/// Rows per register strip (`MR`): 4 rows x 8 columns of f32 accumulators
/// stay in registers across the whole reduction.
pub const MR: usize = 4;

/// Columns per microkernel tile (`NR`): the 8 logical columns of one
/// packed word.
pub const NR: usize = 8;

/// The shared microkernel signature: accumulate
/// `y[m0..m1, col0..col0+NR] += x[m0..m1, kk0..kk0+len] @ tile`.
///
/// * `x` — activations, row-major `(m, k)` with row stride `k`.
/// * `tile` — dequantized weight panel: `len` rows x 8 columns, row
///   stride `tile_stride` (8 for the fused fragment, panel width for the
///   write-back scratch).
/// * `y` — output, row stride `ldy`, columns starting at `col0`.
pub(crate) type MicrokernelFn = fn(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
);

/// Pick the microkernel for this host: the SIMD variant when `simd` is
/// set and the CPU supports it, the portable scalar loop otherwise.
pub(crate) fn select(simd: bool) -> MicrokernelFn {
    if simd {
        #[cfg(target_arch = "x86_64")]
        if avx2_available() {
            return fma_tile8_avx2;
        }
        #[cfg(target_arch = "aarch64")]
        return fma_tile8_neon;
    }
    fma_tile8_scalar
}

/// The SIMD level [`select`]`(true)` resolves to on this host
/// (`"avx2"`, `"neon"`, or `"scalar"`) — bench/JSON row labeling.
pub fn simd_level() -> &'static str {
    if cfg!(target_arch = "aarch64") {
        return "neon";
    }
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return "avx2";
    }
    "scalar"
}

// The shared AVX2+FMA CPUID probe: one definition gates the microkernel
// and the nibble decoders identically, so the "avx2" tier is coherent.
#[cfg(target_arch = "x86_64")]
use crate::quant::decode::avx2_available;

/// Bounds shared by every variant; hoisted so the unsafe paths can rely
/// on them (debug builds assert, release builds trust the callers inside
/// this crate — both GEMM drivers produce in-range strips by
/// construction).
#[inline]
#[allow(clippy::too_many_arguments)]
fn check_bounds(
    x: &[f32],
    k: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &[f32],
    ldy: usize,
    col0: usize,
) {
    assert!(tile_stride >= NR, "tile stride below the 8-column tile");
    assert!(len > 0 && tile.len() >= (len - 1) * tile_stride + NR, "tile panel too short");
    if m1 > 0 {
        assert!(x.len() >= (m1 - 1) * k + kk0 + len, "x strip out of range");
        assert!(y.len() >= (m1 - 1) * ldy + col0 + NR, "y strip out of range");
    }
}

/// Portable scalar microkernel (see [`MicrokernelFn`] for the contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn fma_tile8_scalar(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
) {
    check_bounds(x, k, m1, kk0, len, tile, tile_stride, y, ldy, col0);
    let mut r = m0;
    while r + MR <= m1 {
        let mut acc = [[0f32; NR]; MR];
        for kk in 0..len {
            let trow = &tile[kk * tile_stride..kk * tile_stride + NR];
            for (i, a) in acc.iter_mut().enumerate() {
                let xv = x[(r + i) * k + kk0 + kk];
                for (ap, &tv) in a.iter_mut().zip(trow) {
                    *ap += xv * tv;
                }
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let yrow = &mut y[(r + i) * ldy + col0..(r + i) * ldy + col0 + NR];
            for (yp, &av) in yrow.iter_mut().zip(a) {
                *yp += av;
            }
        }
        r += MR;
    }
    // Remainder strip (m1 - r < MR rows).
    while r < m1 {
        let mut acc = [0f32; NR];
        for kk in 0..len {
            let xv = x[r * k + kk0 + kk];
            let trow = &tile[kk * tile_stride..kk * tile_stride + NR];
            for (ap, &tv) in acc.iter_mut().zip(trow) {
                *ap += xv * tv;
            }
        }
        let yrow = &mut y[r * ldy + col0..r * ldy + col0 + NR];
        for (yp, &av) in yrow.iter_mut().zip(&acc) {
            *yp += av;
        }
        r += 1;
    }
}

/// AVX2 entry point: safe wrapper that asserts the strip bounds, then
/// calls the `target_feature` body. Only reachable through [`select`],
/// which verified CPUID support.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn fma_tile8_avx2(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
) {
    check_bounds(x, k, m1, kk0, len, tile, tile_stride, y, ldy, col0);
    // SAFETY: `select` gated this path on the AVX2+FMA CPUID probe, and
    // `check_bounds` proved every pointer offset below in range.
    unsafe { fma_tile8_avx2_body(x, k, m0, m1, kk0, len, tile, tile_stride, y, ldy, col0) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn fma_tile8_avx2_body(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
) {
    use std::arch::x86_64::*;
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut r = m0;
    while r + MR <= m1 {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut tp = tile.as_ptr();
        let xbase = xp.add(r * k + kk0);
        for kk in 0..len {
            let trow = _mm256_loadu_ps(tp);
            tp = tp.add(tile_stride);
            acc0 = _mm256_fmadd_ps(_mm256_set1_ps(*xbase.add(kk)), trow, acc0);
            acc1 = _mm256_fmadd_ps(_mm256_set1_ps(*xbase.add(k + kk)), trow, acc1);
            acc2 = _mm256_fmadd_ps(_mm256_set1_ps(*xbase.add(2 * k + kk)), trow, acc2);
            acc3 = _mm256_fmadd_ps(_mm256_set1_ps(*xbase.add(3 * k + kk)), trow, acc3);
        }
        for (i, acc) in [acc0, acc1, acc2, acc3].into_iter().enumerate() {
            let yrow = yp.add((r + i) * ldy + col0);
            _mm256_storeu_ps(yrow, _mm256_add_ps(_mm256_loadu_ps(yrow), acc));
        }
        r += MR;
    }
    while r < m1 {
        let mut acc = _mm256_setzero_ps();
        let mut tp = tile.as_ptr();
        let xbase = xp.add(r * k + kk0);
        for kk in 0..len {
            let trow = _mm256_loadu_ps(tp);
            tp = tp.add(tile_stride);
            acc = _mm256_fmadd_ps(_mm256_set1_ps(*xbase.add(kk)), trow, acc);
        }
        let yrow = yp.add(r * ldy + col0);
        _mm256_storeu_ps(yrow, _mm256_add_ps(_mm256_loadu_ps(yrow), acc));
        r += 1;
    }
}

/// NEON entry point (aarch64 mandates NEON, so no runtime probe).
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)]
fn fma_tile8_neon(
    x: &[f32],
    k: usize,
    m0: usize,
    m1: usize,
    kk0: usize,
    len: usize,
    tile: &[f32],
    tile_stride: usize,
    y: &mut [f32],
    ldy: usize,
    col0: usize,
) {
    check_bounds(x, k, m1, kk0, len, tile, tile_stride, y, ldy, col0);
    // SAFETY: NEON is a baseline aarch64 feature and `check_bounds`
    // proved every pointer offset below in range.
    unsafe {
        use std::arch::aarch64::*;
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut r = m0;
        while r < m1 {
            let rows = (m1 - r).min(MR);
            let mut lo = [vdupq_n_f32(0.0); MR];
            let mut hi = [vdupq_n_f32(0.0); MR];
            let mut tp = tile.as_ptr();
            let xbase = xp.add(r * k + kk0);
            for kk in 0..len {
                let tlo = vld1q_f32(tp);
                let thi = vld1q_f32(tp.add(4));
                tp = tp.add(tile_stride);
                for i in 0..rows {
                    let xv = *xbase.add(i * k + kk);
                    lo[i] = vfmaq_n_f32(lo[i], tlo, xv);
                    hi[i] = vfmaq_n_f32(hi[i], thi, xv);
                }
            }
            for i in 0..rows {
                let yrow = yp.add((r + i) * ldy + col0);
                vst1q_f32(yrow, vaddq_f32(vld1q_f32(yrow), lo[i]));
                vst1q_f32(yrow.add(4), vaddq_f32(vld1q_f32(yrow.add(4)), hi[i]));
            }
            r += rows;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, default_cases};

    fn reference(
        x: &[f32],
        k: usize,
        m: usize,
        tile: &[f32],
        stride: usize,
        len: usize,
    ) -> Vec<f32> {
        let mut y = vec![0f32; m * NR];
        for r in 0..m {
            for kk in 0..len {
                for p in 0..NR {
                    y[r * NR + p] += x[r * k + kk] * tile[kk * stride + p];
                }
            }
        }
        y
    }

    #[test]
    fn matches_reference_including_remainder_rows() {
        // m = 7 exercises one full MR strip plus a 3-row remainder.
        let (m, k, len) = (7usize, 24usize, 24usize);
        let x: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
        let tile: Vec<f32> = (0..len * NR).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
        let mut y = vec![0f32; m * NR];
        fma_tile8_scalar(&x, k, 0, m, 0, len, &tile, NR, &mut y, NR, 0);
        assert_eq!(y, reference(&x, k, m, &tile, NR, len));
    }

    #[test]
    fn strided_tile_and_offset_output() {
        // Tile embedded in a wider panel (stride 24), output written into
        // a wider y at col0 = 8, rows 2..5 only, reduction offset kk0 = 8.
        let (k, len, stride, ldy) = (32usize, 16usize, 24usize, 32usize);
        let x: Vec<f32> = (0..6 * k).map(|i| ((i * 5) % 11) as f32 - 5.0).collect();
        let panel: Vec<f32> = (0..len * stride).map(|i| ((i * 3) % 17) as f32 * 0.125).collect();
        let mut y = vec![1.0f32; 6 * ldy]; // pre-filled: microkernel accumulates
        fma_tile8_scalar(&x, k, 2, 5, 8, len, &panel, stride, &mut y, ldy, 8);
        for r in 0..6 {
            for c in 0..ldy {
                let mut want = 1.0f32;
                if (2..5).contains(&r) && (8..16).contains(&c) {
                    for kk in 0..len {
                        want += x[r * k + 8 + kk] * panel[kk * stride + (c - 8)];
                    }
                }
                let got = y[r * ldy + c];
                let tol = 1e-4 * want.abs().max(1.0);
                assert!((got - want).abs() <= tol, "r={r} c={c}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn simd_level_reports_a_known_tier() {
        assert!(["avx2", "neon", "scalar"].contains(&simd_level()));
        // Both selections must be callable whatever the host supports.
        let (m, k, len) = (3usize, 16usize, 16usize);
        let x = vec![1.0f32; m * k];
        let tile = vec![0.5f32; len * NR];
        for simd in [false, true] {
            let mut y = vec![0f32; m * NR];
            select(simd)(&x, k, 0, m, 0, len, &tile, NR, &mut y, NR, 0);
            for &v in &y {
                assert!((v - 8.0).abs() < 1e-4, "simd={simd}: {v}");
            }
        }
    }

    #[test]
    fn prop_simd_matches_scalar_over_random_shapes_and_strides() {
        // The SIMD-vs-scalar equivalence property at the microkernel
        // level: random (m, k-strip, stride, offsets), both variants on
        // identical inputs, 1e-6 relative (FMA rounds once per
        // multiply-add where the scalar path rounds twice).
        let simd = select(true);
        check("fma-tile8-simd-vs-scalar", 0x51D0, default_cases(), |rng| {
            let m = rng.range_usize(1, 9);
            let k = rng.range_usize(8, 96);
            let len = rng.range_usize(1, k.min(64));
            let kk0 = rng.range_usize(0, k - len);
            let stride = NR + rng.range_usize(0, 24);
            let ldy = NR + rng.range_usize(0, 16);
            let col0 = rng.range_usize(0, ldy - NR);
            let x: Vec<f32> = (0..m * k).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let tile: Vec<f32> =
                (0..len * stride).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let mut y_scalar = vec![0.5f32; m * ldy];
            let mut y_simd = y_scalar.clone();
            fma_tile8_scalar(&x, k, 0, m, kk0, len, &tile, stride, &mut y_scalar, ldy, col0);
            simd(&x, k, 0, m, kk0, len, &tile, stride, &mut y_simd, ldy, col0);
            for (i, (&a, &b)) in y_scalar.iter().zip(&y_simd).enumerate() {
                let tol = 1e-6 * a.abs().max(b.abs()).max(1.0);
                assert!(
                    (a - b).abs() as f64 <= tol as f64,
                    "m={m} k={k} len={len} stride={stride} idx={i}: scalar {a} vs simd {b}"
                );
            }
        });
    }
}
