//! Native W4A16-style fused dequant-GEMM backends — the paper's kernel
//! pair, executable on this machine's silicon.
//!
//! `gpusim` *prices* the write-back effect analytically; this module
//! *runs* it. Two GEMM paths share one blocking scheme
//! ([`Blocking`]), one thread partitioner, and one `4 x 8` register
//! microkernel, and differ only in how dequantized weights reach the
//! FMA units:
//!
//! ```text
//!              interleaved stream (pack_quick)        AWQ words (pack_awq)
//!                        |                                   |
//!   fused:    decode kc x 8 fragment panel        write-back: dequantize the
//!             in-register, tile order, no         whole kc x nc tile into a
//!             runtime permutation (8 KiB,         scratch buffer (16x larger,
//!             L1-hot — the register file's        runtime FT-order scatter —
//!             CPU stand-in)                       the smem staging round-trip)
//!                        |                                   |
//!                  microkernel FMA                     microkernel FMA
//!                  (operands L1-hot)                  (operands via scratch)
//! ```
//!
//! [`gemm_quick_fused`] is the CPU analogue of the paper's direct
//! DRAM→register weight path (§3.1–3.2): the offline interleave means the
//! decode emits values already in microkernel tile order, so nothing is
//! permuted at runtime and the staged panel is an order of magnitude
//! smaller and nearer than the baseline's. [`gemm_awq_writeback`] reproduces the
//! baseline's dequant→staging-buffer→GEMM structure, including the
//! runtime `FT_ORDER` unscramble. The measured gap between them is the
//! mechanism of the paper's Figures 2/7, in real numbers (`bench
//! kernels`, `figures::kernel_matmul`), and feeds the
//! [`crate::gpusim::kernel_model::calibrate_writeback`] hook so the
//! simulation layer can be calibrated from measured rather than modeled
//! tile costs.
//!
//! Since PR 5 the module is a *runtime*, not just a kernel pair:
//!
//! * the microkernel and nibble decoders are explicitly SIMD (AVX2 on
//!   x86_64, NEON on aarch64, scalar fallback — [`Blocking::simd`]),
//! * worker tiles dispatch through a persistent condvar-parked
//!   [`WorkerPool`] with work stealing over column panels, replacing the
//!   spawn-per-call scoped threads that dominated decode-shape latency
//!   ([`Blocking::pool`] reverts, for the bench comparison),
//! * a per-(shape, blocking) [`PlanCache`] keeps panel ranges, fragment
//!   run-offset tables, and decode/staging scratch resident, so a
//!   repeated-shape call — every decode step — allocates nothing,
//! * [`StepExecutor`] runs a whole [`crate::model::LlmSpec`] decode step
//!   (or one tensor-parallel rank's share) through any backend and
//!   reports measured end-to-end tokens/sec (`simulate step`), the
//!   number [`crate::gpusim::calibrate_step_writeback`] fits the GPU
//!   model against.
//!
//! Since PR 7 this runtime also backs the *serving* path end to end: the
//! `--measured` twins of `simulate continuous` / `simulate tp` hand every
//! scheduler step's mixed chunked-prefill/decode batch to a
//! [`StepExecutor`] per TP rank (`coordinator::measured`), so the plan
//! cache sees the serving-path batch sizes — not just decode shapes — and
//! the pool takes concurrent submissions from rank threads.

mod attention;
mod blocking;
mod executor;
mod fused;
mod microkernel;
pub(crate) mod partition;
mod plan;
mod pool;
mod writeback;

pub use attention::{attn_dense_tiled, attn_quant_fused, naive_attention, AttnConfig};
pub use blocking::Blocking;
pub use executor::{StepBackend, StepExecutor, StepGemm, StepResult};
pub use fused::{gemm_quick_fused, gemm_quick_fused_planned, QuickWeights};
pub use microkernel::{simd_level, MR, NR};
pub use plan::{ColPanel, GemmPlan, PlanCache};
pub use pool::WorkerPool;
pub use writeback::{gemm_awq_writeback, gemm_awq_writeback_planned, AwqWeights};

use crate::quant::{dequantize_into, QuantizedTensor};

/// One prepared W4A16 GEMM layer: weights in some backend-specific layout,
/// activations in, f32 out.
pub trait KernelBackend: Send + Sync {
    /// Short display name (bench rows, JSON records).
    fn name(&self) -> &'static str;
    /// `(k, n)` — in-features (reduction) and out-features.
    fn dims(&self) -> (usize, usize);
    /// Compute `y(m, n) = x(m, k) @ w(k, n)`, overwriting `y`.
    ///
    /// # Panics
    ///
    /// Panics unless `x.len() == m * k` and `y.len() == m * n`.
    fn gemm(&self, x: &[f32], m: usize, y: &mut [f32]);
}

/// Reference backend: full `quant::dequantize` + a plain triple-loop GEMM
/// with f64 accumulation (essentially exact at these reductions). The
/// ground truth both optimized paths are differential-tested against —
/// f64 accumulators keep the reference's own rounding error out of the
/// 1e-4 gate even at K = 4096.
pub struct NaiveBackend {
    w: Vec<f32>,
    k: usize,
    n: usize,
}

impl NaiveBackend {
    /// Dequantize `t` once (into an owned buffer) and keep the dense f32
    /// weights for reference GEMMs.
    pub fn from_quantized(t: &QuantizedTensor) -> Self {
        let mut w = vec![0f32; t.k * t.n];
        dequantize_into(t, &mut w);
        NaiveBackend { w, k: t.k, n: t.n }
    }
}

impl KernelBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    fn gemm(&self, x: &[f32], m: usize, y: &mut [f32]) {
        assert_eq!(x.len(), m * self.k, "x buffer size");
        assert_eq!(y.len(), m * self.n, "y buffer size");
        let mut acc = vec![0f64; self.n];
        for r in 0..m {
            acc.fill(0.0);
            for (kk, &xv) in x[r * self.k..(r + 1) * self.k].iter().enumerate() {
                let xv = xv as f64;
                let wrow = &self.w[kk * self.n..(kk + 1) * self.n];
                for (av, &wv) in acc.iter_mut().zip(wrow) {
                    *av += xv * wv as f64;
                }
            }
            let yrow = &mut y[r * self.n..(r + 1) * self.n];
            for (yv, &av) in yrow.iter_mut().zip(&acc) {
                *yv = av as f32;
            }
        }
    }
}

/// [`gemm_quick_fused`] behind the [`KernelBackend`] trait.
pub struct QuickFusedBackend {
    /// Interleaved weights.
    pub weights: QuickWeights,
    /// Blocking/threading configuration.
    pub blocking: Blocking,
}

impl QuickFusedBackend {
    /// Pack `t` into the QUICK layout with the given blocking.
    pub fn new(t: &QuantizedTensor, blocking: Blocking) -> Self {
        QuickFusedBackend { weights: QuickWeights::from_quantized(t), blocking }
    }
}

impl KernelBackend for QuickFusedBackend {
    fn name(&self) -> &'static str {
        "fused"
    }

    fn dims(&self) -> (usize, usize) {
        (self.weights.k, self.weights.n)
    }

    fn gemm(&self, x: &[f32], m: usize, y: &mut [f32]) {
        gemm_quick_fused(x, m, &self.weights, &self.blocking, y)
            .unwrap_or_else(|e| panic!("gemm_quick_fused: {e}"));
    }
}

/// [`gemm_awq_writeback`] behind the [`KernelBackend`] trait.
pub struct AwqWritebackBackend {
    /// Stock-AWQ-layout weights.
    pub weights: AwqWeights,
    /// Blocking/threading configuration.
    pub blocking: Blocking,
}

impl AwqWritebackBackend {
    /// Pack `t` into the stock AWQ layout with the given blocking.
    pub fn new(t: &QuantizedTensor, blocking: Blocking) -> Self {
        AwqWritebackBackend { weights: AwqWeights::from_quantized(t), blocking }
    }
}

impl KernelBackend for AwqWritebackBackend {
    fn name(&self) -> &'static str {
        "writeback"
    }

    fn dims(&self) -> (usize, usize) {
        (self.weights.k, self.weights.n)
    }

    fn gemm(&self, x: &[f32], m: usize, y: &mut [f32]) {
        gemm_awq_writeback(x, m, &self.weights, &self.blocking, y)
            .unwrap_or_else(|e| panic!("gemm_awq_writeback: {e}"));
    }
}

/// Largest element-wise relative error between two result buffers
/// (`|a-b| / max(|a|, |b|, 1)` — absolute near zero, relative at scale).
pub fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let diff = (x - y).abs() as f64;
            let scale = x.abs().max(y.abs()).max(1.0) as f64;
            diff / scale
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, quantize_groupwise};
    use crate::util::Rng;

    #[test]
    fn naive_backend_matches_dequantize_plus_gemm() {
        let (k, n, g, m) = (64, 24, 32, 3);
        let mut rng = Rng::seed_from_u64(5);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let t = quantize_groupwise(&w, k, n, g);
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        // Hand-rolled reference straight off quant::dequantize (f64
        // accumulation, same order as the backend — near bit-equal).
        let dq = dequantize(&t);
        let mut want64 = vec![0f64; m * n];
        for r in 0..m {
            for kk in 0..k {
                for c in 0..n {
                    want64[r * n + c] += x[r * k + kk] as f64 * dq[kk * n + c] as f64;
                }
            }
        }
        let want: Vec<f32> = want64.iter().map(|&v| v as f32).collect();
        let naive = NaiveBackend::from_quantized(&t);
        assert_eq!(naive.dims(), (k, n));
        let mut got = vec![0f32; m * n];
        naive.gemm(&x, m, &mut got);
        assert!(max_rel_err(&got, &want) <= 1e-6);
    }

    #[test]
    fn trait_objects_cover_all_three_backends() {
        let (k, n, g, m) = (48, 32, 16, 4);
        let mut rng = Rng::seed_from_u64(21);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let t = quantize_groupwise(&w, k, n, g);
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let backends: Vec<Box<dyn KernelBackend>> = vec![
            Box::new(NaiveBackend::from_quantized(&t)),
            Box::new(QuickFusedBackend::new(&t, Blocking::default())),
            Box::new(AwqWritebackBackend::new(&t, Blocking::default())),
        ];
        let mut results = Vec::new();
        for b in &backends {
            assert_eq!(b.dims(), (k, n), "{}", b.name());
            let mut y = vec![0f32; m * n];
            b.gemm(&x, m, &mut y);
            results.push(y);
        }
        assert!(max_rel_err(&results[1], &results[0]) <= 1e-4, "fused vs naive");
        assert!(max_rel_err(&results[2], &results[0]) <= 1e-4, "writeback vs naive");
    }

    #[test]
    fn rel_err_metric_behaves() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        // Small absolute deviation near zero is measured absolutely.
        let e = max_rel_err(&[0.0], &[1e-5]);
        assert!((e - 1e-5).abs() < 1e-12);
        // At scale, it is relative.
        let e = max_rel_err(&[100.0], &[101.0]);
        assert!((e - 1.0 / 101.0).abs() < 1e-9);
    }
}
