//! Thread-pool partitioner for the native GEMM backends.
//!
//! Work is split along the *word-column* axis (8 logical N columns per
//! word), mirroring how the interleaved stream is naturally strided: each
//! worker owns a contiguous range of word-columns, so it reads disjoint
//! stream/word regions and produces disjoint output columns. Workers
//! accumulate into private column-panel buffers which the caller's thread
//! scatters back into the row-major output after the join — an `O(m*n)`
//! copy that is negligible against the `O(m*n*k)` GEMM and keeps the whole
//! path safe Rust (no shared mutable output).

use std::ops::Range;

use crate::quant::PACK_FACTOR;

/// Split `total` items into at most `parts` contiguous ranges of
/// near-equal size (larger ranges first; no empty ranges).
pub(crate) fn split_ranges(total: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, total.max(1));
    let (base, extra) = (total / parts, total % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `work` over the `n / 8` word-columns of an `m x n` GEMM output,
/// split across `threads` workers.
///
/// `work(wr, out, ldy, out_col0)` must accumulate the output columns
/// `wr.start*8 .. wr.end*8` into `out`, where element `(row, col)` lives
/// at `out[row * ldy + (col - out_col0)]`. Single-threaded calls receive
/// `y` itself (`ldy = n`, `out_col0 = 0`); workers receive a private
/// zeroed panel that is scattered into `y` after the join.
pub(crate) fn gemm_over_columns(
    m: usize,
    n: usize,
    threads: usize,
    y: &mut [f32],
    work: &(impl Fn(Range<usize>, &mut [f32], usize, usize) + Sync),
) {
    let w_total = n / PACK_FACTOR;
    let parts = split_ranges(w_total, threads);
    if parts.len() <= 1 {
        work(0..w_total, y, n, 0);
        return;
    }
    let panels: Vec<(Range<usize>, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = parts
            .into_iter()
            .map(|wr| {
                s.spawn(move || {
                    let cols = (wr.end - wr.start) * PACK_FACTOR;
                    let mut panel = vec![0f32; m * cols];
                    work(wr.clone(), &mut panel, cols, wr.start * PACK_FACTOR);
                    (wr, panel)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("kernel worker panicked")).collect()
    });
    for (wr, panel) in panels {
        let (c0, cols) = (wr.start * PACK_FACTOR, (wr.end - wr.start) * PACK_FACTOR);
        for row in 0..m {
            y[row * n + c0..row * n + c0 + cols]
                .copy_from_slice(&panel[row * cols..(row + 1) * cols]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_disjointly() {
        for (total, parts) in [(7usize, 3usize), (8, 2), (3, 8), (1, 1), (16, 5)] {
            let ranges = split_ranges(total, parts);
            assert!(ranges.len() <= parts && !ranges.iter().any(|r| r.is_empty()));
            let mut covered = 0;
            for r in &ranges {
                assert_eq!(r.start, covered, "contiguous");
                covered = r.end;
            }
            assert_eq!(covered, total);
        }
    }

    fn fill_by_column(wr: Range<usize>, out: &mut [f32], ldy: usize, c0: usize, m: usize) {
        for row in 0..m {
            for wj in wr.clone() {
                for p in 0..PACK_FACTOR {
                    let col = wj * PACK_FACTOR + p;
                    out[row * ldy + (col - c0)] += (row * 1000 + col) as f32;
                }
            }
        }
    }

    #[test]
    fn partitioned_run_equals_single_thread() {
        let (m, n) = (5usize, 48usize);
        let mut single = vec![0f32; m * n];
        gemm_over_columns(m, n, 1, &mut single, &|wr, out: &mut [f32], ldy, c0| {
            fill_by_column(wr, out, ldy, c0, m)
        });
        for threads in [2usize, 3, 16] {
            let mut multi = vec![0f32; m * n];
            gemm_over_columns(m, n, threads, &mut multi, &|wr, out: &mut [f32], ldy, c0| {
                fill_by_column(wr, out, ldy, c0, m)
            });
            assert_eq!(multi, single, "threads={threads}");
        }
    }
}
