//! Spawn-per-call task dispatcher — PR 4's threading model, kept as the
//! measured baseline the persistent [`super::WorkerPool`] is benchmarked
//! against (`bench kernels --decode-sweep`, pool-vs-spawn rows).
//!
//! Work units are the same column-panel tiles the pool steals; the only
//! difference is the dispatch cost: this path pays a fresh
//! `std::thread::scope` spawn/join round-trip on every GEMM call, which
//! at decode shapes (M = 1–8) is material against the arithmetic. Each
//! spawned worker owns a contiguous *static* slice of the tile list (no
//! stealing — a straggler idles its peers), mirroring the PR 4 behavior
//! the decode-sweep rows quantify.

use std::ops::Range;

/// Split `total` items into at most `parts` contiguous ranges of
/// near-equal size (larger ranges first; no empty ranges).
pub(crate) fn split_ranges(total: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, total.max(1));
    let (base, extra) = (total / parts, total % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `body(task, slot)` for every `task in 0..tasks` across freshly
/// spawned scoped threads (at most `threads`), blocking until all
/// finish. Same contract as [`super::WorkerPool::run`]; the slot is the
/// spawned worker's index, so per-slot scratch keeps working.
pub(crate) fn spawn_run(tasks: usize, threads: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    if tasks == 0 {
        return;
    }
    let parts = split_ranges(tasks, threads);
    if parts.len() <= 1 {
        for t in 0..tasks {
            body(t, 0);
        }
        return;
    }
    std::thread::scope(|s| {
        for (slot, range) in parts.into_iter().enumerate() {
            s.spawn(move || {
                for t in range {
                    body(t, slot);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_covers_disjointly() {
        for (total, parts) in [(7usize, 3usize), (8, 2), (3, 8), (1, 1), (16, 5)] {
            let ranges = split_ranges(total, parts);
            assert!(ranges.len() <= parts && !ranges.iter().any(|r| r.is_empty()));
            let mut covered = 0;
            for r in &ranges {
                assert_eq!(r.start, covered, "contiguous");
                covered = r.end;
            }
            assert_eq!(covered, total);
        }
    }

    #[test]
    fn spawn_run_covers_every_task_once() {
        for (tasks, threads) in [(1usize, 4usize), (7, 3), (16, 2), (5, 8)] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            let max_slot = AtomicUsize::new(0);
            spawn_run(tasks, threads, &|t, slot| {
                hits[t].fetch_add(1, Ordering::Relaxed);
                max_slot.fetch_max(slot, Ordering::Relaxed);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} ({tasks}/{threads})");
            }
            assert!(max_slot.load(Ordering::Relaxed) < threads);
        }
    }
}
