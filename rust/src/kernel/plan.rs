//! Per-(shape, blocking) execution plans for the native GEMM paths.
//!
//! A decode step runs the *same* handful of GEMM shapes every token; PR 4
//! recomputed panel ranges and allocated fragment/scratch/output buffers
//! on every call. A [`GemmPlan`] hoists everything shape-dependent out of
//! the hot path:
//!
//! * the **column-panel tiles** the work-stealing partitioner hands out
//!   (one per `nc_words` word-columns),
//! * the **`quick_run_offset` table** — the stream offset of every
//!   (K-tile, word-column) fragment run, so the fused decode loop does a
//!   table read instead of re-deriving the interleave arithmetic,
//! * **per-slot scratch** (the fused fragment panel / write-back staging
//!   tile, one per participant) and **per-tile output panels** (the
//!   private accumulation buffers the scatter drains), both kept
//!   resident so repeated same-shape calls allocate *nothing* — verified
//!   by the hot-path bench's counting allocator.
//!
//! [`PlanCache`] memoizes plans process-wide (keyed by `(m, k, n,
//! Blocking)`), mirroring `quant::ldmatrix_fragment_perm_memo`: the first
//! call per shape builds, every later call — every subsequent decode
//! step — is a map hit.
//!
//! The measured serving twins (`simulate continuous --measured`) widened
//! the M population the cache serves: the continuous scheduler executes
//! steps at its *actual* mixed chunked-prefill/decode token counts, so
//! alongside the handful of decode shapes the cache now memoizes one
//! plan per distinct step batch the serving policy produces (bounded by
//! its token budget).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::obs::{Counter, Registry};
use crate::quant::decode::TILE_ROWS;
use crate::quant::{quick_run_offset, PACK_FACTOR};

use super::blocking::Blocking;
use super::partition;
use super::pool::WorkerPool;

/// One work-stealing tile: a contiguous panel of word-columns
/// `[wj0, wj1)` (8 logical output columns per word-column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColPanel {
    /// First word-column of the panel.
    pub wj0: usize,
    /// One past the last word-column.
    pub wj1: usize,
}

impl ColPanel {
    /// Word-columns in the panel.
    pub fn words(&self) -> usize {
        self.wj1 - self.wj0
    }

    /// Logical output columns in the panel.
    pub fn cols(&self) -> usize {
        self.words() * PACK_FACTOR
    }

    /// First logical output column.
    pub fn col0(&self) -> usize {
        self.wj0 * PACK_FACTOR
    }
}

/// Task body the GEMM drivers hand to [`GemmPlan::execute`]:
/// `(panel, out, ldy, out_col0, scratch)` — accumulate the panel's output
/// columns into `out`, where element `(row, col)` lives at
/// `out[row * ldy + (col - out_col0)]`, using `scratch` (at least
/// [`Blocking::scratch_len`] f32) as decode/staging space.
pub(crate) type TaskBody<'a> = dyn Fn(&ColPanel, &mut [f32], usize, usize, &mut [f32]) + Sync + 'a;

/// A reusable execution plan for one `(m, k, n, blocking)` GEMM shape.
pub struct GemmPlan {
    /// Activation rows (batch) this plan was built for.
    pub m: usize,
    /// Reduction dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// The blocking the plan was built from.
    pub blocking: Blocking,
    /// Resolved participant count ([`Blocking::resolve_threads`]).
    pub threads: usize,
    /// The column-panel tiles work is stolen over.
    pub tasks: Vec<ColPanel>,
    /// `run_offsets[kt * w_total + wj]` = stream word offset of fragment
    /// run `(kt, wj)` — the precomputed [`quick_run_offset`] table. The
    /// table depends only on `(k, n)`, so [`PlanCache`] shares one copy
    /// across every (m, blocking) plan of the same weight shape.
    run_offsets: Arc<Vec<usize>>,
    w_total: usize,
    /// Per-slot decode/staging scratch ([`Blocking::scratch_len`] each).
    scratch: Vec<Mutex<Vec<f32>>>,
    /// Per-tile private output panels (`m * cols` each); empty when the
    /// plan executes single-threaded straight into `y`.
    panels: Vec<Mutex<Vec<f32>>>,
    /// Serializes parallel executions of this plan: the shared panels
    /// are a per-call invariant (zero → accumulate → scatter), so two
    /// concurrent same-shape GEMMs must take turns. Held through the
    /// scatter — the pool's own submit lock releases before that copy.
    exec: Mutex<()>,
}

impl GemmPlan {
    /// The `(k, n)`-only [`quick_run_offset`] table (one entry per
    /// fragment run), shared across plans by [`PlanCache`].
    fn offset_table(k: usize, n: usize) -> Vec<usize> {
        let w_total = n / PACK_FACTOR;
        let kt_total = k / TILE_ROWS;
        let mut run_offsets = Vec::with_capacity(kt_total * w_total);
        for kt in 0..kt_total {
            for wj in 0..w_total {
                run_offsets.push(quick_run_offset(kt, wj, w_total));
            }
        }
        run_offsets
    }

    fn build(m: usize, k: usize, n: usize, blocking: Blocking) -> GemmPlan {
        Self::build_with_offsets(m, k, n, blocking, Arc::new(Self::offset_table(k, n)))
    }

    fn build_with_offsets(
        m: usize,
        k: usize,
        n: usize,
        blocking: Blocking,
        run_offsets: Arc<Vec<usize>>,
    ) -> GemmPlan {
        let w_total = n / PACK_FACTOR;
        debug_assert_eq!(run_offsets.len(), (k / TILE_ROWS) * w_total);
        let threads = blocking.resolve_threads(m, k, n);
        let mut tasks = Vec::with_capacity(blocking.n_tiles(n));
        let mut wj0 = 0;
        while wj0 < w_total {
            let wj1 = (wj0 + blocking.nc_words).min(w_total);
            tasks.push(ColPanel { wj0, wj1 });
            wj0 = wj1;
        }
        let multi = threads > 1 && tasks.len() > 1;
        let slots = if multi { threads } else { 1 };
        let scratch = (0..slots).map(|_| Mutex::new(vec![0f32; blocking.scratch_len()])).collect();
        let panels = if multi {
            tasks.iter().map(|t| Mutex::new(vec![0f32; m * t.cols()])).collect()
        } else {
            Vec::new()
        };
        GemmPlan {
            m,
            k,
            n,
            blocking,
            threads,
            tasks,
            run_offsets,
            w_total,
            scratch,
            panels,
            exec: Mutex::new(()),
        }
    }

    /// Stream word offset of fragment run `(kt, wj)` (table lookup; the
    /// closed form lives in [`quick_run_offset`]).
    #[inline]
    pub fn run_offset(&self, kt: usize, wj: usize) -> usize {
        self.run_offsets[kt * self.w_total + wj]
    }

    /// True when this plan dispatches tiles across threads (vs running
    /// the whole GEMM inline on the caller).
    pub fn is_parallel(&self) -> bool {
        !self.panels.is_empty()
    }

    /// Run `work` over every column-panel tile, overwriting `y` with the
    /// accumulated result.
    ///
    /// Single-threaded plans run every tile inline, straight into `y`.
    /// Parallel plans dispatch tiles through the persistent
    /// [`WorkerPool`] (or PR 4-style spawned scoped threads when
    /// [`Blocking::pool`] is off), each tile accumulating into its
    /// resident private panel; the caller's thread then scatters the
    /// panels back into row-major `y` — an `O(m*n)` copy, negligible
    /// against the `O(m*n*k)` GEMM.
    pub(crate) fn execute(&self, y: &mut [f32], work: &TaskBody<'_>) {
        debug_assert_eq!(y.len(), self.m * self.n);
        if !self.is_parallel() {
            y.fill(0.0);
            let mut scratch = lock_ignore_poison(&self.scratch[0]);
            for task in &self.tasks {
                work(task, y, self.n, 0, &mut scratch);
            }
            return;
        }
        // Two concurrent same-shape calls resolve to this same cached
        // plan; the panels implement a per-call zero→accumulate→scatter
        // protocol, so executions must not interleave. (Tradeoff: truly
        // concurrent same-shape GEMMs serialize here — acceptable while
        // the engine issues its GEMM stream sequentially; revisit with
        // pooled per-call panels if that changes.)
        let _exclusive = lock_ignore_poison(&self.exec);
        let body = |ti: usize, slot: usize| {
            let task = &self.tasks[ti];
            let mut panel = lock_ignore_poison(&self.panels[ti]);
            panel.fill(0.0);
            let mut scratch = lock_ignore_poison(&self.scratch[slot]);
            work(task, &mut panel, task.cols(), task.col0(), &mut scratch);
        };
        if self.blocking.pool {
            WorkerPool::global().run(self.tasks.len(), self.threads, &body);
        } else {
            partition::spawn_run(self.tasks.len(), self.threads, &body);
        }
        for (ti, task) in self.tasks.iter().enumerate() {
            let panel = lock_ignore_poison(&self.panels[ti]);
            let (c0, cols) = (task.col0(), task.cols());
            for row in 0..self.m {
                y[row * self.n + c0..row * self.n + c0 + cols]
                    .copy_from_slice(&panel[row * cols..(row + 1) * cols]);
            }
        }
    }
}

/// Lock that shrugs off poisoning: every buffer behind these mutexes is
/// re-zeroed or fully overwritten before use, so a panicked predecessor
/// leaves nothing worth invalidating a long-lived cached plan over (a
/// poisoned panel would otherwise brick its shape forever — the caller
/// already saw the original panic via the pool's scope-join re-raise).
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Registry handles for the plan cache's hit/miss counters, resolved
/// once; the steady-state hit path adds one relaxed atomic increment.
struct CacheMetrics {
    hits: Counter,
    misses: Counter,
}

fn cache_metrics() -> &'static CacheMetrics {
    static METRICS: OnceLock<CacheMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        CacheMetrics { hits: r.counter("plan_cache.hits"), misses: r.counter("plan_cache.misses") }
    })
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    m: usize,
    k: usize,
    n: usize,
    b: Blocking,
}

/// Process-wide memo of [`GemmPlan`]s, keyed by `(m, k, n, blocking)`.
///
/// There is no eviction: every distinct key keeps its panels/scratch
/// resident (order `m * n` f32 per parallel plan), which is exactly what
/// a decode loop over a fixed shape set wants. Callers sweeping many
/// transient shapes (bench harnesses, engines with unbounded mixed batch
/// sizes) should bucket M to a small set of plan sizes or call
/// [`PlanCache::clear`] between phases.
#[derive(Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<GemmPlan>>>,
    /// Shared `(k, n)` -> run-offset tables (shape-only, so one copy
    /// serves every m/blocking variant of a weight matrix).
    offsets: Mutex<HashMap<(usize, usize), Arc<Vec<usize>>>>,
}

impl PlanCache {
    /// An empty cache (tests; production code shares [`PlanCache::global`]).
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The process-wide cache every `gemm_quick_fused` /
    /// `gemm_awq_writeback` call resolves plans through.
    pub fn global() -> &'static PlanCache {
        static CACHE: OnceLock<PlanCache> = OnceLock::new();
        CACHE.get_or_init(PlanCache::new)
    }

    /// Fetch (or build and memoize) the plan for an `m x k x n` GEMM
    /// under `b`. Errors on the [`Blocking::validate`] shape contract.
    pub fn plan(&self, m: usize, k: usize, n: usize, b: &Blocking) -> Result<Arc<GemmPlan>> {
        b.validate(k, n)?;
        anyhow::ensure!(m > 0, "M must be > 0");
        let key = PlanKey { m, k, n, b: *b };
        if let Some(plan) = self.plans.lock().unwrap().get(&key) {
            cache_metrics().hits.inc();
            return Ok(Arc::clone(plan));
        }
        cache_metrics().misses.inc();
        let offsets = {
            let mut map = self.offsets.lock().unwrap();
            let entry =
                map.entry((k, n)).or_insert_with(|| Arc::new(GemmPlan::offset_table(k, n)));
            Arc::clone(entry)
        };
        // Build outside the plans lock (plans can be MBs); a racing
        // builder just loses its copy to the first insert.
        let built = Arc::new(GemmPlan::build_with_offsets(m, k, n, *b, offsets));
        let mut map = self.plans.lock().unwrap();
        Ok(Arc::clone(map.entry(key).or_insert(built)))
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// True when no plan has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan and shared offset table (tests / memory
    /// pressure).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
        self.offsets.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiles_cover_all_word_columns_disjointly() {
        for (n, nc) in [(128usize, 16usize), (4096, 16), (48, 1), (64, 5)] {
            let b = Blocking { nc_words: nc, ..Blocking::default() };
            let plan = GemmPlan::build(4, 64, n, b);
            let mut next = 0;
            for t in &plan.tasks {
                assert_eq!(t.wj0, next, "contiguous");
                assert!(t.words() >= 1 && t.words() <= nc);
                next = t.wj1;
            }
            assert_eq!(next, n / PACK_FACTOR);
            assert_eq!(plan.tasks.len(), b.n_tiles(n));
        }
    }

    #[test]
    fn run_offset_table_matches_closed_form() {
        let plan = GemmPlan::build(2, 96, 64, Blocking::default());
        let w_total = 64 / PACK_FACTOR;
        for kt in 0..96 / TILE_ROWS {
            for wj in 0..w_total {
                assert_eq!(plan.run_offset(kt, wj), quick_run_offset(kt, wj, w_total));
            }
        }
    }

    #[test]
    fn cache_returns_the_same_plan_for_the_same_key() {
        let cache = PlanCache::new();
        assert!(cache.is_empty());
        let b = Blocking::default();
        let p1 = cache.plan(8, 64, 64, &b).unwrap();
        let p2 = cache.plan(8, 64, 64, &b).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "same key must hit the memo");
        assert_eq!(cache.len(), 1);
        let p3 = cache.plan(9, 64, 64, &b).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3), "m is part of the key");
        assert!(
            Arc::ptr_eq(&p1.run_offsets, &p3.run_offsets),
            "same (k, n) must share one run-offset table"
        );
        let scalar = Blocking { simd: false, ..b };
        let p4 = cache.plan(8, 64, 64, &scalar).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p4), "blocking is part of the key");
        assert_eq!(cache.len(), 3);
        cache.clear();
        assert!(cache.is_empty());
        // Shape violations surface as errors, not cache entries.
        assert!(cache.plan(0, 64, 64, &b).is_err());
        assert!(cache.plan(1, 20, 64, &b).is_err());
        assert!(cache.is_empty());
    }

    #[test]
    fn execute_single_thread_accumulates_into_y() {
        let b = Blocking { threads: 1, nc_words: 2, ..Blocking::default() };
        let (m, k, n) = (3usize, 32usize, 48usize);
        let plan = GemmPlan::build(m, k, n, b);
        assert!(!plan.is_parallel());
        let mut y = vec![f32::NAN; m * n];
        plan.execute(&mut y, &|task, out, ldy, c0, _scratch| {
            for row in 0..m {
                for col in task.col0()..task.col0() + task.cols() {
                    out[row * ldy + (col - c0)] += (row * 1000 + col) as f32;
                }
            }
        });
        for row in 0..m {
            for col in 0..n {
                assert_eq!(y[row * n + col], (row * 1000 + col) as f32);
            }
        }
    }

    #[test]
    fn execute_parallel_matches_single_thread() {
        let (m, k, n) = (5usize, 32usize, 64usize);
        let fill = |task: &ColPanel, out: &mut [f32], ldy: usize, c0: usize, _s: &mut [f32]| {
            for row in 0..m {
                for col in task.col0()..task.col0() + task.cols() {
                    out[row * ldy + (col - c0)] += (row * 100 + col) as f32;
                }
            }
        };
        let single = {
            let plan = GemmPlan::build(m, k, n, Blocking { threads: 1, ..Blocking::default() });
            let mut y = vec![0f32; m * n];
            plan.execute(&mut y, &fill);
            y
        };
        for pool in [true, false] {
            let b = Blocking { threads: 3, nc_words: 1, pool, ..Blocking::default() };
            let plan = GemmPlan::build(m, k, n, b);
            assert!(plan.is_parallel());
            let mut y = vec![f32::NAN; m * n];
            plan.execute(&mut y, &fill);
            assert_eq!(y, single, "pool={pool}");
            // Resident buffers mean a second pass produces the same
            // result (panels re-zeroed per call, not accumulated).
            let mut y2 = vec![0f32; m * n];
            plan.execute(&mut y2, &fill);
            assert_eq!(y2, single, "pool={pool} second pass");
        }
    }
}
