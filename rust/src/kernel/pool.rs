//! Persistent worker pool for the native GEMM backends.
//!
//! PR 4 spawned a fresh `std::thread::scope` per GEMM call — at decode
//! shapes (M = 1–8) the spawn/join round-trip is the dominant per-call
//! cost, paid once per layer per token. This pool spawns its workers
//! **once**, parks them on a condvar, and hands each submitted job out as
//! a list of *tasks* (column-panel tiles) that participants claim from a
//! shared cursor — work stealing at tile granularity, so an uneven panel
//! (or a worker descheduled by the OS) never idles the rest.
//!
//! Design constraints that shaped the implementation:
//!
//! * **Zero steady-state allocation.** Job state lives inline in the
//!   pool (no per-job `Arc`), so a decode step's dozens of GEMM
//!   dispatches allocate nothing — verified by the hot-path bench's
//!   counting allocator.
//! * **Borrowed closures.** The task body borrows the caller's stack
//!   (activations, weights, plan scratch). Its lifetime is erased to
//!   `'static` on submit; soundness holds because every task claim
//!   happens under the pool lock *before* the shared cursor passes
//!   `tasks`, and [`WorkerPool::run`] returns only after the completion
//!   count reaches `tasks` — no worker can reach the closure after `run`
//!   returns.
//! * **Bounded participation.** A job caps its parallelism at `threads`
//!   (the plan's resolved count); surplus workers note the epoch and go
//!   back to sleep instead of contending.
//!
//! One job runs at a time (submissions serialize on a mutex); the
//! caller's thread always participates as slot 0, so a pool with `w`
//! workers yields up to `w + 1`-way parallelism. The measured
//! tensor-parallel serving path leans on exactly this: each TP rank's
//! `StepExecutor` submits from its own thread, the submit mutex
//! interleaves their GEMM jobs, and the resulting group wall time is the
//! ranks-share-one-CPU stand-in `coordinator::measured` reports.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::obs::{trace, Counter, Gauge, Registry};

/// Lock `m`, ignoring poisoning. A panicking task body is caught in
/// [`participate`] and re-raised on the submitting caller
/// ([`WorkerPool::run`]'s scope-join semantics) — but that re-raise
/// unwinds through `run` while the submit guard is still live, which
/// poisons the mutex. Every critical section here leaves the state
/// consistent before any panic can fire (the claim/done protocol never
/// unwinds mid-update), so the poison bit carries no information;
/// honoring it would brick the pool for every job after a caught panic.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait`] with the same poison-ignoring contract as
/// [`lock_ignore_poison`].
fn wait_ignore_poison<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// A task body: `(task_index, slot)` where `slot < threads` identifies
/// the participant (stable per participant within one job — used to
/// index per-slot scratch).
pub type Task<'a> = dyn Fn(usize, usize) + Sync + 'a;

/// Registry handles for the pool's metrics, resolved once — steady-state
/// updates are relaxed atomic ops (see the zero-alloc contract above).
struct PoolMetrics {
    /// Jobs dispatched through the parked workers (inline fast-path
    /// jobs are not counted — no pool machinery runs).
    jobs: Counter,
    /// Tasks (column-panel tiles) executed across all participants.
    tasks_run: Counter,
    /// Tasks claimed by pool workers rather than the submitting thread —
    /// tiles the work-stealing cursor moved off the caller.
    tasks_stolen: Counter,
    /// Condvar park transitions in [`worker_loop`].
    parks: Counter,
    /// Condvar wake-ups in [`worker_loop`] (includes spurious wakes).
    wakes: Counter,
    /// Total participant busy nanoseconds (claim loop entry to drain).
    busy_ns: Counter,
    /// Unclaimed tasks of the in-flight job (0 between jobs).
    queue_depth: Gauge,
}

fn metrics() -> &'static PoolMetrics {
    static METRICS: OnceLock<PoolMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = Registry::global();
        PoolMetrics {
            jobs: r.counter("pool.jobs"),
            tasks_run: r.counter("pool.tasks_run"),
            tasks_stolen: r.counter("pool.tasks_stolen"),
            parks: r.counter("pool.parks"),
            wakes: r.counter("pool.wakes"),
            busy_ns: r.counter("pool.busy_ns"),
            queue_depth: r.gauge("pool.queue_depth"),
        }
    })
}

struct State {
    /// Monotone job counter; workers use it to tell a fresh job from one
    /// they already served (or skipped).
    epoch: u64,
    /// The current job's task body; `None` between jobs.
    body: Option<&'static Task<'static>>,
    /// Tasks in the current job.
    tasks: usize,
    /// Participation cap (slots) of the current job.
    slots: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Completed task count; `run` returns when this reaches `tasks`.
    done: usize,
    /// Participants so far (caller = 1); assigns slot ids.
    joined: usize,
    /// First panic payload a task body raised during the current job;
    /// the submitting caller resumes it after the job drains
    /// (scope-join semantics, original message preserved).
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    /// Set once on drop; workers exit.
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The caller parks here while its job drains.
    done_cv: Condvar,
}

/// The persistent, condvar-parked, work-stealing worker pool.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes job submission (one job at a time).
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked threads. The caller's thread
    /// participates in every job, so `workers = cores - 1` saturates the
    /// machine.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                body: None,
                tasks: 0,
                slots: 0,
                next: 0,
                done: 0,
                joined: 0,
                panic_payload: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("quick-kernel-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn kernel pool worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), handles }
    }

    /// The process-wide pool the GEMM plans dispatch through: spawned on
    /// first use with `available_parallelism - 1` workers, parked when
    /// idle, alive for the process lifetime.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
            WorkerPool::new(cores.saturating_sub(1))
        })
    }

    /// Worker threads parked in this pool (parallelism is `workers + 1`:
    /// the submitting thread always participates).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `body(task, slot)` for every `task in 0..tasks`, with at most
    /// `threads` concurrent participants (the calling thread is always
    /// one of them, as slot 0). Blocks until every task completed.
    ///
    /// Tasks must be independent; `slot` is stable per participant and
    /// `< threads`, so callers may index per-slot scratch with it.
    /// Must not be called from inside a pool task (the nested submission
    /// would deadlock behind its own job).
    pub fn run(&self, tasks: usize, threads: usize, body: &Task<'_>) {
        if tasks == 0 {
            return;
        }
        let slots = threads.min(tasks);
        if slots <= 1 || self.handles.is_empty() {
            for t in 0..tasks {
                body(t, 0);
            }
            return;
        }
        let _submission = lock_ignore_poison(&self.submit);
        let _span =
            trace::span2("pool.run", "pool", "tasks", tasks as f64, "threads", slots as f64);
        metrics().jobs.inc();
        metrics().queue_depth.set(tasks as i64);
        // SAFETY: lifetime erasure only — the pointee outlives this call,
        // and the claim/completion protocol below guarantees no worker
        // dereferences the body after this function returns (claims
        // happen under the state lock while `next < tasks`; we return
        // only once `done == tasks`, i.e. after every claimed task
        // finished).
        let body_static: &'static Task<'static> =
            unsafe { std::mem::transmute::<&Task<'_>, &'static Task<'static>>(body) };
        let epoch = {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.epoch += 1;
            st.body = Some(body_static);
            st.tasks = tasks;
            st.slots = slots;
            st.next = 0;
            st.done = 0;
            st.joined = 1; // the caller holds slot 0
            st.panic_payload = None;
            st.epoch
        };
        self.shared.work_cv.notify_all();
        participate(&self.shared, epoch, body, 0);
        let mut st = lock_ignore_poison(&self.shared.state);
        while st.done < st.tasks {
            st = wait_ignore_poison(&self.shared.done_cv, st);
        }
        st.body = None;
        let payload = st.panic_payload.take();
        drop(st);
        metrics().queue_depth.set(0);
        if let Some(payload) = payload {
            // Scope-join semantics: a panic anywhere in the job resumes
            // on the submitting thread, original payload intact, once
            // every task has drained.
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_ignore_poison(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim-and-run loop shared by the caller (slot 0) and joined workers:
/// steal the next unclaimed task under the lock, run it outside the
/// lock, bump the completion count, wake the caller on the last one. A
/// panicking body is caught and recorded so the job still drains (and a
/// worker thread survives); the caller re-raises it after the join.
fn participate(shared: &Shared, epoch: u64, body: &Task<'_>, slot: usize) {
    let mut span = trace::span1("pool.participate", "pool", "slot", slot as f64);
    let t0 = Instant::now();
    let mut claimed = 0u64;
    loop {
        let t = {
            let mut st = lock_ignore_poison(&shared.state);
            if st.epoch != epoch || st.next >= st.tasks {
                break;
            }
            let t = st.next;
            st.next += 1;
            t
        };
        claimed += 1;
        metrics().queue_depth.add(-1);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(t, slot)));
        let mut st = lock_ignore_poison(&shared.state);
        if st.epoch == epoch {
            if let Err(payload) = outcome {
                st.panic_payload.get_or_insert(payload);
            }
            st.done += 1;
            if st.done >= st.tasks {
                shared.done_cv.notify_all();
            }
        }
    }
    let m = metrics();
    m.busy_ns.add(t0.elapsed().as_nanos() as u64);
    m.tasks_run.add(claimed);
    if slot != 0 {
        m.tasks_stolen.add(claimed);
    }
    span.arg("claimed", claimed as f64);
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let (epoch, body, slot) = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(body) = st.body {
                    if st.epoch != last_epoch {
                        if st.joined < st.slots && st.next < st.tasks {
                            let slot = st.joined;
                            st.joined += 1;
                            break (st.epoch, body, slot);
                        }
                        // Job saturated (or already drained): note the
                        // epoch so the next wake-up does not re-examine
                        // it, then park again.
                        last_epoch = st.epoch;
                    }
                }
                metrics().parks.inc();
                st = wait_ignore_poison(&shared.work_cv, st);
                metrics().wakes.inc();
            }
        };
        last_epoch = epoch;
        participate(shared, epoch, body, slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        for tasks in [1usize, 2, 7, 64] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, 4, &|t, _slot| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (t, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {t} of {tasks}");
            }
        }
    }

    #[test]
    fn slots_stay_below_thread_cap() {
        let pool = WorkerPool::new(4);
        let max_slot = AtomicUsize::new(0);
        pool.run(32, 2, &|_t, slot| {
            max_slot.fetch_max(slot, Ordering::Relaxed);
            // A little work so both participants engage.
            std::hint::black_box((0..500u64).sum::<u64>());
        });
        assert!(max_slot.load(Ordering::Relaxed) < 2);
    }

    #[test]
    fn sequential_jobs_reuse_the_pool() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(8, 3, &|t, _| {
                total.fetch_add(t + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * (1 + 2 + 3 + 4 + 5 + 6 + 7 + 8));
    }

    #[test]
    fn zero_workers_runs_inline() {
        let pool = WorkerPool::new(0);
        let sum = AtomicUsize::new(0);
        pool.run(5, 8, &|t, slot| {
            assert_eq!(slot, 0);
            sum.fetch_add(t, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn task_panics_propagate_to_the_caller_and_spare_the_pool() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, 3, &|t, _| {
                if t == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "task panic must surface on the caller");
        // The pool survives and serves the next job.
        let sum = AtomicUsize::new(0);
        pool.run(4, 3, &|t, _| {
            sum.fetch_add(t, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn pool_is_not_poisoned_by_a_panicking_job() {
        // The re-raised panic unwinds through `run` with the submit
        // guard live, poisoning the mutex; before the poison-ignoring
        // locks, every job after the first panic died at `lock()` with
        // a PoisonError instead of running. Several rounds, so a panic
        // landing on either side of the submit guard is covered.
        let pool = WorkerPool::new(2);
        for round in 0..3usize {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(8, 3, &|t, _| {
                    if t == round {
                        panic!("boom {round}");
                    }
                });
            }));
            assert!(caught.is_err(), "round {round}: the panic must surface");
            let sum = AtomicUsize::new(0);
            pool.run(16, 3, &|t, _| {
                sum.fetch_add(t, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 120, "round {round}: pool must stay usable");
        }
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
    }
}
