//! `gemm_awq_writeback` — the baseline path that dequantizes each K-tile
//! into an f32 scratch buffer before a dense GEMM pass.
//!
//! This is the AutoAWQ structure the paper's Figure 2 describes, mapped
//! to CPU: per (M-block, N-panel, K-block) the kernel first *writes back*
//! the whole dequantized `kc x nc` weight tile to a scratch buffer (the
//! stand-in for the shared-memory staging tile), unscrambling the FT
//! nibble order at runtime as stock AWQ must, and only then runs the same
//! `4 x 8` microkernel the fused path uses — now reading operands through
//! the scratch round-trip instead of from a just-decoded L1-hot fragment.
//! Blocking, threading, SIMD tier, and the inner loop are shared with
//! [`super::gemm_quick_fused`], so the measured gap between the two paths
//! isolates exactly the write-back the interleaved layout deletes. (The
//! SIMD AWQ decoder still pays the FT unscramble — as a `vpermps` — the
//! same way the GPU baseline pays it as a shuffle.)
//!
//! The staging tile itself is the plan's resident per-slot scratch
//! ([`super::PlanCache`]), so repeated same-shape calls allocate nothing.

use anyhow::Result;

use crate::quant::decode::{
    select_awq_decoder, select_awq_lut_decoder, DecodeAwqFn, DecodeAwqLutFn,
};
use crate::quant::{pack_awq, Codebook, CodebookKind, DecoderKind, QuantizedTensor, PACK_FACTOR};

use super::blocking::Blocking;
use super::fused::effective_decoder;
use super::microkernel;
use super::plan::{GemmPlan, PlanCache};

/// A weight matrix in the stock AutoAWQ layout (row-major `(k, n/8)` words
/// in FT nibble order + group metadata), ready for [`gemm_awq_writeback`].
#[derive(Debug, Clone)]
pub struct AwqWeights {
    /// Packed words, row-major `(k, n/8)`, FT nibble order.
    pub qweight: Vec<u32>,
    /// Per-group scales, row-major `(k / group_size, n)`.
    pub scales: Vec<f32>,
    /// Per-group zero-points, same shape as scales.
    pub zeros: Vec<f32>,
    /// In-features (reduction axis).
    pub k: usize,
    /// Out-features.
    pub n: usize,
    /// Quantization group length along K.
    pub group_size: usize,
    /// The 16-entry grid the words' nibbles index. Non-uniform grids
    /// (NF4/MXFP4) force the LUT decode tier in [`gemm_awq_writeback`].
    pub codebook: CodebookKind,
}

impl AwqWeights {
    /// Pack a logical quantized tensor into the stock AWQ layout
    /// (the tensor's codebook rides along).
    ///
    /// # Panics
    ///
    /// Panics on the `pack_awq` shape contract (`n % 8`).
    pub fn from_quantized(t: &QuantizedTensor) -> Self {
        AwqWeights {
            qweight: pack_awq(&t.codes, t.k, t.n),
            scales: t.scales.clone(),
            zeros: t.zeros.clone(),
            k: t.k,
            n: t.n,
            group_size: t.group_size,
            codebook: t.codebook,
        }
    }
}

/// The AWQ twin of `fused::QuickDecode`: one enum dispatch per word,
/// function pointers and codebook bound once per GEMM call.
enum AwqDecode {
    /// Shift-mask expansion (uniform INT4 only).
    Shift(DecodeAwqFn),
    /// Codebook table lookup.
    Lut(DecodeAwqLutFn, &'static Codebook),
}

impl AwqDecode {
    fn resolve(simd: bool, requested: DecoderKind, codebook: CodebookKind) -> Self {
        match effective_decoder(requested, codebook) {
            DecoderKind::ShiftMask => AwqDecode::Shift(select_awq_decoder(simd)),
            DecoderKind::Lut => AwqDecode::Lut(select_awq_lut_decoder(simd), codebook.table()),
        }
    }

    #[inline]
    fn word(&self, word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
        match self {
            AwqDecode::Shift(f) => f(word, s8, z8, out),
            AwqDecode::Lut(f, cb) => f(word, s8, z8, cb, out),
        }
    }
}

/// `y(m, n) = x(m, k) @ w(k, n)` with `w` dequantized tile-by-tile into a
/// scratch buffer before the dense GEMM pass; `y` is overwritten.
///
/// Resolves the execution plan through the process-wide [`PlanCache`];
/// errors on shape violations (`x`/`y` length, blocking contract).
pub fn gemm_awq_writeback(
    x: &[f32],
    m: usize,
    w: &AwqWeights,
    b: &Blocking,
    y: &mut [f32],
) -> Result<()> {
    let plan = PlanCache::global().plan(m, w.k, w.n, b)?;
    gemm_awq_writeback_planned(x, w, &plan, y)
}

/// [`gemm_awq_writeback`] with a caller-held [`GemmPlan`] (the
/// `StepExecutor` hot path — no cache lookup per call).
pub fn gemm_awq_writeback_planned(
    x: &[f32],
    w: &AwqWeights,
    plan: &GemmPlan,
    y: &mut [f32],
) -> Result<()> {
    anyhow::ensure!(
        plan.k == w.k && plan.n == w.n,
        "plan shape ({}, {}) does not match weights ({}, {})",
        plan.k,
        plan.n,
        w.k,
        w.n
    );
    let m = plan.m;
    anyhow::ensure!(x.len() == m * w.k, "x holds {} values, needs {}", x.len(), m * w.k);
    anyhow::ensure!(y.len() == m * w.n, "y holds {} values, needs {}", y.len(), m * w.n);
    let b = plan.blocking;
    let kern = microkernel::select(b.simd);
    let decode = AwqDecode::resolve(b.simd, b.decoder, w.codebook);
    let w_total = w.n / PACK_FACTOR;
    plan.execute(y, &|panel, out, ldy, out_c0, scratch| {
        // The write-back staging tile (kc x nc f32, 16x the fused
        // fragment panel) lives in the plan's per-slot scratch — refilled
        // in place for every (M-block, N-panel, K-block), never
        // reallocated.
        let ncols = panel.cols();
        let mut m0 = 0;
        while m0 < m {
            let m1 = (m0 + b.mc).min(m);
            let mut kb0 = 0;
            while kb0 < w.k {
                let kc_len = b.kc.min(w.k - kb0);
                // Write-back pass: dequantize the whole kc x nc tile to
                // scratch, unscrambling FT order word by word.
                for kk in 0..kc_len {
                    let row = kb0 + kk;
                    let gbase = (row / w.group_size) * w.n;
                    for wj in panel.wj0..panel.wj1 {
                        let c0 = wj * PACK_FACTOR;
                        decode.word(
                            w.qweight[row * w_total + wj],
                            &w.scales[gbase + c0..gbase + c0 + PACK_FACTOR],
                            &w.zeros[gbase + c0..gbase + c0 + PACK_FACTOR],
                            &mut scratch[kk * ncols + (wj - panel.wj0) * PACK_FACTOR..],
                        );
                    }
                }
                // Dense GEMM pass over the staged tile.
                for wj in panel.wj0..panel.wj1 {
                    kern(
                        x,
                        w.k,
                        m0,
                        m1,
                        kb0,
                        kc_len,
                        &scratch[(wj - panel.wj0) * PACK_FACTOR..],
                        ncols,
                        out,
                        ldy,
                        wj * PACK_FACTOR - out_c0,
                    );
                }
                kb0 += kc_len;
            }
            m0 = m1;
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{max_rel_err, KernelBackend, NaiveBackend};
    use crate::quant::quantize_groupwise;
    use crate::util::Rng;

    fn rand_case(k: usize, n: usize, g: usize, m: usize, seed: u64) -> (Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::seed_from_u64(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let t = quantize_groupwise(&w, k, n, g);
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        (x, t)
    }

    #[test]
    fn matches_naive_on_nonsquare_shapes() {
        for (k, n, g, m) in [(64, 24, 32, 1), (128, 40, 64, 9), (96, 64, 32, 5)] {
            let (x, t) = rand_case(k, n, g, m, 1000 + m as u64);
            let naive = NaiveBackend::from_quantized(&t);
            let mut want = vec![0f32; m * n];
            naive.gemm(&x, m, &mut want);
            let w = AwqWeights::from_quantized(&t);
            let mut got = vec![f32::NAN; m * n];
            gemm_awq_writeback(&x, m, &w, &Blocking::default(), &mut got).unwrap();
            let err = max_rel_err(&got, &want);
            assert!(err <= 1e-4, "k={k} n={n} g={g} m={m}: rel err {err}");
        }
    }

    #[test]
    fn partial_panels_and_tiny_blocking_agree() {
        // nc_words = 2 with 6 word-columns leaves a partial N-panel; kc
        // smaller than K leaves partial K-blocks; mc = 3 strips M oddly.
        let (k, n, g, m) = (80, 48, 16, 11);
        let (x, t) = rand_case(k, n, g, m, 8);
        let naive = NaiveBackend::from_quantized(&t);
        let mut want = vec![0f32; m * n];
        naive.gemm(&x, m, &mut want);
        let w = AwqWeights::from_quantized(&t);
        let tiny = Blocking { mc: 3, kc: 32, nc_words: 2, threads: 1, ..Blocking::default() };
        let mut got = vec![0f32; m * n];
        gemm_awq_writeback(&x, m, &w, &tiny, &mut got).unwrap();
        assert!(max_rel_err(&got, &want) <= 1e-4);
    }

    #[test]
    fn multithreaded_pool_and_spawn_equal_single() {
        let (k, n, g, m) = (64, 80, 32, 6);
        let (x, t) = rand_case(k, n, g, m, 12);
        let w = AwqWeights::from_quantized(&t);
        let mut single = vec![0f32; m * n];
        gemm_awq_writeback(&x, m, &w, &Blocking { threads: 1, ..Blocking::default() }, &mut single)
            .unwrap();
        for pool in [true, false] {
            let b = Blocking { threads: 3, nc_words: 2, pool, ..Blocking::default() };
            let mut multi = vec![0f32; m * n];
            gemm_awq_writeback(&x, m, &w, &b, &mut multi).unwrap();
            assert_eq!(single, multi, "pool={pool}");
        }
    }

    #[test]
    fn lut_decoder_on_uniform_weights_is_bit_identical() {
        let (k, n, g, m) = (96, 40, 32, 7);
        let (x, t) = rand_case(k, n, g, m, 64);
        let w = AwqWeights::from_quantized(&t);
        let shift = Blocking { threads: 1, ..Blocking::default() };
        let lut = Blocking { threads: 1, decoder: DecoderKind::Lut, ..Blocking::default() };
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        gemm_awq_writeback(&x, m, &w, &shift, &mut a).unwrap();
        gemm_awq_writeback(&x, m, &w, &lut, &mut b).unwrap();
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn nonuniform_codebooks_match_naive_reference() {
        use crate::quant::quantize_groupwise_codebook;
        let (k, n, g, m) = (64, 48, 32, 5);
        let mut rng = Rng::seed_from_u64(78);
        let wf: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
        for kind in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let t = quantize_groupwise_codebook(&wf, k, n, g, kind);
            let naive = NaiveBackend::from_quantized(&t);
            let mut want = vec![0f32; m * n];
            naive.gemm(&x, m, &mut want);
            let w = AwqWeights::from_quantized(&t);
            let mut got = vec![f32::NAN; m * n];
            gemm_awq_writeback(&x, m, &w, &Blocking::default(), &mut got).unwrap();
            let err = max_rel_err(&got, &want);
            assert!(err <= 1e-4, "{kind:?}: rel err {err}");
        }
    }

    #[test]
    fn simd_and_scalar_agree_closely() {
        let (k, n, g, m) = (256, 64, 64, 7);
        let (x, t) = rand_case(k, n, g, m, 13);
        let w = AwqWeights::from_quantized(&t);
        let mut simd = vec![0f32; m * n];
        let mut scalar = vec![0f32; m * n];
        gemm_awq_writeback(&x, m, &w, &Blocking { threads: 1, ..Blocking::default() }, &mut simd)
            .unwrap();
        let sb = Blocking { threads: 1, simd: false, ..Blocking::default() };
        gemm_awq_writeback(&x, m, &w, &sb, &mut scalar).unwrap();
        // Full-GEMM bar (see the fused twin test): 1e-5; the strict 1e-6
        // microkernel property lives in microkernel.rs.
        assert!(max_rel_err(&simd, &scalar) <= 1e-5);
    }
}
