//! # quick-infer
//!
//! Full-system reproduction of **QUICK: Quantization-aware Interleaving and
//! Conflict-free Kernel for efficient LLM inference** (Kim et al.,
//! SqueezeBits, 2024) on a Rust + JAX + Pallas three-layer stack.
//!
//! Layer map (see `DESIGN.md`):
//!
//! * [`quant`] — offline 4-bit packing and the QUICK interleaving
//!   permutations (paper §3.2, Figs. 4–6); byte-compatible with
//!   `python/compile/kernels/pack.py`. [`quant::shard`] draws
//!   tensor-parallel shard boundaries in logical `(k, n)` space and packs
//!   each shard independently (the interleaved stream cannot be sliced).
//! * [`kernel`] — the *native* W4A16 dequant-GEMM backend pair: a fused
//!   cache-blocked, register-tiled, multithreaded microkernel that decodes
//!   nibbles in-register straight out of the interleaved stream, vs the
//!   AWQ-style dequant-to-scratch-then-GEMM baseline — the paper's
//!   mechanism executing in measurable silicon (`bench kernels`).
//! * [`gpusim`] — cycle-approximate GPU kernel execution model: shared-memory
//!   bank-conflict counting, occupancy, DRAM traffic, and tile schedules for
//!   the fp16 / AWQ / QUICK kernels, plus the ring-collective cost model
//!   behind tensor-parallel steps ([`gpusim::collective`]). Regenerates the
//!   paper's Figures 3, 7, 8 and Table 1 on a machine with no NVIDIA GPU.
//! * [`model`] — LLM architecture tables (Mistral-7B … Llama-2-70B) and
//!   per-layer GEMM shape/byte accounting, including the OOM predictor
//!   behind Figure 8's missing fp16 bars.
//! * [`workload`] — synthetic serving workloads (ShareGPT-like length
//!   distributions, Poisson arrivals, shared-prefix multi-turn chat) for
//!   the Table 1 benchmark and the prefix-cache evaluation.
//! * [`runtime`] — PJRT execution of the AOT artifacts emitted by
//!   `python/compile/aot.py` (`artifacts/hlo/*.hlo.txt`).
//! * [`coordinator`] — the serving engine: request router, token-budget
//!   continuous batcher with chunked prefill (decode tokens fill each
//!   step's budget first; admitted prompts chunk into the remainder and
//!   ride the same mixed step), paged KV-cache manager with copy-on-write
//!   block sharing, automatic prefix cache (`coordinator::prefix`),
//!   preemption/requeue under KV pressure, metrics.
//! * [`obs`] — always-on observability: process-wide metrics registry,
//!   lock-free span tracer emitting Perfetto-loadable Chrome-trace JSON
//!   (`--trace <path>`), and the per-GEMM-shape modeled-vs-measured
//!   drift accountant (`report obs`).
//!
//! Python never runs on the request path: `make artifacts` AOT-lowers the
//! JAX/Pallas model once, and the [`runtime`] executes the HLO from Rust.
//!
//! See the top-level `README.md` for the quickstart and the map from every
//! paper figure/table to its `quick-infer simulate <which>` invocation.

// Every public item carries rustdoc; new undocumented API warns (the CI
// clippy gate allows the lint so a missed item degrades to a warning
// rather than blocking unrelated changes).
#![warn(missing_docs)]

pub mod coordinator;
pub mod gpusim;
pub mod kernel;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod tokenizer;
pub mod util;
pub mod figures;
pub mod workload;
