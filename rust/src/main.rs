//! quick-infer CLI — leader entrypoint (std-only arg parsing).
//!
//! Subcommands:
//! * `serve`    — run the PJRT-backed engine over a synthetic workload on
//!   the AOT-compiled tiny model and print serving metrics.
//! * `simulate` — regenerate a paper experiment or serving extension
//!   (fig3 | fig7 | fig8 | table1 | prefix | continuous | tp |
//!   kernel-matmul | step | kv | all) from the gpusim cost model
//!   (kernel-matmul/step/kv: measured on this CPU) and print paper-style
//!   rows. `continuous` and
//!   `tp` accept `--measured`: serve the same workloads on the native
//!   StepExecutor runtime (real GEMM streams on this CPU, modeled ring
//!   collectives) and report measured tokens/sec next to the modeled
//!   twin, feeding the drift ledger.
//! * `bench`    — measured native-kernel benchmarks with structured JSON
//!   trajectory output (`bench kernels` → `BENCH_kernels.json`).
//! * `report`   — observability: print the metrics-registry snapshot and
//!   model/measured drift (`report obs`), or validate a Chrome-trace
//!   file written by `--trace` (`report trace PATH`).
//! * `profile`  — one-GEMM kernel-model breakdown on a chosen device.
//! * `loadtest` — online latency percentiles vs offered load.
//! * `generate` — end-to-end text generation on the tiny model.
//! * `quantize` — offline packing demo: quantize + QUICK-interleave a
//!   random matrix and report layouts.
//! * `info`     — list artifacts and device specs.

use anyhow::{bail, Result};

use quick_infer::coordinator::{Engine, EngineConfig, GenerationRequest};
use quick_infer::figures;
use quick_infer::gpusim::{Calib, Gpu, KernelKind};
use quick_infer::runtime::Runtime;
use quick_infer::util::rng::Rng;
use quick_infer::workload;

/// Valid `simulate` targets, listed by the unknown-target error (keep in
/// sync with the USAGE block and the dispatch match below).
const SIMULATE_TARGETS: &str =
    "fig3|fig7|fig8|table1|prefix|continuous|tp|kernel-matmul|step|kv|chaos|all";

/// Valid `bench` targets, listed by the unknown-target error (keep in
/// sync with the USAGE block and the dispatch match below).
const BENCH_TARGETS: &str = "kernels|check";

/// Valid `report` targets, listed by the unknown-target error (keep in
/// sync with the USAGE block and the dispatch match below).
const REPORT_TARGETS: &str = "obs|trace";

const USAGE: &str = "\
quick-infer — QUICK (2024) reproduction: conflict-free W4A16 inference stack

USAGE:
    quick-infer serve    [--artifacts DIR] [--kernel quick|awq|fp16]
                         [--requests N] [--seed S]
        Serve a synthetic workload on the AOT-compiled tiny model via PJRT.
        Defaults: --artifacts artifacts, --kernel quick, --requests 32, --seed 0.

    quick-infer simulate [fig3|fig7|fig8|table1|prefix|continuous|tp|kernel-matmul|step|kv|chaos|all]
                         [--model M] [--codebook int4|nf4|mxfp4] [--trace PATH]
                         [--measured] [--quick]
        Regenerate one experiment from the gpusim cost model (default: all).
          fig3        smem bank conflicts per kernel
          fig7        GEMM TOPS vs batch on all four devices
          fig8        end-to-end decode tokens/s vs batch (with OOM cutoffs)
          table1      vLLM-style serving throughput (A6000)
          prefix      automatic prefix cache on/off (extension)
          continuous  continuous batching vs static waves (extension);
                      --measured serves the tiny model on the native
                      StepExecutor runtime instead of the cost model:
                      real GEMM streams per mixed prefill/decode step,
                      prefix hits skip real compute, drift ledger
                      populated per shape (--quick shrinks the workload;
                      --codebook nf4|mxfp4 serves non-uniform 4-bit
                      weights through the LUT decode tier)
          tp          tensor-parallel scaling sweep, tp 1|2|4|8 (extension);
                      --measured runs tp ranks concurrently on the
                      native runtime with gpusim-priced ring collectives
                      (--quick limits degrees to 1|2)
          kernel-matmul  *measured* native fused vs write-back W4A16 GEMM
                      M-sweep on this CPU, 1024x1024 g128 (not part of
                      'all': host-dependent wall time, not a model query)
          step        *measured* end-to-end decode step tokens/s: every
                      weight GEMM of --model (default tiny) through the
                      native runtime at M in {1, 2, 4, 8}, plus the
                      step-fitted gpusim calibration; --codebook
                      int4|nf4|mxfp4 (default int4) picks the weight
                      grid — non-uniform grids decode via the LUT tier
                      (not part of 'all')
          kv          quantized KV cache: per-precision density table
                      (f16/kv8/kv4 bytes per token, tokens per block),
                      shared-prefix serving under memory pressure at each
                      precision, and a *measured* fused dequant-attention
                      call fit into the gpusim kv_attn_scale calibration
                      (not part of 'all': includes host wall time)
          chaos       chaos serving: goodput under deterministic fault
                      schedules (crashes, stalls, KV-pool pressure) for
                      QUICK vs AWQ, with the SLO degrade ladder
                      (f16 -> kv8 -> kv4) against reject-only shedding
                      (--quick skips the mixed-fault sweep; not part of
                      'all': it asserts on its own acceptance bars)

    quick-infer bench    [kernels|check] [--k K] [--n N] [--group-size G]
                         [--json PATH] [--quick] [--decode-sweep] [--attention]
                         [--lut] [--strict] [--trace PATH]
        Run a measured native-kernel benchmark and append a structured
        JSON point to the perf trajectory (default target: kernels).
          kernels     fused-from-interleaved vs dequant-to-scratch GEMM,
                      M in {1, 8, 32, 128, 256}, plus the decode-shape
                      runtime sweep (M in {1, 2, 4, 8}: pool-vs-spawn,
                      SIMD-vs-scalar, dispatch overhead), the LUT decoder
                      sweep (shift-mask vs byte-shuffle LUT on INT4, plus
                      NF4/MXFP4 codebooks), and the fused
                      dequant-attention KV sweep (kv4/kv8 vs dense over
                      context x batch); exits non-zero if any path
                      diverges from the naive reference (>1e-4 rel).
                      --decode-sweep runs only the decode sweep;
                      --attention runs only the attention sweep;
                      --lut runs only the LUT decoder sweep.
          check       parse a previously written BENCH_kernels.json and
                      exit non-zero unless it is well-formed and its
                      differential gate passed (CI post-step). A
                      committed '\"placeholder\": true' file passes with
                      a warning; --strict rejects it (CI).
        Defaults: --k 4096, --n 4096, --group-size 128, --json writes
        BENCH_kernels.json at the repo root (nearest ancestor with
        ROADMAP.md/.git, else the cwd). --quick shrinks the layer to
        512x512 and the sample count for CI smoke runs.

    quick-infer report   [obs|trace PATH] [--min-spans N] [--min-threads N]
        Observability reports (default target: obs).
          obs         run a short instrumented workload, then print the
                      metrics-registry snapshot (pool, plan cache,
                      executor, scheduler, prefix cache, latency
                      histograms), the per-GEMM-shape modeled vs
                      measured drift ratios, and the measured
                      per-decoder dequant calibration (shift-mask vs
                      LUT fit via calibrate_dequant)
          trace       parse a Chrome-trace JSON written by --trace and
                      exit non-zero unless it holds >= --min-spans spans
                      (default 1) from >= --min-threads threads
                      (default 1)

        Any simulate or bench run accepts --trace PATH: record runtime
        spans (executor GEMMs, worker pool, scheduler) while the command
        runs and write Chrome-trace-event JSON to PATH — open it in
        Perfetto or chrome://tracing.

    quick-infer profile  [--gpu 4090|a6000|l40|a100] [--m M] [--n N] [--k K]
        Per-kernel latency/TOPS breakdown of one GEMM.
        Defaults: --gpu 4090, --m 64, --n 8192, --k 8192.

    quick-infer loadtest [--rates 1,2,4,8,16] [--requests N]
        Online latency percentiles vs offered load (A6000, Vicuna-13B).
        Defaults: --rates 1,2,4,8,16, --requests 200.

    quick-infer generate --prompt TEXT [--max-new N] [--kernel K] [--temperature T]
        End-to-end generation on the tiny model.
        Defaults: --prompt 'the quick brown fox', --max-new 16, --kernel quick,
        greedy sampling unless --temperature is given.

    quick-infer quantize [--k K] [--n N] [--group-size G]
        Offline packing demo: quantize + QUICK-interleave a random matrix.
        Defaults: --k 256, --n 256, --group-size 128.

    quick-infer info     [--artifacts DIR]
        List device specs, a kernel-model spot check, and AOT artifacts.
        Defaults: --artifacts artifacts.
";

/// Minimal flag parser: `--key value` pairs plus positional args.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

/// Flags that take no value (presence means `true`).
const BOOL_FLAGS: [&str; 6] = ["quick", "decode-sweep", "attention", "lut", "measured", "strict"];

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                    continue;
                }
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: '{s}'")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "serve" => serve(
            &args.get("artifacts", "artifacts"),
            &args.get("kernel", "quick"),
            args.get_num("requests", 32usize)?,
            args.get_num("seed", 0u64)?,
        ),
        "simulate" => with_trace(args.flags.get("trace"), || {
            simulate(args.positional.first().map(String::as_str).unwrap_or("all"), &args)
        }),
        "bench" => with_trace(args.flags.get("trace"), || {
            bench_cmd(args.positional.first().map(String::as_str).unwrap_or("kernels"), &args)
        }),
        "report" => {
            report_cmd(args.positional.first().map(String::as_str).unwrap_or("obs"), &args)
        }
        "quantize" => quantize_demo(
            args.get_num("k", 256usize)?,
            args.get_num("n", 256usize)?,
            args.get_num("group-size", 128usize)?,
        ),
        "profile" => profile_cmd(
            &args.get("gpu", "4090"),
            args.get_num("m", 64u64)?,
            args.get_num("n", 8192u64)?,
            args.get_num("k", 8192u64)?,
        ),
        "loadtest" => loadtest(&args.get("rates", "1,2,4,8,16"), args.get_num("requests", 200usize)?),
        "generate" => generate(
            &args.get("artifacts", "artifacts"),
            &args.get("kernel", "quick"),
            &args.get("prompt", "the quick brown fox"),
            args.get_num("max-new", 16usize)?,
            args.flags.get("temperature").map(|t| t.parse().unwrap_or(1.0)),
        ),
        "info" => info(&args.get("artifacts", "artifacts")),
        "-h" | "--help" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Parse the `--codebook` flag (default `int4`) into a weight grid;
/// unknown names list the valid ones.
fn parse_codebook(args: &Args) -> Result<quick_infer::quant::CodebookKind> {
    let name = args.get("codebook", "int4");
    quick_infer::quant::CodebookKind::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown codebook '{name}' (int4|nf4|mxfp4)"))
}

/// Run `f` with the span tracer on when `--trace PATH` was given,
/// writing the Chrome-trace JSON and a one-line summary afterwards.
fn with_trace(path: Option<&String>, f: impl FnOnce() -> Result<()>) -> Result<()> {
    use quick_infer::obs::trace;
    let Some(path) = path else { return f() };
    if !trace::COMPILED {
        bail!("--trace needs the tracer, but this binary was built with the trace_off feature");
    }
    trace::enable();
    let res = f();
    trace::disable();
    trace::write_chrome_trace(std::path::Path::new(path))?;
    println!(
        "wrote trace {path}: {} spans from {} threads ({} dropped)",
        trace::events_recorded(),
        trace::threads_with_events(),
        trace::events_dropped()
    );
    res
}

/// Dispatch `quick-infer report <target>`; unknown targets list the
/// valid ones.
fn report_cmd(target: &str, args: &Args) -> Result<()> {
    match target {
        "obs" => report_obs(),
        "trace" => report_trace(
            args.positional.get(1).map(String::as_str),
            args.get_num("min-spans", 1usize)?,
            args.get_num("min-threads", 1usize)?,
        ),
        other => bail!("unknown report target '{other}' — valid targets: {REPORT_TARGETS}"),
    }
}

/// `report obs`: run a short instrumented workload so every subsystem
/// has recorded something, then print the registry snapshot and the
/// per-shape model/measured drift ratios.
fn report_obs() -> Result<()> {
    use quick_infer::coordinator::simserve::{
        simulate_continuous, simulate_serving, ContinuousPolicy, SimPolicy,
    };
    use quick_infer::model::Model;
    use quick_infer::obs::{DriftAccountant, Registry};
    use quick_infer::util::Bench;
    use quick_infer::workload::{BurstyWorkload, SharedPrefixWorkload};

    println!("populating the registry with a short instrumented workload...");
    // Measured step sweep on the tiny model: executor spans, worker
    // pool, plan cache, and the drift accountant.
    figures::step_throughput_with(
        &mut std::io::sink(),
        Model::Tiny,
        128,
        &[1, 4],
        &Bench::smoke().silent(),
        quick_infer::quant::CodebookKind::Int4Uniform,
    )?;
    // Small simulated serving runs: continuous scheduler + prefix cache.
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    let calib = Calib::default();
    let bursty = BurstyWorkload::default().online(60, 1.0, 2028);
    let cont = simulate_continuous(
        &dev,
        &spec,
        KernelKind::Quick,
        &bursty,
        &ContinuousPolicy::default(),
        &calib,
    )?;
    let shared = SharedPrefixWorkload::default().offline(40, 2029);
    let _ =
        simulate_serving(&dev, &spec, KernelKind::Quick, &shared, &SimPolicy::default(), &calib)?;

    // A small *measured* continuous run: the serving path driven by the
    // native StepExecutor runtime, feeding the drift ledger per shape.
    use quick_infer::coordinator::measured::measured_bursty;
    use quick_infer::coordinator::simserve::simulate_continuous_measured;
    use quick_infer::kernel::StepBackend;
    let tiny = Model::Tiny.spec();
    let measured = simulate_continuous_measured(
        &dev,
        &tiny,
        StepBackend::Fused,
        &measured_bursty(8, 2030),
        &ContinuousPolicy::measured_default(),
        &calib,
        128,
        0x5EED,
    )?;

    // A chaos sample: a crash plus a KV-pressure window over two
    // replicas, so the chaos.* counters asserted below are provably
    // live (crash while replica 1 is squeezed forces both failover and
    // degraded admissions).
    use quick_infer::coordinator::faults::{
        run_chaos, ChaosPolicy, FaultEvent, FaultKind, FaultPlan, Scenario,
    };
    use quick_infer::workload::Request;
    let chaos_reqs: Vec<Request> = (0..12u64)
        .map(|i| Request {
            id: 1 + i,
            prompt_tokens: 220,
            gen_tokens: 8,
            arrival_s_micros: i * 100_000,
            sys_id: 0,
            sys_tokens: 0,
            stream_id: 1 + i,
        })
        .collect();
    let chaos_plan = FaultPlan {
        seed: 0,
        scenario: Scenario::Mixed,
        events: vec![
            FaultEvent { at_s: 0.0, kind: FaultKind::PressureStart { replica: 1, frac: 0.9 } },
            FaultEvent { at_s: 0.05, kind: FaultKind::Crash { replica: 0 } },
            FaultEvent { at_s: 0.6, kind: FaultKind::Recover { replica: 0 } },
            FaultEvent { at_s: 1.2, kind: FaultKind::PressureEnd { replica: 1 } },
        ],
    };
    let chaos = run_chaos(
        &dev,
        &spec,
        KernelKind::Quick,
        &chaos_reqs,
        &chaos_plan,
        &ChaosPolicy { pool_blocks: Some(64), ..Default::default() },
        &calib,
    )?;
    println!(
        "\nsample chaos run: {} finished / {} shed, {} requeued on failover, {} degraded",
        chaos.finished,
        chaos.rejected,
        chaos.failover_requeues,
        chaos.degraded_int8 + chaos.degraded_int4
    );

    println!("\nsample continuous run ({} on {}, QUICK):", spec.name, dev.name);
    println!("{}", cont.report());
    println!("\nsample measured continuous run ({} on this CPU, fused):", tiny.name);
    println!("{}", measured.report());
    println!();
    println!("{}", Registry::global().report());
    println!();
    println!("{}", DriftAccountant::global().report());

    // Per-decoder dequant calibration: time one uniform-INT4 layer under
    // both nibble-decode tiers (same bits, decoder flipped via Blocking),
    // then fit the LUT tier's dequant scale so the cost model's
    // shift-mask/LUT latency ratio matches what this CPU measured.
    use quick_infer::gpusim::calibrate_dequant;
    use quick_infer::kernel::{gemm_quick_fused, Blocking, QuickWeights};
    use quick_infer::quant::{quantize_groupwise, DecoderKind};
    use quick_infer::util::rng::Rng;
    let (ck, cn, cg, cm) = (512usize, 512usize, 128usize, 8usize);
    let mut rng = Rng::seed_from_u64(0xD0C0);
    let w: Vec<f32> = (0..ck * cn).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let qw = QuickWeights::from_quantized(&quantize_groupwise(&w, ck, cn, cg));
    let x: Vec<f32> = (0..cm * ck).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let mut y = vec![0f32; cm * cn];
    let cbench = Bench::smoke().silent();
    let mut time_decoder = |b: &Blocking, label: &str| -> anyhow::Result<f64> {
        let r = cbench.run(&format!("obs decoder {label}"), || {
            gemm_quick_fused(&x, cm, &qw, b, &mut y).expect("fused gemm");
            y[0]
        });
        Ok(r.median_ns / 1e9)
    };
    let shift_s = time_decoder(&Blocking::default(), "shift-mask")?;
    let lut_s = time_decoder(&Blocking { decoder: DecoderKind::Lut, ..Blocking::default() }, "lut")?;
    let fitted =
        calibrate_dequant(&dev, KernelKind::Quick, cm as u64, cn as u64, ck as u64, shift_s, lut_s, &calib);
    println!("\n-- decoder calibration ({ck}x{cn} g{cg} m{cm}, measured on this CPU) --");
    println!("{:<12} {:>13} {:>14}", "decoder", "measured s", "dequant scale");
    for (label, s, d) in [
        ("shift-mask", shift_s, DecoderKind::ShiftMask),
        ("lut", lut_s, DecoderKind::Lut),
    ] {
        println!("{label:<12} {s:>13.3e} {:>14.3}", fitted.dequant_scale(d));
    }
    println!(
        "measured lut/shift-mask gap: {:.2}x -> calibrated dequant_scale_lut {:.3} (default 1.0)",
        lut_s / shift_s.max(1e-12),
        fitted.dequant_scale(DecoderKind::Lut)
    );

    anyhow::ensure!(
        !DriftAccountant::global().is_empty(),
        "drift ledger is empty after a measured run — the modeled-vs-measured seam is dark"
    );
    anyhow::ensure!(
        Registry::global().counter("chaos.crashes").get() > 0,
        "chaos.crashes is zero after a crash-bearing chaos run"
    );
    anyhow::ensure!(
        Registry::global().counter("chaos.degraded_admissions").get() > 0,
        "chaos.degraded_admissions is zero after a pressured chaos run"
    );
    Ok(())
}

/// `report trace`: parse a Chrome-trace JSON written by `--trace` and
/// fail unless it holds enough spans from enough distinct threads — the
/// CI smoke gate behind the trace artifact.
fn report_trace(path: Option<&str>, min_spans: usize, min_threads: usize) -> Result<()> {
    use quick_infer::util::Json;
    let path = path.ok_or_else(|| anyhow::anyhow!("report trace needs a file path"))?;
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let doc = Json::parse(text.trim())?;
    let events = doc.req("traceEvents")?.as_arr()?;
    let mut spans = 0usize;
    let mut tids = std::collections::BTreeSet::new();
    for ev in events {
        if ev.req("ph")?.as_str()? != "X" {
            continue;
        }
        anyhow::ensure!(!ev.req("name")?.as_str()?.is_empty(), "span with an empty name");
        let (ts, dur) = (ev.req("ts")?.as_f64()?, ev.req("dur")?.as_f64()?);
        anyhow::ensure!(ts >= 0.0 && dur >= 0.0, "span with negative ts/dur: {ts}/{dur}");
        spans += 1;
        tids.insert(ev.req("tid")?.as_f64()? as u64);
    }
    let dropped = doc.req("droppedEvents")?.as_f64()?;
    println!(
        "trace ok: {spans} spans across {} threads ({} events total, {dropped} dropped)",
        tids.len(),
        events.len()
    );
    anyhow::ensure!(spans >= min_spans, "only {spans} spans, need >= {min_spans}");
    anyhow::ensure!(
        tids.len() >= min_threads,
        "spans from only {} threads, need >= {min_threads}",
        tids.len()
    );
    Ok(())
}

fn serve(artifacts: &str, kernel: &str, n_requests: usize, seed: u64) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    println!("platform: {}", rt.platform());
    let mut engine = Engine::new(
        rt,
        EngineConfig { kernel: kernel.into(), max_queue: 1024, ..Default::default() },
    )?;
    // Prompts sized to the prefill window; generation budget bounded by
    // the remaining context.
    let max_prompt = engine.prefill_window() as u64;
    let max_gen = (engine.max_context() as u64 - max_prompt).min(24);
    let reqs = workload::tiny_workload(n_requests, max_prompt, max_gen, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xC0FFEE);

    let t0 = std::time::Instant::now();
    for r in &reqs {
        let prompt: Vec<i32> =
            (0..r.prompt_tokens).map(|_| rng.range_u64(0, 511) as i32).collect();
        engine.submit(GenerationRequest {
            id: r.id,
            prompt,
            max_new_tokens: r.gen_tokens as usize,
            temperature: None,
            eos_token: None,
        })?;
    }
    engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", engine.metrics.report(wall));
    println!("completions: {}", engine.drain_completions().len());
    Ok(())
}

fn simulate(which: &str, args: &Args) -> Result<()> {
    let out = &mut std::io::stdout();
    match which {
        "fig3" => {
            figures::fig3(out)?;
        }
        "fig7" => {
            figures::fig7(out)?;
        }
        "fig8" => {
            figures::fig8(out)?;
        }
        "table1" => {
            figures::table1(out)?;
        }
        "prefix" => {
            figures::prefix_cache(out)?;
        }
        "continuous" => {
            if args.flags.contains_key("measured") {
                let n = if args.flags.contains_key("quick") { 16 } else { 48 };
                figures::measured_serving(out, n, parse_codebook(args)?)?;
            } else {
                figures::continuous_batching(out)?;
            }
        }
        "tp" => {
            if args.flags.contains_key("measured") {
                let (degrees, n): (&[u64], usize) = if args.flags.contains_key("quick") {
                    (&[1, 2], 12)
                } else {
                    (&[1, 2, 4], 32)
                };
                figures::tensor_parallel_measured(out, degrees, n)?;
            } else {
                figures::tensor_parallel(out)?;
            }
        }
        "kernel-matmul" => {
            figures::kernel_matmul(out)?;
        }
        "kv" => {
            figures::kv_cache_quant(out)?;
        }
        "chaos" => {
            figures::chaos_serving(out, args.flags.contains_key("quick"))?;
        }
        "step" => {
            let name = args.get("model", "tiny");
            let model = quick_infer::model::Model::parse(&name)
                .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (try 'tiny')"))?;
            figures::step_throughput_with(
                out,
                model,
                128,
                &figures::DECODE_SWEEP_BATCHES,
                &quick_infer::util::Bench::fast(),
                parse_codebook(args)?,
            )?;
        }
        "all" => {
            figures::fig3(out)?;
            figures::fig7(out)?;
            figures::fig8(out)?;
            figures::table1(out)?;
            figures::prefix_cache(out)?;
            figures::continuous_batching(out)?;
            figures::tensor_parallel(out)?;
        }
        other => {
            bail!("unknown experiment '{other}' — valid targets: {SIMULATE_TARGETS}")
        }
    }
    Ok(())
}

/// Dispatch `quick-infer bench <target>`; unknown targets list the valid
/// ones (the same discoverability contract `simulate <unknown>` has).
fn bench_cmd(target: &str, args: &Args) -> Result<()> {
    match target {
        "kernels" => bench_kernels(
            args.get_num("k", 4096usize)?,
            args.get_num("n", 4096usize)?,
            args.get_num("group-size", 128usize)?,
            args.flags.get("json").map(String::as_str),
            args.flags.contains_key("quick"),
            args.flags.contains_key("decode-sweep"),
            args.flags.contains_key("attention"),
            args.flags.contains_key("lut"),
        ),
        "check" => bench_check(
            args.positional.get(1).map(String::as_str),
            args.flags.contains_key("strict"),
        ),
        other => bail!("unknown bench target '{other}' — valid targets: {BENCH_TARGETS}"),
    }
}

/// Default output path for a bench trajectory file: the nearest ancestor
/// directory holding ROADMAP.md or .git (the repo root), else the cwd.
fn bench_trajectory_path(name: &str) -> std::path::PathBuf {
    let mut dir = match std::env::current_dir() {
        Ok(d) => d,
        Err(_) => return std::path::PathBuf::from(name),
    };
    loop {
        if dir.join("ROADMAP.md").exists() || dir.join(".git").exists() {
            return dir.join(name);
        }
        if !dir.pop() {
            return std::path::PathBuf::from(name);
        }
    }
}

/// `bench kernels`: measured fused vs write-back M-sweep, the
/// decode-shape runtime sweep (pool-vs-spawn, SIMD-vs-scalar, dispatch
/// overhead), the fused dequant-attention KV sweep, the differential
/// gates, and the gpusim calibration — all emitted as one structured
/// JSON point (always written, even when a gate then fails the process).
#[allow(clippy::too_many_arguments)]
fn bench_kernels(
    k: usize,
    n: usize,
    group_size: usize,
    json: Option<&str>,
    quick: bool,
    decode_only: bool,
    attention_only: bool,
    lut_only: bool,
) -> Result<()> {
    use quick_infer::util::{Bench, Json};
    anyhow::ensure!(
        [decode_only, attention_only, lut_only].iter().filter(|b| **b).count() <= 1,
        "--decode-sweep, --attention, and --lut are mutually exclusive"
    );
    let (k, n, bench) = if quick {
        (512.min(k), 512.min(n), Bench::smoke())
    } else {
        (k, n, Bench::fast())
    };
    let out = &mut std::io::stdout();
    let report = if decode_only || attention_only || lut_only {
        None
    } else {
        Some(figures::kernel_matmul_with(
            out,
            k,
            n,
            group_size,
            &figures::KERNEL_MATMUL_BATCHES,
            &bench,
        )?)
    };
    let decode = if attention_only || lut_only {
        None
    } else {
        Some(figures::decode_sweep_with(
            out,
            k,
            n,
            group_size,
            &figures::DECODE_SWEEP_BATCHES,
            &bench,
        )?)
    };
    // LUT decoder sweep: part of every default run (including --quick CI
    // smoke — `bench check --strict` requires its rows and gate key),
    // skipped only when another sweep was requested alone.
    let lut = if decode_only || attention_only {
        None
    } else {
        Some(figures::lut_sweep_with(
            out,
            k,
            n,
            group_size,
            &figures::DECODE_SWEEP_BATCHES,
            &bench,
        )?)
    };
    // Attention sweep: head dim / group are the KV-cache contract
    // (d=128, g=KV_GROUP), not the weight-layer shape; --quick shrinks
    // the swept contexts and batches.
    let (attn_seqs, attn_batches): (&[usize], &[usize]) = if quick {
        (&[64, 256], &[1, 4])
    } else {
        (&figures::ATTN_SWEEP_SEQS, &figures::ATTN_SWEEP_BATCHES)
    };
    let attn = if decode_only || lut_only {
        None
    } else {
        Some(figures::attention_sweep_with(
            out,
            128,
            quick_infer::quant::KV_GROUP,
            attn_seqs,
            attn_batches,
            &bench,
        )?)
    };

    let path = match json {
        Some(p) => std::path::PathBuf::from(p),
        None => bench_trajectory_path("BENCH_kernels.json"),
    };
    let mut shape = std::collections::BTreeMap::new();
    shape.insert("k".to_string(), Json::Num(k as f64));
    shape.insert("n".to_string(), Json::Num(n as f64));
    shape.insert("group_size".to_string(), Json::Num(group_size as f64));
    let rows = Json::Arr(
        report
            .iter()
            .flat_map(|rep| rep.rows.iter())
            .map(|r| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("m".to_string(), Json::Num(r.m as f64));
                o.insert("fused_gflops".to_string(), Json::Num(r.fused_gflops));
                o.insert("writeback_gflops".to_string(), Json::Num(r.writeback_gflops));
                o.insert("fused_over_writeback".to_string(), Json::Num(r.speedup()));
                Json::Obj(o)
            })
            .collect(),
    );
    let decode_rows = Json::Arr(
        decode
            .iter()
            .flat_map(|d| d.rows.iter())
            .map(|r| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("m".to_string(), Json::Num(r.m as f64));
                o.insert("fused_pool_simd_gflops".to_string(), Json::Num(r.fused_pool_simd_gflops));
                o.insert(
                    "fused_pool_scalar_gflops".to_string(),
                    Json::Num(r.fused_pool_scalar_gflops),
                );
                o.insert(
                    "fused_spawn_simd_gflops".to_string(),
                    Json::Num(r.fused_spawn_simd_gflops),
                );
                o.insert(
                    "fused_spawn_scalar_gflops".to_string(),
                    Json::Num(r.fused_spawn_scalar_gflops),
                );
                o.insert(
                    "writeback_pool_simd_gflops".to_string(),
                    Json::Num(r.writeback_pool_simd_gflops),
                );
                o.insert("pool_dispatch_ns".to_string(), Json::Num(r.pool_dispatch_ns));
                o.insert("spawn_dispatch_ns".to_string(), Json::Num(r.spawn_dispatch_ns));
                o.insert(
                    "pool_dispatch_traced_ns".to_string(),
                    Json::Num(r.pool_dispatch_traced_ns),
                );
                o.insert("runtime_speedup".to_string(), Json::Num(r.runtime_speedup()));
                o.insert("fused_over_writeback".to_string(), Json::Num(r.fused_over_writeback()));
                Json::Obj(o)
            })
            .collect(),
    );
    let lut_rows = Json::Arr(
        lut.iter()
            .flat_map(|l| l.rows.iter())
            .map(|r| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("m".to_string(), Json::Num(r.m as f64));
                o.insert("shift_mask_gflops".to_string(), Json::Num(r.shift_mask_gflops));
                o.insert("lut_int4_gflops".to_string(), Json::Num(r.lut_int4_gflops));
                o.insert("lut_nf4_gflops".to_string(), Json::Num(r.lut_nf4_gflops));
                o.insert("lut_mxfp4_gflops".to_string(), Json::Num(r.lut_mxfp4_gflops));
                o.insert("lut_over_shift".to_string(), Json::Num(r.lut_over_shift()));
                o.insert("nonuniform_over_int4".to_string(), Json::Num(r.nonuniform_over_int4()));
                Json::Obj(o)
            })
            .collect(),
    );
    let attn_rows = Json::Arr(
        attn.iter()
            .flat_map(|a| a.rows.iter())
            .map(|r| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("seq".to_string(), Json::Num(r.seq as f64));
                o.insert("m".to_string(), Json::Num(r.m as f64));
                o.insert("q4_gflops".to_string(), Json::Num(r.q4_gflops));
                o.insert("q8_gflops".to_string(), Json::Num(r.q8_gflops));
                o.insert("dense_gflops".to_string(), Json::Num(r.dense_gflops));
                o.insert("q4_over_dense".to_string(), Json::Num(r.q4_over_dense()));
                Json::Obj(o)
            })
            .collect(),
    );
    // Each gate key is the worst divergence any sweep that ran observed;
    // keys for skipped sweeps are omitted.
    let mut fused_err = None;
    let mut wb_err = None;
    if let Some(d) = &decode {
        fused_err = Some(d.fused_rel_err);
        wb_err = Some(d.writeback_rel_err);
    }
    if let Some(rep) = &report {
        fused_err = Some(fused_err.unwrap_or(0.0).max(rep.fused_rel_err));
        wb_err = Some(wb_err.unwrap_or(0.0).max(rep.writeback_rel_err));
    }
    let attn_err = attn.as_ref().map(|a| a.q4_rel_err.max(a.q8_rel_err).max(a.dense_rel_err));
    let lut_err = lut.as_ref().map(|l| l.lut_rel_err);
    let mut gate = std::collections::BTreeMap::new();
    if let Some(e) = fused_err {
        gate.insert("fused_rel_err".to_string(), Json::Num(e));
    }
    if let Some(e) = wb_err {
        gate.insert("writeback_rel_err".to_string(), Json::Num(e));
    }
    if let Some(e) = attn_err {
        gate.insert("attn_rel_err".to_string(), Json::Num(e));
    }
    if let Some(e) = lut_err {
        gate.insert("lut_rel_err".to_string(), Json::Num(e));
    }
    gate.insert("tolerance".to_string(), Json::Num(1e-4));
    let mut extra = vec![
        ("bench", Json::Str("kernels".to_string())),
        ("quick", Json::Bool(quick)),
        ("shape", Json::Obj(shape)),
        ("rows", rows),
        ("differential_gate", Json::Obj(gate)),
    ];
    if let Some(level) = decode.as_ref().map(|d| d.simd_level).or(lut.as_ref().map(|l| l.simd_level))
    {
        extra.push(("simd_level", Json::Str(level.to_string())));
    }
    let mut acceptance = std::collections::BTreeMap::new();
    if let Some(d) = &decode {
        extra.push(("decode_sweep", decode_rows));
        let last = d.rows.last().expect("non-empty decode sweep");
        let min_gap = d
            .rows
            .iter()
            .map(figures::DecodeSweepRow::fused_over_writeback)
            .fold(f64::INFINITY, f64::min);
        acceptance
            .insert("runtime_speedup_at_max_m".to_string(), Json::Num(last.runtime_speedup()));
        acceptance.insert("runtime_speedup_bar".to_string(), Json::Num(1.5));
        acceptance.insert("min_fused_over_writeback".to_string(), Json::Num(min_gap));
        acceptance.insert("fused_over_writeback_bar".to_string(), Json::Num(1.0));
    }
    if let Some(l) = &lut {
        extra.push(("lut_sweep", lut_rows));
        acceptance.insert("lut_speedup".to_string(), Json::Num(l.lut_speedup()));
        acceptance.insert("lut_speedup_bar".to_string(), Json::Num(1.0));
        acceptance
            .insert("min_nonuniform_over_int4".to_string(), Json::Num(l.min_nonuniform_over_int4()));
        acceptance.insert("nonuniform_over_int4_bar".to_string(), Json::Num(0.95));
    }
    if !acceptance.is_empty() {
        extra.push(("acceptance", Json::Obj(acceptance)));
    }
    if attn.is_some() {
        extra.push(("attention_sweep", attn_rows));
    }
    if let Some(rep) = &report {
        extra.push(("calibrated_writeback_scale", Json::Num(rep.calibrated.writeback_scale)));
    }
    bench.write_json(&path, &extra)?;
    println!("\nwrote {}", path.display());

    // CI gate: structured output above, hard failure below — a diverging
    // kernel must fail the job even though the artifact was written.
    for (label, err) in [
        ("fused", fused_err),
        ("write-back", wb_err),
        ("attention", attn_err),
        ("lut", lut_err),
    ] {
        if let Some(e) = err {
            anyhow::ensure!(e <= 1e-4, "kernel divergence: {label} {e:.2e} vs naive exceeds 1e-4");
        }
    }
    Ok(())
}

/// `bench check`: re-open a previously written `BENCH_kernels.json`
/// (default: the repo-root trajectory path) and fail unless it parses
/// and its differential gate passed — the CI step that proves the
/// artifact the job uploads is a valid trajectory point.
fn bench_check(path: Option<&str>, strict: bool) -> Result<()> {
    use quick_infer::util::benchjson::check_bench_json;
    let path = match path {
        Some(p) => std::path::PathBuf::from(p),
        None => bench_trajectory_path("BENCH_kernels.json"),
    };
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    // The validation itself lives in util::benchjson (shared with the
    // failure-injection tests); this is just the CLI veneer around it.
    let summary = check_bench_json(&text, strict)
        .map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))?;
    if summary.placeholder {
        println!(
            "warning: {} is a committed placeholder with no measured runs; run \
             `cargo run --release -- bench kernels` to record real numbers \
             (CI validates with --strict)",
            path.display()
        );
        return Ok(());
    }
    let gate_summary = summary
        .gate
        .iter()
        .map(|(k, e)| format!("{k} {e:.2e}"))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "bench JSON ok: {} runs, {} decode-sweep rows, {} attention rows, {} lut rows, \
         gate [{gate_summary}] (tol {:.0e})",
        summary.runs,
        summary.decode_rows.unwrap_or(0),
        summary.attn_rows.unwrap_or(0),
        summary.lut_rows.unwrap_or(0),
        summary.tolerance
    );
    if let Some((speedup, gap)) = summary.acceptance {
        println!(
            "acceptance (informational): runtime speedup {speedup:.2}x (bar 1.5x), \
             min fused/wb {gap:.2}x (bar 1.0x)"
        );
    }
    if let Some((lut_speedup, nonuniform)) = summary.lut_acceptance {
        println!(
            "lut acceptance (informational): lut/shift-mask {lut_speedup:.2}x (bar 1.0x), \
             min nonuniform/int4-lut {nonuniform:.2}x (bar 0.95x)"
        );
    }
    Ok(())
}

fn quantize_demo(k: usize, n: usize, group_size: usize) -> Result<()> {
    use quick_infer::quant;
    let mut rng = Rng::seed_from_u64(7);
    let w: Vec<f32> = (0..k * n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
    let t = quant::quantize_groupwise(&w, k, n, group_size);
    let awq = quant::pack_awq(&t.codes, k, n);
    let quick = quant::pack_quick(&t.codes, k, n);
    let deq = quant::dequantize(&t);
    let max_err = w.iter().zip(&deq).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    println!("quantized {k}x{n} (group {group_size}):");
    println!(
        "  packed words: {} u32 ({} KiB, was {} KiB fp32)",
        awq.len(),
        awq.len() * 4 / 1024,
        k * n * 4 / 1024
    );
    println!("  AWQ[0..4]   = {:08x?}", &awq[..4.min(awq.len())]);
    println!("  QUICK[0..4] = {:08x?}", &quick[..4.min(quick.len())]);
    println!("  max |w - dq(q(w))| = {max_err:.5}");
    Ok(())
}

fn profile_cmd(gpu: &str, m: u64, n: u64, k: u64) -> Result<()> {
    let dev = match gpu.to_ascii_lowercase().as_str() {
        "4090" | "rtx4090" => Gpu::Rtx4090,
        "a6000" => Gpu::RtxA6000,
        "l40" => Gpu::L40,
        "a100" => Gpu::A100,
        other => bail!("unknown gpu '{other}' (4090|a6000|l40|a100)"),
    }
    .spec();
    for kind in KernelKind::ALL {
        let r = quick_infer::gpusim::report::profile(&dev, kind, m, n, k, &Calib::default());
        print!("{}", r.render());
        println!();
    }
    Ok(())
}

fn generate(
    artifacts: &str,
    kernel: &str,
    prompt: &str,
    max_new: usize,
    temperature: Option<f32>,
) -> Result<()> {
    use quick_infer::tokenizer::default_tokenizer;
    let tok = default_tokenizer();
    let rt = Runtime::open(artifacts)?;
    let mut engine = Engine::new(
        rt,
        EngineConfig { kernel: kernel.into(), max_queue: 4, ..Default::default() },
    )?;
    let ids = tok.encode(prompt);
    anyhow::ensure!(
        ids.len() + max_new <= engine.max_context(),
        "prompt ({} tokens) + max_new ({max_new}) exceeds the tiny model's {}-token context",
        ids.len(),
        engine.max_context()
    );
    println!("prompt: {prompt:?} -> {} tokens", ids.len());
    engine.submit(GenerationRequest {
        id: 0,
        prompt: ids,
        max_new_tokens: max_new,
        temperature,
        eos_token: None,
    })?;
    engine.run_to_completion()?;
    let c = engine.drain_completions().pop().expect("one completion");
    println!("generated ids: {:?}", c.tokens);
    println!("decoded:       {:?}", tok.decode(&c.tokens));
    println!("(random-weight tiny model: output is gibberish by design — this demo\n exercises the text->tokens->PJRT->tokens->text path end to end)");
    Ok(())
}

fn loadtest(rates: &str, n: usize) -> Result<()> {
    use quick_infer::coordinator::simserve::{simulate_online, SimPolicy};
    use quick_infer::model::Model;
    use quick_infer::workload::ShareGptLike;
    let dev = Gpu::RtxA6000.spec();
    let spec = Model::Vicuna13B.spec();
    println!("== latency vs offered load: {} on {} ({} reqs/point) ==", spec.name, dev.name, n);
    println!("{:>8} {:>8} {:>12} {:>12} {:>12} {:>12}", "rate", "kernel", "p50 e2e", "p90 e2e", "p99 e2e", "tok/s");
    for rate_s in rates.split(',') {
        let rate: f64 = rate_s.trim().parse().map_err(|_| anyhow::anyhow!("bad rate '{rate_s}'"))?;
        for kind in [KernelKind::Awq, KernelKind::Quick] {
            let reqs = ShareGptLike::new().online(n, rate, 77);
            let r = simulate_online(
                &dev,
                &spec,
                kind,
                &reqs,
                &SimPolicy::default(),
                &Calib::default(),
            )?;
            println!(
                "{:>8.1} {:>8} {:>11.2}s {:>11.2}s {:>11.2}s {:>12.1}",
                rate,
                kind.label(),
                r.e2e_quantile_s(0.5),
                r.e2e_quantile_s(0.9),
                r.e2e_quantile_s(0.99),
                r.gen_tok_per_s
            );
        }
    }
    Ok(())
}

fn info(artifacts: &str) -> Result<()> {
    println!("== devices ==");
    for g in Gpu::ALL {
        let s = g.spec();
        println!(
            "  {:10} {:3} SMs  {:7.1} TC TFLOPs  {:6.0} GB/s  {:3.0} GiB",
            s.name, s.sms, s.tc_tflops, s.dram_gbps, s.mem_gib
        );
    }
    println!("== kernel model spot check (A100, 256x8192x8192) ==");
    for kind in KernelKind::ALL {
        let p = quick_infer::gpusim::kernel_model::model_gemm(
            &Gpu::A100.spec(),
            kind,
            256,
            8192,
            8192,
            &Calib::default(),
        );
        println!("  {:6} {:8.1} TOPS  {:.1} us", kind.label(), p.tops, p.latency_s * 1e6);
    }
    if let Ok(rt) = Runtime::open(artifacts) {
        println!("== artifacts ({}) ==", artifacts);
        for a in &rt.manifest.artifacts {
            println!("  {:28} kind={:8} kernel={}", a.name, a.kind, a.kernel);
        }
    } else {
        println!("(no artifacts dir at '{artifacts}'; run `make artifacts`)");
    }
    Ok(())
}
