//! LLM architecture tables and per-layer GEMM/byte accounting.
//!
//! Figure 8 and Table 1 depend on the models only through (a) the GEMM
//! shapes of one decode/prefill step as a function of batch size and (b)
//! memory footprints (weights + KV cache) — both derivable from the
//! published architecture hyperparameters tabulated here.

mod specs;

pub use specs::{LlmSpec, Model};

/// One weight GEMM in a transformer forward pass: `y(M,N) = x(M,K) @ W(K,N)`
/// where `M` = tokens in flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub name: &'static str,
    pub k: u64,
    pub n: u64,
    /// How many times this GEMM runs per model forward (= n_layers for
    /// per-layer projections, 1 for the LM head).
    pub count: u64,
}

impl LlmSpec {
    /// The weight GEMMs of one forward pass (token count supplied later as
    /// M). Llama-family: fused-equivalent QKV (listed separately to keep
    /// shapes exact), attention output, and the SwiGLU MLP triple.
    pub fn gemms(&self) -> Vec<GemmShape> {
        let d = self.d_model;
        let kv_n = self.kv_heads * self.head_dim();
        vec![
            GemmShape { name: "wq", k: d, n: d, count: self.n_layers },
            GemmShape { name: "wk", k: d, n: kv_n, count: self.n_layers },
            GemmShape { name: "wv", k: d, n: kv_n, count: self.n_layers },
            GemmShape { name: "wo", k: d, n: d, count: self.n_layers },
            GemmShape { name: "w_gate", k: d, n: self.d_ff, count: self.n_layers },
            GemmShape { name: "w_up", k: d, n: self.d_ff, count: self.n_layers },
            GemmShape { name: "w_down", k: self.d_ff, n: d, count: self.n_layers },
            GemmShape { name: "lm_head", k: d, n: self.vocab, count: 1 },
        ]
    }

    pub fn head_dim(&self) -> u64 {
        self.d_model / self.n_heads
    }

    /// Total parameters in the weight GEMMs (embedding excluded — it is a
    /// lookup, not a GEMM, and is shared with lm_head in some checkpoints).
    pub fn gemm_params(&self) -> u64 {
        self.gemms().iter().map(|g| g.k * g.n * g.count).sum()
    }

    /// Approximate total parameter count (adds the embedding table).
    pub fn total_params(&self) -> u64 {
        self.gemm_params() + self.vocab * self.d_model
    }

    /// Weight bytes at the given precision (4-bit adds fp16 scales + packed
    /// zeros per 128-group).
    pub fn weight_bytes(&self, w4: bool) -> f64 {
        let p = self.gemm_params() as f64;
        let embed = (self.vocab * self.d_model) as f64 * 2.0; // always fp16
        if w4 {
            p * (0.5 + 2.5 / 128.0) + embed
        } else {
            p * 2.0 + embed
        }
    }

    /// KV-cache bytes for `batch` sequences of `seq_len` tokens (fp16).
    pub fn kv_bytes(&self, batch: u64, seq_len: u64) -> f64 {
        (2 * self.n_layers * batch * seq_len * self.kv_heads * self.head_dim()) as f64
            * 2.0
    }

    /// Peak activation bytes for a decode step at `batch` (rough: a few
    /// d_ff-wide fp16 buffers per token in flight).
    pub fn activation_bytes(&self, batch: u64) -> f64 {
        (batch * (2 * self.d_ff + 4 * self.d_model)) as f64 * 2.0 * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published() {
        // Within 5% of the named sizes (embedding/untied-head conventions
        // account for the slack).
        let cases = [
            (Model::Mistral7B, 7.2e9),
            (Model::Vicuna13B, 13.0e9),
            (Model::Llama2_13B, 13.0e9),
            (Model::Llama33B, 32.5e9),
            (Model::Llama2_70B, 69.0e9),
        ];
        for (m, want) in cases {
            let got = m.spec().total_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.08, "{:?}: {got:.3e} vs {want:.3e} ({rel:.2})", m);
        }
    }

    #[test]
    fn w4_weights_are_4x_smaller() {
        let s = Model::Llama2_13B.spec();
        let ratio = s.weight_bytes(false) / s.weight_bytes(true);
        assert!((3.5..4.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn gqa_models_have_smaller_kv() {
        let mistral = Model::Mistral7B.spec(); // 8 KV heads (GQA)
        let llama13 = Model::Llama2_13B.spec(); // full MHA
        let m = mistral.kv_bytes(1, 4096);
        let l = llama13.kv_bytes(1, 4096);
        assert!(m < l / 2.0, "GQA cache {m} not much smaller than MHA {l}");
    }

    #[test]
    fn gemm_shapes_positive_and_tiled() {
        for m in Model::ALL {
            for g in m.spec().gemms() {
                assert!(g.k >= 128 && g.n >= 128);
                assert_eq!(g.k % 64, 0, "{:?}/{}", m, g.name);
            }
        }
    }
}
