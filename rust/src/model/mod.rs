//! LLM architecture tables and per-layer GEMM/byte accounting.
//!
//! Figure 8 and Table 1 depend on the models only through (a) the GEMM
//! shapes of one decode/prefill step as a function of batch size and (b)
//! memory footprints (weights + KV cache) — both derivable from the
//! published architecture hyperparameters tabulated here.

mod specs;

pub use specs::{LlmSpec, Model};

/// One weight GEMM in a transformer forward pass: `y(M,N) = x(M,K) @ W(K,N)`
/// where `M` = tokens in flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    /// Projection name ("wq", "w_down", "lm_head", ...).
    pub name: &'static str,
    /// Reduction (input-feature) dimension.
    pub k: u64,
    /// Output-feature dimension.
    pub n: u64,
    /// How many times this GEMM runs per model forward (= n_layers for
    /// per-layer projections, 1 for the LM head).
    pub count: u64,
}

impl LlmSpec {
    /// The weight GEMMs of one forward pass (token count supplied later as
    /// M). Llama-family: fused-equivalent QKV (listed separately to keep
    /// shapes exact), attention output, and the SwiGLU MLP triple.
    pub fn gemms(&self) -> Vec<GemmShape> {
        let d = self.d_model;
        let kv_n = self.kv_heads * self.head_dim();
        vec![
            GemmShape { name: "wq", k: d, n: d, count: self.n_layers },
            GemmShape { name: "wk", k: d, n: kv_n, count: self.n_layers },
            GemmShape { name: "wv", k: d, n: kv_n, count: self.n_layers },
            GemmShape { name: "wo", k: d, n: d, count: self.n_layers },
            GemmShape { name: "w_gate", k: d, n: self.d_ff, count: self.n_layers },
            GemmShape { name: "w_up", k: d, n: self.d_ff, count: self.n_layers },
            GemmShape { name: "w_down", k: self.d_ff, n: d, count: self.n_layers },
            GemmShape { name: "lm_head", k: d, n: self.vocab, count: 1 },
        ]
    }

    /// The weight GEMMs of one forward pass as **one rank of a
    /// `tp`-way tensor-parallel group** sees them (Megatron partitioning):
    /// QKV / gate / up / lm_head are column-parallel (each rank owns
    /// `N / tp` output features), attention-output and MLP-down are
    /// row-parallel (each rank owns `K / tp` of the reduction, producing a
    /// partial sum the per-layer all-reduce combines — costed by
    /// `gpusim::collective`). `tp = 1` returns [`LlmSpec::gemms`] exactly.
    ///
    /// # Panics
    ///
    /// Panics if `tp` does not divide every partitioned dimension —
    /// including the query and KV head counts, since attention shards at
    /// head granularity (a fractional head per rank is physically
    /// meaningless even when `kv_heads * head_dim` happens to divide) —
    /// the same alignment discipline `quant::shard::try_shard_plan`
    /// enforces on the packed weights themselves. The Table-1/Fig-8
    /// models divide cleanly for tp ∈ {1, 2, 4, 8} except LLaMA-33B
    /// (52 heads), which supports tp ∈ {1, 2, 4}.
    pub fn tp_gemms(&self, tp: u64) -> Vec<GemmShape> {
        assert!(tp >= 1, "tp_degree must be >= 1 (got {tp})");
        assert_eq!(
            self.n_heads % tp,
            0,
            "{}: {} query heads not divisible by tp={tp}",
            self.name,
            self.n_heads
        );
        assert_eq!(
            self.kv_heads % tp,
            0,
            "{}: {} KV heads not divisible by tp={tp} (attention shards whole heads)",
            self.name,
            self.kv_heads
        );
        self.gemms()
            .into_iter()
            .map(|mut g| {
                match g.name {
                    // Row-parallel: reduction dimension is sharded.
                    "wo" | "w_down" => {
                        assert_eq!(g.k % tp, 0, "{}: K={} not divisible by tp={tp}", g.name, g.k);
                        g.k /= tp;
                    }
                    // Column-parallel: output features are sharded.
                    _ => {
                        assert_eq!(g.n % tp, 0, "{}: N={} not divisible by tp={tp}", g.name, g.n);
                        g.n /= tp;
                    }
                }
                g
            })
            .collect()
    }

    /// Attention head dimension (`d_model / n_heads`).
    pub fn head_dim(&self) -> u64 {
        self.d_model / self.n_heads
    }

    /// Total parameters in the weight GEMMs (embedding excluded — it is a
    /// lookup, not a GEMM, and is shared with lm_head in some checkpoints).
    pub fn gemm_params(&self) -> u64 {
        self.gemms().iter().map(|g| g.k * g.n * g.count).sum()
    }

    /// Approximate total parameter count (adds the embedding table).
    pub fn total_params(&self) -> u64 {
        self.gemm_params() + self.vocab * self.d_model
    }

    /// Weight bytes at the given precision (4-bit adds fp16 scales + packed
    /// zeros per 128-group).
    pub fn weight_bytes(&self, w4: bool) -> f64 {
        let p = self.gemm_params() as f64;
        let embed = (self.vocab * self.d_model) as f64 * 2.0; // always fp16
        if w4 {
            p * (0.5 + 2.5 / 128.0) + embed
        } else {
            p * 2.0 + embed
        }
    }

    /// KV-cache bytes for `batch` sequences of `seq_len` tokens (fp16).
    pub fn kv_bytes(&self, batch: u64, seq_len: u64) -> f64 {
        (2 * self.n_layers * batch * seq_len * self.kv_heads * self.head_dim()) as f64
            * 2.0
    }

    /// Peak activation bytes for a decode step at `batch` (rough: a few
    /// d_ff-wide fp16 buffers per token in flight).
    pub fn activation_bytes(&self, batch: u64) -> f64 {
        (batch * (2 * self.d_ff + 4 * self.d_model)) as f64 * 2.0 * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_published() {
        // Within 5% of the named sizes (embedding/untied-head conventions
        // account for the slack).
        let cases = [
            (Model::Mistral7B, 7.2e9),
            (Model::Vicuna13B, 13.0e9),
            (Model::Llama2_13B, 13.0e9),
            (Model::Llama33B, 32.5e9),
            (Model::Llama2_70B, 69.0e9),
        ];
        for (m, want) in cases {
            let got = m.spec().total_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.08, "{:?}: {got:.3e} vs {want:.3e} ({rel:.2})", m);
        }
    }

    #[test]
    fn w4_weights_are_4x_smaller() {
        let s = Model::Llama2_13B.spec();
        let ratio = s.weight_bytes(false) / s.weight_bytes(true);
        assert!((3.5..4.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn gqa_models_have_smaller_kv() {
        let mistral = Model::Mistral7B.spec(); // 8 KV heads (GQA)
        let llama13 = Model::Llama2_13B.spec(); // full MHA
        let m = mistral.kv_bytes(1, 4096);
        let l = llama13.kv_bytes(1, 4096);
        assert!(m < l / 2.0, "GQA cache {m} not much smaller than MHA {l}");
    }

    #[test]
    fn tp_gemms_shard_the_full_volume() {
        for m in [Model::Mistral7B, Model::Vicuna13B, Model::Llama2_70B] {
            let spec = m.spec();
            let full: u64 = spec.gemms().iter().map(|g| g.k * g.n * g.count).sum();
            for tp in [1u64, 2, 4, 8] {
                let sharded: u64 =
                    spec.tp_gemms(tp).iter().map(|g| g.k * g.n * g.count).sum();
                assert_eq!(sharded, full / tp, "{m:?} tp={tp}");
            }
            assert_eq!(spec.tp_gemms(1), spec.gemms(), "{m:?}: tp=1 must be identity");
        }
    }

    #[test]
    #[should_panic(expected = "KV heads not divisible")]
    fn tp_gemms_rejects_fractional_kv_heads() {
        // Mistral-7B has 8 KV heads: tp=16 would shard half a head even
        // though kv_n = 1024 divides 16 — head granularity must gate.
        Model::Mistral7B.spec().tp_gemms(16);
    }

    #[test]
    fn tp_gemms_split_the_declared_axes() {
        let spec = Model::Llama2_70B.spec();
        let by_name = |gs: &[GemmShape], name: &str| {
            gs.iter().find(|g| g.name == name).copied().unwrap()
        };
        let full = spec.gemms();
        let tp4 = spec.tp_gemms(4);
        // Row-parallel shards K, keeps N.
        for name in ["wo", "w_down"] {
            assert_eq!(by_name(&tp4, name).k, by_name(&full, name).k / 4);
            assert_eq!(by_name(&tp4, name).n, by_name(&full, name).n);
        }
        // Column-parallel shards N, keeps K.
        for name in ["wq", "wk", "wv", "w_gate", "w_up", "lm_head"] {
            assert_eq!(by_name(&tp4, name).n, by_name(&full, name).n / 4);
            assert_eq!(by_name(&tp4, name).k, by_name(&full, name).k);
        }
    }

    #[test]
    fn gemm_shapes_positive_and_tiled() {
        for m in Model::ALL {
            for g in m.spec().gemms() {
                assert!(g.k >= 128 && g.n >= 128);
                assert_eq!(g.k % 64, 0, "{:?}/{}", m, g.name);
            }
        }
    }
}
