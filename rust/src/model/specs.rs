//! Published hyperparameters for the paper's evaluation models.

/// Architecture hyperparameters of one LLM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlmSpec {
    /// Checkpoint name as the paper prints it.
    pub name: &'static str,
    /// Vocabulary size.
    pub vocab: u64,
    /// Hidden width.
    pub d_model: u64,
    /// Transformer layer count.
    pub n_layers: u64,
    /// Attention (query) head count.
    pub n_heads: u64,
    /// KV heads (< n_heads for GQA models).
    pub kv_heads: u64,
    /// MLP inner width (SwiGLU).
    pub d_ff: u64,
    /// Max context the checkpoint supports.
    pub max_seq: u64,
}

/// The models of Figures 8 and Table 1, plus the tiny runnable config used
/// by the end-to-end PJRT path (matching `python/compile/aot.py::CFG`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Model {
    /// Mistral-7B-v0.1 (GQA).
    Mistral7B,
    /// Vicuna-13B (LLaMA-13B fine-tune, full MHA).
    Vicuna13B,
    /// LLaMA-2-13B (full MHA).
    Llama2_13B,
    /// LLaMA-33B (the original LLaMA release).
    Llama33B,
    /// LLaMA-2-70B (GQA).
    Llama2_70B,
    /// The AOT-compiled tiny Llama actually served by the Rust engine.
    Tiny,
}

impl Model {
    /// Every tabulated model, evaluation models first.
    pub const ALL: [Model; 6] = [
        Model::Mistral7B,
        Model::Vicuna13B,
        Model::Llama2_13B,
        Model::Llama33B,
        Model::Llama2_70B,
        Model::Tiny,
    ];

    /// Parse a CLI model name: the `spec().name` spelling
    /// (case-insensitive) or the common short aliases
    /// (`tiny`, `mistral`, `vicuna`, `llama2-13b`, `llama-33b`,
    /// `llama2-70b`).
    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" | "tiny-llama" => Some(Model::Tiny),
            "mistral" | "mistral7b" | "mistral-7b" => Some(Model::Mistral7B),
            "vicuna" | "vicuna13b" | "vicuna-13b" => Some(Model::Vicuna13B),
            "llama2-13b" | "llama-2-13b" => Some(Model::Llama2_13B),
            "llama33b" | "llama-33b" => Some(Model::Llama33B),
            "llama2-70b" | "llama-2-70b" => Some(Model::Llama2_70B),
            _ => None,
        }
    }

    /// Published hyperparameters for this model.
    pub fn spec(self) -> LlmSpec {
        match self {
            Model::Mistral7B => LlmSpec {
                name: "Mistral-7B",
                vocab: 32000,
                d_model: 4096,
                n_layers: 32,
                n_heads: 32,
                kv_heads: 8,
                d_ff: 14336,
                max_seq: 8192,
            },
            // Vicuna-13B = fine-tuned LLaMA-13B.
            Model::Vicuna13B | Model::Llama2_13B => LlmSpec {
                name: if matches!(self, Model::Vicuna13B) {
                    "Vicuna-13B"
                } else {
                    "LLaMA-2-13B"
                },
                vocab: 32000,
                d_model: 5120,
                n_layers: 40,
                n_heads: 40,
                kv_heads: 40,
                d_ff: 13824,
                max_seq: 4096,
            },
            Model::Llama33B => LlmSpec {
                name: "LLaMA-33B",
                vocab: 32000,
                d_model: 6656,
                n_layers: 60,
                n_heads: 52,
                kv_heads: 52,
                d_ff: 17920,
                max_seq: 2048,
            },
            Model::Llama2_70B => LlmSpec {
                name: "LLaMA-2-70B",
                vocab: 32000,
                d_model: 8192,
                n_layers: 80,
                n_heads: 64,
                kv_heads: 8,
                d_ff: 28672,
                max_seq: 4096,
            },
            Model::Tiny => LlmSpec {
                name: "tiny-llama",
                vocab: 512,
                d_model: 256,
                n_layers: 4,
                n_heads: 4,
                kv_heads: 4,
                d_ff: 512,
                max_seq: 64,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_spec_name() {
        for m in Model::ALL {
            let name = m.spec().name;
            assert_eq!(Model::parse(name), Some(m), "{name}");
            assert_eq!(Model::parse(&name.to_ascii_uppercase()), Some(m), "{name} uppercased");
        }
        assert_eq!(Model::parse("tiny"), Some(Model::Tiny));
        assert_eq!(Model::parse("mistral"), Some(Model::Mistral7B));
        assert_eq!(Model::parse("gpt-5"), None);
    }
}
