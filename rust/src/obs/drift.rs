//! Model-vs-measured drift accounting.
//!
//! The ROADMAP's open seam: serving simulations run on `gpusim`-modeled
//! kernel latencies while the native kernel runtime measures real ones,
//! and the two meet only at one-shot calibration. The drift accountant
//! makes that seam continuously observable — every instrumented
//! [`crate::kernel::StepExecutor`] step records the modeled latency next
//! to the measured one, keyed by GEMM shape, and `report obs` surfaces
//! the running modeled/measured ratio per shape. A ratio near 1.0 means
//! the cost model tracks the silicon; a drifting shape pinpoints where
//! the model needs recalibration.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::Json;

use super::registry::Report;

/// A GEMM shape as the accountant keys it: `m` activation rows against
/// a `k x n` weight.
pub type ShapeKey = (u64, u64, u64);

/// Accumulated modeled-vs-measured time for one GEMM shape.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DriftStat {
    /// Total `gpusim`-modeled seconds attributed to this shape.
    pub modeled_s: f64,
    /// Total measured wall seconds for the same calls.
    pub measured_s: f64,
    /// Kernel invocations folded in.
    pub samples: u64,
}

impl DriftStat {
    /// Running modeled/measured ratio (1.0 = the model tracks the
    /// measurement exactly; 0 when nothing has been measured).
    ///
    /// Prefer [`DriftStat::ratio_opt`] when rendering: the 0.0 returned
    /// here for an unmeasured shape is a sentinel, indistinguishable
    /// from a catastrophic model overshoot.
    pub fn ratio(&self) -> f64 {
        self.ratio_opt().unwrap_or(0.0)
    }

    /// Running modeled/measured ratio, or `None` when nothing has been
    /// measured for this shape.
    pub fn ratio_opt(&self) -> Option<f64> {
        if self.measured_s <= 0.0 { None } else { Some(self.modeled_s / self.measured_s) }
    }
}

/// Process-wide ledger of modeled vs. measured GEMM latency per shape.
///
/// Recording takes a short lock and updates in place; a shape allocates
/// only on its first appearance, so steady-state accounting stays
/// allocation-free.
#[derive(Debug, Default)]
pub struct DriftAccountant {
    shapes: Mutex<BTreeMap<ShapeKey, DriftStat>>,
}

impl DriftAccountant {
    /// A fresh, empty accountant (tests; production code uses
    /// [`DriftAccountant::global`]).
    pub fn new() -> DriftAccountant {
        DriftAccountant::default()
    }

    /// The process-wide accountant instrumented executors report to.
    pub fn global() -> &'static DriftAccountant {
        static GLOBAL: OnceLock<DriftAccountant> = OnceLock::new();
        GLOBAL.get_or_init(DriftAccountant::new)
    }

    /// Fold one observation for shape `(m, k, n)`: `modeled_s` of
    /// `gpusim` cost next to `measured_s` of wall time, covering
    /// `samples` kernel invocations.
    pub fn record(&self, key: ShapeKey, modeled_s: f64, measured_s: f64, samples: u64) {
        let mut shapes = self.shapes.lock().unwrap_or_else(|e| e.into_inner());
        let stat = shapes.entry(key).or_default();
        stat.modeled_s += modeled_s;
        stat.measured_s += measured_s;
        stat.samples += samples;
    }

    /// `true` when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.shapes.lock().unwrap_or_else(|e| e.into_inner()).is_empty()
    }

    /// Point-in-time copy of every shape's accumulated stat, sorted by
    /// shape key.
    pub fn snapshot(&self) -> Vec<(ShapeKey, DriftStat)> {
        self.shapes
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, v)| (*k, *v))
            .collect()
    }

    /// Discard all recorded shapes.
    pub fn reset(&self) {
        self.shapes.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    /// Deterministic JSON: an array of `{m, k, n, modeled_s,
    /// measured_s, samples, ratio}` objects sorted by shape. The
    /// `ratio` key is omitted for a shape with no measured time — a
    /// sentinel 0.0 would read as extreme model overshoot.
    pub fn json(&self) -> Json {
        Json::Arr(
            self.snapshot()
                .into_iter()
                .map(|((m, k, n), s)| {
                    let mut o = BTreeMap::new();
                    o.insert("m".to_string(), Json::Num(m as f64));
                    o.insert("k".to_string(), Json::Num(k as f64));
                    o.insert("n".to_string(), Json::Num(n as f64));
                    o.insert("modeled_s".to_string(), Json::Num(s.modeled_s));
                    o.insert("measured_s".to_string(), Json::Num(s.measured_s));
                    o.insert("samples".to_string(), Json::Num(s.samples as f64));
                    if let Some(ratio) = s.ratio_opt() {
                        o.insert("ratio".to_string(), Json::Num(ratio));
                    }
                    Json::Obj(o)
                })
                .collect(),
        )
    }

    /// Per-shape drift table rendered through the shared [`Report`]
    /// writer.
    pub fn report(&self) -> String {
        let mut r = Report::new();
        r.section("model/measured drift (per GEMM shape)");
        let snap = self.snapshot();
        if snap.is_empty() {
            r.metric("(none)", "no instrumented steps recorded");
        }
        for ((m, k, n), s) in snap {
            let ratio = match s.ratio_opt() {
                Some(v) => format!("{v:.3}"),
                None => "n/a".to_string(),
            };
            r.metric(
                &format!("m{m} {k}x{n}"),
                format!(
                    "modeled {:>9.1} us, measured {:>9.1} us, ratio {ratio} (n={})",
                    s.modeled_s / s.samples.max(1) as f64 * 1e6,
                    s.measured_s / s.samples.max(1) as f64 * 1e6,
                    s.samples
                ),
            );
        }
        r.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_ratios() {
        let d = DriftAccountant::new();
        assert!(d.is_empty());
        d.record((8, 256, 512), 2e-6, 4e-6, 1);
        d.record((8, 256, 512), 2e-6, 4e-6, 1);
        d.record((1, 256, 256), 1e-6, 1e-6, 3);
        let snap = d.snapshot();
        assert_eq!(snap.len(), 2);
        // Sorted by shape key: (1, 256, 256) first.
        assert_eq!(snap[0].0, (1, 256, 256));
        assert_eq!(snap[0].1.samples, 3);
        assert!((snap[1].1.ratio() - 0.5).abs() < 1e-12);
        let doc = Json::parse(&d.json().to_string()).unwrap();
        let arr = doc.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!((arr[1].req("ratio").unwrap().as_f64().unwrap() - 0.5).abs() < 1e-12);
        let text = d.report();
        assert!(text.contains("m8 256x512"), "{text}");
        assert!(text.contains("ratio 0.500"), "{text}");
        d.reset();
        assert!(d.is_empty());
    }

    #[test]
    fn empty_ratio_is_zero() {
        assert_eq!(DriftStat::default().ratio(), 0.0);
        assert_eq!(DriftStat::default().ratio_opt(), None);
        let text = DriftAccountant::new().report();
        assert!(text.contains("no instrumented steps"), "{text}");
    }

    #[test]
    fn unmeasured_shape_renders_na_and_omits_json_ratio() {
        let d = DriftAccountant::new();
        d.record((4, 128, 128), 5e-6, 0.0, 0);
        let text = d.report();
        assert!(text.contains("ratio n/a"), "{text}");
        assert!(!text.contains("ratio 0.000"), "{text}");
        let doc = Json::parse(&d.json().to_string()).unwrap();
        let arr = doc.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert!(arr[0].get("ratio").is_none(), "sentinel ratio must be omitted");
        assert!(arr[0].get("modeled_s").is_some());
    }
}
