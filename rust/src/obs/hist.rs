//! Shared fixed-bucket latency histogram.
//!
//! Grown out of `coordinator::metrics` (PR 2) into the observability
//! layer so the engine, the serving simulations, and the metrics
//! [`super::Registry`] all accumulate latencies through one
//! implementation. Buckets are log-spaced powers of two from 1 µs, so
//! recording is a branch-free `partition_point` and the memory footprint
//! is constant regardless of sample count.

use std::time::Duration;

/// Fixed-bucket latency histogram (log-spaced, 1 µs .. ~1073 s).
///
/// Records are O(log buckets) with no allocation after construction;
/// quantiles interpolate linearly inside the winning bucket and are
/// clamped to the observed maximum, so `quantile_s(1.0) == max_s()`.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    bounds: Vec<f64>,
    count: u64,
    sum_s: f64,
    max_s: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram with the standard latency bucketing.
    pub fn new() -> Self {
        // 1us * 2^i, 30 buckets -> covers up to ~1073 s.
        let bounds: Vec<f64> = (0..30).map(|i| 1e-6 * (1u64 << i) as f64).collect();
        Histogram { buckets: vec![0; 31], bounds, count: 0, sum_s: 0.0, max_s: 0.0 }
    }

    /// Record one duration.
    pub fn record(&mut self, d: Duration) {
        self.record_s(d.as_secs_f64());
    }

    /// Record one latency in seconds.
    pub fn record_s(&mut self, s: f64) {
        let idx = self.bounds.partition_point(|&b| b < s);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_s += s;
        self.max_s = self.max_s.max(s);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// `true` when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Sum of all recorded latencies, in seconds.
    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    /// Mean recorded latency (0 when empty).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum_s / self.count as f64 }
    }

    /// Largest recorded latency (0 when empty).
    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile, interpolated linearly within the winning
    /// bucket (the pre-PR-6 version returned the bucket's raw upper
    /// bound, which inflated every quantile by up to 2x — a power-of-two
    /// bucket's width). Results never exceed [`Histogram::max_s`].
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            let before = acc;
            acc += c;
            if acc >= target && c > 0 {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi =
                    if i < self.bounds.len() { self.bounds[i] } else { self.max_s.max(lo) };
                let frac = (target - before) as f64 / c as f64;
                return (lo + (hi - lo) * frac).min(self.max_s);
            }
        }
        self.max_s
    }

    /// Fold another histogram into this one. Bucketing is identical by
    /// construction, so the merge is exact: count, sum, max, and every
    /// bucket equal what a single histogram recording both sample
    /// streams would hold.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, ob) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += ob;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        self.max_s = self.max_s.max(other.max_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_s(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        let p99 = h.quantile_s(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-3 && p99 <= h.max_s() * 2.0);
        assert!((h.mean_s() - 0.05).abs() < 0.01);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_s(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // All mass in one bucket: (2.048ms, 4.096ms]. The old
        // implementation returned the 4.096ms upper bound for every
        // quantile; interpolation must land strictly inside the bucket
        // for interior quantiles and never exceed the observed max.
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record_s(3e-3);
        }
        let p10 = h.quantile_s(0.10);
        let p90 = h.quantile_s(0.90);
        assert!(p10 > 2.048e-3 && p10 < 4.096e-3, "p10={p10}");
        assert!(p90 > p10, "p90={p90} p10={p10}");
        assert!(h.quantile_s(1.0) <= h.max_s());
    }

    #[test]
    fn quantile_monotone_in_q() {
        let mut h = Histogram::new();
        for i in 0..200u64 {
            h.record_s(1e-5 * (1 + i * 37 % 999) as f64);
        }
        let mut prev = 0.0;
        for i in 0..=20 {
            let v = h.quantile_s(i as f64 / 20.0);
            assert!(v >= prev, "q={}: {v} < {prev}", i as f64 / 20.0);
            prev = v;
        }
        assert!(prev <= h.max_s());
    }

    #[test]
    fn merge_is_exact() {
        let (mut a, mut b, mut whole) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 1..=500u64 {
            let s = i as f64 * 3.7e-5;
            if i % 2 == 0 { a.record_s(s) } else { b.record_s(s) }
            whole.record_s(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.sum_s() - whole.sum_s()).abs() < 1e-12);
        assert_eq!(a.max_s(), whole.max_s());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_s(q), whole.quantile_s(q), "q={q}");
        }
    }
}
