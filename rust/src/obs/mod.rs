//! Always-on observability: metrics registry, span tracer, and
//! model-vs-measured drift accounting (std-only).
//!
//! The paper's claim is a *measured* kernel gap, and the serving stack
//! above it schedules against *modeled* `gpusim` costs — this module
//! makes both sides continuously visible so every kernel and scheduling
//! change is verifiable rather than asserted:
//!
//! * [`Registry`] — process-wide named [`Counter`]s, [`Gauge`]s, and
//!   latency [`Histogram`]s with a deterministic JSON snapshot and a
//!   shared text [`Report`] writer (`quick-infer report obs`).
//! * [`trace`] — a low-overhead span tracer with lock-free per-thread
//!   ring buffers emitting Chrome-trace-event JSON; pass
//!   `--trace <path>` to any `simulate`/`bench` target and open the
//!   file in Perfetto. Disabled probes cost one atomic load; the
//!   `trace_off` cargo feature compiles them out entirely.
//! * [`DriftAccountant`] — per-GEMM-shape ledger of `gpusim`-modeled
//!   latency next to measured wall time, surfacing a running
//!   modeled/measured ratio per shape.
//!
//! Instrumented layers: `kernel::StepExecutor` (per-GEMM spans with
//! GFLOP/s + drift), `kernel::WorkerPool` (per-worker busy time,
//! steals, park/wake, queue depth), `kernel::PlanCache` (hit/miss),
//! `coordinator::ContinuousScheduler` (batch composition, chunked
//! prefill, preemptions), `coordinator::prefix` (hit rate, evictions),
//! and the serving `Engine` (TTFT/TPOT/E2E histograms). The hotpath
//! bench proves the instrumented kernel paths still allocate nothing in
//! steady state with tracing enabled.

pub mod drift;
pub mod hist;
pub mod registry;
pub mod trace;

pub use drift::{DriftAccountant, DriftStat};
pub use hist::Histogram;
pub use registry::{Counter, Gauge, HistogramHandle, Registry, Report};
