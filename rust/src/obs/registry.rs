//! Process-wide metrics registry: named counters, gauges, and latency
//! histograms with a deterministic JSON snapshot and a shared text
//! report writer.
//!
//! Instrumentation sites acquire a handle once (typically through a
//! `OnceLock`) and then update it forever after with a relaxed atomic op
//! or a short uncontended lock — no allocation, no name lookup — so the
//! registry can stay on in throughput runs without violating the
//! kernel runtime's zero-steady-state-allocation contract.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::util::Json;

use super::hist::Histogram;

fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A monotonically increasing event counter. Cloning shares the
/// underlying atomic, so a handle cached at an instrumentation site
/// observes [`Registry::reset`] (which zeroes in place).
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins signed instantaneous value (queue depths, active
/// worker counts). Cloning shares the underlying atomic.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Adjust the value by `delta` (may be negative).
    #[inline]
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A shared handle onto a registered [`Histogram`]. Records take a
/// short mutex (locking does not allocate), so the handle is safe on
/// serving paths guarded by the zero-alloc gate.
#[derive(Debug, Clone)]
pub struct HistogramHandle(Arc<Mutex<Histogram>>);

impl Default for HistogramHandle {
    fn default() -> Self {
        HistogramHandle(Arc::new(Mutex::new(Histogram::new())))
    }
}

impl HistogramHandle {
    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_s(d.as_secs_f64());
    }

    /// Record one latency in seconds.
    #[inline]
    pub fn record_s(&self, s: f64) {
        lock_ignore_poison(&self.0).record_s(s);
    }

    /// A point-in-time copy of the underlying histogram.
    pub fn snapshot(&self) -> Histogram {
        lock_ignore_poison(&self.0).clone()
    }
}

/// Process-wide registry of named metrics.
///
/// Names are `&'static str` in dotted `subsystem.metric` form (see the
/// README glossary). `BTreeMap` storage makes [`Registry::snapshot`]
/// and [`Registry::report`] deterministic: same metric values, same
/// bytes out.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<&'static str, Counter>>,
    gauges: Mutex<BTreeMap<&'static str, Gauge>>,
    hists: Mutex<BTreeMap<&'static str, HistogramHandle>>,
}

impl Registry {
    /// A fresh, empty registry (tests; production code uses
    /// [`Registry::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry every instrumentation site reports to.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// The counter named `name`, registering it on first use.
    pub fn counter(&self, name: &'static str) -> Counter {
        lock_ignore_poison(&self.counters).entry(name).or_default().clone()
    }

    /// The gauge named `name`, registering it on first use.
    pub fn gauge(&self, name: &'static str) -> Gauge {
        lock_ignore_poison(&self.gauges).entry(name).or_default().clone()
    }

    /// The histogram named `name`, registering it on first use.
    pub fn histogram(&self, name: &'static str) -> HistogramHandle {
        lock_ignore_poison(&self.hists).entry(name).or_default().clone()
    }

    /// Zero every metric in place. Handles cached at instrumentation
    /// sites stay valid and observe the reset.
    pub fn reset(&self) {
        for c in lock_ignore_poison(&self.counters).values() {
            c.0.store(0, Ordering::Relaxed);
        }
        for g in lock_ignore_poison(&self.gauges).values() {
            g.0.store(0, Ordering::Relaxed);
        }
        for h in lock_ignore_poison(&self.hists).values() {
            *lock_ignore_poison(&h.0) = Histogram::new();
        }
    }

    /// Deterministic JSON snapshot: `{"counters": {...}, "gauges":
    /// {...}, "histograms": {name: {count, mean_s, p50_s, p99_s,
    /// max_s}}}`, keys sorted.
    pub fn snapshot(&self) -> Json {
        let counters = lock_ignore_poison(&self.counters)
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v.get() as f64)))
            .collect();
        let gauges = lock_ignore_poison(&self.gauges)
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(v.get() as f64)))
            .collect();
        let hists = lock_ignore_poison(&self.hists)
            .iter()
            .map(|(k, v)| {
                let h = v.snapshot();
                let mut o = BTreeMap::new();
                o.insert("count".to_string(), Json::Num(h.count() as f64));
                o.insert("mean_s".to_string(), Json::Num(h.mean_s()));
                o.insert("p50_s".to_string(), Json::Num(h.quantile_s(0.5)));
                o.insert("p99_s".to_string(), Json::Num(h.quantile_s(0.99)));
                o.insert("max_s".to_string(), Json::Num(h.max_s()));
                (k.to_string(), Json::Obj(o))
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("counters".to_string(), Json::Obj(counters));
        doc.insert("gauges".to_string(), Json::Obj(gauges));
        doc.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(doc)
    }

    /// Human-readable snapshot rendered through the shared [`Report`]
    /// writer (the same formatting `EngineMetrics::report` uses, so
    /// serving output and `report obs` cannot drift apart).
    pub fn report(&self) -> String {
        let mut r = Report::new();
        r.section("counters");
        for (name, c) in lock_ignore_poison(&self.counters).iter() {
            r.metric(name, c.get().to_string());
        }
        r.section("gauges");
        for (name, g) in lock_ignore_poison(&self.gauges).iter() {
            r.metric(name, g.get().to_string());
        }
        r.section("histograms");
        for (name, h) in lock_ignore_poison(&self.hists).iter() {
            let h = h.snapshot();
            r.metric(name, format!("{}{}", Report::hist_ms(&h), format_args!(" (n={})", h.count())));
        }
        r.finish()
    }
}

/// Shared text-report writer: one formatting path for engine metric
/// summaries, registry dumps, and drift tables, so every surface that
/// prints counters renders them identically.
#[derive(Debug, Default)]
pub struct Report {
    out: String,
}

impl Report {
    /// An empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// One `label:    text` line; labels are padded to a 10-column
    /// gutter (the engine-report layout).
    pub fn line(&mut self, label: &str, text: impl AsRef<str>) -> &mut Report {
        let _ = writeln!(self.out, "{:<10}{}", format!("{label}:"), text.as_ref());
        self
    }

    /// An unindented section header (`name:`).
    pub fn section(&mut self, name: &str) -> &mut Report {
        let _ = writeln!(self.out, "{name}:");
        self
    }

    /// One indented `name  value` line under a [`Report::section`].
    pub fn metric(&mut self, name: &str, value: impl AsRef<str>) -> &mut Report {
        let _ = writeln!(self.out, "  {:<34} {}", name, value.as_ref());
        self
    }

    /// The canonical mean/p50/p99 rendering of a latency histogram, in
    /// milliseconds.
    pub fn hist_ms(h: &Histogram) -> String {
        format!(
            "mean {:.1} ms, p50 {:.1} ms, p99 {:.1} ms",
            h.mean_s() * 1e3,
            h.quantile_s(0.5) * 1e3,
            h.quantile_s(0.99) * 1e3,
        )
    }

    /// The finished report text (no trailing newline).
    pub fn finish(&mut self) -> String {
        let s = std::mem::take(&mut self.out);
        s.trim_end().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_roundtrip() {
        let r = Registry::new();
        let c = r.counter("test.count");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("test.count").get(), 5);
        let g = r.gauge("test.depth");
        g.set(7);
        g.add(-2);
        assert_eq!(r.gauge("test.depth").get(), 5);
        let h = r.histogram("test.lat_s");
        h.record_s(1e-3);
        h.record(Duration::from_millis(2));
        assert_eq!(r.histogram("test.lat_s").snapshot().count(), 2);
    }

    #[test]
    fn snapshot_is_deterministic() {
        let build = || {
            let r = Registry::new();
            // Register in different orders; BTreeMap sorts either way.
            for name in ["b.two", "a.one", "c.three"] {
                r.counter(name).add(name.len() as u64);
            }
            r.gauge("z.depth").set(-3);
            r.histogram("lat").record_s(0.25);
            r
        };
        let (r1, r2) = (build(), build());
        assert_eq!(r1.snapshot().to_string(), r2.snapshot().to_string());
        assert_eq!(r1.report(), r2.report());
        let doc = Json::parse(&r1.snapshot().to_string()).unwrap();
        assert_eq!(doc.req("counters").unwrap().req("a.one").unwrap().as_u64().unwrap(), 5);
        assert_eq!(doc.req("gauges").unwrap().req("z.depth").unwrap().as_f64().unwrap(), -3.0);
        assert!(
            doc.req("histograms").unwrap().req("lat").unwrap().req("p99_s").unwrap().as_f64().unwrap()
                > 0.0
        );
    }

    #[test]
    fn reset_preserves_cached_handles() {
        let r = Registry::new();
        let c = r.counter("x");
        let h = r.histogram("y");
        c.add(9);
        h.record_s(1.0);
        r.reset();
        assert_eq!(c.get(), 0);
        assert_eq!(h.snapshot().count(), 0);
        c.inc(); // cached handle still feeds the registry
        assert_eq!(r.counter("x").get(), 1);
    }

    #[test]
    fn report_writer_layout() {
        let mut rep = Report::new();
        rep.line("TTFT", "mean 1.0 ms");
        rep.section("counters");
        rep.metric("a.b", "3");
        let text = rep.finish();
        assert!(text.contains("TTFT:     mean 1.0 ms"), "{text}");
        assert!(text.contains("counters:\n  a.b"), "{text}");
    }
}
