//! Low-overhead span tracer with lock-free per-thread ring buffers,
//! exporting Chrome-trace-event JSON (loadable in Perfetto or
//! `chrome://tracing`).
//!
//! Design constraints, in priority order:
//!
//! 1. **Disabled is free.** [`enabled`] is one relaxed atomic load (and
//!    a compile-time constant `false` under the `trace_off` feature), so
//!    instrumentation can live permanently inside the kernel runtime's
//!    hot loops.
//! 2. **Enabled allocates only at thread warmup.** Each thread lazily
//!    allocates one fixed-capacity event ring on its first span and
//!    registers it in a global list; after that, recording a span is a
//!    slot write plus one `Release` store — no locks, no allocation.
//!    The hotpath bench's `CountingAlloc` gate holds with tracing on.
//! 3. **Concurrent emission is well-formed.** Rings are single-producer
//!    (the owning thread) and drop-newest when full — slots are never
//!    overwritten, so the exporter's `Acquire` read of the published
//!    length sees only fully written events and the emitted trace is
//!    never torn or interleaved.
//!
//! Spans are scoped guards ([`span`]) or explicit completes
//! ([`complete`]) carrying up to four numeric args each; thread names
//! surface as Chrome-trace `"M"` metadata records.

use std::cell::{OnceCell, UnsafeCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::util::Json;

/// Events each thread can hold before dropping (drop-newest keeps the
/// ring race-free; the `obs.trace.dropped` count is exported in the
/// trace metadata so truncation is visible).
pub const RING_CAP: usize = 8192;

/// `false` when the tracer was compiled out with the `trace_off` cargo
/// feature — every probe then folds to a constant branch.
pub const COMPILED: bool = cfg!(not(feature = "trace_off"));

#[derive(Clone, Copy)]
struct Event {
    name: &'static str,
    cat: &'static str,
    t0_ns: u64,
    dur_ns: u64,
    args: [(&'static str, f64); 4],
    n_args: u8,
}

const EMPTY_EVENT: Event =
    Event { name: "", cat: "", t0_ns: 0, dur_ns: 0, args: [("", 0.0); 4], n_args: 0 };

struct Ring {
    slots: Box<[UnsafeCell<Event>]>,
    /// Published event count. Only the owning thread stores (with
    /// `Release`, after fully writing slot `len`); readers load with
    /// `Acquire`, which makes every slot below the loaded value visible
    /// and immutable — published slots are never rewritten.
    len: AtomicUsize,
    dropped: AtomicU64,
    tid: u64,
    thread_name: String,
}

// SAFETY: the UnsafeCell slots follow an SPSC publication protocol —
// only the owning thread writes, only at index `len`, and publishes via
// a Release store of `len + 1`; concurrent readers touch only indices
// below an Acquire-loaded `len`. See `Ring::len`.
unsafe impl Sync for Ring {}
unsafe impl Send for Ring {}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

thread_local! {
    static LOCAL_RING: OnceCell<Arc<Ring>> = const { OnceCell::new() };
}

/// Whether spans are being recorded right now. One relaxed load; the
/// hot-path probe every instrumentation site gates on.
#[inline]
pub fn enabled() -> bool {
    COMPILED && ENABLED.load(Ordering::Relaxed)
}

/// Start recording spans (also pins the trace epoch so timestamps start
/// near zero). A no-op when compiled out via `trace_off`.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording spans. Already-recorded events stay exportable.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Nanoseconds since the trace epoch (pinned on first [`enable`]).
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn register_ring() -> Arc<Ring> {
    let mut all = rings().lock().unwrap_or_else(|e| e.into_inner());
    let tid = all.len() as u64 + 1;
    let thread_name = std::thread::current()
        .name()
        .map(str::to_string)
        .unwrap_or_else(|| format!("thread-{tid}"));
    let slots: Box<[UnsafeCell<Event>]> =
        (0..RING_CAP).map(|_| UnsafeCell::new(EMPTY_EVENT)).collect();
    let ring = Arc::new(Ring {
        slots,
        len: AtomicUsize::new(0),
        dropped: AtomicU64::new(0),
        tid,
        thread_name,
    });
    all.push(Arc::clone(&ring));
    ring
}

#[inline]
fn record(ev: Event) {
    LOCAL_RING.with(|cell| {
        let ring = cell.get_or_init(register_ring);
        let len = ring.len.load(Ordering::Relaxed);
        if len >= RING_CAP {
            ring.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // SAFETY: sole producer; slot `len` is unpublished until the
        // Release store below.
        unsafe { *ring.slots[len].get() = ev };
        ring.len.store(len + 1, Ordering::Release);
    });
}

/// Record a completed span explicitly: it started `start_ns` after the
/// trace epoch and ran for `dur_ns`. Up to four `args` are kept (the
/// Chrome-trace `args` object); extras are dropped. No-op when
/// disabled.
pub fn complete(
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    dur_ns: u64,
    args: &[(&'static str, f64)],
) {
    if !enabled() {
        return;
    }
    let mut ev = Event { name, cat, t0_ns: start_ns, dur_ns, ..EMPTY_EVENT };
    for (i, &(k, v)) in args.iter().take(4).enumerate() {
        ev.args[i] = (k, v);
        ev.n_args = (i + 1) as u8;
    }
    record(ev);
}

/// A scoped span: records one complete event from construction to drop.
/// Construction while the tracer is disabled costs one atomic load and
/// records nothing.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    args: [(&'static str, f64); 4],
    n_args: u8,
    armed: bool,
}

impl Span {
    /// Attach a numeric arg discovered mid-span (e.g. how many tasks a
    /// worker ended up claiming). At most four args are kept.
    #[inline]
    pub fn arg(&mut self, key: &'static str, value: f64) {
        if self.armed && (self.n_args as usize) < 4 {
            self.args[self.n_args as usize] = (key, value);
            self.n_args += 1;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            let mut ev = Event {
                name: self.name,
                cat: self.cat,
                t0_ns: self.start_ns,
                dur_ns: now_ns().saturating_sub(self.start_ns),
                args: self.args,
                n_args: self.n_args,
            };
            if !enabled() {
                return;
            }
            ev.dur_ns = ev.dur_ns.max(1);
            record(ev);
        }
    }
}

/// Open a scoped span named `name` in category `cat`.
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    let armed = enabled();
    Span {
        name,
        cat,
        start_ns: if armed { now_ns() } else { 0 },
        args: [("", 0.0); 4],
        n_args: 0,
        armed,
    }
}

/// Open a scoped span carrying one numeric arg.
#[inline]
pub fn span1(name: &'static str, cat: &'static str, k0: &'static str, v0: f64) -> Span {
    let mut s = span(name, cat);
    s.arg(k0, v0);
    s
}

/// Open a scoped span carrying two numeric args.
#[inline]
pub fn span2(
    name: &'static str,
    cat: &'static str,
    k0: &'static str,
    v0: f64,
    k1: &'static str,
    v1: f64,
) -> Span {
    let mut s = span(name, cat);
    s.arg(k0, v0);
    s.arg(k1, v1);
    s
}

/// Total events currently held across all thread rings.
pub fn events_recorded() -> u64 {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    all.iter().map(|r| r.len.load(Ordering::Acquire) as u64).sum()
}

/// Events rejected because a thread's ring was full.
pub fn events_dropped() -> u64 {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    all.iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
}

/// Number of threads that have recorded at least one event.
pub fn threads_with_events() -> usize {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    all.iter().filter(|r| r.len.load(Ordering::Acquire) > 0).count()
}

/// Discard all recorded events (ring capacity and registration are
/// kept). **Requires quiescence**: call only while the tracer is
/// disabled and no instrumented work is in flight, otherwise a thread
/// mid-record may republish stale slots.
pub fn reset() {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    for r in all.iter() {
        r.len.store(0, Ordering::Release);
        r.dropped.store(0, Ordering::Relaxed);
    }
}

fn event_json(ring: &Ring, ev: &Event) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(ev.name.to_string()));
    o.insert("cat".to_string(), Json::Str(ev.cat.to_string()));
    o.insert("ph".to_string(), Json::Str("X".to_string()));
    o.insert("ts".to_string(), Json::Num(ev.t0_ns as f64 / 1e3));
    o.insert("dur".to_string(), Json::Num(ev.dur_ns as f64 / 1e3));
    o.insert("pid".to_string(), Json::Num(1.0));
    o.insert("tid".to_string(), Json::Num(ring.tid as f64));
    let mut args = BTreeMap::new();
    for &(k, v) in ev.args.iter().take(ev.n_args as usize) {
        args.insert(k.to_string(), Json::Num(v));
    }
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

fn meta_json(tid: u64, which: &str, name: &str) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(which.to_string()));
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("pid".to_string(), Json::Num(1.0));
    o.insert("tid".to_string(), Json::Num(tid as f64));
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name.to_string()));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

/// Everything recorded so far as a Chrome-trace-event JSON document
/// (`{"traceEvents": [...]}` object form, `ts`/`dur` in microseconds).
pub fn chrome_trace_json() -> Json {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    let mut events = vec![meta_json(0, "process_name", "quick-infer")];
    for ring in all.iter() {
        events.push(meta_json(ring.tid, "thread_name", &ring.thread_name));
        let n = ring.len.load(Ordering::Acquire).min(RING_CAP);
        for slot in ring.slots.iter().take(n) {
            // SAFETY: indices below the Acquire-loaded `len` are fully
            // published and never rewritten (drop-newest ring).
            let ev = unsafe { *slot.get() };
            events.push(event_json(ring, &ev));
        }
    }
    // `rings()` is a non-reentrant mutex and `all` is still held here, so
    // the dropped total must come from the guard, not events_dropped().
    let dropped: u64 = all.iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum();
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("droppedEvents".to_string(), Json::Num(dropped as f64));
    Json::Obj(doc)
}

/// Write [`chrome_trace_json`] to `path` (open the file in Perfetto /
/// `chrome://tracing` to inspect the run).
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    std::fs::write(path, format!("{}\n", chrome_trace_json()))
        .map_err(|e| anyhow::anyhow!("writing trace to {}: {e}", path.display()))?;
    Ok(())
}

/// Serializes unit tests that toggle the process-global tracer (they
/// share one test binary); every test that calls [`enable`]/[`disable`]
/// must hold this guard, whichever module it lives in.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_guard as test_lock;

    fn count_named(doc: &Json, name: &str) -> usize {
        doc.req("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("name").map(|n| n.as_str().unwrap() == name).unwrap_or(false))
            .count()
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_lock();
        disable();
        {
            let _s = span("obs_test_disabled_span", "test");
        }
        complete("obs_test_disabled_complete", "test", 0, 10, &[]);
        let doc = chrome_trace_json();
        assert_eq!(count_named(&doc, "obs_test_disabled_span"), 0);
        assert_eq!(count_named(&doc, "obs_test_disabled_complete"), 0);
    }

    #[test]
    fn spans_round_trip_through_chrome_json() {
        let _g = test_lock();
        enable();
        {
            let mut s = span1("obs_test_span", "test", "m", 32.0);
            s.arg("extra", 7.0);
        }
        complete("obs_test_complete", "test", 5_000, 2_000, &[("k", 1.0)]);
        disable();
        let doc = chrome_trace_json();
        assert!(count_named(&doc, "obs_test_span") >= 1);
        assert!(count_named(&doc, "obs_test_complete") >= 1);
        // Re-parse through the strict JSON parser: the export is valid.
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        let ev = events
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str().unwrap() == "obs_test_complete") == Some(true))
            .unwrap();
        assert_eq!(ev.req("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.req("ts").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(ev.req("dur").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(ev.req("args").unwrap().req("k").unwrap().as_f64().unwrap(), 1.0);
        // Thread metadata is present for this thread's ring.
        assert!(events.iter().any(|e| {
            e.get("ph").map(|p| p.as_str().unwrap() == "M") == Some(true)
                && e.get("name").map(|n| n.as_str().unwrap() == "thread_name") == Some(true)
        }));
    }

    #[test]
    fn ring_drops_newest_when_full() {
        let _g = test_lock();
        enable();
        for _ in 0..2 * RING_CAP {
            complete("obs_test_flood", "test", 0, 1, &[]);
        }
        disable();
        assert!(events_dropped() > 0);
        // The ring stayed at capacity: no wraparound, no torn slots.
        let all = rings().lock().unwrap();
        let mine = all.iter().map(|r| r.len.load(Ordering::Acquire)).max().unwrap();
        assert!(mine <= RING_CAP);
    }
}
