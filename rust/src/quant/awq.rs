//! Asymmetric per-group 4-bit weight quantization (AWQ storage convention).
//!
//! Matrices are row-major `(K, N)` — `K` in-features (reduction axis, groups
//! run along it), `N` out-features — multiplied as `y = x @ w`.
//!
//! Codes may index any 16-entry [`Codebook`] grid
//! ([`quantize_groupwise_codebook`]); the stock path
//! ([`quantize_groupwise`]) is the uniform INT4 grid, for which decode
//! `(table[q] - z) * s` degenerates to the classic `(q - z) * s`.

use super::codebook::{nearest_code, CodebookKind};

/// Quantization bit width.
pub const QBITS: u32 = 4;
/// Largest representable code (`2^QBITS - 1`).
pub const QMAX: i32 = (1 << QBITS) - 1; // 15

/// A group-quantized weight matrix in logical (unpacked) form.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedTensor {
    /// 4-bit codes in `[0, 15]`, row-major `(k, n)`.
    pub codes: Vec<i32>,
    /// Per-group scales, row-major `(k / group_size, n)`.
    pub scales: Vec<f32>,
    /// Per-group zero-points (integral, stored as f32), same shape as scales.
    pub zeros: Vec<f32>,
    pub k: usize,
    pub n: usize,
    pub group_size: usize,
    /// Which 16-entry grid the codes index (uniform INT4 for the stock
    /// AWQ path; NF4/MXFP4 decode through the LUT tier).
    pub codebook: CodebookKind,
}

impl QuantizedTensor {
    /// Number of quantization groups along K.
    pub fn groups(&self) -> usize {
        self.k / self.group_size
    }
}

/// Quantize `w` (row-major `(k, n)`) to 4 bits with groups of `group_size`
/// along K. Mirrors `quantize.quantize_groupwise` exactly (same rounding:
/// round-half-even via `f32::round_ties_even`, numpy's default).
pub fn quantize_groupwise(w: &[f32], k: usize, n: usize, group_size: usize) -> QuantizedTensor {
    assert_eq!(w.len(), k * n, "weight buffer size mismatch");
    assert!(
        group_size > 0 && k % group_size == 0,
        "K={k} not divisible by group_size={group_size}"
    );
    let g = k / group_size;
    let mut scales = vec![0f32; g * n];
    let mut zeros = vec![0f32; g * n];
    let mut codes = vec![0i32; k * n];

    // Row-major passes (perf pass §Perf iteration 1): the natural
    // per-(group, col) loop strides by `n` floats per access and was
    // cache-hostile at 4k x 4k (228 ms); scanning rows sequentially with
    // per-column running min/max buffers is pure streaming.
    let mut wmin = vec![0f32; n];
    let mut wmax = vec![0f32; n];
    for gi in 0..g {
        let base = gi * group_size * n;
        wmin.copy_from_slice(&w[base..base + n]);
        wmax.copy_from_slice(&w[base..base + n]);
        for r in 1..group_size {
            let row = &w[base + r * n..base + (r + 1) * n];
            for col in 0..n {
                let v = row[col];
                if v < wmin[col] {
                    wmin[col] = v;
                }
                if v > wmax[col] {
                    wmax[col] = v;
                }
            }
        }
        let srow = &mut scales[gi * n..(gi + 1) * n];
        let zrow = &mut zeros[gi * n..(gi + 1) * n];
        for col in 0..n {
            let mut s = (wmax[col] - wmin[col]) / QMAX as f32;
            if s <= 0.0 {
                s = 1.0; // degenerate all-equal group (matches Python guard)
            }
            srow[col] = s;
            zrow[col] = (-wmin[col] / s).round_ties_even().clamp(0.0, QMAX as f32);
        }
        for r in 0..group_size {
            let off = base + r * n;
            let (wrow, crow) = (&w[off..off + n], &mut codes[off..off + n]);
            for col in 0..n {
                let q = (wrow[col] / srow[col]).round_ties_even() + zrow[col];
                crow[col] = q.clamp(0.0, QMAX as f32) as i32;
            }
        }
    }
    QuantizedTensor { codes, scales, zeros, k, n, group_size, codebook: CodebookKind::Int4Uniform }
}

/// Quantize `w` onto an arbitrary 16-entry codebook grid.
///
/// For [`CodebookKind::Int4Uniform`] this is exactly
/// [`quantize_groupwise`] (asymmetric min/max affine). The non-uniform
/// grids (NF4, MXFP4) are symmetric, so the zero-points are all `0.0`
/// and the per-`(group, column)` scale is `absmax / max|table|`;
/// codes are nearest-entry in code space (`w / s`), first minimizer
/// winning ties — the `np.argmin` convention the Python golden-fixture
/// mirror uses.
pub fn quantize_groupwise_codebook(
    w: &[f32],
    k: usize,
    n: usize,
    group_size: usize,
    kind: CodebookKind,
) -> QuantizedTensor {
    if kind.is_uniform() {
        return quantize_groupwise(w, k, n, group_size);
    }
    assert_eq!(w.len(), k * n, "weight buffer size mismatch");
    assert!(
        group_size > 0 && k % group_size == 0,
        "K={k} not divisible by group_size={group_size}"
    );
    let cb = kind.table();
    let cb_max = cb.absmax();
    let g = k / group_size;
    let mut scales = vec![0f32; g * n];
    let zeros = vec![0f32; g * n];
    let mut codes = vec![0i32; k * n];
    // Same row-major streaming passes as the uniform path: absmax per
    // column, then a code pass over the group's rows.
    let mut wabs = vec![0f32; n];
    for gi in 0..g {
        let base = gi * group_size * n;
        wabs.iter_mut().zip(&w[base..base + n]).for_each(|(a, &v)| *a = v.abs());
        for r in 1..group_size {
            let row = &w[base + r * n..base + (r + 1) * n];
            for col in 0..n {
                let v = row[col].abs();
                if v > wabs[col] {
                    wabs[col] = v;
                }
            }
        }
        let srow = &mut scales[gi * n..(gi + 1) * n];
        for col in 0..n {
            let mut s = wabs[col] / cb_max;
            if s <= 0.0 {
                s = 1.0; // degenerate all-zero group (uniform-path guard)
            }
            srow[col] = s;
        }
        for r in 0..group_size {
            let off = base + r * n;
            let (wrow, crow) = (&w[off..off + n], &mut codes[off..off + n]);
            for col in 0..n {
                crow[col] = nearest_code(cb, wrow[col] / srow[col]);
            }
        }
    }
    QuantizedTensor { codes, scales, zeros, k, n, group_size, codebook: kind }
}

/// Dequantize back to f32: `(table[q] - z) * s` per group (plain
/// `(q - z) * s` on the uniform grid). Inverse of
/// [`quantize_groupwise`] / [`quantize_groupwise_codebook`] up to
/// quantization error.
///
/// Allocates a fresh buffer per call; hot loops (the write-back kernel's
/// scratch pass, the hotpath bench) should reuse one via
/// [`dequantize_into`].
pub fn dequantize(t: &QuantizedTensor) -> Vec<f32> {
    let mut out = vec![0f32; t.k * t.n];
    dequantize_into(t, &mut out);
    out
}

/// [`dequantize`] into a caller-provided `k * n` buffer, so per-call
/// allocation stays out of hot loops.
///
/// # Panics
///
/// Panics unless `out.len() == t.k * t.n`.
pub fn dequantize_into(t: &QuantizedTensor, out: &mut [f32]) {
    assert_eq!(
        out.len(),
        t.k * t.n,
        "dequantize_into: buffer holds {} values, shape ({}, {}) needs {}",
        out.len(),
        t.k,
        t.n,
        t.k * t.n
    );
    // The table walk covers the uniform grid too (identity table), and
    // `table[q] - z` there is exactly `q as f32 - z`: bit-identical to
    // the historical formula.
    let lut = &t.codebook.table().values;
    for row in 0..t.k {
        let gi = row / t.group_size;
        let srow = &t.scales[gi * t.n..(gi + 1) * t.n];
        let zrow = &t.zeros[gi * t.n..(gi + 1) * t.n];
        let crow = &t.codes[row * t.n..(row + 1) * t.n];
        let orow = &mut out[row * t.n..(row + 1) * t.n];
        for col in 0..t.n {
            orow[col] = (lut[crow[col] as usize & 0xF] - zrow[col]) * srow[col];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_w(k: usize, n: usize, seed: u64) -> Vec<f32> {
        // xorshift — deterministic, no external dep needed here
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..k * n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn roundtrip_error_bounded() {
        let (k, n, g) = (128, 32, 32);
        let w = rand_w(k, n, 7);
        let t = quantize_groupwise(&w, k, n, g);
        let w2 = dequantize(&t);
        for row in 0..k {
            let gi = row / g;
            for col in 0..n {
                let err = (w[row * n + col] - w2[row * n + col]).abs();
                let half_lsb = t.scales[gi * n + col] * 0.5 + 1e-6;
                assert!(err <= half_lsb, "err {err} > {half_lsb}");
            }
        }
    }

    #[test]
    fn codes_in_range() {
        let w = rand_w(64, 16, 3);
        let t = quantize_groupwise(&w, 64, 16, 64);
        assert!(t.codes.iter().all(|&c| (0..=QMAX).contains(&c)));
        assert!(t.zeros.iter().all(|&z| z == z.trunc() && z >= 0.0));
    }

    #[test]
    fn degenerate_group_has_unit_scale() {
        let w = vec![0.25f32; 32 * 8];
        let t = quantize_groupwise(&w, 32, 8, 32);
        assert!(t.scales.iter().all(|&s| s == 1.0));
        let w2 = dequantize(&t);
        for (a, b) in w.iter().zip(&w2) {
            assert!((a - b).abs() <= 0.5 + 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_bad_group() {
        quantize_groupwise(&[0.0; 96], 12, 8, 8);
    }

    #[test]
    fn dequantize_into_matches_allocating_variant() {
        let (k, n, g) = (96, 24, 32);
        let t = quantize_groupwise(&rand_w(k, n, 11), k, n, g);
        let fresh = dequantize(&t);
        let mut reused = vec![f32::NAN; k * n];
        dequantize_into(&t, &mut reused);
        assert_eq!(fresh, reused);
        // The buffer really is reused: a second pass overwrites in place.
        dequantize_into(&t, &mut reused);
        assert_eq!(fresh, reused);
    }

    #[test]
    fn codebook_roundtrip_error_bounded_by_grid_gap() {
        // Nearest-entry rounding: per element the reconstruction error
        // is at most half the widest adjacent gap of the grid, scaled.
        let (k, n, g) = (96, 24, 32);
        let w = rand_w(k, n, 13);
        for kind in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let cb = kind.table();
            let mut sorted = cb.values;
            sorted.sort_by(f32::total_cmp);
            let half_gap =
                sorted.windows(2).map(|p| (p[1] - p[0]) / 2.0).fold(0f32, f32::max);
            let t = quantize_groupwise_codebook(&w, k, n, g, kind);
            assert_eq!(t.codebook, kind);
            assert!(t.zeros.iter().all(|&z| z == 0.0), "{kind:?} grids are symmetric");
            assert!(t.codes.iter().all(|&c| (0..=QMAX).contains(&c)));
            let back = dequantize(&t);
            for row in 0..k {
                let gi = row / g;
                for col in 0..n {
                    let err = (w[row * n + col] - back[row * n + col]).abs();
                    let bound = t.scales[gi * n + col] * half_gap + 1e-5;
                    assert!(err <= bound, "{kind:?} ({row},{col}): {err} > {bound}");
                }
            }
        }
    }

    #[test]
    fn codebook_uniform_delegates_to_stock_path() {
        let (k, n, g) = (64, 16, 32);
        let w = rand_w(k, n, 29);
        let a = quantize_groupwise(&w, k, n, g);
        let b = quantize_groupwise_codebook(&w, k, n, g, CodebookKind::Int4Uniform);
        assert_eq!(a, b);
        assert_eq!(b.codebook, CodebookKind::Int4Uniform);
    }

    #[test]
    fn codebook_degenerate_group_has_unit_scale() {
        let w = vec![0f32; 32 * 8];
        for kind in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let t = quantize_groupwise_codebook(&w, 32, 8, 32, kind);
            assert!(t.scales.iter().all(|&s| s == 1.0));
            // An all-zero group decodes back to exact zeros (both grids
            // contain 0.0).
            assert!(dequantize(&t).iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    #[should_panic(expected = "buffer holds")]
    fn dequantize_into_rejects_wrong_size() {
        let t = quantize_groupwise(&rand_w(32, 8, 1), 32, 8, 32);
        dequantize_into(&t, &mut [0f32; 7]);
    }
}
