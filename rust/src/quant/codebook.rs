//! 16-entry decode codebooks for non-uniform 4-bit weight formats.
//!
//! The shift-mask decoders in [`super::decode`] hard-code the uniform
//! INT4 grid: a nibble `q` decodes to `(q - zero) * scale`. FLUTE-style
//! table-lookup decode generalizes the grid to an arbitrary 16-entry
//! [`Codebook`]: `q` indexes a value table and the decode becomes
//! `(table[q] - zero) * scale` — the *same* affine, so uniform INT4 is
//! the identity codebook (`table[q] == q as f32`, bit-identical to the
//! shift-mask path) while NF4 (QLoRA's normal-float grid) and MXFP4
//! (the OCP microscaling E2M1 grid) ride through the very same kernels
//! at the very same speed: the lookup is an in-register byte shuffle
//! (`vpermps` pair on AVX2, `tbl` on NEON, a scalar table walk in the
//! portable fallback), not a gather.
//!
//! Quantization onto a non-uniform codebook is absmax-scaled
//! nearest-entry rounding with a zero zero-point (both NF4 and MXFP4
//! are symmetric grids): `scale = absmax / max|table|` per
//! `(group, column)`, `code = argmin_q |w / scale - table[q]|` with the
//! first minimizing entry winning ties — exactly NumPy's `argmin`
//! convention, which the golden-fixture mirror in
//! `python/tests/gen_golden_fixtures.py` relies on.

/// Which 16-entry value grid a 4-bit tensor's codes index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CodebookKind {
    /// The uniform grid `table[q] = q` — stock AWQ/QUICK INT4. Decodes
    /// bit-identically through the shift-mask and LUT tiers.
    #[default]
    Int4Uniform,
    /// QLoRA's NormalFloat-4 grid (quantiles of a standard normal,
    /// normalized to `[-1, 1]`).
    Nf4,
    /// OCP microscaling FP4 (E2M1): `±{0, 0.5, 1, 1.5, 2, 3, 4, 6}`
    /// with the nibble's bit 3 as the sign.
    Mxfp4,
}

/// Every built-in codebook, in CLI/bench display order.
pub const CODEBOOKS: [CodebookKind; 3] =
    [CodebookKind::Int4Uniform, CodebookKind::Nf4, CodebookKind::Mxfp4];

impl CodebookKind {
    /// Short stable label used in bench rows, JSON keys, and fixtures.
    pub fn label(self) -> &'static str {
        match self {
            CodebookKind::Int4Uniform => "int4",
            CodebookKind::Nf4 => "nf4",
            CodebookKind::Mxfp4 => "mxfp4",
        }
    }

    /// Parse a CLI `--codebook` argument (the [`Self::label`] strings).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "int4" => Some(CodebookKind::Int4Uniform),
            "nf4" => Some(CodebookKind::Nf4),
            "mxfp4" => Some(CodebookKind::Mxfp4),
            _ => None,
        }
    }

    /// The 16-entry value table for this grid.
    pub fn table(self) -> &'static Codebook {
        match self {
            CodebookKind::Int4Uniform => &INT4_UNIFORM,
            CodebookKind::Nf4 => &NF4,
            CodebookKind::Mxfp4 => &MXFP4,
        }
    }

    /// Whether codes on this grid decode identically through the
    /// shift-mask tier (only the uniform grid does; everything else
    /// requires the LUT decoders).
    pub fn is_uniform(self) -> bool {
        self == CodebookKind::Int4Uniform
    }
}

/// Which nibble-decode tier a GEMM call runs: the original shift-mask
/// arithmetic expansion or the codebook table lookup. Part of
/// [`crate::kernel::Blocking`], so it flows into `GemmPlan`/`PlanCache`
/// keys; a non-uniform [`CodebookKind`] on the weights forces
/// [`DecoderKind::Lut`] regardless of this knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DecoderKind {
    /// `(q - z) * s` via shift + mask + int→float convert (PR 5 tier).
    #[default]
    ShiftMask,
    /// `(table[q] - z) * s` via in-register 16-entry table shuffle.
    Lut,
}

/// Both decode tiers, in display order.
pub const DECODERS: [DecoderKind; 2] = [DecoderKind::ShiftMask, DecoderKind::Lut];

impl DecoderKind {
    /// Short stable label used in bench rows and the calibration table.
    pub fn label(self) -> &'static str {
        match self {
            DecoderKind::ShiftMask => "shift-mask",
            DecoderKind::Lut => "lut",
        }
    }
}

/// A 16-entry lookup table mapping a nibble code to its decoded value.
///
/// Decode applies the shared affine `(values[q] - zero) * scale`; for
/// the built-in non-uniform grids the zero-points are all `0.0` (the
/// grids are symmetric), for [`CodebookKind::Int4Uniform`] the table is
/// the identity and the stock asymmetric zero-points apply unchanged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Codebook {
    /// The grid this table belongs to.
    pub kind: CodebookKind,
    /// `values[q]` = decoded value of nibble code `q`.
    pub values: [f32; 16],
}

impl Codebook {
    /// Largest magnitude on the grid — the absmax quantization divisor.
    pub fn absmax(&self) -> f32 {
        self.values.iter().fold(0f32, |m, v| m.max(v.abs()))
    }
}

/// The identity grid: `values[q] = q as f32`.
pub static INT4_UNIFORM: Codebook = Codebook {
    kind: CodebookKind::Int4Uniform,
    values: [
        0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0,
    ],
};

/// QLoRA's NF4 grid (Dettmers et al., exact bitsandbytes constants).
pub static NF4: Codebook = Codebook {
    kind: CodebookKind::Nf4,
    values: [
        -1.0,
        -0.696_192_8,
        -0.525_073_05,
        -0.394_917_5,
        -0.284_441_38,
        -0.184_773_43,
        -0.091_050_036,
        0.0,
        0.079_580_3,
        0.160_930_2,
        0.246_112_3,
        0.337_915_24,
        0.440_709_83,
        0.562_617,
        0.722_956_84,
        1.0,
    ],
};

/// OCP MXFP4 (E2M1): sign in nibble bit 3, magnitudes
/// `{0, 0.5, 1, 1.5, 2, 3, 4, 6}` in bits 0-2.
pub static MXFP4: Codebook = Codebook {
    kind: CodebookKind::Mxfp4,
    values: [
        0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0,
    ],
};

/// Nearest grid entry for `t` (in code space, i.e. already divided by
/// the group scale): first minimizing index wins ties, matching
/// `np.argmin` in the Python fixture mirror.
pub fn nearest_code(cb: &Codebook, t: f32) -> i32 {
    let mut best = 0usize;
    let mut best_d = (t - cb.values[0]).abs();
    for (q, &v) in cb.values.iter().enumerate().skip(1) {
        let d = (t - v).abs();
        if d < best_d {
            best_d = d;
            best = q;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int4_uniform_is_identity() {
        for q in 0..16 {
            assert_eq!(INT4_UNIFORM.values[q], q as f32);
        }
        assert_eq!(INT4_UNIFORM.absmax(), 15.0);
    }

    #[test]
    fn nonuniform_grids_are_symmetric_with_zero() {
        for cb in [&NF4, &MXFP4] {
            assert!(cb.values.contains(&0.0), "{:?} lacks exact zero", cb.kind);
            assert_eq!(cb.values.len(), 16);
        }
        assert_eq!(NF4.absmax(), 1.0);
        assert_eq!(MXFP4.absmax(), 6.0);
        // MXFP4 sign structure: bit 3 flips the sign of the magnitude.
        for q in 0..8 {
            assert_eq!(MXFP4.values[q + 8], -MXFP4.values[q]);
        }
    }

    #[test]
    fn nf4_is_strictly_increasing() {
        for q in 1..16 {
            assert!(NF4.values[q] > NF4.values[q - 1]);
        }
    }

    #[test]
    fn nearest_code_picks_first_on_tie() {
        // Midpoint between uniform entries 3 and 4 rounds to 3 (first
        // minimizer), the NumPy argmin convention.
        assert_eq!(nearest_code(&INT4_UNIFORM, 3.5), 3);
        assert_eq!(nearest_code(&INT4_UNIFORM, -10.0), 0);
        assert_eq!(nearest_code(&INT4_UNIFORM, 99.0), 15);
        assert_eq!(nearest_code(&NF4, -1.0), 0);
        assert_eq!(nearest_code(&NF4, 1.0), 15);
        assert_eq!(nearest_code(&MXFP4, -5.9), 15);
    }

    #[test]
    fn labels_parse_back() {
        for kind in CODEBOOKS {
            assert_eq!(CodebookKind::parse(kind.label()), Some(kind));
            assert_eq!(kind.table().kind, kind);
        }
        assert_eq!(CodebookKind::parse("fp8"), None);
    }
}
