//! Tile-order decode helpers for the native kernel backend
//! ([`crate::kernel`]): in-register dequantization straight out of the
//! packed word layouts.
//!
//! Two decoders, one per executable GEMM path:
//!
//! * [`decode_quick_run_into`] — consumes one 16-word run of the
//!   [`super::pack_quick`] interleaved stream and emits a 16x8 f32
//!   fragment **already in microkernel tile order** (k-major rows, the 8
//!   logical columns of one word in slot order). Because the offline
//!   interleave put the words in fragment-consumption order and the
//!   dequant-aware nibble reorder put the nibbles in logical order, the
//!   decode is a straight sequential scan: no gather, no runtime
//!   permutation — the CPU analogue of the paper's direct DRAM→register
//!   `ldmatrix`-free load (§3.2).
//! * [`decode_awq_word_into`] — consumes one stock-AWQ word
//!   ([`super::pack_awq`], FT nibble order) and *scatters* the 8 values
//!   through [`FT_ORDER`] to recover logical column order — the runtime
//!   unscramble the baseline kernel pays on every word, which the QUICK
//!   layout moved offline.
//!
//! Both apply the per-group `(q - zero) * scale` affine inline, so the
//! caller never materializes raw codes.
//!
//! Each decoder exists in two bit-identical implementations: a portable
//! scalar loop (`*_scalar`) and, on x86_64 with AVX2, a vectorized one
//! that expands all 8 nibbles of a word in one `vpsrlvd` + mask +
//! `cvtdq2ps` sequence (the FLUTE-style in-register LUT-free expansion).
//! The SIMD AWQ variant still pays the FT-order unscramble — as a
//! `vpermps` — mirroring how the GPU baseline pays it as a shuffle. The
//! un-suffixed entry points dispatch on a one-time CPUID probe; the
//! kernel layer pins either path via `Blocking::simd`
//! ([`select_quick_decoder`] / [`select_awq_decoder`]).

use std::sync::OnceLock;

use super::codebook::Codebook;
use super::interleave::MMA_K;
use super::pack::{FT_ORDER, PACK_FACTOR};

/// Rows of one interleaved fragment run (the `mma.m16n8k16` K-tile).
pub const TILE_ROWS: usize = MMA_K;
/// Columns of one fragment run (logical columns per packed word).
pub const TILE_COLS: usize = PACK_FACTOR;

/// Signature shared by the quick-run decoders (scalar and SIMD): see
/// [`decode_quick_run_into`] for the argument contract.
pub type DecodeQuickFn = fn(&[u32], usize, usize, &[f32], &[f32], usize, usize, &mut [f32]);

/// Signature shared by the AWQ word decoders (scalar and SIMD): see
/// [`decode_awq_word_into`] for the argument contract.
pub type DecodeAwqFn = fn(u32, &[f32], &[f32], &mut [f32]);

/// Signature shared by the LUT quick-run decoders: the
/// [`decode_quick_run_into`] contract plus the 16-entry [`Codebook`]
/// whose values the nibbles index (`(table[q] - z) * s`).
pub type DecodeQuickLutFn =
    fn(&[u32], usize, usize, &[f32], &[f32], usize, usize, &Codebook, &mut [f32]);

/// Signature shared by the LUT AWQ word decoders: the
/// [`decode_awq_word_into`] contract plus the [`Codebook`].
pub type DecodeAwqLutFn = fn(u32, &[f32], &[f32], &Codebook, &mut [f32]);

/// Resolve a function pointer once per process: the first call probes
/// the CPU-feature tier, every later call is a single atomic load — the
/// per-GEMM dispatch does no repeated feature detection.
macro_rules! memoized_tier {
    ($simd:expr, $cache:ident : $ty:ty, $fast:expr, $slow:expr) => {{
        #[cfg(target_arch = "x86_64")]
        {
            static $cache: OnceLock<$ty> = OnceLock::new();
            if $simd {
                return *$cache.get_or_init(|| if avx2_available() { $fast } else { $slow });
            }
        }
        let _ = $simd;
        $slow
    }};
}

/// Pick the quick-run decoder: SIMD when requested and supported, the
/// scalar loop otherwise. The two are bit-identical (same `(q - z) * s`
/// f32 arithmetic, no FMA), so this is a pure speed knob. The feature
/// probe is memoized behind a `OnceLock` function pointer: per-call
/// dispatch is one atomic load, never a repeated CPUID.
pub fn select_quick_decoder(simd: bool) -> DecodeQuickFn {
    memoized_tier!(
        simd,
        QUICK_SIMD: DecodeQuickFn,
        decode_quick_run_into_avx2,
        decode_quick_run_into_scalar
    )
}

/// Pick the AWQ word decoder (same contract as [`select_quick_decoder`]).
pub fn select_awq_decoder(simd: bool) -> DecodeAwqFn {
    memoized_tier!(
        simd,
        AWQ_SIMD: DecodeAwqFn,
        decode_awq_word_into_avx2,
        decode_awq_word_into_scalar
    )
}

/// Pick the LUT quick-run decoder (FLUTE-style table shuffle): SIMD
/// expands the lookup as a `vpermps` pair over the codebook halves with
/// a sign-bit blend; scalar walks the 16-entry table. With the
/// [`CodebookKind::Int4Uniform`](super::CodebookKind::Int4Uniform)
/// table both are bit-identical to the shift-mask tier (the table is
/// the identity and the affine is the same `(v - z) * s`, no FMA).
pub fn select_quick_lut_decoder(simd: bool) -> DecodeQuickLutFn {
    memoized_tier!(
        simd,
        QUICK_LUT_SIMD: DecodeQuickLutFn,
        decode_quick_run_into_lut_avx2,
        decode_quick_run_into_lut_scalar
    )
}

/// Pick the LUT AWQ word decoder (same tiering as
/// [`select_quick_lut_decoder`], still paying the FT-order unscramble).
pub fn select_awq_lut_decoder(simd: bool) -> DecodeAwqLutFn {
    memoized_tier!(
        simd,
        AWQ_LUT_SIMD: DecodeAwqLutFn,
        decode_awq_word_into_lut_avx2,
        decode_awq_word_into_lut_scalar
    )
}

/// One-time cached CPUID probe for the "avx2" runtime tier — AVX2 *and*
/// FMA, even though the decoders themselves use no FMA, so this single
/// gate serves both the decoders and the microkernel
/// (`kernel::simd_level`): one coherent tier, and bench rows labeled
/// `scalar` really run scalar everywhere.
#[cfg(target_arch = "x86_64")]
pub(crate) fn avx2_available() -> bool {
    use std::sync::OnceLock;
    static AVAIL: OnceLock<bool> = OnceLock::new();
    *AVAIL.get_or_init(|| {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    })
}

/// Word offset of the 16-word run for k-tile `kt`, word-column `wj` in a
/// [`super::pack_quick`] stream with `w_total = n / 8` word-columns.
///
/// This is the closed form of the fragment interleave: run `(kt, wj)`
/// occupies stream words `[(kt*w_total + wj)*16, ...+16)`.
#[inline]
pub fn quick_run_offset(kt: usize, wj: usize, w_total: usize) -> usize {
    (kt * w_total + wj) * TILE_ROWS
}

/// Decode one interleaved 16-word run into a 16x8 row-major f32 fragment,
/// applying per-group scales/zeros inline.
///
/// * `run` — the 16 stream words at [`quick_run_offset`]`(kt, wj, w_total)`.
/// * `row0` — absolute K row of the tile's first row (`kt * 16`, offset by
///   any K-blocking the caller applies).
/// * `col0` — absolute N column of the fragment's first column (`wj * 8`).
/// * `scales` / `zeros` — row-major `(k / group_size, n)` group metadata.
///
/// `frag[r * 8 + p]` receives the dequantized weight for logical element
/// `(row0 + r, col0 + p)` — exactly the order the register-tiled
/// microkernel consumes, so no permutation happens at runtime. `frag`
/// must hold at least `16 * 8` values (callers stack several runs into
/// one K-strip panel).
///
/// Dispatches to the SIMD implementation when the host supports it; use
/// [`decode_quick_run_into_scalar`] to pin the portable loop.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn decode_quick_run_into(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    frag: &mut [f32],
) {
    select_quick_decoder(true)(run, row0, col0, scales, zeros, n, group_size, frag)
}

/// Portable scalar implementation of [`decode_quick_run_into`] — also the
/// reference the SIMD variant is property-tested against (bit-identical).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn decode_quick_run_into_scalar(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    frag: &mut [f32],
) {
    debug_assert_eq!(run.len(), TILE_ROWS);
    debug_assert!(frag.len() >= TILE_ROWS * TILE_COLS);
    for (r, &word) in run.iter().enumerate() {
        let gbase = ((row0 + r) / group_size) * n + col0;
        let s = &scales[gbase..gbase + TILE_COLS];
        let z = &zeros[gbase..gbase + TILE_COLS];
        let out = &mut frag[r * TILE_COLS..(r + 1) * TILE_COLS];
        for p in 0..TILE_COLS {
            let q = ((word >> (4 * p)) & 0xF) as f32;
            out[p] = (q - z[p]) * s[p];
        }
    }
}

/// AVX2 implementation of [`decode_quick_run_into`]: one variable shift
/// expands all 8 nibbles of a word at once; the group metadata row and
/// the fragment row are each a single 256-bit load/store.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn decode_quick_run_into_avx2(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    frag: &mut [f32],
) {
    assert_eq!(run.len(), TILE_ROWS);
    assert!(frag.len() >= TILE_ROWS * TILE_COLS);
    let last_gbase = ((row0 + TILE_ROWS - 1) / group_size) * n + col0;
    assert!(scales.len() >= last_gbase + TILE_COLS && zeros.len() >= last_gbase + TILE_COLS);
    // SAFETY: AVX2 presence was checked by `select_quick_decoder`; the
    // asserts above bound every load/store offset used in the body.
    unsafe {
        decode_quick_run_into_avx2_body(run, row0, col0, scales, zeros, n, group_size, frag)
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn decode_quick_run_into_avx2_body(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    frag: &mut [f32],
) {
    use std::arch::x86_64::*;
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let mask = _mm256_set1_epi32(0xF);
    let fp = frag.as_mut_ptr();
    for (r, &word) in run.iter().enumerate() {
        let gbase = ((row0 + r) / group_size) * n + col0;
        let s = _mm256_loadu_ps(scales.as_ptr().add(gbase));
        let z = _mm256_loadu_ps(zeros.as_ptr().add(gbase));
        let q = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(word as i32), shifts), mask);
        let v = _mm256_mul_ps(_mm256_sub_ps(_mm256_cvtepi32_ps(q), z), s);
        _mm256_storeu_ps(fp.add(r * TILE_COLS), v);
    }
}

/// Decode one stock-AWQ word (FT nibble order) into 8 dequantized f32s in
/// *logical* column order, scattering through [`FT_ORDER`] — the runtime
/// permutation the baseline write-back kernel pays per word.
///
/// `s8` / `z8` hold the group's scales/zeros for the word's 8 logical
/// columns; `out` receives logical columns `8*wj .. 8*wj + 8`.
///
/// Dispatches to the SIMD implementation when the host supports it; use
/// [`decode_awq_word_into_scalar`] to pin the portable loop.
#[inline]
pub fn decode_awq_word_into(word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
    select_awq_decoder(true)(word, s8, z8, out)
}

/// Portable scalar implementation of [`decode_awq_word_into`] — also the
/// reference the SIMD variant is property-tested against (bit-identical).
#[inline]
pub fn decode_awq_word_into_scalar(word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
    debug_assert!(s8.len() >= TILE_COLS && z8.len() >= TILE_COLS && out.len() >= TILE_COLS);
    for (p, &dst) in FT_ORDER.iter().enumerate() {
        let q = ((word >> (4 * p)) & 0xF) as f32;
        out[dst] = (q - z8[dst]) * s8[dst];
    }
}

/// `FT_INV[j]` = the nibble slot holding logical column `j`
/// (the inverse of [`FT_ORDER`]): `out[j] = nibbles[FT_INV[j]]`.
#[cfg(target_arch = "x86_64")]
const FT_INV: [i32; PACK_FACTOR] = [0, 4, 1, 5, 2, 6, 3, 7];

/// AVX2 implementation of [`decode_awq_word_into`]: the FT-order
/// unscramble becomes a `vpermps` — still a per-word runtime permutation,
/// exactly the cost class the QUICK layout moves offline.
#[cfg(target_arch = "x86_64")]
fn decode_awq_word_into_avx2(word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
    assert!(s8.len() >= TILE_COLS && z8.len() >= TILE_COLS && out.len() >= TILE_COLS);
    // SAFETY: AVX2 presence was checked by `select_awq_decoder`; the
    // assert above bounds the three 8-float loads/stores.
    unsafe { decode_awq_word_into_avx2_body(word, s8, z8, out) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_awq_word_into_avx2_body(word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let mask = _mm256_set1_epi32(0xF);
    let perm = _mm256_setr_epi32(
        FT_INV[0], FT_INV[1], FT_INV[2], FT_INV[3], FT_INV[4], FT_INV[5], FT_INV[6], FT_INV[7],
    );
    let q = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(word as i32), shifts), mask);
    // Unscramble FT slot order -> logical column order, then apply the
    // affine with straight (logical-order) metadata loads.
    let ql = _mm256_permutevar8x32_ps(_mm256_cvtepi32_ps(q), perm);
    let s = _mm256_loadu_ps(s8.as_ptr());
    let z = _mm256_loadu_ps(z8.as_ptr());
    _mm256_storeu_ps(out.as_mut_ptr(), _mm256_mul_ps(_mm256_sub_ps(ql, z), s));
}

/// LUT tier of [`decode_quick_run_into`]: decode one interleaved
/// 16-word run against a 16-entry [`Codebook`], `frag[r*8+p] =
/// (cb.values[q] - z) * s`. Same argument contract, tile order, and
/// group-metadata addressing as the shift-mask tier; with the uniform
/// INT4 table the output is bit-identical to it.
///
/// Portable scalar implementation — also the reference the SIMD
/// variant is property-tested against (bit-identical).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn decode_quick_run_into_lut_scalar(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    cb: &Codebook,
    frag: &mut [f32],
) {
    debug_assert_eq!(run.len(), TILE_ROWS);
    debug_assert!(frag.len() >= TILE_ROWS * TILE_COLS);
    let lut = &cb.values;
    for (r, &word) in run.iter().enumerate() {
        let gbase = ((row0 + r) / group_size) * n + col0;
        let s = &scales[gbase..gbase + TILE_COLS];
        let z = &zeros[gbase..gbase + TILE_COLS];
        let out = &mut frag[r * TILE_COLS..(r + 1) * TILE_COLS];
        for p in 0..TILE_COLS {
            let q = ((word >> (4 * p)) & 0xF) as usize;
            out[p] = (lut[q] - z[p]) * s[p];
        }
    }
}

/// AVX2 implementation of the LUT quick-run decode: the 16-entry table
/// lives in two `ymm` registers for the whole run; each word's 8
/// nibbles index both halves via `vpermps` (which reads only the low 3
/// index bits, so no mask is needed) and nibble bit 3 — shifted into
/// the sign position — blends the halves. No gather, no table memory
/// traffic after the two initial loads.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
fn decode_quick_run_into_lut_avx2(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    cb: &Codebook,
    frag: &mut [f32],
) {
    assert_eq!(run.len(), TILE_ROWS);
    assert!(frag.len() >= TILE_ROWS * TILE_COLS);
    let last_gbase = ((row0 + TILE_ROWS - 1) / group_size) * n + col0;
    assert!(scales.len() >= last_gbase + TILE_COLS && zeros.len() >= last_gbase + TILE_COLS);
    // SAFETY: AVX2 presence was checked by `select_quick_lut_decoder`;
    // the asserts above bound every load/store offset in the body.
    unsafe {
        decode_quick_run_into_lut_avx2_body(run, row0, col0, scales, zeros, n, group_size, cb, frag)
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn decode_quick_run_into_lut_avx2_body(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    cb: &Codebook,
    frag: &mut [f32],
) {
    use std::arch::x86_64::*;
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let lo = _mm256_loadu_ps(cb.values.as_ptr());
    let hi = _mm256_loadu_ps(cb.values.as_ptr().add(8));
    let fp = frag.as_mut_ptr();
    for (r, &word) in run.iter().enumerate() {
        let gbase = ((row0 + r) / group_size) * n + col0;
        let s = _mm256_loadu_ps(scales.as_ptr().add(gbase));
        let z = _mm256_loadu_ps(zeros.as_ptr().add(gbase));
        // Lane p holds the word shifted right by 4p: nibble p in bits
        // 0-3 with the higher nibbles as garbage above — harmless,
        // because vpermps reads only bits 0-2 and the sign-select shift
        // below discards everything past bit 3.
        let q = _mm256_srlv_epi32(_mm256_set1_epi32(word as i32), shifts);
        let vlo = _mm256_permutevar8x32_ps(lo, q);
        let vhi = _mm256_permutevar8x32_ps(hi, q);
        let sel = _mm256_castsi256_ps(_mm256_slli_epi32(q, 28));
        let v = _mm256_blendv_ps(vlo, vhi, sel);
        _mm256_storeu_ps(fp.add(r * TILE_COLS), _mm256_mul_ps(_mm256_sub_ps(v, z), s));
    }
}

/// LUT tier of [`decode_awq_word_into`]: decode one stock-AWQ word
/// against a [`Codebook`], still scattering through [`FT_ORDER`] to
/// recover logical column order. Portable scalar implementation — the
/// bit-identical reference for the SIMD variant.
#[inline]
pub fn decode_awq_word_into_lut_scalar(
    word: u32,
    s8: &[f32],
    z8: &[f32],
    cb: &Codebook,
    out: &mut [f32],
) {
    debug_assert!(s8.len() >= TILE_COLS && z8.len() >= TILE_COLS && out.len() >= TILE_COLS);
    let lut = &cb.values;
    for (p, &dst) in FT_ORDER.iter().enumerate() {
        let q = ((word >> (4 * p)) & 0xF) as usize;
        out[dst] = (lut[q] - z8[dst]) * s8[dst];
    }
}

/// AVX2 implementation of the LUT AWQ word decode: table shuffle as in
/// the quick variant, then the FT-order unscramble as a `vpermps` —
/// the baseline still pays its runtime permutation on top of the LUT.
#[cfg(target_arch = "x86_64")]
fn decode_awq_word_into_lut_avx2(word: u32, s8: &[f32], z8: &[f32], cb: &Codebook, out: &mut [f32]) {
    assert!(s8.len() >= TILE_COLS && z8.len() >= TILE_COLS && out.len() >= TILE_COLS);
    // SAFETY: AVX2 presence was checked by `select_awq_lut_decoder`;
    // the assert above bounds the 8-float loads/stores.
    unsafe { decode_awq_word_into_lut_avx2_body(word, s8, z8, cb, out) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_awq_word_into_lut_avx2_body(
    word: u32,
    s8: &[f32],
    z8: &[f32],
    cb: &Codebook,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let shifts = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
    let perm = _mm256_setr_epi32(
        FT_INV[0], FT_INV[1], FT_INV[2], FT_INV[3], FT_INV[4], FT_INV[5], FT_INV[6], FT_INV[7],
    );
    let lo = _mm256_loadu_ps(cb.values.as_ptr());
    let hi = _mm256_loadu_ps(cb.values.as_ptr().add(8));
    let q = _mm256_srlv_epi32(_mm256_set1_epi32(word as i32), shifts);
    let vlo = _mm256_permutevar8x32_ps(lo, q);
    let vhi = _mm256_permutevar8x32_ps(hi, q);
    let sel = _mm256_castsi256_ps(_mm256_slli_epi32(q, 28));
    let v = _mm256_blendv_ps(vlo, vhi, sel);
    // Unscramble FT slot order -> logical column order, then the affine
    // with straight (logical-order) metadata loads.
    let vl = _mm256_permutevar8x32_ps(v, perm);
    let s = _mm256_loadu_ps(s8.as_ptr());
    let z = _mm256_loadu_ps(z8.as_ptr());
    _mm256_storeu_ps(out.as_mut_ptr(), _mm256_mul_ps(_mm256_sub_ps(vl, z), s));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{
        dequantize, pack_awq, pack_quick, quantize_groupwise, quantize_groupwise_codebook,
        CodebookKind, CODEBOOKS,
    };

    fn rand_w(k: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..k * n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn quick_run_decodes_to_dequantized_tile() {
        let (k, n, g) = (64, 32, 32);
        let t = quantize_groupwise(&rand_w(k, n, 3), k, n, g);
        let stream = pack_quick(&t.codes, k, n);
        let reference = dequantize(&t);
        let w_total = n / TILE_COLS;
        let mut frag = [0f32; TILE_ROWS * TILE_COLS];
        for kt in 0..k / TILE_ROWS {
            for wj in 0..w_total {
                let off = quick_run_offset(kt, wj, w_total);
                decode_quick_run_into(
                    &stream[off..off + TILE_ROWS],
                    kt * TILE_ROWS,
                    wj * TILE_COLS,
                    &t.scales,
                    &t.zeros,
                    n,
                    g,
                    &mut frag,
                );
                for r in 0..TILE_ROWS {
                    for p in 0..TILE_COLS {
                        let want = reference[(kt * TILE_ROWS + r) * n + wj * TILE_COLS + p];
                        assert_eq!(frag[r * TILE_COLS + p], want, "kt={kt} wj={wj} r={r} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn awq_word_decodes_to_logical_order() {
        let (k, n, g) = (32, 16, 16);
        let t = quantize_groupwise(&rand_w(k, n, 9), k, n, g);
        let words = pack_awq(&t.codes, k, n);
        let reference = dequantize(&t);
        let w_total = n / TILE_COLS;
        let mut row = vec![0f32; TILE_COLS];
        for r in 0..k {
            let gbase = (r / g) * n;
            for wj in 0..w_total {
                let c0 = wj * TILE_COLS;
                decode_awq_word_into(
                    words[r * w_total + wj],
                    &t.scales[gbase + c0..gbase + c0 + TILE_COLS],
                    &t.zeros[gbase + c0..gbase + c0 + TILE_COLS],
                    &mut row,
                );
                assert_eq!(row, reference[r * n + c0..r * n + c0 + TILE_COLS], "r={r} wj={wj}");
            }
        }
    }

    #[test]
    fn simd_decoders_are_bit_identical_to_scalar() {
        // Same (q - z) * s arithmetic, no FMA: the SIMD decoders must be
        // *bit*-equal, not just close.
        let (k, n, g) = (64, 40, 32);
        let t = quantize_groupwise(&rand_w(k, n, 17), k, n, g);
        let stream = pack_quick(&t.codes, k, n);
        let words = pack_awq(&t.codes, k, n);
        let w_total = n / TILE_COLS;
        let quick_simd = select_quick_decoder(true);
        let awq_simd = select_awq_decoder(true);
        let mut a = [0f32; TILE_ROWS * TILE_COLS];
        let mut b = [0f32; TILE_ROWS * TILE_COLS];
        for kt in 0..k / TILE_ROWS {
            for wj in 0..w_total {
                let off = quick_run_offset(kt, wj, w_total);
                let run = &stream[off..off + TILE_ROWS];
                decode_quick_run_into_scalar(
                    run,
                    kt * TILE_ROWS,
                    wj * TILE_COLS,
                    &t.scales,
                    &t.zeros,
                    n,
                    g,
                    &mut a,
                );
                quick_simd(run, kt * TILE_ROWS, wj * TILE_COLS, &t.scales, &t.zeros, n, g, &mut b);
                assert_eq!(a, b, "quick kt={kt} wj={wj}");
            }
        }
        let (mut ra, mut rb) = (vec![0f32; TILE_COLS], vec![0f32; TILE_COLS]);
        for r in 0..k {
            let gbase = (r / g) * n;
            for wj in 0..w_total {
                let c0 = wj * TILE_COLS;
                let s8 = &t.scales[gbase + c0..gbase + c0 + TILE_COLS];
                let z8 = &t.zeros[gbase + c0..gbase + c0 + TILE_COLS];
                decode_awq_word_into_scalar(words[r * w_total + wj], s8, z8, &mut ra);
                awq_simd(words[r * w_total + wj], s8, z8, &mut rb);
                assert_eq!(ra, rb, "awq r={r} wj={wj}");
            }
        }
    }

    #[test]
    fn ft_inv_inverts_ft_order() {
        #[cfg(target_arch = "x86_64")]
        for (p, &dst) in FT_ORDER.iter().enumerate() {
            assert_eq!(FT_INV[dst] as usize, p);
        }
    }

    #[test]
    fn lut_int4_is_bit_identical_to_shift_mask() {
        // The identity codebook must reproduce the shift-mask tier
        // *bit*-for-bit, in every (SIMD, scalar) pairing, both layouts.
        let (k, n, g) = (64, 40, 32);
        let t = quantize_groupwise(&rand_w(k, n, 23), k, n, g);
        let cb = CodebookKind::Int4Uniform.table();
        let stream = pack_quick(&t.codes, k, n);
        let words = pack_awq(&t.codes, k, n);
        let w_total = n / TILE_COLS;
        let mut a = [0f32; TILE_ROWS * TILE_COLS];
        let mut b = [0f32; TILE_ROWS * TILE_COLS];
        for simd in [false, true] {
            let shift = select_quick_decoder(simd);
            let lut = select_quick_lut_decoder(simd);
            for kt in 0..k / TILE_ROWS {
                for wj in 0..w_total {
                    let off = quick_run_offset(kt, wj, w_total);
                    let run = &stream[off..off + TILE_ROWS];
                    shift(run, kt * TILE_ROWS, wj * TILE_COLS, &t.scales, &t.zeros, n, g, &mut a);
                    lut(run, kt * TILE_ROWS, wj * TILE_COLS, &t.scales, &t.zeros, n, g, cb, &mut b);
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "simd={simd} kt={kt} wj={wj}");
                    }
                }
            }
            let shift_awq = select_awq_decoder(simd);
            let lut_awq = select_awq_lut_decoder(simd);
            let (mut ra, mut rb) = (vec![0f32; TILE_COLS], vec![0f32; TILE_COLS]);
            for r in 0..k {
                let gbase = (r / g) * n;
                for wj in 0..w_total {
                    let c0 = wj * TILE_COLS;
                    let s8 = &t.scales[gbase + c0..gbase + c0 + TILE_COLS];
                    let z8 = &t.zeros[gbase + c0..gbase + c0 + TILE_COLS];
                    shift_awq(words[r * w_total + wj], s8, z8, &mut ra);
                    lut_awq(words[r * w_total + wj], s8, z8, cb, &mut rb);
                    for (x, y) in ra.iter().zip(&rb) {
                        assert_eq!(x.to_bits(), y.to_bits(), "awq simd={simd} r={r} wj={wj}");
                    }
                }
            }
        }
    }

    #[test]
    fn lut_simd_is_bit_identical_to_lut_scalar_every_codebook() {
        let (k, n, g) = (48, 24, 16);
        for kind in CODEBOOKS {
            let t = quantize_groupwise_codebook(&rand_w(k, n, 31), k, n, g, kind);
            let cb = kind.table();
            let stream = pack_quick(&t.codes, k, n);
            let words = pack_awq(&t.codes, k, n);
            let w_total = n / TILE_COLS;
            let quick_simd = select_quick_lut_decoder(true);
            let awq_simd = select_awq_lut_decoder(true);
            let mut a = [0f32; TILE_ROWS * TILE_COLS];
            let mut b = [0f32; TILE_ROWS * TILE_COLS];
            for kt in 0..k / TILE_ROWS {
                for wj in 0..w_total {
                    let off = quick_run_offset(kt, wj, w_total);
                    let run = &stream[off..off + TILE_ROWS];
                    decode_quick_run_into_lut_scalar(
                        run,
                        kt * TILE_ROWS,
                        wj * TILE_COLS,
                        &t.scales,
                        &t.zeros,
                        n,
                        g,
                        cb,
                        &mut a,
                    );
                    quick_simd(
                        run,
                        kt * TILE_ROWS,
                        wj * TILE_COLS,
                        &t.scales,
                        &t.zeros,
                        n,
                        g,
                        cb,
                        &mut b,
                    );
                    for (x, y) in a.iter().zip(&b) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{kind:?} kt={kt} wj={wj}");
                    }
                }
            }
            let (mut ra, mut rb) = (vec![0f32; TILE_COLS], vec![0f32; TILE_COLS]);
            for r in 0..k {
                let gbase = (r / g) * n;
                for wj in 0..w_total {
                    let c0 = wj * TILE_COLS;
                    let s8 = &t.scales[gbase + c0..gbase + c0 + TILE_COLS];
                    let z8 = &t.zeros[gbase + c0..gbase + c0 + TILE_COLS];
                    decode_awq_word_into_lut_scalar(words[r * w_total + wj], s8, z8, cb, &mut ra);
                    awq_simd(words[r * w_total + wj], s8, z8, cb, &mut rb);
                    for (x, y) in ra.iter().zip(&rb) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{kind:?} awq r={r} wj={wj}");
                    }
                }
            }
        }
    }

    #[test]
    fn lut_decode_matches_codebook_dequantize() {
        // Decoding the interleaved stream through the LUT tier must
        // reproduce `dequantize` exactly for the non-uniform grids.
        let (k, n, g) = (32, 16, 16);
        for kind in [CodebookKind::Nf4, CodebookKind::Mxfp4] {
            let t = quantize_groupwise_codebook(&rand_w(k, n, 41), k, n, g, kind);
            let reference = dequantize(&t);
            let stream = pack_quick(&t.codes, k, n);
            let w_total = n / TILE_COLS;
            let decode = select_quick_lut_decoder(true);
            let mut frag = [0f32; TILE_ROWS * TILE_COLS];
            for kt in 0..k / TILE_ROWS {
                for wj in 0..w_total {
                    let off = quick_run_offset(kt, wj, w_total);
                    decode(
                        &stream[off..off + TILE_ROWS],
                        kt * TILE_ROWS,
                        wj * TILE_COLS,
                        &t.scales,
                        &t.zeros,
                        n,
                        g,
                        kind.table(),
                        &mut frag,
                    );
                    for r in 0..TILE_ROWS {
                        for p in 0..TILE_COLS {
                            let want = reference[(kt * TILE_ROWS + r) * n + wj * TILE_COLS + p];
                            assert_eq!(
                                frag[r * TILE_COLS + p],
                                want,
                                "{kind:?} kt={kt} wj={wj} r={r} p={p}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn run_offsets_tile_the_stream_exactly() {
        let (k, w_total) = (48, 4);
        let mut seen = vec![false; k * w_total];
        for kt in 0..k / TILE_ROWS {
            for wj in 0..w_total {
                let off = quick_run_offset(kt, wj, w_total);
                for covered in seen.iter_mut().skip(off).take(TILE_ROWS) {
                    assert!(!*covered);
                    *covered = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }
}
