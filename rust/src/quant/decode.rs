//! Tile-order decode helpers for the native kernel backend
//! ([`crate::kernel`]): in-register dequantization straight out of the
//! packed word layouts.
//!
//! Two decoders, one per executable GEMM path:
//!
//! * [`decode_quick_run_into`] — consumes one 16-word run of the
//!   [`super::pack_quick`] interleaved stream and emits a 16x8 f32
//!   fragment **already in microkernel tile order** (k-major rows, the 8
//!   logical columns of one word in slot order). Because the offline
//!   interleave put the words in fragment-consumption order and the
//!   dequant-aware nibble reorder put the nibbles in logical order, the
//!   decode is a straight sequential scan: no gather, no runtime
//!   permutation — the CPU analogue of the paper's direct DRAM→register
//!   `ldmatrix`-free load (§3.2).
//! * [`decode_awq_word_into`] — consumes one stock-AWQ word
//!   ([`super::pack_awq`], FT nibble order) and *scatters* the 8 values
//!   through [`FT_ORDER`] to recover logical column order — the runtime
//!   unscramble the baseline kernel pays on every word, which the QUICK
//!   layout moved offline.
//!
//! Both apply the per-group `(q - zero) * scale` affine inline, so the
//! caller never materializes raw codes.

use super::interleave::MMA_K;
use super::pack::{FT_ORDER, PACK_FACTOR};

/// Rows of one interleaved fragment run (the `mma.m16n8k16` K-tile).
pub const TILE_ROWS: usize = MMA_K;
/// Columns of one fragment run (logical columns per packed word).
pub const TILE_COLS: usize = PACK_FACTOR;

/// Word offset of the 16-word run for k-tile `kt`, word-column `wj` in a
/// [`super::pack_quick`] stream with `w_total = n / 8` word-columns.
///
/// This is the closed form of the fragment interleave: run `(kt, wj)`
/// occupies stream words `[(kt*w_total + wj)*16, ...+16)`.
#[inline]
pub fn quick_run_offset(kt: usize, wj: usize, w_total: usize) -> usize {
    (kt * w_total + wj) * TILE_ROWS
}

/// Decode one interleaved 16-word run into a 16x8 row-major f32 fragment,
/// applying per-group scales/zeros inline.
///
/// * `run` — the 16 stream words at [`quick_run_offset`]`(kt, wj, w_total)`.
/// * `row0` — absolute K row of the tile's first row (`kt * 16`, offset by
///   any K-blocking the caller applies).
/// * `col0` — absolute N column of the fragment's first column (`wj * 8`).
/// * `scales` / `zeros` — row-major `(k / group_size, n)` group metadata.
///
/// `frag[r * 8 + p]` receives the dequantized weight for logical element
/// `(row0 + r, col0 + p)` — exactly the order the register-tiled
/// microkernel consumes, so no permutation happens at runtime. `frag`
/// must hold at least `16 * 8` values (callers stack several runs into
/// one K-strip panel).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn decode_quick_run_into(
    run: &[u32],
    row0: usize,
    col0: usize,
    scales: &[f32],
    zeros: &[f32],
    n: usize,
    group_size: usize,
    frag: &mut [f32],
) {
    debug_assert_eq!(run.len(), TILE_ROWS);
    debug_assert!(frag.len() >= TILE_ROWS * TILE_COLS);
    for (r, &word) in run.iter().enumerate() {
        let gbase = ((row0 + r) / group_size) * n + col0;
        let s = &scales[gbase..gbase + TILE_COLS];
        let z = &zeros[gbase..gbase + TILE_COLS];
        let out = &mut frag[r * TILE_COLS..(r + 1) * TILE_COLS];
        for p in 0..TILE_COLS {
            let q = ((word >> (4 * p)) & 0xF) as f32;
            out[p] = (q - z[p]) * s[p];
        }
    }
}

/// Decode one stock-AWQ word (FT nibble order) into 8 dequantized f32s in
/// *logical* column order, scattering through [`FT_ORDER`] — the runtime
/// permutation the baseline write-back kernel pays per word.
///
/// `s8` / `z8` hold the group's scales/zeros for the word's 8 logical
/// columns; `out` receives logical columns `8*wj .. 8*wj + 8`.
#[inline]
pub fn decode_awq_word_into(word: u32, s8: &[f32], z8: &[f32], out: &mut [f32]) {
    debug_assert!(s8.len() >= TILE_COLS && z8.len() >= TILE_COLS && out.len() >= TILE_COLS);
    for (p, &dst) in FT_ORDER.iter().enumerate() {
        let q = ((word >> (4 * p)) & 0xF) as f32;
        out[dst] = (q - z8[dst]) * s8[dst];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, pack_awq, pack_quick, quantize_groupwise};

    fn rand_w(k: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..k * n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s >> 11) as f64 / (1u64 << 53) as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn quick_run_decodes_to_dequantized_tile() {
        let (k, n, g) = (64, 32, 32);
        let t = quantize_groupwise(&rand_w(k, n, 3), k, n, g);
        let stream = pack_quick(&t.codes, k, n);
        let reference = dequantize(&t);
        let w_total = n / TILE_COLS;
        let mut frag = [0f32; TILE_ROWS * TILE_COLS];
        for kt in 0..k / TILE_ROWS {
            for wj in 0..w_total {
                let off = quick_run_offset(kt, wj, w_total);
                decode_quick_run_into(
                    &stream[off..off + TILE_ROWS],
                    kt * TILE_ROWS,
                    wj * TILE_COLS,
                    &t.scales,
                    &t.zeros,
                    n,
                    g,
                    &mut frag,
                );
                for r in 0..TILE_ROWS {
                    for p in 0..TILE_COLS {
                        let want = reference[(kt * TILE_ROWS + r) * n + wj * TILE_COLS + p];
                        assert_eq!(frag[r * TILE_COLS + p], want, "kt={kt} wj={wj} r={r} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn awq_word_decodes_to_logical_order() {
        let (k, n, g) = (32, 16, 16);
        let t = quantize_groupwise(&rand_w(k, n, 9), k, n, g);
        let words = pack_awq(&t.codes, k, n);
        let reference = dequantize(&t);
        let w_total = n / TILE_COLS;
        let mut row = vec![0f32; TILE_COLS];
        for r in 0..k {
            let gbase = (r / g) * n;
            for wj in 0..w_total {
                let c0 = wj * TILE_COLS;
                decode_awq_word_into(
                    words[r * w_total + wj],
                    &t.scales[gbase + c0..gbase + c0 + TILE_COLS],
                    &t.zeros[gbase + c0..gbase + c0 + TILE_COLS],
                    &mut row,
                );
                assert_eq!(row, reference[r * n + c0..r * n + c0 + TILE_COLS], "r={r} wj={wj}");
            }
        }
    }

    #[test]
    fn run_offsets_tile_the_stream_exactly() {
        let (k, w_total) = (48, 4);
        let mut seen = vec![false; k * w_total];
        for kt in 0..k / TILE_ROWS {
            for wj in 0..w_total {
                let off = quick_run_offset(kt, wj, w_total);
                for covered in seen.iter_mut().skip(off).take(TILE_ROWS) {
                    assert!(!*covered);
                    *covered = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }
}
